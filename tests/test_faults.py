"""Byzantine fault injection + the robust defense stack.

Covers the fault-trace layer (deterministic schedules, engine parity under
every fault mode), the robust reducers end-to-end (trimmed_mean/median
recover what a plain mean loses at byzantine_frac=0.3), the explicit
``robust_aggregation="mean"`` golden pin, trust/quarantine kill-and-resume,
and the divergence watchdog's rollback-and-recover path.
"""
import json
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

from _resume_prog import check_resume
from repro.common.types import FedConfig
from repro.fed import faults, simulator

GOLDEN_PATH = pathlib.Path(__file__).parent / "data" / "golden_rounds.json"
TOL = dict(rtol=0.0, atol=1e-5)

ATTACK_MODES = [m for m in faults.FAULT_MODES if m != "none"]


def _cfg(engine="loop", **kw):
    base = dict(num_clients=5, rounds=2, method="edgefd", scenario="strong",
                proxy_batch=96, batch_size=32, lr=1e-2, seed=0, engine=engine)
    base.update(kw)
    return FedConfig(**base)


def _run(cfg, n_train=500, n_test=200):
    return simulator.run(cfg, "mnist_feat", n_train=n_train, n_test=n_test)


# ------------------------------------------------------------- fault traces

def test_fault_schedule_deterministic_and_windowed():
    """The trace is a pure function of (seed, round, client): same inputs
    give the same mask, different seeds/rounds differ, the byzantine
    subset is exactly round(frac*C) and round-independent, and the
    start/duration window gates everything."""
    kw = dict(seed=3, mode="scaled", fault_prob=0.3, byzantine_frac=0.2)
    m1 = faults.fault_mask(20, 5, **kw)
    m2 = faults.fault_mask(20, 5, **kw)
    np.testing.assert_array_equal(m1, m2)
    assert not np.array_equal(m1, faults.fault_mask(20, 6, **kw))
    assert not np.array_equal(
        m1, faults.fault_mask(20, 5, **{**kw, "seed": 4}))

    byz = faults.byzantine_ids(20, seed=3, byzantine_frac=0.2)
    assert int(byz.sum()) == 4  # round(0.2 * 20)
    for r in (0, 7, 123):
        m = faults.fault_mask(20, r, seed=3, mode="nan", byzantine_frac=0.2)
        np.testing.assert_array_equal(m, byz)  # fixed subset, every round

    win = dict(seed=0, mode="nan", byzantine_frac=0.5, fault_start=3,
               fault_duration=2)
    assert faults.fault_mask(8, 2, **win) is None
    assert faults.fault_mask(8, 3, **win) is not None
    assert faults.fault_mask(8, 4, **win) is not None
    assert faults.fault_mask(8, 5, **win) is None
    # duration 0 = unbounded
    assert faults.fault_mask(
        8, 999, seed=0, mode="nan", byzantine_frac=0.5, fault_start=3
    ) is not None


def test_injector_corruption_is_scoped_and_deterministic():
    """Only faulty participants' rows change; honest rows are untouched;
    a fault-free round hands back the very same objects (zero-copy)."""
    inj = faults.FaultInjector(6, mode="colluding_flip", seed=0,
                               byzantine_frac=0.34)
    byz = faults.byzantine_ids(6, seed=0, byzantine_frac=0.34)
    rng = np.random.default_rng(0)
    lo = rng.normal(size=(6, 4, 3)).astype(np.float32)
    mk = np.ones((6, 4), bool)
    out_lo, out_mk = inj.corrupt_reports(0, lo, mk, None)
    for c in range(6):
        if byz[c]:
            np.testing.assert_allclose(out_lo[c], -faults.SCALE_FACTOR * lo[c])
        else:
            np.testing.assert_array_equal(out_lo[c], lo[c])
    np.testing.assert_array_equal(out_mk, mk)

    # participants mask gates injection: with every attacker sampled out,
    # the payload passes through as the same objects
    part = ~byz
    same_lo, same_mk = inj.corrupt_reports(1, lo, mk, part)
    assert same_lo is lo and same_mk is mk


def test_stale_replay_caches_and_replays():
    """First faulty round passes through (cache warming); the next faulty
    round replays the cached report; the cache survives a state_dict
    round-trip."""
    inj = faults.FaultInjector(3, mode="stale_replay", seed=0,
                               byzantine_frac=0.4)  # round(0.4*3) = 1 client
    cid = int(np.nonzero(faults.byzantine_ids(3, seed=0,
                                              byzantine_frac=0.4))[0][0])
    r0 = np.full((3, 2, 2), 1.0, np.float32)
    r1 = np.full((3, 2, 2), 2.0, np.float32)
    mk = np.ones((3, 2), bool)
    out0, _ = inj.corrupt_reports(0, r0, mk, None)
    np.testing.assert_array_equal(out0[cid], r0[cid])  # warmup: unchanged

    inj2 = faults.FaultInjector(3, mode="stale_replay", seed=0,
                                byzantine_frac=0.4)
    inj2.load_state_dict(inj.state_dict())
    out1, _ = inj2.corrupt_reports(1, r1, mk, None)
    np.testing.assert_array_equal(out1[cid], r0[cid])  # replayed round 0
    honest = [c for c in range(3) if c != cid]
    np.testing.assert_array_equal(out1[honest], r1[honest])


# -------------------------------------------------- defaults stay bit-exact

def test_explicit_mean_reproduces_golden_logs():
    """robust_aggregation="mean" + fault_mode="none" spelled out explicitly
    must replay the pre-robustness goldens bit-for-bit — the whole defense
    stack defaults to a no-op."""
    golden = json.loads(GOLDEN_PATH.read_text())
    for name, engine in [("edgefd_loop", "loop"), ("edgefd_cohort", "cohort")]:
        cfg = FedConfig(num_clients=4, rounds=2, method="edgefd",
                        scenario="strong", proxy_batch=128, batch_size=32,
                        seed=0, engine=engine, round_mode="sync",
                        kernel_backend="jnp", zoo="shared",
                        fault_mode="none", robust_aggregation="mean",
                        sanitize_reports=True)
        res = simulator.run(cfg, "mnist_feat", n_train=600, n_test=200)
        for g, n in zip(golden[name], res.rounds):
            assert g["accs"] == n.accs, (name, n.round)
            assert g["mean_acc"] == n.mean_acc
            assert g["local_loss"] == n.local_loss
            assert g["distill_loss"] == n.distill_loss
            assert g["id_fraction"] == n.id_fraction
            assert g["bytes_up"] == n.bytes_up
            assert g["bytes_down"] == n.bytes_down
            assert n.scrubbed_rows == 0 and n.quarantined is None
            assert n.rollbacks == 0


# -------------------------------------------------------- engine parity

@pytest.mark.parametrize("mode", ATTACK_MODES)
def test_fault_parity_loop_vs_cohort(mode):
    """The injector sits in the engine-independent scheduler path, so loop
    and cohort produce identical logs under every fault mode."""
    kw = dict(fault_mode=mode, byzantine_frac=0.4, fault_prob=0.2)
    loop = _run(_cfg("loop", **kw))
    cohort = _run(_cfg("cohort", **kw))
    for rl, rc in zip(loop.rounds, cohort.rounds):
        np.testing.assert_allclose(rl.accs, rc.accs, **TOL)
        np.testing.assert_allclose(rl.distill_loss, rc.distill_loss, **TOL)
        np.testing.assert_allclose(rl.id_fraction, rc.id_fraction, **TOL)
        assert rl.bytes_up == rc.bytes_up
        assert rl.scrubbed_rows == rc.scrubbed_rows


def test_fault_parity_mesh_subprocess():
    """Mesh-sharded engine on 4 forced host devices injects the identical
    fault trace (and reduces robustly) — same subprocess vehicle as
    tests/test_cohort_parity.py, since jax pins the device count at init."""
    here = os.path.dirname(os.path.abspath(__file__))
    prog = os.path.join(here, "_mesh_parity_prog.py")
    src = os.path.abspath(os.path.join(here, "..", "src"))
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    res = subprocess.run(
        [sys.executable, prog, "--devices", "4", "--clients", "5",
         "--fault-mode", "colluding_flip", "--byzantine-frac", "0.4",
         "--robust-aggregation", "trimmed_mean"],
        env=env, capture_output=True, text=True, timeout=540)
    assert res.returncode == 0, (
        f"mesh fault parity failed:\n{res.stdout}\n{res.stderr}")
    assert "PARITY-OK" in res.stdout, res.stdout


# ------------------------------------------------ robust reducers, end to end

def test_robust_recovers_where_mean_collapses():
    """byzantine_frac=0.3 colluding flip: trimmed_mean/median finish within
    tolerance of the fault-free baseline while the plain mean collapses by
    at least twice that margin (the BENCH_robust acceptance shape)."""
    base = dict(num_clients=10, rounds=4, method="edgefd", scenario="iid",
                proxy_batch=96, batch_size=32)
    attack = dict(fault_mode="colluding_flip", byzantine_frac=0.3)

    def acc(**kw):
        return _run(FedConfig(**base, **kw), n_train=600, n_test=250).final_acc

    baseline = acc()
    mean_atk = acc(**attack)
    trimmed = acc(**attack, robust_aggregation="trimmed_mean", trim_frac=0.45)
    median = acc(**attack, robust_aggregation="median")
    tol = 0.08
    assert trimmed >= baseline - tol, (trimmed, baseline)
    assert median >= baseline - tol, (median, baseline)
    assert mean_atk <= baseline - 2 * tol, (mean_atk, baseline)


def test_sanitize_scrubs_nan_and_surfaces_counts():
    """Default sanitize: a nan attack is scrubbed at ingest, the per-round
    scrub count lands on RoundLog, and accuracy stays near fault-free."""
    base = dict(num_clients=6, rounds=3, method="edgefd", scenario="strong",
                proxy_batch=96, batch_size=32)
    clean = _run(FedConfig(**base))
    nan = _run(FedConfig(**base, fault_mode="nan", byzantine_frac=0.34))
    assert all(r.scrubbed_rows > 0 for r in nan.rounds)
    assert all(np.isfinite(r.distill_loss) for r in nan.rounds)
    assert nan.final_acc >= clean.final_acc - 0.15


def test_robust_two_tier_e1_equals_flat():
    """num_edges=1 never enters the partial-fusion path, so the two-tier
    robust server is *exactly* the flat reducer — the documented anchor of
    the E>1 approximation."""
    from repro.core import aggregation
    from repro.data.proxy import ProxyData
    from repro.fed.server import Server

    proxy = ProxyData(x=np.zeros((32, 4), np.float32),
                      y=np.zeros((32,), np.int64),
                      owner=np.zeros((32,), np.int32))
    rng = np.random.default_rng(0)
    lo = rng.normal(size=(6, 32, 5)).astype(np.float32)
    mk = rng.random((6, 32)) < 0.8
    srv = Server(proxy, seed=0, num_edges=1,
                 robust_aggregation="median")
    teacher, valid = srv.aggregate(lo, mk)
    t_ref, v_ref = aggregation.robust_reduce(lo, mk, "median")
    np.testing.assert_array_equal(teacher, np.asarray(t_ref))
    np.testing.assert_array_equal(valid, np.asarray(v_ref))


# --------------------------------------------- quarantine: state + resume

def test_quarantine_triggers_and_resumes_bit_for_bit():
    """Trust tracking quarantines the scaled attacker, the quarantined
    rounds drop it from the participant draw, and the whole trust/
    quarantine/fault state rides kill-and-resume bit-for-bit."""
    kw = dict(fault_mode="scaled", byzantine_frac=0.25,
              robust_aggregation="trimmed_mean", trim_frac=0.3,
              quarantine_threshold=2.0, quarantine_rounds=2)
    res = _run(_cfg("loop", rounds=3, num_clients=4,
                    participation_fraction=1.0, **kw))
    cid = int(np.nonzero(faults.byzantine_ids(4, seed=0,
                                              byzantine_frac=0.25))[0][0])
    quarantined = [c for r in res.rounds for c in (r.quarantined or [])]
    assert cid in quarantined
    # the round after the event runs without the attacker
    ev = next(r.round for r in res.rounds if r.quarantined)
    after = next(r for r in res.rounds if r.round == ev + 1)
    assert after.participants is not None and cid not in after.participants

    # kill-and-resume at every boundary of round 1, with staleness +
    # partial participation in the mix (the _resume_prog defaults)
    n = check_resume("loop", 0, "sync", **kw)
    assert n == 5


def test_stale_replay_cache_resumes_bit_for_bit():
    """The stale_replay cache is injector state: killing between its warm
    and replay rounds must not change what gets replayed."""
    n = check_resume("loop", 0, "sync", fault_mode="stale_replay",
                     fault_prob=0.4)
    assert n == 5


# ------------------------------------------------------ divergence watchdog

def test_watchdog_rolls_back_and_recovers():
    """Mid-run nan burst with sanitize OFF (the historical poison path):
    without the watchdog the service never recovers; with it, the burst
    round is rolled back, the nan senders are quarantined, pre-burst logs
    are bit-identical to fault-free, and every retired log is finite."""
    base = dict(num_clients=6, rounds=4, method="edgefd", scenario="strong",
                proxy_batch=96, batch_size=32, sanitize_reports=False)
    burst = dict(fault_mode="nan", byzantine_frac=0.34, fault_start=2,
                 fault_duration=1)
    clean = _run(FedConfig(**base))
    broken = _run(FedConfig(**base, **burst))
    guarded = _run(FedConfig(**base, **burst, watchdog=True))

    assert not np.isfinite(broken.rounds[-1].distill_loss)  # no defense
    assert len(guarded.rounds) == 4
    assert all(np.isfinite(r.mean_acc) and np.isfinite(r.distill_loss)
               for r in guarded.rounds)
    assert guarded.rounds[-1].rollbacks >= 1
    assert any(r.quarantined for r in guarded.rounds)
    assert guarded.final_acc >= clean.final_acc - 0.15
    # pre-burst rounds are untouched by the machinery: bit-identical on
    # every deterministic field (sim timeline fields price at measured
    # wall-clock under simulator.run, so they never match across runs)
    def pinned(r):
        return (r.accs, r.mean_acc, r.local_loss, r.distill_loss,
                r.id_fraction, r.bytes_up, r.bytes_down)

    for c, g in zip(clean.rounds[:2], guarded.rounds[:2]):
        assert pinned(c) == pinned(g)
