"""Density-ratio estimator tests: both DREs must separate ID from OOD."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dre import KMeansDRE, KuLSIFDRE


@pytest.fixture
def id_ood():
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    private = jax.random.normal(k1, (300, 8))                 # N(0, I)
    id_test = jax.random.normal(k2, (100, 8))
    ood_test = jax.random.normal(k3, (100, 8)) + 8.0          # shifted blob
    return private, id_test, ood_test


def test_kmeans_dre_separates(id_ood):
    private, id_t, ood_t = id_ood
    dre = KMeansDRE(num_centroids=1).learn(jax.random.PRNGKey(1), private)
    id_mask = np.asarray(dre.is_id(id_t))
    ood_mask = np.asarray(dre.is_id(ood_t))
    assert id_mask.mean() > 0.85
    assert ood_mask.mean() < 0.05


def test_kmeans_dre_threshold_calibration(id_ood):
    private, _, _ = id_ood
    dre = KMeansDRE(num_centroids=2, calibration_q=0.9)
    dre = dre.learn(jax.random.PRNGKey(1), private)
    frac = float(np.asarray(dre.is_id(private)).mean())
    assert 0.85 <= frac <= 0.95      # ≈ q by construction


def test_kmeans_dre_estimate_monotone_in_distance(id_ood):
    private, id_t, ood_t = id_ood
    dre = KMeansDRE(num_centroids=1).learn(jax.random.PRNGKey(1), private)
    assert float(jnp.mean(dre.estimate(id_t))) > float(jnp.mean(dre.estimate(ood_t)))


def test_kulsif_dre_separates(id_ood):
    private, id_t, ood_t = id_ood
    dre = KuLSIFDRE(sigma=3.0, lam=0.1, num_aux=128)
    dre = dre.learn(jax.random.PRNGKey(2), private)
    r_id = float(jnp.mean(dre.estimate(id_t)))
    r_ood = float(jnp.mean(dre.estimate(ood_t)))
    assert r_id > r_ood, (r_id, r_ood)
    assert r_id > 0.0


def test_kulsif_vs_kmeans_agreement(id_ood):
    """The paper's claim: the cheap estimator makes the same ID/OOD calls."""
    private, id_t, ood_t = id_ood
    km = KMeansDRE(num_centroids=1).learn(jax.random.PRNGKey(1), private)
    ku = KuLSIFDRE(sigma=3.0, lam=0.1, num_aux=128,
                   threshold=0.0).learn(jax.random.PRNGKey(2), private)
    test = jnp.concatenate([id_t, ood_t])
    truth = np.r_[np.ones(len(id_t), bool), np.zeros(len(ood_t), bool)]
    km_calls = np.asarray(km.is_id(test))
    # choose kulsif threshold at its median ratio (fair comparison point)
    ratios = np.asarray(ku.estimate(test))
    ku_calls = ratios >= np.median(ratios)
    km_acc = (km_calls == truth).mean()
    ku_acc = (ku_calls == truth).mean()
    assert km_acc >= 0.95
    assert ku_acc >= 0.9
