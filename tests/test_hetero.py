"""Heterogeneous model zoos: mixed populations, concurrent-cohort
scheduling and the FedDF ensemble server.

Gates, in order of importance:

* a single-cohort population under ``concurrent_cohorts=True`` replays
  the serial phase graph **bit-for-bit** (pinned against
  ``tests/data/golden_rounds.json``, the same goldens the scheduler and
  kernel-dispatch layers certify against);
* on the mixed three-width zoo, serial and concurrent sync runs are
  numerically identical (only the simulated timeline moves), and the
  loop and cohort engines agree within the engine tolerance under both
  sync and overlap;
* the interleaved trace is deterministic in the seed, and under overlap
  a cohort's round r+1 training genuinely overlaps round r's server
  phases;
* the simulated makespan of the concurrent graph beats the serial graph
  under anti-correlated per-cohort costs;
* ``method="server_distill"`` trains the server's central student every
  round and reports its accuracy.
"""

import json
import pathlib

import jax
import numpy as np
import pytest

from repro.common.types import FedConfig
from repro.core.methods import get_method
from repro.fed import simulator
from repro.fed.scheduler import RoundScheduler, round_phases
from repro.fed.simulator import resolve_zoo

GOLDEN_PATH = pathlib.Path(__file__).parent / "data" / "golden_rounds.json"
TOL = dict(rtol=0.0, atol=1e-5)


def _cfg(**kw):
    base = dict(
        num_clients=6,
        rounds=2,
        method="fedmd",
        scenario="strong",
        proxy_batch=64,
        batch_size=32,
        lr=1e-2,
        seed=0,
        engine="cohort",
        zoo="mixed",
        round_mode="sync",
    )
    base.update(kw)
    return FedConfig(**base)


def _build_sched(cfg, **sched_kw):
    clients, server, x_test, y_test = simulator.build_experiment(
        cfg, "mnist_feat", n_train=600, n_test=200, mlp_hidden=(16,)
    )
    engine = simulator.build_engine(clients, cfg)
    method = get_method(cfg.method)
    if method.client_filter != "none":
        engine.learn_dres(jax.random.PRNGKey(cfg.seed))
    return RoundScheduler(engine, server, method, cfg, x_test, y_test, **sched_kw)


def _rows(res):
    return [
        (r.accs, r.mean_acc, r.local_loss, r.distill_loss, r.id_fraction)
        for r in res.rounds
    ]


# -------------------------------------------------------- zoo resolution


def test_resolve_zoo(monkeypatch):
    monkeypatch.delenv("REPRO_ZOO", raising=False)
    assert resolve_zoo("auto") == "shared"
    assert resolve_zoo("shared") == "shared"
    assert resolve_zoo("mixed") == "mixed"
    monkeypatch.setenv("REPRO_ZOO", "mixed")
    assert resolve_zoo("auto") == "mixed"
    assert resolve_zoo("shared") == "shared"  # explicit config wins
    monkeypatch.setenv("REPRO_ZOO", "auto")
    assert resolve_zoo("auto") == "shared"
    with pytest.raises(ValueError):
        resolve_zoo("nonsense")
    monkeypatch.setenv("REPRO_ZOO", "nonsense")
    with pytest.raises(ValueError):
        resolve_zoo("auto")


def test_mixed_zoo_builds_three_cohorts():
    cfg = _cfg()
    clients, _, _, _ = simulator.build_experiment(
        cfg, "mnist_feat", n_train=600, n_test=200, mlp_hidden=(16,)
    )
    keys = {c.arch_key for c in clients}
    assert len(keys) == 3  # three width variants, cycled by cid % 3
    engine = simulator.build_engine(clients, cfg)
    assert len(engine.cohort_positions()) == 3


def test_run_rejects_bad_zoo():
    with pytest.raises(ValueError):
        simulator.run(_cfg(zoo="bogus"), "mnist_feat", n_train=64, n_test=32)


# -------------------------------------------- golden (single-cohort sync)


def test_single_cohort_concurrent_matches_golden_bit_for_bit():
    """With one architecture cohort the concurrent graph must degenerate
    to exactly the serial schedule: same goldens as the lockstep tree,
    bit for bit. round_mode/kernel_backend/zoo are pinned so the test
    also holds under the overlap/pallas/mixed CI matrix entries."""
    golden = json.loads(GOLDEN_PATH.read_text())["edgefd_cohort"]
    cfg = FedConfig(
        num_clients=4,
        rounds=2,
        method="edgefd",
        scenario="strong",
        proxy_batch=128,
        batch_size=32,
        seed=0,
        engine="cohort",
        zoo="shared",
        round_mode="sync",
        kernel_backend="jnp",
        concurrent_cohorts=True,
    )
    res = simulator.run(cfg, "mnist_feat", n_train=600, n_test=200)
    assert len(res.rounds) == len(golden)
    for g, n in zip(golden, res.rounds):
        assert g["accs"] == n.accs
        assert g["mean_acc"] == n.mean_acc
        assert g["local_loss"] == n.local_loss
        assert g["distill_loss"] == n.distill_loss
        assert g["id_fraction"] == n.id_fraction
        assert g["bytes_up"] == n.bytes_up
        assert g["bytes_down"] == n.bytes_down


# ----------------------------------------------------- numerics parity


def test_sync_concurrent_is_bitwise_serial_on_mixed_zoo():
    """Sync mode: the concurrent graph reorders nothing the numerics can
    see (order deps pin the host order), so serial and concurrent runs
    of the same mixed-zoo experiment are bit-identical."""
    a = simulator.run(_cfg(), "mnist_feat", n_train=600, n_test=200)
    b = simulator.run(_cfg(concurrent_cohorts=True), "mnist_feat", n_train=600, n_test=200)
    assert _rows(a) == _rows(b)


@pytest.mark.parametrize(
    "mode_kw",
    [
        dict(round_mode="sync"),
        dict(
            round_mode="overlap",
            max_inflight=2,
            participation_fraction=0.75,
            staleness_decay=0.5,
        ),
    ],
    ids=["sync", "overlap"],
)
def test_loop_cohort_parity_on_mixed_zoo_concurrent(mode_kw):
    """The engines must agree on the mixed zoo with concurrent cohorts —
    the loop engine groups clients by arch_key into the same cohorts the
    cohort engine stacks."""
    a = simulator.run(
        _cfg(engine="loop", concurrent_cohorts=True, **mode_kw),
        "mnist_feat",
        n_train=600,
        n_test=200,
    )
    b = simulator.run(
        _cfg(engine="cohort", concurrent_cohorts=True, **mode_kw),
        "mnist_feat",
        n_train=600,
        n_test=200,
    )
    for ra, rb in zip(a.rounds, b.rounds):
        np.testing.assert_allclose(ra.accs, rb.accs, **TOL)
        np.testing.assert_allclose(ra.local_loss, rb.local_loss, **TOL)
        np.testing.assert_allclose(ra.distill_loss, rb.distill_loss, **TOL)
        assert ra.id_fraction == rb.id_fraction
        assert ra.participants == rb.participants


# ------------------------------------------------------ trace properties


def _overlap_cfg(**kw):
    return _cfg(
        rounds=3,
        round_mode="overlap",
        max_inflight=2,
        participation_fraction=0.75,
        staleness_decay=0.5,
        concurrent_cohorts=True,
        **kw,
    )


def test_interleaved_trace_is_seed_deterministic():
    traces = []
    for _ in range(2):
        sched = _build_sched(_overlap_cfg())
        sched.run_rounds(0, 3)
        traces.append(list(sched.trace))
    assert traces[0] == traces[1]
    # per-cohort nodes actually exist in the trace
    assert any(len(k) == 3 for k in traces[0])


def test_overlap_interleaves_cohort_rounds():
    """Under overlap a cohort's round-1 training must run before round
    0's aggregate — per-cohort admission is the whole point."""
    sched = _build_sched(_overlap_cfg())
    sched.run_rounds(0, 3)
    t = sched.trace
    agg0 = t.index(("aggregate", 0))
    assert any(
        t.index(("local_train", 1, ci)) < agg0
        for ci in range(3)
        if ("local_train", 1, ci) in t
    )


def test_concurrent_beats_serial_on_sim_clock():
    """Anti-correlated per-cohort costs: the serial graph pays
    sum-over-phases of the slowest cohort, concurrent pays roughly the
    slowest chain — its makespan must be strictly smaller."""
    costs = {
        "local_train@0": 2.0,
        "local_train@1": 0.5,
        "report": 0.1,
        "aggregate": 0.2,
        "distill@0": 0.5,
        "distill@1": 2.0,
        "eval": 0.0,
    }
    spans = {}
    for concurrent in (False, True):
        cfg = _cfg(concurrent_cohorts=concurrent, straggler_factor=1.0)
        sched = _build_sched(cfg, sim_phase_costs=costs)
        logs = sched.run_rounds(0, cfg.rounds)
        spans[concurrent] = max(lg.sim_finish_s for lg in logs)
    assert spans[True] < spans[False]


# ------------------------------------------------- FedDF ensemble server


def test_server_distill_trains_a_student():
    cfg = _cfg(
        method="server_distill",
        rounds=2,
        scenario="iid",
        server_distill_epochs=8,
    )
    assert "server_distill" in round_phases(get_method("server_distill"))
    res = simulator.run(cfg, "mnist_feat", n_train=600, n_test=200)
    for lg in res.rounds:
        assert lg.server_distill_loss > 0.0
        assert 0.0 <= lg.server_student_acc <= 1.0
    # the student must actually learn from the ensemble: round-1 accuracy
    # above chance on the 10-class problem
    assert res.rounds[-1].server_student_acc > 0.15


def test_server_distill_concurrent_matches_serial():
    kw = dict(method="server_distill", rounds=2, server_distill_epochs=2)
    a = simulator.run(_cfg(**kw), "mnist_feat", n_train=600, n_test=200)
    b = simulator.run(
        _cfg(concurrent_cohorts=True, **kw),
        "mnist_feat",
        n_train=600,
        n_test=200,
    )
    assert _rows(a) == _rows(b)
    for ra, rb in zip(a.rounds, b.rounds):
        assert ra.server_distill_loss == rb.server_distill_loss
        assert ra.server_student_acc == rb.server_student_acc
