"""Optimizer convergence + checkpoint roundtrip."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.optim.optimizers import adamw, apply_updates, clip_by_global_norm, sgd
from repro.optim.schedules import cosine_decay, linear_warmup_cosine


@pytest.mark.parametrize("make_opt", [lambda: sgd(0.1), lambda: adamw(0.1)])
def test_optimizer_converges_quadratic(make_opt):
    opt = make_opt()
    params = {"w": jnp.asarray([3.0, -2.0]), "b": jnp.asarray(1.5)}
    state = opt.init(params)

    def loss_fn(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    for _ in range(200):
        g = jax.grad(loss_fn)(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    assert float(loss_fn(params)) < 1e-3


def test_adamw_bf16_state_dtype():
    opt = adamw(0.1, state_dtype=jnp.bfloat16)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = opt.init(params)
    assert state["m"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones((4,), jnp.bfloat16)}
    upd, state = opt.update(g, state, params)
    assert upd["w"].dtype == jnp.bfloat16


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    total = jnp.sqrt(jnp.sum(jnp.square(clipped["a"])))
    np.testing.assert_allclose(float(total), 1.0, rtol=1e-5)


def test_schedules_shapes():
    s = linear_warmup_cosine(1e-3, 10, 100)
    assert float(s(jnp.int32(0))) <= 1.1e-4
    np.testing.assert_allclose(float(s(jnp.int32(10))), 1e-3, rtol=1e-5)
    assert float(s(jnp.int32(100))) < 5e-4
    c = cosine_decay(1.0, 100)
    assert float(c(jnp.int32(100))) == pytest.approx(0.1, rel=1e-3)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"layer": {"w": jnp.arange(6.0).reshape(2, 3),
                      "b": jnp.zeros(3)},
            "step": jnp.int32(7)}
    d = str(tmp_path / "ck")
    save_checkpoint(d, 3, tree)
    assert latest_step(d) == 3
    like = jax.tree.map(jnp.zeros_like, tree)
    out = restore_checkpoint(d, 3, like)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    d = str(tmp_path / "ck")
    save_checkpoint(d, 0, {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError, match="shape mismatch"):
        restore_checkpoint(d, 0, {"w": jnp.zeros((3, 3))})
