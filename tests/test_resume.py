"""Kill-and-resume: snapshot/restore bit-for-bit across engines and modes.

The headline guarantee of the resumable service (launch/fed_serve): kill
at any phase boundary, resume from the last checkpoint, and completed
round logs are bit-for-bit identical to the uninterrupted run. The
in-process tests exercise every phase boundary of a middle round through
``RoundScheduler.snapshot()/restore()`` directly; the subprocess tests
cover the mesh-sharded engine (forced 4-device host) and the real
SIGKILL-the-process path through ``fed_serve``'s crash hook.
"""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

import _resume_prog
from _resume_prog import build_sched, check_resume, strip
from repro.common.types import FedConfig


@pytest.mark.parametrize("round_mode", ["sync", "overlap"])
def test_loop_resume_every_boundary(round_mode):
    """Loop engine, partial participation + staleness: restore from every
    phase boundary of round 1 replays the rest bit-for-bit."""
    n = check_resume("loop", 0, round_mode)
    assert n == 5  # one snapshot per phase of the crash round


def test_cohort_resume_inflight_boundaries():
    """Cohort engine under overlap: the boundaries where round 1 is
    genuinely in flight (reports pending, stacked state mid-round)."""
    n = check_resume("cohort", 0, "overlap",
                     boundaries=("report", "aggregate", "distill"))
    assert n == 3


def test_mesh_resume_and_cross_engine_forced_devices():
    """Mesh-sharded engine on 4 forced host devices: same-engine resume is
    bit-for-bit, and a mesh checkpoint restores into the unsharded loop
    engine (and vice versa) within the mesh-parity tolerance. jax fixes
    the device count at first init, so single-device hosts re-run
    tests/_resume_prog.py in a subprocess."""
    if jax.device_count() >= 4:
        _resume_prog.check_resume("cohort", 4, "overlap")
        _resume_prog.check_cross_engine("cohort", 4, "loop", 0)
        _resume_prog.check_cross_engine("loop", 0, "cohort", 4)
        return
    here = os.path.dirname(os.path.abspath(__file__))
    prog = os.path.join(here, "_resume_prog.py")
    src = os.path.abspath(os.path.join(here, "..", "src"))
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    res = subprocess.run(
        [sys.executable, prog, "--devices", "4", "--engine", "cohort",
         "--round-mode", "overlap", "--cross"],
        env=env, capture_output=True, text=True, timeout=540)
    assert res.returncode == 0, (
        f"mesh resume subprocess failed:\n{res.stdout}\n{res.stderr}")
    assert "RESUME-OK" in res.stdout and "CROSS-OK" in res.stdout, res.stdout


def test_fed_serve_sigkill_resume(tmp_path):
    """The real crash harness: fed_serve SIGKILLs itself at a phase
    boundary of round 1 (overlap, so round-0's checkpoint carries round-1
    in-flight state), a second invocation resumes from the checkpoint, and
    the log history matches an uninterrupted service bit-for-bit."""
    here = os.path.dirname(os.path.abspath(__file__))
    src = os.path.abspath(os.path.join(here, "..", "src"))
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    common = [sys.executable, "-m", "repro.launch.fed_serve",
              "--clients", "3", "--rounds", "2", "--n-train", "256",
              "--n-test", "64", "--round-mode", "overlap",
              "--participation", "0.75", "--staleness-decay", "0.5",
              "--fixed-phase-costs"]
    ckpt = str(tmp_path / "svc")

    crashed = subprocess.run(
        common + ["--ckpt-dir", ckpt, "--ckpt-every", "1",
                  "--crash-after-phase", "aggregate:1"],
        env=env, capture_output=True, text=True, timeout=540)
    assert crashed.returncode == -9, (  # died by its own SIGKILL
        f"expected SIGKILL exit, got {crashed.returncode}:\n"
        f"{crashed.stdout}\n{crashed.stderr}")
    assert os.path.exists(os.path.join(ckpt, "ckpt_00000001.npz"))

    # the retired-log sidecar is appended before each checkpoint, so it
    # survives the SIGKILL alongside the checkpoint it belongs to
    sidecar = os.path.join(ckpt, "logs.jsonl")
    assert os.path.exists(sidecar)

    resumed = subprocess.run(
        common + ["--ckpt-dir", ckpt, "--ckpt-every", "1", "--resume",
                  "--json", str(tmp_path / "resumed.json")],
        env=env, capture_output=True, text=True, timeout=540)
    assert resumed.returncode == 0, resumed.stdout + resumed.stderr
    assert "resumed from checkpoint step 1" in resumed.stdout

    # after the resumed service finishes, the sidecar holds exactly the
    # full retired history (checkpoints themselves carry no logs)
    with open(sidecar) as f:
        side = [json.loads(ln) for ln in f if ln.strip()]
    assert [d["round"] for d in side] == [0, 1]

    ref = subprocess.run(
        common + ["--json", str(tmp_path / "ref.json")],
        env=env, capture_output=True, text=True, timeout=540)
    assert ref.returncode == 0, ref.stdout + ref.stderr

    def load(p):
        with open(p) as f:
            return [{k: v for k, v in d.items()
                     if k not in _resume_prog.MEASURED_FIELDS}
                    for d in json.load(f)]
    assert load(tmp_path / "resumed.json") == load(tmp_path / "ref.json")


def test_logs_tail_keeps_checkpoint_bytes_flat(tmp_path):
    """Streaming history out of the snapshot (satellite of the robustness
    PR): with ``logs_tail=0`` the checkpoint carries a monotone
    ``completed`` counter instead of the log list, so checkpoint bytes
    stop growing with service age — while full snapshots demonstrably
    grow round over round. The tail-less tree still restores (fed_serve
    reconstructs history from the sidecar)."""
    from repro.checkpoint import save_state
    from repro.fed.state import ExperimentState

    cfg = FedConfig(num_clients=4, rounds=4, method="edgefd",
                    scenario="strong", proxy_batch=64, batch_size=32,
                    seed=0, round_mode="sync")
    sched = build_sched(cfg)
    sched.begin(0, cfg.rounds)
    flat_sizes, full_sizes, trees = [], [], []
    done = 0
    while sched.has_pending():
        _, _, log = sched.step()
        if log is None:
            continue
        done += 1
        d_flat, d_full = str(tmp_path / f"flat{done}"), str(
            tmp_path / f"full{done}")
        p1 = save_state(d_flat, done, sched.snapshot(logs_tail=0).to_tree())
        p2 = save_state(d_full, done, sched.snapshot().to_tree())
        flat_sizes.append(os.path.getsize(p1))
        full_sizes.append(os.path.getsize(p2))
        trees.append(sched.snapshot(logs_tail=0).to_tree())
    assert done == 4
    # full snapshots grow with history; tail-less ones stay flat
    assert full_sizes[-1] > full_sizes[0]
    assert max(flat_sizes) - min(flat_sizes) < 512
    # a tail-less tree restores, with completed preserved and logs empty
    s2 = build_sched(cfg)
    s2.restore(ExperimentState.from_tree(trees[1]))
    assert s2.completed == 2 and s2.logs == []
    s2.drain()
    ref = build_sched(cfg).run_rounds(0, cfg.rounds)
    assert strip(s2.logs) == strip(ref[2:])


def test_backpressure_ages_never_negative():
    """Event-ordered admission under a tight report budget: overflow
    clients drain through the staleness buffer with ages moving only
    forward — mean staleness and buffer ages never go negative, and the
    cap demonstrably rejects reports under overlap."""
    cfg = FedConfig(num_clients=6, rounds=4, method="edgefd",
                    scenario="strong", proxy_batch=64, batch_size=32,
                    seed=1, round_mode="overlap", max_inflight=2,
                    staleness_decay=0.5, max_pending_reports=3,
                    straggler_factor=4.0)
    sched = build_sched(cfg)
    logs = sched.run_rounds(0, cfg.rounds)
    assert all(lg.mean_staleness >= 0.0 for lg in logs)
    # the cap binds: some round admitted fewer reporters than the fleet
    assert any(lg.participants is not None and len(lg.participants) < 6
               for lg in logs)
    buf = sched.server._stale
    assert buf is not None
    ages = logs[-1].round - np.asarray(buf.last_round)[buf.reported]
    assert (ages >= 0).all()


def test_server_distill_resume_every_boundary():
    """FedDF ensemble server: the student's params/opt/rng ride the
    checkpoint, so restoring at any boundary of round 1 — including the
    new server_distill phase — replays the rest bit-for-bit."""
    n = check_resume("loop", 0, "sync", method="server_distill")
    assert n == 6  # six phases: the extra one is server_distill


def test_concurrent_cohort_resume_boundaries():
    """Mixed zoo + per-cohort phase nodes under overlap: every cohort
    node of round 1 is a kill boundary, and the interleaved schedule
    resumes bit-for-bit."""
    n = check_resume("cohort", 0, "overlap", zoo="mixed",
                     concurrent_cohorts=True)
    # 4 clients cycle into 3 cohorts (cid % 3): 3 client phases x 3
    # cohort nodes + aggregate + eval
    assert n == 11


def test_snapshot_restore_preserves_event_loop_bookkeeping():
    """Structural round-trip: pending/done/trace/sim-times survive the
    tree form (JSON manifest types), and restore rejects a round-mode
    mismatch."""
    cfg = FedConfig(num_clients=4, rounds=3, method="edgefd",
                    scenario="strong", proxy_batch=64, batch_size=32,
                    seed=0, round_mode="overlap", max_inflight=2)
    s1 = build_sched(cfg)
    s1.begin(0, cfg.rounds)
    for _ in range(7):
        s1.step()
    tree = s1.snapshot().to_tree()

    s2 = build_sched(cfg)
    s2.restore(tree)
    assert s2._pending == s1._pending
    assert s2._done == s1._done
    assert s2.trace == s1.trace
    assert s2._sim_end == s1._sim_end
    assert strip(s2.logs) == strip(s1.logs)

    cfg_sync = FedConfig(num_clients=4, rounds=3, method="edgefd",
                         scenario="strong", proxy_batch=64, batch_size=32,
                         seed=0, round_mode="sync")
    s3 = build_sched(cfg_sync)
    with pytest.raises(ValueError, match="round_mode"):
        s3.restore(tree)
