"""Transformer federated scenario + 2-D (clients, model) mesh acceptance.

Pins the ISSUE-10 parity chain: loop == cohort == 2-D-mesh-sharded round
logs for a transformer cohort (``lm_tokens`` — every client a reduced
granite backbone, ``core/fd_trainer.TransformerClientModel``) within the
established engine tolerance, and kill-and-resume through a model-sharded
round staying bit-for-bit. jax fixes the device count at first init, so
multi-device cases run in-process on a >=4-device host (the CI matrix's
forced-host-device entries) and re-run the shared checker programs in a
subprocess elsewhere.
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

import _mesh_parity_prog
from repro.common.types import FedConfig
from repro.fed import simulator

TOL = dict(rtol=0.0, atol=1e-5)


def _run(engine, num_devices=0, model_shards=0, **kw):
    base = dict(num_clients=3, rounds=2, proxy_batch=64, batch_size=16,
                lr=1e-2, seed=0, engine=engine, num_devices=num_devices,
                model_shards=model_shards)
    base.update(kw)
    return simulator.run(FedConfig(**base), "lm_tokens",
                         n_train=300, n_test=150)


def _assert_logs_match(a, b, exact=False):
    assert len(a.rounds) == len(b.rounds)
    for rl, rc in zip(a.rounds, b.rounds):
        if exact:
            np.testing.assert_array_equal(rl.accs, rc.accs)
            assert rl.local_loss == rc.local_loss
            assert rl.distill_loss == rc.distill_loss
            assert rl.id_fraction == rc.id_fraction
        else:
            np.testing.assert_allclose(rl.accs, rc.accs, **TOL)
            np.testing.assert_allclose(rl.local_loss, rc.local_loss, **TOL)
            np.testing.assert_allclose(rl.distill_loss, rc.distill_loss,
                                       **TOL)
            np.testing.assert_allclose(rl.id_fraction, rc.id_fraction, **TOL)
        assert rl.bytes_up == rc.bytes_up


def _subprocess_env():
    here = os.path.dirname(os.path.abspath(__file__))
    src = os.path.abspath(os.path.join(here, "..", "src"))
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    return here, env


def test_transformer_loop_cohort_parity():
    """The engine stack treats transformer clients like any other cohort:
    vmapped execution must reproduce the per-client loop."""
    _assert_logs_match(_run("loop"), _run("cohort"))


def test_transformer_learns_the_bands():
    """Sanity: the reduced backbone actually learns the vocab-band task —
    final accuracy beats the 1/32 chance floor with headroom."""
    res = _run("cohort", rounds=3)
    assert res.final_acc > 3.0 / 32.0


def test_transformer_2d_mesh_parity():
    """loop == cohort == 2-D-mesh-sharded (2x2 forced host devices) for a
    transformer cohort — the ISSUE-10 acceptance pin."""
    if jax.device_count() >= 4:
        _mesh_parity_prog.check_parity(4, 4, model_shards=2,
                                       dataset="lm_tokens",
                                       n_train=300, n_test=150)
        return
    here, env = _subprocess_env()
    res = subprocess.run(
        [sys.executable, os.path.join(here, "_mesh_parity_prog.py"),
         "--devices", "4", "--clients", "4", "--model-shards", "2",
         "--dataset", "lm_tokens"],
        env=env, capture_output=True, text=True, timeout=480)
    assert res.returncode == 0, (
        f"2-D mesh parity subprocess failed:\n{res.stdout}\n{res.stderr}")
    assert "PARITY-OK" in res.stdout, res.stdout


def test_model_shards_env_is_inert_without_mesh(monkeypatch):
    """$REPRO_MODEL_SHARDS (the CI matrix vehicle) must never change a
    meshless run: engine selection ignores it when num_devices == 0, so
    every existing golden stays bit-for-bit under the env."""
    base = _run("cohort")
    monkeypatch.setenv("REPRO_MODEL_SHARDS", "2")
    under_env = _run("cohort")
    _assert_logs_match(base, under_env, exact=True)


def test_sharded_kill_and_resume_bit_for_bit():
    """Kill-and-resume through a model-sharded round: snapshot at every
    phase boundary of a middle round on the 2-D mesh, restore fresh, and
    the completed logs must be bit-for-bit the uninterrupted run's."""
    if jax.device_count() >= 4:
        import _resume_prog
        n = _resume_prog.check_resume("cohort", 4, "overlap",
                                      model_shards=2)
        assert n > 0
        return
    here, env = _subprocess_env()
    res = subprocess.run(
        [sys.executable, os.path.join(here, "_resume_prog.py"),
         "--devices", "4", "--engine", "cohort", "--round-mode", "overlap",
         "--model-shards", "2"],
        env=env, capture_output=True, text=True, timeout=540)
    assert res.returncode == 0, (
        f"sharded resume subprocess failed:\n{res.stdout}\n{res.stderr}")
    assert "RESUME-OK" in res.stdout, res.stdout


def test_engine_from_config_builds_2d_mesh():
    """FedConfig.model_shards reaches the cohort engine's mesh (and the
    loop engine rejects it legibly)."""
    from repro.core.protocol import as_engine
    with pytest.raises(ValueError, match="cohort"):
        as_engine([], "loop", model_shards=2)
    if jax.device_count() >= 4:
        from repro.fed.client import Client  # noqa: F401  (import check)
        cfg = FedConfig(num_clients=4, rounds=1, seed=0, engine="cohort",
                        num_devices=4, model_shards=2, batch_size=16,
                        proxy_batch=64)
        from repro.fed.simulator import build_engine, build_experiment
        clients, _, _, _ = build_experiment(cfg, "lm_tokens", n_train=200,
                                            n_test=100)
        engine = build_engine(clients, cfg)
        mesh = engine.cohorts[0].mesh
        assert mesh.axis_names == ("clients", "model")
        assert mesh.devices.shape == (2, 2)
