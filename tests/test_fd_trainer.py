"""FD-for-transformers trainer: the paper's technique on the big backbones."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.core import fd_trainer as FD
from repro.core.kmeans import kmeans_fit
from repro.models import transformer as T
from repro.optim.optimizers import sgd


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_arch("granite-8b"))
    key = jax.random.PRNGKey(0)
    n_clients, B, S = 3, 2, 16
    opt = sgd(1e-2)
    states, centroids, thresholds, batches = [], [], [], []
    # each client's private tokens come from a distinct vocab band — the
    # LM analogue of strong non-IID label partitioning
    for c in range(n_clients):
        kc = jax.random.fold_in(key, c)
        params = T.init_params(cfg, kc)
        states.append((params, opt.init(params)))
        lo = c * cfg.vocab_size // n_clients
        hi = (c + 1) * cfg.vocab_size // n_clients
        toks = jax.random.randint(kc, (B, S), lo, hi)
        batches.append({"tokens": toks, "labels": toks})
        feats = FD.proxy_features(params, cfg, toks)
        res = kmeans_fit(kc, feats, 1)
        centroids.append(res.centroids)
        from repro.core.kmeans import min_dist_to_centroids
        d = min_dist_to_centroids(feats, res.centroids)
        thresholds.append(float(jnp.max(d)) * 1.5)
    # proxy: one batch from each client's band, owners recorded
    proxy = jnp.concatenate([b["tokens"][:1] for b in batches])
    owner = jnp.arange(n_clients, dtype=jnp.int32)
    return cfg, opt, states, batches, proxy, owner, centroids, thresholds


def test_fd_round_runs_and_filters(setup):
    cfg, opt, states, batches, proxy, owner, cents, thrs = setup
    new_states, metrics, id_frac = FD.fd_round_local(
        cfg, opt, states, batches, proxy, owner, cents, thrs)
    assert len(new_states) == 3
    for m in metrics:
        assert np.isfinite(float(m["loss"]))
        assert float(m["kl"]) >= -1e-5
    # strong non-IID vocab bands: the filter must reject some foreign proxies
    assert id_frac < 1.0
    # own contributions always pass (stage-1 provenance)
    assert id_frac >= 1.0 / 3 - 1e-6


def test_fd_loss_distill_weight_zero_equals_ce(setup):
    cfg, opt, states, batches, proxy, owner, cents, thrs = setup
    params = states[0][0]
    teacher = jnp.zeros((proxy.shape[0], cfg.vocab_size))
    w = jnp.zeros((proxy.shape[0],))
    loss, m = FD.fd_loss(params, cfg, batches[0], proxy, teacher, w,
                         distill_weight=1.0)
    ce_only, _ = T.train_loss(params, cfg, batches[0])
    np.testing.assert_allclose(float(loss), float(ce_only), rtol=1e-5)


def test_psum_step_equals_local_round(setup):
    """The mesh-collective step (vmap stands in for the mesh) must produce
    the same teacher-driven update as the hub-form reference."""
    cfg, opt, states, batches, proxy, owner, cents, thrs = setup
    step = FD.make_fd_train_step(cfg, opt, axis_name="clients")
    p_stack = jax.tree.map(lambda *xs: jnp.stack(xs), *[s[0] for s in states])
    o_stack = jax.tree.map(lambda *xs: jnp.stack(xs), *[s[1] for s in states])
    b_stack = jax.tree.map(lambda *xs: jnp.stack(xs), *batches)
    c_stack = jnp.stack(cents)
    t_stack = jnp.asarray(thrs)
    ids = jnp.arange(3, dtype=jnp.int32)
    vstep = jax.vmap(step, axis_name="clients",
                     in_axes=(0, 0, 0, None, None, 0, 0, 0))
    new_p, new_o, metrics = vstep(p_stack, o_stack, b_stack, proxy, owner,
                                  c_stack, t_stack, ids)
    ref_states, ref_metrics, _ = FD.fd_round_local(
        cfg, opt, states, batches, proxy, owner, cents, thrs)
    for c in range(3):
        a = jax.tree.leaves(jax.tree.map(lambda x: x[c], new_p))
        b = jax.tree.leaves(ref_states[c][0])
        for x, y in zip(a, b):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=5e-4, atol=5e-5)
