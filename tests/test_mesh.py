"""fed/mesh.py invariants: mesh construction, padding, placement, wiring.

Multi-device behavior (real sharding, client-axis padding) is covered
end-to-end by ``test_cohort_parity.py::test_mesh_sharded_parity_forced_devices``;
these tests pin the helper contracts and the single-device-mesh path, which
must be available on any host.
"""
import jax
import numpy as np
import pytest

from repro.fed import mesh as M


def test_build_client_mesh_zero_is_off():
    assert M.build_client_mesh(0) is None


def test_build_client_mesh_single_device():
    m = M.build_client_mesh(1, axis="clients")
    assert m.axis_names == ("clients",)
    assert m.devices.size == 1


def test_build_client_mesh_all_devices():
    m = M.build_client_mesh(-1)
    assert m.devices.size == jax.device_count()


def test_build_client_mesh_too_many_devices_is_legible():
    with pytest.raises(ValueError,
                       match="xla_force_host_platform_device_count"):
        M.build_client_mesh(jax.device_count() + 1)


def test_padded_size():
    class FakeMesh:                     # only .devices.size is read
        devices = np.zeros(4)

    assert M.padded_size(8, None) == 8  # no mesh: no padding
    assert M.padded_size(5, FakeMesh) == 8
    assert M.padded_size(8, FakeMesh) == 8
    assert M.padded_size(1, FakeMesh) == 4


def test_shard_and_replicate_placement():
    m = M.build_client_mesh(1)
    tree = {"a": np.arange(8.0).reshape(4, 2), "b": np.arange(4)}
    sharded = M.shard_clients(tree, m)
    np.testing.assert_array_equal(np.asarray(sharded["a"]), tree["a"])
    assert sharded["a"].sharding.mesh.axis_names == ("clients",)
    rep = M.replicate(tree, m)
    np.testing.assert_array_equal(np.asarray(rep["b"]), tree["b"])
    assert rep["b"].sharding.is_fully_replicated
    # no mesh: both placements are the identity
    assert M.shard_clients(tree, None) is tree
    assert M.replicate(tree, None) is tree


def test_loop_engine_rejects_num_devices():
    from repro.core.protocol import as_engine
    with pytest.raises(ValueError, match="cohort"):
        as_engine([], "loop", num_devices=2)


def test_prebuilt_meshless_engine_with_num_devices_warns():
    from repro.core.protocol import LoopEngine, as_engine
    engine = LoopEngine([])
    with pytest.warns(UserWarning, match="pre-built"):
        assert as_engine(engine, "cohort", num_devices=2) is engine


def test_single_device_mesh_parity():
    """num_devices=1 runs the full sharded code path (device_put placement,
    output pinning, padded learn) on any host and must reproduce the
    unsharded cohort logs exactly."""
    from repro.common.types import FedConfig
    from repro.fed import simulator

    logs = {}
    for nd in (0, 1):
        cfg = FedConfig(num_clients=3, rounds=1, method="edgefd",
                        scenario="strong", proxy_batch=60, batch_size=32,
                        lr=1e-2, seed=0, engine="cohort", num_devices=nd)
        logs[nd] = simulator.run(cfg, "mnist_feat", n_train=400, n_test=200)
    for a, b in zip(logs[0].rounds, logs[1].rounds):
        np.testing.assert_allclose(a.accs, b.accs, rtol=0.0, atol=1e-5)
        np.testing.assert_allclose(a.local_loss, b.local_loss,
                                   rtol=0.0, atol=1e-5)
        np.testing.assert_allclose(a.distill_loss, b.distill_loss,
                                   rtol=0.0, atol=1e-5)
        np.testing.assert_allclose(a.id_fraction, b.id_fraction,
                                   rtol=0.0, atol=1e-5)
