"""fed/mesh.py invariants: mesh construction, padding, placement, wiring.

Multi-device behavior (real sharding, client-axis padding) is covered
end-to-end by ``test_cohort_parity.py::test_mesh_sharded_parity_forced_devices``;
these tests pin the helper contracts and the single-device-mesh path, which
must be available on any host.
"""
import jax
import numpy as np
import pytest

from repro.fed import mesh as M


def test_build_client_mesh_zero_is_off():
    assert M.build_client_mesh(0) is None


def test_build_client_mesh_single_device():
    m = M.build_client_mesh(1, axis="clients")
    assert m.axis_names == ("clients",)
    assert m.devices.size == 1


def test_build_client_mesh_all_devices():
    m = M.build_client_mesh(-1)
    assert m.devices.size == jax.device_count()


def test_build_client_mesh_too_many_devices_is_legible():
    with pytest.raises(ValueError,
                       match="xla_force_host_platform_device_count"):
        M.build_client_mesh(jax.device_count() + 1)


def test_padded_size():
    class FakeMesh:                     # only .devices.size is read
        devices = np.zeros(4)

    assert M.padded_size(8, None) == 8  # no mesh: no padding
    assert M.padded_size(5, FakeMesh) == 8
    assert M.padded_size(8, FakeMesh) == 8
    assert M.padded_size(1, FakeMesh) == 4


def test_shard_and_replicate_placement():
    m = M.build_client_mesh(1)
    tree = {"a": np.arange(8.0).reshape(4, 2), "b": np.arange(4)}
    sharded = M.shard_clients(tree, m)
    np.testing.assert_array_equal(np.asarray(sharded["a"]), tree["a"])
    assert sharded["a"].sharding.mesh.axis_names == ("clients",)
    rep = M.replicate(tree, m)
    np.testing.assert_array_equal(np.asarray(rep["b"]), tree["b"])
    assert rep["b"].sharding.is_fully_replicated
    # no mesh: both placements are the identity
    assert M.shard_clients(tree, None) is tree
    assert M.replicate(tree, None) is tree


def test_loop_engine_rejects_num_devices():
    from repro.core.protocol import as_engine
    with pytest.raises(ValueError, match="cohort"):
        as_engine([], "loop", num_devices=2)


def test_prebuilt_meshless_engine_with_num_devices_warns():
    from repro.core.protocol import LoopEngine, as_engine
    engine = LoopEngine([])
    with pytest.warns(UserWarning, match="pre-built"):
        assert as_engine(engine, "cohort", num_devices=2) is engine


def test_single_device_mesh_parity():
    """num_devices=1 runs the full sharded code path (device_put placement,
    output pinning, padded learn) on any host and must reproduce the
    unsharded cohort logs exactly."""
    from repro.common.types import FedConfig
    from repro.fed import simulator

    logs = {}
    for nd in (0, 1):
        cfg = FedConfig(num_clients=3, rounds=1, method="edgefd",
                        scenario="strong", proxy_batch=60, batch_size=32,
                        lr=1e-2, seed=0, engine="cohort", num_devices=nd)
        logs[nd] = simulator.run(cfg, "mnist_feat", n_train=400, n_test=200)
    for a, b in zip(logs[0].rounds, logs[1].rounds):
        np.testing.assert_allclose(a.accs, b.accs, rtol=0.0, atol=1e-5)
        np.testing.assert_allclose(a.local_loss, b.local_loss,
                                   rtol=0.0, atol=1e-5)
        np.testing.assert_allclose(a.distill_loss, b.distill_loss,
                                   rtol=0.0, atol=1e-5)
        np.testing.assert_allclose(a.id_fraction, b.id_fraction,
                                   rtol=0.0, atol=1e-5)


# ------------------------------------------------- 2-D (clients, model)

def test_build_client_mesh_2d_shape():
    """model_shards folds the SAME num_devices into a (clients, model)
    mesh — never over-subscribing the host."""
    if jax.device_count() >= 4:
        m = M.build_client_mesh(4, model_shards=2)
        assert m.axis_names == ("clients", "model")
        assert m.devices.shape == (2, 2)
        assert M.client_axis_size(m) == 2
        assert M.model_axis_name(m) == "model"
    # one shard per model IS no model sharding: explicit model_shards=1
    # degrades to the historical 1-D client mesh bit-for-bit
    m1 = M.build_client_mesh(1, model_shards=1)
    assert m1.axis_names == ("clients",)
    assert M.model_axis_name(m1) is None


def test_model_shards_is_off_on_1d_mesh():
    m = M.build_client_mesh(1)
    assert m.axis_names == ("clients",)
    assert M.model_axis_name(m) is None
    assert M.model_axis_name(None) is None
    assert M.client_axis_size(None) == 1


def test_model_shards_without_mesh_raises():
    with pytest.raises(ValueError, match="requires a device mesh"):
        M.build_client_mesh(0, model_shards=2)


def test_model_shards_nondivisible_raises():
    with pytest.raises(ValueError, match="cannot tile"):
        M.build_client_mesh(1, model_shards=3)


def test_too_many_devices_error_mentions_product():
    with pytest.raises(ValueError, match="TOTAL devices"):
        M.build_client_mesh(jax.device_count() + 2, model_shards=2)
    # and still carries the historical actionable hint
    with pytest.raises(ValueError,
                       match="xla_force_host_platform_device_count"):
        M.build_client_mesh(jax.device_count() + 2, model_shards=2)


def test_env_model_shards_is_clamped_not_fatal(monkeypatch):
    """$REPRO_MODEL_SHARDS is a CI sweep vehicle: a value the device count
    cannot tile clamps to gcd(num_devices, env) instead of exploding the
    matrix entry. An explicit config value stays strict (above)."""
    monkeypatch.setenv(M.MODEL_SHARDS_ENV, "3")
    m = M.build_client_mesh(1)          # gcd(1, 3) == 1 -> no model axis
    assert m.devices.shape == (1,)
    assert m.axis_names == ("clients",)
    # env never forces a mesh into a meshless run
    assert M.build_client_mesh(0) is None


def test_resolve_model_shards_validation(monkeypatch):
    with pytest.raises(ValueError, match=">= 0"):
        M.resolve_model_shards(-1)
    monkeypatch.setenv(M.MODEL_SHARDS_ENV, "nope")
    with pytest.raises(ValueError, match="not an integer"):
        M.resolve_model_shards(0)
    monkeypatch.setenv(M.MODEL_SHARDS_ENV, "2")
    assert M.resolve_model_shards(0) == 2
    assert M.resolve_model_shards(4) == 4   # explicit beats env


def test_build_mesh_validates_shape():
    with pytest.raises(ValueError, match="axis names"):
        M.build_mesh((1, 1), ("clients",))
    with pytest.raises(ValueError, match="positive"):
        M.build_mesh((0,), ("clients",))


def test_padded_size_uses_client_axis_only():
    class Fake2D:                       # only .devices.shape is read
        devices = np.zeros((2, 2))

    assert M.padded_size(5, Fake2D) == 6    # multiple of 2, not of 4
    assert M.padded_size(2, Fake2D) == 2


def test_stacked_state_shardings_1d_is_client_split():
    m = M.build_client_mesh(1)
    tree = {"w": np.zeros((4, 8, 6)), "b": np.zeros((4, 6)),
            "step": np.zeros(())}
    sh = M.stacked_state_shardings(tree, m)
    assert sh["w"].spec == jax.sharding.PartitionSpec("clients")
    assert sh["b"].spec == jax.sharding.PartitionSpec("clients")
    assert sh["step"].spec == jax.sharding.PartitionSpec()


@pytest.mark.skipif(jax.device_count() < 4, reason="needs 4 devices")
def test_stacked_state_shardings_2d_splits_model_dims():
    """On a 2x2 mesh a stacked transformer param tree gets client x model
    specs: wq heads -> model, embed vocab -> model, biases stay
    client-split, scalars replicate."""
    from jax.sharding import PartitionSpec as P
    m = M.build_client_mesh(4, model_shards=2)
    C, L = 2, 2
    tree = {"embed": np.zeros((C, 32, 64)),
            "blocks": {"wq": np.zeros((C, L, 64, 4, 16)),
                       "bq": np.zeros((C, L, 4, 16))},
            "step": np.zeros(())}
    sh = M.stacked_state_shardings(tree, m)
    assert sh["embed"].spec == P("clients", "model")
    assert sh["blocks"]["wq"].spec == P("clients", None, None, "model")
    assert sh["step"].spec == P()


@pytest.mark.skipif(jax.device_count() < 4, reason="needs 4 devices")
def test_shard_stacked_state_places_and_roundtrips():
    m = M.build_client_mesh(4, model_shards=2)
    tree = {"w": np.arange(2 * 8 * 4, dtype=np.float32).reshape(2, 8, 4)}
    placed = M.shard_stacked_state(tree, m)
    np.testing.assert_array_equal(np.asarray(placed["w"]), tree["w"])
    assert placed["w"].sharding.mesh.axis_names == ("clients", "model")
