"""Partial participation & staleness (repro.fed.participation).

Gates, in order of importance:

  * ``participation_fraction=1.0, staleness_decay=0`` reproduces the
    pre-participation round logs **bit-for-bit** (the machinery must be
    invisible when disabled);
  * under ``participation_fraction < 1`` the loop and cohort engines (and
    the mesh-sharded cohort engine, via the forced-device harness) produce
    identical round logs — sampling, rng-stream skipping and staleness
    reuse are engine-independent;
  * every sampling policy is deterministic in ``(seed, round)``;
  * sampling a different subset each round changes only data, never
    shapes: no cohort phase retraces.
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.common.types import FedConfig
from repro.core.methods import get_method
from repro.core.protocol import run_round
from repro.fed import simulator
from repro.fed.cohort import CohortEngine
from repro.fed.participation import (StalenessBuffer, cohort_size,
                                     sample_participants, validate_config)

TOL = dict(rtol=0.0, atol=1e-5)


def _cfg(engine, **kw):
    base = dict(num_clients=5, rounds=3, method="edgefd", scenario="strong",
                proxy_batch=120, batch_size=32, lr=1e-2, seed=0,
                engine=engine)
    base.update(kw)
    return FedConfig(**base)


# ---------------------------------------------------------------- sampling

@pytest.mark.parametrize("policy", ["uniform", "weighted", "roundrobin"])
def test_policy_deterministic_in_seed_and_round(policy):
    sizes = np.array([10, 20, 30, 40, 50, 60])
    for r in range(4):
        a = sample_participants(r, 6, 0.5, policy, seed=3, data_sizes=sizes)
        b = sample_participants(r, 6, 0.5, policy, seed=3, data_sizes=sizes)
        np.testing.assert_array_equal(a, b)
        assert a.sum() == cohort_size(6, 0.5) == 3


def test_uniform_varies_across_rounds():
    draws = {tuple(np.flatnonzero(
        sample_participants(r, 20, 0.25, "uniform", seed=0)))
        for r in range(12)}
    assert len(draws) > 1, "uniform sampling must not freeze one subset"


def test_roundrobin_covers_everyone_each_cycle():
    c, frac = 7, 0.3                       # k = 2, cycle = ceil(7/2) = 4
    k = cohort_size(c, frac)
    seen = set()
    for r in range(-(-c // k)):
        mask = sample_participants(r, c, frac, "roundrobin")
        assert mask.sum() == k
        seen |= set(np.flatnonzero(mask))
    assert seen == set(range(c))


def test_weighted_prefers_large_shards():
    sizes = np.array([1000, 1, 1, 1, 1, 1, 1, 1])
    hits = np.zeros(8)
    for r in range(40):
        hits += sample_participants(r, 8, 0.25, "weighted", seed=0,
                                    data_sizes=sizes)
    assert hits[0] == max(hits) and hits[0] >= 35, hits


def test_fraction_one_is_everyone():
    for policy in ("uniform", "weighted", "roundrobin"):
        mask = sample_participants(5, 9, 1.0, policy,
                                   data_sizes=np.ones(9))
        assert mask.all()


def test_sampling_validation():
    with pytest.raises(ValueError, match="policy"):
        sample_participants(0, 4, 0.5, "fifo")
    with pytest.raises(ValueError, match="fraction"):
        sample_participants(0, 4, 0.0, "uniform")
    with pytest.raises(ValueError, match="data_sizes"):
        sample_participants(0, 4, 0.5, "weighted")
    with pytest.raises(ValueError, match="clients with data"):
        sample_participants(0, 4, 0.75, "weighted",
                            data_sizes=np.array([1, 0, 0, 0]))
    with pytest.raises(ValueError, match="participation_policy"):
        validate_config(_cfg("loop", participation_policy="fifo"))
    with pytest.raises(ValueError, match="participation_fraction"):
        validate_config(_cfg("loop", participation_fraction=1.5))
    with pytest.raises(ValueError, match="staleness_decay"):
        validate_config(_cfg("loop", staleness_decay=-0.1))


# ---------------------------------------------------------------- staleness

def test_staleness_buffer_ages_and_weights():
    buf = StalenessBuffer(num_clients=3, proxy_size=6, num_classes=2)
    idx0 = np.array([0, 1, 2])
    logits = np.arange(3 * 3 * 2, dtype=np.float32).reshape(3, 3, 2)
    masks = np.ones((3, 3), bool)
    # round 0: clients 0, 1 report
    m0 = buf.merge(0, [True, True, False], idx0, logits, masks, decay=0.5)
    np.testing.assert_array_equal(m0.client_weights, [1.0, 1.0, 0.0])
    assert not m0.masks[2].any(), "never-reported client contributes nothing"
    # round 2 (client 1 skipped two rounds): only client 2 fresh
    idx2 = np.array([1, 2, 3])
    m2 = buf.merge(2, [False, False, True], idx2, logits, masks, decay=0.5)
    np.testing.assert_allclose(m2.client_weights, [0.25, 0.25, 1.0])
    # stale rows come from the cache at *this* round's indices: client 0
    # reported positions {0,1,2}, so position 3 is unknown for it
    np.testing.assert_array_equal(m2.masks[0], [True, True, False])
    np.testing.assert_allclose(m2.logits[0, 0], logits[0, 1])
    assert m2.mean_staleness == pytest.approx((2 + 2 + 0) / 3)


def test_staleness_decay_zero_drops_stale():
    buf = StalenessBuffer(2, 4, 2)
    idx = np.array([0, 1])
    logits = np.ones((2, 2, 2), np.float32)
    masks = np.ones((2, 2), bool)
    buf.merge(0, [True, True], idx, logits, masks, decay=0.0)
    m = buf.merge(1, [True, False], idx, logits, masks, decay=0.0)
    np.testing.assert_array_equal(m.client_weights, [1.0, 0.0])


def test_staleness_decay_one_full_reuse():
    buf = StalenessBuffer(2, 4, 2)
    idx = np.array([0, 1])
    logits = np.ones((2, 2, 2), np.float32)
    masks = np.ones((2, 2), bool)
    buf.merge(0, [True, True], idx, logits, masks, decay=1.0)
    m = buf.merge(5, [True, False], idx, logits, masks, decay=1.0)
    np.testing.assert_array_equal(m.client_weights, [1.0, 1.0])
    np.testing.assert_array_equal(m.masks, masks)


# ------------------------------------------------------------- regressions

@pytest.mark.parametrize("engine", ["loop", "cohort"])
def test_defaults_reproduce_legacy_logs_bit_for_bit(engine):
    """participation_fraction=1.0, staleness_decay=0 (the defaults) must
    leave the round logs *bit-for-bit* identical to the pre-participation
    protocol — replicated here as the exact legacy call sequence (engine
    calls without a mask, aggregation without client weights).

    round_mode is pinned to "sync": the legacy sequence IS the lockstep
    order, so the comparison must not follow the REPRO_ROUND_MODE=overlap
    CI matrix entry (sync stays the FedConfig default either way)."""
    cfg = _cfg(engine, rounds=2, round_mode="sync")
    new = simulator.run(cfg, "mnist_feat", n_train=800, n_test=300)

    clients, server, x_test, y_test = simulator.build_experiment(
        cfg, "mnist_feat", n_train=800, n_test=300)
    eng = simulator.build_engine(clients, cfg)
    method = get_method(cfg.method)
    key = jax.random.PRNGKey(cfg.seed)
    eng.learn_dres(key)
    for r, log in enumerate(new.rounds):
        local_losses = eng.local_train_all(cfg.local_epochs, cfg.batch_size)
        idx = server.select_indices(cfg.proxy_batch)
        px, powner = server.proxy.x[idx], server.proxy.owner[idx]
        logits, masks = eng.proxy_logits_and_masks(px, powner)
        teacher, valid = server.aggregate(logits, masks,
                                          sharpen=method.sharpen,
                                          entropy_filter=method.server_filter)
        distill_losses = eng.distill_all(px, teacher,
                                         valid.astype(np.float32),
                                         cfg.distill_epochs, cfg.batch_size)
        accs = eng.evaluate_all(x_test, y_test)
        assert log.accs == accs                               # bit-for-bit
        assert log.mean_acc == float(np.mean(accs))
        assert log.local_loss == float(np.mean(local_losses))
        assert log.distill_loss == float(np.mean(distill_losses))
        assert log.id_fraction == float(masks.mean())
        assert log.bytes_up == server.bytes_received
        assert log.bytes_down == server.bytes_broadcast
        assert log.participants is None and log.mean_staleness == 0.0


@pytest.mark.parametrize("policy,decay", [("uniform", 0.0),
                                          ("roundrobin", 0.5),
                                          ("weighted", 1.0)])
def test_loop_cohort_parity_partial_participation(policy, decay):
    """fraction < 1: loop and cohort logs must still match — the sampled
    subset, the skipped rng streams and the staleness reuse are all
    engine-independent."""
    results = {}
    for engine in ("loop", "cohort"):
        cfg = _cfg(engine, participation_fraction=0.5,
                   participation_policy=policy, staleness_decay=decay)
        results[engine] = simulator.run(cfg, "mnist_feat",
                                        n_train=800, n_test=300)
    for rl, rc in zip(results["loop"].rounds, results["cohort"].rounds):
        assert rl.participants == rc.participants
        assert len(rl.participants) == cohort_size(5, 0.5)
        np.testing.assert_allclose(rl.accs, rc.accs, **TOL)
        np.testing.assert_allclose(rl.local_loss, rc.local_loss, **TOL)
        np.testing.assert_allclose(rl.distill_loss, rc.distill_loss, **TOL)
        np.testing.assert_allclose(rl.id_fraction, rc.id_fraction, **TOL)
        np.testing.assert_allclose(rl.mean_staleness, rc.mean_staleness,
                                   **TOL)
        assert rl.bytes_up == rc.bytes_up
        assert rl.bytes_down == rc.bytes_down


def test_mesh_sharded_parity_partial_participation():
    """loop == cohort == mesh@4 under fraction < 1 (forced-device harness,
    like tests/test_cohort_parity.py): the participation mask must compose
    with the mesh's dummy-client padding. C=5 on 4 devices exercises a
    padded cohort with sampled-out real clients."""
    if jax.device_count() >= 4:
        import _mesh_parity_prog
        _mesh_parity_prog.check_parity(5, 4, participation_fraction=0.5,
                                       staleness_decay=0.5)
        return
    here = os.path.dirname(os.path.abspath(__file__))
    prog = os.path.join(here, "_mesh_parity_prog.py")
    src = os.path.abspath(os.path.join(here, "..", "src"))
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    res = subprocess.run(
        [sys.executable, prog, "--devices", "4", "--clients", "5",
         "--participation", "0.5", "--staleness-decay", "0.5"],
        env=env, capture_output=True, text=True, timeout=480)
    assert res.returncode == 0, (
        f"mesh participation parity subprocess failed:\n"
        f"{res.stdout}\n{res.stderr}")
    assert res.stdout.count("PARITY-OK") == 1, res.stdout


@pytest.mark.parametrize("method", ["edgefd", "fkd"])
def test_participation_reduces_upload_bytes(method):
    """Only participants upload — on the proxy-logit path (mask-compressed
    logits) and the data-free classwise path (per-class mean matrices)
    alike: at fraction 0.5 the per-round upload must be strictly below the
    full-participation run's."""
    full = simulator.run(_cfg("loop", rounds=2, method=method), "mnist_feat",
                         n_train=800, n_test=300)
    half = simulator.run(_cfg("loop", rounds=2, method=method,
                              participation_fraction=0.5),
                         "mnist_feat", n_train=800, n_test=300)
    assert half.rounds[-1].bytes_up < full.rounds[-1].bytes_up


def test_changing_subset_does_not_retrace_cohort_phases():
    """The participation mask changes plan *data*, never shapes: running
    rounds over different sampled subsets must reuse every compiled cohort
    phase (one trace per phase, total)."""
    from repro.fed.client import Client
    from repro.models.cnn import MLPClassifier
    from repro.optim.optimizers import sgd

    mlp = MLPClassifier(d_in=8, hidden=(16,), num_classes=4)
    traces = []

    def counting_apply(params, x, train):
        traces.append(tuple(x.shape))    # one entry per (re)trace
        return mlp.apply(params, x, train)

    rng = np.random.default_rng(0)
    opt = sgd(1e-2)
    key = jax.random.PRNGKey(0)
    clients = []
    for cid in range(4):
        key, sub = jax.random.split(key)
        clients.append(Client(
            cid, counting_apply, mlp.init(sub), opt,
            rng.normal(size=(64, 8)).astype(np.float32),
            rng.integers(0, 4, size=64), num_classes=4, arch_key="mlp",
            seed=0))
    engine = CohortEngine(clients)
    px = rng.normal(size=(32, 8)).astype(np.float32)
    teacher = rng.normal(size=(32, 4)).astype(np.float32)
    w = np.ones((32,), np.float32)
    masks = [np.array([True, True, False, False]),
             np.array([False, False, True, True]),
             np.array([True, False, True, False])]
    engine.local_train_all(1, 32, participants=masks[0])
    engine.distill_all(px, teacher, w, 1, 32, participants=masks[0])
    first = len(traces)
    for m in masks[1:]:
        engine.local_train_all(1, 32, participants=m)
        engine.distill_all(px, teacher, w, 1, 32, participants=m)
    assert len(traces) == first, (
        f"sampling a different subset retraced a phase: "
        f"{first} -> {len(traces)} traces ({traces})")


def test_run_round_records_participants_and_staleness():
    cfg = _cfg("loop", participation_fraction=0.6, staleness_decay=0.5)
    clients, server, x_test, y_test = simulator.build_experiment(
        cfg, "mnist_feat", n_train=800, n_test=300)
    engine = simulator.build_engine(clients, cfg)
    engine.learn_dres(jax.random.PRNGKey(cfg.seed))
    method = get_method(cfg.method)
    logs = [run_round(r, engine, server, method, cfg, x_test, y_test)
            for r in range(3)]
    k = cohort_size(cfg.num_clients, cfg.participation_fraction)
    assert all(len(log.participants) == k for log in logs)
    assert logs[0].mean_staleness == 0.0
    assert any(log.mean_staleness > 0.0 for log in logs[1:]), (
        "with fraction < 1 some aggregated knowledge must age")
