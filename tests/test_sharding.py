"""Sharding-spec derivation + HLO analysis unit tests (1-device safe)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.launch import mesh as M
from repro.launch.analytic import step_cost
from repro.launch.hlo_analysis import (collective_bytes_corrected,
                                       split_computations, while_trip_counts)
from repro.configs import SHAPES, get_arch


class FakeMesh:
    """Duck-typed mesh for spec derivation without devices."""
    def __init__(self, **axes):
        self.shape = dict(axes)
        self.axis_names = tuple(axes)


def test_param_spec_prefers_largest_divisible():
    mesh = FakeMesh(data=16, model=16)
    spec = M.param_spec((2048, 11008), mesh)
    assert spec == P("data", "model")
    spec = M.param_spec((11008, 2048), mesh)
    assert spec == P("model", "data")


def test_param_spec_skips_stack_axes():
    mesh = FakeMesh(data=16, model=16)
    spec = M.param_spec((36, 2048, 11008), mesh, n_stack_axes=1)
    assert spec[0] is None


def test_param_spec_indivisible_replicates():
    mesh = FakeMesh(data=16, model=16)
    spec = M.param_spec((10, 7), mesh)
    assert spec == P(None, None)


@settings(max_examples=40, deadline=None)
@given(dims=st.lists(st.integers(1, 4096), min_size=1, max_size=4),
       model=st.sampled_from([1, 4, 16]), data=st.sampled_from([1, 4, 16]))
def test_param_spec_always_divisible(dims, model, data):
    """Property: whatever the shape, assigned axes always divide evenly."""
    mesh = FakeMesh(data=data, model=model)
    spec = M.param_spec(tuple(dims), mesh)
    for d, axis in zip(dims, spec):
        if axis == "model":
            assert d % model == 0
        if axis == "data":
            assert d % data == 0
    # an axis is used at most once
    axes = [a for a in spec if a]
    assert len(axes) == len(set(axes))


def test_hlo_trip_count_correction():
    """A jitted scan's collectives must be multiplied by trip count."""
    mesh = jax.make_mesh((1,), ("data",))

    def f(x):
        def body(c, _):
            c = jax.lax.with_sharding_constraint(
                c, jax.sharding.NamedSharding(mesh, P(None)))
            return c * 2.0, None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    txt = jax.jit(f).lower(jnp.ones((8,))).compile().as_text()
    comps = split_computations(txt)
    assert len(comps) >= 1
    trips = while_trip_counts(txt)
    if trips:  # XLA may unroll tiny loops; if a while exists, trip must be 7
        assert any(t == 7 for _, t in trips)


def test_analytic_cost_model_scales():
    qwen = get_arch("qwen2.5-3b")
    llama = get_arch("llama3-405b")
    tr = SHAPES["train_4k"]
    c_q = step_cost(qwen, tr)
    c_l = step_cost(llama, tr)
    # 405b must cost ~2 orders of magnitude more compute than 3b
    assert c_l.flops / c_q.flops > 50
    # train flops ≈ 6·N·T
    t = tr.global_batch * tr.seq_len
    assert c_q.flops == pytest.approx(6 * qwen.active_param_count() * t,
                                      rel=0.35)


def test_analytic_decode_memory_dominated_by_params_or_cache():
    cfg = get_arch("llama3-405b")
    c = step_cost(cfg, SHAPES["decode_32k"])
    assert c.detail["param_bytes"] + c.detail["cache_bytes"] == \
        pytest.approx(c.hbm_bytes - SHAPES["decode_32k"].global_batch
                      * cfg.vocab_size * 2)


def test_make_debug_mesh_single_device():
    mesh = M.make_debug_mesh(1, 1)
    assert mesh.shape == {"data": 1, "model": 1}
