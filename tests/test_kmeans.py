"""KMeans + KMeans-DRE unit & property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.kmeans import kmeans_fit, min_dist_to_centroids, pairwise_sq_dists


def _blobs(key, n_per: int, centers, std=0.5):
    ks = jax.random.split(key, len(centers))
    xs = [c + std * jax.random.normal(k, (n_per, len(c)))
          for k, c in zip(ks, jnp.asarray(centers, jnp.float32))]
    return jnp.concatenate(xs)


def test_pairwise_matches_direct():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (40, 7))
    c = jax.random.normal(jax.random.fold_in(key, 1), (5, 7))
    direct = jnp.sum((x[:, None] - c[None]) ** 2, -1)
    np.testing.assert_allclose(np.asarray(pairwise_sq_dists(x, c)),
                               np.asarray(direct), rtol=1e-4, atol=1e-4)


def test_kmeans_recovers_blobs():
    key = jax.random.PRNGKey(1)
    centers = [[0.0, 0.0], [10.0, 0.0], [0.0, 10.0]]
    x = _blobs(key, 100, centers)
    res = kmeans_fit(jax.random.PRNGKey(2), x, 3)
    # each true center has a learned centroid within 1.0
    d = jnp.sqrt(pairwise_sq_dists(jnp.asarray(centers, jnp.float32),
                                   res.centroids))
    assert float(jnp.max(jnp.min(d, axis=1))) < 1.0


def test_kmeans_shapes_and_assignment_range():
    x = jax.random.normal(jax.random.PRNGKey(3), (123, 9))
    res = kmeans_fit(jax.random.PRNGKey(4), x, 4)
    assert res.centroids.shape == (4, 9)
    assert res.assignments.shape == (123,)
    assert int(res.assignments.min()) >= 0
    assert int(res.assignments.max()) < 4
    assert float(res.inertia) >= 0.0


@settings(max_examples=20, deadline=None)
@given(n=st.integers(10, 80), d=st.integers(1, 10), k=st.integers(1, 5),
       seed=st.integers(0, 2**31 - 1))
def test_kmeans_inertia_not_worse_than_single_centroid(n, d, k, seed):
    """Property: k centroids never fit worse than the global mean."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    res = kmeans_fit(jax.random.PRNGKey(seed + 1), x, k)
    mean = jnp.mean(x, axis=0, keepdims=True)
    inertia1 = float(jnp.sum(pairwise_sq_dists(x, mean)))
    assert float(res.inertia) <= inertia1 + 1e-3


@settings(max_examples=20, deadline=None)
@given(n=st.integers(5, 60), d=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
def test_min_dist_nonnegative_and_zero_on_centroids(n, d, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    res = kmeans_fit(jax.random.PRNGKey(seed + 1), x, min(3, n))
    md = min_dist_to_centroids(x, res.centroids)
    assert float(md.min()) >= 0.0
    on_cent = min_dist_to_centroids(res.centroids, res.centroids)
    np.testing.assert_allclose(np.asarray(on_cent), 0.0, atol=1e-3)
