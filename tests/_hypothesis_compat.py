"""Deterministic stand-in for `hypothesis` when the real package is absent.

The tier-1 suite uses a small slice of the hypothesis API:

    from hypothesis import given, settings, strategies as st
    @settings(max_examples=N, deadline=None)
    @given(a=st.integers(0, 9), b=st.floats(0.1, 5.0), ...)

When `hypothesis` is importable this module is never used (see conftest.py).
Otherwise conftest installs this module under the name ``hypothesis`` so the
property tests still run: each ``@given`` test executes ``max_examples``
examples drawn from a per-test deterministic RNG (seeded from the test's
qualified name), so failures are reproducible run-to-run. No shrinking, no
database — install the real package (requirements-dev.txt) for that.
"""
from __future__ import annotations

import random
import types
import zlib

__version__ = "0.0-repro-shim"


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example_from(self, rng: random.Random):
        return self._draw(rng)


def _integers(min_value, max_value):
    return _Strategy(lambda r: r.randint(min_value, max_value))


def _floats(min_value, max_value, **_kw):
    return _Strategy(lambda r: r.uniform(min_value, max_value))


def _booleans():
    return _Strategy(lambda r: bool(r.getrandbits(1)))


def _sampled_from(seq):
    elems = list(seq)
    return _Strategy(lambda r: elems[r.randrange(len(elems))])


def _lists(elements, min_size=0, max_size=10, **_kw):
    return _Strategy(
        lambda r: [elements.example_from(r)
                   for _ in range(r.randint(min_size, max_size))])


def _just(value):
    return _Strategy(lambda r: value)


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = _integers
strategies.floats = _floats
strategies.booleans = _booleans
strategies.sampled_from = _sampled_from
strategies.lists = _lists
strategies.just = _just

_DEFAULT_MAX_EXAMPLES = 10


class settings:
    """Decorator form only (the suite never uses profiles)."""

    def __init__(self, max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._shim_max_examples = self.max_examples
        return fn


def given(*args, **strat_kwargs):
    if args:
        raise TypeError("hypothesis shim supports keyword strategies only")

    def deco(fn):
        def wrapper(*a, **kw):
            n = getattr(wrapper, "_shim_max_examples",
                        getattr(fn, "_shim_max_examples", _DEFAULT_MAX_EXAMPLES))
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                drawn = {k: s.example_from(rng) for k, s in strat_kwargs.items()}
                fn(*a, **drawn, **kw)

        # no functools.wraps: pytest must see the wrapper's empty signature,
        # not the strategy parameters (they are not fixtures)
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        return wrapper

    return deco


class HealthCheck:
    too_slow = "too_slow"
    data_too_large = "data_too_large"


def assume(condition) -> bool:
    """Best effort: silently accept (shim draws are unconditioned)."""
    return bool(condition)
