"""Checkpoint layer: atomic writes, retention, corruption fallback, and
the nested-manifest experiment-state format (repro.checkpoint.ckpt)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (checkpoint_steps, latest_step,
                              restore_checkpoint, restore_state,
                              save_checkpoint, save_state)
from repro.fed.state import load_rng_state, rng_state_dict


def _ls(d):
    return sorted(os.listdir(d))


def test_atomic_write_leaves_no_orphans(tmp_path):
    """The historical bug: np.savez handed a name without ``.npz``
    silently appends one, so tmp files became ``ckpt_*.npz.tmp.npz``
    orphans and the rename missed. The atomic writer must leave exactly
    the final file."""
    d = str(tmp_path / "ck")
    save_checkpoint(d, 3, {"w": jnp.zeros((2,))})
    assert _ls(d) == ["ckpt_00000003.npz"]
    save_state(d, 4, {"x": np.arange(3)})
    assert _ls(d) == ["ckpt_00000003.npz", "ckpt_00000004.npz"]


def test_latest_step_sweeps_stale_tmp_files(tmp_path):
    """A writer that died mid-save leaves ``ckpt_*.tmp*`` siblings; they
    are never valid restore targets and latest_step deletes them."""
    d = str(tmp_path / "ck")
    save_checkpoint(d, 1, {"w": jnp.zeros(2)})
    for orphan in ("ckpt_00000002.npz.tmp.npz", "ckpt_00000002.npz.tmp"):
        with open(os.path.join(d, orphan), "wb") as f:
            f.write(b"torn write")
    assert latest_step(d) == 1
    assert _ls(d) == ["ckpt_00000001.npz"]


def test_latest_step_missing_dir(tmp_path):
    assert latest_step(str(tmp_path / "nope")) is None
    assert checkpoint_steps(str(tmp_path / "nope")) == []


def test_retention_keeps_last_k(tmp_path):
    d = str(tmp_path / "ck")
    for s in range(5):
        save_checkpoint(d, s, {"w": jnp.zeros(2)}, keep_last=2)
    assert checkpoint_steps(d) == [3, 4]
    # state-format saves share the same retention
    save_state(d, 5, {"x": 1}, keep_last=2)
    assert checkpoint_steps(d) == [4, 5]


def test_corrupt_checkpoint_falls_back_with_warning(tmp_path):
    """A truncated newest file must not take the service down: restore
    warns and steps back to the previous checkpoint."""
    d = str(tmp_path / "ck")
    save_state(d, 1, {"val": 10, "arr": np.arange(4)})
    save_state(d, 2, {"val": 20, "arr": np.arange(4)})
    path2 = os.path.join(d, "ckpt_00000002.npz")
    with open(path2, "r+b") as f:  # tear the zip central directory
        f.truncate(os.path.getsize(path2) // 2)
    with pytest.warns(UserWarning, match="unreadable"):
        state = restore_state(d, 2, fallback=True)
    assert state["val"] == 10
    np.testing.assert_array_equal(state["arr"], np.arange(4))
    with pytest.raises(Exception):
        restore_state(d, 2, fallback=False)


def test_corrupt_pytree_checkpoint_falls_back(tmp_path):
    d = str(tmp_path / "ck")
    like = {"w": jnp.zeros(3)}
    save_checkpoint(d, 1, {"w": jnp.arange(3.0)})
    save_checkpoint(d, 2, {"w": jnp.arange(3.0) * 2})
    with open(os.path.join(d, "ckpt_00000002.npz"), "wb") as f:
        f.write(b"not a zip at all")
    with pytest.warns(UserWarning, match="unreadable"):
        out = restore_checkpoint(d, 2, like, fallback=True)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(3.0))


def test_save_state_roundtrips_nonarray_leaves(tmp_path):
    """The resume path carries ints beyond 64 bits (PCG64 words), None,
    bools, strs, nested lists and mixed arrays — all must round-trip
    exactly, with array dtypes preserved."""
    d = str(tmp_path / "ck")
    gen = np.random.default_rng(7)
    gen.standard_normal(13)  # advance so the state is nontrivial
    state = {
        "version": 1,
        "cursors": {"round": 42, "edge": [0, 3, None]},
        "big": (1 << 100) + 12345,  # wider than any numpy integer
        "flags": [True, False, None, "sync", 2.5],
        "mask": np.array([True, False, True]),
        "f32": np.arange(6, dtype=np.float32).reshape(2, 3),
        "i64": np.arange(4, dtype=np.int64),
        "jax": jnp.ones((2, 2)),
        "rng": rng_state_dict(gen),
        "empty": [],
    }
    save_state(d, 0, state)
    out = restore_state(d)
    assert out["version"] == 1 and out["cursors"] == state["cursors"]
    assert out["big"] == state["big"]
    assert out["flags"] == state["flags"]
    assert out["empty"] == []
    for k in ("mask", "f32", "i64"):
        np.testing.assert_array_equal(out[k], state[k])
        assert out[k].dtype == np.asarray(state[k]).dtype
    np.testing.assert_array_equal(out["jax"], np.ones((2, 2)))
    # restored rng state drives a generator to identical draws
    gen2 = np.random.default_rng(0)
    load_rng_state(gen2, out["rng"])
    np.testing.assert_array_equal(gen.standard_normal(5),
                                  gen2.standard_normal(5))


def test_save_state_rejects_bad_structures(tmp_path):
    d = str(tmp_path / "ck")
    with pytest.raises(TypeError, match="keys must be str"):
        save_state(d, 0, {1: "int key"})
    with pytest.raises(TypeError, match="reserved"):
        save_state(d, 0, {"__npz__": "reserved key"})
    with pytest.raises(TypeError, match="unserializable"):
        save_state(d, 0, {"bad": object()})


def test_restore_state_on_pytree_checkpoint_raises(tmp_path):
    d = str(tmp_path / "ck")
    save_checkpoint(d, 0, {"w": jnp.zeros(2)})
    with pytest.raises(KeyError, match="manifest"):
        restore_state(d, 0)


def test_shape_mismatch_message_names_leaf_and_shapes(tmp_path):
    d = str(tmp_path / "ck")
    save_checkpoint(d, 0, {"layer": {"w": jnp.zeros((2, 4))}})
    with pytest.raises(ValueError) as ei:
        restore_checkpoint(d, 0, {"layer": {"w": jnp.zeros((3, 4))}})
    msg = str(ei.value)
    assert "shape mismatch" in msg and "layer/w" in msg
    assert "(2, 4)" in msg and "(3, 4)" in msg


def test_missing_leaf_raises_keyerror(tmp_path):
    d = str(tmp_path / "ck")
    save_checkpoint(d, 0, {"a": jnp.zeros(2)})
    with pytest.raises(KeyError, match="missing leaf"):
        restore_checkpoint(d, 0, {"a": jnp.zeros(2), "b": jnp.zeros(2)})


def test_restore_with_shardings_device_puts(tmp_path):
    """Restore-time resharding: leaves are device_put onto the supplied
    sharding (a 1-device mesh here; the forced-4-device path is covered
    by the mesh resume subprocess test)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec
    d = str(tmp_path / "ck")
    tree = {"w": jnp.arange(8.0)}
    save_checkpoint(d, 0, tree)
    mesh = Mesh(np.array(jax.devices()[:1]), ("clients",))
    sh = {"w": NamedSharding(mesh, PartitionSpec())}
    out = restore_checkpoint(d, 0, jax.tree.map(jnp.zeros_like, tree), sh)
    assert isinstance(out["w"], jax.Array)
    assert out["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(8.0))


def test_flaky_writer_retries_then_succeeds(tmp_path, monkeypatch):
    """Transient OSError from the tmp-file writer: _atomic_savez retries
    with exponential backoff (sleeping between attempts), warns per
    failure, and the checkpoint still lands intact."""
    from repro.checkpoint import ckpt

    real_write = ckpt._write_tmp
    calls = {"n": 0}

    def flaky(tmp, arrays):
        calls["n"] += 1
        if calls["n"] <= 2:
            raise OSError(28, "No space left on device (injected)")
        real_write(tmp, arrays)

    sleeps = []
    monkeypatch.setattr(ckpt, "_write_tmp", flaky)
    monkeypatch.setattr(ckpt.time, "sleep", sleeps.append)
    d = str(tmp_path / "ck")
    with pytest.warns(UserWarning, match="retry"):
        save_state(d, 7, {"x": np.arange(3)})
    assert calls["n"] == 3
    assert sleeps == sorted(sleeps) and len(sleeps) == 2  # backoff grows
    assert sleeps[1] > sleeps[0]
    out = restore_state(d, 7)
    np.testing.assert_array_equal(out["x"], np.arange(3))


def test_flaky_writer_exhausts_retries_and_raises(tmp_path, monkeypatch):
    """A persistent storage fault surfaces as OSError after the retry
    budget — callers (fed_serve) decide whether to warn-and-continue —
    and no tmp orphan or torn final file is left behind."""
    from repro.checkpoint import ckpt

    def always_fail(tmp, arrays):
        raise OSError(30, "Read-only file system (injected)")

    monkeypatch.setattr(ckpt, "_write_tmp", always_fail)
    monkeypatch.setattr(ckpt.time, "sleep", lambda s: None)
    d = str(tmp_path / "ck")
    with pytest.warns(UserWarning, match="retry"):
        with pytest.raises(OSError, match="Read-only"):
            save_state(d, 1, {"x": np.arange(3)})
    assert latest_step(d) is None  # sweeps any tmp litter, finds nothing
