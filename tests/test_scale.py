"""Fleet-scale machinery gates (wave streaming, two-tier server, clock
traces, communication accounting).

What must hold, in order of importance:

  * the scale knobs are invisible when disabled: ``wave_size=0`` and
    ``num_edge_aggregators=1`` reproduce the historical round logs
    **bit-for-bit**, and a waved run equals the device-resident run
    exactly (per-client lanes are independent, so padding differences
    cannot leak into results);
  * the two-tier server is a regrouped sum: E edges vs the flat server
    agree on accuracies within float tolerance and on the byte ledger
    *exactly*;
  * upload pricing is pre-filter (what crossed the network), downloads
    are priced on every teacher broadcast — including the data-free
    classwise path;
  * the clock's trace machinery (speeds, arrivals, churn, dropout) is
    deterministic in ``(seed, round, client)`` and stable under fleet
    growth, and every vectorized rewrite (stale merge, timeline) is
    pinned bit-identical to its per-client loop reference;
  * streaming waves changes plan *data*, never shapes: one trace per
    phase, no matter how many waves pass through the device.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.types import FedConfig
from repro.core.filtering import server_entropy_filter
from repro.core.protocol import as_engine
from repro.data.proxy import ProxyData
from repro.fed import simulator
from repro.fed.clock import (SimTimeline, arrival_offsets, client_speeds,
                             dropout_mask, online_mask)
from repro.fed.cohort import CohortEngine
from repro.fed.participation import StalenessBuffer, cohort_size
from repro.fed.server import Server

TOL = dict(rtol=0.0, atol=1e-5)


def _cfg(**kw):
    base = dict(num_clients=5, rounds=2, method="edgefd", scenario="strong",
                proxy_batch=120, batch_size=32, lr=1e-2, seed=0,
                engine="cohort")
    base.update(kw)
    return FedConfig(**base)


def _run(cfg):
    return simulator.run(cfg, "mnist_feat", n_train=600, n_test=200)


def _tiny_clients(n=5, apply_fn=None, d_in=8, num_classes=4):
    from repro.fed.client import Client
    from repro.models.cnn import MLPClassifier
    from repro.optim.optimizers import sgd

    mlp = MLPClassifier(d_in=d_in, hidden=(16,), num_classes=num_classes)
    rng = np.random.default_rng(0)
    key = jax.random.PRNGKey(0)
    opt = sgd(1e-2)          # cohort members must share one instance
    clients = []
    for cid in range(n):
        key, sub = jax.random.split(key)
        clients.append(Client(
            cid, apply_fn or mlp.apply, mlp.init(sub), opt,
            rng.normal(size=(64, d_in)).astype(np.float32),
            rng.integers(0, num_classes, size=64),
            num_classes=num_classes, arch_key="mlp", seed=0))
    return mlp, clients


def _stub_server(t=6, k=4, num_edges=1):
    proxy = ProxyData(x=np.zeros((t, 3), np.float32),
                      y=np.zeros((t,), np.int64),
                      owner=np.zeros((t,), np.int32))
    return Server(proxy, seed=0, num_edges=num_edges)


# ------------------------------------------------------------ wave parity

def test_wave_streaming_bit_identical():
    """Streaming C=5 through the device in waves of 2 must reproduce the
    device-resident run bit-for-bit — results, losses and the byte ledger."""
    a = _run(_cfg())
    b = _run(_cfg(wave_size=2))
    for ra, rb in zip(a.rounds, b.rounds):
        np.testing.assert_array_equal(np.asarray(ra.accs),
                                      np.asarray(rb.accs))
        assert ra.local_loss == rb.local_loss
        assert ra.distill_loss == rb.distill_loss
        assert ra.id_fraction == rb.id_fraction
        assert ra.bytes_up == rb.bytes_up
        assert ra.bytes_down == rb.bytes_down


def test_wave_streaming_with_participation_and_staleness():
    """Waves compose with the subset/staleness path: same sampled subsets,
    same teachers, same ledger."""
    kw = dict(participation_fraction=0.6, staleness_decay=0.5, rounds=3)
    a = _run(_cfg(**kw))
    b = _run(_cfg(wave_size=2, **kw))
    for ra, rb in zip(a.rounds, b.rounds):
        assert ra.participants == rb.participants
        np.testing.assert_allclose(np.asarray(ra.accs),
                                   np.asarray(rb.accs), **TOL)
        assert ra.bytes_up == rb.bytes_up
        assert ra.mean_staleness == rb.mean_staleness


def test_wave_size_validation():
    _, clients = _tiny_clients(3)
    with pytest.raises(ValueError, match="wave_size"):
        CohortEngine(clients, wave_size=-1)
    with pytest.raises(ValueError, match="cohort"):
        as_engine(clients, "loop", wave_size=2)


def test_wave_streaming_does_not_retrace():
    """Every wave reuses the compiled phases: lead shapes are the padded
    wave size, so wave 1..W hit the trace of wave 0 — and later rounds hit
    it too. O(1) compiles regardless of C/wave_size."""
    from repro.models.cnn import MLPClassifier

    mlp = MLPClassifier(d_in=8, hidden=(16,), num_classes=4)
    traces = []

    def counting_apply(params, x, train):
        traces.append(tuple(x.shape))    # one entry per (re)trace
        return mlp.apply(params, x, train)

    _, clients = _tiny_clients(5, apply_fn=counting_apply)
    engine = CohortEngine(clients, wave_size=2)   # 3 waves over C=5
    rng = np.random.default_rng(0)
    px = rng.normal(size=(32, 8)).astype(np.float32)
    teacher = rng.normal(size=(32, 4)).astype(np.float32)
    w = np.ones((32,), np.float32)
    engine.local_train_all(1, 32)
    engine.distill_all(px, teacher, w, 1, 32)
    first = len(traces)
    for _ in range(2):
        engine.local_train_all(1, 32)
        engine.distill_all(px, teacher, w, 1, 32)
    assert len(traces) == first, (
        f"wave streaming retraced a phase: {first} -> {len(traces)} "
        f"traces ({traces})")


# ------------------------------------------------------- two-tier server

@pytest.mark.parametrize("kw", [
    dict(),                                                  # full, fresh
    dict(participation_fraction=0.6, staleness_decay=0.5),   # subset+stale
    dict(method="selective-fd"),               # entropy filter at the edges
])
def test_two_tier_matches_flat_server(kw):
    """E edge aggregators are a regrouped sum over client shards: same
    accuracies (float tolerance), identical byte ledger and staleness."""
    a = _run(_cfg(rounds=3, **kw))
    b = _run(_cfg(rounds=3, num_edge_aggregators=3, **kw))
    for ra, rb in zip(a.rounds, b.rounds):
        np.testing.assert_allclose(np.asarray(ra.accs),
                                   np.asarray(rb.accs), **TOL)
        assert ra.bytes_up == rb.bytes_up
        assert ra.bytes_down == rb.bytes_down
        np.testing.assert_allclose(ra.mean_staleness, rb.mean_staleness,
                                   **TOL)


def test_more_edges_than_clients_is_capped():
    res = _run(_cfg(num_edge_aggregators=64))
    assert res.rounds[-1].mean_acc >= 0.0


def test_edge_count_validation():
    with pytest.raises(ValueError, match="num_edges"):
        _stub_server(num_edges=0)


def test_two_tier_subset_prices_fresh_uploads_only():
    """Edges price uploads from the *pre-filter fresh* masks of this
    round's reporters — stale reuse crosses no network and costs nothing;
    flat and two-tier servers agree on the ledger exactly."""
    rng = np.random.default_rng(0)
    C, t, k = 4, 6, 4
    part = np.array([True, False, True, False])
    idx = np.arange(t)
    logits = rng.normal(size=(C, t, k)).astype(np.float32)
    masks = rng.random((C, t)) < 0.7
    logits[~part] = 0.0
    masks[~part] = False
    expected = int(masks[part].sum()) * k * 4

    for edges in (1, 2):
        srv = _stub_server(t=t, k=k, num_edges=edges)
        srv.ingest_reports(0, part, idx, logits, masks, decay=0.5)
        srv.aggregate_round(0)
        assert srv.bytes_received == expected, f"num_edges={edges}"


# ------------------------------------------------- communication ledger

def test_aggregate_prices_prefilter_uploads():
    """Clients upload their ID rows *before* the server-side entropy
    filter tightens the masks: bytes_received must price the pre-filter
    masks (the filtered count undercounted Selective-FD's uploads)."""
    C, t, k = 3, 6, 4
    logits = np.zeros((C, t, k), np.float32)
    logits[:, :3] = np.array([8.0, 0.0, 0.0, 0.0])   # confident → kept
    masks = np.ones((C, t), bool)                    # flat rows → filtered
    kept = np.asarray(server_entropy_filter(jnp.asarray(logits),
                                            jnp.asarray(masks)))
    assert kept.sum() < masks.sum(), "filter must tighten some rows"

    srv = _stub_server(t=t, k=k)
    srv.aggregate(logits, masks, entropy_filter=True)
    assert srv.bytes_received == int(masks.sum()) * k * 4


def test_classwise_broadcast_is_accounted():
    """The fused classwise teacher is broadcast like any other teacher:
    the data-free FKD/PLS path must not report zero download traffic."""
    rng = np.random.default_rng(0)
    C, k_cls, k = 4, 5, 5
    mc = [(rng.normal(size=(k_cls, k)).astype(np.float32),
           rng.integers(0, 3, size=k_cls).astype(np.float32))
          for _ in range(C)]
    srv = _stub_server(t=6, k=k)
    teacher, _ = srv.aggregate_classwise(mc, count_weighted=True)
    assert srv.bytes_broadcast == teacher.size * 4
    assert srv.bytes_received == C * k_cls * k * 4


# ----------------------------------------------------- clock trace pins

@pytest.mark.parametrize("seed", [0, 1, 12345])
def test_client_speeds_match_per_client_generator(seed):
    """The vectorized SeedSequence/PCG64 lanes must stay bit-identical to
    constructing one numpy Generator per client."""
    got = client_speeds(7, seed=seed, straggler_factor=4.0)
    for cid in range(7):
        rng = np.random.default_rng(
            np.random.SeedSequence([seed, cid, 0xC10C]))
        assert got[cid] == 1.0 + 3.0 * rng.random(), f"client {cid}"


def test_poisson_arrivals_match_per_client_generator():
    off = arrival_offsets(5, 3, seed=7, process="poisson", spread=10.0)
    for cid in range(5):
        rng = np.random.default_rng(
            np.random.SeedSequence([7, 3, cid, 0xA881]))
        assert off[cid] == 10.0 * -np.log1p(-rng.random()), f"client {cid}"


def test_arrival_traces_deterministic_and_stable_under_growth():
    """A client's trace depends on (seed, round, client) only — growing
    the fleet must not reshuffle the existing clients' arrivals."""
    for proc in ("poisson", "bursty"):
        a = arrival_offsets(16, 2, seed=3, process=proc, spread=30.0)
        b = arrival_offsets(16, 2, seed=3, process=proc, spread=30.0)
        np.testing.assert_array_equal(a, b)
        big = arrival_offsets(64, 2, seed=3, process=proc, spread=30.0)
        np.testing.assert_array_equal(big[:16], a)
        other = arrival_offsets(16, 3, seed=3, process=proc, spread=30.0)
        assert not np.array_equal(other, a), "trace must vary per round"
        assert (a >= 0).all()


def test_arrival_static_and_zero_spread_are_free():
    assert arrival_offsets(8, 0, seed=0, process="static", spread=5.0) is None
    assert arrival_offsets(8, 0, seed=0, process="poisson", spread=0.0) is None
    assert arrival_offsets(0, 0, seed=0, process="poisson", spread=5.0) is None


def test_churn_and_dropout_masks():
    assert online_mask(8, 0, seed=0, churn=0.0) is None
    assert dropout_mask(8, 0, seed=0, dropout=0.0) is None
    on = online_mask(4096, 1, seed=0, churn=0.25)
    assert 0.6 < on.mean() < 0.9          # ~75% stay online
    np.testing.assert_array_equal(on, online_mask(4096, 1, seed=0,
                                                  churn=0.25))
    drop = dropout_mask(4096, 1, seed=0, dropout=0.1)
    assert 0.02 < drop.mean() < 0.2
    assert not np.array_equal(drop, dropout_mask(4096, 2, seed=0,
                                                 dropout=0.1))


def test_timeline_matches_per_client_loop():
    """The vectorized lane update must equal the serial per-client loop —
    lane occupancy, barriers and all — across rounds with offsets."""
    speeds = client_speeds(6, seed=0)
    vec, ref = SimTimeline(speeds), SimTimeline(speeds)
    rng = np.random.default_rng(0)
    for r in range(4):
        part = rng.random(6) < 0.7
        offs = (rng.random(6) * 3.0).astype(np.float64)
        base, ready = 1.0 + r, float(r)
        got = vec.client_phase(part, base, ready_s=ready, offsets=offs)
        finishes = []
        for c in np.flatnonzero(part):
            start = max(ready + offs[c], ref.client_free[c])
            fin = start + base * speeds[c]
            ref.client_free[c] = fin
            finishes.append(fin)
        assert got == max([ready] + finishes)
        np.testing.assert_array_equal(vec.client_free, ref.client_free)


def test_stale_merge_matches_per_client_loop():
    """The fancy-index buffer write (one numpy op) must stay bit-identical
    to the historical per-client loop it replaced."""
    rng = np.random.default_rng(1)
    C, P, t, K = 6, 12, 5, 3
    buf = StalenessBuffer(C, P, K)
    ref_logits = np.zeros((C, P, K), np.float32)
    ref_masks = np.zeros((C, P), bool)
    for r in range(4):
        part = rng.random(C) < 0.5
        part[r % C] = True                       # never an empty round
        idx = rng.choice(P, size=t, replace=False)
        logits = rng.normal(size=(C, t, K)).astype(np.float32)
        masks = rng.random((C, t)) < 0.8
        logits[~part] = 0.0
        masks[~part] = False
        merged = buf.merge(r, part, idx, logits, masks, 0.5)
        for c in np.flatnonzero(part):
            ref_logits[c, idx] = logits[c]
            ref_masks[c, idx] = masks[c]
        np.testing.assert_array_equal(buf.logits, ref_logits)
        np.testing.assert_array_equal(buf.masks, ref_masks)
        np.testing.assert_array_equal(
            merged.masks, np.where(part[:, None], masks, ref_masks[:, idx]))


# ------------------------------------------------------- cohort_size pin

def test_cohort_size_bankers_rounding_pinned():
    """round() is banker's rounding: half-integers go to the nearest even
    count. Every golden/round log encodes this, so it is pinned."""
    assert cohort_size(5, 0.5) == 2      # 2.5 → 2, not 3
    assert cohort_size(7, 0.5) == 4      # 3.5 → 4
    assert cohort_size(10, 0.25) == 2    # 2.5 → 2
    assert cohort_size(6, 0.5) == 3
    assert cohort_size(3, 0.01) == 1     # clamped to >= 1
    assert cohort_size(3, 1.0) == 3


# ------------------------------------------------- scheduler integration

def test_churn_dropout_round_runs_and_ages_reports():
    """A full stack round with bursty arrivals + churn + dropout on top of
    subset sampling must run, keep accuracies sane and age some reports."""
    cfg = _cfg(rounds=3, participation_fraction=0.6, staleness_decay=0.5,
               arrival_process="bursty", arrival_spread=30.0,
               churn_prob=0.2, dropout_prob=0.1, num_edge_aggregators=2,
               wave_size=2)
    res = _run(cfg)
    assert all(0.0 <= r.mean_acc <= 1.0 for r in res.rounds)
    assert any(r.mean_staleness > 0.0 for r in res.rounds[1:]), (
        "churn/dropout must leave some aggregated reports stale")
    # arrivals push the simulated finish later than the static clock
    static = _run(_cfg(rounds=3, participation_fraction=0.6,
                       staleness_decay=0.5))
    assert res.rounds[-1].sim_finish_s > static.rounds[-1].sim_finish_s


def test_bad_traffic_config_fails_fast():
    from repro.fed.scheduler import validate_config
    with pytest.raises(ValueError, match="arrival_process"):
        validate_config(_cfg(arrival_process="diurnal"))
    with pytest.raises(ValueError, match="churn"):
        validate_config(_cfg(churn_prob=1.0))
    with pytest.raises(ValueError, match="dropout"):
        validate_config(_cfg(dropout_prob=-0.1))
    with pytest.raises(ValueError, match="arrival_spread"):
        validate_config(_cfg(arrival_spread=-1.0))
