"""Regenerate the default-backend golden round logs.

``tests/test_kernel_dispatch.py`` asserts that federated round logs under
the *default* kernel backend (auto -> jnp on CPU) stay bit-for-bit
identical to the logs recorded before the Pallas dispatch layer landed
(PR 4). The golden file was generated from the pre-dispatch tree; rerun
this ONLY if an intentional numeric change is being made, and say so in
the commit message:

    PYTHONPATH=src:tests python tests/_golden_gen.py
"""
from __future__ import annotations

import json
import os
import pathlib

os.environ.setdefault("JAX_PLATFORMS", "cpu")
# the goldens certify the jnp reference path (the CPU default) — pin it so
# an exported REPRO_KERNEL_BACKEND=pallas can't silently poison them
os.environ["REPRO_KERNEL_BACKEND"] = "jnp"

GOLDEN_PATH = pathlib.Path(__file__).parent / "data" / "golden_rounds.json"

# tiny but real: exercises KMeans-DRE fit + calibration + filter + KL
# distill (edgefd) and the KuLSIF learn/estimate path (selective-fd)
CASES = [
    {"name": "edgefd_loop", "method": "edgefd", "engine": "loop"},
    {"name": "edgefd_cohort", "method": "edgefd", "engine": "cohort"},
    {"name": "selectivefd_loop", "method": "selective-fd", "engine": "loop"},
]
DATA_KW = dict(n_train=600, n_test=200)


def run_case(case):
    from repro.common.types import FedConfig
    from repro.fed import simulator

    cfg = FedConfig(num_clients=4, rounds=2, method=case["method"],
                    scenario="strong", proxy_batch=128, batch_size=32,
                    seed=0, engine=case["engine"])
    res = simulator.run(cfg, "mnist_feat", **DATA_KW)
    return [
        {"round": log.round, "mean_acc": log.mean_acc, "accs": log.accs,
         "local_loss": log.local_loss, "distill_loss": log.distill_loss,
         "id_fraction": log.id_fraction, "bytes_up": log.bytes_up,
         "bytes_down": log.bytes_down}
        for log in res.rounds
    ]


def main():
    out = {case["name"]: run_case(case) for case in CASES}
    GOLDEN_PATH.parent.mkdir(parents=True, exist_ok=True)
    with open(GOLDEN_PATH, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {GOLDEN_PATH}")


if __name__ == "__main__":
    main()
