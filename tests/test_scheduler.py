"""Round phase-graph scheduler (repro.fed.scheduler) + straggler clock.

Gates, in order of importance:

  * ``round_mode="sync"`` (the default) reproduces the pre-scheduler round
    logs **bit-for-bit** (pinned against ``tests/data/golden_rounds.json``,
    the same goldens the kernel-dispatch layer certifies against);
  * under ``round_mode="overlap"`` the loop and cohort engines (and the
    mesh-sharded cohort engine, via the forced-device harness) produce
    identical round logs — the pipeline schedule is engine-independent;
  * the overlap schedule is deterministic in the seed: same seed ⇒ same
    execution trace, same logs, same straggler speeds;
  * the simulated straggler timeline prices overlap strictly below sync
    for the same per-phase costs;
  * ``run_round`` rejects a zero/negative/overful participation fraction
    on every entry path.
"""
import json
import os
import pathlib
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.common.types import FedConfig
from repro.core.methods import get_method
from repro.core.protocol import run_round
from repro.fed import simulator
from repro.fed.clock import SimTimeline, client_speeds
from repro.fed.scheduler import (RoundScheduler, resolve_round_mode,
                                 round_phases, validate_config)

GOLDEN_PATH = pathlib.Path(__file__).parent / "data" / "golden_rounds.json"
TOL = dict(rtol=0.0, atol=1e-5)


def _cfg(engine="loop", **kw):
    base = dict(num_clients=5, rounds=3, method="edgefd", scenario="strong",
                proxy_batch=120, batch_size=32, lr=1e-2, seed=0,
                engine=engine)
    base.update(kw)
    return FedConfig(**base)


def _overlap_cfg(engine="loop", **kw):
    base = dict(round_mode="overlap", max_inflight=2,
                participation_fraction=0.6, staleness_decay=0.5)
    base.update(kw)
    return _cfg(engine, **base)


def _build_scheduler(cfg, **sched_kw):
    clients, server, x_test, y_test = simulator.build_experiment(
        cfg, "mnist_feat", n_train=800, n_test=300)
    engine = simulator.build_engine(clients, cfg)
    engine.learn_dres(jax.random.PRNGKey(cfg.seed))
    return RoundScheduler(engine, server, get_method(cfg.method), cfg,
                          x_test, y_test, **sched_kw)


# ----------------------------------------------------------- golden (sync)

def test_sync_mode_reproduces_golden_logs_bit_for_bit():
    """The scheduler's sync path must replay the lockstep Algorithm-1
    order exactly: same goldens as the pre-scheduler tree, bit for bit.
    round_mode/kernel_backend/zoo are pinned so the test also holds under
    the REPRO_ROUND_MODE=overlap / REPRO_KERNEL_BACKEND=pallas /
    REPRO_ZOO=mixed CI entries — on a clean CPU host these pins ARE the
    defaults."""
    golden = json.loads(GOLDEN_PATH.read_text())
    for name, method, engine in [("edgefd_loop", "edgefd", "loop"),
                                 ("edgefd_cohort", "edgefd", "cohort")]:
        cfg = FedConfig(num_clients=4, rounds=2, method=method,
                        scenario="strong", proxy_batch=128, batch_size=32,
                        seed=0, engine=engine, round_mode="sync",
                        kernel_backend="jnp", zoo="shared")
        res = simulator.run(cfg, "mnist_feat", n_train=600, n_test=200)
        assert len(res.rounds) == len(golden[name])
        for g, n in zip(golden[name], res.rounds):
            assert g["accs"] == n.accs, (name, n.round)
            assert g["mean_acc"] == n.mean_acc
            assert g["local_loss"] == n.local_loss
            assert g["distill_loss"] == n.distill_loss
            assert g["id_fraction"] == n.id_fraction
            assert g["bytes_up"] == n.bytes_up
            assert g["bytes_down"] == n.bytes_down


def test_sync_trace_is_lockstep():
    cfg = _cfg(rounds=2, round_mode="sync")
    sched = _build_scheduler(cfg)
    sched.run_rounds(0, cfg.rounds)
    expected = [(p, r) for r in range(2)
                for p in round_phases(get_method(cfg.method))]
    assert sched.trace == expected


# ------------------------------------------------------------ overlap mode

def test_overlap_pipeline_reorders_phases():
    """max_inflight=2 must run round 1's local_train/report BEFORE round
    0's aggregate — that reordering IS the overlap."""
    cfg = _overlap_cfg(rounds=3)
    sched = _build_scheduler(cfg)
    sched.run_rounds(0, cfg.rounds)
    t = sched.trace
    assert t.index(("local_train", 1)) < t.index(("aggregate", 0))
    assert t.index(("report", 1)) < t.index(("aggregate", 0))
    # admission control: round 2 must NOT start before round 0 retired
    assert t.index(("local_train", 2)) > t.index(("eval", 0))
    # drains stay in round order (server rng / buffer / log assembly)
    assert t.index(("aggregate", 0)) < t.index(("aggregate", 1))
    assert t.index(("eval", 0)) < t.index(("eval", 1))


def test_overlap_schedule_deterministic_in_seed():
    """Same seed ⇒ identical execution trace, identical round logs (bit
    for bit) and identical straggler speeds across two fresh builds."""
    runs = []
    for _ in range(2):
        cfg = _overlap_cfg(rounds=3)
        sched = _build_scheduler(cfg)
        logs = sched.run_rounds(0, cfg.rounds)
        runs.append((sched.trace, logs, sched.timeline.speeds.copy()))
    (t0, l0, s0), (t1, l1, s1) = runs
    assert t0 == t1
    np.testing.assert_array_equal(s0, s1)
    for a, b in zip(l0, l1):
        assert a.accs == b.accs
        assert a.local_loss == b.local_loss
        assert a.distill_loss == b.distill_loss
        assert a.participants == b.participants


@pytest.mark.parametrize("method", ["edgefd", "fkd", "indlearn"])
def test_overlap_loop_cohort_parity(method):
    """The pipeline schedule is engine-independent: loop and cohort logs
    must match under overlap — across the proxy-distillation, data-free
    and no-collaboration phase graphs."""
    results = {}
    for engine in ("loop", "cohort"):
        cfg = _overlap_cfg(engine, method=method)
        results[engine] = simulator.run(cfg, "mnist_feat",
                                        n_train=800, n_test=300)
    for rl, rc in zip(results["loop"].rounds, results["cohort"].rounds):
        assert rl.participants == rc.participants
        np.testing.assert_allclose(rl.accs, rc.accs, **TOL)
        np.testing.assert_allclose(rl.local_loss, rc.local_loss, **TOL)
        np.testing.assert_allclose(rl.distill_loss, rc.distill_loss, **TOL)
        np.testing.assert_allclose(rl.id_fraction, rc.id_fraction, **TOL)
        np.testing.assert_allclose(rl.mean_staleness, rc.mean_staleness,
                                   **TOL)
        assert rl.bytes_up == rc.bytes_up


def test_overlap_mesh_sharded_parity():
    """loop == cohort == mesh@4 under round_mode="overlap" (forced-device
    harness like tests/test_cohort_parity.py); C=5 on 4 devices exercises
    a padded cohort inside the pipeline."""
    if jax.device_count() >= 4:
        import _mesh_parity_prog
        _mesh_parity_prog.check_parity(5, 4, participation_fraction=0.5,
                                       staleness_decay=0.5,
                                       round_mode="overlap", rounds=3)
        return
    here = os.path.dirname(os.path.abspath(__file__))
    prog = os.path.join(here, "_mesh_parity_prog.py")
    src = os.path.abspath(os.path.join(here, "..", "src"))
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    res = subprocess.run(
        [sys.executable, prog, "--devices", "4", "--clients", "5",
         "--participation", "0.5", "--staleness-decay", "0.5",
         "--round-mode", "overlap", "--rounds", "3"],
        env=env, capture_output=True, text=True, timeout=480)
    assert res.returncode == 0, (
        f"overlap mesh parity subprocess failed:\n"
        f"{res.stdout}\n{res.stderr}")
    assert res.stdout.count("PARITY-OK") == 1, res.stdout


def test_run_round_single_call_accepts_overlap():
    """A single run_round call cannot overlap with anything: overlap mode
    must degenerate to the sync order, not crash."""
    cfg = _overlap_cfg(rounds=1)
    clients, server, x_test, y_test = simulator.build_experiment(
        cfg, "mnist_feat", n_train=800, n_test=300)
    engine = simulator.build_engine(clients, cfg)
    engine.learn_dres(jax.random.PRNGKey(cfg.seed))
    log = run_round(0, engine, server, get_method(cfg.method), cfg,
                    x_test, y_test)
    assert log.round == 0 and log.accs


# ------------------------------------------------------------- accounting

def test_phase_wall_clock_breakdown_recorded():
    cfg = _cfg(rounds=2, round_mode="sync")
    res = simulator.run(cfg, "mnist_feat", n_train=800, n_test=300)
    for log in res.rounds:
        assert set(log.phase_s) == set(round_phases(get_method(cfg.method)))
        assert all(v >= 0.0 for v in log.phase_s.values())
        assert log.wall_s == pytest.approx(sum(log.phase_s.values()))
        assert isinstance(log.sim_finish_s, float)
    # rounds retire in order on the simulated timeline
    finishes = [log.sim_finish_s for log in res.rounds]
    assert finishes == sorted(finishes) and finishes[0] > 0.0


def test_client_speeds_deterministic_and_bounded():
    a = client_speeds(8, seed=3, straggler_factor=4.0)
    b = client_speeds(8, seed=3, straggler_factor=4.0)
    np.testing.assert_array_equal(a, b)
    assert np.all((1.0 <= a) & (a <= 4.0))
    assert not np.array_equal(a, client_speeds(8, seed=4,
                                               straggler_factor=4.0))
    # per-client draws: client c keeps its speed when the fleet grows
    np.testing.assert_array_equal(a[:4], client_speeds(4, seed=3,
                                                       straggler_factor=4.0))
    np.testing.assert_array_equal(client_speeds(5, straggler_factor=1.0),
                                  np.ones(5))
    with pytest.raises(ValueError, match="straggler_factor"):
        client_speeds(4, straggler_factor=0.5)


def test_sim_timeline_overlap_beats_sync_within_acc_tolerance():
    """Fixed per-phase costs through the scheduler's own graphs: the
    overlap pipeline must retire the same rounds strictly earlier on the
    simulated straggler timeline than lockstep does — while landing
    within accuracy tolerance of the lockstep trajectory (overlap is a
    different protocol, not a broken one)."""
    costs = {"local_train": 1.0, "report": 0.1, "aggregate": 0.5,
             "distill": 1.0, "eval": 0.0}
    finish, final_acc = {}, {}
    for mode in ("sync", "overlap"):
        cfg = _overlap_cfg(rounds=4, round_mode=mode)
        sched = _build_scheduler(cfg, sim_phase_costs=costs)
        logs = sched.run_rounds(0, cfg.rounds)
        finish[mode] = logs[-1].sim_finish_s
        final_acc[mode] = logs[-1].mean_acc
    assert finish["overlap"] < finish["sync"], finish
    assert abs(final_acc["overlap"] - final_acc["sync"]) < 0.1, final_acc


def test_sim_timeline_primitives():
    tl = SimTimeline(np.array([1.0, 2.0]))
    # both clients start at 0; the 2x straggler gates the barrier
    assert tl.client_phase(None, 1.0) == pytest.approx(2.0)
    # server waits for its input, then runs serially
    assert tl.server_phase(0.5, ready_s=2.0) == pytest.approx(2.5)
    assert tl.server_phase(0.5, ready_s=0.0) == pytest.approx(3.0)
    # a busy lane defers the next phase for that client only: client 0's
    # lane is occupied until 1.0, so its next 1.0 s phase ends at 2.0
    end = tl.client_phase(np.array([True, False]), 1.0, ready_s=0.0)
    assert end == pytest.approx(2.0)
    # participants=[] completes at ready_s
    assert tl.client_phase(np.array([False, False]), 5.0,
                           ready_s=7.0) == pytest.approx(7.0)


# ------------------------------------------------------------- validation

def test_run_round_rejects_bad_participation_fraction():
    """Satellite: 0 and negative fractions must fail loudly at the
    run_round entry path (only > 1 was rejected before)."""
    cfg = _cfg(rounds=1)
    clients, server, x_test, y_test = simulator.build_experiment(
        cfg, "mnist_feat", n_train=400, n_test=200)
    method = get_method(cfg.method)
    for bad in (0.0, -0.25, 1.5):
        bad_cfg = _cfg(rounds=1, participation_fraction=bad)
        with pytest.raises(ValueError, match="participation_fraction"):
            run_round(0, clients, server, method, bad_cfg, x_test, y_test)


def test_round_mode_resolution_and_validation():
    assert resolve_round_mode("sync") == "sync"
    assert resolve_round_mode("overlap") == "overlap"
    env_backup = os.environ.pop("REPRO_ROUND_MODE", None)
    try:
        assert resolve_round_mode("auto") == "sync"
        os.environ["REPRO_ROUND_MODE"] = "overlap"
        assert resolve_round_mode("auto") == "overlap"
        # explicit modes beat the env var
        assert resolve_round_mode("sync") == "sync"
    finally:
        if env_backup is None:
            os.environ.pop("REPRO_ROUND_MODE", None)
        else:
            os.environ["REPRO_ROUND_MODE"] = env_backup
    with pytest.raises(ValueError, match="round_mode"):
        resolve_round_mode("eager")
    with pytest.raises(ValueError, match="round_mode"):
        validate_config(_cfg(round_mode="pipelined"))
    with pytest.raises(ValueError, match="max_inflight"):
        validate_config(_cfg(max_inflight=0))
    with pytest.raises(ValueError, match="straggler_factor"):
        validate_config(_cfg(straggler_factor=0.0))


def test_staleness_buffer_rejects_out_of_order_merge():
    from repro.fed.participation import StalenessBuffer
    buf = StalenessBuffer(2, 4, 2)
    idx = np.array([0, 1])
    logits = np.ones((2, 2, 2), np.float32)
    masks = np.ones((2, 2), bool)
    buf.merge(3, [True, False], idx, logits, masks, decay=0.5)
    buf.merge(3, [True, False], idx, logits, masks, decay=0.5)  # same: OK
    with pytest.raises(ValueError, match="round order"):
        buf.merge(2, [True, False], idx, logits, masks, decay=0.5)
