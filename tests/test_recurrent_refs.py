"""Recurrent-layer math vs sequential references.

The chunkwise mLSTM and the associative-scan RG-LRU are the performance
forms; these tests pin them to direct per-timestep recurrences.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import rglru as RG
from repro.models import ssm as S


def test_mlstm_chunkwise_equals_sequential_decode():
    """Running the chunkwise trainer over a sequence must equal stepping
    the decode recurrence token by token."""
    key = jax.random.PRNGKey(0)
    B, SEQ, D, N = 2, 20, 32, 2
    p = S.init_mlstm(key, D, N)
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, SEQ, D)) * 0.3

    out_chunk = S.mlstm_forward(p, x, N, chunk=8)

    state = S.mlstm_zero_state(B, N, 2 * D // N)
    outs = []
    for t in range(SEQ):
        y, state = S.mlstm_decode(p, x[:, t:t + 1], state, N)
        outs.append(y[:, 0])
    out_seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_chunk), np.asarray(out_seq),
                               rtol=2e-3, atol=2e-3)


def test_rglru_assoc_scan_equals_sequential():
    """Associative-scan RG-LRU == naive h_t = a_t h_{t-1} + b_t loop."""
    key = jax.random.PRNGKey(2)
    B, SEQ, D = 2, 16, 24
    p = RG.init_rglru(key, D)
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, SEQ, D)) * 0.5

    out_scan = RG.rglru_forward(p, x)

    state = RG.rglru_zero_state(B, D)
    outs = []
    for t in range(SEQ):
        y, state = RG.rglru_decode(p, x[:, t:t + 1], state)
        outs.append(y[:, 0])
    out_seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_scan), np.asarray(out_seq),
                               rtol=2e-3, atol=2e-3)


def test_rglru_decay_bounds():
    """Recurrence gate a_t ∈ (0, 1): state cannot blow up."""
    key = jax.random.PRNGKey(3)
    p = RG.init_rglru(key, 16)
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, 8, 16))
    xc = RG._conv1d(p, jnp.einsum("bsd,de->bse", x, p["wx"]))
    a, _ = RG._gates(p, xc)
    assert float(a.min()) > 0.0
    assert float(a.max()) < 1.0


def test_slstm_custom_vjp_long_sequence_stable():
    """Stabilised exponential gating: no NaN/inf over 200 steps."""
    key = jax.random.PRNGKey(4)
    B, SEQ, D, N = 1, 200, 16, 2
    p = S.init_slstm(key, D, N)
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, SEQ, D)) * 2.0
    out = S.slstm_forward(p, x, N)
    assert bool(jnp.all(jnp.isfinite(out)))
    g = jax.grad(lambda q: jnp.sum(S.slstm_forward(q, x, N) ** 2))(p)
    assert all(bool(jnp.all(jnp.isfinite(leaf)))
               for leaf in jax.tree.leaves(g))
