"""Server aggregation: masked mean, psum equivalence, class-wise means."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.aggregation import (classwise_mean_logits, masked_mean_logits,
                                    masked_mean_logits_psum)


def test_masked_mean_manual():
    logits = jnp.asarray([[[1.0, 3.0]], [[3.0, 5.0]], [[100.0, 100.0]]])
    mask = jnp.asarray([[True], [True], [False]])
    teacher, valid = masked_mean_logits(logits, mask)
    np.testing.assert_allclose(np.asarray(teacher), [[2.0, 4.0]])
    assert bool(valid[0])


def test_masked_mean_no_contributors():
    logits = jnp.ones((2, 3, 4))
    mask = jnp.zeros((2, 3), bool)
    teacher, valid = masked_mean_logits(logits, mask)
    np.testing.assert_allclose(np.asarray(teacher), 0.0)
    assert not bool(jnp.any(valid))


@settings(max_examples=20, deadline=None)
@given(c=st.integers(1, 6), t=st.integers(1, 10), k=st.integers(2, 8),
       seed=st.integers(0, 2**31 - 1))
def test_psum_equals_gather_form(c, t, k, seed):
    """The mesh-collective aggregation (DESIGN.md §3) must equal the
    hub-and-spoke form — vmap with an axis name stands in for the mesh."""
    key = jax.random.PRNGKey(seed)
    logits = jax.random.normal(key, (c, t, k))
    mask = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.6, (c, t))
    ref_teacher, ref_valid = masked_mean_logits(logits, mask)
    psum_fn = jax.vmap(lambda lg, m: masked_mean_logits_psum(lg, m, "clients"),
                       axis_name="clients")
    teacher, valid = psum_fn(logits, mask)
    # every rank receives the same teacher == the hub result
    np.testing.assert_allclose(np.asarray(teacher[0]), np.asarray(ref_teacher),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(valid[0]), np.asarray(ref_valid))


def test_classwise_means():
    logits = jnp.asarray([[1.0, 0.0], [3.0, 0.0], [0.0, 5.0]])
    labels = jnp.asarray([0, 0, 1])
    means, counts = classwise_mean_logits(logits, labels, 3)
    np.testing.assert_allclose(np.asarray(means[0]), [2.0, 0.0])
    np.testing.assert_allclose(np.asarray(means[1]), [0.0, 5.0])
    np.testing.assert_allclose(np.asarray(means[2]), [0.0, 0.0])
    np.testing.assert_allclose(np.asarray(counts), [2.0, 1.0, 0.0])


# --------------------------------------------------------------------------
# Robust reducers + sanitation (the defense-stack primitives)
# --------------------------------------------------------------------------

from repro.core.aggregation import (client_outlier_distance, krum_row_logits,
                                    median_logits, robust_reduce,
                                    scrub_nonfinite, trimmed_mean_logits)


def _attack_stack():
    """5 clients x 1 position x 2 classes: 4 honest near [1, 2], one
    masked-out row, one huge-magnitude attacker at index 0."""
    logits = jnp.asarray([[[1000.0, -1000.0]],
                          [[1.0, 2.0]],
                          [[1.2, 1.8]],
                          [[0.8, 2.2]],
                          [[55.0, 55.0]]])
    mask = jnp.asarray([[True], [True], [True], [True], [False]])
    return logits, mask


def test_trimmed_mean_drops_extremes_exactly():
    """n=4 valid, trim_frac=0.25 -> drop 1 low + 1 high per coordinate:
    class 0 keeps {1.0, 1.2}, class 1 keeps {2.0, 1.8}."""
    logits, mask = _attack_stack()
    teacher, valid = trimmed_mean_logits(logits, mask, trim_frac=0.25)
    np.testing.assert_allclose(np.asarray(teacher), [[1.1, 1.9]], atol=1e-6)
    assert bool(valid[0])


def test_median_exact_even_and_odd():
    logits, mask = _attack_stack()
    teacher, _ = median_logits(logits, mask)  # even n=4: mid-pair average
    np.testing.assert_allclose(np.asarray(teacher), [[1.1, 1.9]], atol=1e-6)
    odd = median_logits(logits, mask.at[4, 0].set(True))[0]  # n=5
    np.testing.assert_allclose(np.asarray(odd), [[1.2, 2.0]], atol=1e-6)


def test_median_ignores_nan_rows():
    """Non-finite rows are invalid regardless of the mask — the reducer's
    own finite-guard, independent of the server sanitize pass."""
    logits, mask = _attack_stack()
    poisoned = logits.at[2].set(jnp.nan)
    teacher, valid = median_logits(poisoned, mask)  # n=3: 0.8, 1.0, 1000
    np.testing.assert_allclose(np.asarray(teacher), [[1.0, 2.0]], atol=1e-6)
    assert bool(valid[0])


def test_krum_row_picks_corroborated_row():
    """Krum selects one *actual* client row, and never the attacker's: the
    honest cluster corroborates itself."""
    logits, mask = _attack_stack()
    teacher, valid = krum_row_logits(logits, mask)
    honest = np.asarray(logits)[1:4, 0]
    assert any(np.allclose(np.asarray(teacher)[0], h) for h in honest)
    assert bool(valid[0])


def test_robust_reduce_mean_is_masked_mean_bitwise():
    """mode="mean" must dispatch to the exact legacy path (bit-for-bit),
    not a rewritten mean — that is the default-compatibility anchor."""
    rng = np.random.default_rng(0)
    logits = rng.normal(size=(6, 9, 4)).astype(np.float32)
    mask = rng.random((6, 9)) < 0.7
    t_ref, v_ref = masked_mean_logits(logits, mask)
    t_got, v_got = robust_reduce(logits, mask, "mean")
    np.testing.assert_array_equal(np.asarray(t_got), np.asarray(t_ref))
    np.testing.assert_array_equal(np.asarray(v_got), np.asarray(v_ref))


def test_robust_reducers_match_mean_on_clean_unanimous_input():
    """With identical honest reports, every reducer returns the same
    teacher (sanity: robustness costs nothing in the no-attack limit)."""
    logits = jnp.broadcast_to(jnp.asarray([[1.0, 2.0, 3.0]]), (5, 1, 3))
    mask = jnp.ones((5, 1), bool)
    ref = np.asarray(masked_mean_logits(logits, mask)[0])
    for mode in ("trimmed_mean", "median", "krum_row"):
        got = np.asarray(robust_reduce(logits, mask, mode)[0])
        np.testing.assert_allclose(got, ref, atol=1e-6)


def test_guard_finite_off_reproduces_nan_poisoning():
    """guard_finite=False restores the legacy propagation: one NaN row
    poisons the fused position. This is the attack surface the watchdog
    exists for (Server passes guard_finite=sanitize)."""
    logits = jnp.asarray([[[1.0, 2.0]], [[jnp.nan, jnp.nan]]])
    mask = jnp.ones((2, 1), bool)
    guarded, _ = masked_mean_logits(logits, mask)  # default: guarded
    np.testing.assert_allclose(np.asarray(guarded), [[1.0, 2.0]])
    raw, _ = masked_mean_logits(logits, mask, guard_finite=False)
    assert not np.isfinite(np.asarray(raw)).any()


def test_scrub_nonfinite_counts_and_zero_copy():
    lo = np.ones((3, 4, 2), np.float32)
    mk = np.ones((3, 4), bool)
    same_lo, same_mk, scrubbed = scrub_nonfinite(lo, mk)
    assert same_lo is lo and same_mk is mk  # clean path: same objects
    np.testing.assert_array_equal(scrubbed, [0, 0, 0])

    lo2 = lo.copy()
    lo2[1, :2] = np.inf
    out_lo, out_mk, scrubbed = scrub_nonfinite(lo2, mk)
    np.testing.assert_array_equal(scrubbed, [0, 2, 0])
    assert not out_mk[1, :2].any() and out_mk[1, 2:].all()
    assert np.isfinite(out_lo).all()


def test_client_outlier_distance_scores_attackers():
    """Far-from-center clients score high, NaN senders score inf, and
    non-contributing clients are excluded from trust updates."""
    teacher = np.zeros((4, 3), np.float32)
    lo = np.zeros((4, 4, 3), np.float32)
    lo[1] += 10.0          # magnitude attacker
    lo[2, 0] = np.nan      # nan sender
    mk = np.ones((4, 4), bool)
    mk[3] = False          # sat out this round
    dist, contributing = client_outlier_distance(lo, mk, teacher)
    assert dist[0] == 0.0
    assert dist[1] == 100.0
    assert np.isinf(dist[2])
    assert dist[3] == 0.0 and not contributing[3]
    assert list(contributing[:3]) == [True, True, True]
