"""Server aggregation: masked mean, psum equivalence, class-wise means."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.aggregation import (classwise_mean_logits, masked_mean_logits,
                                    masked_mean_logits_psum)


def test_masked_mean_manual():
    logits = jnp.asarray([[[1.0, 3.0]], [[3.0, 5.0]], [[100.0, 100.0]]])
    mask = jnp.asarray([[True], [True], [False]])
    teacher, valid = masked_mean_logits(logits, mask)
    np.testing.assert_allclose(np.asarray(teacher), [[2.0, 4.0]])
    assert bool(valid[0])


def test_masked_mean_no_contributors():
    logits = jnp.ones((2, 3, 4))
    mask = jnp.zeros((2, 3), bool)
    teacher, valid = masked_mean_logits(logits, mask)
    np.testing.assert_allclose(np.asarray(teacher), 0.0)
    assert not bool(jnp.any(valid))


@settings(max_examples=20, deadline=None)
@given(c=st.integers(1, 6), t=st.integers(1, 10), k=st.integers(2, 8),
       seed=st.integers(0, 2**31 - 1))
def test_psum_equals_gather_form(c, t, k, seed):
    """The mesh-collective aggregation (DESIGN.md §3) must equal the
    hub-and-spoke form — vmap with an axis name stands in for the mesh."""
    key = jax.random.PRNGKey(seed)
    logits = jax.random.normal(key, (c, t, k))
    mask = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.6, (c, t))
    ref_teacher, ref_valid = masked_mean_logits(logits, mask)
    psum_fn = jax.vmap(lambda lg, m: masked_mean_logits_psum(lg, m, "clients"),
                       axis_name="clients")
    teacher, valid = psum_fn(logits, mask)
    # every rank receives the same teacher == the hub result
    np.testing.assert_allclose(np.asarray(teacher[0]), np.asarray(ref_teacher),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(valid[0]), np.asarray(ref_valid))


def test_classwise_means():
    logits = jnp.asarray([[1.0, 0.0], [3.0, 0.0], [0.0, 5.0]])
    labels = jnp.asarray([0, 0, 1])
    means, counts = classwise_mean_logits(logits, labels, 3)
    np.testing.assert_allclose(np.asarray(means[0]), [2.0, 0.0])
    np.testing.assert_allclose(np.asarray(means[1]), [0.0, 5.0])
    np.testing.assert_allclose(np.asarray(means[2]), [0.0, 0.0])
    np.testing.assert_allclose(np.asarray(counts), [2.0, 1.0, 0.0])
