"""End-to-end behaviour tests: the paper's claims at small scale.

These are the integration gates: Algorithm 1 runs, EdgeFD's filtering
produces the accuracy ordering of Table III, and the communication
accounting moves the right way.
"""
import numpy as np
import pytest

from repro.common.types import FedConfig
from repro.fed import simulator


def _run(method, scenario, rounds=4, **kw):
    # round_mode is pinned: these tests assert the *paper's* accuracy
    # orderings, which are claims about the lockstep Algorithm-1 protocol
    # — overlap mode trades a slightly different trajectory for round
    # throughput (its accuracy tolerance is gated by
    # benchmarks/async_rounds.py and tests/test_scheduler.py), so the
    # REPRO_ROUND_MODE=overlap CI entry must not move these thresholds.
    cfg = FedConfig(num_clients=5, rounds=rounds, method=method,
                    scenario=scenario, proxy_batch=200, lr=1e-2,
                    round_mode="sync", **kw)
    return simulator.run(cfg, "mnist_feat", n_train=1500, n_test=400)


@pytest.fixture(scope="module")
def strong_results():
    return {m: _run(m, "strong") for m in ("edgefd", "fedmd", "indlearn")}


def test_protocol_runs_and_improves(strong_results):
    res = strong_results["edgefd"]
    assert len(res.rounds) == 4
    assert res.final_acc > res.rounds[0].mean_acc * 0.9
    assert res.final_acc > 0.5


def test_edgefd_beats_unfiltered_strong_noniid(strong_results):
    """Table III, strong non-IID: client-side filtering must help."""
    assert strong_results["edgefd"].best_acc > \
        strong_results["fedmd"].best_acc - 0.02


def test_collaboration_beats_indlearn(strong_results):
    """IndLearn is capped by local label coverage (2/10 classes)."""
    assert strong_results["indlearn"].best_acc < 0.35
    assert strong_results["edgefd"].best_acc > \
        strong_results["indlearn"].best_acc + 0.3


def test_edgefd_filter_selective(strong_results):
    """Under strong non-IID the ID fraction must be well below 1 (the
    filter rejects other clients' classes) and above the own-share floor."""
    idf = strong_results["edgefd"].rounds[-1].id_fraction
    assert 0.1 < idf < 0.8


def test_iid_all_methods_comparable():
    e = _run("edgefd", "iid", rounds=3)
    f = _run("fedmd", "iid", rounds=3)
    assert abs(e.best_acc - f.best_acc) < 0.15


def test_data_free_method_runs():
    r = _run("fkd", "weak", rounds=3)
    assert r.final_acc > 0.3   # data-free FD learns something under weak


def test_selective_fd_baseline_runs():
    r = _run("selective-fd", "strong", rounds=3)
    assert r.final_acc > 0.4


def test_comm_accounting_monotone(strong_results):
    logs = strong_results["edgefd"].rounds
    ups = [log.bytes_up for log in logs]
    assert all(b > a for a, b in zip(ups, ups[1:]))
    # filtered upload must be smaller than unfiltered (same rounds/batch)
    assert strong_results["edgefd"].rounds[-1].bytes_up < \
        strong_results["fedmd"].rounds[-1].bytes_up
