"""kernels/flash_attention: pallas(interpret) ≡ jnp oracle, forward and
gradient, under jit(vmap); plus the dispatch wiring into
``models.layers.attention_forward`` (precedence + trace stability,
mirroring tests/test_kernel_dispatch.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import dispatch
from repro.kernels.flash_attention import ops, ref


def _qkv(key, b=2, n=4, nkv=4, s=48, h=16, dtype=jnp.float32):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, n, s, h), dtype)
    k = jax.random.normal(kk, (b, nkv, s, h), dtype)
    v = jax.random.normal(kv, (b, nkv, s, h), dtype)
    return q, k, v


def _expand(x, rep):
    return jnp.repeat(x, rep, axis=1) if rep > 1 else x


# ------------------------------------------------------------- forward

@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("nkv", [4, 2, 1])
def test_forward_matches_ref(causal, nkv):
    """Kernel ≡ oracle for full/causal attention and every GQA ratio,
    including a sequence length that is not a block multiple (padding +
    kv_len masking)."""
    q, k, v = _qkv(jax.random.PRNGKey(0), nkv=nkv, s=70)
    out = ops.attention(q, k, v, causal=causal, interpret=True)
    want = ref.attention(q, _expand(k, 4 // nkv), _expand(v, 4 // nkv),
                         causal=causal)
    assert out.shape == q.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_forward_under_jit_vmap():
    """An extra leading batch axis via jit(vmap) — the cohort engine's
    execution shape — must agree with per-slice calls."""
    q, k, v = _qkv(jax.random.PRNGKey(1), nkv=2, s=64)
    bq, bk, bv = (jnp.stack([t, t * 0.5]) for t in (q, k, v))
    out = jax.jit(jax.vmap(
        lambda a, b_, c: ops.attention(a, b_, c, causal=True,
                                       interpret=True)))(bq, bk, bv)
    for i, scale in enumerate((1.0, 0.5)):
        want = ops.attention(q * scale, k * scale, v * scale,
                             causal=True, interpret=True)
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(want),
                                   atol=2e-5)


# ------------------------------------------------------------- gradient

@pytest.mark.parametrize("nkv", [4, 2])
def test_gradient_matches_ref(nkv):
    """custom_vjp backward (oracle recompute) ≡ differentiating the oracle
    directly, for q, k and v — including the GQA grouped-kv cotangent
    sum."""
    rep = 4 // nkv
    q, k, v = _qkv(jax.random.PRNGKey(2), nkv=nkv, s=40)

    def loss_kernel(q_, k_, v_):
        return jnp.sum(ops.attention(q_, k_, v_, causal=True,
                                     interpret=True) ** 2)

    def loss_ref(q_, k_, v_):
        o = ref.attention(q_, _expand(k_, rep), _expand(v_, rep),
                          causal=True)
        return jnp.sum(o.astype(q_.dtype) ** 2)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for name, a, b in zip("qkv", gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4,
                                   err_msg=f"grad wrt {name}")


def test_gradient_under_jit_vmap():
    q, k, v = _qkv(jax.random.PRNGKey(3), nkv=2, s=32)
    bq, bk, bv = (jnp.stack([t, t + 0.1]) for t in (q, k, v))

    def loss(q_, k_, v_):
        return jnp.sum(ops.attention(q_, k_, v_, causal=True,
                                     interpret=True) ** 2)

    got = jax.jit(jax.vmap(jax.grad(loss)))(bq, bk, bv)
    for i in range(2):
        want = jax.grad(loss)(bq[i], bk[i], bv[i])
        np.testing.assert_allclose(np.asarray(got[i]), np.asarray(want),
                                   atol=1e-4)


# ------------------------------------------------------------- dispatch

def _layers_qkv(key, b=2, s=48, n=4, h=16):
    """(B, S, N, h) — the models.layers layout dispatch.flash_attention
    takes (kv already GQA-expanded)."""
    kq, kk, kv = jax.random.split(key, 3)
    return (jax.random.normal(kq, (b, s, n, h)),
            jax.random.normal(kk, (b, s, n, h)),
            jax.random.normal(kv, (b, s, n, h)))


def test_dispatch_backends_agree():
    q, k, v = _layers_qkv(jax.random.PRNGKey(4))
    base = dispatch.flash_attention(q, k, v, causal=True, backend="jnp")
    with dispatch.kernel_backend("pallas"):
        pal = dispatch.flash_attention(q, k, v, causal=True)
    assert base.shape == pal.shape == q.shape
    np.testing.assert_allclose(np.asarray(base), np.asarray(pal), atol=2e-5)


def test_dispatch_jnp_is_the_historical_sequence():
    """The jnp route must be op-for-op layers' make_mask + attention_scores
    (the default-backend bit-for-bit guarantee)."""
    from repro.models import layers as L
    q, k, v = _layers_qkv(jax.random.PRNGKey(5))
    got = dispatch.flash_attention(q, k, v, causal=True, window=0,
                                   backend="jnp")
    mask = L.make_mask(q.shape[1], k.shape[1], causal=True, window=0)
    want = L.attention_scores(q, k, v, mask)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_dispatch_window_always_takes_reference_path():
    """The kernel has no sliding-window support: window>0 must hit the
    reference sequence on EVERY backend."""
    from repro.models import layers as L
    q, k, v = _layers_qkv(jax.random.PRNGKey(6))
    mask = L.make_mask(q.shape[1], k.shape[1], causal=True, window=8)
    want = np.asarray(L.attention_scores(q, k, v, mask))
    for backend in ("jnp", "pallas"):
        got = dispatch.flash_attention(q, k, v, causal=True, window=8,
                                       backend=backend)
        np.testing.assert_array_equal(np.asarray(got), want)


def test_explicit_backend_beats_context():
    q, k, v = _layers_qkv(jax.random.PRNGKey(7))
    from repro.models import layers as L
    mask = L.make_mask(q.shape[1], k.shape[1], causal=True, window=0)
    want = np.asarray(L.attention_scores(q, k, v, mask))
    with dispatch.kernel_backend("pallas"):
        got = dispatch.flash_attention(q, k, v, causal=True, backend="jnp")
    np.testing.assert_array_equal(np.asarray(got), want)


def test_attention_forward_wiring_backend_parity():
    """models.layers.attention_forward (the transformer hot path) agrees
    across backends now that its non-chunked branch is dispatched."""
    from repro.configs import get_arch, reduced
    from repro.models import transformer as T
    cfg = reduced(get_arch("granite-8b"), layers=2, d_model=64, vocab=32)
    params = T.init_params(cfg, jax.random.PRNGKey(8))
    tokens = jax.random.randint(jax.random.PRNGKey(9), (2, 24), 0,
                                cfg.vocab_size)
    base, _ = T.forward(params, cfg, tokens)
    with dispatch.kernel_backend("pallas"):
        pal, _ = T.forward(params, cfg, tokens)
    np.testing.assert_allclose(np.asarray(base), np.asarray(pal), atol=2e-4)


def test_trace_stability_across_backend_flips():
    """Resolution bakes at trace time: a jitted forward compiled under one
    ambient backend must not retrace when the ambient flips."""
    traces = []

    @jax.jit
    def fwd(q, k, v):
        traces.append(q.shape)
        return dispatch.flash_attention(q, k, v, causal=True)

    q, k, v = _layers_qkv(jax.random.PRNGKey(10))
    fwd(q, k, v)
    first = len(traces)
    assert first == 1
    for ambient in ("pallas", "jnp", "auto"):
        with dispatch.kernel_backend(ambient):
            fwd(q, k, v)
    assert len(traces) == first, (
        f"ambient backend flip retraced flash_attention: {traces}")
