"""Data pipeline invariants: partitioners, proxy construction, token streams."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data.partition import partition
from repro.data.proxy import build_proxy
from repro.data.synthetic import make_dataset
from repro.data.tokens import MarkovTokenStream


def _toy(n=400, k=10, seed=0):
    rng = np.random.default_rng(seed)
    y = rng.integers(0, k, n)
    x = rng.standard_normal((n, 5)) + y[:, None]
    return x, y


def test_weak_partition_rejects_bad_labels_per_client():
    """labels_per_client outside [1, num_classes] used to surface as an
    opaque numpy error from rng.choice(replace=False); it must be a clear
    ValueError naming the parameter."""
    x, y = _toy()
    for bad in (11, 0, -2):
        with pytest.raises(ValueError, match="labels_per_client"):
            partition(x, y, num_clients=3, num_classes=10, scenario="weak",
                      labels_per_client=bad)
    # the boundary value is legal: every client holds every label
    parts = partition(x, y, num_clients=3, num_classes=10, scenario="weak",
                      labels_per_client=10)
    assert all(len(p.labels) == 10 for p in parts)


def test_strong_noniid_disjoint_labels():
    x, y = _toy()
    parts = partition(x, y, num_clients=5, num_classes=10, scenario="strong")
    seen = set()
    for p in parts:
        labels = set(np.unique(p.y))
        assert labels <= set(p.labels)
        assert not (labels & seen), "strong non-IID labels must not overlap"
        seen |= labels
    total = sum(len(p.y) for p in parts)
    assert total == len(y)


@settings(max_examples=15, deadline=None)
@given(nc=st.integers(2, 10), lpc=st.integers(1, 5), seed=st.integers(0, 1000))
def test_weak_noniid_label_budget(nc, lpc, seed):
    x, y = _toy(seed=seed)
    parts = partition(x, y, num_clients=nc, num_classes=10, scenario="weak",
                      labels_per_client=lpc, seed=seed)
    assert sum(len(p.y) for p in parts) == len(y)
    for p in parts:
        assert set(np.unique(p.y)) <= set(p.labels)


def test_iid_covers_all():
    x, y = _toy()
    parts = partition(x, y, num_clients=4, num_classes=10, scenario="iid")
    assert sum(len(p.y) for p in parts) == len(y)
    # every client should see most classes under IID
    for p in parts:
        assert len(np.unique(p.y)) >= 8


def test_proxy_provenance_and_fraction():
    x, y = _toy()
    parts = partition(x, y, num_clients=5, num_classes=10, scenario="strong")
    proxy = build_proxy(parts, alpha=0.2, seed=0)
    assert len(proxy.y) == len(proxy.owner) == len(proxy.x)
    for cid, p in enumerate(parts):
        take = (proxy.owner == cid).sum()
        assert abs(take - 0.2 * len(p.y)) <= 1
        # provenance: every proxy sample owned by cid exists in cid's data
        mine = proxy.x[proxy.owner == cid]
        for row in mine[:3]:
            assert (np.isclose(p.x, row).all(axis=1)).any()


def test_synthetic_dataset_separation_ordering():
    """mnist-like clusters are tighter than cifar-like (paper Fig 4)."""
    def score(name):
        ds = make_dataset(name, n_train=500, n_test=10)
        x = np.asarray(ds.x).reshape(500, -1)
        y = np.asarray(ds.y)
        mus = np.stack([x[y == c].mean(0) for c in range(10) if (y == c).any()])
        within = np.mean([np.linalg.norm(x[y == c] - x[y == c].mean(0), axis=1).mean()
                          for c in range(10) if (y == c).sum() > 1])
        between = np.linalg.norm(mus[:, None] - mus[None], axis=-1)
        between = between[between > 0].mean()
        return between / within
    assert score("mnist_feat") > score("cifar_feat")


def test_markov_stream_learnable():
    st_ = MarkovTokenStream(100, branching=4, seed=0)
    b = st_.batch(8, 50)
    assert b["tokens"].shape == (8, 50)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    # successors constrained: each token's next token is one of 4
    succ = st_.succ
    for r in range(8):
        for t in range(49):
            assert b["tokens"][r, t + 1] in succ[b["tokens"][r, t]]
