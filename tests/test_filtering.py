"""Two-stage client filter properties (Algorithm 1, CLIENTFILTER)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.dre import KMeansDRE
from repro.core.filtering import membership_mask, server_entropy_filter, two_stage_filter


def _fitted_dre(key, n=200, d=6):
    x = jax.random.normal(key, (n, d))
    return KMeansDRE(num_centroids=1).learn(jax.random.fold_in(key, 1), x), x


def test_stage1_membership_always_id():
    key = jax.random.PRNGKey(0)
    dre, private = _fitted_dre(key)
    # proxy: far-away OOD samples, but owned by this client
    proxy = jax.random.normal(jax.random.fold_in(key, 2), (50, 6)) + 100.0
    owner = jnp.zeros((50,), jnp.int32)
    fs = two_stage_filter(dre, proxy, owner, client_id=0)
    assert bool(jnp.all(fs.mask)), "own proxy samples must always be ID"
    assert bool(jnp.all(fs.stage1))
    assert not bool(jnp.any(fs.stage2))    # distance test would reject them


def test_mask_is_union_of_stages():
    key = jax.random.PRNGKey(1)
    dre, _ = _fitted_dre(key)
    proxy = jnp.concatenate([
        jax.random.normal(jax.random.fold_in(key, 2), (40, 6)),          # ID
        jax.random.normal(jax.random.fold_in(key, 3), (40, 6)) + 50.0,   # OOD
    ])
    owner = jnp.asarray([7] * 40 + [0] * 20 + [7] * 20, jnp.int32)
    fs = two_stage_filter(dre, proxy, owner, client_id=0)
    np.testing.assert_array_equal(np.asarray(fs.mask),
                                  np.asarray(fs.stage1 | fs.stage2))
    # the 20 OOD samples owned by client 0 survive through stage 1 only
    assert bool(jnp.all(fs.mask[40:60]))
    assert not bool(jnp.any(fs.mask[60:]))


@settings(max_examples=25, deadline=None)
@given(t=st.integers(1, 64), cid=st.integers(0, 9), seed=st.integers(0, 2**31 - 1))
def test_membership_exactness(t, cid, seed):
    rng = np.random.default_rng(seed)
    owner = rng.integers(0, 10, t).astype(np.int32)
    m = np.asarray(membership_mask(jnp.asarray(owner), cid))
    np.testing.assert_array_equal(m, owner == cid)


def test_server_entropy_filter_drops_uniform_logits():
    c, t, k = 3, 10, 10
    confident = jnp.zeros((c, t, k)).at[..., 0].set(10.0)
    uniform = jnp.zeros((c, t, k))
    mask = jnp.ones((c, t), bool)
    keep_conf = server_entropy_filter(confident, mask)
    keep_unif = server_entropy_filter(uniform, mask)
    assert bool(jnp.all(keep_conf))
    assert not bool(jnp.any(keep_unif))
