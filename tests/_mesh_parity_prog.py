"""Multi-device cohort parity checker (shared by test + subprocess modes).

``check_parity`` runs the same experiment through the loop engine, the
unsharded cohort engine, and the mesh-sharded cohort engine, and asserts the
round logs match within the acceptance tolerance (1e-5).

jax fixes the device count at first init, so a single-device pytest process
cannot build a 4-device mesh; ``tests/test_cohort_parity.py`` re-runs this
file as a subprocess with ``--xla_force_host_platform_device_count`` set
when too few devices are visible (and calls ``check_parity`` directly when
CI already forced a multi-device host — see .github/workflows/ci.yml).

    PYTHONPATH=src python tests/_mesh_parity_prog.py --devices 4 --clients 4 5
"""
from __future__ import annotations

TOL = dict(rtol=0.0, atol=1e-5)


def check_parity(num_clients: int, devices: int, method: str = "edgefd",
                 scenario: str = "strong",
                 participation_fraction: float = 1.0,
                 participation_policy: str = "uniform",
                 staleness_decay: float = 0.0,
                 round_mode: str = "auto",
                 max_inflight: int = 2, rounds: int = 2,
                 model_shards: int = 0, dataset: str = "mnist_feat",
                 n_train: int = 800, n_test: int = 300, **cfg_kw) -> None:
    import numpy as np

    from repro.common.types import FedConfig
    from repro.fed import simulator

    results = {}
    for name, engine, ndev, ms in (("loop", "loop", 0, 0),
                                   ("cohort", "cohort", 0, 0),
                                   ("mesh", "cohort", devices, model_shards)):
        cfg = FedConfig(num_clients=num_clients, rounds=rounds, method=method,
                        scenario=scenario, proxy_batch=120, batch_size=32,
                        lr=1e-2, seed=0, engine=engine, num_devices=ndev,
                        model_shards=ms,
                        participation_fraction=participation_fraction,
                        participation_policy=participation_policy,
                        staleness_decay=staleness_decay,
                        round_mode=round_mode, max_inflight=max_inflight,
                        **cfg_kw)
        results[name] = simulator.run(cfg, dataset,
                                      n_train=n_train, n_test=n_test)
    base = results["loop"]
    for name in ("cohort", "mesh"):
        other = results[name]
        assert len(base.rounds) == len(other.rounds)
        for rl, rc in zip(base.rounds, other.rounds):
            np.testing.assert_allclose(rl.accs, rc.accs, **TOL)
            np.testing.assert_allclose(rl.mean_acc, rc.mean_acc, **TOL)
            np.testing.assert_allclose(rl.local_loss, rc.local_loss, **TOL)
            np.testing.assert_allclose(rl.distill_loss, rc.distill_loss,
                                       **TOL)
            np.testing.assert_allclose(rl.id_fraction, rc.id_fraction, **TOL)
            np.testing.assert_allclose(rl.mean_staleness, rc.mean_staleness,
                                       **TOL)
            assert rl.participants == rc.participants
            assert rl.bytes_up == rc.bytes_up
            assert rl.bytes_down == rc.bytes_down


def main(argv=None) -> None:
    import argparse
    import os

    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--clients", type=int, nargs="+", default=[4, 5])
    ap.add_argument("--participation", type=float, default=1.0)
    ap.add_argument("--policy", default="uniform")
    ap.add_argument("--staleness-decay", type=float, default=0.0)
    ap.add_argument("--round-mode", default="auto")
    ap.add_argument("--max-inflight", type=int, default=2)
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--model-shards", type=int, default=0,
                    help="2-D mesh for the sharded entry: fold --devices "
                         "into a (devices // M, M) (clients, model) mesh")
    ap.add_argument("--dataset", default="mnist_feat")
    ap.add_argument("--fault-mode", default="none")
    ap.add_argument("--byzantine-frac", type=float, default=0.0)
    ap.add_argument("--fault-prob", type=float, default=0.0)
    ap.add_argument("--robust-aggregation", default="mean")
    args = ap.parse_args(argv)

    # must happen before the first jax import (device count is init-time)
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={args.devices}")

    import jax
    assert jax.device_count() >= args.devices, (
        f"forced {args.devices} host devices but jax sees "
        f"{jax.device_count()} — XLA_FLAGS arrived after jax init?")
    for c in args.clients:
        check_parity(c, args.devices,
                     model_shards=args.model_shards,
                     dataset=args.dataset,
                     participation_fraction=args.participation,
                     participation_policy=args.policy,
                     staleness_decay=args.staleness_decay,
                     round_mode=args.round_mode,
                     max_inflight=args.max_inflight, rounds=args.rounds,
                     fault_mode=args.fault_mode,
                     byzantine_frac=args.byzantine_frac,
                     fault_prob=args.fault_prob,
                     robust_aggregation=args.robust_aggregation)
        print(f"PARITY-OK clients={c} devices={args.devices} "
              f"model_shards={args.model_shards} dataset={args.dataset} "
              f"participation={args.participation} "
              f"round_mode={args.round_mode} "
              f"fault_mode={args.fault_mode}")


if __name__ == "__main__":
    main()
