"""Gaussian-mechanism proxy privatization (beyond-paper, §V-D)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.privacy import (clip_samples, gaussian_sigma, make_dp,
                                privatize_proxy, privatize_proxy_np)


def test_sigma_monotone_in_epsilon():
    assert gaussian_sigma(0.5, 1e-5, 1.0) > gaussian_sigma(2.0, 1e-5, 1.0)
    with pytest.raises(ValueError):
        gaussian_sigma(0.0, 1e-5, 1.0)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 32), d=st.integers(1, 16), c=st.floats(0.1, 5.0),
       seed=st.integers(0, 2**31 - 1))
def test_clip_bounds_norm(n, d, c, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, d)) * 10
    clipped = clip_samples(x, c)
    norms = jnp.linalg.norm(clipped.reshape(n, -1), axis=1)
    assert float(norms.max()) <= c + 1e-4


def test_privatize_noise_scale():
    dp = make_dp(epsilon=1.0, delta=1e-5, clip_norm=1.0)
    x = jnp.zeros((2000, 8))
    out = privatize_proxy(jax.random.PRNGKey(0), x, dp)
    emp = float(jnp.std(out))
    assert abs(emp - dp.sigma) / dp.sigma < 0.1


def test_np_and_jax_variants_match_distribution():
    dp = make_dp(epsilon=2.0)
    rng = np.random.default_rng(0)
    x = rng.standard_normal((500, 6)).astype(np.float32) * 3
    a = privatize_proxy_np(rng, x, dp)
    b = np.asarray(privatize_proxy(jax.random.PRNGKey(1), jnp.asarray(x), dp))
    assert abs(a.std() - b.std()) < 0.2


def test_privacy_accuracy_tradeoff():
    """More noise on the proxy -> DRE filtering degrades monotonically-ish."""
    from repro.core.dre import KMeansDRE
    key = jax.random.PRNGKey(5)
    private = jax.random.normal(key, (300, 8))
    ood = jax.random.normal(jax.random.fold_in(key, 1), (100, 8)) + 8.0
    dre = KMeansDRE(num_centroids=1).learn(jax.random.fold_in(key, 2), private)
    aucs = []
    for eps in (100.0, 1.0, 0.05):
        dp = make_dp(epsilon=eps, clip_norm=10.0)
        noisy_id = privatize_proxy(jax.random.fold_in(key, 3), private, dp)
        noisy_ood = privatize_proxy(jax.random.fold_in(key, 4), ood, dp)
        acc = (float(np.asarray(dre.is_id(noisy_id)).mean())
               + 1 - float(np.asarray(dre.is_id(noisy_ood)).mean())) / 2
        aucs.append(acc)
    assert aucs[0] > 0.8          # weak noise: filter still works
    assert aucs[0] >= aucs[-1]    # strong noise cannot be better
