"""Kill-and-resume checker (shared by test + subprocess modes).

``check_resume`` runs an experiment to completion capturing a
``RoundScheduler.snapshot()`` at every phase boundary of a middle round,
then for each boundary rebuilds the experiment from scratch (fresh-process
semantics), restores, drains, and asserts the completed round logs are
bit-for-bit identical to the uninterrupted run. ``check_cross_engine``
saves under one engine and restores under another (the engine checkpoint
format is keyed per client), asserting parity within the engine tolerance.

jax fixes the device count at first init, so the mesh-sharded cases re-run
this file as a subprocess with ``--xla_force_host_platform_device_count``
set when too few devices are visible (see tests/test_resume.py)::

    PYTHONPATH=src python tests/_resume_prog.py --devices 4 --engine cohort
"""
from __future__ import annotations

import dataclasses

# deterministic sim pricing so the timeline fields are comparable
FIXED_COSTS = {"local_train": 1.0, "report": 0.1, "aggregate": 0.3,
               "distill": 1.0, "eval": 0.0}
# host-measured wall-clock can never match across runs; everything else
# must be bit-for-bit
MEASURED_FIELDS = ("wall_s", "phase_s")


def _cfg(engine: str, devices: int, round_mode: str, **kw):
    from repro.common.types import FedConfig
    base = dict(num_clients=4, rounds=3, method="edgefd", scenario="strong",
                proxy_batch=64, batch_size=32, lr=1e-2, seed=0,
                engine=engine, num_devices=devices, round_mode=round_mode,
                max_inflight=2, participation_fraction=0.75,
                staleness_decay=0.5)
    base.update(kw)
    return FedConfig(**base)


def build_sched(cfg, dataset: str = "mnist_feat"):
    import jax

    from repro.core.methods import get_method
    from repro.fed.scheduler import RoundScheduler
    from repro.fed.simulator import build_engine, build_experiment
    clients, server, x_test, y_test = build_experiment(
        cfg, dataset, n_train=400, n_test=100, mlp_hidden=(16,))
    engine = build_engine(clients, cfg)
    method = get_method(cfg.method)
    if method.client_filter != "none":
        engine.learn_dres(jax.random.PRNGKey(cfg.seed))
    return RoundScheduler(engine, server, method, cfg, x_test, y_test,
                          sim_phase_costs=FIXED_COSTS)


def strip(logs):
    return [{k: v for k, v in dataclasses.asdict(lg).items()
             if k not in MEASURED_FIELDS} for lg in logs]


def check_resume(engine: str, devices: int, round_mode: str,
                 crash_round: int = 1, boundaries=None,
                 dataset: str = "mnist_feat", **cfg_kw) -> int:
    """Snapshot at every phase boundary of ``crash_round``; resume each."""
    cfg = _cfg(engine, devices, round_mode, **cfg_kw)
    ref_sched = build_sched(cfg, dataset)
    ref_sched.begin(0, cfg.rounds)
    snaps = []
    while ref_sched.has_pending():
        phase, r, _ = ref_sched.step()
        if r == crash_round and (boundaries is None or phase in boundaries):
            snaps.append(((phase, r), ref_sched.snapshot().to_tree()))
    ref = strip(ref_sched.logs)
    assert snaps, "crash round never executed"
    for (phase, r), tree in snaps:
        sched = build_sched(cfg, dataset)  # fresh-process semantics
        sched.restore(tree)
        sched.drain()
        got = strip(sched.logs)
        assert got == ref, (
            f"resume from boundary ({phase}, {r}) diverged "
            f"[engine={engine} devices={devices} mode={round_mode}]")
    return len(snaps)


def check_cross_engine(save_engine: str, save_devices: int,
                       load_engine: str, load_devices: int,
                       round_mode: str = "sync") -> None:
    """Save under one engine layout, restore under another.

    Engines agree within 1e-5 (the mesh-parity tolerance), not bitwise, so
    the restored run is compared to an uninterrupted run of the *loading*
    engine."""
    import numpy as np
    cfg_save = _cfg(save_engine, save_devices, round_mode)
    cfg_load = _cfg(load_engine, load_devices, round_mode)

    s1 = build_sched(cfg_save)
    s1.begin(0, cfg_save.rounds)
    tree = None
    while s1.has_pending():
        phase, r, _ = s1.step()
        if (phase, r) == ("eval", 0):  # a retired-round boundary
            tree = s1.snapshot().to_tree()

    s2 = build_sched(cfg_load)
    s2.restore(tree)
    s2.drain()

    s3 = build_sched(cfg_load)  # uninterrupted reference
    logs_ref = s3.run_rounds(0, cfg_load.rounds)
    assert len(s2.logs) == len(logs_ref)
    for got, ref in zip(s2.logs[1:], logs_ref[1:]):  # round 0 ran on saver
        np.testing.assert_allclose(got.accs, ref.accs, rtol=0.0, atol=1e-5)
        np.testing.assert_allclose(got.local_loss, ref.local_loss,
                                   rtol=0.0, atol=1e-5)
        np.testing.assert_allclose(got.distill_loss, ref.distill_loss,
                                   rtol=0.0, atol=1e-5)
        assert got.participants == ref.participants


def main(argv=None) -> None:
    import argparse
    import os

    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--engine", default="cohort")
    ap.add_argument("--round-mode", default="overlap")
    ap.add_argument("--model-shards", type=int, default=0,
                    help="2-D (clients, model) mesh: fold --devices into "
                         "a (devices // M, M) mesh for the sharded runs")
    ap.add_argument("--dataset", default="mnist_feat")
    ap.add_argument("--cross", action="store_true",
                    help="also check mesh<->loop cross-engine restore")
    args = ap.parse_args(argv)

    # must happen before the first jax import (device count is init-time)
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={args.devices}")

    import jax
    assert jax.device_count() >= args.devices, (
        f"forced {args.devices} host devices but jax sees "
        f"{jax.device_count()} — XLA_FLAGS arrived after jax init?")
    n = check_resume(args.engine, args.devices, args.round_mode,
                     model_shards=args.model_shards, dataset=args.dataset)
    print(f"RESUME-OK engine={args.engine} devices={args.devices} "
          f"model_shards={args.model_shards} dataset={args.dataset} "
          f"mode={args.round_mode} boundaries={n}")
    if args.cross:
        check_cross_engine("cohort", args.devices, "loop", 0)
        check_cross_engine("loop", 0, "cohort", args.devices)
        print(f"CROSS-OK mesh@{args.devices}<->loop")


if __name__ == "__main__":
    main()
