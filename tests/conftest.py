import importlib.util
import os
import pathlib
import sys

# tests run on CPU; the CI matrix additionally forces a multi-device host
# (XLA_FLAGS=--xla_force_host_platform_device_count=4) so the mesh-sharded
# cohort engine is exercised in-process — see test_cohort_parity.py
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Property tests prefer real hypothesis (requirements-dev.txt); fall back to
# the deterministic shim so `pytest -q` collects out of the box.
try:
    import hypothesis  # noqa: F401
except ImportError:
    _shim_path = pathlib.Path(__file__).parent / "_hypothesis_compat.py"
    _spec = importlib.util.spec_from_file_location("hypothesis", _shim_path)
    _shim = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_shim)
    sys.modules["hypothesis"] = _shim
    sys.modules["hypothesis.strategies"] = _shim.strategies

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
