import os

# tests run on the single real CPU device; only dryrun.py overrides this
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
