"""Per-architecture smoke tests + decode/prefill consistency.

Every assigned architecture instantiates its REDUCED same-family variant
(≤2 layers-worth of groups, d_model ≤ 512, ≤4 experts), runs one forward /
train step on CPU, and asserts output shapes + no NaNs. Decode-capable
families additionally verify that token-by-token decode with a cache
reproduces the full-sequence forward logits (the key cache-correctness
invariant).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_arch, reduced
from repro.models import transformer as T

ALL_ARCHS = sorted(ARCHS)


def _batch_for(cfg, key, b=2, s=16):
    if cfg.family == "audio":
        return {"frames": jax.random.normal(key, (b, s, cfg.frontend_stub_dim)),
                "labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["vision"] = jax.random.normal(
            jax.random.fold_in(key, 9), (b, cfg.num_vision_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_shapes_no_nan(arch):
    cfg = reduced(get_arch(arch))
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    b, s = 2, 16
    batch = _batch_for(cfg, key, b, s)
    if cfg.family == "audio":
        logits, aux = T.forward(params, cfg, frames=batch["frames"])
    elif cfg.family == "vlm":
        logits, aux = T.forward(params, cfg, batch["tokens"],
                                vision=batch["vision"])
    else:
        logits, aux = T.forward(params, cfg, batch["tokens"])
    assert logits.shape == (b, s, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch):
    cfg = reduced(get_arch(arch))
    key = jax.random.PRNGKey(1)
    params = T.init_params(cfg, key)
    batch = _batch_for(cfg, key)
    loss, grads = jax.value_and_grad(
        lambda p: T.train_loss(p, cfg, batch)[0])(params)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0.0


DECODE_ARCHS = [a for a in ALL_ARCHS if get_arch(a).family != "audio"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_forward(arch):
    """Token-by-token decode must reproduce full-sequence forward logits.

    MoE: exact equivalence requires no capacity dropping (Switch-style
    drops depend on batch composition), so the test raises the capacity
    factor to cover every token; production keeps 1.25.
    """
    import dataclasses
    from repro.common.types import MoEConfig
    cfg = reduced(get_arch(arch))
    if cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=MoEConfig(
            num_experts=cfg.moe.num_experts, top_k=cfg.moe.top_k,
            capacity_factor=float(cfg.moe.num_experts)))
    key = jax.random.PRNGKey(2)
    params = T.init_params(cfg, key)
    b, s = 2, 12
    batch = _batch_for(cfg, key, b, s)
    tokens = batch["tokens"]
    kw = {"vision": batch["vision"]} if cfg.family == "vlm" else {}
    ref_logits, _ = T.forward(params, cfg, tokens, **kw)

    cache = T.init_cache(cfg, b, s + 4, jnp.float32,
                         vision=batch.get("vision"), params=params)
    outs = []
    for t in range(s):
        lg, cache = T.decode_step(params, cfg, tokens[:, t:t + 1], cache,
                                  jnp.int32(t))
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits), np.asarray(ref_logits),
                               rtol=2e-2, atol=2e-2)


def test_sliding_window_matches_ref():
    """Dense arch with window_override: decode attends to ≤W last tokens."""
    import dataclasses
    cfg = reduced(get_arch("granite-8b"))
    key = jax.random.PRNGKey(3)
    params = T.init_params(cfg, key)
    b, s, w = 1, 20, 8
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    ref_logits, _ = T.forward(params, cfg, tokens, window_override=w)
    cache = T.init_cache(cfg, b, s, jnp.float32, window_override=w)
    assert cache["k"].shape[2] == w    # ring buffer is the window
    outs = []
    for t in range(s):
        lg, cache = T.decode_step(params, cfg, tokens[:, t:t + 1], cache,
                                  jnp.int32(t), window_override=w)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref_logits),
                               rtol=2e-2, atol=2e-2)


def test_moe_aux_loss_positive_and_balanced_at_uniform():
    cfg = reduced(get_arch("granite-moe-1b-a400m"))
    key = jax.random.PRNGKey(4)
    params = T.init_params(cfg, key)
    batch = _batch_for(cfg, key)
    _, aux = T.forward(params, cfg, batch["tokens"])
    # Switch aux loss is >= 1.0 at perfect balance (E * sum f*p = 1)
    assert float(aux) / cfg.num_layers >= 0.9


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "xlstm-350m", "recurrentgemma-2b"])
def test_two_step_loss_decreases(arch):
    """A few SGD steps on a fixed batch reduce the loss (trainability)."""
    cfg = reduced(get_arch(arch))
    key = jax.random.PRNGKey(5)
    params = T.init_params(cfg, key)
    batch = _batch_for(cfg, key, b=4, s=32)
    lr = 5e-2

    @jax.jit
    def step(p):
        loss, g = jax.value_and_grad(lambda q: T.train_loss(q, cfg, batch)[0])(p)
        return loss, jax.tree.map(lambda a, b: a - lr * b, p, g)

    l0, params = step(params)
    for _ in range(5):
        l1, params = step(params)
    assert float(l1) < float(l0)


def test_param_count_analytic_close_to_actual():
    """ArchConfig.param_count() (roofline input) tracks the real pytree."""
    for arch in ["qwen2.5-3b", "granite-8b"]:
        cfg = reduced(get_arch(arch))
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        actual = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
        est = cfg.param_count()
        assert abs(est - actual) / actual < 0.15, (arch, est, actual)
