"""The paper's heterogeneous client CNNs (Tables I & II) + image-mode FD."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.cnn import (CIFAR_CLIENTS, MNIST_CLIENTS, MLPClassifier,
                              get_client_model)


@pytest.mark.parametrize("idx", range(10))
def test_mnist_client_forward(idx):
    spec, hw, ch = get_client_model(idx, "mnist")
    params = spec.init(jax.random.PRNGKey(idx), hw, ch)
    x = jax.random.normal(jax.random.PRNGKey(100 + idx), (4, hw, hw, ch))
    logits = spec.apply(params, x)
    assert logits.shape == (4, 10)
    assert not bool(jnp.any(jnp.isnan(logits)))


@pytest.mark.parametrize("idx", range(10))
def test_cifar_client_forward(idx):
    spec, hw, ch = get_client_model(idx, "cifar10")
    params = spec.init(jax.random.PRNGKey(idx), hw, ch)
    x = jax.random.normal(jax.random.PRNGKey(200 + idx), (2, hw, hw, ch))
    logits = spec.apply(params, x, train=True)
    assert logits.shape == (2, 10)
    assert not bool(jnp.any(jnp.isnan(logits)))


def test_architectures_are_heterogeneous():
    """System heterogeneity (the FD selling point): param counts differ."""
    counts = []
    for idx in range(10):
        spec, hw, ch = get_client_model(idx, "mnist")
        params = spec.init(jax.random.PRNGKey(0), hw, ch)
        counts.append(sum(int(np.prod(leaf.shape))
                          for p in params for leaf in jax.tree.leaves(p)))
    assert len(set(counts)) >= 6, counts


def test_cnn_client_trains_on_images():
    """One CNN client learns a separable 2-class image problem."""
    from repro.core.distill import ce_loss
    from repro.optim.optimizers import apply_updates, sgd
    spec, hw, ch = get_client_model(0, "mnist")
    params = spec.init(jax.random.PRNGKey(0), hw, ch)
    key = jax.random.PRNGKey(1)
    y = jnp.asarray([0, 1] * 16)
    x = jax.random.normal(key, (32, hw, hw, ch)) * 0.1 \
        + y[:, None, None, None] * 1.0
    opt = sgd(5e-2)
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        loss, g = jax.value_and_grad(
            lambda p: ce_loss(spec.apply(p, x, True), y))(params)
        upd, state = opt.update(g, state, params)
        return apply_updates(params, upd), state, loss

    params, state, l0 = step(params, state)
    for _ in range(15):
        params, state, l1 = step(params, state)
    assert float(l1) < float(l0)


def test_image_mode_fd_simulation():
    """Full image-mode EdgeFD round with the paper's CNN clients."""
    from repro.common.types import FedConfig
    from repro.fed import simulator
    cfg = FedConfig(num_clients=3, rounds=1, method="edgefd",
                    scenario="strong", proxy_batch=60, lr=1e-2, batch_size=32)
    res = simulator.run(cfg, "mnist_like", n_train=360, n_test=120)
    assert len(res.rounds) == 1
    assert 0.0 < res.final_acc <= 1.0
    assert res.rounds[0].id_fraction < 1.0   # filter active in pixel space
