"""Distillation loss properties."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.distill import ce_loss, kd_kl_loss, kd_mse_loss


def test_kl_zero_iff_equal():
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (8, 10)) * 3
    assert abs(float(kd_kl_loss(logits, logits, 3.0))) < 1e-5


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 16), k=st.integers(2, 12), temp=st.floats(0.5, 8.0),
       seed=st.integers(0, 2**31 - 1))
def test_kl_nonnegative(n, k, temp, seed):
    key = jax.random.PRNGKey(seed)
    s = jax.random.normal(key, (n, k)) * 4
    t = jax.random.normal(jax.random.fold_in(key, 1), (n, k)) * 4
    assert float(kd_kl_loss(s, t, temp)) >= -1e-5


def test_kl_weight_masking():
    key = jax.random.PRNGKey(2)
    s = jax.random.normal(key, (4, 6))
    t = jax.random.normal(jax.random.fold_in(key, 1), (4, 6))
    w_first = jnp.asarray([1.0, 0.0, 0.0, 0.0])
    l_first = float(kd_kl_loss(s, t, 2.0, w_first))
    l_single = float(kd_kl_loss(s[:1], t[:1], 2.0))
    np.testing.assert_allclose(l_first, l_single, rtol=1e-5)
    # all-zero weights -> zero loss, no NaN
    assert float(kd_kl_loss(s, t, 2.0, jnp.zeros(4))) == 0.0


def test_kl_shift_invariance():
    """Logit shift invariance of softmax KL."""
    key = jax.random.PRNGKey(3)
    s = jax.random.normal(key, (5, 7))
    t = jax.random.normal(jax.random.fold_in(key, 1), (5, 7))
    l1 = float(kd_kl_loss(s, t, 3.0))
    l2 = float(kd_kl_loss(s + 100.0, t - 50.0, 3.0))
    np.testing.assert_allclose(l1, l2, rtol=1e-4)


def test_mse_and_ce_basic():
    s = jnp.asarray([[2.0, 0.0]])
    assert float(kd_mse_loss(s, s)) == 0.0
    labels = jnp.asarray([0])
    # CE decreases as the correct logit grows
    assert float(ce_loss(jnp.asarray([[5.0, 0.0]]), labels)) < \
        float(ce_loss(jnp.asarray([[1.0, 0.0]]), labels))


def test_pallas_kl_grad_matches_ref():
    """distill_kl kernel output is usable and matches the loss module."""
    from repro.kernels.distill_kl import ops, ref
    key = jax.random.PRNGKey(4)
    s = jax.random.normal(key, (37, 10)) * 2
    t = jax.random.normal(jax.random.fold_in(key, 1), (37, 10)) * 2
    per = np.asarray(ops.kd_kl_per_sample(s, t, 3.0))
    np.testing.assert_allclose(per.mean(), float(kd_kl_loss(s, t, 3.0)),
                               rtol=1e-5)
