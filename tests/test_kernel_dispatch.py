"""The kernel backend-dispatch layer (``repro.kernels.dispatch``).

Four contracts:

  * resolution — ``kernel_backend ∈ {auto, pallas, jnp}``: explicit beats
    context beats ``REPRO_KERNEL_BACKEND`` beats the platform rule, and
    interpret-mode Pallas is never an ``auto`` choice off-TPU;
  * parity — pallas(interpret) ≡ jnp for the fused Lloyd step, the
    ``distill_kl`` forward *and gradient* (custom-VJP backward kernel),
    and the KuLSIF gram matrices;
  * stability — backend selection is baked in at trace time: flipping the
    ambient backend never retraces a compiled round phase;
  * regression — same-seed end-to-end round logs: loop == cohort == mesh
    under ``kernel_backend="pallas"`` and ≈ the jnp backend; the default
    backend reproduces the pre-dispatch golden logs bit-for-bit
    (``tests/data/golden_rounds.json``, regenerate via
    ``tests/_golden_gen.py`` only for intentional numeric changes).
"""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.types import FedConfig
from repro.core.distill import kd_kl_loss
from repro.core.dre import KMeansDRE, KuLSIFDRE
from repro.core.kmeans import kmeans_fit, kmeans_fit_batched
from repro.fed import simulator
from repro.kernels import dispatch
from repro.kernels.kmeans_dist import ops as kd_ops

GOLDEN_PATH = pathlib.Path(__file__).parent / "data" / "golden_rounds.json"


# ------------------------------------------------------------------ resolve

def test_resolve_explicit_wins():
    assert dispatch.resolve("pallas") == "pallas"
    assert dispatch.resolve("jnp") == "jnp"


def test_resolve_auto_is_jnp_off_tpu(monkeypatch):
    monkeypatch.delenv(dispatch.ENV_VAR, raising=False)
    assert jax.default_backend() != "tpu"   # the CI/test platform
    assert dispatch.resolve("auto") == "jnp"
    assert dispatch.resolve(None) == "jnp"


def test_resolve_env_overrides_auto(monkeypatch):
    monkeypatch.setenv(dispatch.ENV_VAR, "pallas")
    assert dispatch.resolve("auto") == "pallas"
    assert dispatch.resolve(None) == "pallas"
    assert dispatch.resolve("jnp") == "jnp"       # explicit still wins


def test_context_manager_overrides_env(monkeypatch):
    monkeypatch.setenv(dispatch.ENV_VAR, "jnp")
    with dispatch.kernel_backend("pallas"):
        assert dispatch.resolve(None) == "pallas"
        assert dispatch.resolve("jnp") == "jnp"   # explicit still wins
        with dispatch.kernel_backend("jnp"):      # innermost context wins
            assert dispatch.resolve(None) == "jnp"
        assert dispatch.resolve(None) == "pallas"
    assert dispatch.resolve(None) == "jnp"


def test_unknown_backend_rejected(monkeypatch):
    with pytest.raises(ValueError, match="unknown kernel backend"):
        dispatch.resolve("mosaic")
    with pytest.raises(ValueError, match="unknown kernel backend"):
        with dispatch.kernel_backend("cuda"):
            pass
    monkeypatch.setenv(dispatch.ENV_VAR, "nope")
    with pytest.raises(ValueError, match="unknown kernel backend"):
        dispatch.resolve(None)


def test_simulator_rejects_bad_backend():
    cfg = FedConfig(num_clients=2, rounds=1, kernel_backend="fast")
    with pytest.raises(ValueError, match="unknown kernel backend"):
        simulator.run(cfg, "mnist_feat", n_train=200, n_test=50)


# ------------------------------------------------------- fused Lloyd parity

@pytest.mark.parametrize("n,d,k", [(64, 8, 1), (300, 17, 5), (257, 50, 10)])
def test_lloyd_step_pallas_matches_jnp(n, d, k):
    key = jax.random.PRNGKey(n + d + k)
    x = jax.random.normal(key, (n, d))
    cents = jax.random.normal(jax.random.fold_in(key, 1), (k, d)) * 2
    a_p, m_p, s_p, c_p = dispatch.lloyd_step(x, cents, backend="pallas")
    a_j, m_j, s_j, c_j = dispatch.lloyd_step(x, cents, backend="jnp")
    np.testing.assert_array_equal(np.asarray(a_p), np.asarray(a_j))
    np.testing.assert_allclose(np.asarray(m_p), np.asarray(m_j),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_p), np.asarray(s_j),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(c_p), np.asarray(c_j))


def test_lloyd_step_batched_matches_per_slice():
    key = jax.random.PRNGKey(0)
    xb = jax.random.normal(key, (3, 130, 9))
    cb = jax.random.normal(jax.random.fold_in(key, 1), (3, 4, 9))
    a_b, m_b, s_b, c_b = dispatch.lloyd_step(xb, cb, backend="pallas")
    for i in range(3):
        a1, m1, s1, c1 = kd_ops.lloyd_step(xb[i], cb[i])
        np.testing.assert_array_equal(np.asarray(a_b[i]), np.asarray(a1))
        np.testing.assert_allclose(np.asarray(s_b[i]), np.asarray(s1),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(c_b[i]), np.asarray(c1))


def test_lloyd_padding_excluded_from_sums():
    """ops.py pads n up to the block size; padded rows must not leak into
    the per-centroid sums/counts (the fit would drift toward zero)."""
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (70, 5)) + 10.0   # far from the pad zeros
    cents = jax.random.normal(jax.random.fold_in(key, 1), (2, 5)) + 10.0
    _, _, sums, counts = dispatch.lloyd_step(x, cents, backend="pallas")
    assert float(jnp.sum(counts)) == x.shape[0]
    np.testing.assert_allclose(np.asarray(jnp.sum(sums, axis=0)),
                               np.asarray(jnp.sum(x, axis=0)), rtol=1e-5)


@pytest.mark.parametrize("batched", [False, True])
def test_kmeans_fit_backend_parity(batched):
    key = jax.random.PRNGKey(7)
    if batched:
        keys = jax.random.split(key, 3)
        xs = jax.random.normal(jax.random.fold_in(key, 9), (3, 120, 6)) * 2
        r_j = kmeans_fit_batched(keys, xs, 3, 25, backend="jnp")
        r_p = kmeans_fit_batched(keys, xs, 3, 25, backend="pallas")
    else:
        x = jax.random.normal(jax.random.fold_in(key, 9), (150, 6)) * 2
        r_j = kmeans_fit(key, x, 3, 25, backend="jnp")
        r_p = kmeans_fit(key, x, 3, 25, backend="pallas")
    np.testing.assert_allclose(np.asarray(r_j.centroids),
                               np.asarray(r_p.centroids),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(r_j.assignments),
                                  np.asarray(r_p.assignments))
    np.testing.assert_allclose(np.asarray(r_j.inertia),
                               np.asarray(r_p.inertia), rtol=1e-3)
    np.testing.assert_array_equal(np.asarray(r_j.n_iter),
                                  np.asarray(r_p.n_iter))


# ------------------------------------------------- distill_kl fwd + gradient

def _kl_inputs(n=300, k=10, seed=0):
    key = jax.random.PRNGKey(seed)
    s = jax.random.normal(key, (n, k)) * 3
    t = jax.random.normal(jax.random.fold_in(key, 1), (n, k)) * 3
    w = (jax.random.uniform(jax.random.fold_in(key, 2), (n,)) > 0.3
         ).astype(jnp.float32)
    return s, t, w


@pytest.mark.parametrize("temp", [1.0, 3.0])
def test_distill_kl_forward_backend_parity(temp):
    s, t, w = _kl_inputs()
    l_j = kd_kl_loss(s, t, temp, w, backend="jnp")
    l_p = kd_kl_loss(s, t, temp, w, backend="pallas")
    np.testing.assert_allclose(float(l_j), float(l_p), rtol=1e-5)


@pytest.mark.parametrize("wrt", ["student", "teacher"])
def test_distill_kl_gradient_backend_parity(wrt):
    """No gradient test existed for the kernel before the custom-VJP: the
    fused Pallas backward must match jax.grad through the jnp loss."""
    s, t, w = _kl_inputs()

    def loss(backend):
        if wrt == "student":
            return lambda a: kd_kl_loss(a, t, 3.0, w, backend=backend)
        return lambda a: kd_kl_loss(s, a, 3.0, w, backend=backend)

    primal = s if wrt == "student" else t
    g_j = jax.grad(loss("jnp"))(primal)
    g_p = jax.grad(loss("pallas"))(primal)
    np.testing.assert_allclose(np.asarray(g_p), np.asarray(g_j),
                               rtol=1e-4, atol=1e-6)


def test_distill_kl_gradient_parity_under_vmap_jit():
    """The cohort engine differentiates the loss inside jit(vmap(...)) —
    the Pallas custom-VJP must batch through the kernel grid."""
    key = jax.random.PRNGKey(5)
    sb = jax.random.normal(key, (4, 64, 10))
    tb = jax.random.normal(jax.random.fold_in(key, 1), (4, 64, 10))

    def g(backend):
        return jax.jit(jax.vmap(lambda a, b: jax.grad(
            lambda aa: kd_kl_loss(aa, b, 3.0, backend=backend))(a)))(sb, tb)

    np.testing.assert_allclose(np.asarray(g("pallas")), np.asarray(g("jnp")),
                               rtol=1e-4, atol=1e-6)


# ------------------------------------------------------- KuLSIF gram parity

def test_rbf_matrix_backend_parity():
    key = jax.random.PRNGKey(11)
    a = jax.random.normal(key, (300, 12))
    b = jax.random.normal(jax.random.fold_in(key, 1), (170, 12))
    o_p = dispatch.rbf_matrix(a, b, 2.5, backend="pallas")
    o_j = dispatch.rbf_matrix(a, b, 2.5, backend="jnp")
    np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_j),
                               rtol=1e-5, atol=1e-6)


def test_kulsif_learn_estimate_backend_parity():
    key = jax.random.PRNGKey(13)
    x = jax.random.normal(key, (200, 12))
    tst = jax.random.normal(jax.random.fold_in(key, 1), (50, 12))
    d_j = KuLSIFDRE(sigma=3.0, num_aux=96, kernel_backend="jnp"
                    ).learn(jax.random.PRNGKey(2), x)
    d_p = KuLSIFDRE(sigma=3.0, num_aux=96, kernel_backend="pallas"
                    ).learn(jax.random.PRNGKey(2), x)
    np.testing.assert_allclose(np.asarray(d_p.alpha), np.asarray(d_j.alpha),
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(d_p.estimate(tst)),
                               np.asarray(d_j.estimate(tst)),
                               rtol=1e-4, atol=1e-6)


# ------------------------------------------- KMeansDRE threshold (satellite)

def test_kmeans_dre_calibrated_threshold_stays_on_device():
    """The calibrated T^ID must not round-trip through the host (it used
    to be float(jnp.quantile(...))); public semantics are preserved —
    comparisons, float() and re-learn all behave as before."""
    key = jax.random.PRNGKey(17)
    x = jax.random.normal(key, (240, 12))
    dre = KMeansDRE(num_centroids=2).learn(jax.random.PRNGKey(0), x)
    assert isinstance(dre.threshold, jax.Array)       # no host sync
    frac = float(np.asarray(dre.is_id(x)).mean())
    assert abs(frac - dre.calibration_q) < 0.05
    # float() still yields the calibrated quantile
    d = np.asarray(dre.distances(x))
    assert abs(float(dre.threshold) - float(np.quantile(d, 0.95))) < 1e-4
    # a fixed threshold is passed through untouched (python float stays)
    fixed = KMeansDRE(num_centroids=1, threshold=2.5).learn(
        jax.random.PRNGKey(0), x)
    assert fixed.threshold == 2.5


# ------------------------------------------------------- trace stability

def test_backend_selection_never_retraces_round_phases():
    """Backend resolution happens at trace time and is baked into the
    compiled phases: re-running rounds — even with the ambient backend
    flipped between them — must not retrace anything."""
    from repro.fed.client import Client
    from repro.fed.cohort import CohortEngine
    from repro.models.cnn import MLPClassifier
    from repro.optim.optimizers import sgd

    mlp = MLPClassifier(d_in=8, hidden=(16,), num_classes=4)
    traces = []

    def counting_apply(params, x, train):
        traces.append(tuple(x.shape))    # one entry per (re)trace
        return mlp.apply(params, x, train)

    rng = np.random.default_rng(0)
    opt = sgd(1e-2)
    key = jax.random.PRNGKey(0)
    clients = []
    for cid in range(4):
        key, sub = jax.random.split(key)
        clients.append(Client(
            cid, counting_apply, mlp.init(sub), opt,
            rng.normal(size=(64, 8)).astype(np.float32),
            rng.integers(0, 4, size=64), num_classes=4, arch_key="mlp",
            seed=0, kernel_backend="pallas"))
    engine = CohortEngine(clients)
    px = rng.normal(size=(32, 8)).astype(np.float32)
    teacher = rng.normal(size=(32, 4)).astype(np.float32)
    w = np.ones((32,), np.float32)
    engine.local_train_all(1, 32)
    engine.distill_all(px, teacher, w, 1, 32)
    first = len(traces)
    assert first > 0
    for ambient in ("jnp", "pallas", "auto"):
        with dispatch.kernel_backend(ambient):
            engine.local_train_all(1, 32)
            engine.distill_all(px, teacher, w, 1, 32)
    assert len(traces) == first, (
        f"flipping the ambient kernel backend retraced a phase: "
        f"{first} -> {len(traces)} traces ({traces})")


# ------------------------------------------------------ end-to-end parity

def _run_rounds(method, engine, backend, num_devices=0, clients=4,
                round_mode="auto", zoo="auto"):
    # round_mode="auto" lets the REPRO_ROUND_MODE=overlap CI matrix entry
    # exercise these parity cases through the overlap scheduler; the
    # golden test below pins "sync" (its logs certify the lockstep order)
    cfg = FedConfig(num_clients=clients, rounds=2, method=method,
                    scenario="strong", proxy_batch=128, batch_size=32,
                    seed=0, engine=engine, num_devices=num_devices,
                    kernel_backend=backend, round_mode=round_mode, zoo=zoo)
    return simulator.run(cfg, "mnist_feat", n_train=600, n_test=200).rounds


@pytest.mark.parametrize("method", ["edgefd", "selective-fd"])
def test_e2e_pallas_loop_cohort_mesh_match_jnp(method):
    """Same-seed round logs: loop == cohort == mesh-sharded cohort under
    kernel_backend="pallas" (interpret on CPU), all within tolerance of
    the jnp backend. num_devices=-1 uses every visible device, so the CI
    4-device matrix entry exercises real sharding here."""
    loop_p = _run_rounds(method, "loop", "pallas")
    cohort_p = _run_rounds(method, "cohort", "pallas")
    mesh_p = _run_rounds(method, "cohort", "pallas", num_devices=-1)
    loop_j = _run_rounds(method, "loop", "jnp")
    for lp, cp, mp, lj in zip(loop_p, cohort_p, mesh_p, loop_j):
        np.testing.assert_allclose(lp.accs, cp.accs, atol=1e-6)
        np.testing.assert_allclose(lp.accs, mp.accs, atol=1e-6)
        np.testing.assert_allclose(lp.distill_loss, cp.distill_loss,
                                   rtol=1e-4)
        np.testing.assert_allclose(lp.distill_loss, mp.distill_loss,
                                   rtol=1e-4)
        # pallas vs jnp: same algorithm, different accumulation order
        np.testing.assert_allclose(lp.accs, lj.accs, atol=0.02)
        np.testing.assert_allclose(lp.distill_loss, lj.distill_loss,
                                   rtol=0.05)
        np.testing.assert_allclose(lp.id_fraction, lj.id_fraction, atol=0.02)


def test_default_backend_round_logs_bit_for_bit_golden():
    """The default backend on CPU (auto -> jnp) must reproduce the round
    logs recorded before the dispatch layer existed, bit for bit. The cfg
    pins kernel_backend="jnp" so the test also holds under the
    REPRO_KERNEL_BACKEND=pallas CI matrix entry — on a clean CPU host
    that IS the default (see test_resolve_auto_is_jnp_off_tpu)."""
    golden = json.loads(GOLDEN_PATH.read_text())
    cases = [("edgefd_loop", "edgefd", "loop"),
             ("edgefd_cohort", "edgefd", "cohort"),
             ("selectivefd_loop", "selective-fd", "loop")]
    for name, method, engine in cases:
        # zoo pinned too: the goldens are shared-population logs and must
        # hold under the REPRO_ZOO=mixed CI matrix entry
        new = _run_rounds(method, engine, "jnp", round_mode="sync",
                          zoo="shared")
        assert len(new) == len(golden[name])
        for g, n in zip(golden[name], new):
            assert g["accs"] == n.accs, (name, n.round)
            assert g["mean_acc"] == n.mean_acc
            assert g["local_loss"] == n.local_loss
            assert g["distill_loss"] == n.distill_loss
            assert g["id_fraction"] == n.id_fraction
            assert g["bytes_up"] == n.bytes_up
            assert g["bytes_down"] == n.bytes_down
