"""Loop ↔ cohort engine parity: same seed ⇒ same round logs.

The cohort engine (``repro.fed.cohort``) is only admissible if it is a pure
execution-strategy change: stacked vmapped clients must reproduce the
per-client loop's round logs — per-client accuracies, losses, ID fractions
and byte accounting — within float tolerance (acceptance gate: 1e-5).

Scenarios cover the three partition regimes (strong/weak non-IID, IID — the
IID case has uniform per-client sizes and exercises the *vmapped* KMeans-DRE
learn path) and the method axes: filtered (edgefd), unfiltered ensemble
(fedmd), no collaboration (indlearn), data-free (fkd), and the KuLSIF-filter
baseline (selective-fd).
"""
import numpy as np
import pytest

from repro.common.types import FedConfig
from repro.fed import simulator
from repro.fed.cohort import CohortEngine

TOL = dict(rtol=0.0, atol=1e-5)


def _cfg(method, scenario, engine, **kw):
    base = dict(num_clients=5, rounds=2, method=method, scenario=scenario,
                proxy_batch=120, batch_size=32, lr=1e-2, seed=0, engine=engine)
    base.update(kw)
    return FedConfig(**base)


def _pair(method, scenario, **kw):
    res = {}
    for engine in ("loop", "cohort"):
        res[engine] = simulator.run(_cfg(method, scenario, engine, **kw),
                                    "mnist_feat", n_train=800, n_test=300)
    return res["loop"], res["cohort"]


def _assert_logs_match(loop, cohort):
    assert len(loop.rounds) == len(cohort.rounds)
    for rl, rc in zip(loop.rounds, cohort.rounds):
        np.testing.assert_allclose(rl.accs, rc.accs, **TOL)
        np.testing.assert_allclose(rl.mean_acc, rc.mean_acc, **TOL)
        np.testing.assert_allclose(rl.local_loss, rc.local_loss, **TOL)
        np.testing.assert_allclose(rl.distill_loss, rc.distill_loss, **TOL)
        np.testing.assert_allclose(rl.id_fraction, rc.id_fraction, **TOL)
        assert rl.bytes_up == rc.bytes_up
        assert rl.bytes_down == rc.bytes_down


@pytest.mark.parametrize("scenario", ["strong", "weak", "iid"])
def test_edgefd_parity_across_scenarios(scenario):
    _assert_logs_match(*_pair("edgefd", scenario))


@pytest.mark.parametrize("method", ["fedmd", "indlearn", "fkd"])
def test_method_parity_strong_noniid(method):
    _assert_logs_match(*_pair(method, "strong"))


def test_kulsif_filter_parity():
    """selective-fd: batched KuLSIF estimate (far-sentinel padding) must
    reproduce the per-client ratio filter."""
    _assert_logs_match(*_pair("selective-fd", "strong"))


def test_parity_with_ragged_client_sizes():
    """Weak non-IID with few labels per client yields very unequal private
    set sizes — the padded/masked step machinery is what's under test."""
    _assert_logs_match(*_pair("edgefd", "weak", labels_per_client=1))


def test_parity_short_proxy_batch():
    """Proxy batch smaller than the train batch: the single short-batch rule
    (fed/batching.py) must behave identically in both engines."""
    _assert_logs_match(*_pair("edgefd", "strong", proxy_batch=20,
                              batch_size=64))


def test_cohort_groups_homogeneous_clients():
    cfg = _cfg("edgefd", "strong", "cohort")
    clients, server, x_test, y_test = simulator.build_experiment(
        cfg, "mnist_feat", n_train=800, n_test=300)
    engine = CohortEngine(clients)
    # feature mode: all clients share the MLP arch -> exactly one cohort
    assert len(engine.cohorts) == 1
    assert engine.cohorts[0].positions == list(range(cfg.num_clients))


def test_cohort_sync_to_clients():
    cfg = _cfg("edgefd", "strong", "cohort", rounds=1)
    clients, server, x_test, y_test = simulator.build_experiment(
        cfg, "mnist_feat", n_train=800, n_test=300)
    before = [np.asarray(c.params[0]["w"]).copy() for c in clients]
    engine = simulator.build_engine(clients, cfg)
    from repro.core.protocol import run_experiment
    run_experiment(engine, server, cfg.method, cfg, x_test, y_test)
    engine.sync_to_clients()
    for c, b in zip(clients, before):
        assert not np.allclose(np.asarray(c.params[0]["w"]), b)
