"""Loop ↔ cohort engine parity: same seed ⇒ same round logs.

The cohort engine (``repro.fed.cohort``) is only admissible if it is a pure
execution-strategy change: stacked vmapped clients must reproduce the
per-client loop's round logs — per-client accuracies, losses, ID fractions
and byte accounting — within float tolerance (acceptance gate: 1e-5).

Scenarios cover the three partition regimes (strong/weak non-IID, IID — the
IID case has uniform per-client sizes and exercises the *vmapped* KMeans-DRE
learn path) and the method axes: filtered (edgefd), unfiltered ensemble
(fedmd), no collaboration (indlearn), data-free (fkd), and the KuLSIF-filter
baseline (selective-fd).
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.common.types import FedConfig
from repro.fed import simulator
from repro.fed.cohort import CohortEngine

TOL = dict(rtol=0.0, atol=1e-5)


def _cfg(method, scenario, engine, **kw):
    base = dict(num_clients=5, rounds=2, method=method, scenario=scenario,
                proxy_batch=120, batch_size=32, lr=1e-2, seed=0, engine=engine)
    base.update(kw)
    return FedConfig(**base)


def _pair(method, scenario, **kw):
    res = {}
    for engine in ("loop", "cohort"):
        res[engine] = simulator.run(_cfg(method, scenario, engine, **kw),
                                    "mnist_feat", n_train=800, n_test=300)
    return res["loop"], res["cohort"]


def _assert_logs_match(loop, cohort):
    assert len(loop.rounds) == len(cohort.rounds)
    for rl, rc in zip(loop.rounds, cohort.rounds):
        np.testing.assert_allclose(rl.accs, rc.accs, **TOL)
        np.testing.assert_allclose(rl.mean_acc, rc.mean_acc, **TOL)
        np.testing.assert_allclose(rl.local_loss, rc.local_loss, **TOL)
        np.testing.assert_allclose(rl.distill_loss, rc.distill_loss, **TOL)
        np.testing.assert_allclose(rl.id_fraction, rc.id_fraction, **TOL)
        assert rl.bytes_up == rc.bytes_up
        assert rl.bytes_down == rc.bytes_down


@pytest.mark.parametrize("scenario", ["strong", "weak", "iid"])
def test_edgefd_parity_across_scenarios(scenario):
    _assert_logs_match(*_pair("edgefd", scenario))


@pytest.mark.parametrize("method", ["fedmd", "indlearn", "fkd"])
def test_method_parity_strong_noniid(method):
    _assert_logs_match(*_pair(method, "strong"))


def test_kulsif_filter_parity():
    """selective-fd: batched KuLSIF estimate (far-sentinel padding) must
    reproduce the per-client ratio filter."""
    _assert_logs_match(*_pair("selective-fd", "strong"))


def test_parity_with_ragged_client_sizes():
    """Weak non-IID with few labels per client yields very unequal private
    set sizes — the padded/masked step machinery is what's under test."""
    _assert_logs_match(*_pair("edgefd", "weak", labels_per_client=1))


def test_parity_short_proxy_batch():
    """Proxy batch smaller than the train batch: the single short-batch rule
    (fed/batching.py) must behave identically in both engines."""
    _assert_logs_match(*_pair("edgefd", "strong", proxy_batch=20,
                              batch_size=64))


def test_cohort_groups_homogeneous_clients():
    # zoo pinned: this test certifies the single-cohort structure of the
    # shared population (the REPRO_ZOO=mixed CI entry builds three)
    cfg = _cfg("edgefd", "strong", "cohort", zoo="shared")
    clients, server, x_test, y_test = simulator.build_experiment(
        cfg, "mnist_feat", n_train=800, n_test=300)
    engine = CohortEngine(clients)
    # feature mode: all clients share the MLP arch -> exactly one cohort
    assert len(engine.cohorts) == 1
    assert engine.cohorts[0].positions == list(range(cfg.num_clients))


def test_mesh_sharded_parity_forced_devices():
    """Same-seed parity for the mesh-sharded cohort engine on 4 forced host
    devices: C=4 (divisible) and C=5 (exercises client-axis padding with
    validity-gated dummy clients). jax fixes the device count at first init,
    so on single-device hosts the check re-runs in a subprocess that forces
    XLA_FLAGS=--xla_force_host_platform_device_count=4 before importing jax;
    the multi-device CI job runs it in-process."""
    if jax.device_count() >= 4:
        import _mesh_parity_prog
        for c in (4, 5):
            _mesh_parity_prog.check_parity(c, 4)
        return
    here = os.path.dirname(os.path.abspath(__file__))
    prog = os.path.join(here, "_mesh_parity_prog.py")
    src = os.path.abspath(os.path.join(here, "..", "src"))
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    # < CI's per-test --timeout=600 (pytest-timeout), so a wedged child is
    # reported by this assert instead of a blunt test kill
    res = subprocess.run(
        [sys.executable, prog, "--devices", "4", "--clients", "4", "5"],
        env=env, capture_output=True, text=True, timeout=480)
    assert res.returncode == 0, (
        f"mesh parity subprocess failed:\n{res.stdout}\n{res.stderr}")
    assert res.stdout.count("PARITY-OK") == 2, res.stdout


def test_run_round_honors_cfg_engine(monkeypatch):
    """Regression: run_round built its engine with as_engine(clients) —
    dropping cfg.engine — so a raw client list under engine='cohort'
    silently ran the slow loop engine."""
    import repro.fed.cohort as cohort_mod
    from repro.core import protocol
    from repro.core.methods import get_method

    cfg = _cfg("fedmd", "strong", "cohort", rounds=1)
    clients, server, x_test, y_test = simulator.build_experiment(
        cfg, "mnist_feat", n_train=800, n_test=300)
    created = []

    class SpyEngine(CohortEngine):
        def __init__(self, cs, **kw):
            created.append(len(cs))
            super().__init__(cs, **kw)

    monkeypatch.setattr(cohort_mod, "CohortEngine", SpyEngine)
    protocol.run_round(0, clients, server, get_method(cfg.method), cfg,
                       x_test, y_test)
    assert created == [cfg.num_clients], (
        "run_round must build the engine cfg.engine selects when handed a "
        "raw client list")


def test_run_round_raw_list_trains_across_rounds():
    """A per-call cohort engine is transient: unless run_round syncs its
    stacked params back onto the Client objects — and unless a fresh engine
    adopts the clients' already-learned DRE filters — successive raw-list
    calls restart from the initial weights (or silently stop filtering)
    every round. Multi-round raw-list logs must match the loop engine's
    exactly for the filtered method."""
    from repro.core import protocol
    from repro.core.methods import get_method

    logs = {}
    for engine in ("loop", "cohort"):
        cfg = _cfg("edgefd", "strong", engine, rounds=3)
        clients, server, x_test, y_test = simulator.build_experiment(
            cfg, "mnist_feat", n_train=800, n_test=300)
        method = get_method(cfg.method)
        key = jax.random.PRNGKey(cfg.seed)
        for i, c in enumerate(clients):   # what run_experiment's init does
            c.learn_dre(jax.random.fold_in(key, i))
        logs[engine] = [protocol.run_round(r, clients, server, method, cfg,
                                           x_test, y_test)
                        for r in range(cfg.rounds)]
    for rl, rc in zip(logs["loop"], logs["cohort"]):
        np.testing.assert_allclose(rl.accs, rc.accs, **TOL)
        np.testing.assert_allclose(rl.local_loss, rc.local_loss, **TOL)
        np.testing.assert_allclose(rl.distill_loss, rc.distill_loss, **TOL)
        np.testing.assert_allclose(rl.id_fraction, rc.id_fraction, **TOL)
    assert logs["cohort"][-1].mean_acc > logs["cohort"][0].mean_acc, (
        "accuracy must improve across raw-list rounds (state persisted)")


def test_evaluate_pads_tail_batch_single_compile():
    """Regression: _Cohort.evaluate sliced x_test into a ragged final batch,
    silently recompiling the eval fn for every distinct tail shape. With
    the padded+masked tail, the model traces exactly once per test-set
    shape — and the accuracies still match the per-client reference."""
    from repro.fed.client import Client
    from repro.models.cnn import MLPClassifier
    from repro.optim.optimizers import sgd

    mlp = MLPClassifier(d_in=8, hidden=(16,), num_classes=4)
    traces = []

    def counting_apply(params, x, train):
        traces.append(tuple(x.shape))    # one entry per (re)trace
        return mlp.apply(params, x, train)

    rng = np.random.default_rng(0)
    opt = sgd(1e-2)
    key = jax.random.PRNGKey(0)
    clients = []
    for cid in range(3):
        key, sub = jax.random.split(key)
        clients.append(Client(
            cid, counting_apply, mlp.init(sub), opt,
            rng.normal(size=(40, 8)).astype(np.float32),
            rng.integers(0, 4, size=40), num_classes=4, arch_key="mlp",
            seed=0))
    engine = CohortEngine(clients)
    # 700 % 512 != 0: the old path compiled (512, 8) AND the (188, 8) tail
    x_test = rng.normal(size=(700, 8)).astype(np.float32)
    y_test = np.asarray(rng.integers(0, 4, size=700))
    accs = engine.evaluate_all(x_test, y_test)
    assert len(traces) == 1, (
        f"eval traced {len(traces)} times for one test-set shape "
        f"(shapes: {traces}); the tail batch must be padded, not ragged")
    engine.evaluate_all(x_test, y_test)
    assert len(traces) == 1, "second eval of the same shape must hit the cache"
    ref = [c.evaluate(x_test, y_test) for c in clients]
    np.testing.assert_allclose(accs, ref, **TOL)


def test_transient_engine_adopts_custom_dre_via_loop_fallback():
    """A cohort built from clients carrying an unknown (non-KMeans/KuLSIF)
    estimator must take the per-client mask fallback — not silently stop
    filtering with all-True masks — matching the loop engine exactly."""
    import dataclasses as dc

    from repro.fed.client import Client
    from repro.models.cnn import MLPClassifier
    from repro.optim.optimizers import sgd

    @dc.dataclass
    class NormDRE:                         # distances + threshold interface
        threshold: float = 2.0

        def distances(self, t):
            import jax.numpy as jnp
            return jnp.linalg.norm(t, axis=1)

    mlp = MLPClassifier(d_in=6, hidden=(8,), num_classes=3)
    rng = np.random.default_rng(0)
    opt = sgd(1e-2)
    key = jax.random.PRNGKey(0)
    clients = []
    for cid in range(2):
        key, sub = jax.random.split(key)
        clients.append(Client(
            cid, mlp.apply, mlp.init(sub), opt,
            rng.normal(size=(20, 6)).astype(np.float32),
            rng.integers(0, 3, size=20), dre=NormDRE(),
            num_classes=3, arch_key="mlp", seed=0))
    px = np.concatenate([np.zeros((5, 6), np.float32),          # ID (d=0)
                         np.full((5, 6), 9.0, np.float32)])     # OOD (d>>thr)
    powner = np.full((10,), -1, np.int32)   # no sample owned by either client
    engine = CohortEngine(clients)
    _, masks = engine.proxy_logits_and_masks(px, powner)
    ref = np.stack([np.asarray(c.filter_mask(px, powner).mask)
                    for c in clients])
    np.testing.assert_array_equal(masks, ref)
    assert not masks.all(), "OOD proxy samples must be filtered out"
    assert masks[:, :5].all(), "ID proxy samples must be kept"


def test_mixed_dre_cohort_matches_loop():
    """A cohort where only some members carry a (learned) DRE must use the
    per-client mask fallback — not return all-True for everyone because
    member 0 happens to be filterless."""
    from repro.core.dre import KMeansDRE
    from repro.fed.client import Client
    from repro.models.cnn import MLPClassifier
    from repro.optim.optimizers import sgd

    mlp = MLPClassifier(d_in=6, hidden=(8,), num_classes=3)
    rng = np.random.default_rng(0)
    opt = sgd(1e-2)
    key = jax.random.PRNGKey(0)
    clients = []
    for cid in range(2):
        key, sub = jax.random.split(key)
        x = rng.normal(size=(20, 6)).astype(np.float32) * 0.1
        dre = None
        if cid == 1:
            import jax.numpy as jnp
            dre = KMeansDRE(num_centroids=1, threshold=2.0).learn(
                jax.random.fold_in(key, cid), jnp.asarray(x))
        clients.append(Client(cid, mlp.apply, mlp.init(sub), opt, x,
                              rng.integers(0, 3, size=20), dre=dre,
                              num_classes=3, arch_key="mlp", seed=0))
    px = np.concatenate([np.zeros((5, 6), np.float32),          # ID
                         np.full((5, 6), 9.0, np.float32)])     # OOD
    powner = np.full((10,), -1, np.int32)
    engine = CohortEngine(clients)
    _, masks = engine.proxy_logits_and_masks(px, powner)
    ref = np.stack([np.asarray(c.filter_mask(px, powner).mask)
                    for c in clients])
    np.testing.assert_array_equal(masks, ref)
    assert masks[0].all(), "filterless member keeps every proxy sample"
    assert not masks[1, 5:].any(), "filtered member drops OOD samples"


def test_transient_engine_unlearned_dre_fails_like_loop():
    """Filter masks requested from a cohort whose clients carry *unlearned*
    DREs must fail exactly like the loop engine (KMeansDRE.distances
    asserts 'call learn() first'), not silently return all-True masks."""
    cfg = _cfg("edgefd", "strong", "cohort", rounds=1)
    clients, server, x_test, y_test = simulator.build_experiment(
        cfg, "mnist_feat", n_train=800, n_test=300)
    engine = CohortEngine(clients)      # learn_dres deliberately not called
    px = np.asarray(server.proxy.x[:10])
    powner = np.asarray(server.proxy.owner[:10])
    with pytest.raises(AssertionError, match="learn"):
        engine.proxy_logits_and_masks(px, powner)


def test_nonuniform_calibration_q_matches_loop():
    """The vmapped KMeans-DRE fit bakes one (calibration_q, max_iter) into
    the whole batch; members differing in either must take the per-client
    path and calibrate exactly like the loop engine."""
    from repro.core.dre import KMeansDRE
    from repro.core.protocol import LoopEngine
    from repro.fed.client import Client
    from repro.models.cnn import MLPClassifier
    from repro.optim.optimizers import sgd

    mlp = MLPClassifier(d_in=6, hidden=(8,), num_classes=3)
    opt = sgd(1e-2)

    def make_clients():
        rng = np.random.default_rng(0)
        key = jax.random.PRNGKey(0)
        out = []
        for cid, q in enumerate((0.5, 0.99)):
            key, sub = jax.random.split(key)
            out.append(Client(
                cid, mlp.apply, mlp.init(sub), opt,
                rng.normal(size=(20, 6)).astype(np.float32),
                rng.integers(0, 3, size=20),
                dre=KMeansDRE(num_centroids=1, threshold=None,
                              calibration_q=q),
                num_classes=3, arch_key="mlp", seed=0))
        return out

    key = jax.random.PRNGKey(7)
    loop_clients, cohort_clients = make_clients(), make_clients()
    LoopEngine(loop_clients).learn_dres(key)
    CohortEngine(cohort_clients).learn_dres(key)
    for cl, cc in zip(loop_clients, cohort_clients):
        np.testing.assert_allclose(cc.dre.threshold, cl.dre.threshold, **TOL)
    assert loop_clients[0].dre.threshold < loop_clients[1].dre.threshold, (
        "distinct calibration quantiles must yield distinct thresholds")


def test_run_experiment_raw_list_syncs_cohort_state():
    """run_experiment over a raw client list with engine='cohort' builds an
    internal engine; its trained params must land back on the Client
    objects before it is discarded (the loop engine mutates in place, so
    raw-list callers rightly expect trained clients either way)."""
    from repro.core.protocol import run_experiment

    cfg = _cfg("edgefd", "strong", "cohort", rounds=1)
    clients, server, x_test, y_test = simulator.build_experiment(
        cfg, "mnist_feat", n_train=800, n_test=300)
    before = [np.asarray(c.params[0]["w"]).copy() for c in clients]
    run_experiment(clients, server, cfg.method, cfg, x_test, y_test)
    for c, b in zip(clients, before):
        assert not np.allclose(np.asarray(c.params[0]["w"]), b), (
            "client params must reflect the training run_experiment did")


def test_cohort_sync_to_clients():
    cfg = _cfg("edgefd", "strong", "cohort", rounds=1)
    clients, server, x_test, y_test = simulator.build_experiment(
        cfg, "mnist_feat", n_train=800, n_test=300)
    before = [np.asarray(c.params[0]["w"]).copy() for c in clients]
    engine = simulator.build_engine(clients, cfg)
    from repro.core.protocol import run_experiment
    run_experiment(engine, server, cfg.method, cfg, x_test, y_test)
    engine.sync_to_clients()
    for c, b in zip(clients, before):
        assert not np.allclose(np.asarray(c.params[0]["w"]), b)
