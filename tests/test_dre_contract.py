"""The DRE contract (paper §III/§V-B) pinned down as properties.

Both estimators expose learn/estimate/is_id. This module asserts the parts
the round protocol silently relies on:

  * threshold calibration — KMeansDRE's quantile calibration keeps ≈ q of
    the private data ID, for any q and centroid count;
  * monotonicity — is_id decisions are monotone in the underlying statistic
    (distance for KMeans, ratio for KuLSIF): loosening the threshold can
    only grow the ID set, and estimate() ordering matches is_id ordering;
  * vmapped ≡ looped — ``kmeans_fit_batched`` (the cohort engine's one-call
    filter fit) matches per-client ``kmeans_fit`` for identical keys.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dre import KMeansDRE, KuLSIFDRE
from repro.core.kmeans import kmeans_fit, kmeans_fit_batched, min_dist_to_centroids


@pytest.fixture(scope="module")
def blobs():
    key = jax.random.PRNGKey(7)
    k1, k2 = jax.random.split(key)
    private = jax.random.normal(k1, (240, 12))
    test = jnp.concatenate([jax.random.normal(k2, (120, 12)),
                            jax.random.normal(k2, (120, 12)) + 6.0])
    return private, test


# ---------------------------------------------------------------- calibration

@pytest.mark.parametrize("q", [0.8, 0.9, 0.99])
@pytest.mark.parametrize("k", [1, 3])
def test_kmeans_threshold_calibration_tracks_quantile(blobs, q, k):
    private, _ = blobs
    dre = KMeansDRE(num_centroids=k, calibration_q=q)
    dre = dre.learn(jax.random.PRNGKey(0), private)
    frac = float(np.asarray(dre.is_id(private)).mean())
    assert abs(frac - q) < 0.05, (frac, q)


def test_kmeans_fixed_threshold_respected(blobs):
    private, test = blobs
    dre = KMeansDRE(num_centroids=1, threshold=2.5)
    dre = dre.learn(jax.random.PRNGKey(0), private)
    assert dre.threshold == 2.5
    d = np.asarray(dre.distances(test))
    np.testing.assert_array_equal(np.asarray(dre.is_id(test)), d <= 2.5)


# --------------------------------------------------------------- monotonicity

def test_kmeans_is_id_monotone_in_threshold(blobs):
    private, test = blobs
    dre = KMeansDRE(num_centroids=2).learn(jax.random.PRNGKey(1), private)
    masks = []
    for thr in (0.5, 2.0, 8.0, 32.0):
        masks.append(np.asarray(
            dataclasses.replace(dre, threshold=thr).is_id(test)))
    for tight, loose in zip(masks, masks[1:]):
        assert np.all(loose[tight])           # looser threshold ⊇ tighter
    assert masks[-1].sum() > masks[0].sum()


def test_kmeans_estimate_orders_like_distance(blobs):
    private, test = blobs
    dre = KMeansDRE(num_centroids=2).learn(jax.random.PRNGKey(1), private)
    d = np.asarray(dre.distances(test))
    est = np.asarray(dre.estimate(test))
    np.testing.assert_allclose(est, -d, rtol=1e-6)
    # every ID sample's estimate >= every OOD sample's estimate boundary
    mask = np.asarray(dre.is_id(test))
    assert mask.any() and (~mask).any()
    assert est[mask].min() >= est[~mask].max() - 1e-6


def test_kulsif_is_id_monotone_in_threshold(blobs):
    private, test = blobs
    dre = KuLSIFDRE(sigma=3.0, lam=0.1, num_aux=96)
    dre = dre.learn(jax.random.PRNGKey(2), private)
    counts = []
    for thr in (-1e9, 0.0, 0.5, 1e9):
        counts.append(int(np.asarray(
            dataclasses.replace(dre, threshold=thr).is_id(test)).sum()))
    assert counts[0] == len(test) and counts[-1] == 0
    assert all(a >= b for a, b in zip(counts, counts[1:]))


def test_kulsif_ratio_higher_on_id(blobs):
    private, test = blobs
    dre = KuLSIFDRE(sigma=3.0, lam=0.1, num_aux=96)
    dre = dre.learn(jax.random.PRNGKey(2), private)
    r = np.asarray(dre.estimate(test))
    assert r[:120].mean() > r[120:].mean()    # first half is in-distribution


# --------------------------------------------------------- vmapped vs looped

def test_kmeans_fit_batched_matches_loop():
    key = jax.random.PRNGKey(3)
    C, n, d, k = 4, 96, 6, 3
    keys = jax.random.split(key, C)
    xs = jax.random.normal(jax.random.fold_in(key, 99), (C, n, d)) * 2.0
    batched = kmeans_fit_batched(keys, xs, k, 25)
    for i in range(C):
        single = kmeans_fit(keys[i], xs[i], k, 25)
        np.testing.assert_allclose(np.asarray(batched.centroids[i]),
                                   np.asarray(single.centroids),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(batched.assignments[i]),
                                      np.asarray(single.assignments))
        np.testing.assert_allclose(float(batched.inertia[i]),
                                   float(single.inertia), rtol=1e-4)


def test_vmapped_min_dist_matches_loop():
    key = jax.random.PRNGKey(4)
    xs = jax.random.normal(key, (3, 50, 5))
    cents = jax.random.normal(jax.random.fold_in(key, 1), (3, 2, 5))
    batched = jax.vmap(min_dist_to_centroids)(xs, cents)
    for i in range(3):
        np.testing.assert_allclose(np.asarray(batched[i]),
                                   np.asarray(min_dist_to_centroids(xs[i], cents[i])),
                                   rtol=1e-5, atol=1e-6)
