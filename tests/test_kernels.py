"""Per-kernel allclose sweeps: shapes × dtypes vs the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.distill_kl import ops as kl_ops, ref as kl_ref
from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.kernels.kmeans_dist import ops as kd_ops, ref as kd_ref
from repro.kernels.kulsif_rbf import ops as rbf_ops, ref as rbf_ref


@pytest.mark.parametrize("t,d,c", [(64, 8, 1), (300, 50, 7), (1000, 784, 10),
                                   (257, 17, 3)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kmeans_dist_sweep(t, d, c, dtype):
    key = jax.random.PRNGKey(t + d + c)
    x = jax.random.normal(key, (t, d)).astype(dtype)
    cent = (jax.random.normal(jax.random.fold_in(key, 1), (c, d)) * 2).astype(dtype)
    thr = float(np.sqrt(d))
    d1, m1 = kd_ops.min_dist_and_mask(x, cent, thr)
    d2, m2 = kd_ref.min_dist_and_mask(x.astype(jnp.float32),
                                      cent.astype(jnp.float32), thr)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=tol, atol=tol)
    if dtype == jnp.float32:
        np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))


@pytest.mark.parametrize("n,m,d", [(64, 64, 4), (300, 170, 40), (513, 100, 8)])
@pytest.mark.parametrize("sigma", [0.5, 2.5])
def test_kulsif_rbf_sweep(n, m, d, sigma):
    key = jax.random.PRNGKey(n + m)
    a = jax.random.normal(key, (n, d))
    b = jax.random.normal(jax.random.fold_in(key, 1), (m, d))
    o1 = rbf_ops.rbf_matrix(a, b, sigma)
    o2 = rbf_ref.rbf_matrix(a, b, sigma)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("n,k", [(32, 10), (700, 10), (513, 151)])
@pytest.mark.parametrize("temp", [1.0, 3.0])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_distill_kl_sweep(n, k, temp, dtype):
    key = jax.random.PRNGKey(n + k)
    s = (jax.random.normal(key, (n, k)) * 3).astype(dtype)
    t = (jax.random.normal(jax.random.fold_in(key, 1), (n, k)) * 3).astype(dtype)
    o1 = kl_ops.kd_kl_per_sample(s, t, temp)
    o2 = kl_ref.kd_kl_per_sample(s.astype(jnp.float32),
                                 t.astype(jnp.float32), temp)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("b,n,nkv,s,h", [
    (1, 2, 2, 128, 32), (2, 4, 2, 300, 64), (1, 8, 1, 130, 16),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(b, n, nkv, s, h, causal):
    key = jax.random.PRNGKey(b * 100 + s)
    q = jax.random.normal(key, (b, n, s, h))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, nkv, s, h))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, nkv, s, h))
    o1 = fa_ops.attention(q, k, v, causal=causal, block_q=64, block_k=64)
    rep = n // nkv
    o2 = fa_ref.attention(q, jnp.repeat(k, rep, 1), jnp.repeat(v, rep, 1),
                          causal=causal)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    key = jax.random.PRNGKey(9)
    q = jax.random.normal(key, (1, 2, 128, 32)).astype(jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 128, 32)).astype(jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 128, 32)).astype(jnp.bfloat16)
    o1 = fa_ops.attention(q, k, v, block_q=64, block_k=64)
    o2 = fa_ref.attention(q.astype(jnp.float32), k.astype(jnp.float32),
                          v.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(o1, dtype=np.float32), np.asarray(o2),
                               rtol=5e-2, atol=5e-2)


def test_kmeans_kernel_equals_core_api():
    """kernel path and repro.core.kmeans agree (framework integration)."""
    from repro.core.kmeans import min_dist_to_centroids
    key = jax.random.PRNGKey(11)
    x = jax.random.normal(key, (200, 30))
    c = jax.random.normal(jax.random.fold_in(key, 1), (5, 30))
    d_core = min_dist_to_centroids(x, c)
    d_kern, _ = kd_ops.min_dist_and_mask(x, c, 1.0)
    np.testing.assert_allclose(np.asarray(d_core), np.asarray(d_kern),
                               rtol=1e-4, atol=1e-4)
