"""Parameter-pytree helpers: initialization and arithmetic.

The framework uses plain nested-dict pytrees for parameters (no flax/haiku).
Modules are (init_fn, apply_fn) pairs; these helpers keep initializer code
uniform and dtype-correct.
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


def init_dense(key, d_in: int, d_out: int, dtype=jnp.float32, bias: bool = False,
               scale: float | None = None):
    """Lecun-normal dense init; returns {'w': (d_in, d_out)[, 'b': (d_out,)]}."""
    std = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out)) * std).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def init_embedding(key, vocab: int, d_model: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d_model)) * 0.02).astype(dtype)


def init_conv(key, c_in: int, c_out: int, k: int, dtype=jnp.float32):
    """He-normal conv init; returns {'w': (k,k,c_in,c_out), 'b': (c_out,)}."""
    fan_in = c_in * k * k
    std = math.sqrt(2.0 / fan_in)
    return {
        "w": (jax.random.normal(key, (k, k, c_in, c_out)) * std).astype(dtype),
        "b": jnp.zeros((c_out,), dtype),
    }


def param_count(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def param_bytes(tree) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def split_keys(key, names: Sequence[str]):
    keys = jax.random.split(key, len(names))
    return dict(zip(names, keys))
