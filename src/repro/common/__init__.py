from repro.common.pytree import (
    init_conv,
    init_dense,
    init_embedding,
    param_bytes,
    param_count,
    tree_add,
    tree_scale,
    tree_zeros_like,
)
from repro.common.types import ArchConfig, AttentionKind, InputShape, MoEConfig
