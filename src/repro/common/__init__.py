from repro.common.pytree import (
    init_dense,
    init_embedding,
    init_conv,
    param_count,
    param_bytes,
    tree_zeros_like,
    tree_add,
    tree_scale,
)
from repro.common.types import ArchConfig, InputShape, MoEConfig, AttentionKind
