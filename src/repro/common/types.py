"""Typed configuration objects shared across the framework.

``ArchConfig`` is the single source of truth for an architecture: the model
zoo builds parameter pytrees from it, ``launch/dryrun.py`` derives input
specs and shardings from it, and the roofline analysis reads its analytic
parameter/FLOP counts.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Tuple


class AttentionKind(str, enum.Enum):
    FULL = "full"                  # causal full attention
    SLIDING = "sliding"            # sliding-window causal attention
    LOCAL_HYBRID = "local_hybrid"  # RG-LRU blocks interleaved w/ local attn
    RECURRENT = "recurrent"        # attention-free (xLSTM)
    ENCODER = "encoder"            # bidirectional, encoder-only (audio)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    # capacity factor used for fixed-shape expert dispatch (TPU-friendly)
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | vlm | audio | ssm | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default d_model // num_heads
    moe: Optional[MoEConfig] = None
    attention: AttentionKind = AttentionKind.FULL
    qkv_bias: bool = False                  # qwen2.5 style
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    # vlm: every `cross_attn_every` layers one cross-attention layer is
    # inserted (llama-3.2-vision style); the vision tokens come in as a
    # stubbed precomputed embedding input.
    cross_attn_every: int = 0
    num_vision_tokens: int = 0
    # hybrid (recurrentgemma): pattern period, e.g. 3 => (rglru, rglru, attn)
    hybrid_period: int = 0
    local_window: int = 2048                # local/sliding attn window
    # ssm (xlstm): ratio of mLSTM blocks (rest sLSTM)
    slstm_every: int = 0
    # audio: encoder-only, frontend stubbed; inputs are frame embeddings
    frontend_stub_dim: int = 0
    dtype: str = "bfloat16"
    # citation for the config (source paper / model card)
    source: str = ""
    tie_embeddings: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    @property
    def is_decoder(self) -> bool:
        return self.attention != AttentionKind.ENCODER

    def param_count(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS = 6·N·D roofline term)."""
        d, h = self.d_model, self.resolved_head_dim
        nq, nkv = self.num_heads, self.num_kv_heads
        attn = d * (nq * h) + 2 * d * (nkv * h) + (nq * h) * d
        if self.qkv_bias:
            attn += (nq + 2 * nkv) * h
        if self.moe is not None:
            ffn = self.moe.num_experts * 3 * d * self.d_ff + d * self.moe.num_experts
        elif self.d_ff > 0:
            ffn = 3 * d * self.d_ff  # gate/up/down (SwiGLU)
        else:
            ffn = 0
        if self.attention == AttentionKind.RECURRENT:
            # xLSTM block ~ 4 gate projections + cell params, approx 8*d*d
            attn = 8 * d * d
            ffn = 0 if self.d_ff == 0 else ffn
        per_layer = attn + ffn + 2 * d  # two RMSNorm scales
        if self.cross_attn_every:
            n_cross = self.num_layers // self.cross_attn_every
            per_cross = 2 * d * (nq * h) + 2 * d * (nkv * h) + 2 * d
            cross = n_cross * per_cross
        else:
            cross = 0
        emb = self.vocab_size * d
        head = 0 if self.tie_embeddings else self.vocab_size * d
        return self.num_layers * per_layer + cross + emb + head + d

    def active_param_count(self) -> int:
        """Active params per token (MoE uses top_k of num_experts)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        ffn_all = self.num_layers * self.moe.num_experts * 3 * self.d_model * self.d_ff
        ffn_act = self.num_layers * self.moe.top_k * 3 * self.d_model * self.d_ff
        return full - ffn_all + ffn_act


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str    # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.mode == "decode"


# ----------------------------------------------------------------------------
# Federated (paper-scale) configs
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FedConfig:
    """Configuration of a federated-distillation experiment (Algorithm 1)."""
    num_clients: int = 10
    rounds: int = 20
    local_epochs: int = 1
    distill_epochs: int = 1
    # server-side student epochs per ensemble-distillation round
    # (method="server_distill" only); 0 = same as distill_epochs. FedDF
    # typically runs the central student many more steps than client KD.
    server_distill_epochs: int = 0
    proxy_fraction: float = 0.2      # alpha — fraction of private data shared
    proxy_batch: int = 256           # |I_r| proxy indices per round
    id_threshold: Optional[float] = None  # T^ID; None = per-client calibration
    temperature: float = 3.0         # distillation temperature
    distill_weight: float = 1.0      # lambda on the KL term
    scenario: str = "strong"         # strong | weak | iid
    labels_per_client: int = 3       # weak non-IID overlap degree
    method: str = "edgefd"
    lr: float = 1e-2
    batch_size: int = 64
    feature_extractor: bool = False  # CIFAR10*-style pre-extracted features
    seed: int = 0
    # execution engine: "loop" drives clients one by one (heterogeneous-safe);
    # "cohort" stacks homogeneous-architecture clients and vmaps every round
    # phase (repro.fed.cohort) — same round logs, far fewer dispatches.
    engine: str = "loop"
    # device mesh over the cohort client axis (engine="cohort" only):
    # 0 = unsharded (default), -1 = all visible jax devices, N > 0 = a 1-D
    # mesh over exactly N devices (repro.fed.mesh). CPU hosts emulate N
    # devices with XLA_FLAGS=--xla_force_host_platform_device_count=N.
    num_devices: int = 0
    mesh_axis: str = "clients"
    # model shards per client (engine="cohort" with num_devices != 0 only):
    # m > 0 folds the SAME num_devices devices into a 2-D (clients, model)
    # mesh of shape (num_devices // m, m) — each stacked client's weight
    # matrices additionally split over the "model" axis (repro.fed.mesh),
    # so cohort members bigger than one device can be federated. 0 = the
    # 1-D client mesh bit-for-bit; $REPRO_MODEL_SHARDS fills in for 0
    # (best-effort, clamped to a divisor of num_devices — the CI vehicle).
    model_shards: int = 0
    # partial participation (repro.fed.participation): each round a subset of
    # round(participation_fraction * num_clients) clients trains/reports;
    # 1.0 = every client (the paper's setting, bit-for-bit the legacy logs).
    participation_fraction: float = 1.0
    # how the per-round subset is drawn, seeded from (seed, round):
    # "uniform" = without replacement, "weighted" = P ∝ private-set size,
    # "roundrobin" = deterministic rotating block.
    participation_policy: str = "uniform"
    # staleness model: non-participants keep their last-reported proxy logits
    # and the server down-weights them by staleness_decay ** age (age =
    # rounds since the client last reported). 0.0 drops non-participants
    # silently; 1.0 reuses stale knowledge at full weight (FedBuff-style).
    staleness_decay: float = 0.0
    # round scheduling (repro.fed.scheduler): "sync" replays the lockstep
    # Algorithm-1 phase order (bit-for-bit the legacy round logs);
    # "overlap" admits up to max_inflight rounds concurrently — round r+1
    # trains/reports while round r aggregates/distills, with stale
    # knowledge draining through the staleness buffer. "auto" = sync
    # unless the REPRO_ROUND_MODE env var says otherwise (a CI vehicle,
    # like REPRO_KERNEL_BACKEND; explicit sync/overlap always win).
    round_mode: str = "auto"
    # overlap only: how many rounds may be in flight at once (1 = lockstep;
    # round r's local_train admits once round r - max_inflight retired)
    max_inflight: int = 2
    # simulated straggler clock (repro.fed.clock): per-client slowdown
    # multipliers drawn deterministically from (seed, client) in
    # [1, straggler_factor]; 1.0 = homogeneous fleet. Pure accounting — it
    # never changes numerics, only RoundLog.sim_finish_s (the axis on
    # which overlap beats sync, see benchmarks/async_rounds.py).
    straggler_factor: float = 4.0
    # client-axis wave streaming (engine="cohort" only): the cohort engine
    # host-stages every stacked (C, ...) pytree and runs each compiled
    # phase wave_size clients at a time, freeing device buffers between
    # waves — peak device memory is bounded by the wave, not by C. 0 (the
    # default) keeps the whole client axis device-resident in one wave,
    # bit-for-bit the historical path. Composes with num_devices (each
    # wave is padded to a mesh multiple and sharded).
    wave_size: int = 0
    # two-tier hierarchical server (repro.fed.server): E edge aggregators
    # each own a contiguous client shard, apply the server-side filter and
    # staleness bookkeeping locally (per-shard lazily materialized
    # StalenessBuffer) and hand the root E partial sums to fuse — root
    # work and in-flight report footprint scale with E, not C. 1 (the
    # default) is the flat single-tier server, bit-for-bit the legacy
    # aggregation and byte accounting.
    num_edge_aggregators: int = 1
    # trace-driven arrival processes (repro.fed.clock): how clients arrive
    # at each round on the simulated timeline. "static" = everyone ready
    # at the phase start (legacy); "poisson" = iid exponential delays with
    # mean arrival_spread seconds; "bursty" = clients cluster into
    # arrival_bursts spikes spread over arrival_spread seconds (a client's
    # burst is stable in (seed, client) — think timezone waves). All draws
    # are deterministic in (seed, round, client). Pure timeline accounting.
    arrival_process: str = "static"
    arrival_spread: float = 0.0
    arrival_bursts: int = 4
    # per-round churn: each client is offline for the whole round with
    # probability churn_prob (deterministic in (seed, round, client));
    # offline clients are removed from the participant set and drain
    # through the staleness machinery like sampled-out clients. 0 = never.
    churn_prob: float = 0.0
    # mid-round dropout: a participating client trains but drops before
    # reporting with probability dropout_prob — its fresh report never
    # reaches the server, so its row rides the staleness buffer. 0 = never.
    dropout_prob: float = 0.0
    # admission/backpressure: how many client reports the server will hold
    # in flight (summed over pending, un-aggregated rounds) before it stops
    # admitting. Reports arrive in simulated-arrival order (straggler lane
    # finish, ties by client id); overflow clients are demoted to
    # non-participants for the round and drain through the staleness
    # machinery like dropouts. 0 (default) = unbounded, bit-for-bit the
    # legacy ingestion.
    max_pending_reports: int = 0
    # kernel backend for the round hot paths (repro.kernels.dispatch):
    # "auto" = Pallas kernels on TPU, jnp reference elsewhere (also honors
    # the REPRO_KERNEL_BACKEND env var / kernel_backend() context manager);
    # "pallas" forces the kernels (interpret mode off-TPU — a test/CI
    # vehicle, not a fast path); "jnp" forces the reference code, which on
    # CPU is bit-for-bit the pre-dispatch behavior.
    kernel_backend: str = "auto"
    # client model zoo (repro.fed.simulator.build_experiment): "shared"
    # gives every client the same architecture (one cohort — the legacy
    # feature-mode zoo), "mixed" cycles a small set of MLP width variants
    # across clients so the cohort engine sees a genuinely heterogeneous
    # zoo (image mode is always per-client heterogeneous and ignores this
    # knob). "auto" (default) = shared unless the REPRO_ZOO env var says
    # otherwise (same pattern as REPRO_KERNEL_BACKEND/REPRO_ROUND_MODE).
    zoo: str = "auto"
    # concurrent-cohort scheduling (repro.fed.scheduler): when True, the
    # phase graph keys client-side phase nodes (local_train/report/distill)
    # per cohort, so different cohorts' phases interleave within and across
    # rounds — cohort A distills round r while cohort B already trains
    # round r+1 on the simulated straggler clock. Aggregation stays a
    # global barrier (the protocol needs every cohort's report). With a
    # single cohort this reproduces the serial schedule bit-for-bit; the
    # default False keeps the engine-wide phase nodes.
    concurrent_cohorts: bool = False
    # -- payload-fault injection (repro.fed.faults) --------------------------
    # deterministic report corruption applied *after* local training, in the
    # scheduler's ingest path, so every engine injects identically. "none"
    # (default) never builds the injector — bit-for-bit the legacy logs.
    # Modes: nan | random_logits | scaled | colluding_flip | stale_replay.
    fault_mode: str = "none"
    # transient corruption: each participant flips an independent coin per
    # round (deterministic in (seed, round, client)). 0 = never.
    fault_prob: float = 0.0
    # fixed adversarial subset: round(byzantine_frac * C) clients, stable in
    # (seed, client), corrupt every round the window is active. 0 = none.
    byzantine_frac: float = 0.0
    # attack window in round indices: faults fire for rounds in
    # [fault_start, fault_start + fault_duration); duration 0 = unbounded.
    fault_start: int = 0
    fault_duration: int = 0
    # -- robust knowledge aggregation (repro.core.aggregation) ---------------
    # reducer over the client axis of the stacked (C, t, K) reports:
    # "mean" (default, bit-for-bit legacy) | "trimmed_mean" | "median" |
    # "krum_row". With num_edge_aggregators > 1 the robust reduce runs
    # edge-locally and the root fuses contributor-weighted edge centers —
    # an approximation of the flat robust reduce (exact at E=1).
    robust_aggregation: str = "mean"
    # trimmed_mean only: fraction trimmed from each tail per coordinate
    # (must exceed the expected Byzantine fraction to tolerate it).
    trim_frac: float = 0.2
    # server sanitize pass: scrub non-finite report rows at ingest and
    # account them per client (RoundLog.scrubbed_rows). On by default — an
    # exact no-op on finite reports.
    sanitize_reports: bool = True
    # -- trust & quarantine (repro.fed.server) -------------------------------
    # per-client trust = EWMA of the per-round outlier distance from the
    # robust center, normalized by the round median. A contributing client
    # whose trust exceeds quarantine_threshold is demoted to a
    # non-participant for quarantine_rounds * strikes rounds (escalating),
    # then re-admitted on probation (trust reset to threshold / 2).
    # 0 (default) disables trust tracking entirely.
    quarantine_threshold: float = 0.0
    trust_ewma: float = 0.5
    quarantine_rounds: int = 2
    # -- divergence watchdog (repro.fed.scheduler) ---------------------------
    # guard on retired RoundLog health: non-finite losses/accs, mean_acc
    # collapsing > watchdog_acc_drop below the best seen, or distill loss
    # spiking > watchdog_loss_factor x the recent median trigger a rollback
    # to the last healthy in-memory snapshot and quarantine the round's
    # top-suspect clients. False (default) = no snapshots, no checks.
    watchdog: bool = False
    watchdog_acc_drop: float = 0.2
    watchdog_loss_factor: float = 10.0
    watchdog_max_rollbacks: int = 3
