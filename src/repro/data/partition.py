"""Non-IID partitioners (paper §IV-A).

strong — each client gets a unique, non-overlapping label subset
         (10 clients × 10 classes ⇒ 1 exclusive class each);
weak   — each client gets `labels_per_client` labels drawn at random
         (overlapping allowed), samples of a label split evenly among its
         holders;
iid    — uniform random split.
"""
from __future__ import annotations

from typing import List, NamedTuple

import numpy as np


class ClientData(NamedTuple):
    x: np.ndarray
    y: np.ndarray
    labels: np.ndarray    # the label set this client holds


def _by_label(y: np.ndarray, num_classes: int):
    return [np.where(y == c)[0] for c in range(num_classes)]


def partition(x, y, *, num_clients: int, num_classes: int, scenario: str,
              labels_per_client: int = 3, seed: int = 0) -> List[ClientData]:
    x = np.asarray(x)
    y = np.asarray(y)
    if scenario == "weak":
        # rng.choice(..., replace=False) below would die with an opaque
        # numpy error ("Cannot take a larger sample...") — fail legibly
        if not 1 <= labels_per_client <= num_classes:
            raise ValueError(
                f"labels_per_client={labels_per_client} must be in "
                f"[1, num_classes={num_classes}] for the weak non-IID "
                "partition (each client draws that many distinct labels)")
    rng = np.random.default_rng(seed)
    idx_by_label = _by_label(y, num_classes)
    out: List[ClientData] = []

    if scenario == "strong":
        # unique non-overlapping label subsets; with C == K, one class each
        perm = rng.permutation(num_classes)
        chunks = np.array_split(perm, num_clients)
        for c in range(num_clients):
            labels = np.sort(chunks[c])
            idx = np.concatenate([idx_by_label[lab] for lab in labels])
            rng.shuffle(idx)
            out.append(ClientData(x[idx], y[idx], labels))

    elif scenario == "weak":
        holders = [[] for _ in range(num_classes)]
        client_labels = []
        for c in range(num_clients):
            labels = rng.choice(num_classes, size=labels_per_client, replace=False)
            client_labels.append(np.sort(labels))
            for lab in labels:
                holders[lab].append(c)
        # ensure every class has ≥1 holder so data isn't orphaned
        for lab in range(num_classes):
            if not holders[lab]:
                c = int(rng.integers(num_clients))
                holders[lab].append(c)
                client_labels[c] = np.sort(np.append(client_labels[c], lab))
        buckets = [[] for _ in range(num_clients)]
        for lab in range(num_classes):
            idx = idx_by_label[lab].copy()
            rng.shuffle(idx)
            for part, c in zip(np.array_split(idx, len(holders[lab])),
                               holders[lab]):
                buckets[c].append(part)
        for c in range(num_clients):
            idx = np.concatenate(buckets[c]) if buckets[c] else np.array([], np.int64)
            rng.shuffle(idx)
            out.append(ClientData(x[idx], y[idx], client_labels[c]))

    elif scenario == "iid":
        idx = rng.permutation(len(y))
        for part in np.array_split(idx, num_clients):
            out.append(ClientData(x[part], y[part], np.arange(num_classes)))
    else:
        raise ValueError(f"unknown scenario {scenario!r}")
    return out
