from repro.data.partition import ClientData, partition
from repro.data.proxy import ProxyData, build_proxy, select_round_indices
from repro.data.synthetic import SPECS, Dataset, make_dataset
from repro.data.tokens import MarkovTokenStream, synth_frames, synth_vision
