"""Synthetic class-clustered datasets standing in for MNIST / FashionMNIST /
CIFAR-10 (offline container — DESIGN.md §7.1).

Each dataset is a mixture of per-class Gaussian clusters in a latent space,
rendered either as flat feature vectors (the CIFAR10* pre-extracted-feature
mode the paper uses for complex data, §V-C) or as image tensors via a fixed
random linear decoder (pixel mode). The knob that matters for the paper's
claims is **class separation vs overlap**:

  * ``mnist_like``        — well-separated clusters (Fig 4a: distinct blobs)
  * ``fashion_like``      — moderately separated (Fig 4b)
  * ``cifar_like``        — strongly overlapping, higher-dim latent (Fig 4c)
  * ``cifar_feat_like``   — cifar re-embedded with wider margins
                            (Fig 4d: what a pretrained ResNet-18 gives you)

Every sample also carries a latent cluster coordinate so tests can verify
DRE behaviour against ground truth densities.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class Dataset(NamedTuple):
    x: jax.Array        # (n, ...) samples (images NHWC or flat features)
    y: jax.Array        # (n,) int32 labels
    x_test: jax.Array
    y_test: jax.Array
    num_classes: int
    name: str


@dataclasses.dataclass(frozen=True)
class SyntheticSpec:
    name: str
    num_classes: int = 10
    latent_dim: int = 16
    separation: float = 6.0      # distance between class means
    within_std: float = 1.0      # intra-class spread
    image_hw: int = 0            # 0 = flat features, else render to (hw,hw,ch)
    channels: int = 1
    feature_dim: int = 50        # flat-feature output dim
    seq_len: int = 0             # >0 = token mode: x is (n, seq_len) int32


SPECS = {
    "mnist_like": SyntheticSpec("mnist_like", separation=8.0, within_std=1.0,
                                image_hw=28, channels=1),
    "fashion_like": SyntheticSpec("fashion_like", separation=5.0, within_std=1.2,
                                  image_hw=28, channels=1),
    "cifar_like": SyntheticSpec("cifar_like", separation=2.5, within_std=1.6,
                                latent_dim=32, image_hw=32, channels=3),
    # feature-space variants (fast CPU path; paper's CIFAR10* mode)
    "mnist_feat": SyntheticSpec("mnist_feat", separation=8.0, within_std=1.0),
    "fashion_feat": SyntheticSpec("fashion_feat", separation=5.0, within_std=1.2),
    "cifar_feat": SyntheticSpec("cifar_feat", separation=2.5, within_std=1.6,
                                latent_dim=32),
    "cifar_feat_resnet": SyntheticSpec("cifar_feat_resnet", separation=6.0,
                                       within_std=1.1, latent_dim=32),
    # token mode (transformer clients): each sample is a (seq_len,) int32
    # token sequence drawn from a narrow vocab band around a latent token
    # t*, labelled y = t* — an LM next-token task whose classes ARE vocab
    # entries, so the label partitioners produce vocab-band non-IID (the LM
    # analogue of the paper's strong scenario) and the bands stay separable
    # in raw token-id space for the KMeans-DRE filter.
    "lm_tokens": SyntheticSpec("lm_tokens", num_classes=32, seq_len=16),
}


def make_dataset(name: str, *, n_train: int = 5000, n_test: int = 1000,
                 seed: int = 0) -> Dataset:
    spec = SPECS[name]
    key = jax.random.PRNGKey(seed)
    k_means, k_tr, k_te, k_dec = jax.random.split(key, 4)

    if spec.seq_len:
        half_w = max(1, spec.num_classes // 16)

        def sample_tokens(k, n):
            ky, kz = jax.random.split(k)
            y = jax.random.randint(ky, (n,), 0, spec.num_classes)
            noise = jax.random.randint(kz, (n, spec.seq_len),
                                       -half_w, half_w + 1)
            x = jnp.mod(y[:, None] + noise, spec.num_classes)
            return x.astype(jnp.int32), y.astype(jnp.int32)

        x_tr, y_tr = sample_tokens(k_tr, n_train)
        x_te, y_te = sample_tokens(k_te, n_test)
        return Dataset(x=x_tr, y=y_tr, x_test=x_te, y_test=y_te,
                       num_classes=spec.num_classes, name=name)

    means = jax.random.normal(k_means, (spec.num_classes, spec.latent_dim))
    means = means / jnp.linalg.norm(means, axis=-1, keepdims=True) * spec.separation

    def sample(k, n):
        ky, kz = jax.random.split(k)
        y = jax.random.randint(ky, (n,), 0, spec.num_classes)
        z = means[y] + spec.within_std * jax.random.normal(kz, (n, spec.latent_dim))
        return z, y.astype(jnp.int32)

    z_tr, y_tr = sample(k_tr, n_train)
    z_te, y_te = sample(k_te, n_test)

    if spec.image_hw:
        out_dim = spec.image_hw * spec.image_hw * spec.channels
        dec = jax.random.normal(k_dec, (spec.latent_dim, out_dim)) / jnp.sqrt(spec.latent_dim)
        def render(z):
            img = jnp.tanh(z @ dec)          # bounded pixels in (-1, 1)
            return img.reshape(-1, spec.image_hw, spec.image_hw, spec.channels)
        x_tr, x_te = render(z_tr), render(z_te)
    else:
        dec = (jax.random.normal(k_dec, (spec.latent_dim, spec.feature_dim))
               / jnp.sqrt(spec.latent_dim))
        x_tr, x_te = z_tr @ dec, z_te @ dec

    return Dataset(x=x_tr, y=y_tr, x_test=x_te, y_test=y_te,
                   num_classes=spec.num_classes, name=name)
