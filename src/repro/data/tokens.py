"""Synthetic token / frame / patch streams for the large-architecture drivers.

A Zipfian token sampler with Markov structure gives the LM examples a
learnable signal (bigram statistics) so the 100M-model driver's loss
visibly decreases — pure-uniform tokens would bottom out at log(V).
"""
from __future__ import annotations

import numpy as np


class MarkovTokenStream:
    """Order-1 Markov chain over a Zipf vocabulary."""

    def __init__(self, vocab_size: int, branching: int = 32, seed: int = 0):
        self.vocab = vocab_size
        self.rng = np.random.default_rng(seed)
        # each token transitions to one of `branching` successors
        self.succ = self.rng.integers(0, vocab_size,
                                      size=(vocab_size, branching))
        ranks = np.arange(1, branching + 1, dtype=np.float64)
        p = 1.0 / ranks
        self.p = p / p.sum()

    def batch(self, batch_size: int, seq_len: int):
        """Returns {tokens (B,S), labels (B,S)} — labels are next tokens."""
        out = np.empty((batch_size, seq_len + 1), np.int32)
        out[:, 0] = self.rng.integers(0, self.vocab, size=batch_size)
        for t in range(seq_len):
            choice = self.rng.choice(self.succ.shape[1], size=batch_size, p=self.p)
            out[:, t + 1] = self.succ[out[:, t], choice]
        return {"tokens": out[:, :-1], "labels": out[:, 1:]}


def synth_frames(rng, batch: int, seq: int, dim: int):
    """Audio frontend stub output: smooth frame embeddings."""
    base = rng.standard_normal((batch, seq // 4 + 2, dim)).astype(np.float32)
    idx = np.linspace(0, base.shape[1] - 1.001, seq)
    lo = idx.astype(int)
    frac = (idx - lo)[None, :, None].astype(np.float32)
    return base[:, lo] * (1 - frac) + base[:, lo + 1] * frac


def synth_vision(rng, batch: int, num_tokens: int, dim: int):
    """Vision frontend stub output: patch embeddings."""
    return rng.standard_normal((batch, num_tokens, dim)).astype(np.float32)
