"""Proxy dataset construction (Algorithm 1, Initialization lines 5–8).

Each client contributes a fraction alpha of its private data; the server
concatenates and redistributes. Provenance (owner id per proxy sample) is
recorded — it drives stage 1 of the two-stage client filter (exact
membership) without any per-round set lookups.
"""
from __future__ import annotations

from typing import List, NamedTuple, Sequence

import numpy as np

from repro.data.partition import ClientData


class ProxyData(NamedTuple):
    x: np.ndarray        # (t, ...) proxy samples
    y: np.ndarray        # (t,) labels (held by server; used for eval only)
    owner: np.ndarray    # (t,) int32 contributing client


def build_proxy(clients: Sequence[ClientData], alpha: float,
                seed: int = 0) -> ProxyData:
    rng = np.random.default_rng(seed)
    xs, ys, owners = [], [], []
    for cid, c in enumerate(clients):
        n = len(c.y)
        take = max(1, int(round(alpha * n)))
        idx = rng.choice(n, size=take, replace=False)
        xs.append(np.asarray(c.x)[idx])
        ys.append(np.asarray(c.y)[idx])
        owners.append(np.full(take, cid, np.int32))
    x = np.concatenate(xs)
    y = np.concatenate(ys)
    o = np.concatenate(owners)
    perm = rng.permutation(len(y))
    return ProxyData(x[perm], y[perm], o[perm])


def select_round_indices(rng: np.random.Generator, proxy: ProxyData,
                         batch: int) -> np.ndarray:
    """Server's per-round random index selection (Algorithm 1 line 13)."""
    return rng.choice(len(proxy.y), size=min(batch, len(proxy.y)), replace=False)
