"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, chunkwise-parallel)
and sLSTM (scalar memory, sequential scan with exponential-gating stabilizer).

TPU adaptation (DESIGN.md §3): mLSTM trains in the chunkwise-parallel form —
intra-chunk quadratic attention-like compute on an MXU-friendly (L_c × L_c)
tile plus an inter-chunk `lax.scan` carrying the (d_k × d_v) matrix state —
instead of a per-timestep CUDA kernel. Decode carries O(1)-in-sequence state,
which is what makes the ``long_500k`` shape native for this family.
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.common.pytree import init_dense
from repro.models.layers import init_rmsnorm, rmsnorm
from repro.models.sharding import constrain

CHUNK = 256
_CLAMP = 8.0  # clamp on input-gate preactivation (keeps exp() in f32 range)

# sLSTM time-scan unroll factor (perf lever, EXPERIMENTS.md §Perf pair B):
# the recurrent-weight gradient partials are all-reduced once per TIMESTEP
# when the batch axis is sharded; unrolling the scan body exposes `k`
# consecutive reductions to XLA's all-reduce-reassociation pass, which
# collapses them into one per unrolled block (t_collective ÷ k).
SLSTM_UNROLL = 1


def set_slstm_unroll(k: int) -> None:
    global SLSTM_UNROLL
    SLSTM_UNROLL = max(1, int(k))


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, d_model: int, num_heads: int, dtype=jnp.float32):
    """Pre-up-projection mLSTM block (proj factor 2)."""
    d_in = 2 * d_model
    names = ["wup", "wgate", "wq", "wk", "wv", "wi", "wf", "wo_gate", "wdown"]
    ks = jax.random.split(key, len(names))
    std = 1.0 / math.sqrt(d_model)
    std_i = 1.0 / math.sqrt(d_in)
    h = d_in // num_heads
    p = {
        "norm": init_rmsnorm(d_model, dtype),
        "wup": (jax.random.normal(ks[0], (d_model, d_in)) * std).astype(dtype),
        "wgate": (jax.random.normal(ks[1], (d_model, d_in)) * std).astype(dtype),
        "wq": (jax.random.normal(ks[2], (d_in, num_heads, h)) * std_i).astype(dtype),
        "wk": (jax.random.normal(ks[3], (d_in, num_heads, h)) * std_i).astype(dtype),
        "wv": (jax.random.normal(ks[4], (d_in, num_heads, h)) * std_i).astype(dtype),
        "wi": (jax.random.normal(ks[5], (d_in, num_heads)) * std_i).astype(dtype),
        "bi": jnp.zeros((num_heads,), dtype),
        "wf": (jax.random.normal(ks[6], (d_in, num_heads)) * std_i).astype(dtype),
        "bf": jnp.full((num_heads,), 3.0, dtype),  # init forget-gate open
        "wdown": (jax.random.normal(ks[8], (d_in, d_model)) * std_i).astype(dtype),
    }
    return p


class MLSTMState(NamedTuple):
    c: jax.Array   # (B, N, hk, hv) matrix memory
    n: jax.Array   # (B, N, hk) normalizer


def mlstm_zero_state(batch: int, num_heads: int, head_dim: int, dtype=jnp.float32):
    return MLSTMState(
        c=jnp.zeros((batch, num_heads, head_dim, head_dim), dtype),
        n=jnp.zeros((batch, num_heads, head_dim), dtype),
    )


def _mlstm_gates(p, u):
    """u: (B,S,d_in) -> per-head q,k,v,(log i, log f) in f32."""
    q = jnp.einsum("bsd,dnh->bsnh", u, p["wq"])
    k = jnp.einsum("bsd,dnh->bsnh", u, p["wk"]) / math.sqrt(p["wk"].shape[-1])
    v = jnp.einsum("bsd,dnh->bsnh", u, p["wv"])
    log_i = jnp.clip(
        (jnp.einsum("bsd,dn->bsn", u, p["wi"]) + p["bi"]).astype(jnp.float32),
        -_CLAMP, _CLAMP)
    log_f = jax.nn.log_sigmoid(
        (jnp.einsum("bsd,dn->bsn", u, p["wf"]) + p["bf"]).astype(jnp.float32))
    return q, k, v, log_i, log_f


def _mlstm_chunk(q, k, v, log_i, log_f, state: MLSTMState):
    """One chunk, parallel within. q,k,v: (B,L,N,h); gates: (B,L,N) f32."""
    b, L, n, h = q.shape
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    A = jnp.cumsum(log_f, axis=1)                          # (B,L,N) inclusive
    # intra-chunk decay matrix D[t,s] = exp(A_t - A_s + log_i_s), s<=t
    At = A[:, :, None, :]                                  # (B,L,1,N)
    As = A[:, None, :, :]                                  # (B,1,L,N)
    li = log_i[:, None, :, :]                              # (B,1,L,N)
    expo = At - As + li
    tri = (jnp.arange(L)[:, None] >= jnp.arange(L)[None, :])[None, :, :, None]
    D = jnp.where(tri, jnp.exp(jnp.minimum(expo, _CLAMP * 4)), 0.0)  # (B,L,L,N)
    scores = jnp.einsum("btnh,bsnh->btsn", qf, kf) * D
    intra = jnp.einsum("btsn,bsnh->btnh", scores, vf)
    intra_n = jnp.einsum("btsn,bsnh->btnh", D, kf)
    # contribution of carried-in state
    decay_t = jnp.exp(At[:, :, 0, :])                      # (B,L,N) = exp(A_t)
    inter = jnp.einsum("btnh,bnhg->btng", qf, state.c.astype(jnp.float32)) \
        * decay_t[..., None]
    inter_n = state.n.astype(jnp.float32)[:, None] * decay_t[..., None]
    num = intra + inter                                    # (B,L,N,h_v)
    nn = intra_n + inter_n                                 # (B,L,N,h_k)
    denom = jnp.maximum(jnp.abs(jnp.einsum("btnh,btnh->btn", qf, nn)), 1.0)
    out = num / denom[..., None]
    # chunk-end state
    aL = A[:, -1, :]                                       # (B,N)
    w = jnp.exp(aL[:, None, :] - A + log_i)                # (B,L,N)
    c_new = state.c.astype(jnp.float32) * jnp.exp(aL)[..., None, None] \
        + jnp.einsum("bsn,bsnh,bsng->bnhg", w, kf, vf)
    n_new = state.n.astype(jnp.float32) * jnp.exp(aL)[..., None] \
        + jnp.einsum("bsn,bsnh->bnh", w, kf)
    return out, MLSTMState(c_new, n_new)


def mlstm_forward(p, x, num_heads: int, *, chunk: int = CHUNK, eps: float = 1e-5):
    """x: (B,S,d_model) -> (B,S,d_model). Training/prefill path."""
    b, s, d = x.shape
    xn = rmsnorm(p["norm"], x, eps)
    u = jnp.einsum("bsd,de->bse", xn, p["wup"])
    gate = jax.nn.silu(jnp.einsum("bsd,de->bse", xn, p["wgate"]))
    q, k, v, log_i, log_f = _mlstm_gates(p, u)
    h = q.shape[-1]
    L = min(chunk, s)
    pad = (-s) % L
    if pad:
        def padf(a):
            return jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        q, k, v, log_i, log_f = map(padf, (q, k, v, log_i, log_f))
    nc = (s + pad) // L

    def resh(a):
        return a.reshape((b, nc, L) + a.shape[2:])
    qs, ks, vs, lis, lfs = map(resh, (q, k, v, log_i, log_f))

    def body(state, inp):
        qc, kc, vc, lic, lfc = inp
        out, state = _mlstm_chunk(qc, kc, vc, lic, lfc, state)
        return state, out

    state0 = mlstm_zero_state(b, num_heads, h)
    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (qs, ks, vs, lis, lfs))
    _, outs = jax.lax.scan(body, state0, xs)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s + pad, num_heads, h)[:, :s]
    out = out.reshape(b, s, -1).astype(x.dtype) * gate
    y = jnp.einsum("bse,ed->bsd", out, p["wdown"])
    return constrain(x + y, "batch", "seq", "embed")


def mlstm_decode(p, x, state: MLSTMState, num_heads: int, eps: float = 1e-5):
    """x: (B,1,d). Returns (y, new_state)."""
    b = x.shape[0]
    xn = rmsnorm(p["norm"], x, eps)
    u = jnp.einsum("bsd,de->bse", xn, p["wup"])
    gate = jax.nn.silu(jnp.einsum("bsd,de->bse", xn, p["wgate"]))
    q, k, v, log_i, log_f = _mlstm_gates(p, u)
    qf, kf, vf = (a[:, 0].astype(jnp.float32) for a in (q, k, v))  # (B,N,h)
    i_t = jnp.exp(log_i[:, 0])                                     # (B,N)
    f_t = jnp.exp(log_f[:, 0])
    c = state.c.astype(jnp.float32) * f_t[..., None, None] \
        + i_t[..., None, None] * jnp.einsum("bnh,bng->bnhg", kf, vf)
    n = state.n.astype(jnp.float32) * f_t[..., None] + i_t[..., None] * kf
    num = jnp.einsum("bnh,bnhg->bng", qf, c)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bnh,bnh->bn", qf, n)), 1.0)
    out = (num / denom[..., None]).reshape(b, 1, -1).astype(x.dtype) * gate
    y = jnp.einsum("bse,ed->bsd", out, p["wdown"])
    return x + y, MLSTMState(c, n)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, d_model: int, num_heads: int, dtype=jnp.float32):
    """Post-up-projection sLSTM block: sLSTM at d_model + gated MLP (4/3)."""
    names = ["wz", "wi", "wf", "wo", "rz", "ri", "rf", "ro", "wup", "wgate", "wdown"]
    ks = jax.random.split(key, len(names))
    std = 1.0 / math.sqrt(d_model)
    h = d_model // num_heads
    d_ff = (4 * d_model) // 3
    # recurrent weights are block-diagonal per head: (N, h, h)
    def rmat(k):
        return (jax.random.normal(k, (num_heads, h, h)) * (1.0 / math.sqrt(h))).astype(dtype)
    p = {
        "norm": init_rmsnorm(d_model, dtype),
        "wz": (jax.random.normal(ks[0], (d_model, d_model)) * std).astype(dtype),
        "wi": (jax.random.normal(ks[1], (d_model, d_model)) * std).astype(dtype),
        "wf": (jax.random.normal(ks[2], (d_model, d_model)) * std).astype(dtype),
        "wo": (jax.random.normal(ks[3], (d_model, d_model)) * std).astype(dtype),
        "rz": rmat(ks[4]), "ri": rmat(ks[5]), "rf": rmat(ks[6]), "ro": rmat(ks[7]),
        "bz": jnp.zeros((d_model,), dtype), "bi": jnp.zeros((d_model,), dtype),
        "bf": jnp.full((d_model,), 3.0, dtype), "bo": jnp.zeros((d_model,), dtype),
        "norm2": init_rmsnorm(d_model, dtype),
        "wup": (jax.random.normal(ks[8], (d_model, d_ff)) * std).astype(dtype),
        "wgate": (jax.random.normal(ks[9], (d_model, d_ff)) * std).astype(dtype),
        "wdown": (jax.random.normal(ks[10], (d_ff, d_model))
                  * (1.0 / math.sqrt(d_ff))).astype(dtype),
    }
    return p


class SLSTMState(NamedTuple):
    h: jax.Array   # (B, D)
    c: jax.Array   # (B, D)
    n: jax.Array   # (B, D)
    m: jax.Array   # (B, D) log-space stabilizer


def slstm_zero_state(batch: int, d_model: int, dtype=jnp.float32):
    z = jnp.zeros((batch, d_model), dtype)
    return SLSTMState(z, z, z, jnp.full((batch, d_model), -1e9, jnp.float32))


def _slstm_step(p, num_heads, state: SLSTMState, zi_fi_oi):
    """One timestep. zi_fi_oi: precomputed Wx contributions (B,D) each."""
    wz, wi, wf, wo = zi_fi_oi
    b, d = state.h.shape
    hprev = state.h.reshape(b, num_heads, -1)

    def rec(r):
        return jnp.einsum("bnh,nhg->bng", hprev, r).reshape(b, d)
    z = jnp.tanh(wz + rec(p["rz"]))
    i_pre = (wi + rec(p["ri"])).astype(jnp.float32)
    f_pre = (wf + rec(p["rf"])).astype(jnp.float32)
    o = jax.nn.sigmoid(wo + rec(p["ro"]))
    log_f = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(log_f + state.m, i_pre)
    i_s = jnp.exp(i_pre - m_new)
    f_s = jnp.exp(log_f + state.m - m_new)
    c = f_s * state.c.astype(jnp.float32) + i_s * z.astype(jnp.float32)
    n = f_s * state.n.astype(jnp.float32) + i_s
    h = (o.astype(jnp.float32) * c / jnp.maximum(n, 1e-6)).astype(state.h.dtype)
    return SLSTMState(h, c.astype(state.c.dtype), n.astype(state.n.dtype), m_new)


# ---------------------------------------------------------------------------
# custom-VJP sLSTM core (perf lever, EXPERIMENTS.md §Perf pair B)
#
# Plain AD of the time scan contracts the batch axis of the recurrent-weight
# gradients INSIDE the loop; under batch sharding GSPMD then emits one
# all-reduce per timestep (~200 GB/step for train_4k). This VJP accumulates
# dR with the batch axis KEPT (B, N, h, h) across the reverse scan and sums
# over batch once at the end — a single all-reduce after the loop.
# ---------------------------------------------------------------------------

def _local_step(recs, state, num_heads):
    """Step math given precomputed recurrent contributions (no R inside)."""
    rz, ri, rf, ro = recs
    z = jnp.tanh(rz)
    i_pre = ri.astype(jnp.float32)
    f_pre = rf.astype(jnp.float32)
    o = jax.nn.sigmoid(ro)
    log_f = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(log_f + state.m, i_pre)
    i_s = jnp.exp(i_pre - m_new)
    f_s = jnp.exp(log_f + state.m - m_new)
    c = f_s * state.c.astype(jnp.float32) + i_s * z.astype(jnp.float32)
    n = f_s * state.n.astype(jnp.float32) + i_s
    h = (o.astype(jnp.float32) * c / jnp.maximum(n, 1e-6)).astype(state.h.dtype)
    return SLSTMState(h, c.astype(state.c.dtype), n.astype(state.n.dtype), m_new)


def _recs(rmats, hprev, wx_t, num_heads):
    b, d = hprev.shape
    hh = hprev.reshape(b, num_heads, -1)

    def rec(r):
        return jnp.einsum("bnh,nhg->bng", hh, r).reshape(b, d)
    return tuple(w + rec(r) for w, r in zip(wx_t, rmats))


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _slstm_scan(rmats, wx, num_heads):
    """rmats: (rz, ri, rf, ro) each (N,h,h); wx: 4×(S,B,D) Wx+b inputs.
    Returns hs (S,B,D)."""
    b, d = wx[0].shape[1:]

    def body(state, wx_t):
        state = _local_step(_recs(rmats, state.h, wx_t, num_heads), state,
                            num_heads)
        return state, state.h

    _, hs = jax.lax.scan(body, slstm_zero_state(b, d, wx[0].dtype), wx)
    return hs


def _slstm_scan_fwd(rmats, wx, num_heads):
    b, d = wx[0].shape[1:]

    def body(state, wx_t):
        new = _local_step(_recs(rmats, state.h, wx_t, num_heads), state,
                          num_heads)
        return new, (new.h, state)          # save h_t and state_{t-1}

    _, (hs, prev_states) = jax.lax.scan(
        body, slstm_zero_state(b, d, wx[0].dtype), wx)
    return hs, (rmats, wx, prev_states)


def _slstm_scan_bwd(num_heads, res, g_hs):
    rmats, wx, prev_states = res
    b, d = wx[0].shape[1:]
    n_h = num_heads
    hd = d // n_h

    def step_out(recs, state):
        new = _local_step(recs, state, num_heads)
        return (new.h, new.c, new.n, new.m)

    zero_state = slstm_zero_state(b, d, wx[0].dtype)
    dR0 = tuple(jnp.zeros((b, n_h, hd, hd), jnp.float32) for _ in range(4))

    def body(carry, xs):
        (dh, dc, dn, dm), dR = carry
        g_t, wx_t, state_prev = xs
        recs = _recs(rmats, state_prev.h, wx_t, num_heads)
        _, vjp_fn = jax.vjp(step_out, recs, state_prev)
        d_recs, d_state = vjp_fn((dh + g_t, dc, dn, dm))
        # dR accumulated WITH batch axis (the whole point):
        hprev = state_prev.h.reshape(b, n_h, hd)
        dR = tuple(
            acc + jnp.einsum("bnh,bng->bnhg", hprev,
                             dr.reshape(b, n_h, hd).astype(jnp.float32))
            for acc, dr in zip(dR, d_recs))
        # cotangent into h_{t-1} via the recurrent matmuls:
        dh_prev = d_state.h.astype(jnp.float32)
        for dr, r in zip(d_recs, rmats):
            dh_prev = dh_prev + jnp.einsum(
                "bng,nhg->bnh", dr.reshape(b, n_h, hd).astype(jnp.float32),
                r.astype(jnp.float32)).reshape(b, d)
        dwx_t = tuple(dr for dr in d_recs)   # wx enters additively
        new_carry = ((dh_prev.astype(g_t.dtype), d_state.c, d_state.n,
                      d_state.m), dR)
        return new_carry, dwx_t

    st_dt = wx[0].dtype   # slstm_zero_state uses the input dtype for h/c/n
    zeros = (jnp.zeros((b, d), st_dt), jnp.zeros((b, d), st_dt),
             jnp.zeros((b, d), st_dt), jnp.zeros((b, d), jnp.float32))
    (_, dR), dwx = jax.lax.scan(body, (zeros, dR0),
                                (g_hs, wx, prev_states), reverse=True)
    # single batch contraction AFTER the loop -> one all-reduce under SPMD
    d_rmats = tuple(jnp.sum(a, axis=0).astype(r.dtype)
                    for a, r in zip(dR, rmats))
    return d_rmats, dwx


_slstm_scan.defvjp(_slstm_scan_fwd, _slstm_scan_bwd)

USE_SLSTM_CUSTOM_VJP = True


def set_slstm_custom_vjp(on: bool) -> None:
    global USE_SLSTM_CUSTOM_VJP
    USE_SLSTM_CUSTOM_VJP = bool(on)


def slstm_forward(p, x, num_heads: int, eps: float = 1e-5):
    """x: (B,S,d) -> (B,S,d). Sequential lax.scan over time."""
    b, s, d = x.shape
    xn = rmsnorm(p["norm"], x, eps)
    wz = jnp.einsum("bsd,de->bse", xn, p["wz"]) + p["bz"]
    wi = jnp.einsum("bsd,de->bse", xn, p["wi"]) + p["bi"]
    wf = jnp.einsum("bsd,de->bse", xn, p["wf"]) + p["bf"]
    wo = jnp.einsum("bsd,de->bse", xn, p["wo"]) + p["bo"]

    if USE_SLSTM_CUSTOM_VJP:
        rmats = (p["rz"], p["ri"], p["rf"], p["ro"])
        wx = tuple(jnp.moveaxis(a, 1, 0) for a in (wz, wi, wf, wo))
        hs = _slstm_scan(rmats, wx, num_heads)
    else:
        def body(state, inp):
            state = _slstm_step(p, num_heads, state, inp)
            return state, state.h

        xs = tuple(jnp.moveaxis(a, 1, 0) for a in (wz, wi, wf, wo))
        _, hs = jax.lax.scan(body, slstm_zero_state(b, d, x.dtype), xs,
                             unroll=SLSTM_UNROLL)
    h = jnp.moveaxis(hs, 0, 1)
    x = x + h
    # gated MLP
    xn2 = rmsnorm(p["norm2"], x, eps)
    u = jnp.einsum("bsd,df->bsf", xn2, p["wup"])
    g = jax.nn.gelu(jnp.einsum("bsd,df->bsf", xn2, p["wgate"]))
    y = jnp.einsum("bsf,fd->bsd", u * g, p["wdown"])
    return constrain(x + y, "batch", "seq", "embed")


def slstm_decode(p, x, state: SLSTMState, num_heads: int, eps: float = 1e-5):
    b = x.shape[0]
    xn = rmsnorm(p["norm"], x, eps)[:, 0]
    wz = xn @ p["wz"] + p["bz"]
    wi = xn @ p["wi"] + p["bi"]
    wf = xn @ p["wf"] + p["bf"]
    wo = xn @ p["wo"] + p["bo"]
    state = _slstm_step(p, num_heads, state, (wz, wi, wf, wo))
    x = x + state.h[:, None]
    xn2 = rmsnorm(p["norm2"], x, eps)
    u = jnp.einsum("bsd,df->bsf", xn2, p["wup"])
    g = jax.nn.gelu(jnp.einsum("bsd,df->bsf", xn2, p["wgate"]))
    y = jnp.einsum("bsf,fd->bsd", u * g, p["wdown"])
    return x + y, state
