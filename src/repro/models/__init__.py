from repro.models import cnn, layers, moe, rglru, sharding, ssm, transformer
