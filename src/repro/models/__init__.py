from repro.models import layers, moe, rglru, ssm, transformer, cnn, sharding
