"""Mixture-of-Experts layer: top-k router + fixed-capacity expert dispatch.

TPU-native design: dispatch/combine are dense one-hot einsums over a fixed
expert-capacity buffer (Switch/GShard style), which GSPMD lowers to
all-to-all on the ``model`` (expert) axis — no dynamic shapes. Router uses
softmax-after-top-k normalization (granite / mixtral convention) and an
auxiliary load-balance loss (Switch eq. 4).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import psum_einsum
from repro.models.sharding import constrain


def init_moe(key, d_model: int, d_ff: int, num_experts: int, dtype=jnp.float32):
    kr, kg, ku, kd = jax.random.split(key, 4)
    std = 1.0 / math.sqrt(d_model)
    return {
        "router": (jax.random.normal(kr, (d_model, num_experts)) * std).astype(dtype),
        "wg": (jax.random.normal(kg, (num_experts, d_model, d_ff)) * std).astype(dtype),
        "wu": (jax.random.normal(ku, (num_experts, d_model, d_ff)) * std).astype(dtype),
        "wd": (jax.random.normal(kd, (num_experts, d_ff, d_model))
               * (1.0 / math.sqrt(d_ff))).astype(dtype),
    }


def moe_forward(p, x, *, top_k: int, capacity_factor: float = 1.25):
    """x: (B, S, d) -> (out, aux_loss).

    Fixed capacity C = ceil(cf * S_tokens * top_k / E) per expert per batch
    row; overflowing tokens are dropped (standard Switch behaviour).
    """
    b, s, d = x.shape
    e = p["router"].shape[-1]
    n_tok = s
    cap = max(1, int(math.ceil(capacity_factor * n_tok * top_k / e)))
    cap = min(cap, n_tok)

    logits = jnp.einsum("bsd,de->bse", x, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)

    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)          # (b,s,k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # aux load-balance loss: E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=(0, 1))                            # (e,)
    one_hot_all = jax.nn.one_hot(expert_idx, e, dtype=jnp.float32)  # (b,s,k,e)
    ce = jnp.mean(jnp.sum(one_hot_all, axis=2), axis=(0, 1))     # (e,) frac routed
    aux = e * jnp.sum(me * ce / top_k)

    # position of each (token, k) within its expert's capacity buffer
    # cumulative count of tokens routed to the same expert before this slot
    flat_one_hot = one_hot_all.reshape(b, s * top_k, e)
    pos_in_expert = jnp.cumsum(flat_one_hot, axis=1) - flat_one_hot   # (b, s*k, e)
    pos = jnp.sum(pos_in_expert * flat_one_hot, axis=-1)              # (b, s*k)
    keep = pos < cap
    pos = jnp.minimum(pos, cap - 1).astype(jnp.int32)

    gates_flat = gate_vals.reshape(b, s * top_k) * keep.astype(jnp.float32)
    # dispatch tensor: (b, s*k, e, cap) — kept in the activation dtype
    # (bf16): halves the dispatch/combine all-to-all bytes (§Perf pair C)
    cap_one_hot = jax.nn.one_hot(pos, cap, dtype=x.dtype)
    dispatch = flat_one_hot.astype(x.dtype)[..., None] \
        * cap_one_hot[:, :, None, :] \
        * keep[..., None, None].astype(x.dtype)
    combine = dispatch * gates_flat[..., None, None].astype(x.dtype)

    xf = jnp.repeat(x, top_k, axis=1)                       # (b, s*k, d) token per slot
    expert_in = psum_einsum("btec,btd->becd", dispatch, xf)
    expert_in = constrain(expert_in, "batch", "experts", None, None)

    g = jnp.einsum("becd,edf->becf", expert_in, p["wg"])
    u = jnp.einsum("becd,edf->becf", expert_in, p["wu"])
    h = jax.nn.silu(g) * u
    expert_out = jnp.einsum("becf,efd->becd", h, p["wd"])
    expert_out = constrain(expert_out, "batch", "experts", None, None)

    yf = psum_einsum("btec,becd->btd", combine, expert_out)
    # slots for the same token are adjacent after jnp.repeat; sum merges top-k
    y = yf.reshape(b, s, top_k, d).sum(axis=2)
    return constrain(y, "batch", "seq", "embed"), aux
