"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Diagonal real-gated linear recurrence:
    a_t = exp(-c * softplus(Λ) * sigmoid(r_t))           (recurrence gate)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)   (input gate i_t)

TPU adaptation: the diagonal recurrence is evaluated with
``jax.lax.associative_scan`` (Blelloch parallel scan) over the sequence —
log-depth on the VPU instead of a sequential CUDA kernel. Decode is the O(1)
single-step update. A short (width-4) temporal conv precedes the LRU, per the
Griffin recurrent block.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import init_rmsnorm, rmsnorm
from repro.models.sharding import constrain

_C = 8.0  # Griffin's fixed scalar c
CONV_W = 4


def init_rglru(key, d_model: int, dtype=jnp.float32):
    """Griffin recurrent block: in-proj (2 branches), conv1d, RG-LRU, out."""
    ks = jax.random.split(key, 5)
    std = 1.0 / math.sqrt(d_model)
    d_rnn = d_model  # Griffin uses d_rnn ≈ 4/3 d; we keep = d for simplicity
    # Λ init so that a^c ∈ [0.9, 0.999] (paper init)
    u = jax.random.uniform(ks[3], (d_rnn,), minval=0.9 ** 2, maxval=0.999 ** 2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # softplus^-1(-log(u)/c)
    return {
        "norm": init_rmsnorm(d_model, dtype),
        "wx": (jax.random.normal(ks[0], (d_model, d_rnn)) * std).astype(dtype),
        "wgate": (jax.random.normal(ks[1], (d_model, d_rnn)) * std).astype(dtype),
        "conv": (jax.random.normal(ks[2], (CONV_W, d_rnn)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((d_rnn,), dtype),
        "lambda": lam.astype(jnp.float32),
        "wr": (jax.random.normal(ks[4], (d_rnn, d_rnn)) * std).astype(dtype),
        "br": jnp.zeros((d_rnn,), dtype),
        "wi": (jax.random.normal(jax.random.fold_in(ks[4], 1), (d_rnn, d_rnn)) * std).astype(dtype),
        "bi": jnp.zeros((d_rnn,), dtype),
        "wout": (jax.random.normal(jax.random.fold_in(ks[4], 2),
                                   (d_rnn, d_model)) * std).astype(dtype),
    }


class RGLRUState(NamedTuple):
    h: jax.Array          # (B, d_rnn) recurrent state
    conv: jax.Array       # (B, CONV_W-1, d_rnn) conv tail buffer


def rglru_zero_state(batch: int, d_rnn: int, dtype=jnp.float32):
    return RGLRUState(
        h=jnp.zeros((batch, d_rnn), jnp.float32),
        conv=jnp.zeros((batch, CONV_W - 1, d_rnn), dtype),
    )


def _gates(p, xc):
    """xc: (B,S,d_rnn) post-conv. Returns (a, beta*gated_x) in f32."""
    r = jax.nn.sigmoid((jnp.einsum("bsd,de->bse", xc, p["wr"]) + p["br"]).astype(jnp.float32))
    i = jax.nn.sigmoid((jnp.einsum("bsd,de->bse", xc, p["wi"]) + p["bi"]).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lambda"]) * r          # (B,S,d) ≤ 0
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * i * xc.astype(jnp.float32)


def _conv1d(p, x, tail=None):
    """Causal depthwise conv, width CONV_W. x: (B,S,d)."""
    if tail is None:
        xp = jnp.pad(x, ((0, 0), (CONV_W - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * p["conv"][i] for i in range(CONV_W))
    return out + p["conv_b"]


def rglru_forward(p, x, eps: float = 1e-5):
    """x: (B,S,d_model) -> (B,S,d_model) with residual."""
    xn = rmsnorm(p["norm"], x, eps)
    branch = jnp.einsum("bsd,de->bse", xn, p["wx"])
    gate = jax.nn.gelu(jnp.einsum("bsd,de->bse", xn, p["wgate"]))
    xc = _conv1d(p, branch)
    a, b = _gates(p, xc)

    # h_t = a_t h_{t-1} + b_t  — associative scan with pairs (a, b)
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, br + ar * bl

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    out = (h.astype(x.dtype) * gate)
    y = jnp.einsum("bse,ed->bsd", out, p["wout"])
    return constrain(x + y, "batch", "seq", "embed")


def rglru_decode(p, x, state: RGLRUState, eps: float = 1e-5):
    """x: (B,1,d_model). Returns (y, new_state)."""
    xn = rmsnorm(p["norm"], x, eps)
    branch = jnp.einsum("bsd,de->bse", xn, p["wx"])
    gate = jax.nn.gelu(jnp.einsum("bsd,de->bse", xn, p["wgate"]))
    xc = _conv1d(p, branch, tail=state.conv)
    new_tail = jnp.concatenate([state.conv[:, 1:], branch.astype(state.conv.dtype)], axis=1)
    a, b = _gates(p, xc)
    h = a[:, 0] * state.h + b[:, 0]
    out = h[:, None].astype(x.dtype) * gate
    y = jnp.einsum("bse,ed->bsd", out, p["wout"])
    return x + y, RGLRUState(h, new_tail)
