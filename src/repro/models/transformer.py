"""Unified backbone assembly for all assigned architecture families.

One config-driven model with family-specific layer stacks, all iterated with
``jax.lax.scan`` over stacked per-layer parameters (bounded HLO size even at
126 layers). Three entry points:

  * ``forward``      — full-sequence (training / prefill), returns logits
                       (+ MoE aux loss) and optionally the filled KV cache.
  * ``train_loss``   — CE loss (+ MoE aux) for the LM objective; encoder
                       (audio) uses frame-level unit prediction.
  * ``decode_step``  — one token against a cache (KV / recurrent state),
                       the ``decode_32k`` / ``long_500k`` path.

Families:
  dense / moe   — pre-norm GQA + SwiGLU (or MoE) blocks, scan over layers.
  vlm           — groups of (cross_attn_every-1) self layers + 1 gated
                  cross-attention layer; vision embeds come in pre-computed
                  (frontend stub per assignment carve-out).
  audio         — encoder-only bidirectional stack over frame embeddings.
  ssm (xlstm)   — alternating mLSTM / sLSTM groups.
  hybrid        — (period-1) RG-LRU blocks + 1 local-attention block.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.common.types import ArchConfig, AttentionKind
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import rglru as RG
from repro.models import ssm as SSM
from repro.models.sharding import constrain, gather_fsdp


def _stack_init(init_fn, key, n: int):
    """vmap an init over n layer keys -> stacked params (leading axis n)."""
    return jax.vmap(init_fn)(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(cfg: ArchConfig, key, dtype=jnp.float32):
    keys = jax.random.split(key, 8)
    d = cfg.d_model
    params: dict[str, Any] = {}
    if cfg.family != "audio":
        params["embed"] = (jax.random.normal(keys[0], (cfg.vocab_size, d)) * 0.02).astype(dtype)
    else:
        # frontend stub: inputs are frame embeddings; a single projection
        # stands in for the conv feature extractor output layer norm.
        params["frame_proj"] = (jax.random.normal(keys[0], (cfg.frontend_stub_dim, d))
                                * (1.0 / math.sqrt(cfg.frontend_stub_dim))).astype(dtype)
    params["final_norm"] = L.init_rmsnorm(d, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(keys[1], (d, cfg.vocab_size))
                             * (1.0 / math.sqrt(d))).astype(dtype)

    fam = cfg.family
    if fam in ("dense", "moe", "audio"):
        def one(k):
            ks = jax.random.split(k, 4)
            p = {
                "ln1": L.init_rmsnorm(d, dtype),
                "attn": L.init_attention(ks[0], cfg, dtype),
                "ln2": L.init_rmsnorm(d, dtype),
            }
            if cfg.moe is not None:
                p["moe"] = MOE.init_moe(ks[1], d, cfg.d_ff, cfg.moe.num_experts, dtype)
            else:
                p["mlp"] = L.init_mlp(ks[1], d, cfg.d_ff, dtype)
            return p
        params["blocks"] = _stack_init(one, keys[2], cfg.num_layers)

    elif fam == "vlm":
        per = cfg.cross_attn_every
        groups = cfg.num_layers // per
        n_self = per - 1

        def one_self(k):
            ks = jax.random.split(k, 2)
            return {
                "ln1": L.init_rmsnorm(d, dtype),
                "attn": L.init_attention(ks[0], cfg, dtype),
                "ln2": L.init_rmsnorm(d, dtype),
                "mlp": L.init_mlp(ks[1], d, cfg.d_ff, dtype),
            }

        def one_cross(k):
            ks = jax.random.split(k, 2)
            return {
                "ln1": L.init_rmsnorm(d, dtype),
                "attn": L.init_attention(ks[0], cfg, dtype),
                "gate_attn": jnp.zeros((), dtype),    # tanh-gated, init 0
                "ln2": L.init_rmsnorm(d, dtype),
                "mlp": L.init_mlp(ks[1], d, cfg.d_ff, dtype),
                "gate_mlp": jnp.zeros((), dtype),
            }

        def one_group(k):
            k1, k2 = jax.random.split(k)
            return {
                "self": _stack_init(one_self, k1, n_self),
                "cross": one_cross(k2),
            }
        params["blocks"] = _stack_init(one_group, keys[2], groups)

    elif fam == "ssm":
        groups = cfg.num_layers // 2

        def one_group(k):
            k1, k2 = jax.random.split(k)
            return {
                "mlstm": SSM.init_mlstm(k1, d, cfg.num_heads, dtype),
                "slstm": SSM.init_slstm(k2, d, cfg.num_heads, dtype),
            }
        params["blocks"] = _stack_init(one_group, keys[2], groups)

    elif fam == "hybrid":
        per = cfg.hybrid_period
        groups = cfg.num_layers // per
        n_rec = per - 1

        def one_rec(k):
            k1, k2 = jax.random.split(k)
            return {
                "rglru": RG.init_rglru(k1, d, dtype),
                "ln2": L.init_rmsnorm(d, dtype),
                "mlp": L.init_mlp(k2, d, cfg.d_ff, dtype),
            }

        def one_attn(k):
            k1, k2 = jax.random.split(k)
            return {
                "ln1": L.init_rmsnorm(d, dtype),
                "attn": L.init_attention(k1, cfg, dtype),
                "ln2": L.init_rmsnorm(d, dtype),
                "mlp": L.init_mlp(k2, d, cfg.d_ff, dtype),
            }

        def one_group(k):
            k1, k2 = jax.random.split(k)
            return {
                "rec": _stack_init(one_rec, k1, n_rec),
                "attn": one_attn(k2),
            }
        params["blocks"] = _stack_init(one_group, keys[2], groups)
    else:
        raise ValueError(f"unknown family {fam}")
    return params


# ---------------------------------------------------------------------------
# full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def _dense_block(p, x, cfg, positions, *, causal, window):
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    x = x + L.attention_forward(p["attn"], h, cfg, positions=positions,
                                causal=causal, window=window)
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in p:
        y, aux = MOE.moe_forward(p["moe"], h, top_k=cfg.moe.top_k,
                                 capacity_factor=cfg.moe.capacity_factor)
        x = x + y
    else:
        x = x + L.mlp_forward(p["mlp"], h)
    return x, aux


def _cross_block(p, x, cfg, vision_kv):
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    att = L.attention_forward(p["attn"], h, cfg, causal=False,
                              kv_override=vision_kv)
    x = x + jnp.tanh(p["gate_attn"]) * att
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    x = x + jnp.tanh(p["gate_mlp"]) * L.mlp_forward(p["mlp"], h)
    return x


def _vision_kv(p_cross, vision, cfg):
    """Project vision embeddings (B, V, d) to cross-attn K/V (per group).

    Returned un-expanded (n_kv heads); consumers expand per their GQA ratio.
    """
    k = jnp.einsum("bvd,dnh->bvnh", vision, p_cross["attn"]["wk"])
    v = jnp.einsum("bvd,dnh->bvnh", vision, p_cross["attn"]["wv"])
    return k, v


def _maybe_remat(fn, remat: bool):
    return jax.checkpoint(fn) if remat else fn


def forward(params, cfg: ArchConfig, tokens=None, *, frames=None, vision=None,
            remat: bool = False, window_override: Optional[int] = None):
    """Full-sequence forward. Returns (logits, aux_loss).

    tokens: (B, S) int32 — LM families.  frames: (B, S, stub_dim) — audio.
    vision: (B, V, d_model) — vlm patch embeddings (stub).
    window_override: force sliding-window attention width (long-context
    variant of dense archs).
    """
    fam = cfg.family
    if fam == "audio":
        x = jnp.einsum("bsf,fd->bsd", frames, params["frame_proj"])
        causal = False
    else:
        x = jnp.take(params["embed"], tokens, axis=0)
        causal = True
    x = constrain(x, "batch", "seq", "embed")
    b, s = x.shape[:2]
    positions = jnp.arange(s)
    window = window_override if window_override is not None else (
        cfg.local_window if cfg.attention == AttentionKind.SLIDING else 0)

    aux_total = jnp.zeros((), jnp.float32)
    if fam in ("dense", "moe", "audio"):
        def body(carry, p):
            x, aux = carry
            x, a = _dense_block(p, x, cfg, positions, causal=causal, window=window)
            return (x, aux + a), None
        (x, aux_total), _ = jax.lax.scan(_maybe_remat(body, remat),
                                         (x, aux_total), params["blocks"])

    elif fam == "vlm":
        def body(carry, p):
            x, aux = carry
            def inner(xc, ps):
                xc, _ = _dense_block(ps, xc, cfg, positions, causal=True, window=window)
                return xc, None
            x, _ = jax.lax.scan(inner, x, p["self"])
            vkv = _vision_kv(p["cross"], vision, cfg)
            x = _cross_block(p["cross"], x, cfg, vkv)
            return (x, aux), None
        (x, aux_total), _ = jax.lax.scan(_maybe_remat(body, remat),
                                         (x, aux_total), params["blocks"])

    elif fam == "ssm":
        def body(carry, p):
            x, aux = carry
            x = SSM.mlstm_forward(p["mlstm"], x, cfg.num_heads)
            x = SSM.slstm_forward(p["slstm"], x, cfg.num_heads)
            return (x, aux), None
        (x, aux_total), _ = jax.lax.scan(_maybe_remat(body, remat),
                                         (x, aux_total), params["blocks"])

    elif fam == "hybrid":
        def body(carry, p):
            x, aux = carry
            def inner(xc, ps):
                xc = RG.rglru_forward(ps["rglru"], xc, cfg.norm_eps)
                h = L.rmsnorm(ps["ln2"], xc, cfg.norm_eps)
                return xc + L.mlp_forward(ps["mlp"], h), None
            x, _ = jax.lax.scan(inner, x, p["rec"])
            pa = p["attn"]
            h = L.rmsnorm(pa["ln1"], x, cfg.norm_eps)
            x = x + L.attention_forward(pa["attn"], h, cfg, positions=positions,
                                        causal=True, window=cfg.local_window)
            h = L.rmsnorm(pa["ln2"], x, cfg.norm_eps)
            x = x + L.mlp_forward(pa["mlp"], h)
            return (x, aux), None
        (x, aux_total), _ = jax.lax.scan(_maybe_remat(body, remat),
                                         (x, aux_total), params["blocks"])
    else:
        raise ValueError(fam)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    return constrain(logits, "batch", "seq", "vocab"), aux_total


def features(params, cfg: ArchConfig, tokens=None, *, frames=None):
    """Pooled input-embedding features — the FD filter's embedding space.

    The KMeans-DRE filter (repro.core) operates on a cheap, model-independent
    feature space (paper §V-C uses pre-extracted features for complex data);
    pooling the embedding lookup gives every heterogeneous client a filter
    input without running its full backbone.
    """
    x = jnp.take(params["embed"], tokens, axis=0) if tokens is not None else frames
    return jnp.mean(x, axis=1)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def cross_entropy(logits, labels, *, z_loss: float = 0.0):
    """Token-mean CE in f32; labels < 0 are masked out."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    ce = lse - ll
    if z_loss:
        ce = ce + z_loss * jnp.square(lse)
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(ce * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def train_loss(params, cfg: ArchConfig, batch, *, remat: bool = False,
               aux_weight: float = 0.01):
    """batch: {tokens, labels[, vision, frames]} -> (loss, metrics)."""
    kwargs = {}
    if cfg.family == "vlm":
        kwargs["vision"] = batch["vision"]
    if cfg.family == "audio":
        logits, aux = forward(params, cfg, frames=batch["frames"], remat=remat)
    else:
        logits, aux = forward(params, cfg, batch["tokens"], remat=remat, **kwargs)
    ce = cross_entropy(logits, batch["labels"])
    loss = ce + aux_weight * aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# KV / state caches + decode
# ---------------------------------------------------------------------------

class DecodeCache(NamedTuple):
    kind: Any            # static pytree leaf-free marker not stored; see below
    data: Any


def init_cache(cfg: ArchConfig, batch: int, cache_len: int, dtype=jnp.bfloat16,
               *, window_override: Optional[int] = None, vision=None, params=None):
    """Build the decode cache pytree for `batch` rows and `cache_len` context.

    dense/moe : {k, v}: (L, B, C, n_kv, h) — C = cache_len, or the sliding
                window if window_override is set (ring buffer).
    vlm       : + cross-attn K/V precomputed from vision embeddings.
    ssm       : mLSTM matrix state + sLSTM scalar state per group.
    hybrid    : RG-LRU state + conv tail + local-window KV ring buffer.
    """
    h = cfg.resolved_head_dim
    nkv = cfg.num_kv_heads
    fam = cfg.family
    if fam in ("dense", "moe"):
        c = min(cache_len, window_override) if window_override else cache_len
        return {
            "k": jnp.zeros((cfg.num_layers, batch, c, nkv, h), dtype),
            "v": jnp.zeros((cfg.num_layers, batch, c, nkv, h), dtype),
        }
    if fam == "vlm":
        per = cfg.cross_attn_every
        groups = cfg.num_layers // per
        n_self = per - 1
        c = min(cache_len, window_override) if window_override else cache_len
        cache = {
            "k": jnp.zeros((groups, n_self, batch, c, nkv, h), dtype),
            "v": jnp.zeros((groups, n_self, batch, c, nkv, h), dtype),
        }
        if vision is not None and params is not None:
            def vk(pg):
                k, v = _vision_kv(pg["cross"], vision, cfg)
                return {"ck": k.astype(dtype), "cv": v.astype(dtype)}
            cache["cross"] = jax.vmap(vk)(params["blocks"])
        else:
            nv = cfg.num_vision_tokens
            cache["cross"] = {
                "ck": jnp.zeros((groups, batch, nv, nkv, h), dtype),
                "cv": jnp.zeros((groups, batch, nv, nkv, h), dtype),
            }
        return cache
    if fam == "ssm":
        groups = cfg.num_layers // 2
        hh = 2 * cfg.d_model // cfg.num_heads
        return {
            "mlstm_c": jnp.zeros((groups, batch, cfg.num_heads, hh, hh), jnp.float32),
            "mlstm_n": jnp.zeros((groups, batch, cfg.num_heads, hh), jnp.float32),
            "slstm_h": jnp.zeros((groups, batch, cfg.d_model), dtype),
            "slstm_c": jnp.zeros((groups, batch, cfg.d_model), jnp.float32),
            "slstm_n": jnp.zeros((groups, batch, cfg.d_model), jnp.float32),
            "slstm_m": jnp.full((groups, batch, cfg.d_model), -1e9, jnp.float32),
        }
    if fam == "hybrid":
        per = cfg.hybrid_period
        groups = cfg.num_layers // per
        w = min(cfg.local_window, cache_len)
        return {
            "rg_h": jnp.zeros((groups, per - 1, batch, cfg.d_model), jnp.float32),
            "rg_conv": jnp.zeros((groups, per - 1, batch, RG.CONV_W - 1, cfg.d_model), dtype),
            "k": jnp.zeros((groups, batch, w, nkv, h), dtype),
            "v": jnp.zeros((groups, batch, w, nkv, h), dtype),
        }
    raise ValueError(f"no decode cache for family {fam}")


def decode_step(params, cfg: ArchConfig, tokens, cache, pos, *,
                window_override: Optional[int] = None):
    """One decode step. tokens: (B, 1) int32; pos: scalar int32.

    Returns (logits (B, 1, V), new_cache).
    """
    fam = cfg.family
    x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, "batch", None, "embed")
    window = window_override or 0

    if fam in ("dense", "moe"):
        ring = window if (window and cache["k"].shape[2] == window) else 0

        def body(x, inp):
            p, ck, cv = inp
            h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
            att, nk, nv = L.attention_decode(p["attn"], h, cfg, ck, cv, pos,
                                             window=ring)
            x = x + att
            h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
            if "moe" in p:
                y, _ = MOE.moe_forward(p["moe"], h, top_k=cfg.moe.top_k)
                x = x + y
            else:
                x = x + L.mlp_forward(p["mlp"], h)
            return x, (nk, nv)

        x, (nk, nv) = jax.lax.scan(body, x, (params["blocks"], cache["k"], cache["v"]))
        new_cache = {"k": nk, "v": nv}

    elif fam == "vlm":
        ring = window if (window and cache["k"].shape[3] == window) else 0

        def body(x, inp):
            p, ck, cv, cross = inp

            def inner(x, si):
                ps, ck1, cv1 = si
                h = L.rmsnorm(ps["ln1"], x, cfg.norm_eps)
                att, nk, nv = L.attention_decode(ps["attn"], h, cfg, ck1, cv1,
                                                 pos, window=ring)
                x = x + att
                h = L.rmsnorm(ps["ln2"], x, cfg.norm_eps)
                return x + L.mlp_forward(ps["mlp"], h), (nk, nv)

            x, (nk, nv) = jax.lax.scan(inner, x, (p["self"], ck, cv))
            pc = p["cross"]
            h = L.rmsnorm(pc["ln1"], x, cfg.norm_eps)
            q = jnp.einsum("bsd,dnh->bsnh", h, pc["attn"]["wq"])
            if "bq" in pc["attn"]:
                q = q + pc["attn"]["bq"]
            # cross KV layout: (B, num_vision_tokens, n_kv, h) — mask over
            # the vision-token axis (axis 1)
            mask = jnp.ones((1, 1, 1, cross["ck"].shape[1]), bool)
            nrep = cfg.num_heads // cfg.num_kv_heads
            att = L.attention_scores(q,
                                     L._expand_kv(cross["ck"].astype(q.dtype), nrep),
                                     L._expand_kv(cross["cv"].astype(q.dtype), nrep),
                                     mask)
            att = jnp.einsum("bsnh,nhd->bsd", att, pc["attn"]["wo"])
            x = x + jnp.tanh(pc["gate_attn"]) * att
            h = L.rmsnorm(pc["ln2"], x, cfg.norm_eps)
            x = x + jnp.tanh(pc["gate_mlp"]) * L.mlp_forward(pc["mlp"], h)
            return x, (nk, nv)

        x, (nk, nv) = jax.lax.scan(
            body, x, (params["blocks"], cache["k"], cache["v"], cache["cross"]))
        new_cache = dict(cache, k=nk, v=nv)

    elif fam == "ssm":
        def body(x, inp):
            p, mc, mn, sh, sc, sn, sm = inp
            x, mst = SSM.mlstm_decode(p["mlstm"], x, SSM.MLSTMState(mc, mn),
                                      cfg.num_heads)
            x, sst = SSM.slstm_decode(p["slstm"], x,
                                      SSM.SLSTMState(sh, sc, sn, sm),
                                      cfg.num_heads)
            return x, (mst.c, mst.n, sst.h, sst.c, sst.n, sst.m)

        x, (mc, mn, sh, sc, sn, sm) = jax.lax.scan(
            body, x, (params["blocks"], cache["mlstm_c"], cache["mlstm_n"],
                      cache["slstm_h"], cache["slstm_c"], cache["slstm_n"],
                      cache["slstm_m"]))
        new_cache = {"mlstm_c": mc, "mlstm_n": mn, "slstm_h": sh,
                     "slstm_c": sc, "slstm_n": sn, "slstm_m": sm}

    elif fam == "hybrid":
        w = cache["k"].shape[2]

        def body(x, inp):
            p, rh, rconv, ck, cv = inp

            def inner(x, ri):
                ps, h0, c0 = ri
                x, st = RG.rglru_decode(ps["rglru"], x, RG.RGLRUState(h0, c0),
                                        cfg.norm_eps)
                h = L.rmsnorm(ps["ln2"], x, cfg.norm_eps)
                x = x + L.mlp_forward(ps["mlp"], h)
                return x, (st.h, st.conv)

            x, (nh, nconv) = jax.lax.scan(inner, x, (p["rec"], rh, rconv))
            pa = p["attn"]
            h = L.rmsnorm(pa["ln1"], x, cfg.norm_eps)
            att, nk, nv = L.attention_decode(pa["attn"], h, cfg, ck, cv, pos,
                                             window=w)
            x = x + att
            h = L.rmsnorm(pa["ln2"], x, cfg.norm_eps)
            x = x + L.mlp_forward(pa["mlp"], h)
            return x, (nh, nconv, nk, nv)

        x, (nh, nconv, nk, nv) = jax.lax.scan(
            body, x, (params["blocks"], cache["rg_h"], cache["rg_conv"],
                      cache["k"], cache["v"]))
        new_cache = {"rg_h": nh, "rg_conv": nconv, "k": nk, "v": nv}
    else:
        raise ValueError(f"decode unsupported for family {fam}")

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    return constrain(logits, "batch", None, "vocab"), new_cache
