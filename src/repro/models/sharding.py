"""MaxText-style logical-axis sharding annotations.

Model code annotates activations with *logical* axis names
(``constrain(x, "batch", None, "model_ff")``). The launcher installs a
logical→mesh-axis mapping (``set_logical_rules``) before tracing; outside a
mesh context the annotation is a no-op, so the same model code runs on a
single CPU device in tests and fully sharded in the dry-run.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Union

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()

# logical axis name -> mesh axis name (or tuple of mesh axes, or None)
DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ff": "model",
    "vocab": "model",
    "experts": "model",
    "kv_seq": None,
    "vision_seq": None,
}

# beyond-paper sharding profiles (EXPERIMENTS.md §Perf):
#   2d  — baseline: batch over (pod,data), tensor-parallel over model, FSDP
#         params over data. General-purpose, collective-heavy for small models.
#   dp  — pure data parallel: batch over EVERY axis, params replicated.
#         Kills all TP activation collectives; only grad all-reduce remains.
#         Small models only (params must fit one device).
#   tp  — tensor parallel without FSDP: params sharded over model only,
#         batch over (pod,data). No per-step param gathers — decode's friend.
PROFILES = {
    "2d": DEFAULT_RULES,
    "dp": {**{k: None for k in DEFAULT_RULES},
           "batch": ("pod", "data", "model")},
    "tp": DEFAULT_RULES,
}


def set_logical_rules(rules: Optional[dict], mesh=None) -> None:
    _state.rules = rules
    _state.mesh = mesh


@contextlib.contextmanager
def logical_rules(rules: Optional[dict], mesh=None):
    """Scoped ``set_logical_rules``: installs (rules, mesh) for the duration
    of the block and restores the previous mapping on exit. Engines that own
    a private mesh (e.g. the cohort engine's 1-D client mesh) wrap their
    jitted-call sites in this so traces triggered inside pick up the right
    rules without leaking them into unrelated code."""
    prev = (getattr(_state, "rules", None), getattr(_state, "mesh", None))
    set_logical_rules(rules, mesh)
    try:
        yield
    finally:
        set_logical_rules(*prev)


def get_mesh():
    return getattr(_state, "mesh", None)


def _resolve(axis: Optional[str], rules: dict, mesh_axes) -> Optional[Union[str, tuple]]:
    if axis is None:
        return None
    m = rules.get(axis, None)
    if m is None:
        return None
    if isinstance(m, tuple):
        kept = tuple(a for a in m if a in mesh_axes)
        return kept if kept else None
    return m if m in mesh_axes else None


def logical_spec(*axes: Optional[str]) -> Optional[P]:
    rules = getattr(_state, "rules", None)
    mesh = getattr(_state, "mesh", None)
    if rules is None or mesh is None:
        return None
    mesh_axes = set(mesh.axis_names)
    return P(*[_resolve(a, rules, mesh_axes) for a in axes])


def constrain(x, *axes: Optional[str]):
    """Apply a sharding constraint if a mesh/rule set is installed."""
    spec = logical_spec(*axes)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(getattr(_state, "mesh"), spec))


def gather_fsdp(params_subtree):
    """Explicit ZeRO-3 weight gathering (EXPERIMENTS.md §Perf pair A).

    Called INSIDE the traced layer body: constrains every weight leaf to its
    name-aware spec with the 'data' (FSDP) axis removed. GSPMD then
    materialises one weight all-gather per use (537 MB for llama-405B wq)
    instead of re-sharding the residual activations (4.3 GB f32, measured) —
    the cost model picks the activation path without this hint. No-op when
    no mesh is installed or FSDP is off (specs match).
    """
    mesh = getattr(_state, "mesh", None)
    if mesh is None:
        return params_subtree
    from repro.launch.mesh import param_spec  # local import: no cycle at load

    def leaf(path, w):
        name = next((str(p.key) for p in reversed(path)
                     if hasattr(p, "key")), None)
        spec = param_spec(w.shape, mesh, n_stack_axes=0, fsdp=False,
                          name=name)
        return jax.lax.with_sharding_constraint(
            w, jax.sharding.NamedSharding(mesh, spec))

    return jax.tree_util.tree_map_with_path(leaf, params_subtree)
