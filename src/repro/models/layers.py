"""Transformer building blocks: RMSNorm, RoPE, GQA attention, SwiGLU MLP.

Pure-JAX pytree modules (init_* / apply pairs). All matmuls are einsums with
explicit head axes so GSPMD can shard heads / d_ff on the ``model`` mesh
axis. Supports full-causal, sliding-window, local (block) and bidirectional
(encoder) attention, plus single-token decode against a KV cache.
"""
from __future__ import annotations

import math
import threading
from typing import Optional

import jax
import jax.numpy as jnp

from repro.common.pytree import init_dense
from repro.models.sharding import constrain

NEG_INF = -1e30  # large-negative in f32; avoids NaN from (-inf) - (-inf)

# ---------------------------------------------------------------------------
# attention execution options (beyond-paper perf levers; EXPERIMENTS.md §Perf)
# ---------------------------------------------------------------------------
_opts = threading.local()


def set_attention_options(*, chunk_q: int = 0, bf16_psum: bool = False) -> None:
    """chunk_q > 0 enables flash-style query-chunked attention: the (S, S)
    score matrix is never materialised — scores are computed per (chunk_q, S)
    tile inside a lax.scan (the XLA-expressible analogue of the Pallas
    flash_attention kernel, usable inside the pjit'd train step).

    bf16_psum forces bf16 output on the projections whose results are
    partial-summed across the model axis (attention out-proj, MLP down-proj,
    MoE dispatch/combine): without it XLA keeps the f32 dot accumulator
    alive across the all-reduce, doubling TP collective bytes (§Perf)."""
    _opts.chunk_q = chunk_q
    _opts.bf16_psum = bf16_psum


def get_chunk_q() -> int:
    return getattr(_opts, "chunk_q", 0)


def psum_dtype(dtype):
    return jnp.bfloat16 if getattr(_opts, "bf16_psum", False) else None


def psum_einsum(spec, a, b):
    """einsum for partial-sum-producing projections (bf16-psum aware)."""
    pt = psum_dtype(a.dtype)
    if pt is not None:
        return jnp.einsum(spec, a, b, preferred_element_type=pt)
    return jnp.einsum(spec, a, b)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * p["scale"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_angles(positions, head_dim: int, theta: float):
    """positions: (...,) int32 -> cos/sin of shape (..., head_dim//2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, D); cos/sin: (S, D//2) or (B, S, D//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:  # (S, half) -> broadcast over batch and heads
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:  # (B, S, half)
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    return jnp.concatenate([out1, out2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def init_attention(key, cfg, dtype=jnp.float32):
    d, h = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    kq, kk, kv, ko = jax.random.split(key, 4)
    std = 1.0 / math.sqrt(d)
    p = {
        "wq": (jax.random.normal(kq, (d, nq, h)) * std).astype(dtype),
        "wk": (jax.random.normal(kk, (d, nkv, h)) * std).astype(dtype),
        "wv": (jax.random.normal(kv, (d, nkv, h)) * std).astype(dtype),
        "wo": (jax.random.normal(ko, (nq, h, d)) * (std / math.sqrt(cfg.num_layers))).astype(dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nq, h), dtype)
        p["bk"] = jnp.zeros((nkv, h), dtype)
        p["bv"] = jnp.zeros((nkv, h), dtype)
    return p


def _qkv(p, x):
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"])
    k = jnp.einsum("bsd,dnh->bsnh", x, p["wk"])
    v = jnp.einsum("bsd,dnh->bsnh", x, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    return q, k, v


def _expand_kv(k, n_rep: int):
    """(B, S, n_kv, h) -> (B, S, n_kv*n_rep, h) by repeat (GQA)."""
    if n_rep == 1:
        return k
    b, s, nkv, h = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, nkv, n_rep, h))
    return k.reshape(b, s, nkv * n_rep, h)


def attention_scores(q, k, v, mask):
    """q: (B,Sq,N,H) k,v: (B,Sk,N,H) mask: broadcastable to (B,N,Sq,Sk)."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    logits = jnp.einsum("bqnh,bknh->bnqk", q, k).astype(jnp.float32) * scale
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bnqk,bknh->bqnh", probs, v)


def make_mask(sq: int, sk: int, *, causal: bool, window: int = 0,
              q_offset: int = 0):
    """Boolean mask (1, 1, sq, sk). window>0 = sliding causal window."""
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(sk)[None, :]
    if causal:
        m = kpos <= qpos
        if window > 0:
            m = m & (kpos > qpos - window)
    else:
        m = jnp.ones((sq, sk), bool)
    return m[None, None]


def chunked_attention(q, k, v, *, causal: bool, window: int = 0,
                      chunk_q: int = 512):
    """Query-chunked attention: lax.scan over q tiles; per-step memory is
    (B, N, C, Sk) instead of (B, N, Sq, Sk). q/k/v: (B, S, N, h)."""
    b, sq, n, h = q.shape
    sk = k.shape[1]
    c = min(chunk_q, sq)
    pad = (-sq) % c
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (sq + pad) // c
    qs = q.reshape(b, nc, c, n, h)
    scale = 1.0 / math.sqrt(h)
    kpos = jnp.arange(sk)[None, :]

    def body(_, inp):
        qc, ci = inp                                    # (b, c, n, h), scalar
        logits = jnp.einsum("bqnh,bknh->bnqk", qc, k).astype(jnp.float32) * scale
        qpos = ci * c + jnp.arange(c)[:, None]
        if causal:
            m = kpos <= qpos
            if window > 0:
                m = m & (kpos > qpos - window)
        else:
            m = jnp.ones((c, sk), bool)
        logits = jnp.where(m[None, None], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        oc = jnp.einsum("bnqk,bknh->bqnh", probs, v)
        return None, oc

    _, os_ = jax.lax.scan(body, None, (jnp.moveaxis(qs, 1, 0), jnp.arange(nc)))
    out = jnp.moveaxis(os_, 0, 1).reshape(b, sq + pad, n, h)
    return out[:, :sq]


def attention_forward(p, x, cfg, *, positions=None, causal=True,
                      window: int = 0, kv_override=None):
    """Full-sequence attention. kv_override: (k, v) for cross-attention."""
    b, s, d = x.shape
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    q, k, v = _qkv(p, x)
    if kv_override is not None:
        k, v = kv_override
    elif positions is not None:
        cos, sin = rope_angles(positions, cfg.resolved_head_dim, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    q = constrain(q, "batch", "seq", "heads", "head_dim")
    k = constrain(k, "batch", "seq", "kv_heads", "head_dim")
    kk = _expand_kv(k, nq // nkv)
    vv = _expand_kv(v, nq // nkv)
    chunk = get_chunk_q()
    if chunk and s > chunk:
        o = chunked_attention(q, kk, vv, causal=causal, window=window,
                              chunk_q=chunk)
    else:
        # dispatched: jnp route == the historical make_mask+attention_scores
        # sequence bit-for-bit; pallas route = the fused flash kernel
        from repro.kernels import dispatch
        o = dispatch.flash_attention(q, kk, vv, causal=causal, window=window)
    o = constrain(o, "batch", "seq", "heads", "head_dim")
    out = psum_einsum("bsnh,nhd->bsd", o, p["wo"])
    return constrain(out, "batch", "seq", "embed")


def attention_decode(p, x, cfg, cache_k, cache_v, pos, *, window: int = 0):
    """Single-token decode. x: (B, 1, d); cache_k/v: (B, S_cache, n_kv, h);
    pos: scalar int32 current position. Returns (out, new_k, new_v).

    Grouped-query einsum — the KV cache is NEVER expanded to n_q heads
    (materialising the (B, S, N, h) broadcast gathered the whole seq-sharded
    cache: 172 GB/step measured on llama-3.2-vision decode_32k, §Perf D).
    """
    b = x.shape[0]
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    nrep = nq // nkv
    q, k, v = _qkv(p, x)
    s_cache = cache_k.shape[1]
    if window > 0:
        # ring-buffer write for sliding-window caches
        slot = jnp.mod(pos, s_cache)
    else:
        slot = pos
    cos, sin = rope_angles(jnp.array([pos]), cfg.resolved_head_dim, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    new_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype),
                                         (0, slot, 0, 0))
    new_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype),
                                         (0, slot, 0, 0))
    kpos = jnp.arange(s_cache)
    if window > 0:
        # every ring slot is valid once pos >= s_cache; before that only <= pos
        valid = jnp.where(pos >= s_cache, jnp.ones_like(kpos, bool), kpos <= pos)
    else:
        valid = kpos <= pos
    h = q.shape[-1]
    qg = q.reshape(b, 1, nkv, nrep, h)
    scale = 1.0 / math.sqrt(h)
    logits = jnp.einsum("bqgrh,bkgh->bgrqk", qg,
                        new_k.astype(qg.dtype)).astype(jnp.float32) * scale
    logits = jnp.where(valid[None, None, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    og = jnp.einsum("bgrqk,bkgh->bqgrh", probs, new_v.astype(x.dtype))
    o = og.reshape(b, 1, nq, h)
    out = jnp.einsum("bsnh,nhd->bsd", o, p["wo"])
    return constrain(out, "batch", None, "embed"), new_k, new_v


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, dtype=jnp.float32):
    kg, ku, kd = jax.random.split(key, 3)
    std = 1.0 / math.sqrt(d_model)
    return {
        "wg": (jax.random.normal(kg, (d_model, d_ff)) * std).astype(dtype),
        "wu": (jax.random.normal(ku, (d_model, d_ff)) * std).astype(dtype),
        "wd": (jax.random.normal(kd, (d_ff, d_model)) * (1.0 / math.sqrt(d_ff))).astype(dtype),
    }


def mlp_forward(p, x):
    g = jnp.einsum("bsd,df->bsf", x, p["wg"])
    u = jnp.einsum("bsd,df->bsf", x, p["wu"])
    h = jax.nn.silu(g) * u
    h = constrain(h, "batch", "seq", "ff")
    out = psum_einsum("bsf,fd->bsd", h, p["wd"])
    return constrain(out, "batch", "seq", "embed")
