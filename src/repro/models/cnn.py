"""The paper's heterogeneous client CNNs (Tables I and II).

Ten distinct MNIST/FashionMNIST architectures and ten CIFAR-10 architectures,
one per client — system heterogeneity is the point of feature-based FD (each
client deploys a model matched to its resources; only logits are exchanged).

Implemented faithfully from Table I. Table II's extraction in the provided
paper text is partially garbled (OCR); we reconstruct ten VGG-style variants
consistent with the legible rows (see DESIGN.md §7). Each model is an
(init, apply) pair over NHWC inputs; apply returns logits (B, num_classes).

Conv blocks follow the FedMD-style reference implementations: conv → relu →
maxpool(2) for 5x5 kernels (LeNet lineage) and conv → [bn] → relu with
padding for 3x3 stacks, flatten, then the listed Linear stack.
"""
from __future__ import annotations

import functools
import math
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.common.pytree import init_conv, init_dense


def _conv2d(p, x, *, stride=1, padding="VALID"):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def _maxpool(x, k=2):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, k, k, 1), "VALID")


def _batchnorm_init(c):
    return {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,)),
            "mean": jnp.zeros((c,)), "var": jnp.ones((c,))}


def _batchnorm(p, x, train: bool):
    # inference-style BN using tracked stats; training updates are handled
    # by the fed trainer via momentum on batch stats (kept simple: use batch
    # stats when train=True).
    if train:
        mean = jnp.mean(x, axis=(0, 1, 2))
        var = jnp.var(x, axis=(0, 1, 2))
    else:
        mean, var = p["mean"], p["var"]
    inv = jax.lax.rsqrt(var + 1e-5)
    return (x - mean) * inv * p["scale"] + p["bias"]


class Spec:
    """Declarative layer list -> (init, apply)."""

    def __init__(self, layers: Sequence[tuple], num_classes: int = 10):
        self.layers = layers
        self.num_classes = num_classes

    def init(self, key, input_hw: int, channels: int):
        params = []
        k = key
        h = w = input_hw
        c = channels
        flat = None
        for spec in self.layers:
            k, sub = jax.random.split(k)
            kind = spec[0]
            if kind == "conv":
                _, cout, ksz, pool, pad = spec
                params.append(init_conv(sub, c, cout, ksz))
                if pad == "SAME":
                    pass
                else:
                    h, w = h - ksz + 1, w - ksz + 1
                if pool:
                    h, w = h // 2, w // 2
                c = cout
                flat = h * w * c
            elif kind == "bn":
                params.append(_batchnorm_init(c))
            elif kind == "linear":
                _, dout = spec
                din = flat if flat is not None else c
                params.append(init_dense(sub, din, dout, bias=True))
                flat = dout
        return params

    def apply(self, params, x, train: bool = False):
        """x: (B, H, W, C) -> logits (B, num_classes)."""
        i = 0
        flat_done = False
        for spec in self.layers:
            p = params[i]
            kind = spec[0]
            if kind == "conv":
                _, cout, ksz, pool, pad = spec
                x = _conv2d(p, x, padding=pad)
                x = jax.nn.relu(x)
                if pool:
                    x = _maxpool(x)
            elif kind == "bn":
                x = _batchnorm(p, x, train)
            elif kind == "linear":
                if not flat_done:
                    x = x.reshape(x.shape[0], -1)
                    flat_done = True
                x = x @ p["w"] + p["b"]
                if spec[1] != self.num_classes:
                    x = jax.nn.relu(x)
            i += 1
        return x


def C(cout, k, pool=True, pad="VALID"):
    return ("conv", cout, k, pool, pad)


def BN():
    return ("bn",)


def Lin(d):
    return ("linear", d)


# --------------------------------------------------------------------------
# Table I — MNIST / FashionMNIST clients (28x28x1)
# --------------------------------------------------------------------------
MNIST_CLIENTS: list[Spec] = [
    Spec([C(10, 5), C(20, 5), Lin(50), Lin(10)]),                       # 1
    Spec([C(16, 3), C(32, 3), C(64, 3, pool=False), Lin(50), Lin(10)]), # 2
    Spec([C(10, 5), C(20, 5), Lin(50), Lin(10)]),                       # 3
    Spec([C(12, 3), C(24, 3), C(48, 3, pool=False), Lin(100), Lin(50), Lin(10)]),  # 4
    Spec([C(8, 5), C(16, 5), Lin(100), Lin(50), Lin(10)]),              # 5
    Spec([C(6, 7), C(12, 5), Lin(50), Lin(10)]),                        # 6
    Spec([C(32, 3, pool=False), C(64, 3, pool=False), Lin(50), Lin(10)]),  # 7
    Spec([C(20, 5), C(30, 5), Lin(50), Lin(10)]),                       # 8
    Spec([C(8, 5), C(16, 5), Lin(64), Lin(32), Lin(10)]),               # 9
    Spec([C(16, 3), C(32, 3), C(64, 3), Lin(100), Lin(10)]),            # 10
]

# --------------------------------------------------------------------------
# Table II — CIFAR-10 clients (32x32x3); VGG-style with BatchNorm
# --------------------------------------------------------------------------
CIFAR_CLIENTS: list[Spec] = [
    Spec([C(64, 3, pad="SAME"), BN(), C(128, 3, pad="SAME"), BN(),
          C(256, 3, pool=False, pad="SAME"), BN(), Lin(512), Lin(10)]),
    Spec([C(64, 3, pad="SAME"), BN(), C(128, 3, pad="SAME"), BN(),
          C(128, 3, pool=False, pad="SAME"), BN(),
          C(256, 3, pad="SAME"), BN(), Lin(512), Lin(10)]),
    Spec([C(64, 5, pad="SAME"), BN(), C(128, 5, pad="SAME"), BN(),
          Lin(256), Lin(10)]),
    Spec([C(64, 3, pad="SAME"), BN(), C(128, 3, pad="SAME"), BN(),
          C(256, 3, pad="SAME"), BN(), C(512, 3, pool=False, pad="SAME"), BN(),
          Lin(512), Lin(10)]),
    Spec([C(32, 3, pad="SAME"), BN(), C(64, 3, pad="SAME"), BN(),
          C(128, 3, pad="SAME"), BN(), Lin(256), Lin(10)]),
    Spec([C(32, 3, pad="SAME"), BN(), C(64, 3, pad="SAME"), BN(),
          C(128, 3, pad="SAME"), BN(), C(256, 3, pool=False, pad="SAME"), BN(),
          Lin(512), Lin(10)]),
    Spec([C(64, 3, pad="SAME"), BN(), C(128, 3, pad="SAME"), BN(),
          C(256, 3, pool=False, pad="SAME"), BN(), Lin(1024), Lin(10)]),
    Spec([C(64, 3, pad="SAME"), BN(), C(128, 3, pad="SAME"), BN(),
          Lin(512), Lin(10)]),
    Spec([C(64, 3, pad="SAME"), BN(), C(128, 3, pad="SAME"), BN(),
          C(128, 3, pool=False, pad="SAME"), BN(),
          Lin(512), Lin(256), Lin(10)]),
    Spec([C(64, 3, pad="SAME"), BN(), C(128, 3, pad="SAME"), BN(),
          C(256, 3, pad="SAME"), BN(), Lin(1024), Lin(10)]),
]


def get_client_model(idx: int, dataset: str = "mnist"):
    """Returns (spec, input_hw, channels) for client idx (0-based)."""
    if dataset in ("mnist", "fashionmnist"):
        return MNIST_CLIENTS[idx % 10], 28, 1
    if dataset in ("cifar10",):
        return CIFAR_CLIENTS[idx % 10], 32, 3
    raise ValueError(dataset)


class MLPClassifier:
    """Small MLP for pre-extracted-feature experiments (CIFAR10* mode)."""

    def __init__(self, d_in: int, hidden: Sequence[int] = (256, 128),
                 num_classes: int = 10):
        self.dims = [d_in, *hidden, num_classes]

    def init(self, key):
        params = []
        for i in range(len(self.dims) - 1):
            key, sub = jax.random.split(key)
            params.append(init_dense(sub, self.dims[i], self.dims[i + 1], bias=True))
        return params

    def apply(self, params, x, train: bool = False):
        for i, p in enumerate(params):
            x = x @ p["w"] + p["b"]
            if i < len(params) - 1:
                x = jax.nn.relu(x)
        return x
