"""KMeans in pure JAX: k-means++ seeding + Lloyd iterations via lax.scan.

The paper's KMeans-DRE learns centroid positions from a client's private
data (Algorithm 1 line 3). Time O(k·n·c·d), space O(c·d + n) — Table IV.

The assignment step is the compute hot-spot; ``repro.kernels.kmeans_dist``
provides the Pallas TPU kernel for it (matmul-form distances, fused argmin
+ per-centroid accumulation). ``kmeans_fit``/``kmeans_fit_batched`` route
through the kernel when the resolved ``kernel_backend`` is ``"pallas"``
(``repro.kernels.dispatch``); the default jnp path below is kept inline
and op-for-op unchanged — the default-backend bit-for-bit guarantee rides
on it.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
# canonical impl moved to the dispatch layer; re-exported for importers
from repro.kernels.dispatch import pairwise_sq_dists as pairwise_sq_dists


class KMeansResult(NamedTuple):
    centroids: jax.Array     # (c, d)
    assignments: jax.Array   # (n,) int32
    inertia: jax.Array       # scalar — sum of squared distances
    n_iter: jax.Array        # iterations executed


def kmeans_plus_plus(key, x, k: int):
    """k-means++ seeding (faithful to sklearn's default, which the paper uses)."""
    n = x.shape[0]
    k0, key = jax.random.split(key)
    first = jax.random.randint(k0, (), 0, n)
    centroids = jnp.zeros((k, x.shape[1]), x.dtype).at[0].set(x[first])

    def body(carry, i):
        centroids, key, min_d2 = carry
        d2 = jnp.sum(jnp.square(x - centroids[i - 1]), axis=-1)
        min_d2 = jnp.minimum(min_d2, d2)
        key, sub = jax.random.split(key)
        probs = min_d2 / jnp.maximum(jnp.sum(min_d2), 1e-12)
        nxt = jax.random.choice(sub, n, p=probs)
        centroids = centroids.at[i].set(x[nxt])
        return (centroids, key, min_d2), None

    if k > 1:
        init_d2 = jnp.full((n,), jnp.inf, x.dtype)
        (centroids, _, _), _ = jax.lax.scan(
            body, (centroids, key, init_d2), jnp.arange(1, k))
    return centroids


@partial(jax.jit, static_argnames=("k", "max_iter"))
def _kmeans_fit_jnp(key, x, k: int, max_iter: int, tol):
    """Reference Lloyd's algorithm — the historical ``kmeans_fit`` body,
    unchanged (two matmuls per step: distances, then the (n, k) one-hot
    scatter ``one_hot.T @ x``)."""
    x = x.astype(jnp.float32)
    n, d = x.shape
    init = kmeans_plus_plus(key, x, k)

    def step(carry, _):
        cents, done, iters = carry
        d2 = pairwise_sq_dists(x, cents)
        assign = jnp.argmin(d2, axis=-1)
        one_hot = jax.nn.one_hot(assign, k, dtype=jnp.float32)
        counts = jnp.sum(one_hot, axis=0)                       # (k,)
        sums = one_hot.T @ x                                    # (k, d)
        new = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1.0),
                        cents)
        shift = jnp.sum(jnp.square(new - cents))
        new_done = done | (shift < tol)
        cents = jnp.where(done, cents, new)
        iters = iters + jnp.where(done, 0, 1)
        return (cents, new_done, iters), None

    (cents, _, iters), _ = jax.lax.scan(
        step, (init, jnp.bool_(False), jnp.int32(0)), None, length=max_iter)
    d2 = pairwise_sq_dists(x, cents)
    assign = jnp.argmin(d2, axis=-1).astype(jnp.int32)
    inertia = jnp.sum(jnp.min(d2, axis=-1))
    return KMeansResult(cents, assign, inertia, iters)


@partial(jax.jit, static_argnames=("k", "max_iter"))
def _kmeans_fit_batched_jnp(keys, xs, k: int, max_iter: int, tol):
    return jax.vmap(
        lambda kk, xx: _kmeans_fit_jnp(kk, xx, k, max_iter, tol))(keys, xs)


@partial(jax.jit, static_argnames=("k", "max_iter"))
def _kmeans_fit_pallas(keys, xs, k: int, max_iter: int, tol):
    """Fused-Lloyd fit over a stacked client axis: keys (C, …), xs (C, n, d).

    Each scan step is one ``lloyd_step`` kernel call — the client axis is a
    grid dimension, so the cohort engines' vmapped DRE fit compiles once
    for any C instead of retracing per client, and the (n, k) one-hot /
    second matmul of the reference body never materialise.
    """
    from repro.kernels.kmeans_dist import ops as kd_ops

    xs = xs.astype(jnp.float32)
    c = xs.shape[0]
    init = jax.vmap(lambda kk, xx: kmeans_plus_plus(kk, xx, k))(keys, xs)

    def step(carry, _):
        cents, done, iters = carry
        _, _, sums, counts = kd_ops.lloyd_step(xs, cents)
        new = jnp.where(counts[..., None] > 0,
                        sums / jnp.maximum(counts[..., None], 1.0), cents)
        shift = jnp.sum(jnp.square(new - cents), axis=(-2, -1))
        new_done = done | (shift < tol)
        cents = jnp.where(done[..., None, None], cents, new)
        iters = iters + jnp.where(done, 0, 1)
        return (cents, new_done, iters), None

    (cents, _, iters), _ = jax.lax.scan(
        step, (init, jnp.zeros((c,), bool), jnp.zeros((c,), jnp.int32)),
        None, length=max_iter)
    assign, min_d2, _, _ = kd_ops.lloyd_step(xs, cents)
    inertia = jnp.sum(min_d2, axis=-1)
    return KMeansResult(cents, assign, inertia, iters)


def kmeans_fit(key, x, k: int, max_iter: int = 50, tol: float = 1e-6, *,
               backend: Optional[str] = None):
    """Lloyd's algorithm. x: (n, d) -> KMeansResult. Runs a fixed-shape scan
    with a convergence flag (jit-stable; converged iterations are no-ops).

    ``backend`` selects the assignment-step implementation via
    ``repro.kernels.dispatch`` (None/"auto" = ambient policy): "pallas"
    fuses distances + argmin + per-centroid accumulation in one kernel,
    "jnp" is the reference two-matmul body.
    """
    if dispatch.resolve(backend) == "pallas":
        res = _kmeans_fit_pallas(jnp.asarray(key)[None],
                                 jnp.asarray(x)[None], k, max_iter, tol)
        return KMeansResult(*(leaf[0] for leaf in res))
    return _kmeans_fit_jnp(key, x, k, max_iter, tol)


def kmeans_fit_batched(keys, xs, k: int, max_iter: int = 50, tol: float = 1e-6,
                       *, backend: Optional[str] = None):
    """Fit one KMeans per leading-axis slice in a single compiled call.

    keys: (C, 2) PRNG keys; xs: (C, n, d) stacked per-client data (same n and
    k for every slice — the cohort engine's homogeneity rule). Returns a
    ``KMeansResult`` whose fields carry a leading client axis. Equivalent to
    looping ``kmeans_fit`` per slice (same keys ⇒ same seeding draws), which
    ``tests/test_dre_contract.py`` checks. On the "pallas" backend the
    client axis is a kernel grid dimension (one trace for any C).
    """
    if dispatch.resolve(backend) == "pallas":
        return _kmeans_fit_pallas(jnp.asarray(keys), jnp.asarray(xs),
                                  k, max_iter, tol)
    return _kmeans_fit_batched_jnp(keys, xs, k, max_iter, tol)


def min_dist_to_centroids(x, centroids):
    """Euclidean distance of each row of x to its nearest centroid."""
    d2 = pairwise_sq_dists(x.astype(jnp.float32), centroids.astype(jnp.float32))
    return jnp.sqrt(jnp.min(d2, axis=-1))
