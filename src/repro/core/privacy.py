"""Pre-distillation privacy counter-measure (paper §V-D, listed as future
work — implemented here as a beyond-paper feature).

The Gaussian mechanism on shared proxy data: each client perturbs its proxy
contribution with N(0, σ²) noise calibrated to an (ε, δ) budget via the
analytic Gaussian mechanism bound  σ ≥ Δ₂ · sqrt(2 ln(1.25/δ)) / ε,
where the L2 sensitivity Δ₂ is taken as the per-sample feature-space
clipping norm. This trades filter/teacher quality for a reconstruction
bound on the released proxy samples; benchmarks/fig5_sweeps-style noise
sweeps quantify the accuracy cost.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class DPParams(NamedTuple):
    epsilon: float
    delta: float
    clip_norm: float
    sigma: float        # resulting noise std


def gaussian_sigma(epsilon: float, delta: float, clip_norm: float) -> float:
    """Analytic Gaussian mechanism noise scale (Dwork & Roth Thm A.1)."""
    if epsilon <= 0:
        raise ValueError("epsilon must be positive")
    return clip_norm * math.sqrt(2.0 * math.log(1.25 / delta)) / epsilon


def make_dp(epsilon: float, delta: float = 1e-5,
            clip_norm: float = 1.0) -> DPParams:
    return DPParams(epsilon, delta, clip_norm,
                    gaussian_sigma(epsilon, delta, clip_norm))


def clip_samples(x, clip_norm: float):
    """Per-sample L2 clipping in flattened feature space."""
    flat = x.reshape(x.shape[0], -1)
    norms = jnp.linalg.norm(flat, axis=1, keepdims=True)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(norms, 1e-12))
    return (flat * scale).reshape(x.shape)


def privatize_proxy(key, x, dp: DPParams):
    """Clip + add Gaussian noise: the released proxy subset."""
    clipped = clip_samples(jnp.asarray(x, jnp.float32), dp.clip_norm)
    noise = dp.sigma * jax.random.normal(key, clipped.shape)
    return clipped + noise


def privatize_proxy_np(rng: np.random.Generator, x: np.ndarray,
                       dp: DPParams) -> np.ndarray:
    """NumPy variant for the data-pipeline side (proxy.build_proxy hook)."""
    flat = x.reshape(len(x), -1).astype(np.float32)
    norms = np.linalg.norm(flat, axis=1, keepdims=True)
    flat = flat * np.minimum(1.0, dp.clip_norm / np.maximum(norms, 1e-12))
    flat = flat + dp.sigma * rng.standard_normal(flat.shape).astype(np.float32)
    return flat.reshape(x.shape)
