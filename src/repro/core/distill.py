"""Knowledge-distillation losses (Hinton et al.; Algorithm 1 line 41).

Clients distill from the server's aggregated ensemble logits ȳ over proxy
samples. Temperature-scaled KL is the standard FD objective; MSE-on-logits
is provided for the DS-FL-style variants. A per-sample weight vector lets
callers mask out proxy samples with no valid teacher (zero ID contributors).

``kd_kl_loss`` dispatches its per-sample KL to the fused Pallas kernel
(``repro.kernels.distill_kl`` — custom-VJP, so it is differentiable
through both the forward and the fused backward kernel) when the resolved
``kernel_backend`` is "pallas"; the jnp path below is kept inline and
op-for-op unchanged (default-backend bit-for-bit guarantee).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import dispatch


def kd_kl_loss(student_logits, teacher_logits, temperature: float = 3.0,
               sample_weight=None, *, backend: Optional[str] = None):
    """KL(teacher_T ∥ student_T) · T², mean over weighted samples.

    student_logits/teacher_logits: (..., K). Scaled by T² so gradient
    magnitudes match the CE loss (Hinton et al. 2014). ``backend`` routes
    the per-sample KL through ``repro.kernels.dispatch`` (None/"auto" =
    ambient policy).
    """
    t = temperature
    if dispatch.resolve(backend) == "pallas":
        lead = student_logits.shape[:-1]
        kl = dispatch.kd_kl_per_sample(
            student_logits.reshape(-1, student_logits.shape[-1]),
            teacher_logits.reshape(-1, teacher_logits.shape[-1]),
            t, backend="pallas").reshape(lead)
    else:
        sp = jax.nn.log_softmax(student_logits.astype(jnp.float32) / t, axis=-1)
        tp = jax.nn.softmax(teacher_logits.astype(jnp.float32) / t, axis=-1)
        tlogp = jax.nn.log_softmax(teacher_logits.astype(jnp.float32) / t, axis=-1)
        kl = jnp.sum(tp * (tlogp - sp), axis=-1) * (t * t)
    if sample_weight is None:
        return jnp.mean(kl)
    w = sample_weight.astype(jnp.float32)
    return jnp.sum(kl * w) / jnp.maximum(jnp.sum(w), 1.0)


def kd_mse_loss(student_logits, teacher_logits, sample_weight=None):
    """Mean-squared error on raw logits (FedMD-style digest matching)."""
    se = jnp.mean(jnp.square(student_logits.astype(jnp.float32)
                             - teacher_logits.astype(jnp.float32)), axis=-1)
    if sample_weight is None:
        return jnp.mean(se)
    w = sample_weight.astype(jnp.float32)
    return jnp.sum(se * w) / jnp.maximum(jnp.sum(w), 1.0)


def ce_loss(logits, labels):
    """Plain classification CE (local training, Algorithm 1 line 40)."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)
