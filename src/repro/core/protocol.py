"""Algorithm 1 — the EdgeFD round protocol, generic over Method.

``run_round`` executes one training-phase iteration (lines 12–17);
``run_experiment`` wires data → clients → rounds → evaluation and returns
a result record (accuracy history per client + communication accounting).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from typing import TYPE_CHECKING

from repro.common.types import FedConfig
from repro.core.methods import Method, get_method
from repro.data.proxy import ProxyData

if TYPE_CHECKING:  # avoid core <-> fed import cycle at runtime
    from repro.fed.client import Client
    from repro.fed.server import Server


@dataclasses.dataclass
class RoundLog:
    round: int
    mean_acc: float
    accs: List[float]
    local_loss: float
    distill_loss: float
    id_fraction: float          # fraction of (client, sample) pairs kept ID
    bytes_up: int
    bytes_down: int
    wall_s: float


@dataclasses.dataclass
class ExperimentResult:
    method: str
    scenario: str
    rounds: List[RoundLog]

    @property
    def final_acc(self) -> float:
        return self.rounds[-1].mean_acc if self.rounds else 0.0

    @property
    def best_acc(self) -> float:
        return max(r.mean_acc for r in self.rounds) if self.rounds else 0.0


def run_round(r: int, clients: List["Client"], server: "Server", method: Method,
              cfg: FedConfig, x_test, y_test) -> RoundLog:
    t0 = time.perf_counter()
    local_losses = [c.local_train(cfg.local_epochs, cfg.batch_size)
                    for c in clients]
    distill_losses = []
    id_frac = 1.0

    if method.name == "indlearn":
        pass  # no collaboration
    elif method.data_free:
        means_counts = [c.classwise_means() for c in clients]
        teacher_by_class, valid_by_class = server.aggregate_classwise(
            means_counts, count_weighted=method.count_weighted)
        for c in clients:
            teacher = teacher_by_class[c.y]               # (n, K)
            w = valid_by_class[c.y].astype(np.float32)
            distill_losses.append(
                c.distill(c.x, teacher, w, cfg.distill_epochs, cfg.batch_size))
    else:
        idx = server.select_indices(cfg.proxy_batch)      # line 13
        px = server.proxy.x[idx]
        powner = server.proxy.owner[idx]
        logits, masks = [], []
        for c in clients:                                  # lines 20–25
            logits.append(np.asarray(c.proxy_logits(px)))
            fs = c.filter_mask(px, powner)
            masks.append(np.asarray(fs.mask))
        logits = np.stack(logits)
        masks = np.stack(masks)
        id_frac = float(masks.mean())
        teacher, valid = server.aggregate(                 # line 15
            logits, masks, sharpen=method.sharpen,
            entropy_filter=method.server_filter)
        w = valid.astype(np.float32)
        for c in clients:                                  # line 16 / 38–43
            distill_losses.append(
                c.distill(px, teacher, w, cfg.distill_epochs, cfg.batch_size))

    accs = [c.evaluate(x_test, y_test) for c in clients]
    return RoundLog(
        round=r,
        mean_acc=float(np.mean(accs)),
        accs=accs,
        local_loss=float(np.mean(local_losses)),
        distill_loss=float(np.mean(distill_losses)) if distill_losses else 0.0,
        id_fraction=id_frac,
        bytes_up=server.bytes_received,
        bytes_down=server.bytes_broadcast,
        wall_s=time.perf_counter() - t0,
    )


def run_experiment(clients: List["Client"], server: "Server", method_name: str,
                   cfg: FedConfig, x_test, y_test,
                   progress: Optional[Callable[[RoundLog], None]] = None
                   ) -> ExperimentResult:
    method = get_method(method_name)
    logs = []
    key = jax.random.PRNGKey(cfg.seed)
    for i, c in enumerate(clients):                        # Initialization
        if method.client_filter != "none":
            c.learn_dre(jax.random.fold_in(key, i))
    for r in range(cfg.rounds):                            # Training phase
        log = run_round(r, clients, server, method, cfg, x_test, y_test)
        logs.append(log)
        if progress:
            progress(log)
    return ExperimentResult(method=method_name, scenario=cfg.scenario,
                            rounds=logs)
