"""Algorithm 1 — the EdgeFD round protocol, generic over Method and engine.

``run_round`` executes one training-phase iteration (lines 12–17);
``run_experiment`` wires data → clients → rounds → evaluation and returns
a result record (accuracy history per client + communication accounting).

Both are thin drivers over the *round phase graph* in
``repro.fed.scheduler``: a round decomposes into named phase nodes
(``local_train → report → aggregate → distill → eval``) with declared
data dependencies, and ``FedConfig.round_mode`` selects how the graph is
executed — ``sync`` replays the lockstep Algorithm-1 order bit-for-bit,
``overlap`` pipelines up to ``max_inflight`` rounds (round r+1 trains
while round r aggregates through the staleness buffer).

The phase bodies are written against a small *client engine* interface so
the same graph drives two execution strategies:

  * ``LoopEngine`` (here) — iterate a ``List[Client]`` one at a time.
    Always correct, required for heterogeneous architectures, slow: one
    host↔device round-trip per client per step.
  * ``CohortEngine`` (``repro.fed.cohort``) — stack homogeneous clients
    into leading-axis pytrees and run every per-client op under ``vmap``
    (one compiled call per round phase for the whole cohort).

Engines expose one entry point per phase (``phase_local_train``,
``phase_report``, ``phase_classwise_report``, ``phase_distill``,
``phase_distill_private``, ``phase_eval``); the historical ``*_all``
mega-call names remain as thin aliases for existing callers. Both engines
produce identical ``RoundLog`` streams for the same seed (see
``tests/test_cohort_parity.py``); ``FedConfig.engine`` selects one.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import TYPE_CHECKING, Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.common.types import FedConfig
from repro.core.methods import Method, get_method

if TYPE_CHECKING:  # avoid core <-> fed import cycle at runtime
    from repro.fed.client import Client
    from repro.fed.server import Server


@dataclasses.dataclass
class RoundLog:
    round: int
    mean_acc: float
    accs: List[float]
    local_loss: float
    distill_loss: float
    id_fraction: float          # fraction of (client, sample) pairs kept ID
    bytes_up: int
    bytes_down: int
    wall_s: float
    # partial participation (repro.fed.participation): the client ids that
    # trained/reported this round (None = every client, the legacy setting)
    # and the mean age of the aggregated reports in rounds (0.0 = all fresh)
    participants: Optional[List[int]] = None
    mean_staleness: float = 0.0
    # per-phase host wall-clock breakdown (repro.fed.scheduler phase nodes;
    # wall_s is their sum)
    phase_s: Dict[str, float] = dataclasses.field(default_factory=dict)
    # when this round retired on the simulated straggler timeline
    # (repro.fed.clock) — the axis on which round_mode="overlap" beats
    # "sync"; see benchmarks/async_rounds.py
    sim_finish_s: float = 0.0
    # served-model freshness: a user query served between model refreshes
    # hits the *last retired* round's model, so when round r retires at
    # sim_finish_s the model being replaced has been serving since the
    # previous retirement — this field is that serving interval in
    # simulated seconds (the maximum sim-time age a query could have hit;
    # round 0 measures from service start, i.e. the init model's tenure).
    # Overlap mode retires rounds faster than lockstep, so this is the
    # serving-facing win of the pipelined scheduler (launch/fed_serve.py).
    served_model_age_s: float = 0.0
    # FedDF-style ensemble server (method="server_distill"): the server
    # student's mean KD loss on the proxy batch this round, and its test
    # accuracy measured at the eval phase (None = no student attached)
    server_distill_loss: float = 0.0
    server_student_acc: Optional[float] = None
    # defense stack (repro.fed.server / repro.fed.scheduler): report rows
    # the sanitize pass scrubbed this round, clients quarantined on this
    # round's evidence (None = trust tracking off), and the cumulative
    # watchdog rollback count as of this round's retirement
    scrubbed_rows: int = 0
    quarantined: Optional[List[int]] = None
    rollbacks: int = 0


@dataclasses.dataclass
class ExperimentResult:
    method: str
    scenario: str
    rounds: List[RoundLog]

    @property
    def final_acc(self) -> float:
        return self.rounds[-1].mean_acc if self.rounds else 0.0

    @property
    def best_acc(self) -> float:
        return max(r.mean_acc for r in self.rounds) if self.rounds else 0.0


# ---------------------------------------------------------------------------
# Client engines
# ---------------------------------------------------------------------------

class LoopEngine:
    """Reference engine: drives clients one by one (heterogeneous-safe).

    This is the seed implementation of the round phases factored behind the
    engine interface (one behavioral delta: clients with fewer samples than
    the batch size now train one short batch per epoch instead of silently
    skipping local training — see ``repro.fed.batching``); ``CohortEngine``
    must match its outputs up to float tolerance.

    The ``phase_*`` methods are the scheduler's per-phase entry points; the
    ``*_all`` mega-call names below are thin aliases kept for historical
    callers.
    """

    def __init__(self, clients: Sequence["Client"]):
        self.clients = list(clients)

    @property
    def num_clients(self) -> int:
        return len(self.clients)

    def _part(self, participants) -> np.ndarray:
        """Normalize a participation mask (None = every client).

        A sampled-out client is skipped entirely: no local training, no
        proxy logits, no filter mask, and — critically for loop↔cohort
        parity — no consumption of its private rng stream.
        """
        if participants is None:
            return np.ones((len(self.clients),), bool)
        part = np.asarray(participants, bool)
        if part.shape != (len(self.clients),):
            raise ValueError(
                f"participation mask shape {part.shape} != "
                f"({len(self.clients)},)")
        return part

    def learn_dres(self, key) -> None:
        for i, c in enumerate(self.clients):
            c.learn_dre(jax.random.fold_in(key, i))

    # ------------------------------------------------ per-phase entry points
    def phase_local_train(self, epochs: int, batch_size: int,
                          participants=None) -> List[float]:
        part = self._part(participants)
        return [c.local_train(epochs, batch_size) if part[i] else 0.0
                for i, c in enumerate(self.clients)]

    def phase_classwise_report(self, participants=None):
        part = self._part(participants)
        k = self.clients[0].num_classes
        # zero counts: a sampled-out client contributes nothing classwise
        skipped = (np.zeros((k, k), np.float32), np.zeros((k,), np.float32))
        return [c.classwise_means() if part[i] else skipped
                for i, c in enumerate(self.clients)]

    def phase_report(self, px, powner, participants=None):
        """Returns (logits (C, t, K), masks (C, t)) as numpy arrays;
        sampled-out clients get zero logits and all-False masks (the
        staleness buffer replaces those rows with their last report)."""
        part = self._part(participants)
        t = len(px)
        k = self.clients[0].num_classes
        logits = np.zeros((len(self.clients), t, k), np.float32)
        masks = np.zeros((len(self.clients), t), bool)
        for i, c in enumerate(self.clients):               # lines 20–25
            if not part[i]:
                continue
            logits[i] = np.asarray(c.proxy_logits(px))
            masks[i] = np.asarray(c.filter_mask(px, powner).mask)
        return logits, masks

    def phase_distill(self, px, teacher, weight, epochs: int,
                      batch_size: int, participants=None) -> List[float]:
        part = self._part(participants)
        return [c.distill(px, teacher, weight, epochs, batch_size)
                if part[i] else 0.0
                for i, c in enumerate(self.clients)]

    def phase_distill_private(self, teacher_by_class, valid_by_class,
                              epochs: int, batch_size: int,
                              participants=None) -> List[float]:
        part = self._part(participants)
        out = []
        for i, c in enumerate(self.clients):
            if not part[i]:
                out.append(0.0)
                continue
            teacher = teacher_by_class[c.y]                # (n, K)
            w = valid_by_class[c.y].astype(np.float32)
            out.append(c.distill(c.x, teacher, w, epochs, batch_size))
        return out

    def phase_eval(self, x_test, y_test) -> List[float]:
        return [c.evaluate(x_test, y_test) for c in self.clients]

    # ------------------------------------------------ per-cohort entry points
    # Concurrent-cohort scheduling (repro.fed.scheduler with
    # cfg.concurrent_cohorts=True) keys client-side phase nodes per cohort
    # and drives each group independently. The loop engine groups clients
    # by arch_key exactly like CohortEngine so loop == cohort round-log
    # parity holds node-for-node; every cohort_* call returns values
    # aligned to that cohort's client positions and the scheduler scatters
    # them back into fleet-length structures.

    def cohort_positions(self) -> List[np.ndarray]:
        """Client positions per cohort, grouped by ``arch_key`` in first-
        appearance order (clients without an arch_key are singletons) —
        the same grouping rule as ``CohortEngine``."""
        if getattr(self, "_cohort_pos", None) is None:
            groups: Dict = {}
            for pos, c in enumerate(self.clients):
                key = c.arch_key if c.arch_key is not None else ("solo", pos)
                groups.setdefault(key, []).append(pos)
            self._cohort_pos = [np.asarray(p, int) for p in groups.values()]
        return self._cohort_pos

    def cohort_local_train(self, ci: int, epochs: int, batch_size: int,
                           participants=None) -> List[float]:
        part = self._part(participants)
        return [self.clients[p].local_train(epochs, batch_size)
                if part[p] else 0.0
                for p in self.cohort_positions()[ci]]

    def cohort_classwise_report(self, ci: int, participants=None):
        part = self._part(participants)
        k = self.clients[0].num_classes
        skipped = (np.zeros((k, k), np.float32), np.zeros((k,), np.float32))
        return [self.clients[p].classwise_means() if part[p] else skipped
                for p in self.cohort_positions()[ci]]

    def cohort_report(self, ci: int, px, powner, participants=None):
        """Returns (logits (m, t, K), masks (m, t)) for cohort ``ci``'s m
        clients; sampled-out rows stay zero/False like ``phase_report``."""
        part = self._part(participants)
        pos = self.cohort_positions()[ci]
        t = len(px)
        k = self.clients[0].num_classes
        logits = np.zeros((len(pos), t, k), np.float32)
        masks = np.zeros((len(pos), t), bool)
        for j, p in enumerate(pos):
            if not part[p]:
                continue
            c = self.clients[p]
            logits[j] = np.asarray(c.proxy_logits(px))
            masks[j] = np.asarray(c.filter_mask(px, powner).mask)
        return logits, masks

    def cohort_distill(self, ci: int, px, teacher, weight, epochs: int,
                       batch_size: int, participants=None) -> List[float]:
        part = self._part(participants)
        return [self.clients[p].distill(px, teacher, weight, epochs,
                                        batch_size)
                if part[p] else 0.0
                for p in self.cohort_positions()[ci]]

    def cohort_distill_private(self, ci: int, teacher_by_class,
                               valid_by_class, epochs: int, batch_size: int,
                               participants=None) -> List[float]:
        part = self._part(participants)
        out = []
        for p in self.cohort_positions()[ci]:
            c = self.clients[p]
            if not part[p]:
                out.append(0.0)
                continue
            teacher = teacher_by_class[c.y]                # (n, K)
            w = valid_by_class[c.y].astype(np.float32)
            out.append(c.distill(c.x, teacher, w, epochs, batch_size))
        return out

    # ------------------------------------------------- resumable service
    def state_dict(self) -> Dict:
        """Per-client mutable state (params, opt-state, rng) in the shared
        engine checkpoint format (``repro.fed.state``) — portable across
        loop/cohort/mesh engines."""
        from repro.fed.state import clients_state_dict
        return clients_state_dict(self.clients)

    def load_state_dict(self, sd: Dict) -> None:
        from repro.fed.state import load_clients_state_dict
        load_clients_state_dict(self.clients, sd)

    # -------------------------- historical mega-call names (thin aliases)
    def local_train_all(self, epochs: int, batch_size: int,
                        participants=None) -> List[float]:
        return self.phase_local_train(epochs, batch_size, participants)

    def classwise_means_all(self, participants=None):
        return self.phase_classwise_report(participants)

    def proxy_logits_and_masks(self, px, powner, participants=None):
        return self.phase_report(px, powner, participants)

    def distill_all(self, px, teacher, weight, epochs: int,
                    batch_size: int, participants=None) -> List[float]:
        return self.phase_distill(px, teacher, weight, epochs, batch_size,
                                  participants)

    def distill_private_all(self, teacher_by_class, valid_by_class,
                            epochs: int, batch_size: int,
                            participants=None) -> List[float]:
        return self.phase_distill_private(teacher_by_class, valid_by_class,
                                          epochs, batch_size, participants)

    def evaluate_all(self, x_test, y_test) -> List[float]:
        return self.phase_eval(x_test, y_test)


def as_engine(clients_or_engine, engine: str = "loop", *,
              num_devices: int = 0, mesh_axis: str = "clients",
              wave_size: int = 0, model_shards: int = 0):
    """Coerce a plain client list (the historical API) into an engine.

    ``num_devices``/``mesh_axis`` build the cohort engine's client mesh
    (``repro.fed.mesh``): 0 = unsharded, -1 = all devices, N > 0 = exactly N.
    ``model_shards`` > 0 folds those same devices into a 2-D
    ``(clients, model)`` mesh so each stacked client's weight matrices are
    model-sharded too; 0 keeps the 1-D mesh bit-for-bit.
    ``wave_size`` streams the cohort client axis through the device in
    fixed-size waves (``repro.fed.cohort``); 0 = whole axis resident.
    """
    if hasattr(clients_or_engine, "local_train_all"):
        if wave_size and not getattr(clients_or_engine, "wave_size", 0):
            warnings.warn(
                f"wave_size={wave_size} requested but a pre-built engine "
                "without wave streaming was supplied; it will run as "
                "constructed — build it via simulator.build_engine(...) "
                "or pass the raw client list to honor the config")
        if num_devices and getattr(clients_or_engine, "mesh", None) is None:
            # a pre-built engine runs as constructed; say so instead of
            # letting the config silently promise a mesh that isn't there
            warnings.warn(
                f"num_devices={num_devices} requested but a pre-built "
                "engine without a device mesh was supplied; it will run "
                "as constructed — build it via simulator.build_engine(...) "
                "or pass the raw client list to honor the config")
        return clients_or_engine
    if engine == "cohort":
        # lazy imports: core must not import fed at load time
        from repro.fed.cohort import CohortEngine
        from repro.fed.mesh import build_client_mesh
        mesh = build_client_mesh(num_devices, mesh_axis,
                                 model_shards=model_shards)
        return CohortEngine(clients_or_engine, mesh=mesh, mesh_axis=mesh_axis,
                            wave_size=wave_size)
    if engine != "loop":
        raise ValueError(f"unknown engine {engine!r}; known: loop, cohort")
    if num_devices:
        raise ValueError("num_devices requires engine='cohort' (the loop "
                         "engine drives one client at a time)")
    if wave_size:
        raise ValueError("wave_size requires engine='cohort' (the loop "
                         "engine never stacks a client axis to stream)")
    if model_shards:
        raise ValueError("model_shards requires engine='cohort' (the loop "
                         "engine holds each client's params on one device)")
    return LoopEngine(clients_or_engine)


def engine_from_config(clients_or_engine, cfg: FedConfig):
    """``as_engine`` with every engine-relevant ``FedConfig`` field applied.

    The single cfg→engine mapping — ``run_round``, ``run_experiment`` and
    ``simulator.build_engine`` all route through here so a new
    engine-relevant config field cannot be wired into one and not the
    others."""
    return as_engine(clients_or_engine, cfg.engine,
                     num_devices=cfg.num_devices, mesh_axis=cfg.mesh_axis,
                     wave_size=cfg.wave_size,
                     model_shards=getattr(cfg, "model_shards", 0))


# ---------------------------------------------------------------------------
# Protocol — thin drivers over the phase-graph scheduler
# ---------------------------------------------------------------------------

def _scheduler(engine, server: "Server", method: Method, cfg: FedConfig,
               x_test, y_test):
    # lazy import, like as_engine: core must not import fed at load time
    from repro.fed.scheduler import RoundScheduler
    return RoundScheduler(engine, server, method, cfg, x_test, y_test)


def run_round(r: int, clients, server: "Server", method: Method,
              cfg: FedConfig, x_test, y_test) -> RoundLog:
    """One round through the phase graph.

    A single round cannot overlap with anything, so ``round_mode="overlap"``
    degenerates to the sync phase order here — multi-round callers who want
    pipelining should go through ``run_experiment`` (one scheduler instance
    spanning all rounds). The scheduler validates the config on every entry
    path, so a direct caller cannot slip a zero/negative/overful
    ``participation_fraction`` past the protocol.

    NOTE: a raw client list must honor ``cfg.engine`` — an engine built
    here dies with this call, so its state must flow back to the Client
    objects below. That also means a raw list re-stacks and re-jits the
    cohort phases every round — multi-round callers should build the
    engine once (``simulator.build_engine`` / ``run_experiment``) and pass
    it in.
    """
    engine = engine_from_config(clients, cfg)
    transient = engine is not clients
    log = _scheduler(engine, server, method, cfg, x_test, y_test
                     ).run_rounds(r, 1)[0]
    if transient and hasattr(engine, "sync_to_clients"):
        # engines that train on stacked device state (CohortEngine) must
        # write params/opt-state back before being discarded, or raw-list
        # callers would silently lose every round's training
        engine.sync_to_clients()
    return log


def run_experiment(clients, server: "Server", method_name: str,
                   cfg: FedConfig, x_test, y_test,
                   progress: Optional[Callable[[RoundLog], None]] = None
                   ) -> ExperimentResult:
    engine = engine_from_config(clients, cfg)
    method = get_method(method_name)
    key = jax.random.PRNGKey(cfg.seed)
    if method.client_filter != "none":                     # Initialization
        engine.learn_dres(key)
    logs = _scheduler(engine, server, method, cfg, x_test, y_test
                      ).run_rounds(0, cfg.rounds, progress=progress)
    if engine is not clients and hasattr(engine, "sync_to_clients"):
        # raw-list callers hold only the Client objects — an engine built
        # here must write its trained stacked state back before vanishing
        engine.sync_to_clients()
    return ExperimentResult(method=method_name, scenario=cfg.scenario,
                            rounds=logs)
