"""Two-stage client-side filtering (Algorithm 1, CLIENTFILTER, lines 28–37).

A proxy sample's logits are in-distribution (ID) for client c iff
  stage 1: the sample originated from c's own private data
           (exact-membership test — proxy provenance is known, each client
           contributed its proxy subset), OR
  stage 2: KMeans-DRE distance to c's private centroids ≤ T^ID.

Everything is fixed-shape and vectorised: the filter returns a boolean mask
over the round's proxy batch, never a ragged set — masked aggregation on the
server consumes it directly (eliminating Selective-FD's server-side filter
stage, the paper's second contribution).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class FilterStats(NamedTuple):
    mask: jax.Array        # (t,) bool — ID decisions
    stage1: jax.Array      # (t,) bool — membership hits
    stage2: jax.Array      # (t,) bool — distance-test hits
    distances: jax.Array   # (t,) f32 — DRE distances (diagnostics)


def membership_mask(proxy_owner: jax.Array, client_id: int | jax.Array):
    """Stage 1 via provenance: owner ids recorded at proxy construction."""
    return proxy_owner == client_id


def two_stage_filter(dre, proxy_x, proxy_owner, client_id) -> FilterStats:
    """Full CLIENTFILTER. proxy_x: (t, ...) samples; proxy_owner: (t,) int32."""
    stage1 = membership_mask(proxy_owner, client_id)
    d = dre.distances(proxy_x) if hasattr(dre, "distances") else -dre.estimate(proxy_x)
    if hasattr(dre, "distances"):
        stage2 = d <= dre.threshold
    else:  # ratio-based DRE (KuLSIF): higher ratio = more ID
        stage2 = dre.estimate(proxy_x) >= dre.threshold
        d = -dre.estimate(proxy_x)
    # two-stage short-circuit: stage 2 only *needed* where stage 1 missed;
    # vectorised OR is the fixed-shape equivalent (the redundancy the paper
    # removes is the *server-side* pass, not this union)
    mask = stage1 | stage2
    return FilterStats(mask=mask, stage1=stage1, stage2=stage2, distances=d)


def server_entropy_filter(logits, mask, max_entropy_frac: float = 0.75):
    """Selective-FD's *server-side* ambiguity filter (baseline only).

    Drops client logits whose predictive entropy exceeds a fraction of
    log(num_classes). EdgeFD's claim is that this stage is unnecessary once
    client filtering is robust — the ablation toggles this on/off.
    logits: (C, t, K); mask: (C, t) bool. Returns tightened mask."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    ent = -jnp.sum(probs * jnp.log(jnp.maximum(probs, 1e-12)), axis=-1)
    max_ent = jnp.log(logits.shape[-1]) * max_entropy_frac
    return mask & (ent <= max_ent)
