"""EdgeFD as a first-class trainer for the large-architecture backbones.

The paper's protocol at production scale (DESIGN.md §3): homogeneous-family
clients are ranks on the ``data`` mesh axis; each holds a private token
shard and a KMeans-DRE fitted on its private feature distribution. Per
round, every rank:

  1. predicts logits on the broadcast proxy token batch,
  2. filters them with the two-stage mask (owner-provenance ∪ distance test
     on pooled embedding features — `transformer.features`),
  3. contributes to the ensemble teacher via ONE psum
     (`masked_mean_logits_psum`) — no hub, no server,
  4. takes a combined gradient step:  CE(private) + λ·T²·KL(student ∥ ȳ).

``make_fd_train_step`` returns a pjit-able step; ``fd_round_local`` is the
single-process (vmap-over-clients) variant used in tests/examples.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.common.types import ArchConfig
from repro.core.aggregation import masked_mean_logits_psum
from repro.core.distill import kd_kl_loss
from repro.core.filtering import two_stage_filter
from repro.models import transformer as T


def proxy_features(params, cfg: ArchConfig, proxy_tokens):
    """The filter's feature space for token data: pooled input embeddings
    (model-independent across heterogeneous clients; paper §V-C)."""
    return T.features(params, cfg, proxy_tokens)


class TransformerClientModel:
    """A transformer backbone as a simulator client model.

    Adapts ``models.transformer`` to the MLP/CNN client interface
    (``init(key)`` / ``apply(params, tokens, train)``) using THIS module's
    FD conventions: the classifier output is the LAST-position next-token
    distribution (``fd_loss``'s 'sample logit' for LM clients), so
    ``num_classes == cfg.vocab_size`` and the generic Client CE/distill
    machinery trains the backbone unchanged. One shared instance per arch
    keeps bound-method equality, so the cohort engine stacks all clients of
    an arch into one vmapped (and, with ``model_shards``, tensor-sharded)
    compiled phase.
    """

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    def init(self, key):
        return T.init_params(self.cfg, key)

    def apply(self, params, tokens, train: bool = False):
        logits, _ = T.forward(params, self.cfg, tokens)
        return logits[:, -1]

    def features(self, params, tokens):
        """Pooled input embeddings (``proxy_features``) — the paper's
        model-independent filter space for token data."""
        return proxy_features(params, self.cfg, tokens)


def fd_loss(params, cfg: ArchConfig, private_batch, proxy_tokens, teacher,
            teacher_weight, *, temperature: float = 2.0,
            distill_weight: float = 1.0, remat: bool = False):
    """Combined objective: local CE + weighted distillation KL."""
    ce, metrics = T.train_loss(params, cfg, private_batch, remat=remat)
    student_logits, _ = T.forward(params, cfg, proxy_tokens, remat=remat)
    # distill on the LAST position of each proxy sequence (the FD 'sample
    # logit' for LM clients is the next-token distribution)
    kl = kd_kl_loss(student_logits[:, -1], teacher[:, -1] if teacher.ndim == 3
                    else teacher, temperature, teacher_weight)
    loss = ce + distill_weight * kl
    return loss, {**metrics, "kl": kl, "ce_local": ce}


def make_fd_train_step(cfg: ArchConfig, optimizer, *, axis_name: str = "data",
                       temperature: float = 2.0, distill_weight: float = 1.0,
                       threshold: Optional[float] = None, remat: bool = False):
    """Mesh-collective FD round for shard_map/pjit execution.

    Each rank supplies its own (params, opt_state, private_batch, centroids,
    threshold); proxy_tokens/proxy_owner are replicated. Returns the updated
    client state; the teacher psum happens inside.
    """

    def step(params, opt_state, private_batch, proxy_tokens, proxy_owner,
             centroids, thr, client_id):
        # --- filter (lines 21–24 of Algorithm 1) -------------------------
        feats = proxy_features(params, cfg, proxy_tokens)

        class _DRE:  # minimal duck-typed DRE over the provided centroids
            threshold = thr

            @staticmethod
            def distances(x):
                from repro.core.kmeans import min_dist_to_centroids
                return min_dist_to_centroids(x, centroids)

        fs = two_stage_filter(_DRE, feats, proxy_owner, client_id)
        logits, _ = T.forward(params, cfg, proxy_tokens, remat=remat)
        sample_logits = logits[:, -1]
        # --- one-psum aggregation (line 15) ------------------------------
        teacher, valid = masked_mean_logits_psum(sample_logits, fs.mask,
                                                 axis_name)
        w = valid.astype(jnp.float32)
        # --- local CE + distill gradient step (lines 40–41) --------------
        (loss, metrics), grads = jax.value_and_grad(
            fd_loss, has_aux=True)(params, cfg, private_batch, proxy_tokens,
                                   teacher, w, temperature=temperature,
                                   distill_weight=distill_weight, remat=remat)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = jax.tree.map(jnp.add, params, updates)
        metrics = {**metrics, "loss": loss,
                   "id_fraction": jnp.mean(fs.mask.astype(jnp.float32))}
        return params, opt_state, metrics

    return step


def fd_round_local(cfg: ArchConfig, optimizer, client_states, private_batches,
                   proxy_tokens, proxy_owner, centroids_list, thresholds,
                   **kw):
    """Single-process reference: iterate clients, aggregate like the hub.

    client_states: list of (params, opt_state). Returns updated states +
    per-client metrics. Semantically identical to the psum step (tested).
    """
    from repro.core.aggregation import masked_mean_logits

    logits_all, masks = [], []
    for cid, (params, _) in enumerate(client_states):
        feats = proxy_features(params, cfg, proxy_tokens)

        class _DRE:
            threshold = thresholds[cid]
            _c = centroids_list[cid]

            @staticmethod
            def distances(x, _c=None):
                from repro.core.kmeans import min_dist_to_centroids
                return min_dist_to_centroids(x, centroids_list[cid])

        fs = two_stage_filter(_DRE, feats, proxy_owner, cid)
        lg, _ = T.forward(params, cfg, proxy_tokens)
        logits_all.append(lg[:, -1])
        masks.append(fs.mask)
    teacher, valid = masked_mean_logits(jnp.stack(logits_all),
                                        jnp.stack(masks))
    w = valid.astype(jnp.float32)

    new_states, all_metrics = [], []
    for cid, (params, opt_state) in enumerate(client_states):
        (loss, metrics), grads = jax.value_and_grad(
            fd_loss, has_aux=True)(params, cfg, private_batches[cid],
                                   proxy_tokens, teacher, w, **kw)
        upd, opt_state = optimizer.update(grads, opt_state, params)
        params = jax.tree.map(jnp.add, params, upd)
        new_states.append((params, opt_state))
        all_metrics.append({**metrics, "loss": loss})
    return new_states, all_metrics, float(jnp.stack(masks).mean())
