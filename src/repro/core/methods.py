"""The seven FD methods of Table III behind one interface.

A Method bundles the three policy choices the paper varies:
  * client_filter  — which proxy logits a client uploads (EdgeFD's KMeans-DRE
                     two-stage filter, Selective-FD's KuLSIF filter, or none);
  * server_filter  — optional server-side tightening (Selective-FD only);
  * aggregate      — how the server fuses uploaded logits into a teacher;
  * data_free      — FKD / PLS exchange class-wise mean logits instead of
                     per-sample proxy logits (no proxy data at all).

`repro.core.protocol` drives Algorithm 1 generically over a Method.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax.numpy as jnp

from repro.core import aggregation, filtering
from repro.core.dre import KMeansDRE, KuLSIFDRE


@dataclasses.dataclass(frozen=True)
class Method:
    name: str
    client_filter: str = "none"       # none | kmeans | kulsif
    server_filter: bool = False       # Selective-FD entropy filter
    sharpen: Optional[float] = None   # DS-FL ERA temperature
    data_free: bool = False           # FKD / PLS
    count_weighted: bool = False      # PLS: weight class means by counts
    distill_loss: str = "kl"          # kl | mse
    server_distill: bool = False      # FedDF: server-side ensemble student

    def make_dre(self, *, num_centroids: int, threshold: Optional[float],
                 kulsif_threshold: float = 0.05, num_aux: int = 256,
                 sigma: float = 4.0, kernel_backend: Optional[str] = None):
        if self.client_filter == "kmeans":
            return KMeansDRE(num_centroids=num_centroids, threshold=threshold,
                             kernel_backend=kernel_backend)
        if self.client_filter == "kulsif":
            return KuLSIFDRE(threshold=kulsif_threshold, num_aux=num_aux,
                             sigma=sigma, kernel_backend=kernel_backend)
        return None


EDGEFD = Method(name="edgefd", client_filter="kmeans")
FEDMD = Method(name="fedmd")                                   # plain ensemble
FEDED = Method(name="feded", distill_loss="kl")                # central distill
DSFL = Method(name="dsfl", sharpen=0.5)                        # ERA sharpening
FKD = Method(name="fkd", data_free=True)
PLS = Method(name="pls", data_free=True, count_weighted=True)
SELECTIVE_FD = Method(name="selective-fd", client_filter="kulsif",
                      server_filter=True)
INDLEARN = Method(name="indlearn")                             # no collaboration
# FedDF-style ensemble distillation: clients exchange plain ensemble logits
# (like fedmd), and the server additionally trains a central student on the
# unlabeled proxy data against the masked/weighted ensemble teacher — the
# standard fusion recipe for model-heterogeneous zoos (Lin et al., FedDF).
# The student rides a dedicated `server_distill` phase node between
# aggregate and distill (repro.fed.scheduler) on the serial server lane.
SERVER_DISTILL = Method(name="server_distill", server_distill=True)

METHODS = {m.name: m for m in
           (EDGEFD, FEDMD, FEDED, DSFL, FKD, PLS, SELECTIVE_FD, INDLEARN,
            SERVER_DISTILL)}


def get_method(name: str) -> Method:
    if name not in METHODS:
        raise KeyError(f"unknown method {name!r}; known: {sorted(METHODS)}")
    return METHODS[name]
