"""EdgeFD core — the paper's contribution.

KMeans-DRE density-ratio estimation, two-stage client-side filtering,
masked-mean server aggregation, and the Algorithm-1 protocol, plus the six
baseline FD methods of Table III.
"""
from repro.core.kmeans import kmeans_fit, min_dist_to_centroids, pairwise_sq_dists
from repro.core.dre import KMeansDRE, KuLSIFDRE, make_dre
from repro.core.filtering import two_stage_filter, server_entropy_filter, FilterStats
from repro.core.distill import kd_kl_loss, kd_mse_loss, ce_loss
from repro.core.aggregation import (
    masked_mean_logits,
    masked_mean_logits_psum,
    classwise_mean_logits,
)
from repro.core.methods import METHODS, Method, get_method
from repro.core.protocol import run_experiment, run_round, ExperimentResult
from repro.core import fd_trainer
from repro.core.privacy import make_dp, privatize_proxy, gaussian_sigma
