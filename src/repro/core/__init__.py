"""EdgeFD core — the paper's contribution.

KMeans-DRE density-ratio estimation, two-stage client-side filtering,
masked-mean server aggregation, and the Algorithm-1 protocol, plus the six
baseline FD methods of Table III.
"""
from repro.core import fd_trainer
from repro.core.aggregation import (
    classwise_mean_logits,
    masked_mean_logits,
    masked_mean_logits_psum,
    weighted_masked_mean_logits,
)
from repro.core.distill import ce_loss, kd_kl_loss, kd_mse_loss
from repro.core.dre import KMeansDRE, KuLSIFDRE, make_dre
from repro.core.filtering import (FilterStats, server_entropy_filter,
                                  two_stage_filter)
from repro.core.kmeans import kmeans_fit, min_dist_to_centroids, pairwise_sq_dists
from repro.core.methods import METHODS, Method, get_method
from repro.core.privacy import gaussian_sigma, make_dp, privatize_proxy
from repro.core.protocol import ExperimentResult, run_experiment, run_round
