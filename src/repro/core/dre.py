"""Density-ratio estimators: the paper's KMeans-DRE and the KuLSIF-DRE
baseline it replaces (Kanamori et al. 2012, as used by Selective-FD).

Both expose the paper's two-phase API:
    learn(private_data)    -> fitted state
    estimate(test_data)    -> per-sample score (higher = more in-distribution)
    is_id(test_data)       -> boolean ID mask at the configured threshold

KMeans-DRE (paper §III): score = −distance to nearest private-data centroid;
ID iff distance ≤ T^ID.  Complexity: learn O(k·n·c·d), estimate O(t·c·d).

KuLSIF-DRE (paper §V-B): kernel unconstrained least-squares importance
fitting.  Ratio r(x) = Σ_j α_j K(x, x'_j) + Σ_i β K(x, x_i) with the
analytic KuLSIF solution  α = (K11/m + λ I)^{-1} · (−K12 1/(λ n m)) …
following the operational form used in Selective-FD's released code:
learn solves the m×m system; estimate evaluates kernels of the test
points against both auxiliary and private samples.  Complexity:
learn O(m³ + m²d + nmd), estimate O(t(n+m)d) — Table IV.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.kmeans import kmeans_fit, min_dist_to_centroids
from repro.kernels import dispatch


# ---------------------------------------------------------------------------
# KMeans-DRE (the paper's contribution)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class KMeansDRE:
    """The paper's estimator. One centroid for strong non-IID; one per
    label for weak non-IID / IID (paper §IV-A)."""
    num_centroids: int = 1
    threshold: Optional[float] = None   # None => calibrate at learn()
    calibration_q: float = 0.95         # quantile of private distances
    max_iter: int = 50
    # kernel dispatch for the Lloyd fit (repro.kernels.dispatch);
    # None/"auto" = ambient policy (Pallas on TPU, jnp elsewhere)
    kernel_backend: Optional[str] = None

    centroids: Optional[jax.Array] = None

    def learn(self, key, x) -> "KMeansDRE":
        """Fit centroids; if threshold is None, set T^ID to the
        ``calibration_q`` quantile of the *private* data's own distances —
        the principled realisation of the paper's 'client-specific
        predefined thresholds' (§IV-B). The calibrated threshold stays a
        device scalar (no host sync — per-client learning can be queued
        without blocking; comparisons and float() work on it as before)."""
        flat = x.reshape(x.shape[0], -1)
        res = kmeans_fit(key, flat, self.num_centroids, self.max_iter,
                         backend=self.kernel_backend)
        thr = self.threshold
        if thr is None:
            d = min_dist_to_centroids(flat, res.centroids)
            thr = jnp.quantile(d, self.calibration_q)
        return dataclasses.replace(self, centroids=res.centroids, threshold=thr)

    def distances(self, t):
        assert self.centroids is not None, "call learn() first"
        return min_dist_to_centroids(t.reshape(t.shape[0], -1), self.centroids)

    def estimate(self, t):
        """Density-ratio proxy: monotone in −distance (paper uses the raw
        distance against T^ID; we expose −d so 'higher = more ID')."""
        return -self.distances(t)

    def is_id(self, t):
        return self.distances(t) <= self.threshold


# ---------------------------------------------------------------------------
# KuLSIF-DRE (Selective-FD's estimator — the baseline)
# ---------------------------------------------------------------------------

def rbf_kernel(a, b, sigma: float):
    """K(a,b) = exp(−‖a−b‖²/(2σ²)); a:(n,d) b:(m,d) -> (n,m).

    The canonical jnp reference — delegates to the dispatch layer's jnp
    path (same ops as always; the Pallas-tiled variant is
    ``dispatch.rbf_matrix(..., backend="pallas")``).
    """
    return dispatch.rbf_matrix(a, b, sigma, backend="jnp")


@partial(jax.jit, static_argnames=("backend",))
def _kulsif_learn(aux, private, sigma, lam, backend="jnp"):
    m = aux.shape[0]
    n = private.shape[0]
    k11 = dispatch.rbf_matrix(aux, aux, sigma, backend=backend)      # O(m² d)
    k12 = dispatch.rbf_matrix(aux, private, sigma, backend=backend)  # O(n m d)
    a = k11 / m + lam * jnp.eye(m, dtype=k11.dtype)
    b = -jnp.sum(k12, axis=1) / (lam * n * m)
    alpha = jnp.linalg.solve(a, b)                     # O(m³)
    return alpha


@dataclasses.dataclass
class KuLSIFDRE:
    """Kernel unconstrained least-squares importance fitting.

    Requires locally generated auxiliary (denominator) samples — the paper
    highlights this as an extra burden of statistical DREs; we synthesize
    them uniformly over the private data's bounding box (the 'dataset
    extrema' tuning factor mentioned in §II).
    """
    sigma: float = 1.0
    lam: float = 0.1
    num_aux: int = 256
    threshold: float = 1.0     # on the estimated ratio
    # kernel dispatch for the gram matrices (repro.kernels.dispatch);
    # None/"auto" = ambient policy (Pallas on TPU, jnp elsewhere)
    kernel_backend: Optional[str] = None

    alpha: Optional[jax.Array] = None
    aux: Optional[jax.Array] = None
    private: Optional[jax.Array] = None

    def learn(self, key, x) -> "KuLSIFDRE":
        x = x.reshape(x.shape[0], -1).astype(jnp.float32)
        lo = jnp.min(x, axis=0)
        hi = jnp.max(x, axis=0)
        aux = jax.random.uniform(key, (self.num_aux, x.shape[1]),
                                 minval=lo, maxval=hi)
        alpha = _kulsif_learn(aux, x, jnp.float32(self.sigma),
                              jnp.float32(self.lam),
                              backend=dispatch.resolve(self.kernel_backend))
        return dataclasses.replace(self, alpha=alpha, aux=aux, private=x)

    def estimate(self, t):
        """r̂(t) — density ratio p_private/p_aux (higher = more ID)."""
        assert self.alpha is not None, "call learn() first"
        t = t.reshape(t.shape[0], -1).astype(jnp.float32)
        backend = self.kernel_backend
        k_ta = dispatch.rbf_matrix(t, self.aux, self.sigma,
                                   backend=backend)        # O(t·m·d)
        k_tp = dispatch.rbf_matrix(t, self.private, self.sigma,
                                   backend=backend)        # O(t·n·d)
        n = self.private.shape[0]
        return k_ta @ self.alpha + jnp.sum(k_tp, axis=1) / (self.lam * n)

    def is_id(self, t):
        return self.estimate(t) >= self.threshold


def make_dre(kind: str, **kw):
    if kind == "kmeans":
        return KMeansDRE(**kw)
    if kind == "kulsif":
        return KuLSIFDRE(**kw)
    raise ValueError(f"unknown DRE kind {kind!r}")
