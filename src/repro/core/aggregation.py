"""Server-side aggregation (Algorithm 1 line 15) — masked mean of ID logits.

EdgeFD's server does exactly one thing: average the ID predictions each
client uploaded. No filtering, no teacher model. On the production mesh this
is a psum over the ``data`` axis (DESIGN.md §3) instead of a gather at a hub.

Robust variants (``ROBUST_AGGREGATIONS``) replace the mean over the client
axis with coordinate-wise trimmed mean / median or per-position Krum — the
Byzantine-resilient reducers the FD robustness surveys call for. Every
reducer (including the plain mean) guards against non-finite client rows: a
single inf/NaN logit from a diverged client must never poison the fused
teacher (the guard is an exact no-op on finite inputs, so the legacy logs
stay bit-for-bit).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# reducers over the client axis of the stacked (C, t, K) reports; "mean" is
# the legacy masked mean (bit-for-bit with pre-robustness logs)
ROBUST_AGGREGATIONS = ("mean", "trimmed_mean", "median", "krum_row")


def _finite_rows(logits, mask):
    """Drop non-finite client rows: a (c, t) row with any inf/NaN entry is
    removed from the mask and zeroed in the values (``0 * nan`` is nan, so
    masking alone is not enough). Exact identity on finite inputs."""
    lo = jnp.asarray(logits, jnp.float32)
    fin = jnp.isfinite(lo).all(axis=-1)                      # (C, t)
    return jnp.where(fin[..., None], lo, 0.0), fin


def masked_mean_logits(logits, mask, *, temperature_sharpen: Optional[float] = None,
                       guard_finite: bool = True):
    """logits: (C, t, K) per-client proxy logits; mask: (C, t) ID decisions.

    Returns (teacher (t, K), valid (t,) bool). Samples where no client is ID
    get a zero teacher and valid=False — the distillation loss masks them.
    DS-FL-style temperature sharpening (entropy reduction) is optional.
    Non-finite client rows are excluded (see ``_finite_rows``) unless
    ``guard_finite=False`` re-exposes the historical poison-the-teacher
    behavior (the ``sanitize_reports=False`` attack surface the divergence
    watchdog defends).
    """
    if guard_finite:
        lo, fin = _finite_rows(logits, mask)
        mb = jnp.logical_and(mask, fin)
    else:
        lo, mb = jnp.asarray(logits, jnp.float32), mask
    m = mb.astype(jnp.float32)[..., None]                    # (C, t, 1)
    s = jnp.sum(lo * m, axis=0)                              # (t, K)
    cnt = jnp.sum(m, axis=0)                                 # (t, 1)
    teacher = s / jnp.maximum(cnt, 1.0)
    valid = cnt[..., 0] > 0.0
    if temperature_sharpen:
        probs = jax.nn.softmax(teacher / temperature_sharpen, axis=-1)
        teacher = jnp.log(jnp.maximum(probs, 1e-12))         # sharpened logits
    return teacher, valid


def weighted_masked_mean_logits(logits, mask, client_weights, *,
                                temperature_sharpen: Optional[float] = None,
                                guard_finite: bool = True):
    """``masked_mean_logits`` with a per-client reliability weight.

    ``client_weights``: (C,) — the staleness model's ``decay ** age`` (see
    ``repro.fed.participation``). A fresh report carries weight 1, a stale
    one decays geometrically, weight 0 removes the client entirely; with
    all-ones weights this reduces to ``masked_mean_logits`` exactly (the
    server takes that code path instead for bit-for-bit stability).
    """
    if guard_finite:
        lo, fin = _finite_rows(logits, mask)
        mb = jnp.logical_and(mask, fin)
    else:
        lo, mb = jnp.asarray(logits, jnp.float32), mask
    w = mb.astype(jnp.float32) * client_weights[:, None]     # (C, t)
    wl = w[..., None]                                        # (C, t, 1)
    s = jnp.sum(lo * wl, axis=0)                             # (t, K)
    den = jnp.sum(wl, axis=0)                                # (t, 1)
    # divide by den itself (not a floor): the weights must cancel, so a
    # position whose only contributor is heavily decayed still recovers
    # that contributor's logits exactly. s is exactly 0 wherever den is 0
    # (all weights zero), so the dummy divisor there yields a zero teacher
    # — matching the unweighted form.
    teacher = s / jnp.where(den > 0.0, den, 1.0)
    valid = den[..., 0] > 0.0
    if temperature_sharpen:
        probs = jax.nn.softmax(teacher / temperature_sharpen, axis=-1)
        teacher = jnp.log(jnp.maximum(probs, 1e-12))         # sharpened logits
    return teacher, valid


def partial_masked_sums(logits, mask, client_weights=None, *,
                        guard_finite: bool = True):
    """One edge aggregator's contribution to the masked (weighted) mean.

    logits: (C_e, t, K) — this edge's client shard; mask: (C_e, t);
    ``client_weights``: optional (C_e,) staleness weights (None = all fresh).
    Returns ``(num (t, K), den (t,))`` — the weighted logit sums and weight
    sums this shard contributes. ``fuse_partial_sums`` over every shard's
    pair reproduces ``masked_mean_logits`` / ``weighted_masked_mean_logits``
    on the full stack (the mean is a ratio of sums, so it fuses exactly;
    only float summation order differs across shardings).
    """
    if guard_finite:
        lo, fin = _finite_rows(logits, mask)
        mb = jnp.logical_and(mask, fin)
    else:
        lo, mb = jnp.asarray(logits, jnp.float32), mask
    w = mb.astype(jnp.float32)
    if client_weights is not None:
        w = w * client_weights[:, None]
    num = jnp.sum(lo * w[..., None], axis=0)
    return num, jnp.sum(w, axis=0)


def fuse_partial_sums(nums, dens, *,
                      temperature_sharpen: Optional[float] = None):
    """Root fusion of E edge partials: (E, t, K) nums + (E, t) dens ->
    (teacher (t, K), valid (t,)). The divisor is the summed weight itself
    (floored to a dummy 1 only where it is exactly 0, matching
    ``weighted_masked_mean_logits``; with integer counts this equals the
    unweighted ``max(cnt, 1)`` floor)."""
    s = jnp.sum(jnp.asarray(nums, jnp.float32), axis=0)      # (t, K)
    den = jnp.sum(jnp.asarray(dens, jnp.float32), axis=0)    # (t,)
    teacher = s / jnp.where(den > 0.0, den, 1.0)[..., None]
    valid = den > 0.0
    if temperature_sharpen:
        probs = jax.nn.softmax(teacher / temperature_sharpen, axis=-1)
        teacher = jnp.log(jnp.maximum(probs, 1e-12))         # sharpened logits
    return teacher, valid


def masked_mean_logits_psum(local_logits, local_mask, axis_name: str = "data"):
    """Collective form for the sharded FD runtime: each mesh rank holds one
    client's logits; the masked mean is one all-reduce (psum of (Σ m·y, Σ m))
    over the federation axis. Semantically identical to masked_mean_logits.
    """
    m = local_mask.astype(jnp.float32)[..., None]
    num = jax.lax.psum(local_logits.astype(jnp.float32) * m, axis_name)
    den = jax.lax.psum(m, axis_name)
    teacher = num / jnp.maximum(den, 1.0)
    return teacher, den[..., 0] > 0.0


def classwise_mean_logits(logits, labels, num_classes: int):
    """FKD/PLS-style data-free aggregation: per-label mean logits.

    logits: (n, K) local logits on *private* data; labels: (n,).
    Returns (K_classes, K) matrix of mean logits per class (zero rows for
    absent classes) and per-class counts.
    """
    one_hot = jax.nn.one_hot(labels, num_classes, dtype=jnp.float32)  # (n, C)
    sums = one_hot.T @ logits.astype(jnp.float32)                     # (C, K)
    cnt = jnp.sum(one_hot, axis=0)[:, None]
    return sums / jnp.maximum(cnt, 1.0), cnt[:, 0]


# ---------------------------------------------------------------------------
# Robust reducers over the client axis
# ---------------------------------------------------------------------------

def _sharpen(teacher, temperature_sharpen):
    if temperature_sharpen:
        probs = jax.nn.softmax(teacher / temperature_sharpen, axis=-1)
        teacher = jnp.log(jnp.maximum(probs, 1e-12))
    return teacher


def _sorted_valid(logits, mask):
    """Sort each (t, K) coordinate over the client axis with invalid
    (masked-out or non-finite) rows pushed to ``+inf``, so the first
    ``n[t]`` entries per coordinate are the valid values ascending."""
    lo = jnp.asarray(logits, jnp.float32)
    fin = jnp.isfinite(lo).all(axis=-1)
    m = jnp.logical_and(mask, fin)                           # (C, t)
    xs = jnp.sort(jnp.where(m[..., None], lo, jnp.inf), axis=0)
    n = jnp.sum(m, axis=0)                                   # (t,) int
    return xs, n, m


def trimmed_mean_logits(logits, mask, *, trim_frac: float = 0.2,
                        temperature_sharpen: Optional[float] = None):
    """Coordinate-wise trimmed mean over the client axis.

    Per (t, k) coordinate, drops the ``floor(trim_frac * n_t)`` smallest
    and largest of the ``n_t`` valid client values and averages the rest
    (``trim_frac < 0.5`` guarantees at least one survivor). Tolerates up to
    a ``trim_frac`` fraction of arbitrarily-corrupted clients per position.
    """
    if not 0.0 <= trim_frac < 0.5:
        raise ValueError(f"trim_frac must be in [0, 0.5), got {trim_frac!r}")
    xs, n, _ = _sorted_valid(logits, mask)
    k = jnp.floor(trim_frac * n).astype(n.dtype)             # (t,)
    ranks = jnp.arange(xs.shape[0])[:, None, None]           # (C, 1, 1)
    keep = ((ranks >= k[None, :, None])
            & (ranks < (n - k)[None, :, None]))              # (C, t, 1)
    num = jnp.sum(jnp.where(keep, xs, 0.0), axis=0)          # (t, K)
    den = jnp.sum(keep, axis=0).astype(jnp.float32)          # (t, 1)
    teacher = num / jnp.maximum(den, 1.0)
    return _sharpen(teacher, temperature_sharpen), n > 0


def median_logits(logits, mask, *,
                  temperature_sharpen: Optional[float] = None):
    """Coordinate-wise median over the client axis (the 50%-breakdown
    robust center; even counts average the two middle values)."""
    xs, n, _ = _sorted_valid(logits, mask)
    top = xs.shape[0] - 1
    shape = (1,) + xs.shape[1:]

    def pick(idx):
        idx = jnp.clip(idx, 0, top).astype(jnp.int32)        # (t,)
        return jnp.take_along_axis(
            xs, jnp.broadcast_to(idx[None, :, None], shape), axis=0)[0]

    med = 0.5 * (pick((n - 1) // 2) + pick(n // 2))          # (t, K)
    teacher = jnp.where((n > 0)[:, None], med, 0.0)
    return _sharpen(teacher, temperature_sharpen), n > 0


def krum_row_logits(logits, mask, *,
                    temperature_sharpen: Optional[float] = None):
    """Per-proxy-position Krum: each (t,) position selects the single
    client whose logits sit closest to its ``n_t - 2`` nearest neighbours
    (sum of squared distances), i.e. the most-corroborated report. Ties
    resolve to the lowest client id. O(C^2 t K) — intended for modest
    cohort sizes; prefer trimmed_mean/median at fleet scale."""
    lo = jnp.asarray(logits, jnp.float32)
    fin = jnp.isfinite(lo).all(axis=-1)
    m = jnp.logical_and(mask, fin)                           # (C, t)
    safe = jnp.where(m[..., None], lo, 0.0)
    num_clients = lo.shape[0]
    diff = safe[:, None] - safe[None, :]                     # (C, C, t, K)
    d2 = jnp.sum(diff * diff, axis=-1)                       # (C, C, t)
    pair = m[:, None, :] & m[None, :, :]
    eye = jnp.eye(num_clients, dtype=bool)[:, :, None]
    d2 = jnp.where(pair & ~eye, d2, jnp.inf)
    ds = jnp.sort(d2, axis=1)                                # neighbours asc
    n = jnp.sum(m, axis=0)                                   # (t,)
    q = jnp.maximum(n - 2, 1)
    take = jnp.arange(num_clients)[None, :, None] < q[None, None, :]
    score = jnp.sum(jnp.where(take & jnp.isfinite(ds), ds, 0.0), axis=1)
    score = jnp.where(m, score, jnp.inf)                     # (C, t)
    best = jnp.argmin(score, axis=0)                         # (t,)
    teacher = jnp.take_along_axis(
        safe, jnp.broadcast_to(best[None, :, None],
                               (1,) + safe.shape[1:]), axis=0)[0]
    teacher = jnp.where((n > 0)[:, None], teacher, 0.0)
    return _sharpen(teacher, temperature_sharpen), n > 0


def robust_reduce(logits, mask, mode: str, *, trim_frac: float = 0.2,
                  temperature_sharpen: Optional[float] = None):
    """Dispatch one of ``ROBUST_AGGREGATIONS`` over the client axis.

    ``mean`` takes the exact legacy ``masked_mean_logits`` path. The robust
    modes are unweighted by design — staleness weights act only as a
    contribute/exclude mask upstream (a decayed-but-honest report is one
    vote, not a fractional one; robust order statistics have no natural
    notion of fractional voters).
    """
    if mode == "mean":
        return masked_mean_logits(logits, mask,
                                  temperature_sharpen=temperature_sharpen)
    if mode == "trimmed_mean":
        return trimmed_mean_logits(logits, mask, trim_frac=trim_frac,
                                   temperature_sharpen=temperature_sharpen)
    if mode == "median":
        return median_logits(logits, mask,
                             temperature_sharpen=temperature_sharpen)
    if mode == "krum_row":
        return krum_row_logits(logits, mask,
                               temperature_sharpen=temperature_sharpen)
    raise ValueError(
        f"robust_aggregation must be one of {ROBUST_AGGREGATIONS}, "
        f"got {mode!r}")


# ---------------------------------------------------------------------------
# Host-side sanitation + outlier scoring (defense-stack helpers)
# ---------------------------------------------------------------------------

def scrub_nonfinite(logits: np.ndarray,
                    masks: np.ndarray) -> Tuple[np.ndarray, np.ndarray,
                                                np.ndarray]:
    """Server-side sanitize pass over raw ``(C, t, K)`` reports.

    Rows with any non-finite entry are zeroed and removed from the mask
    *before* they can enter the staleness buffer or an edge partial.
    Returns ``(logits, masks, scrubbed_per_client)`` where the count is the
    number of claimed-ID rows each client lost. Clean inputs are returned
    as the same objects (no copy), keeping the common path bit-for-bit.
    """
    lo = np.asarray(logits, np.float32)
    mk = np.asarray(masks, bool)
    fin = np.isfinite(lo).all(axis=-1)                       # (C, t)
    scrubbed = (mk & ~fin).sum(axis=1).astype(np.int64)      # (C,)
    if fin.all():
        return lo, mk, scrubbed
    return (np.where(fin[..., None], lo, 0.0).astype(np.float32),
            mk & fin, scrubbed)


def client_outlier_distance(logits, masks,
                            teacher) -> Tuple[np.ndarray, np.ndarray]:
    """Per-client mean squared distance from the fused (robust) center.

    The trust/quarantine signal: for each client, the mean over its
    claimed-ID rows of ``mean_k (logit - teacher)^2``, computed only where
    both the client row and the teacher row are finite. A client whose own
    claimed rows contain non-finite values scores ``inf`` (sending NaN *is*
    the strongest outlier evidence). Returns ``(dist (C,), contributing
    (C,) bool)`` — non-contributing clients score 0 and must not have their
    trust updated.
    """
    lo = np.asarray(logits, np.float32)
    mk = np.asarray(masks, bool)
    th = np.asarray(teacher, np.float32)
    own_fin = np.isfinite(lo).all(axis=-1)                   # (C, t)
    th_fin = np.isfinite(th).all(axis=-1)                    # (t,)
    use = mk & own_fin & th_fin[None, :]
    lo_c = np.where(own_fin[..., None], lo, 0.0)
    th_c = np.where(th_fin[:, None], th, 0.0)
    diff = lo_c - th_c[None]
    d2 = np.where(use, (diff * diff).mean(axis=-1), 0.0)     # (C, t)
    cnt = use.sum(axis=1)
    dist = d2.sum(axis=1) / np.maximum(cnt, 1)
    dist = np.where((mk & ~own_fin).any(axis=1), np.inf, dist)
    return dist.astype(np.float64), mk.any(axis=1)
