"""Server-side aggregation (Algorithm 1 line 15) — masked mean of ID logits.

EdgeFD's server does exactly one thing: average the ID predictions each
client uploaded. No filtering, no teacher model. On the production mesh this
is a psum over the ``data`` axis (DESIGN.md §3) instead of a gather at a hub.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def masked_mean_logits(logits, mask, *, temperature_sharpen: Optional[float] = None):
    """logits: (C, t, K) per-client proxy logits; mask: (C, t) ID decisions.

    Returns (teacher (t, K), valid (t,) bool). Samples where no client is ID
    get a zero teacher and valid=False — the distillation loss masks them.
    DS-FL-style temperature sharpening (entropy reduction) is optional.
    """
    m = mask.astype(jnp.float32)[..., None]                  # (C, t, 1)
    s = jnp.sum(logits.astype(jnp.float32) * m, axis=0)      # (t, K)
    cnt = jnp.sum(m, axis=0)                                 # (t, 1)
    teacher = s / jnp.maximum(cnt, 1.0)
    valid = cnt[..., 0] > 0.0
    if temperature_sharpen:
        probs = jax.nn.softmax(teacher / temperature_sharpen, axis=-1)
        teacher = jnp.log(jnp.maximum(probs, 1e-12))         # sharpened logits
    return teacher, valid


def weighted_masked_mean_logits(logits, mask, client_weights, *,
                                temperature_sharpen: Optional[float] = None):
    """``masked_mean_logits`` with a per-client reliability weight.

    ``client_weights``: (C,) — the staleness model's ``decay ** age`` (see
    ``repro.fed.participation``). A fresh report carries weight 1, a stale
    one decays geometrically, weight 0 removes the client entirely; with
    all-ones weights this reduces to ``masked_mean_logits`` exactly (the
    server takes that code path instead for bit-for-bit stability).
    """
    w = mask.astype(jnp.float32) * client_weights[:, None]   # (C, t)
    wl = w[..., None]                                        # (C, t, 1)
    s = jnp.sum(logits.astype(jnp.float32) * wl, axis=0)     # (t, K)
    den = jnp.sum(wl, axis=0)                                # (t, 1)
    # divide by den itself (not a floor): the weights must cancel, so a
    # position whose only contributor is heavily decayed still recovers
    # that contributor's logits exactly. s is exactly 0 wherever den is 0
    # (all weights zero), so the dummy divisor there yields a zero teacher
    # — matching the unweighted form.
    teacher = s / jnp.where(den > 0.0, den, 1.0)
    valid = den[..., 0] > 0.0
    if temperature_sharpen:
        probs = jax.nn.softmax(teacher / temperature_sharpen, axis=-1)
        teacher = jnp.log(jnp.maximum(probs, 1e-12))         # sharpened logits
    return teacher, valid


def partial_masked_sums(logits, mask, client_weights=None):
    """One edge aggregator's contribution to the masked (weighted) mean.

    logits: (C_e, t, K) — this edge's client shard; mask: (C_e, t);
    ``client_weights``: optional (C_e,) staleness weights (None = all fresh).
    Returns ``(num (t, K), den (t,))`` — the weighted logit sums and weight
    sums this shard contributes. ``fuse_partial_sums`` over every shard's
    pair reproduces ``masked_mean_logits`` / ``weighted_masked_mean_logits``
    on the full stack (the mean is a ratio of sums, so it fuses exactly;
    only float summation order differs across shardings).
    """
    w = mask.astype(jnp.float32)
    if client_weights is not None:
        w = w * client_weights[:, None]
    num = jnp.sum(logits.astype(jnp.float32) * w[..., None], axis=0)
    return num, jnp.sum(w, axis=0)


def fuse_partial_sums(nums, dens, *,
                      temperature_sharpen: Optional[float] = None):
    """Root fusion of E edge partials: (E, t, K) nums + (E, t) dens ->
    (teacher (t, K), valid (t,)). The divisor is the summed weight itself
    (floored to a dummy 1 only where it is exactly 0, matching
    ``weighted_masked_mean_logits``; with integer counts this equals the
    unweighted ``max(cnt, 1)`` floor)."""
    s = jnp.sum(jnp.asarray(nums, jnp.float32), axis=0)      # (t, K)
    den = jnp.sum(jnp.asarray(dens, jnp.float32), axis=0)    # (t,)
    teacher = s / jnp.where(den > 0.0, den, 1.0)[..., None]
    valid = den > 0.0
    if temperature_sharpen:
        probs = jax.nn.softmax(teacher / temperature_sharpen, axis=-1)
        teacher = jnp.log(jnp.maximum(probs, 1e-12))         # sharpened logits
    return teacher, valid


def masked_mean_logits_psum(local_logits, local_mask, axis_name: str = "data"):
    """Collective form for the sharded FD runtime: each mesh rank holds one
    client's logits; the masked mean is one all-reduce (psum of (Σ m·y, Σ m))
    over the federation axis. Semantically identical to masked_mean_logits.
    """
    m = local_mask.astype(jnp.float32)[..., None]
    num = jax.lax.psum(local_logits.astype(jnp.float32) * m, axis_name)
    den = jax.lax.psum(m, axis_name)
    teacher = num / jnp.maximum(den, 1.0)
    return teacher, den[..., 0] > 0.0


def classwise_mean_logits(logits, labels, num_classes: int):
    """FKD/PLS-style data-free aggregation: per-label mean logits.

    logits: (n, K) local logits on *private* data; labels: (n,).
    Returns (K_classes, K) matrix of mean logits per class (zero rows for
    absent classes) and per-class counts.
    """
    one_hot = jax.nn.one_hot(labels, num_classes, dtype=jnp.float32)  # (n, C)
    sums = one_hot.T @ logits.astype(jnp.float32)                     # (C, K)
    cnt = jnp.sum(one_hot, axis=0)[:, None]
    return sums / jnp.maximum(cnt, 1.0), cnt[:, 0]
