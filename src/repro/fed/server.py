"""Federated server: proxy bookkeeping + aggregation. Trusted entity that
never trains a model (EdgeFD needs no pre-trained teacher)."""
from __future__ import annotations

from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import aggregation
from repro.core.filtering import server_entropy_filter
from repro.data.proxy import ProxyData, select_round_indices


class Server:
    def __init__(self, proxy: ProxyData, *, seed: int = 0):
        self.proxy = proxy
        self.rng = np.random.default_rng(seed + 7)
        self.bytes_received = 0
        self.bytes_broadcast = 0

    def select_indices(self, batch: int) -> np.ndarray:
        return select_round_indices(self.rng, self.proxy, batch)

    def aggregate(self, logits, masks, *, sharpen: Optional[float] = None,
                  entropy_filter: bool = False):
        """logits: (C, t, K); masks: (C, t). Returns (teacher, valid)."""
        logits = jnp.asarray(logits)
        masks = jnp.asarray(masks)
        if entropy_filter:  # Selective-FD baseline's extra server stage
            masks = server_entropy_filter(logits, masks)
        teacher, valid = aggregation.masked_mean_logits(
            logits, masks, temperature_sharpen=sharpen)
        # accounting: clients upload only ID logits (mask-compressed)
        k = logits.shape[-1]
        self.bytes_received += int(jnp.sum(masks)) * k * 4
        self.bytes_broadcast += int(teacher.shape[0]) * k * 4
        return np.asarray(teacher), np.asarray(valid)

    def aggregate_classwise(self, means_counts, *, count_weighted: bool):
        """FKD/PLS: fuse per-class mean logits from all clients."""
        means = jnp.stack([m for m, _ in means_counts])     # (C, K_cls, K)
        counts = jnp.stack([c for _, c in means_counts])    # (C, K_cls)
        if count_weighted:
            w = counts[..., None]
        else:
            w = (counts > 0).astype(jnp.float32)[..., None]
        teacher = jnp.sum(means * w, axis=0) / jnp.maximum(jnp.sum(w, axis=0), 1.0)
        valid = jnp.sum(counts, axis=0) > 0
        self.bytes_received += int(np.prod(means.shape)) * 4
        return np.asarray(teacher), np.asarray(valid)
