"""Federated server: proxy bookkeeping + aggregation. Trusted entity that
never trains a model (EdgeFD needs no pre-trained teacher).

Report *ingest* and *aggregation* are separate steps so in-flight rounds
can interleave (``repro.fed.scheduler`` with ``round_mode="overlap"``):
``ingest_reports`` records a round's engine outputs — merging stale rows
from the ``StalenessBuffer`` at ingest time, while the buffer still
reflects only earlier rounds — and ``aggregate_round`` later fuses the
recorded reports into a teacher. Under the lockstep ``sync`` mode the two
run back-to-back and reproduce the historical single-call path
bit-for-bit.

With ``num_edges > 1`` the server is **two-tier**: E edge aggregators each
own a contiguous client shard and, at ingest time, locally apply the
server-side filter, run staleness bookkeeping against a *per-shard*
lazily-materialized ``StalenessBuffer``, and reduce their shard to one
``(num, den)`` masked/weighted partial sum (``repro.core.aggregation``).
The root only ever sees E partials — its per-round work and the in-flight
report footprint scale with E and the proxy batch, not with C, which is
what lets ``benchmarks/scale.py`` push C to 16k on a laptop-class host.
``num_edges=1`` (default) is the flat single-tier server, bit-for-bit the
legacy aggregation and byte accounting."""
from __future__ import annotations

from typing import Dict, List, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation
from repro.core import distill as D
from repro.core.filtering import server_entropy_filter
from repro.data.proxy import ProxyData, select_round_indices
from repro.fed.batching import epoch_batches
from repro.fed.participation import StaleMerge, StalenessBuffer
from repro.optim.optimizers import Optimizer, apply_updates


class _ServerStudent:
    """FedDF-style central student (``method="server_distill"``).

    The server — which otherwise never trains — owns one model and distills
    it each round on the unlabeled proxy batch against the masked/weighted
    ensemble teacher the clients are about to receive (Lin et al., FedDF:
    ensemble distillation is the standard fusion for model-heterogeneous
    zoos, since parameter averaging needs a shared architecture). The step
    mirrors ``Client._distill_step`` so the student's KD objective is the
    exact client objective."""

    def __init__(self, apply_fn, params, opt: Optimizer, *,
                 temperature: float = 3.0, seed: int = 0):
        self.apply_fn = apply_fn
        self.params = params
        self.opt = opt
        self.opt_state = opt.init(params)
        self.temperature = temperature
        # epoch shuffling stream, disjoint from the server's admission rng
        # (seed + 7) and every client's stream (seed + 1000 * cid)
        self.rng = np.random.default_rng(seed + 31)

        @jax.jit
        def _distill_step(params, opt_state, xb, teacher, w):
            def loss_fn(p):
                logits = apply_fn(p, xb, True)
                return D.kd_kl_loss(logits, teacher, temperature, w)
            loss, grads = jax.value_and_grad(loss_fn)(params)
            upd, opt_state = opt.update(grads, opt_state, params)
            return apply_updates(params, upd), opt_state, loss

        @jax.jit
        def _predict(params, xb):
            return apply_fn(params, xb, False)

        self._distill_step = _distill_step
        self._predict = _predict

    def distill(self, px, teacher, weight, epochs: int,
                batch_size: int) -> float:
        n = len(px)
        losses = []
        for _ in range(epochs):
            for idx in epoch_batches(self.rng.permutation(n), batch_size):
                self.params, self.opt_state, loss = self._distill_step(
                    self.params, self.opt_state, jnp.asarray(px[idx]),
                    jnp.asarray(teacher[idx]), jnp.asarray(weight[idx]))
                losses.append(float(loss))
        return float(np.mean(losses)) if losses else 0.0

    def evaluate(self, x_test, y_test, batch_size: int = 512) -> float:
        hits = 0
        for lo in range(0, len(y_test), batch_size):
            xb = jnp.asarray(x_test[lo:lo + batch_size])
            preds = np.asarray(jnp.argmax(self._predict(self.params, xb),
                                          axis=-1))
            hits += int((preds == np.asarray(y_test[lo:lo + batch_size]))
                        .sum())
        return hits / max(len(y_test), 1)

    def state_dict(self) -> dict:
        from repro.fed.state import rng_state_dict
        from repro.checkpoint.ckpt import flatten_tree
        return {
            "params": flatten_tree(self.params),
            "opt_state": flatten_tree(self.opt_state),
            "rng": rng_state_dict(self.rng),
        }

    def load_state_dict(self, sd: dict) -> None:
        from repro.fed.state import load_rng_state
        from repro.checkpoint.ckpt import unflatten_like
        self.params = unflatten_like(sd["params"], self.params)
        self.opt_state = unflatten_like(sd["opt_state"], self.opt_state)
        load_rng_state(self.rng, sd["rng"])


class _PendingReports(NamedTuple):
    """One round's ingested-but-not-yet-aggregated proxy reports.

    Exactly one payload is held: the raw engine outputs on the
    full-participation path, or the stale-merged rows on the subset path
    (keeping both would double the in-flight footprint — overlap mode
    parks up to ``max_inflight`` of these)."""
    participants: Optional[np.ndarray]   # (C,) bool, None = everyone
    logits: Optional[np.ndarray]         # (C, t, K); None when merged is set
    masks: Optional[np.ndarray]          # (C, t);   None when merged is set
    merged: Optional[StaleMerge]         # stale-filled rows (subset rounds)


class _PendingPartials(NamedTuple):
    """One round's edge-reduced reports (``num_edges > 1`` only).

    Each edge already collapsed its client shard to a masked/weighted
    partial sum, so a pending round costs O(E · t · K) — the (C, t, K)
    stack never outlives ``ingest_reports``."""
    nums: np.ndarray        # (E, t, K) per-edge weighted logit sums
    dens: np.ndarray        # (E, t) per-edge weight sums
    uploaded_bytes: int     # upload traffic, priced from pre-filter masks
    mean_staleness: float   # exact fleet-wide Σ age / Σ contributing
    # trust-signal extras (track_outliers only; None keeps old checkpoints
    # loadable): per-client distance from the *edge-local* center and the
    # contributing mask, computed at ingest since the stack dies here
    outlier: Optional[np.ndarray] = None    # (C,) float
    contrib: Optional[np.ndarray] = None    # (C,) bool


# EWMA trust scores for non-finite senders are pinned here instead of inf
# so the running average stays finite (inf would never decay back)
_TRUST_CAP = 1e9


class Server:
    def __init__(self, proxy: ProxyData, *, seed: int = 0,
                 num_edges: int = 1, max_pending_reports: int = 0,
                 robust_aggregation: str = "mean", trim_frac: float = 0.2,
                 sanitize: bool = True, quarantine_threshold: float = 0.0,
                 trust_ewma: float = 0.5, quarantine_rounds: int = 2,
                 track_outliers: bool = False):
        if num_edges < 1:
            raise ValueError(f"num_edges must be >= 1, got {num_edges!r}")
        if max_pending_reports < 0:
            raise ValueError(f"max_pending_reports must be >= 0 "
                             f"(0 = unbounded), got {max_pending_reports!r}")
        if robust_aggregation not in aggregation.ROBUST_AGGREGATIONS:
            raise ValueError(
                f"robust_aggregation must be one of "
                f"{aggregation.ROBUST_AGGREGATIONS}, "
                f"got {robust_aggregation!r}")
        if not 0.0 <= trim_frac < 0.5:
            raise ValueError(
                f"trim_frac must be in [0, 0.5), got {trim_frac!r}")
        if quarantine_threshold < 0.0:
            raise ValueError(f"quarantine_threshold must be >= 0 "
                             f"(0 = off), got {quarantine_threshold!r}")
        if not 0.0 < trust_ewma <= 1.0:
            raise ValueError(
                f"trust_ewma must be in (0, 1], got {trust_ewma!r}")
        if quarantine_rounds < 1:
            raise ValueError(f"quarantine_rounds must be >= 1, "
                             f"got {quarantine_rounds!r}")
        self.proxy = proxy
        self.rng = np.random.default_rng(seed + 7)
        self.num_edges = int(num_edges)
        # -- defense stack --------------------------------------------------
        self.robust_aggregation = robust_aggregation
        self.trim_frac = float(trim_frac)
        self.sanitize = bool(sanitize)
        self.quarantine_threshold = float(quarantine_threshold)
        self.trust_ewma = float(trust_ewma)
        self.quarantine_rounds = int(quarantine_rounds)
        # outlier distances are only worth computing when someone consumes
        # them: the auto-quarantine rule or the scheduler's watchdog
        self.track_outliers = bool(track_outliers) or quarantine_threshold > 0
        # sanitize-pass accounting: cumulative scrubbed rows (total and per
        # client) plus the per-round counts the scheduler pops into RoundLog
        self.scrub_total = 0
        self.scrub_clients: Optional[np.ndarray] = None       # (C,) int64
        self._scrubbed_rounds: Dict[int, int] = {}
        # trust & quarantine (lazily sized to the fleet on first signal):
        # trust = EWMA of the median-normalized outlier distance;
        # quarantined_until[c] > r means c sits out round r; strikes
        # escalate re-quarantine duration
        self.trust: Optional[np.ndarray] = None               # (C,) float
        self.quarantined_until: Optional[np.ndarray] = None   # (C,) int64
        self.strikes: Optional[np.ndarray] = None             # (C,) int64
        # per-round normalized outlier scores / quarantine events, parked
        # until the scheduler pops them at round retire (both checkpointed
        # — aggregate and retire can be separated by a kill)
        self._round_outlier: Dict[int, np.ndarray] = {}
        self._quarantine_events: Dict[int, List[int]] = {}
        # admission/backpressure: the ingest queue holds at most this many
        # client reports across all in-flight rounds (0 = unbounded, the
        # legacy behavior). A report arriving at a full queue is refused —
        # the client's round contribution drains through the staleness
        # machinery like a dropout. Counted per round in
        # ``_inflight_reports`` and released by ``aggregate_round``.
        self.max_pending_reports = int(max_pending_reports)
        self._inflight_reports: Dict[int, int] = {}
        self.bytes_received = 0
        self.bytes_broadcast = 0
        # lazily-sized staleness buffer (partial participation only): the
        # last report of every client, by proxy-dataset position.
        # single-tier keeps one flat buffer; two-tier keeps one per edge
        # shard (each materialized on that shard's first subset ingest)
        self._stale: Optional[StalenessBuffer] = None
        self._edge_stale: List[Optional[StalenessBuffer]] = []
        self._shard_slices: Optional[List[slice]] = None
        # rounds whose reports were ingested but not yet aggregated,
        # keyed by round index (overlap mode keeps up to max_inflight here)
        self._pending: Dict[int, Union[_PendingReports,
                                       _PendingPartials]] = {}
        # FedDF central student (method="server_distill" only) — attached
        # by the simulator after model init so the server stays model-free
        # for every other method
        self.student: Optional[_ServerStudent] = None

    def attach_student(self, apply_fn, params, opt: Optimizer, *,
                       temperature: float = 3.0, seed: int = 0) -> None:
        """Give the server a trainable student for ensemble distillation."""
        self.student = _ServerStudent(apply_fn, params, opt,
                                      temperature=temperature, seed=seed)

    def ensemble_distill(self, px, teacher, valid, *, epochs: int,
                         batch_size: int) -> float:
        """One FedDF server round: fit the student on the proxy batch
        against the masked/weighted ensemble teacher. ``valid`` is the
        aggregate coverage mask — rows no client predicted carry zero
        weight, exactly as in client-side distillation."""
        if self.student is None:
            raise RuntimeError("ensemble_distill requires attach_student()")
        w = np.asarray(valid, np.float32)
        return self.student.distill(np.asarray(px), np.asarray(teacher), w,
                                    epochs, batch_size)

    def evaluate_student(self, x_test, y_test) -> float:
        if self.student is None:
            raise RuntimeError("evaluate_student requires attach_student()")
        return self.student.evaluate(x_test, y_test)

    def _shards(self, num_clients: int) -> List[slice]:
        """Contiguous per-edge client shards, fixed at first use."""
        if self._shard_slices is None:
            e = min(self.num_edges, num_clients)
            bounds = np.linspace(0, num_clients, e + 1).astype(int)
            self._shard_slices = [slice(int(a), int(b))
                                  for a, b in zip(bounds[:-1], bounds[1:])
                                  if b > a]
            self._edge_stale = [None] * len(self._shard_slices)
        return self._shard_slices

    def select_indices(self, batch: int) -> np.ndarray:
        return select_round_indices(self.rng, self.proxy, batch)

    # ------------------------------------------------ trust & quarantine
    def _ensure_fleet(self, num_clients: int) -> None:
        """Size (or grow) the per-client bookkeeping arrays. Growth pads
        with zeros — callers that only know a subset of ids (quarantine)
        stay safe when a fleet-sized caller comes along later."""
        def grow(a, dtype):
            if a is None:
                return np.zeros((num_clients,), dtype)
            if a.shape[0] < num_clients:
                b = np.zeros((num_clients,), dtype)
                b[:a.shape[0]] = a
                return b
            return a
        self.trust = grow(self.trust, np.float64)
        self.quarantined_until = grow(self.quarantined_until, np.int64)
        self.strikes = grow(self.strikes, np.int64)
        self.scrub_clients = grow(self.scrub_clients, np.int64)

    def quarantine_mask(self, round_idx: int) -> Optional[np.ndarray]:
        """(C,) bool — True where a client sits out this round. ``None``
        (nobody ever quarantined) keeps the legacy participant draw
        untouched."""
        if self.quarantined_until is None:
            return None
        mask = self.quarantined_until > round_idx
        return mask if mask.any() else None

    def quarantine(self, ids, first_round: int, *,
                   event_round: Optional[int] = None) -> List[int]:
        """Demote ``ids`` to non-participants from ``first_round`` on.

        Duration escalates with each client's strike count
        (``quarantine_rounds * strikes``); on release the client re-enters
        on probation — its trust is reset to half the threshold, so one
        more outlier round re-quarantines it while honest behaviour decays
        it back toward zero. The event is recorded under ``event_round``
        (default ``first_round``) for the scheduler to surface on that
        round's ``RoundLog``."""
        ids = sorted(int(c) for c in np.asarray(ids).ravel())
        if not ids:
            return []
        self._ensure_fleet(max(ids) + 1)
        for c in ids:
            self.strikes[c] += 1
            until = first_round + self.quarantine_rounds * int(
                self.strikes[c])
            self.quarantined_until[c] = max(
                int(self.quarantined_until[c]), until)
            self.trust[c] = 0.5 * self.quarantine_threshold
        key = first_round if event_round is None else event_round
        self._quarantine_events.setdefault(key, []).extend(ids)
        return ids

    def _update_trust(self, round_idx: int, dist: np.ndarray,
                      contributing: np.ndarray) -> None:
        """Fold one round's outlier distances into the EWMA trust scores.

        Distances are normalized by the round's median over finite
        contributors (scale-free across rounds/methods); non-finite
        senders pin at ``_TRUST_CAP``. Non-contributing clients are left
        untouched — absence is not evidence."""
        dist = np.asarray(dist, np.float64)
        contributing = np.asarray(contributing, bool)
        self._ensure_fleet(dist.shape[0])
        finite = np.isfinite(dist) & contributing
        scale = float(np.median(dist[finite])) if finite.any() else 0.0
        with np.errstate(invalid="ignore"):
            norm = np.where(np.isfinite(dist),
                            dist / max(scale, 1e-12), np.inf)
        norm = np.minimum(np.where(contributing, norm, 0.0), _TRUST_CAP)
        a = self.trust_ewma
        self.trust = np.where(contributing,
                              (1.0 - a) * self.trust + a * norm, self.trust)
        self._round_outlier[round_idx] = norm
        if self.quarantine_threshold > 0.0:
            bad = contributing & (self.trust > self.quarantine_threshold)
            if bad.any():
                # round_idx just aggregated — exclusion starts next round
                self.quarantine(np.nonzero(bad)[0], round_idx + 1,
                                event_round=round_idx)

    def pop_scrubbed(self, round_idx: int) -> int:
        """Rows the sanitize pass scrubbed from this round's reports."""
        return int(self._scrubbed_rounds.pop(round_idx, 0))

    def pop_quarantined(self, round_idx: int) -> List[int]:
        """Clients quarantined on this round's evidence (may be empty)."""
        return self._quarantine_events.pop(round_idx, [])

    def pop_round_outlier(self, round_idx: int) -> Optional[np.ndarray]:
        """This round's normalized outlier scores (watchdog suspect
        ranking); None when tracking is off or the round had none."""
        return self._round_outlier.pop(round_idx, None)

    def admit_reports(self, round_idx: int,
                      ordered_ids: np.ndarray) -> np.ndarray:
        """Admission control over one round's report arrivals.

        ``ordered_ids``: the round's reporting client ids in simulated-
        arrival order (the scheduler sorts by report-phase lane finish,
        ties broken by id). Each arrival is admitted while the ingest
        queue has room — ``max_pending_reports`` minus the reports already
        parked for not-yet-aggregated rounds — and refused afterwards, so
        exactly the *earliest* arrivals of an overloaded round get in.
        Returns the admitted prefix; with ``max_pending_reports=0`` every
        report is admitted and nothing is recorded (the legacy path).
        """
        ordered_ids = np.asarray(ordered_ids)
        if self.max_pending_reports <= 0:
            return ordered_ids
        used = sum(self._inflight_reports.values())
        free = max(0, self.max_pending_reports - used)
        admitted = ordered_ids[:free]
        self._inflight_reports[round_idx] = int(admitted.size)
        return admitted

    def merge_stale(self, round_idx: int, participants, idx, logits, masks,
                    *, decay: float) -> StaleMerge:
        """Record this round's fresh reports and fill non-participant rows
        from each client's last report (``repro.fed.participation``)."""
        if self._stale is None:
            c, _, k = np.asarray(logits).shape
            self._stale = StalenessBuffer(c, len(self.proxy.x), k)
        return self._stale.merge(round_idx, participants, idx, logits, masks,
                                 decay)

    def ingest_reports(self, round_idx: int, participants, idx, logits,
                       masks, *, decay: float,
                       entropy_filter: bool = False) -> None:
        """Record one round's engine reports for a later ``aggregate_round``.

        Stale rows are merged *now*: ingests arrive in round order (the
        scheduler's order edges guarantee it), so the buffer reflects
        exactly the rounds before this one and report ages can never go
        negative — even while later rounds' aggregations are still pending.
        ``participants=None`` (full participation) skips the buffer
        entirely, keeping the legacy everyone-reports path untouched.

        ``entropy_filter`` matters only on the two-tier path (the edges
        apply the Selective-FD server filter locally *before* reducing
        their shard); single-tier ingests keep the raw reports and the
        filter runs inside ``aggregate`` as it always has.
        """
        if round_idx in self._pending:
            raise ValueError(f"round {round_idx} reports already ingested "
                             "and not yet aggregated")
        if self.sanitize:
            # scrub *before* anything downstream — most importantly before
            # the staleness merge, so a corrupt row can never enter the
            # buffer and get replayed into later rounds. Clean reports come
            # back as the same objects (bit-for-bit the legacy path).
            logits, masks, per_client = aggregation.scrub_nonfinite(
                np.asarray(logits, np.float32), np.asarray(masks, bool))
            n_bad = int(per_client.sum())
            if n_bad:
                self._scrubbed_rounds[round_idx] = (
                    self._scrubbed_rounds.get(round_idx, 0) + n_bad)
                self.scrub_total += n_bad
                self._ensure_fleet(len(per_client))
                self.scrub_clients += per_client
        if self.num_edges > 1:
            self._pending[round_idx] = self._ingest_edges(
                round_idx, participants, idx, logits, masks, decay=decay,
                entropy_filter=entropy_filter)
            return
        if participants is None:
            self._pending[round_idx] = _PendingReports(
                None, logits, masks, None)
            return
        merged = self.merge_stale(round_idx, participants, idx, logits,
                                  masks, decay=decay)
        self._pending[round_idx] = _PendingReports(
            participants, None, None, merged)

    def _ingest_edges(self, round_idx: int, participants, idx, logits,
                      masks, *, decay: float,
                      entropy_filter: bool) -> _PendingPartials:
        """Two-tier ingest: every edge reduces its client shard to one
        masked/weighted ``(num, den)`` partial, doing the server-side
        filter and staleness bookkeeping shard-locally. The full (C, t, K)
        stack is consumed here and never parked in ``_pending``.

        With a robust ``robust_aggregation`` each edge runs the robust
        reduce over its *own shard* and contributes ``(center * n_e, n_e)``
        — the root then fuses contributor-weighted edge centers. This is an
        **approximation** of the flat robust reduce (a mean of per-shard
        medians is not the global median; its breakdown point degrades when
        attackers concentrate in one shard), traded for the same O(E·t·K)
        root cost as the mean path. ``num_edges=1`` never enters this
        method, so E=1 equals the flat robust reduce exactly."""
        logits = np.asarray(logits, np.float32)
        masks = np.asarray(masks, bool)
        part = (None if participants is None
                else np.asarray(participants, bool))
        k = logits.shape[-1]
        shards = self._shards(logits.shape[0])
        nums, dens = [], []
        uploaded_bytes = 0
        ages_sum, n_contrib = 0.0, 0
        subset = part is not None
        robust = self.robust_aggregation != "mean"
        outlier = (np.zeros((logits.shape[0],), np.float64)
                   if self.track_outliers else None)
        contrib = (np.zeros((logits.shape[0],), bool)
                   if self.track_outliers else None)
        for e, sl in enumerate(shards):
            l_e, m_e = logits[sl], masks[sl]
            cw = None
            if part is None:
                # everyone reported: uploads are the raw ID rows
                uploaded_bytes += int(m_e.sum()) * k * 4
            else:
                # uploads priced from the *pre-filter* fresh masks of this
                # round's reporters; stale reuse costs no bytes
                uploaded_bytes += int(m_e[part[sl]].sum()) * k * 4
                if self._edge_stale[e] is None:
                    self._edge_stale[e] = StalenessBuffer(
                        l_e.shape[0], len(self.proxy.x), k)
                merged = self._edge_stale[e].merge(
                    round_idx, part[sl], idx, l_e, m_e, decay)
                l_e, m_e, cw = merged.logits, merged.masks, merged.client_weights
                ages_sum += merged.ages_sum
                n_contrib += merged.num_contributing
            if entropy_filter:  # per-client-row filter — shard-local is exact
                m_e = np.asarray(server_entropy_filter(
                    jnp.asarray(l_e), jnp.asarray(m_e)))
            if robust:
                # robust modes use staleness weights only as a
                # contribute/exclude mask (one vote per surviving client)
                m_r = m_e if cw is None else (m_e & (cw > 0.0)[:, None])
                t_e, _ = aggregation.robust_reduce(
                    jnp.asarray(l_e), jnp.asarray(m_r),
                    self.robust_aggregation, trim_frac=self.trim_frac)
                center = np.asarray(t_e)
                cnt = m_r.sum(axis=0).astype(np.float32)      # (t,)
                num, den = center * cnt[:, None], cnt
            else:
                m_r = m_e
                num, den = aggregation.partial_masked_sums(
                    jnp.asarray(l_e), jnp.asarray(m_e),
                    None if cw is None else jnp.asarray(cw),
                    guard_finite=self.sanitize)
                num, den = np.asarray(num), np.asarray(den)
                center = None
            if self.track_outliers:
                if center is None:
                    with np.errstate(invalid="ignore"):
                        center = num / np.maximum(den, 1.0)[:, None]
                d_e, c_e = aggregation.client_outlier_distance(
                    l_e, m_r, center)
                outlier[sl], contrib[sl] = d_e, c_e
            nums.append(num)
            dens.append(den)
        mean_staleness = (ages_sum / n_contrib
                          if subset and n_contrib else 0.0)
        return _PendingPartials(np.stack(nums), np.stack(dens),
                                uploaded_bytes, mean_staleness,
                                outlier, contrib)

    def aggregate_round(self, round_idx: int, *,
                        sharpen: Optional[float] = None,
                        entropy_filter: bool = False):
        """Fuse a previously ingested round into (teacher, valid,
        mean_staleness). Full-participation rounds take the exact legacy
        ``aggregate`` call (bit-for-bit the historical teacher and byte
        accounting); subset rounds aggregate the stale-merged rows with
        per-client staleness weights."""
        try:
            p = self._pending.pop(round_idx)
        except KeyError:
            raise ValueError(
                f"no ingested reports for round {round_idx}; call "
                "ingest_reports first") from None
        # aggregation consumes the round's parked reports — release their
        # admission-queue slots so later rounds stop being backpressured
        self._inflight_reports.pop(round_idx, None)
        if isinstance(p, _PendingPartials):
            # two-tier root: fuse the E edge partials (the filter and
            # staleness weights were already folded in at the edges)
            teacher, valid = aggregation.fuse_partial_sums(
                jnp.asarray(p.nums), jnp.asarray(p.dens),
                temperature_sharpen=sharpen)
            self.bytes_received += p.uploaded_bytes
            self.bytes_broadcast += int(teacher.shape[0]) * int(
                teacher.shape[-1]) * 4
            if self.track_outliers and p.outlier is not None:
                self._update_trust(round_idx, p.outlier, p.contrib)
            return (np.asarray(teacher), np.asarray(valid),
                    p.mean_staleness)
        if p.merged is None:
            teacher, valid = self.aggregate(p.logits, p.masks,
                                            sharpen=sharpen,
                                            entropy_filter=entropy_filter)
            if self.track_outliers:
                dist, contrib = aggregation.client_outlier_distance(
                    p.logits, p.masks, teacher)
                self._update_trust(round_idx, dist, contrib)
            return teacher, valid, 0.0
        teacher, valid = self.aggregate(
            p.merged.logits, p.merged.masks, sharpen=sharpen,
            entropy_filter=entropy_filter,
            client_weights=p.merged.client_weights,
            uploaded_rows=p.participants)
        if self.track_outliers:
            m_eff = (np.asarray(p.merged.masks, bool)
                     & (np.asarray(p.merged.client_weights) > 0.0)[:, None])
            dist, contrib = aggregation.client_outlier_distance(
                p.merged.logits, m_eff, teacher)
            self._update_trust(round_idx, dist, contrib)
        return teacher, valid, p.merged.mean_staleness

    def aggregate(self, logits, masks, *, sharpen: Optional[float] = None,
                  entropy_filter: bool = False, client_weights=None,
                  uploaded_rows=None):
        """logits: (C, t, K); masks: (C, t). Returns (teacher, valid).

        ``client_weights`` (C,) down-weights stale contributions by
        ``staleness_decay ** age`` (all-ones — every report fresh — takes
        the plain masked-mean path, bit-for-bit the legacy teacher).
        ``uploaded_rows`` (C,) restricts the upload accounting to clients
        that actually reported this round: stale reuse costs no bytes.
        """
        logits = jnp.asarray(logits)
        masks = jnp.asarray(masks)
        # clients uploaded the *pre-filter* ID rows — snapshot them before
        # the server-side filter tightens the masks, so bytes_received
        # prices what actually crossed the network (the filtered masks
        # undercounted the Selective-FD baseline's uploads)
        uploaded_masks = masks
        if entropy_filter:  # Selective-FD baseline's extra server stage
            masks = server_entropy_filter(logits, masks)
        cw = (None if client_weights is None
              else np.asarray(client_weights, np.float32))
        if self.robust_aggregation != "mean":
            # robust order statistics have no fractional voters: staleness
            # weights act only as a contribute/exclude mask here
            m_r = (masks if cw is None
                   else jnp.logical_and(masks,
                                        jnp.asarray(cw > 0.0)[:, None]))
            teacher, valid = aggregation.robust_reduce(
                logits, m_r, self.robust_aggregation,
                trim_frac=self.trim_frac, temperature_sharpen=sharpen)
        elif cw is not None and not bool(np.all(cw == 1.0)):
            teacher, valid = aggregation.weighted_masked_mean_logits(
                logits, masks, jnp.asarray(cw), temperature_sharpen=sharpen,
                guard_finite=self.sanitize)
        else:
            teacher, valid = aggregation.masked_mean_logits(
                logits, masks, temperature_sharpen=sharpen,
                guard_finite=self.sanitize)
        # accounting: clients upload only ID logits (mask-compressed), and
        # only the round's participants upload at all
        k = logits.shape[-1]
        up = (uploaded_masks if uploaded_rows is None
              else uploaded_masks[np.asarray(uploaded_rows, bool)])
        self.bytes_received += int(jnp.sum(up)) * k * 4
        self.bytes_broadcast += int(teacher.shape[0]) * k * 4
        return np.asarray(teacher), np.asarray(valid)

    def aggregate_classwise(self, means_counts, *, count_weighted: bool,
                            uploaded_rows=None,
                            round_idx: Optional[int] = None):
        """FKD/PLS: fuse per-class mean logits from all clients.

        ``uploaded_rows`` (C,) restricts the upload accounting to this
        round's participants (sampled-out clients hand in zero counts and
        upload nothing); ``None`` keeps the legacy everyone-uploads count.

        With ``num_edges > 1`` each edge reduces its client shard's
        classwise sums first and the root fuses E partials — a regrouped
        sum, identical up to float ordering.

        A robust ``robust_aggregation`` applies the same client-axis
        reducers to the ``(C, K_cls, K)`` stack (class slots standing in
        for proxy positions), unweighted — per-class sample counts become
        a contribute/exclude mask, one vote per reporting client. The
        classwise payload is tiny (K_cls · K), so the robust reduce is
        always global, even with ``num_edges > 1``.
        """
        means = jnp.stack([m for m, _ in means_counts])     # (C, K_cls, K)
        counts = jnp.stack([c for _, c in means_counts])    # (C, K_cls)
        if self.sanitize:
            mn = np.asarray(means, np.float32)
            cn = np.asarray(counts)
            fin = np.isfinite(mn).all(axis=-1)               # (C, K_cls)
            if not fin.all():
                per_client = ((cn > 0) & ~fin).sum(axis=1).astype(np.int64)
                n_bad = int(per_client.sum())
                if n_bad:
                    if round_idx is not None:
                        self._scrubbed_rounds[round_idx] = (
                            self._scrubbed_rounds.get(round_idx, 0) + n_bad)
                    self.scrub_total += n_bad
                    self._ensure_fleet(len(per_client))
                    self.scrub_clients += per_client
                means = jnp.asarray(np.where(fin[..., None], mn, 0.0))
                counts = jnp.asarray(np.where(fin, cn, 0))
        if self.robust_aggregation != "mean":
            teacher, valid = aggregation.robust_reduce(
                means, counts > 0, self.robust_aggregation,
                trim_frac=self.trim_frac)
            teacher, valid = jnp.asarray(teacher), jnp.asarray(valid)
        else:
            if count_weighted:
                w = counts[..., None]
            else:
                w = (counts > 0).astype(jnp.float32)[..., None]
            if self.num_edges > 1:
                shards = self._shards(int(means.shape[0]))
                num = sum(jnp.sum((means * w)[sl], axis=0) for sl in shards)
                den = sum(jnp.sum(w[sl], axis=0) for sl in shards)
            else:
                num = jnp.sum(means * w, axis=0)
                den = jnp.sum(w, axis=0)
            teacher = num / jnp.maximum(den, 1.0)
            valid = jnp.sum(counts, axis=0) > 0
        reporting = (means.shape[0] if uploaded_rows is None
                     else int(np.asarray(uploaded_rows, bool).sum()))
        self.bytes_received += reporting * int(np.prod(means.shape[1:])) * 4
        # the fused classwise teacher is broadcast to every client, exactly
        # like the proxy-logit teacher in ``aggregate`` (this path used to
        # report zero download traffic for FKD/PLS data-free rounds)
        self.bytes_broadcast += int(np.prod(teacher.shape)) * 4
        return np.asarray(teacher), np.asarray(valid)

    # ------------------------------------------------- resumable service
    def state_dict(self) -> dict:
        """All mutable server state (``repro.fed.state.ExperimentState``):
        rng, byte ledger, staleness buffers (flat + per-edge), shard
        bounds, admission-queue occupancy and the parked per-round report
        payloads. The proxy dataset is rebuilt from config, not captured.
        """
        from repro.fed.state import rng_state_dict
        pending = []
        for r in sorted(self._pending):
            p = self._pending[r]
            if isinstance(p, _PendingPartials):
                pending.append({
                    "round": r, "kind": "partials",
                    "nums": p.nums, "dens": p.dens,
                    "uploaded_bytes": int(p.uploaded_bytes),
                    "mean_staleness": float(p.mean_staleness),
                    "outlier": p.outlier, "contrib": p.contrib})
                continue
            m = p.merged
            pending.append({
                "round": r, "kind": "reports",
                "participants": p.participants,
                "logits": p.logits, "masks": p.masks,
                "merged": None if m is None else {
                    "logits": m.logits, "masks": m.masks,
                    "client_weights": m.client_weights,
                    "mean_staleness": float(m.mean_staleness),
                    "ages_sum": float(m.ages_sum),
                    "num_contributing": int(m.num_contributing)}})
        return {
            "rng": rng_state_dict(self.rng),
            "bytes_received": int(self.bytes_received),
            "bytes_broadcast": int(self.bytes_broadcast),
            "stale": (None if self._stale is None
                      else self._stale.state_dict()),
            "edge_stale": [None if b is None else b.state_dict()
                           for b in self._edge_stale],
            "shard_bounds": (None if self._shard_slices is None
                             else [[s.start, s.stop]
                                   for s in self._shard_slices]),
            "inflight_reports": [[r, n] for r, n
                                 in sorted(self._inflight_reports.items())],
            "pending": pending,
            "student": (None if self.student is None
                        else self.student.state_dict()),
            # defense stack: sanitize accounting + trust/quarantine (all
            # optional on load, so pre-robustness checkpoints stay valid)
            "scrub_total": int(self.scrub_total),
            "scrub_clients": self.scrub_clients,
            "scrubbed_rounds": [[r, n] for r, n
                                in sorted(self._scrubbed_rounds.items())],
            "trust": self.trust,
            "quarantined_until": self.quarantined_until,
            "strikes": self.strikes,
            "round_outlier": [[r, a] for r, a
                              in sorted(self._round_outlier.items())],
            "quarantine_events": [
                [r, [int(c) for c in ids]]
                for r, ids in sorted(self._quarantine_events.items())],
        }

    def load_state_dict(self, sd: dict) -> None:
        from repro.fed.state import load_rng_state, opt_array
        load_rng_state(self.rng, sd["rng"])
        self.bytes_received = int(sd["bytes_received"])
        self.bytes_broadcast = int(sd["bytes_broadcast"])
        self._stale = (None if sd["stale"] is None
                       else StalenessBuffer.from_state_dict(sd["stale"]))
        self._edge_stale = [
            None if b is None else StalenessBuffer.from_state_dict(b)
            for b in (sd.get("edge_stale") or [])]
        bounds = sd.get("shard_bounds")
        self._shard_slices = (None if bounds is None
                              else [slice(int(a), int(b))
                                    for a, b in bounds])
        self._inflight_reports = {int(r): int(n)
                                  for r, n in sd.get("inflight_reports", [])}
        self._pending = {}
        for e in sd["pending"]:
            r = int(e["round"])
            if e["kind"] == "partials":
                self._pending[r] = _PendingPartials(
                    np.asarray(e["nums"]), np.asarray(e["dens"]),
                    int(e["uploaded_bytes"]), float(e["mean_staleness"]),
                    opt_array(e.get("outlier"), np.float64),
                    opt_array(e.get("contrib"), bool))
                continue
            m = e["merged"]
            merged = None if m is None else StaleMerge(
                np.asarray(m["logits"], np.float32),
                np.asarray(m["masks"], bool),
                np.asarray(m["client_weights"], np.float32),
                float(m["mean_staleness"]), float(m["ages_sum"]),
                int(m["num_contributing"]))
            self._pending[r] = _PendingReports(
                opt_array(e["participants"], bool),
                opt_array(e["logits"], np.float32),
                opt_array(e["masks"], bool), merged)
        # the student object (model/opt/jit) is rebuilt from config by the
        # simulator; here we only restore its mutable tensors + rng
        student = sd.get("student")
        if student is not None and self.student is not None:
            self.student.load_state_dict(student)
        # defense stack (absent in pre-robustness checkpoints)
        self.scrub_total = int(sd.get("scrub_total", 0))
        self.scrub_clients = opt_array(sd.get("scrub_clients"), np.int64)
        self._scrubbed_rounds = {int(r): int(n)
                                 for r, n in sd.get("scrubbed_rounds", [])}
        self.trust = opt_array(sd.get("trust"), np.float64)
        self.quarantined_until = opt_array(sd.get("quarantined_until"),
                                           np.int64)
        self.strikes = opt_array(sd.get("strikes"), np.int64)
        self._round_outlier = {int(r): np.asarray(a, np.float64)
                               for r, a in sd.get("round_outlier", [])}
        self._quarantine_events = {
            int(r): [int(c) for c in ids]
            for r, ids in sd.get("quarantine_events", [])}
