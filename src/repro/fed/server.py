"""Federated server: proxy bookkeeping + aggregation. Trusted entity that
never trains a model (EdgeFD needs no pre-trained teacher).

Report *ingest* and *aggregation* are separate steps so in-flight rounds
can interleave (``repro.fed.scheduler`` with ``round_mode="overlap"``):
``ingest_reports`` records a round's engine outputs — merging stale rows
from the ``StalenessBuffer`` at ingest time, while the buffer still
reflects only earlier rounds — and ``aggregate_round`` later fuses the
recorded reports into a teacher. Under the lockstep ``sync`` mode the two
run back-to-back and reproduce the historical single-call path
bit-for-bit."""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import aggregation
from repro.core.filtering import server_entropy_filter
from repro.data.proxy import ProxyData, select_round_indices
from repro.fed.participation import StaleMerge, StalenessBuffer


class _PendingReports(NamedTuple):
    """One round's ingested-but-not-yet-aggregated proxy reports.

    Exactly one payload is held: the raw engine outputs on the
    full-participation path, or the stale-merged rows on the subset path
    (keeping both would double the in-flight footprint — overlap mode
    parks up to ``max_inflight`` of these)."""
    participants: Optional[np.ndarray]   # (C,) bool, None = everyone
    logits: Optional[np.ndarray]         # (C, t, K); None when merged is set
    masks: Optional[np.ndarray]          # (C, t);   None when merged is set
    merged: Optional[StaleMerge]         # stale-filled rows (subset rounds)


class Server:
    def __init__(self, proxy: ProxyData, *, seed: int = 0):
        self.proxy = proxy
        self.rng = np.random.default_rng(seed + 7)
        self.bytes_received = 0
        self.bytes_broadcast = 0
        # lazily-sized staleness buffer (partial participation only): the
        # last report of every client, by proxy-dataset position
        self._stale: Optional[StalenessBuffer] = None
        # rounds whose reports were ingested but not yet aggregated,
        # keyed by round index (overlap mode keeps up to max_inflight here)
        self._pending: Dict[int, _PendingReports] = {}

    def select_indices(self, batch: int) -> np.ndarray:
        return select_round_indices(self.rng, self.proxy, batch)

    def merge_stale(self, round_idx: int, participants, idx, logits, masks,
                    *, decay: float) -> StaleMerge:
        """Record this round's fresh reports and fill non-participant rows
        from each client's last report (``repro.fed.participation``)."""
        if self._stale is None:
            c, _, k = np.asarray(logits).shape
            self._stale = StalenessBuffer(c, len(self.proxy.x), k)
        return self._stale.merge(round_idx, participants, idx, logits, masks,
                                 decay)

    def ingest_reports(self, round_idx: int, participants, idx, logits,
                       masks, *, decay: float) -> None:
        """Record one round's engine reports for a later ``aggregate_round``.

        Stale rows are merged *now*: ingests arrive in round order (the
        scheduler's order edges guarantee it), so the buffer reflects
        exactly the rounds before this one and report ages can never go
        negative — even while later rounds' aggregations are still pending.
        ``participants=None`` (full participation) skips the buffer
        entirely, keeping the legacy everyone-reports path untouched.
        """
        if round_idx in self._pending:
            raise ValueError(f"round {round_idx} reports already ingested "
                             "and not yet aggregated")
        if participants is None:
            self._pending[round_idx] = _PendingReports(
                None, logits, masks, None)
            return
        merged = self.merge_stale(round_idx, participants, idx, logits,
                                  masks, decay=decay)
        self._pending[round_idx] = _PendingReports(
            participants, None, None, merged)

    def aggregate_round(self, round_idx: int, *,
                        sharpen: Optional[float] = None,
                        entropy_filter: bool = False):
        """Fuse a previously ingested round into (teacher, valid,
        mean_staleness). Full-participation rounds take the exact legacy
        ``aggregate`` call (bit-for-bit the historical teacher and byte
        accounting); subset rounds aggregate the stale-merged rows with
        per-client staleness weights."""
        try:
            p = self._pending.pop(round_idx)
        except KeyError:
            raise ValueError(
                f"no ingested reports for round {round_idx}; call "
                "ingest_reports first") from None
        if p.merged is None:
            teacher, valid = self.aggregate(p.logits, p.masks,
                                            sharpen=sharpen,
                                            entropy_filter=entropy_filter)
            return teacher, valid, 0.0
        teacher, valid = self.aggregate(
            p.merged.logits, p.merged.masks, sharpen=sharpen,
            entropy_filter=entropy_filter,
            client_weights=p.merged.client_weights,
            uploaded_rows=p.participants)
        return teacher, valid, p.merged.mean_staleness

    def aggregate(self, logits, masks, *, sharpen: Optional[float] = None,
                  entropy_filter: bool = False, client_weights=None,
                  uploaded_rows=None):
        """logits: (C, t, K); masks: (C, t). Returns (teacher, valid).

        ``client_weights`` (C,) down-weights stale contributions by
        ``staleness_decay ** age`` (all-ones — every report fresh — takes
        the plain masked-mean path, bit-for-bit the legacy teacher).
        ``uploaded_rows`` (C,) restricts the upload accounting to clients
        that actually reported this round: stale reuse costs no bytes.
        """
        logits = jnp.asarray(logits)
        masks = jnp.asarray(masks)
        if entropy_filter:  # Selective-FD baseline's extra server stage
            masks = server_entropy_filter(logits, masks)
        cw = (None if client_weights is None
              else np.asarray(client_weights, np.float32))
        if cw is not None and not bool(np.all(cw == 1.0)):
            teacher, valid = aggregation.weighted_masked_mean_logits(
                logits, masks, jnp.asarray(cw), temperature_sharpen=sharpen)
        else:
            teacher, valid = aggregation.masked_mean_logits(
                logits, masks, temperature_sharpen=sharpen)
        # accounting: clients upload only ID logits (mask-compressed), and
        # only the round's participants upload at all
        k = logits.shape[-1]
        up = (masks if uploaded_rows is None
              else masks[np.asarray(uploaded_rows, bool)])
        self.bytes_received += int(jnp.sum(up)) * k * 4
        self.bytes_broadcast += int(teacher.shape[0]) * k * 4
        return np.asarray(teacher), np.asarray(valid)

    def aggregate_classwise(self, means_counts, *, count_weighted: bool,
                            uploaded_rows=None):
        """FKD/PLS: fuse per-class mean logits from all clients.

        ``uploaded_rows`` (C,) restricts the upload accounting to this
        round's participants (sampled-out clients hand in zero counts and
        upload nothing); ``None`` keeps the legacy everyone-uploads count.
        """
        means = jnp.stack([m for m, _ in means_counts])     # (C, K_cls, K)
        counts = jnp.stack([c for _, c in means_counts])    # (C, K_cls)
        if count_weighted:
            w = counts[..., None]
        else:
            w = (counts > 0).astype(jnp.float32)[..., None]
        teacher = jnp.sum(means * w, axis=0) / jnp.maximum(jnp.sum(w, axis=0), 1.0)
        valid = jnp.sum(counts, axis=0) > 0
        reporting = (means.shape[0] if uploaded_rows is None
                     else int(np.asarray(uploaded_rows, bool).sum()))
        self.bytes_received += reporting * int(np.prod(means.shape[1:])) * 4
        return np.asarray(teacher), np.asarray(valid)
