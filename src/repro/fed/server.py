"""Federated server: proxy bookkeeping + aggregation. Trusted entity that
never trains a model (EdgeFD needs no pre-trained teacher)."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import aggregation
from repro.core.filtering import server_entropy_filter
from repro.data.proxy import ProxyData, select_round_indices
from repro.fed.participation import StaleMerge, StalenessBuffer


class Server:
    def __init__(self, proxy: ProxyData, *, seed: int = 0):
        self.proxy = proxy
        self.rng = np.random.default_rng(seed + 7)
        self.bytes_received = 0
        self.bytes_broadcast = 0
        # lazily-sized staleness buffer (partial participation only): the
        # last report of every client, by proxy-dataset position
        self._stale: Optional[StalenessBuffer] = None

    def select_indices(self, batch: int) -> np.ndarray:
        return select_round_indices(self.rng, self.proxy, batch)

    def merge_stale(self, round_idx: int, participants, idx, logits, masks,
                    *, decay: float) -> StaleMerge:
        """Record this round's fresh reports and fill non-participant rows
        from each client's last report (``repro.fed.participation``)."""
        if self._stale is None:
            c, _, k = np.asarray(logits).shape
            self._stale = StalenessBuffer(c, len(self.proxy.x), k)
        return self._stale.merge(round_idx, participants, idx, logits, masks,
                                 decay)

    def aggregate(self, logits, masks, *, sharpen: Optional[float] = None,
                  entropy_filter: bool = False, client_weights=None,
                  uploaded_rows=None):
        """logits: (C, t, K); masks: (C, t). Returns (teacher, valid).

        ``client_weights`` (C,) down-weights stale contributions by
        ``staleness_decay ** age`` (all-ones — every report fresh — takes
        the plain masked-mean path, bit-for-bit the legacy teacher).
        ``uploaded_rows`` (C,) restricts the upload accounting to clients
        that actually reported this round: stale reuse costs no bytes.
        """
        logits = jnp.asarray(logits)
        masks = jnp.asarray(masks)
        if entropy_filter:  # Selective-FD baseline's extra server stage
            masks = server_entropy_filter(logits, masks)
        cw = (None if client_weights is None
              else np.asarray(client_weights, np.float32))
        if cw is not None and not bool(np.all(cw == 1.0)):
            teacher, valid = aggregation.weighted_masked_mean_logits(
                logits, masks, jnp.asarray(cw), temperature_sharpen=sharpen)
        else:
            teacher, valid = aggregation.masked_mean_logits(
                logits, masks, temperature_sharpen=sharpen)
        # accounting: clients upload only ID logits (mask-compressed), and
        # only the round's participants upload at all
        k = logits.shape[-1]
        up = (masks if uploaded_rows is None
              else masks[np.asarray(uploaded_rows, bool)])
        self.bytes_received += int(jnp.sum(up)) * k * 4
        self.bytes_broadcast += int(teacher.shape[0]) * k * 4
        return np.asarray(teacher), np.asarray(valid)

    def aggregate_classwise(self, means_counts, *, count_weighted: bool,
                            uploaded_rows=None):
        """FKD/PLS: fuse per-class mean logits from all clients.

        ``uploaded_rows`` (C,) restricts the upload accounting to this
        round's participants (sampled-out clients hand in zero counts and
        upload nothing); ``None`` keeps the legacy everyone-uploads count.
        """
        means = jnp.stack([m for m, _ in means_counts])     # (C, K_cls, K)
        counts = jnp.stack([c for _, c in means_counts])    # (C, K_cls)
        if count_weighted:
            w = counts[..., None]
        else:
            w = (counts > 0).astype(jnp.float32)[..., None]
        teacher = jnp.sum(means * w, axis=0) / jnp.maximum(jnp.sum(w, axis=0), 1.0)
        valid = jnp.sum(counts, axis=0) > 0
        reporting = (means.shape[0] if uploaded_rows is None
                     else int(np.asarray(uploaded_rows, bool).sum()))
        self.bytes_received += reporting * int(np.prod(means.shape[1:])) * 4
        return np.asarray(teacher), np.asarray(valid)
