"""Externalized experiment state: everything a federated run needs to resume.

A batch simulator can keep all mid-experiment state implicit in one
process; a long-running federated *service* cannot — scheduler in-flight
rounds, ``StalenessBuffer`` contents, rng streams and engine parameters
must survive a restart. ``ExperimentState`` is the explicit, serializable
container for that state, assembled by ``RoundScheduler.snapshot()`` from
per-layer ``state_dict()`` hooks (``Server``, ``StalenessBuffer``,
``SimTimeline``, both engines) and written through
``repro.checkpoint.ckpt.save_state`` (atomic write, retention, corrupt-
file fallback).

Everything in here is *mutable* run state. Deterministically rebuildable
structure — datasets, partitions, client model definitions, learned DREs
(their fit consumes only ``(seed, private data)``) — is deliberately NOT
captured: a resume first rebuilds the experiment from the same
``FedConfig`` and then overlays this state, which keeps checkpoints small
and engine-portable (a loop-engine checkpoint restores into a cohort or
mesh-sharded engine and vice versa, because engine ``state_dict()``s are
keyed per client).

The round-boundary invariants that make the bit-for-bit resume guarantee
hold:

  * every rng that advances during rounds is captured exactly (numpy
    ``Generator.bit_generator.state`` — the 128-bit PCG64 words serialize
    as arbitrary-width JSON ints);
  * participation/churn/dropout/arrival draws are stateless in
    ``(seed, round, client)`` (``repro.fed.participation`` / ``clock``),
    so they need no cursor beyond the round indices already in the
    scheduler's node sets;
  * reports are ingested in round order, so the parked ``Server._pending``
    payloads plus the buffers reproduce any in-flight aggregation.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import numpy as np

STATE_VERSION = 1


def rng_state_dict(gen: np.random.Generator) -> Dict[str, Any]:
    """Serializable bit-generator state of a numpy ``Generator``.

    The returned dict is JSON-able as-is: PCG64's 128-bit state/inc words
    are plain python ints, which ``ckpt.save_state`` round-trips at full
    width (they do NOT fit a uint64 array leaf).
    """
    return gen.bit_generator.state


def load_rng_state(gen: np.random.Generator, state: Dict[str, Any]) -> None:
    """Restore a ``Generator`` in place from ``rng_state_dict`` output."""
    gen.bit_generator.state = state


def opt_array(x: Optional[np.ndarray], dtype=None) -> Optional[np.ndarray]:
    """``None``-preserving ``np.asarray`` (mask/participant fields)."""
    if x is None:
        return None
    return np.asarray(x) if dtype is None else np.asarray(x, dtype)


def clients_state_dict(clients) -> Dict[str, Any]:
    """Per-client mutable state, ordered by position in the client list.

    The single engine checkpoint format: both engines emit it (the cohort
    engine syncs its stacked/host-master state back to the ``Client``
    objects first), so a checkpoint written under one engine restores
    under any other — loop, cohort, mesh-sharded or waved.
    """
    from repro.checkpoint.ckpt import flatten_tree
    return {"clients": [
        {"cid": int(c.cid),
         "params": flatten_tree(c.params),
         "opt_state": flatten_tree(c.opt_state),
         "rng": rng_state_dict(c.rng)}
        for c in clients]}


def load_clients_state_dict(clients, sd: Dict[str, Any]) -> None:
    """Restore ``clients_state_dict`` output onto ``Client`` objects."""
    import jax
    import jax.numpy as jnp

    from repro.checkpoint.ckpt import unflatten_like
    entries = sd["clients"]
    if len(entries) != len(clients):
        raise ValueError(
            f"checkpoint holds {len(entries)} clients but the experiment "
            f"built {len(clients)} — the FedConfig does not match")
    for c, e in zip(clients, entries):
        if int(e["cid"]) != int(c.cid):
            raise ValueError(
                f"client order mismatch: checkpoint cid {e['cid']} at "
                f"position of client {c.cid}")
        c.params = jax.tree.map(
            jnp.asarray,
            unflatten_like(e["params"], c.params,
                           source=f"client {c.cid} params"))
        c.opt_state = jax.tree.map(
            jnp.asarray,
            unflatten_like(e["opt_state"], c.opt_state,
                           source=f"client {c.cid} opt_state"))
        load_rng_state(c.rng, e["rng"])


@dataclasses.dataclass
class ExperimentState:
    """One resumable snapshot of a federated run, at a phase boundary.

    ``scheduler`` holds the node bookkeeping (pending/done node lists,
    execution trace, per-node simulated finish times, the round window)
    plus one payload dict per *in-flight* round — a round whose nodes are
    only partially executed (overlap mode parks up to ``max_inflight`` of
    these). ``server`` / ``timeline`` / ``engine`` are the per-layer
    ``state_dict()`` outputs. ``logs`` carries the retired rounds'
    ``RoundLog``s so a resumed service owns the full history.
    """
    version: int
    round_mode: str
    scheduler: Dict[str, Any]
    timeline: Dict[str, Any]
    server: Dict[str, Any]
    engine: Dict[str, Any]
    logs: List[Dict[str, Any]]

    def to_tree(self) -> Dict[str, Any]:
        """Plain nested-dict form for ``ckpt.save_state``."""
        return dataclasses.asdict(self)

    @classmethod
    def from_tree(cls, tree: Dict[str, Any]) -> "ExperimentState":
        got = int(tree.get("version", -1))
        if got != STATE_VERSION:
            raise ValueError(
                f"experiment-state version {got} is not the supported "
                f"{STATE_VERSION} — this checkpoint was written by an "
                "incompatible build")
        return cls(**{f.name: tree[f.name]
                      for f in dataclasses.fields(cls)})
