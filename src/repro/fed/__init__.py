from repro.fed.client import Client
from repro.fed.server import Server
from repro.fed import simulator
