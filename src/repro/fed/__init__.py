from repro.fed import simulator
from repro.fed.batching import epoch_batches, steps_per_epoch
from repro.fed.client import Client
from repro.fed.clock import SimTimeline, client_speeds
from repro.fed.cohort import CohortEngine
from repro.fed.mesh import build_client_mesh
from repro.fed.scheduler import RoundScheduler
from repro.fed.server import Server
