"""Phase-graph round scheduler: lockstep (sync) and overlapping (overlap).

Algorithm 1's training iteration is not one monolithic step — it is five
phases with explicit data dependencies::

    local_train ──▶ report ──▶ aggregate ──▶ distill ──▶ eval

(``report``/``aggregate``/``distill`` are the proxy-logit exchange for the
distillation methods, the class-wise exchange for the data-free methods,
and absent for ``indlearn``.) This module makes that graph explicit: every
round contributes one node per phase, nodes declare their dependencies,
and a deterministic executor runs whatever is ready. ``FedConfig.
round_mode`` selects between two dependency sets:

``sync`` (the default)
    ``local_train(r)`` additionally depends on ``eval(r-1)`` — a full
    barrier between rounds. The executor then replays the exact legacy
    ``run_round`` phase order, bit-for-bit (golden-pinned in
    ``tests/test_scheduler.py``).

``overlap``
    ``local_train(r)`` depends on ``eval(r - max_inflight)`` instead, so
    up to ``max_inflight`` rounds are in flight at once: round ``r+1``
    trains and reports while round ``r`` aggregates and distills, with
    non-participant knowledge draining through the server's existing
    ``StalenessBuffer`` (reports are ingested in round order, so buffer
    ages never go negative). Numerically this is a *different protocol* —
    round ``r+1`` trains on models that have not yet seen round ``r``'s
    teacher — which is exactly the asynchrony edge deployments pay for
    overlap; final accuracy stays within tolerance of lockstep
    (``benchmarks/async_rounds.py``).

The executor's ready-node policy is what creates the pipeline: client-side
*front* phases (``local_train``, ``report``) run before server-side
*drain* phases (``aggregate``, ``distill``, ``eval``), oldest round first
within each class. Under ``sync`` only one node is ever ready, so the
policy degenerates to the lockstep order; under ``overlap`` it interleaves
rounds like a software pipeline. The policy is engine-independent, so loop
== cohort == mesh-sharded round logs still match under ``overlap``.

Every node execution is timed (``RoundLog.phase_s``) and priced onto the
simulated straggler timeline (``repro.fed.clock``): clients run in
parallel at deterministic per-client speeds, the server is one serial
resource, and ``RoundLog.sim_finish_s`` records when the round retires on
that timeline. That is the axis on which overlap measurably beats sync on
a single host (``BENCH_async.json``).

``REPRO_ROUND_MODE`` (env) fills in for ``round_mode="auto"`` the way
``REPRO_KERNEL_BACKEND`` does for the kernel dispatch layer — a CI
vehicle for running the whole test suite through the overlap scheduler.
Explicit ``sync``/``overlap`` always win over the env var.

Two extensions ride the same graph:

**Concurrent cohorts** (``FedConfig.concurrent_cohorts=True``): client-side
phase nodes (``local_train``/``report``/``distill``) are keyed per cohort —
``(phase, round, cohort)`` — so a heterogeneous zoo's cohorts pipeline
independently: cohort A distills round ``r`` while cohort B already trains
round ``r+1`` on the simulated timeline (admission is per cohort:
``local_train(r, c)`` waits on ``distill(r - max_inflight, c)`` of *its own*
cohort, with host-order edges keeping execution deterministic and
bit-for-bit the serial schedule when only one cohort exists). Aggregation
stays a global barrier — the protocol needs every cohort's report — so the
win is cross-round desynchronization, measurable with per-cohort phase
costs (``sim_phase_costs["phase@cohort"]``, ``benchmarks/hetero_zoo.py``).

**FedDF ensemble server** (``method="server_distill"``): a ``server_distill``
phase node between ``aggregate`` and ``distill`` trains the server's central
student against the masked/weighted ensemble teacher
(``Server.ensemble_distill``), priced on the serial server lane and
checkpointable like every other phase.
"""
from __future__ import annotations

import os
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.protocol import RoundLog
from repro.fed.clock import (ARRIVAL_PROCESSES, SimTimeline, arrival_offsets,
                             client_speeds, dropout_mask, online_mask)
from repro.fed.faults import FaultInjector, validate_fault_config
from repro.fed.participation import sample_participants

ROUND_MODES = ("sync", "overlap")
# the five phase names, in intra-round dependency order
PHASE_ORDER = ("local_train", "report", "aggregate", "distill", "eval")
# client-side phases that admit new rounds into the pipeline; the rest
# drain old ones ("eval" is bookkeeping but retires the round, so it
# drains too)
FRONT_PHASES = frozenset({"local_train", "report"})
# phases priced on client lanes of the simulated timeline ("aggregate" is
# the serial server resource, "eval" is free measurement)
CLIENT_PHASES = frozenset({"local_train", "report", "distill"})


def resolve_round_mode(mode: Optional[str]) -> str:
    """``auto`` → the ``REPRO_ROUND_MODE`` env var if set, else ``sync``.

    Explicit ``sync``/``overlap`` always win — the env var exists so CI can
    run the whole suite through the overlap scheduler without touching
    every config (mirroring ``REPRO_KERNEL_BACKEND``)."""
    if mode in (None, "auto"):
        env = os.environ.get("REPRO_ROUND_MODE")
        # an empty or "auto" env value means "no opinion" (the CI matrix
        # exports the literal matrix cell, which is "auto" off the
        # overlap entry)
        mode = env if env not in (None, "", "auto") else "sync"
    if mode not in ROUND_MODES:
        raise ValueError(f"unknown round_mode {mode!r}; known: auto, "
                         + ", ".join(ROUND_MODES))
    return mode


def validate_config(cfg) -> None:
    """Fail fast on an inconsistent scheduler config (FedConfig-like)."""
    resolve_round_mode(cfg.round_mode)
    if cfg.max_inflight < 1:
        raise ValueError(
            f"max_inflight must be >= 1 (1 = lockstep), got "
            f"{cfg.max_inflight!r}")
    if cfg.straggler_factor < 1.0:
        raise ValueError(
            f"straggler_factor must be >= 1.0 (1.0 = homogeneous fleet), "
            f"got {cfg.straggler_factor!r}")
    f = cfg.participation_fraction
    if not 0.0 < f <= 1.0:
        raise ValueError(
            f"participation_fraction must be in (0, 1], got {f!r}")
    if cfg.arrival_process not in ARRIVAL_PROCESSES:
        raise ValueError(
            f"unknown arrival_process {cfg.arrival_process!r}; known: "
            + ", ".join(ARRIVAL_PROCESSES))
    if cfg.arrival_spread < 0.0:
        raise ValueError(
            f"arrival_spread must be >= 0, got {cfg.arrival_spread!r}")
    if cfg.arrival_bursts < 1:
        raise ValueError(
            f"arrival_bursts must be >= 1, got {cfg.arrival_bursts!r}")
    for knob in ("churn_prob", "dropout_prob"):
        v = getattr(cfg, knob)
        if not 0.0 <= v < 1.0:
            raise ValueError(f"{knob} must be in [0, 1), got {v!r}")
    if getattr(cfg, "max_pending_reports", 0) < 0:
        raise ValueError(
            f"max_pending_reports must be >= 0 (0 = unbounded), got "
            f"{cfg.max_pending_reports!r}")
    validate_fault_config(getattr(cfg, "fault_mode", "none"),
                          getattr(cfg, "fault_prob", 0.0),
                          getattr(cfg, "byzantine_frac", 0.0),
                          getattr(cfg, "fault_start", 0),
                          getattr(cfg, "fault_duration", 0))
    # robust_aggregation / trust knobs are validated where they land (the
    # Server constructor); the watchdog knobs live here with the scheduler
    if getattr(cfg, "watchdog_max_rollbacks", 3) < 0:
        raise ValueError(
            f"watchdog_max_rollbacks must be >= 0, got "
            f"{cfg.watchdog_max_rollbacks!r}")
    if getattr(cfg, "watchdog_acc_drop", 0.2) <= 0.0:
        raise ValueError(
            f"watchdog_acc_drop must be > 0, got "
            f"{cfg.watchdog_acc_drop!r}")
    if getattr(cfg, "watchdog_loss_factor", 10.0) <= 1.0:
        raise ValueError(
            f"watchdog_loss_factor must be > 1, got "
            f"{cfg.watchdog_loss_factor!r}")


def round_phases(method) -> Tuple[str, ...]:
    """The phase nodes one round of ``method`` contributes to the graph."""
    if method.name == "indlearn":  # no collaboration: train, then measure
        return ("local_train", "eval")
    if getattr(method, "server_distill", False):
        # FedDF: the server student trains on the fused teacher before the
        # clients distill — a serial server-lane node riding the graph
        return ("local_train", "report", "aggregate", "server_distill",
                "distill", "eval")
    return PHASE_ORDER


def _entry(engine, phase_name: str, legacy_name: str) -> Callable:
    """Resolve an engine phase entry point, preferring the per-phase
    interface and falling back to the historical ``*_all`` mega-call (so
    pre-built duck-typed engines keep working unchanged)."""
    fn = getattr(engine, phase_name, None)
    return fn if fn is not None else getattr(engine, legacy_name)


class _RoundState:
    """Mutable state threaded between one round's phase nodes."""

    __slots__ = ("r", "part", "kw", "idx", "px", "powner", "means_counts",
                 "teacher", "valid", "teacher_by_class", "valid_by_class",
                 "local_losses", "distill_losses", "id_frac",
                 "mean_staleness", "accs", "phase_s", "sim_finish_s",
                 "report_payload", "rpart", "sampled", "reports_pending",
                 "report_logits", "report_masks", "report_arrival",
                 "server_distill_loss", "server_student_acc")

    def __init__(self, r: int):
        self.r = r
        self.part = None            # participation mask (None = everyone)
        self.kw: Dict = {}          # engine kwargs ({} keeps the legacy
        #                             call sequence at fraction 1)
        self.idx = None             # proxy indices / batch / owners
        self.px = None
        self.powner = None
        self.means_counts = None    # data-free report payload
        self.teacher = None         # aggregation outputs
        self.valid = None
        self.teacher_by_class = None
        self.valid_by_class = None
        self.local_losses: List[float] = []
        self.distill_losses: List[float] = []
        self.id_frac = 1.0
        self.mean_staleness = 0.0
        self.accs = None
        self.phase_s: Dict[str, float] = {}
        self.sim_finish_s = 0.0
        # (logits, masks) parked between the report body and the
        # post-pricing ingest event; consumed within the same node
        # execution, so never present at a phase boundary
        self.report_payload = None
        # --- concurrent-cohort bookkeeping (serial mode leaves these at
        # their defaults). The round's *reporting* participants: training
        # participation (st.part) minus mid-round dropout and admission
        # overflow — serial mode mutates st.part in place instead, but with
        # per-cohort nodes a later cohort's local_train may still need the
        # pre-dropout mask. None = same as st.part.
        self.rpart = None
        self.sampled = False        # participation drawn for this round?
        # per-cohort report accumulation: cohort report nodes fill their
        # rows here; ingestion fires at the round's last report node. These
        # DO live across phase boundaries, so they are checkpointed.
        self.reports_pending = None
        self.report_logits = None
        self.report_masks = None
        # per-client simulated report-arrival times, captured when each
        # report node is priced (later nodes may advance the lanes before
        # the round ingests, so arrival order must be pinned at pricing)
        self.report_arrival = None
        # FedDF ensemble server (method="server_distill")
        self.server_distill_loss = 0.0
        self.server_student_acc = None

    def state_dict(self) -> Dict:
        """Mutable payload of a partially-executed (in-flight) round.

        ``px``/``powner``/``kw`` are derived fields (recomputed from
        ``idx``/``part`` on restore) and ``report_payload`` is transient
        within one node execution, so none of them is captured. Losses and
        accuracies are plain python floats end-to-end, which the JSON
        manifest round-trips exactly (``repr`` round-trip)."""
        from repro.fed.state import opt_array
        return {
            "r": int(self.r),
            "part": opt_array(self.part, bool),
            "idx": opt_array(self.idx),
            "means_counts": (None if self.means_counts is None
                             else [[np.asarray(m), np.asarray(c)]
                                   for m, c in self.means_counts]),
            "teacher": opt_array(self.teacher),
            "valid": opt_array(self.valid),
            "teacher_by_class": opt_array(self.teacher_by_class),
            "valid_by_class": opt_array(self.valid_by_class),
            "local_losses": [float(v) for v in self.local_losses],
            "distill_losses": [float(v) for v in self.distill_losses],
            "id_frac": float(self.id_frac),
            "mean_staleness": float(self.mean_staleness),
            "accs": (None if self.accs is None
                     else [float(a) for a in self.accs]),
            "phase_s": {k: float(v) for k, v in self.phase_s.items()},
            "sim_finish_s": float(self.sim_finish_s),
            "rpart": opt_array(self.rpart, bool),
            "sampled": bool(self.sampled),
            "reports_pending": (None if self.reports_pending is None
                                else int(self.reports_pending)),
            "report_logits": opt_array(self.report_logits),
            "report_masks": opt_array(self.report_masks, bool),
            "report_arrival": opt_array(self.report_arrival),
            "server_distill_loss": float(self.server_distill_loss),
            "server_student_acc": (None if self.server_student_acc is None
                                   else float(self.server_student_acc)),
        }

    def load_state_dict(self, sd: Dict, scheduler) -> None:
        from repro.fed.state import opt_array
        self.part = opt_array(sd["part"], bool)
        self.kw = {} if self.part is None else {"participants": self.part}
        self.idx = opt_array(sd["idx"])
        if self.idx is not None:
            self.px = scheduler.server.proxy.x[self.idx]
            self.powner = scheduler.server.proxy.owner[self.idx]
        mc = sd["means_counts"]
        self.means_counts = (None if mc is None
                             else [(np.asarray(m), np.asarray(c))
                                   for m, c in mc])
        self.teacher = opt_array(sd["teacher"])
        self.valid = opt_array(sd["valid"])
        self.teacher_by_class = opt_array(sd["teacher_by_class"])
        self.valid_by_class = opt_array(sd["valid_by_class"])
        self.local_losses = [float(v) for v in sd["local_losses"]]
        self.distill_losses = [float(v) for v in sd["distill_losses"]]
        self.id_frac = float(sd["id_frac"])
        self.mean_staleness = float(sd["mean_staleness"])
        accs = sd["accs"]
        self.accs = None if accs is None else [float(a) for a in accs]
        self.phase_s = {k: float(v) for k, v in sd["phase_s"].items()}
        self.sim_finish_s = float(sd["sim_finish_s"])
        # concurrent-cohort / ensemble-server fields (``.get``: absent from
        # checkpoints written before these features existed — the defaults
        # are exactly the serial-mode values)
        self.rpart = opt_array(sd.get("rpart"), bool)
        self.sampled = bool(sd.get("sampled", False))
        rp = sd.get("reports_pending")
        self.reports_pending = None if rp is None else int(rp)
        self.report_logits = opt_array(sd.get("report_logits"))
        self.report_masks = opt_array(sd.get("report_masks"), bool)
        self.report_arrival = opt_array(sd.get("report_arrival"))
        self.server_distill_loss = float(sd.get("server_distill_loss", 0.0))
        acc = sd.get("server_student_acc")
        self.server_student_acc = None if acc is None else float(acc)


class RoundScheduler:
    """Executes the round phase graph over an engine/server pair.

    One scheduler instance owns one contiguous run of rounds: the straggler
    timeline, the execution trace and the server's in-flight report records
    all live here. ``run_round``/``run_experiment`` are thin drivers over
    this class.

    ``sim_phase_costs`` (tests/benchmark harnesses) replaces the measured
    per-phase host seconds with fixed base costs, making the simulated
    timeline fully deterministic; ``None`` (the default) prices phases at
    their measured wall-clock.
    """

    def __init__(self, engine, server, method, cfg, x_test, y_test, *,
                 sim_phase_costs: Optional[Dict[str, float]] = None):
        validate_config(cfg)
        self.engine = engine
        self.server = server
        self.method = method
        self.cfg = cfg
        self.x_test = x_test
        self.y_test = y_test
        self.mode = resolve_round_mode(cfg.round_mode)
        # sync IS the overlap graph at pipeline depth 1
        self.max_inflight = cfg.max_inflight if self.mode == "overlap" else 1
        self.phases = round_phases(method)
        self.sim_phase_costs = sim_phase_costs
        self.timeline = SimTimeline(client_speeds(
            engine.num_clients, seed=cfg.seed,
            straggler_factor=cfg.straggler_factor))
        # concurrent-cohort mode: client-side phase nodes are keyed
        # (phase, round, cohort) and each cohort pipelines independently;
        # the engine must expose the per-cohort entry points
        # (cohort_positions / cohort_local_train / ...)
        self._concurrent = bool(getattr(cfg, "concurrent_cohorts", False))
        self._cohort_pos: Optional[List[np.ndarray]] = None
        if self._concurrent:
            if not hasattr(engine, "cohort_positions"):
                raise TypeError(
                    f"concurrent_cohorts=True needs an engine with the "
                    f"per-cohort interface (cohort_positions/cohort_*); "
                    f"{type(engine).__name__} has none")
            self._cohort_pos = [np.asarray(p, int)
                                for p in engine.cohort_positions()]
        # node keys in host execution order — (phase, round) for global
        # nodes, (phase, round, cohort) for per-cohort client nodes; the
        # determinism tests pin this, and it is the record of what the
        # pipeline actually did
        self.trace: List[Tuple] = []
        self._sim_end: Dict[Tuple, float] = {}
        # event-loop state (begin()/step()/drain()); a fresh scheduler has
        # no window open
        self._order = {p: i for i, p in enumerate(self.phases)}
        self._window: Optional[Tuple[int, int]] = None
        self._states: Dict[int, _RoundState] = {}
        self._nodes: Dict[Tuple[str, int], List] = {}
        self._pending: set = set()
        self._done: set = set()
        self.logs: List[RoundLog] = []
        # monotone count of rounds retired in the open window. Equal to
        # ``len(self.logs)`` unless ``snapshot(logs_tail=...)`` truncated
        # the retained history (the fed_serve sidecar streams retired logs
        # out of the checkpoint) — restore then trusts this counter, not
        # the tail length.
        self.completed = 0
        # sim time of the last round retirement — the served-model
        # freshness reference (service start = 0.0)
        self._last_retire_s = 0.0
        # Byzantine / corruption fault trace: built only when enabled, so
        # the default path never constructs one (bit-for-bit legacy)
        self.faults: Optional[FaultInjector] = None
        if getattr(cfg, "fault_mode", "none") != "none":
            self.faults = FaultInjector(
                engine.num_clients, mode=cfg.fault_mode, seed=cfg.seed,
                fault_prob=getattr(cfg, "fault_prob", 0.0),
                byzantine_frac=getattr(cfg, "byzantine_frac", 0.0),
                fault_start=getattr(cfg, "fault_start", 0),
                fault_duration=getattr(cfg, "fault_duration", 0))
        # divergence watchdog: rollback-to-last-healthy-retire on a sick
        # RoundLog. ``_wd_tree`` is the in-memory restore point (a plain
        # nested tree — asdict deep-copies, so later mutation can't alias
        # into it); it is NOT checkpointed and rebuilds at the next
        # healthy retire (or on restore()).
        self._watchdog = bool(getattr(cfg, "watchdog", False))
        self.rollbacks = 0
        self._wd_best_acc = 0.0
        self._wd_loss_hist: List[float] = []
        self._wd_tree = None
        # engine entry points resolved once (per-phase interface, with the
        # historical *_all fallback for pre-built engines)
        self._local_train = _entry(engine, "phase_local_train",
                                   "local_train_all")
        self._report = _entry(engine, "phase_report",
                              "proxy_logits_and_masks")
        self._classwise = _entry(engine, "phase_classwise_report",
                                 "classwise_means_all")
        self._distill = _entry(engine, "phase_distill", "distill_all")
        self._distill_private = _entry(engine, "phase_distill_private",
                                       "distill_private_all")
        self._eval = _entry(engine, "phase_eval", "evaluate_all")

    # ------------------------------------------------------------ the graph
    def _build_deps(self, rounds) -> Dict[Tuple[str, int], List]:
        """Nodes + declared dependencies for a contiguous round window.

        Each dep is ``(phase, round, kind)``: ``data`` deps gate both host
        execution and the simulated timeline; ``order`` deps (same phase,
        previous round) pin host execution order — server rng draws, report
        ingestion and log assembly must happen in round order — but cost
        nothing on the timeline (disjoint clients of different rounds
        genuinely run concurrently; shared clients are serialized by their
        timeline lanes instead).

        Concurrent-cohort mode keys client-side nodes per cohort — deps are
        then ``(phase, round, cohort, kind)``. Data flows stay within a
        cohort until the global aggregate barrier (which needs every
        cohort's report), and admission pipelines per cohort: cohort c's
        ``local_train(r)`` waits on *its own* ``distill(r - max_inflight)``
        on the timeline, with an order-only edge to ``eval(r -
        max_inflight)`` pinning the host order (so a single-cohort zoo
        replays the serial schedule — and its sim times — exactly)."""
        window = set(rounds)
        nodes: Dict[Tuple, List] = {}
        if not self._concurrent:
            for r in rounds:
                for i, p in enumerate(self.phases):
                    deps = []
                    if i > 0:  # intra-round chain: the actual data flow
                        deps.append((self.phases[i - 1], r, "data"))
                    if (r - 1) in window:  # host-order edge
                        deps.append((p, r - 1, "order"))
                    if i == 0 and (r - self.max_inflight) in window:
                        # admission: round r enters the pipeline only once
                        # round r - max_inflight has fully retired
                        deps.append((self.phases[-1], r - self.max_inflight,
                                     "data"))
                    nodes[(p, r)] = deps
            return nodes
        ncoh = len(self._cohort_pos)
        client = [p for p in self.phases if p in CLIENT_PHASES]
        last_client = client[-1]  # the cohort's slowest-retiring phase
        for r in rounds:
            for i, p in enumerate(self.phases):
                prev = self.phases[i - 1] if i > 0 else None
                if p not in CLIENT_PHASES:  # global: aggregate/sdist/eval
                    deps = []
                    if prev is not None:
                        if prev in CLIENT_PHASES:  # barrier on every cohort
                            deps += [(prev, r, cj, "data")
                                     for cj in range(ncoh)]
                        else:
                            deps.append((prev, r, "data"))
                    if (r - 1) in window:
                        deps.append((p, r - 1, "order"))
                    nodes[(p, r)] = deps
                    continue
                for ci in range(ncoh):
                    deps = []
                    if prev is not None:
                        # a client phase's input is its own cohort's
                        # previous client phase, or the global teacher
                        deps.append((prev, r, ci, "data")
                                    if prev in CLIENT_PHASES
                                    else (prev, r, "data"))
                    if (r - 1) in window:
                        deps.append((p, r - 1, ci, "order"))
                        if p == "report":
                            # every cohort of round r-1 reports before any
                            # cohort of round r: the server's proxy-batch
                            # rng draw and report ingestion stay
                            # round-ordered under any interleaving
                            deps += [(p, r - 1, cj, "order")
                                     for cj in range(ncoh) if cj != ci]
                    if p == client[0] and (r - self.max_inflight) in window:
                        q = r - self.max_inflight
                        # per-cohort admission: this cohort's lanes free up
                        # when ITS round-q distill retires — cross-round
                        # pipelining per cohort is the concurrency win...
                        deps.append((last_client, q, ci, "data"))
                        # ...while the host still runs eval(q) first (order
                        # only: free on the timeline), keeping execution
                        # deterministic and serial-equivalent numerics
                        deps.append((self.phases[-1], q, "order"))
                    nodes[(p, r, ci)] = deps
        return nodes

    # ------------------------------------------------------- the event loop
    def begin(self, start: int, count: int) -> None:
        """Open the round window ``[start, start + count)``.

        Builds the node graph and resets per-window bookkeeping; the
        simulated timeline, trace and node finish times carry over from any
        previous window on this scheduler (that is how sequential windows
        chain). ``step()`` then executes one node at a time."""
        if self._pending:
            raise RuntimeError(
                f"cannot begin a new round window: {len(self._pending)} "
                "nodes of the current window are still pending")
        rounds = range(start, start + count)
        self._window = (start, count)
        self._states = {r: _RoundState(r) for r in rounds}
        self._nodes = self._build_deps(rounds)
        self._pending = set(self._nodes)
        self._done = set()
        self.logs = []
        self.completed = 0
        if self._watchdog and hasattr(self.engine, "state_dict"):
            # arm the rollback point at the window start too — a round-0
            # attack must be as recoverable as a mid-run one
            self._wd_tree = self.snapshot().to_tree()

    def has_pending(self) -> bool:
        """True while the open window still has nodes to execute."""
        return bool(self._pending)

    def step(self) -> Tuple[str, int, Optional[RoundLog]]:
        """Execute the single next ready node; the scheduler's event tick.

        Returns ``(phase, round, log)`` where ``log`` is the finished
        ``RoundLog`` when this node retired its round, else ``None``. Every
        return is a phase boundary — a consistent point to ``snapshot()``
        (or crash at: the kill-and-resume harness keys off these)."""
        if not self._pending:
            raise RuntimeError("no pending nodes — call begin() first")
        ready = [
            k for k in self._pending
            if all(d[1] not in self._states or d[:-1] in self._done
                   for d in self._nodes[k])
        ]
        # deterministic pipeline policy: front (client-side) phases
        # before drain phases, oldest round first, intra-round order
        # next, cohort index last — under sync with one cohort exactly one
        # node is ever ready, so this replays the legacy lockstep order
        key = min(ready, key=lambda k: (k[0] not in FRONT_PHASES, k[1],
                                        self._order[k[0]],
                                        k[2] if len(k) > 2 else -1))
        phase, r = key[0], key[1]
        self._run_node(key, self._states[r], self._nodes[key])
        self._pending.remove(key)
        self._done.add(key)
        log = None
        if phase == self.phases[-1]:
            log = self._finish_round(self._states[r])
            if self._watchdog and self._wd_unhealthy(log) \
                    and self._wd_rollback(r):
                # the round was replayed from the last healthy retire; the
                # sick log is discarded and the caller sees no retirement
                return phase, r, None
            self.logs.append(log)
            self.completed += 1
            self._retire(r)
            if self._watchdog:
                self._wd_note_healthy(log)
        return phase, r, log

    def drain(self, progress: Optional[Callable[[RoundLog], None]] = None
              ) -> List[RoundLog]:
        """Run the open window to completion."""
        while self._pending:
            _, _, log = self.step()
            if log is not None and progress:
                progress(log)
        return self.logs

    def run_rounds(self, start: int, count: int,
                   progress: Optional[Callable[[RoundLog], None]] = None
                   ) -> List[RoundLog]:
        """Execute rounds ``[start, start + count)`` through the graph."""
        self.begin(start, count)
        return self.drain(progress)

    def _retire(self, r: int) -> None:
        """Drop a retired round's bookkeeping so memory stays bounded over
        a long-running service (rounds retire in round order — the eval
        nodes chain through same-phase order deps).

        The ready check treats rounds absent from ``_states`` as
        satisfied, so pruning is transparent to dependents. Simulated
        finish times survive a little longer: ``(eval, q)`` is the
        admission dep of ``local_train(q + max_inflight)``, so entries are
        only dropped once they are ``max_inflight`` rounds stale."""
        del self._states[r]
        pop_o = getattr(self.server, "pop_round_outlier", None)
        if pop_o is not None:  # drop the round's suspect scores (the
            pop_o(r)          # watchdog consumed them on rollback already)
        self._done -= {k for k in self._done if k[1] == r}
        horizon = r - self.max_inflight
        for key in [k for k in self._sim_end if k[1] <= horizon]:
            del self._sim_end[key]

    # --------------------------------------------------- snapshot / restore
    def snapshot(self, *, logs_tail: Optional[int] = None):
        """Capture the full experiment at the current phase boundary.

        Returns an ``ExperimentState`` assembling this scheduler's node
        bookkeeping and in-flight round payloads with the ``state_dict()``
        of the timeline, the server (pending reports, staleness buffers,
        byte ledger, rng) and the engine (per-client params/opt-state/rng).
        Call only between ``step()``s — mid-node state is not capturable.

        ``logs_tail`` caps how many retired ``RoundLog``s ride the state
        (``None`` = all of them, the legacy layout). A caller that streams
        retired logs to durable storage of its own (the fed_serve
        ``logs.jsonl`` sidecar) passes ``logs_tail=0`` so checkpoint size
        stays flat over a long service; ``sched["completed"]`` still
        records the true retired count."""
        from repro.fed.state import STATE_VERSION, ExperimentState
        if self._window is None:
            raise RuntimeError("nothing to snapshot — call begin() first")
        if not hasattr(self.engine, "state_dict"):
            raise TypeError(
                f"engine {type(self.engine).__name__} has no state_dict(); "
                "snapshot/restore needs the per-client state hooks")
        inflight = sorted(
            r for r in self._states
            if any(k[1] == r for k in self._done))

        def as_list(key):
            # (phase, round) → [p, r]; (phase, round, cohort) → [p, r, ci]
            # — length discriminates on restore
            return [key[0]] + [int(v) for v in key[1:]]

        sched = {
            "window": [int(self._window[0]), int(self._window[1])],
            "completed": int(self.completed),
            "done": sorted(as_list(k) for k in self._done),
            "trace": [as_list(k) for k in self.trace],
            "sim_end": sorted(as_list(k) + [float(t)]
                              for k, t in self._sim_end.items()),
            "last_retire_s": float(self._last_retire_s),
            "states": [self._states[r].state_dict() for r in inflight],
            "rollbacks": int(self.rollbacks),
            "wd_best_acc": float(self._wd_best_acc),
            "wd_loss_hist": [float(v) for v in self._wd_loss_hist],
        }
        if self.faults is not None:
            sched["faults"] = self.faults.state_dict()
        logs = (self.logs if logs_tail is None
                else self.logs[max(len(self.logs) - int(logs_tail), 0):])
        import dataclasses as _dc
        return ExperimentState(
            version=STATE_VERSION,
            round_mode=self.mode,
            scheduler=sched,
            timeline=self.timeline.state_dict(),
            server=self.server.state_dict(),
            engine=self.engine.state_dict(),
            logs=[_dc.asdict(lg) for lg in logs],
        )

    def restore(self, state) -> None:
        """Rebuild the event loop from a ``snapshot()`` (or its tree form).

        The scheduler must be freshly constructed from the *same*
        ``FedConfig`` (datasets, method, engine layout and rng seeds are
        rebuilt, not checkpointed); this overlays every piece of mutable
        state, after which ``drain()`` continues the run with logs
        bit-for-bit identical to the uninterrupted one."""
        from repro.fed.state import ExperimentState
        if not isinstance(state, ExperimentState):
            state = ExperimentState.from_tree(state)
        if state.round_mode != self.mode:
            raise ValueError(
                f"checkpoint was written in round_mode={state.round_mode!r} "
                f"but this scheduler runs {self.mode!r}")
        sched = state.scheduler
        start, count = (int(v) for v in sched["window"])
        rounds = range(start, start + count)
        self._window = (start, count)
        self._nodes = self._build_deps(rounds)
        completed = int(sched["completed"])
        # rounds retire in order, so the retired set is a prefix
        retired = set(range(start, start + completed))
        def as_key(e):
            # [p, r] → (phase, round); [p, r, ci] → (phase, round, cohort)
            return (e[0],) + tuple(int(v) for v in e[1:])

        self._done = {as_key(e) for e in sched["done"]}
        self._states = {r: _RoundState(r) for r in rounds
                        if r not in retired}
        for st_sd in sched["states"]:
            self._states[int(st_sd["r"])].load_state_dict(st_sd, self)
        self._pending = {k for k in self._nodes
                         if k[1] not in retired and k not in self._done}
        self.trace = [as_key(e) for e in sched["trace"]]
        self._sim_end = {as_key(e[:-1]): float(e[-1])
                         for e in sched["sim_end"]}
        self._last_retire_s = float(sched["last_retire_s"])
        self.timeline.load_state_dict(state.timeline)
        self.server.load_state_dict(state.server)
        self.engine.load_state_dict(state.engine)
        # a tail-truncated snapshot (fed_serve sidecar) carries fewer logs
        # than ``completed``; the counter is authoritative either way
        self.logs = [RoundLog(**lg) for lg in state.logs]
        self.completed = completed
        # robustness state (``.get``: absent from checkpoints written
        # before the fault/watchdog machinery existed)
        if self.faults is not None:
            self.faults.load_state_dict(sched.get("faults", {}))
        self.rollbacks = int(sched.get("rollbacks", 0))
        self._wd_best_acc = float(sched.get("wd_best_acc", 0.0))
        self._wd_loss_hist = [float(v)
                              for v in sched.get("wd_loss_hist", [])]
        if self._watchdog:
            # the restored boundary is (by construction) a healthy one —
            # re-arm the in-memory rollback point here so a fault right
            # after resume can still be rolled back
            self._wd_tree = self.snapshot().to_tree()

    # -------------------------------------------------- divergence watchdog
    def _wd_unhealthy(self, log: RoundLog) -> bool:
        """Health guard over a freshly assembled ``RoundLog``: non-finite
        metrics, an accuracy collapse vs the best healthy round, or a
        distill-loss spike vs the recent healthy median."""
        cfg = self.cfg
        vals = (log.mean_acc, log.local_loss, log.distill_loss)
        if not all(np.isfinite(v) for v in vals):
            return True
        if self._wd_best_acc > 0.0 and \
                log.mean_acc < self._wd_best_acc - cfg.watchdog_acc_drop:
            return True
        if self._wd_loss_hist and log.distill_loss > 0.0:
            ref = float(np.median(self._wd_loss_hist))
            if ref > 0.0 and log.distill_loss > cfg.watchdog_loss_factor * ref:
                return True
        return False

    def _wd_suspects(self, r: int) -> List[int]:
        """Top-suspect clients for round ``r`` from the server's normalized
        outlier scores (median ≈ 1 for honest clients): everyone past 3×
        the honest scale, else the single worst scorer."""
        pop = getattr(self.server, "pop_round_outlier", None)
        dist = pop(r) if pop is not None else None
        if dist is None or dist.size == 0:
            return []
        bad = np.flatnonzero(~np.isfinite(dist) | (dist > 3.0))
        if bad.size == 0 and float(np.max(dist)) > 0.0:
            bad = np.asarray([int(np.argmax(dist))], int)
        return [int(i) for i in bad]

    def _wd_rollback(self, r: int) -> bool:
        """Roll the experiment back to the last healthy retirement and
        quarantine the round's top outlier suspects so the deterministic
        replay of round ``r`` runs without them. Returns False (caller
        retires the sick round as-is) when no restore point exists yet or
        the rollback budget is spent."""
        if self._wd_tree is None or \
                self.rollbacks >= self.cfg.watchdog_max_rollbacks:
            return False
        # capture BEFORE restore: the suspect scores live in server state
        # and the rollback counter rides the sched snapshot, both about to
        # be overwritten
        suspects = self._wd_suspects(r)
        prev = self.rollbacks
        from repro.fed.state import ExperimentState
        self.restore(ExperimentState.from_tree(self._wd_tree))
        self.rollbacks = prev + 1
        if suspects:
            # from round r (not r+1): the replay re-runs r itself, and the
            # fault trace is deterministic — without the quarantine the
            # same clients would poison the same round again
            self.server._ensure_fleet(self.engine.num_clients)
            self.server.quarantine(suspects, r, event_round=r)
        # re-take the restore point so it carries the quarantine and the
        # bumped rollback counter (restore() armed a pre-quarantine one)
        self._wd_tree = self.snapshot().to_tree()
        return True

    def _wd_note_healthy(self, log: RoundLog) -> None:
        """A round retired healthy: refresh the health references and
        re-take the in-memory restore point."""
        self._wd_best_acc = max(self._wd_best_acc, float(log.mean_acc))
        if log.distill_loss > 0.0:
            self._wd_loss_hist.append(float(log.distill_loss))
            del self._wd_loss_hist[:-8]
        self._wd_tree = self.snapshot().to_tree()

    # ------------------------------------------------------- node execution
    def _run_node(self, key: Tuple, st: _RoundState, deps) -> None:
        phase = key[0]
        self.trace.append(key)
        t0 = time.perf_counter()
        if len(key) > 2:  # per-cohort client node (concurrent mode)
            getattr(self, "_phase_" + phase + "_cohort")(st, key[2])
        else:
            getattr(self, "_phase_" + phase)(st)
        dt = time.perf_counter() - t0
        st.phase_s[phase] = st.phase_s.get(phase, 0.0) + dt
        self._account(key, st, deps, dt)
        if phase == "report":
            # ingestion is an *event* driven by the arrival-trace clock: it
            # runs after the node is priced so each report's simulated
            # arrival time (the client's report-lane finish) is known, and
            # admission can replay them in arrival order. In concurrent
            # mode _ingest_reports no-ops until the round's LAST report
            # node has accumulated and priced its cohort's rows.
            t0 = time.perf_counter()
            self._ingest_reports(st)
            st.phase_s[phase] += time.perf_counter() - t0

    def _report_part(self, st: _RoundState):
        """The round's *reporting* participants: serial mode mutates
        ``st.part`` through dropout/admission, concurrent mode keeps the
        training mask intact and tracks the reduced one in ``st.rpart``."""
        return st.rpart if st.rpart is not None else st.part

    def _per_client_cost(self, phase: str, epart) -> Optional[np.ndarray]:
        """Per-client base costs for a serial (engine-wide) client node
        when ``sim_phase_costs`` prices cohorts individually
        (``"phase@cohort"`` keys) — the serial baseline of the hetero-zoo
        benchmark must charge each architecture its own cost or the
        comparison against concurrent mode would be apples to oranges."""
        costs = self.sim_phase_costs
        if costs is None or not any("@" in k for k in costs):
            return None
        cpos = self._cohort_pos
        if cpos is None:
            if not hasattr(self.engine, "cohort_positions"):
                return None
            cpos = self._cohort_pos = [np.asarray(p, int)
                                       for p in self.engine.cohort_positions()]
        per = np.zeros((self.engine.num_clients,), float)
        for ci, pos in enumerate(cpos):
            c = costs.get(f"{phase}@{ci}", costs.get(phase, 0.0))
            n = len(pos) if epart is None else int(epart[pos].sum())
            per[pos] = c / max(n, 1)
        return per

    def _account(self, key: Tuple, st: _RoundState, deps,
                 measured_s: float) -> None:
        """Price the node onto the simulated straggler timeline."""
        phase = key[0]
        ready_s = max((self._sim_end.get(d[:-1], 0.0)
                       for d in deps if d[-1] == "data"),
                      default=0.0)
        costs = self.sim_phase_costs
        if costs is None:
            base = measured_s
        elif len(key) > 2:
            # per-cohort nodes read "phase@cohort" (heterogeneous phase
            # costs), falling back to the shared per-phase cost
            base = costs.get(f"{phase}@{key[2]}", costs.get(phase, 0.0))
        else:
            base = costs.get(phase, 0.0)
        if phase in CLIENT_PHASES:
            epart = (st.part if phase == "local_train"
                     else self._report_part(st))
            if len(key) > 2:  # this node covers one cohort's lanes only
                pos = self._cohort_pos[key[2]]
                lane_part = np.zeros((self.engine.num_clients,), bool)
                lane_part[pos] = True if epart is None else epart[pos]
                n = int(lane_part.sum())
                per_client = base / max(n, 1)
            else:
                lane_part = epart
                n = (self.engine.num_clients if epart is None
                     else int(np.asarray(epart, bool).sum()))
                per_client = self._per_client_cost(phase, epart)
                if per_client is None:
                    per_client = base / max(n, 1)
            # measured host seconds cover every participant back-to-back;
            # deployed clients run in parallel, each paying its own share
            # scaled by its straggler speed. The arrival trace delays when
            # each client shows up for the round — it gates local_train
            # (the round's entry point); later phases inherit the skew
            # through the per-client lane occupancy.
            offsets = None
            if phase == "local_train":
                offsets = arrival_offsets(
                    self.engine.num_clients, st.r, seed=self.cfg.seed,
                    process=self.cfg.arrival_process,
                    spread=self.cfg.arrival_spread,
                    bursts=self.cfg.arrival_bursts)
            end = self.timeline.client_phase(lane_part, per_client,
                                             ready_s, offsets=offsets)
            if phase == "report":
                # pin simulated arrival times NOW: by the time the round
                # ingests (its last report node), other rounds' nodes may
                # already have advanced these lanes
                if st.report_arrival is None:
                    st.report_arrival = np.zeros(
                        (self.engine.num_clients,), float)
                ids = (np.arange(self.engine.num_clients)
                       if lane_part is None else np.flatnonzero(lane_part))
                st.report_arrival[ids] = self.timeline.client_free[ids]
        elif phase in ("aggregate", "server_distill"):
            end = self.timeline.server_phase(base, ready_s)
        else:  # eval: simulation-side measurement, free on the timeline
            end = ready_s
        end = float(end)  # np.float64 would poison RoundLog JSON dumps
        self._sim_end[key] = end
        st.sim_finish_s = end

    # --------------------------------------------------------- phase bodies
    def _draw_participants(self, st: _RoundState) -> None:
        """Participation sampling + churn for one round (deterministic in
        (seed, round) — drawn once whichever node runs first)."""
        cfg = self.cfg
        st.sampled = True
        if cfg.participation_fraction < 1.0:
            sizes = None
            if cfg.participation_policy == "weighted":
                sizes = np.asarray([len(c.y) for c in self.engine.clients],
                                   np.int64)
            st.part = sample_participants(
                st.r, self.engine.num_clients, cfg.participation_fraction,
                cfg.participation_policy, seed=cfg.seed, data_sizes=sizes)
        # per-round churn: an offline client is removed from the round
        # entirely — no training, no report — and drains through the
        # staleness machinery exactly like a sampled-out client
        online = online_mask(self.engine.num_clients, st.r, seed=cfg.seed,
                             churn=cfg.churn_prob)
        if online is not None:
            st.part = online if st.part is None else (st.part & online)
        # quarantined clients sit the round out like sampled-out ones,
        # draining through the staleness buffer — unless that would empty
        # the round entirely (the protocol needs at least one report)
        quarantine = getattr(self.server, "quarantine_mask", None)
        q = quarantine(st.r) if quarantine is not None else None
        if q is not None:
            keep = ~q if st.part is None else (st.part & ~q)
            if keep.any():
                st.part = keep
        if st.part is not None:
            # participants is passed as a kwarg only when a subset was
            # actually drawn, so pre-existing engines with the historical
            # interface keep working at participation_fraction=1 (and the
            # legacy call sequence is preserved bit-for-bit)
            st.kw = {"participants": st.part}

    def _phase_local_train(self, st: _RoundState) -> None:
        cfg = self.cfg
        self._draw_participants(st)
        st.local_losses = self._local_train(cfg.local_epochs, cfg.batch_size,
                                            **st.kw)

    def _phase_local_train_cohort(self, st: _RoundState, ci: int) -> None:
        cfg = self.cfg
        if not st.sampled:  # round-level draw, at the first cohort node
            self._draw_participants(st)
        losses = self.engine.cohort_local_train(
            ci, cfg.local_epochs, cfg.batch_size, participants=st.part)
        if not st.local_losses:
            st.local_losses = [0.0] * self.engine.num_clients
        for j, p in enumerate(self._cohort_pos[ci]):
            st.local_losses[p] = losses[j]

    def _phase_report(self, st: _RoundState) -> None:
        cfg = self.cfg
        # mid-round dropout: these clients trained (local_train already
        # priced their lanes) but vanish before reporting — their fresh
        # report never reaches the server and they sit out the rest of the
        # round, riding the staleness buffer like any non-participant
        dropped = dropout_mask(self.engine.num_clients, st.r, seed=cfg.seed,
                               dropout=cfg.dropout_prob)
        if dropped is not None:
            stayed = (~dropped if st.part is None else (st.part & ~dropped))
            st.part = stayed
            st.kw = {"participants": st.part}
        if self.method.data_free:  # FKD/PLS upload class-wise means
            st.means_counts = self._classwise(**st.kw)
            return
        st.idx = self.server.select_indices(cfg.proxy_batch)
        st.px = self.server.proxy.x[st.idx]
        st.powner = self.server.proxy.owner[st.idx]
        # computed here (the client-side work) but ingested post-pricing in
        # _ingest_reports, once simulated arrival times exist
        st.report_payload = self._report(st.px, st.powner, **st.kw)

    def _phase_report_cohort(self, st: _RoundState, ci: int) -> None:
        cfg = self.cfg
        num = self.engine.num_clients
        pos = self._cohort_pos[ci]
        if st.reports_pending is None:  # round-level setup, first node
            st.reports_pending = len(self._cohort_pos)
            # dropout is drawn once per round; the reduced mask lives in
            # st.rpart so cohorts that have not trained yet still see the
            # full training mask in st.part
            dropped = dropout_mask(num, st.r, seed=cfg.seed,
                                   dropout=cfg.dropout_prob)
            if dropped is not None:
                st.rpart = (~dropped if st.part is None
                            else (st.part & ~dropped))
        part = self._report_part(st)
        if self.method.data_free:
            mc = self.engine.cohort_classwise_report(ci, participants=part)
            if st.means_counts is None:
                k = self.engine.clients[0].num_classes
                zero = (np.zeros((k, k), np.float32),
                        np.zeros((k,), np.float32))
                st.means_counts = [zero] * num
            for j, p in enumerate(pos):
                st.means_counts[p] = mc[j]
        else:
            if st.idx is None:  # the round's shared proxy batch: one draw,
                # round-ordered by the cross-round report order deps, so
                # the server rng stream matches the serial schedule
                st.idx = self.server.select_indices(cfg.proxy_batch)
                st.px = self.server.proxy.x[st.idx]
                st.powner = self.server.proxy.owner[st.idx]
            lg, mk = self.engine.cohort_report(ci, st.px, st.powner,
                                               participants=part)
            if st.report_logits is None:
                t, k = lg.shape[1], lg.shape[2]
                st.report_logits = np.zeros((num, t, k), np.float32)
                st.report_masks = np.zeros((num, t), bool)
            st.report_logits[pos] = lg
            st.report_masks[pos] = mk
        st.reports_pending -= 1

    def _ingest_reports(self, st: _RoundState) -> None:
        """Server-side report ingestion, as an arrival-ordered event.

        Runs right after the report node is priced onto the timeline. With
        ``max_pending_reports > 0`` the server admits reports in simulated
        arrival order (each client's report-lane finish time, ties broken
        by client id) until the in-flight budget is full; overflow clients
        are demoted to non-participants for the rest of the round and drain
        through the staleness machinery exactly like dropouts — their
        buffer entries keep aging forward, so ages never go negative. With
        the cap at 0 (default) admission is the identity and the legacy
        lockstep byte stream is preserved bit-for-bit.

        In concurrent-cohort mode the round's rows accumulate across its
        per-cohort report nodes (``st.report_logits``/``st.report_masks``)
        and ingestion fires once, at the round's last report node — arrival
        times were pinned per node at pricing time (``st.report_arrival``),
        so admission order is independent of how cohorts interleaved."""
        if self.method.data_free:
            return
        if st.report_payload is not None:  # serial: same-node handoff
            logits, masks = st.report_payload
            st.report_payload = None
        elif (st.report_logits is not None and st.reports_pending == 0):
            logits, masks = st.report_logits, st.report_masks
            st.report_logits = st.report_masks = None
        else:  # concurrent: cohorts still reporting
            return
        cfg = self.cfg
        part = self._report_part(st)
        if self.faults is not None:
            # the fault trace corrupts what faulty clients *send* — after
            # training, before the server sees anything. Deterministic in
            # (seed, round, client), so every engine injects identically.
            logits, masks = self.faults.corrupt_reports(
                st.r, logits, masks, part)
        cap = int(getattr(self.server, "max_pending_reports", 0))
        if cap > 0:
            ids = (np.arange(self.engine.num_clients)
                   if part is None else np.flatnonzero(part))
            arrival = st.report_arrival[ids]
            # primary key: simulated arrival; secondary: client id
            ordered = ids[np.lexsort((ids, arrival))]
            admitted_ids = self.server.admit_reports(st.r, ordered)
            if admitted_ids.size < ids.size:
                admitted = np.zeros((self.engine.num_clients,), bool)
                admitted[admitted_ids] = True
                part = admitted
                if self._concurrent:
                    st.rpart = admitted
                else:
                    st.part = admitted
                    st.kw = {"participants": st.part}
        # ID fraction over the clients that actually reported; stale rows
        # merged at aggregation additionally carry reuse
        st.id_frac = (float(masks.mean()) if part is None
                      else (float(masks[part].mean())
                            if part.any() else 0.0))
        self.server.ingest_reports(st.r, part, st.idx, logits, masks,
                                   decay=cfg.staleness_decay,
                                   entropy_filter=self.method.server_filter)

    def _phase_aggregate(self, st: _RoundState) -> None:
        if self.method.data_free:
            if self.faults is not None:
                # classwise payloads are untouched between report and
                # aggregate, so injecting here is payload-equivalent to
                # injecting at report time — and single-sited across the
                # serial and concurrent-cohort report paths
                st.means_counts = self.faults.corrupt_classwise(
                    st.r, st.means_counts, self._report_part(st))
            st.teacher_by_class, st.valid_by_class = \
                self.server.aggregate_classwise(
                    st.means_counts, count_weighted=self.method.count_weighted,
                    uploaded_rows=self._report_part(st),
                    round_idx=st.r)
            st.means_counts = None
            return
        st.teacher, st.valid, st.mean_staleness = self.server.aggregate_round(
            st.r, sharpen=self.method.sharpen,
            entropy_filter=self.method.server_filter)

    def _phase_server_distill(self, st: _RoundState) -> None:
        """FedDF: train the server's central student on the round's proxy
        batch against the fused ensemble teacher (the same teacher/validity
        the clients are about to distill from)."""
        cfg = self.cfg
        epochs = (getattr(cfg, "server_distill_epochs", 0)
                  or cfg.distill_epochs)
        st.server_distill_loss = self.server.ensemble_distill(
            st.px, st.teacher, st.valid, epochs=epochs,
            batch_size=cfg.batch_size)

    def _phase_distill(self, st: _RoundState) -> None:
        cfg = self.cfg
        if self.method.data_free:
            st.distill_losses = self._distill_private(
                st.teacher_by_class, st.valid_by_class, cfg.distill_epochs,
                cfg.batch_size, **st.kw)
            return
        w = st.valid.astype(np.float32)
        st.distill_losses = self._distill(st.px, st.teacher, w,
                                          cfg.distill_epochs, cfg.batch_size,
                                          **st.kw)

    def _phase_distill_cohort(self, st: _RoundState, ci: int) -> None:
        cfg = self.cfg
        part = self._report_part(st)
        if self.method.data_free:
            losses = self.engine.cohort_distill_private(
                ci, st.teacher_by_class, st.valid_by_class,
                cfg.distill_epochs, cfg.batch_size, participants=part)
        else:
            w = st.valid.astype(np.float32)
            losses = self.engine.cohort_distill(
                ci, st.px, st.teacher, w, cfg.distill_epochs,
                cfg.batch_size, participants=part)
        if not st.distill_losses:
            st.distill_losses = [0.0] * self.engine.num_clients
        for j, p in enumerate(self._cohort_pos[ci]):
            st.distill_losses[p] = losses[j]

    def _phase_eval(self, st: _RoundState) -> None:
        st.accs = self._eval(self.x_test, self.y_test)
        if getattr(self.server, "student", None) is not None:
            st.server_student_acc = self.server.evaluate_student(
                self.x_test, self.y_test)

    def _finish_round(self, st: _RoundState) -> RoundLog:
        # served-model freshness: how long the model this round replaces
        # was the one a user query would hit (sim seconds since the last
        # retirement; round 0 measures from service start). Overlap rounds
        # retire in round order on the host but may finish out of order on
        # the sim timeline — the interval clamps at 0 there, and the
        # reference only moves forward.
        age = max(0.0, st.sim_finish_s - self._last_retire_s)
        self._last_retire_s = max(self._last_retire_s, st.sim_finish_s)
        part = self._report_part(st)
        pop_s = getattr(self.server, "pop_scrubbed", None)
        scrubbed = int(pop_s(st.r)) if pop_s is not None else 0
        pop_q = getattr(self.server, "pop_quarantined", None)
        newly_q = pop_q(st.r) if pop_q is not None else []
        return RoundLog(
            round=st.r,
            mean_acc=float(np.mean(st.accs)),
            accs=st.accs,
            local_loss=float(np.mean(st.local_losses)),
            distill_loss=(float(np.mean(st.distill_losses))
                          if st.distill_losses else 0.0),
            id_fraction=st.id_frac,
            bytes_up=self.server.bytes_received,
            bytes_down=self.server.bytes_broadcast,
            wall_s=sum(st.phase_s.values()),
            participants=(None if part is None
                          else [int(i) for i in np.flatnonzero(part)]),
            mean_staleness=st.mean_staleness,
            phase_s=dict(st.phase_s),
            sim_finish_s=st.sim_finish_s,
            served_model_age_s=age,
            server_distill_loss=st.server_distill_loss,
            server_student_acc=st.server_student_acc,
            scrubbed_rows=scrubbed,
            quarantined=(newly_q if newly_q else None),
            rollbacks=self.rollbacks,
        )
