"""Experiment builder: dataset → non-IID partition → proxy → clients/server.

KMeans-DRE centroid count per the paper (§IV-A/B):
  strong non-IID → 1 centroid;
  weak non-IID   → one per held label;
  IID            → one per class.
"""
from __future__ import annotations

import os
from typing import List, Tuple

import jax
import numpy as np

from repro.common.types import FedConfig
from repro.core.methods import get_method
from repro.core.protocol import (ExperimentResult, engine_from_config,
                                 run_experiment)
from repro.data.partition import partition
from repro.data.proxy import build_proxy
from repro.data.synthetic import make_dataset
from repro.fed import participation, scheduler
from repro.fed.client import Client
from repro.fed.server import Server
from repro.kernels import dispatch
from repro.models.cnn import MLPClassifier, get_client_model
from repro.optim.optimizers import sgd


ZOOS = ("shared", "mixed")


def resolve_zoo(zoo: str) -> str:
    """Resolve ``cfg.zoo``: ``"auto"`` defers to the ``REPRO_ZOO``
    environment variable (the CI matrix axis); an empty/``auto`` variable
    means no opinion → ``"shared"`` (the historical single-architecture
    population, bit-for-bit with every golden)."""
    if zoo == "auto":
        zoo = os.environ.get("REPRO_ZOO", "").strip() or "auto"
        if zoo == "auto":
            zoo = "shared"
    if zoo not in ZOOS:
        raise ValueError(f"zoo must be one of {ZOOS} or 'auto', got {zoo!r}")
    return zoo


def _mixed_hidden(mlp_hidden: Tuple[int, ...]) -> List[Tuple[int, ...]]:
    """Three MLP widths for the mixed feature-mode zoo: the configured
    hidden stack, a half-width and a double-width variant (clients cycle
    through them by ``cid % 3``, giving three cohorts)."""
    return [tuple(mlp_hidden),
            tuple(max(4, v // 2) for v in mlp_hidden),
            tuple(v * 2 for v in mlp_hidden)]


def _centroids_for(scenario: str, num_labels: int, num_classes: int) -> int:
    if scenario == "strong":
        return 1
    if scenario == "weak":
        return max(1, num_labels)
    return num_classes


def build_experiment(cfg: FedConfig, dataset_name: str = "mnist_feat",
                     *, n_train: int = 5000, n_test: int = 1000,
                     kulsif: bool = False,
                     mlp_hidden: Tuple[int, ...] = (256, 128)
                     ) -> Tuple[List[Client], Server, np.ndarray, np.ndarray]:
    ds = make_dataset(dataset_name, n_train=n_train, n_test=n_test,
                      seed=cfg.seed)
    clients_data = partition(np.asarray(ds.x), np.asarray(ds.y),
                             num_clients=cfg.num_clients,
                             num_classes=ds.num_classes,
                             scenario=cfg.scenario,
                             labels_per_client=cfg.labels_per_client,
                             seed=cfg.seed)
    proxy = build_proxy(clients_data, cfg.proxy_fraction, seed=cfg.seed)
    qthresh = getattr(cfg, "quarantine_threshold", 0.0)
    server = Server(proxy, seed=cfg.seed,
                    num_edges=cfg.num_edge_aggregators,
                    max_pending_reports=getattr(cfg, "max_pending_reports",
                                                0),
                    robust_aggregation=getattr(cfg, "robust_aggregation",
                                               "mean"),
                    trim_frac=getattr(cfg, "trim_frac", 0.2),
                    sanitize=getattr(cfg, "sanitize_reports", True),
                    quarantine_threshold=qthresh,
                    trust_ewma=getattr(cfg, "trust_ewma", 0.5),
                    quarantine_rounds=getattr(cfg, "quarantine_rounds", 2),
                    # the watchdog ranks suspects by outlier distance, so
                    # tracking must be on even without auto-quarantine
                    track_outliers=bool(getattr(cfg, "watchdog", False))
                    or qthresh > 0)
    method = get_method(cfg.method)

    x_arr = np.asarray(ds.x)
    image_mode = x_arr.ndim == 4
    # token mode: (n, S) integer sequences → transformer clients (the
    # engine-backed version of examples/fd_transformers.py)
    token_mode = x_arr.ndim == 2 and np.issubdtype(x_arr.dtype, np.integer)
    zoo = resolve_zoo(getattr(cfg, "zoo", "auto"))
    key = jax.random.PRNGKey(cfg.seed)
    clients: List[Client] = []
    # one shared optimizer & (in feature mode) one shared apply_fn per
    # architecture so the cohort engine can stack clients with equal arch_key
    shared_opt = sgd(cfg.lr)
    transformer_model = None
    if token_mode:
        # reduced same-family granite backbone sized for CPU lanes; vocab =
        # the dataset's label space (fd_trainer's last-position sample-logit
        # convention). head/ff/vocab dims all divide by 2 and 4, so the 2-D
        # (clients, model) mesh shards them at model_shards ∈ {2, 4}.
        from repro.configs import get_arch, reduced
        from repro.core.fd_trainer import TransformerClientModel
        t_cfg = reduced(get_arch("granite-8b"), layers=2, d_model=64,
                        vocab=ds.num_classes)
        transformer_model = TransformerClientModel(t_cfg)
    # feature-mode zoo: "shared" = one MLP for everyone (the historical
    # population); "mixed" = three width variants cycled by cid % 3, so the
    # cohort engine sees three architecture cohorts. Image mode is already
    # a ten-slot heterogeneous zoo (Tables I/II) under either setting.
    d_in = None if image_mode else np.asarray(ds.x).shape[-1]
    variants = ([tuple(mlp_hidden)] if zoo == "shared"
                else _mixed_hidden(mlp_hidden))
    mlps: List[MLPClassifier] = [None] * len(variants)
    for cid, cd in enumerate(clients_data):
        key, sub = jax.random.split(key)
        if image_mode:
            img_ds = "mnist" if hw_guess(ds.x) == 28 else "cifar10"
            spec, hw, ch = get_client_model(cid, img_ds)
            params = spec.init(sub, hw, ch)
            apply_fn = spec.apply
            arch_key = ("cnn", img_ds, cid % 10)       # Tables I/II zoo slot
        elif token_mode:
            params = transformer_model.init(sub)
            apply_fn = transformer_model.apply
            arch_key = ("transformer", transformer_model.cfg.name)
        else:
            vi = cid % len(variants)
            if mlps[vi] is None:
                mlps[vi] = MLPClassifier(d_in=d_in, hidden=variants[vi],
                                         num_classes=ds.num_classes)
            mlp = mlps[vi]
            params = mlp.init(sub)
            apply_fn = mlp.apply
            arch_key = ("mlp", *mlp.dims)
        dre = method.make_dre(
            num_centroids=_centroids_for(cfg.scenario, len(cd.labels),
                                         ds.num_classes),
            threshold=cfg.id_threshold,
            kernel_backend=cfg.kernel_backend)
        clients.append(Client(cid, apply_fn, params, shared_opt,
                              cd.x, cd.y, dre,
                              num_classes=ds.num_classes,
                              temperature=cfg.temperature,
                              distill_loss=method.distill_loss,
                              seed=cfg.seed, arch_key=arch_key,
                              kernel_backend=cfg.kernel_backend))
    if getattr(method, "server_distill", False):
        # FedDF student, drawn AFTER the client loop so client inits (and
        # therefore every golden trace) are untouched by the extra key
        key, sub = jax.random.split(key)
        if image_mode:
            spec, hw, ch = get_client_model(0, img_ds)
            server.attach_student(spec.apply, spec.init(sub, hw, ch),
                                  shared_opt, temperature=cfg.temperature,
                                  seed=cfg.seed)
        elif token_mode:
            server.attach_student(transformer_model.apply,
                                  transformer_model.init(sub),
                                  shared_opt, temperature=cfg.temperature,
                                  seed=cfg.seed)
        else:
            student_mlp = MLPClassifier(d_in=d_in, hidden=tuple(mlp_hidden),
                                        num_classes=ds.num_classes)
            server.attach_student(student_mlp.apply, student_mlp.init(sub),
                                  shared_opt, temperature=cfg.temperature,
                                  seed=cfg.seed)
    return clients, server, np.asarray(ds.x_test), np.asarray(ds.y_test)


def hw_guess(x) -> int:
    return np.asarray(x).shape[1]


def build_engine(clients: List[Client], cfg: FedConfig):
    """Select the execution engine for a client population (cfg.engine),
    including the cohort engine's client mesh (cfg.num_devices)."""
    return engine_from_config(clients, cfg)


def run(cfg: FedConfig, dataset_name: str = "mnist_feat", *,
        n_train: int = 5000, n_test: int = 1000, progress=None
        ) -> ExperimentResult:
    # fail fast on a bad participation/scheduler/backend config, before
    # any client is built
    participation.validate_config(cfg)
    scheduler.validate_config(cfg)
    dispatch.resolve(cfg.kernel_backend)
    resolve_zoo(getattr(cfg, "zoo", "auto"))
    clients, server, x_test, y_test = build_experiment(
        cfg, dataset_name, n_train=n_train, n_test=n_test)
    engine = build_engine(clients, cfg)
    return run_experiment(engine, server, cfg.method, cfg, x_test, y_test,
                          progress=progress)
