"""Simulated deployment clock: straggler speeds, arrival traces, a timeline.

A single host executes every round phase back-to-back, so "overlapping
rounds beat lockstep rounds" is invisible in host wall-clock — the win
lives in the *deployment* timeline, where every edge client runs in
parallel at its own speed and the slowest participant gates each
synchronous barrier. This module prices a phase schedule onto that
timeline:

``client_speeds``
    ``(C,)`` slowdown multipliers in ``[1, straggler_factor]``, each drawn
    deterministically from ``(seed, client)`` and nothing else — stable
    across rounds, participation subsets, engines and client-count
    changes (client ``c`` keeps its speed when the fleet grows).

``arrival_offsets`` / ``online_mask`` / ``dropout_mask``
    Trace-driven arrival processes for heavy-traffic rounds, every draw
    deterministic in ``(seed, round, client)`` (and nothing else, so
    client ``c``'s trace is stable under fleet growth):

      * **arrival offsets** — when each client shows up for a round on the
        simulated timeline: ``static`` (everyone at phase start, the
        legacy behavior), ``poisson`` (iid exponential delays), or
        ``bursty`` (clients cluster into arrival spikes; a client's burst
        is stable in ``(seed, client)``, like a timezone cohort).
      * **churn** — a client is offline for the whole round with some
        probability; the scheduler removes it from the participant set so
        it drains through the staleness machinery.
      * **mid-round dropout** — a client trains but vanishes before
        reporting; its fresh report never reaches the server.

``SimTimeline``
    Event accounting over two resource kinds: one lane per client (clients
    run in parallel with each other; each client is serial with itself)
    and one serial server. The phase-graph scheduler
    (``repro.fed.scheduler``) replays its *host* execution order through
    the timeline, so per-client data dependencies are respected by
    construction: a lane is occupied in exactly the order the numerics
    consumed it.

The clock is pure accounting on the timeline side (arrival offsets never
touch numerics); churn and dropout DO change the participant set — they
are part of the protocol being simulated, not just its price. Eval phases
are priced at zero: evaluating every client against the held-out test set
is a simulation-side measurement, not deployment work.

Implementation note: per-lane draws are produced by a vectorized,
bit-identical reimplementation of
``np.random.default_rng(SeedSequence([...])).random()`` (SeedSequence's
entropy-mixing hash plus PCG64's 128-bit LCG, both stable by numpy's
reproducibility policy), so a 10^4–10^6-client fleet costs a few numpy
ops instead of C Generator constructions (regression-pinned against the
per-client loop in ``tests/test_scale.py``).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

ARRIVAL_PROCESSES = ("static", "poisson", "bursty")

# ---------------------------------------------------------------------------
# Vectorized (seed, ..., lane) -> uniform double, bit-identical to
# np.random.default_rng(np.random.SeedSequence(entropy)).random() per lane.
# ---------------------------------------------------------------------------

# SeedSequence hashing constants (numpy/_bit_generator.pyx; fixed by
# numpy's stream-compatibility guarantee)
_XSHIFT = np.uint32(16)
_INIT_A = np.uint32(0x43B0D7E5)
_MULT_A = np.uint32(0x931E8875)
_INIT_B = np.uint32(0x8B51F9DD)
_MULT_B = np.uint32(0x58F38DED)
_MIX_MULT_L = np.uint32(0xCA01F9DD)
_MIX_MULT_R = np.uint32(0x4973F715)
_POOL_SIZE = 4

# PCG64's 128-bit LCG multiplier, as (hi, lo) 64-bit limbs
_PCG_MULT_H = np.uint64(2549297995355413924)
_PCG_MULT_L = np.uint64(4865540595714422341)
_MASK32 = np.uint64(0xFFFFFFFF)


def _hashmix(value: np.ndarray, hash_const: list) -> np.ndarray:
    value = value ^ hash_const[0]
    hash_const[0] = hash_const[0] * _MULT_A
    value = value * hash_const[0]
    return value ^ (value >> _XSHIFT)


def _mix(x: np.ndarray, y: np.ndarray) -> np.ndarray:
    result = (x * _MIX_MULT_L) - (y * _MIX_MULT_R)
    return result ^ (result >> _XSHIFT)


def _seedseq_state(entropy_cols) -> np.ndarray:
    """SeedSequence(entropy).generate_state(4, uint64), lane-vectorized.

    ``entropy_cols``: per-word (N,) uint32 arrays — the assembled entropy,
    equal length across lanes (every entropy word must fit uint32).
    Returns (N, 4) uint64.
    """
    n = entropy_cols[0].shape[0]
    with np.errstate(over="ignore"):
        hash_const = [_INIT_A]
        pool = []
        for i in range(_POOL_SIZE):
            v = (entropy_cols[i] if i < len(entropy_cols)
                 else np.zeros(n, np.uint32))
            pool.append(_hashmix(v, hash_const))
        for i_src in range(_POOL_SIZE):
            for i_dst in range(_POOL_SIZE):
                if i_src != i_dst:
                    pool[i_dst] = _mix(pool[i_dst],
                                       _hashmix(pool[i_src], hash_const))
        for i_src in range(_POOL_SIZE, len(entropy_cols)):
            for i_dst in range(_POOL_SIZE):
                pool[i_dst] = _mix(pool[i_dst],
                                   _hashmix(entropy_cols[i_src], hash_const))
        hash_const = [_INIT_B]
        words32 = np.zeros((n, 8), np.uint32)
        for i_dst in range(8):
            data_val = pool[i_dst % _POOL_SIZE] ^ hash_const[0]
            hash_const[0] = hash_const[0] * _MULT_B
            data_val = data_val * hash_const[0]
            words32[:, i_dst] = data_val ^ (data_val >> _XSHIFT)
    w = words32.astype(np.uint64)
    return w[:, 0::2] | (w[:, 1::2] << np.uint64(32))  # low word first


def _mul128(ah, al, bh, bl):
    """(ah<<64|al) * (bh<<64|bl) mod 2^128, element-wise on uint64 limbs."""
    a_lo, a_hi = al & _MASK32, al >> np.uint64(32)
    b_lo, b_hi = bl & _MASK32, bl >> np.uint64(32)
    ll = a_lo * b_lo
    lh = a_lo * b_hi
    hl = a_hi * b_lo
    mid = (ll >> np.uint64(32)) + (lh & _MASK32) + (hl & _MASK32)
    lo = (ll & _MASK32) | (mid << np.uint64(32))
    hi = (a_hi * b_hi + (lh >> np.uint64(32)) + (hl >> np.uint64(32))
          + (mid >> np.uint64(32)) + al * bh + ah * bl)
    return hi, lo


def _add128(ah, al, bh, bl):
    lo = al + bl
    return ah + bh + (lo < al).astype(np.uint64), lo


def _uniform_lanes(entropy_cols) -> np.ndarray:
    """First uniform double of the PCG64 stream seeded per lane."""
    words = _seedseq_state(entropy_cols)
    with np.errstate(over="ignore"):
        init_h, init_l = words[:, 0].copy(), words[:, 1].copy()
        seq_h, seq_l = words[:, 2], words[:, 3]
        inc_h = (seq_h << np.uint64(1)) | (seq_l >> np.uint64(63))
        inc_l = (seq_l << np.uint64(1)) | np.uint64(1)

        def step(h, l):
            h, l = _mul128(h, l, _PCG_MULT_H, _PCG_MULT_L)
            return _add128(h, l, inc_h, inc_l)

        # pcg64_srandom_r: state = 0; step; state += initstate; step
        st_h, st_l = step(np.zeros_like(init_h), np.zeros_like(init_l))
        st_h, st_l = _add128(st_h, st_l, init_h, init_l)
        st_h, st_l = step(st_h, st_l)
        # first next64: step, then XSL-RR output
        st_h, st_l = step(st_h, st_l)
        rot = st_h >> np.uint64(58)
        xored = st_h ^ st_l
        out = (xored >> rot) | (xored << ((np.uint64(64) - rot)
                                          & np.uint64(63)))
    return (out >> np.uint64(11)).astype(np.float64) / 9007199254740992.0


def _lane_uniform(seed: int, num_clients: int, tag: int,
                  round_idx: Optional[int] = None) -> np.ndarray:
    """(C,) uniforms, lane c drawn from (seed[, round], c, tag) only."""
    cs = np.arange(num_clients, dtype=np.uint32)
    cols = [np.full(num_clients, np.uint32(seed % 2**32))]
    if round_idx is not None:
        cols.append(np.full(num_clients, np.uint32(round_idx % 2**32)))
    cols += [cs, np.full(num_clients, np.uint32(tag))]
    return _uniform_lanes(cols)


# ---------------------------------------------------------------------------
# Straggler speeds
# ---------------------------------------------------------------------------

def client_speeds(num_clients: int, *, seed: int = 0,
                  straggler_factor: float = 4.0) -> np.ndarray:
    """``(C,)`` per-client slowdown multipliers in ``[1, straggler_factor]``.

    ``straggler_factor=1`` is a homogeneous fleet (every multiplier exactly
    1). Each entry depends on ``(seed, client)`` only, so the draw is
    reproducible per client regardless of fleet size or round count.
    """
    if straggler_factor < 1.0:
        raise ValueError(
            f"straggler_factor must be >= 1.0 (1.0 = homogeneous fleet), "
            f"got {straggler_factor!r}")
    if straggler_factor == 1.0 or num_clients == 0:
        return np.ones((num_clients,), np.float64)
    u = _lane_uniform(seed, num_clients, 0xC10C)
    return 1.0 + (straggler_factor - 1.0) * u


# ---------------------------------------------------------------------------
# Arrival traces
# ---------------------------------------------------------------------------

def arrival_offsets(num_clients: int, round_idx: int, *, seed: int = 0,
                    process: str = "static", spread: float = 0.0,
                    bursts: int = 4) -> Optional[np.ndarray]:
    """``(C,)`` per-client arrival delays (simulated seconds) for one round.

    ``None`` (the ``static`` process or ``spread=0``) means everyone is
    ready at the phase start — the legacy timeline, byte-for-byte.
    ``poisson`` draws iid exponential delays with mean ``spread``;
    ``bursty`` assigns each client a stable burst slot (uniform over
    ``bursts``, drawn from ``(seed, client)`` only) and spaces the bursts
    evenly over ``spread`` seconds with a small in-burst jitter — the
    flash-crowd shape heavy-traffic deployments actually see.
    """
    if process not in ARRIVAL_PROCESSES:
        raise ValueError(f"unknown arrival process {process!r}; known: "
                         + ", ".join(ARRIVAL_PROCESSES))
    if process == "static" or spread <= 0.0 or num_clients == 0:
        return None
    u = _lane_uniform(seed, num_clients, 0xA881, round_idx)
    if process == "poisson":
        return spread * -np.log1p(-u)
    if bursts < 1:
        raise ValueError(f"arrival_bursts must be >= 1, got {bursts!r}")
    gap = spread / bursts
    slot = np.floor(_lane_uniform(seed, num_clients, 0xB572) * bursts)
    return slot * gap + u * (0.1 * gap)


def online_mask(num_clients: int, round_idx: int, *, seed: int = 0,
                churn: float = 0.0) -> Optional[np.ndarray]:
    """``(C,)`` bool — which clients are online for the whole round.

    ``None`` (``churn=0``) means everyone, the legacy protocol. Each
    client flips its own coin per round, deterministic in
    ``(seed, round, client)``.
    """
    if not 0.0 <= churn < 1.0:
        raise ValueError(f"churn_prob must be in [0, 1), got {churn!r}")
    if churn == 0.0:
        return None
    return _lane_uniform(seed, num_clients, 0x0FF1, round_idx) >= churn


def dropout_mask(num_clients: int, round_idx: int, *, seed: int = 0,
                 dropout: float = 0.0) -> Optional[np.ndarray]:
    """``(C,)`` bool — True where a client drops *mid-round* (it trains but
    its report never reaches the server). ``None`` (``dropout=0``) means
    nobody drops. Deterministic in ``(seed, round, client)``.
    """
    if not 0.0 <= dropout < 1.0:
        raise ValueError(f"dropout_prob must be in [0, 1), got {dropout!r}")
    if dropout == 0.0:
        return None
    return _lane_uniform(seed, num_clients, 0xD801, round_idx) < dropout


# ---------------------------------------------------------------------------
# Timeline
# ---------------------------------------------------------------------------

class SimTimeline:
    """Simulated-deployment event clock: client lanes + one serial server.

    ``client_phase``/``server_phase`` advance the timeline by one phase
    node and return the node's simulated completion time (the barrier at
    which every participant of the phase has finished). Callers feed nodes
    in host execution order; per-lane occupancy then encodes the true
    data-dependency order automatically. Lane updates are vectorized
    (``np.maximum`` over the participating lanes) — identical to the
    per-client loop, one numpy op per phase instead of O(C) Python steps.
    """

    def __init__(self, speeds: np.ndarray):
        self.speeds = np.asarray(speeds, np.float64)
        self.client_free = np.zeros((len(self.speeds),), np.float64)
        self.server_free = 0.0

    # ------------------------------------------------- resumable service
    def state_dict(self) -> dict:
        """Lane occupancy for ``repro.fed.state.ExperimentState``.

        Speeds are not captured: they are a pure function of
        ``(seed, client, straggler_factor)`` and rebuilt at construction.
        """
        return {"client_free": self.client_free.copy(),
                "server_free": float(self.server_free)}

    def load_state_dict(self, sd: dict) -> None:
        lanes = np.asarray(sd["client_free"], np.float64)
        if lanes.shape != self.client_free.shape:
            raise ValueError(
                f"timeline lane-count mismatch: checkpoint {lanes.shape} "
                f"vs fleet {self.client_free.shape}")
        self.client_free = lanes.copy()
        self.server_free = float(sd["server_free"])

    def client_phase(self, participants: Optional[np.ndarray], base_s: float,
                     ready_s: float = 0.0,
                     offsets: Optional[np.ndarray] = None) -> float:
        """All participating clients run the phase in parallel: client ``c``
        starts at ``max(ready_s + its arrival offset, its lane's free
        time)`` and takes ``base_s * speed[c]``. Returns the barrier
        (latest finish); with no participants the phase completes at
        ``ready_s``. ``offsets`` (C,) are per-client arrival delays
        (``arrival_offsets``); ``None`` = everyone ready at ``ready_s``.
        ``base_s`` may also be a (C,) array of per-client base costs
        (heterogeneous-zoo pricing: each cohort's architecture has its own
        phase cost — see the ``"phase@cohort"`` keys of
        ``RoundScheduler.sim_phase_costs``)."""
        if participants is None:
            ids = slice(None)
        else:
            ids = np.flatnonzero(np.asarray(participants, bool))
            if ids.size == 0:
                return ready_s
        ready = ready_s if offsets is None else ready_s + offsets[ids]
        start = np.maximum(ready, self.client_free[ids])
        base = np.asarray(base_s)[ids] if np.ndim(base_s) else base_s
        finish = start + base * self.speeds[ids]
        self.client_free[ids] = finish
        return float(max(ready_s, finish.max())) if finish.size else ready_s

    def server_phase(self, base_s: float, ready_s: float = 0.0) -> float:
        """The server is one serial resource (aggregation runs round by
        round): the phase starts when both the server and its inputs are
        ready and takes ``base_s``."""
        start = max(ready_s, self.server_free)
        self.server_free = start + base_s
        return self.server_free
