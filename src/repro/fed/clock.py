"""Simulated straggler clock: deterministic per-client speeds + a timeline.

A single host executes every round phase back-to-back, so "overlapping
rounds beat lockstep rounds" is invisible in host wall-clock — the win
lives in the *deployment* timeline, where every edge client runs in
parallel at its own speed and the slowest participant gates each
synchronous barrier. This module prices a phase schedule onto that
timeline:

``client_speeds``
    ``(C,)`` slowdown multipliers in ``[1, straggler_factor]``, each drawn
    deterministically from ``(seed, client)`` and nothing else — stable
    across rounds, participation subsets, engines and client-count
    changes (client ``c`` keeps its speed when the fleet grows).

``SimTimeline``
    Event accounting over two resource kinds: one lane per client (clients
    run in parallel with each other; each client is serial with itself)
    and one serial server. The phase-graph scheduler
    (``repro.fed.scheduler``) replays its *host* execution order through
    the timeline, so per-client data dependencies are respected by
    construction: a lane is occupied in exactly the order the numerics
    consumed it.

The clock is pure accounting. It never reorders host execution and never
touches numerics; it only prices the schedule the scheduler chose. Eval
phases are priced at zero: evaluating every client against the held-out
test set is a simulation-side measurement, not deployment work.
"""
from __future__ import annotations

from typing import Optional

import numpy as np


def client_speeds(num_clients: int, *, seed: int = 0,
                  straggler_factor: float = 4.0) -> np.ndarray:
    """``(C,)`` per-client slowdown multipliers in ``[1, straggler_factor]``.

    ``straggler_factor=1`` is a homogeneous fleet (every multiplier exactly
    1). Each entry depends on ``(seed, client)`` only, so the draw is
    reproducible per client regardless of fleet size or round count.
    """
    if straggler_factor < 1.0:
        raise ValueError(
            f"straggler_factor must be >= 1.0 (1.0 = homogeneous fleet), "
            f"got {straggler_factor!r}")
    speeds = np.ones((num_clients,), np.float64)
    if straggler_factor == 1.0:
        return speeds
    for c in range(num_clients):
        u = np.random.default_rng(
            np.random.SeedSequence([seed % 2**32, c, 0xC10C])).random()
        speeds[c] = 1.0 + (straggler_factor - 1.0) * u
    return speeds


class SimTimeline:
    """Simulated-deployment event clock: client lanes + one serial server.

    ``client_phase``/``server_phase`` advance the timeline by one phase
    node and return the node's simulated completion time (the barrier at
    which every participant of the phase has finished). Callers feed nodes
    in host execution order; per-lane occupancy then encodes the true
    data-dependency order automatically.
    """

    def __init__(self, speeds: np.ndarray):
        self.speeds = np.asarray(speeds, np.float64)
        self.client_free = np.zeros((len(self.speeds),), np.float64)
        self.server_free = 0.0

    def client_phase(self, participants: Optional[np.ndarray], base_s: float,
                     ready_s: float = 0.0) -> float:
        """All participating clients run the phase in parallel: client ``c``
        starts at ``max(ready_s, its lane's free time)`` and takes
        ``base_s * speed[c]``. Returns the barrier (latest finish); with no
        participants the phase completes at ``ready_s``."""
        if participants is None:
            ids = np.arange(len(self.speeds))
        else:
            ids = np.flatnonzero(np.asarray(participants, bool))
        end = ready_s
        for c in ids:
            start = max(ready_s, self.client_free[c])
            finish = start + base_s * self.speeds[c]
            self.client_free[c] = finish
            end = max(end, finish)
        return end

    def server_phase(self, base_s: float, ready_s: float = 0.0) -> float:
        """The server is one serial resource (aggregation runs round by
        round): the phase starts when both the server and its inputs are
        ready and takes ``base_s``."""
        start = max(ready_s, self.server_free)
        self.server_free = start + base_s
        return self.server_free
