"""Batched cohort engine: vmapped clients, scanned minibatches, one jit.

The per-client ``LoopEngine`` (``repro.core.protocol``) pays a Python
dispatch and a host↔device transfer per client per step, capping
simulations at a handful of clients. This engine stacks clients into
leading-axis ``(C, ...)`` pytrees and runs every round phase — local
training, proxy logits, filter masks, distillation, evaluation — as a
single compiled call: ``jax.vmap`` over clients, ``jax.lax.scan`` over
minibatch steps. The KMeans-DRE learn/estimate path is vmapped too
(``core.kmeans.kmeans_fit_batched``), so all clients' filters run in one
call per round.

Homogeneous-cohort grouping rule
--------------------------------
``vmap`` requires every stacked client to share one ``apply_fn`` and one
parameter-tree structure, so clients are grouped by ``Client.arch_key``:
clients with equal keys form one cohort; a client with ``arch_key=None``
becomes a singleton cohort (still batched internally, trivially). The
paper's headline setting (Tables I/II) gives *every* client a distinct
CNN — there this engine degenerates to ten singleton cohorts and wins
little; its target is the paper's CIFAR10* feature mode and the FedDF /
FedD3-style scaling regimes (tens to hundreds of clients sharing an
architecture), where one compiled call replaces C Python loops. Mixed
populations work fine: each architecture group is its own cohort and the
round log is assembled in global client order.

Clients with unequal private-set sizes are padded to the cohort maximum;
padded samples carry zero loss weight and padded steps are no-ops
(params/opt-state gated by a validity flag), so results match the loop
engine exactly (``tests/test_cohort_parity.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distill as D
from repro.core.dre import KMeansDRE, KuLSIFDRE, rbf_kernel
from repro.core.kmeans import kmeans_fit_batched, min_dist_to_centroids
from repro.fed.batching import padded_epoch_plan, steps_per_epoch
from repro.fed.client import Client
from repro.optim.optimizers import apply_updates


def _stack_trees(trees):
    return jax.tree.map(lambda *leaves: jnp.stack(leaves), *trees)


def _unstack_tree(tree, i: int):
    return jax.tree.map(lambda leaf: leaf[i], tree)


def _where_tree(flag, new, old):
    return jax.tree.map(lambda a, b: jnp.where(flag, a, b), new, old)


class _Cohort:
    """One homogeneous architecture group: stacked state + jitted round ops."""

    def __init__(self, members: Sequence[Client], positions: Sequence[int]):
        self.members = list(members)
        self.positions = list(positions)     # index into the global client list
        c0 = members[0]
        # arch_key only contracts identical (init, apply) structure; the
        # training hyperparameters below are baked into the cohort's jitted
        # fns once, so they must agree across members
        for c in members[1:]:
            if c.opt is not c0.opt:
                # Optimizer is a NamedTuple of closures — equivalence is
                # undecidable, so cohort members must share one instance
                raise ValueError(
                    f"cohort members {c0.cid} and {c.cid} share arch_key "
                    f"{c0.arch_key!r} but hold distinct Optimizer instances; "
                    "construct one optimizer and pass it to every member "
                    "(or give them distinct arch_keys)")
            for attr in ("temperature", "distill_loss", "num_classes"):
                if getattr(c, attr) != getattr(c0, attr):
                    raise ValueError(
                        f"cohort members {c0.cid} and {c.cid} share arch_key "
                        f"{c0.arch_key!r} but differ in {attr}: "
                        f"{getattr(c0, attr)!r} vs {getattr(c, attr)!r}")
        self.apply_fn = c0.apply_fn
        self.opt = c0.opt
        self.temperature = c0.temperature
        self.loss_kind = c0.distill_loss
        self.num_classes = c0.num_classes

        self.n = np.array([len(c.y) for c in members], np.int64)
        n_max = int(self.n.max())
        x_pad = np.zeros((len(members), n_max, *c0.x.shape[1:]),
                         np.asarray(c0.x).dtype)
        y_pad = np.zeros((len(members), n_max), np.asarray(c0.y).dtype)
        m_pad = np.zeros((len(members), n_max), np.float32)
        for i, c in enumerate(members):
            x_pad[i, : self.n[i]] = c.x
            y_pad[i, : self.n[i]] = c.y
            m_pad[i, : self.n[i]] = 1.0
        self.x = jnp.asarray(x_pad)
        self.y = jnp.asarray(y_pad)
        self.sample_mask = jnp.asarray(m_pad)

        self.params = _stack_trees([c.params for c in members])
        self.opt_state = _stack_trees([c.opt_state for c in members])

        # filter state (filled by learn_dres)
        self.filter_kind = "none"
        self._filter_state: Dict[str, jax.Array] = {}

        self._build_fns()

    # ------------------------------------------------------------- jitted ops
    def _build_fns(self):
        apply_fn, opt = self.apply_fn, self.opt
        temp, loss_kind, k_cls = self.temperature, self.loss_kind, self.num_classes

        def scan_steps(batch_loss):
            """Shared scan skeleton: grad step + validity gating; the three
            training modes differ only in how (idx-batch, weights) become a
            loss. ``batch_loss(params, ib, wb) -> scalar``."""
            def chunk(params, opt_state, idx, w, valid):
                def step(carry, inp):
                    p, o = carry
                    ib, wb, v = inp
                    loss, grads = jax.value_and_grad(batch_loss)(p, ib, wb)
                    upd, o2 = opt.update(grads, o, p)
                    p2 = apply_updates(p, upd)
                    return (_where_tree(v, p2, p), _where_tree(v, o2, o)), loss

                (params, opt_state), losses = jax.lax.scan(
                    step, (params, opt_state), (idx, w, valid))
                return params, opt_state, losses
            return chunk

        def train_chunk(params, opt_state, x, y, idx, w, valid):
            """One client's scan over (steps, batch) index/weight plans."""
            def loss_fn(pp, ib, wb):
                logits = apply_fn(pp, jnp.take(x, ib, axis=0), True)
                logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
                yb = jnp.take(y, ib, axis=0)
                ll = jnp.take_along_axis(logp, yb[:, None], axis=1)[:, 0]
                return -jnp.sum(ll * wb) / jnp.maximum(jnp.sum(wb), 1.0)

            return scan_steps(loss_fn)(params, opt_state, idx, w, valid)

        def kd_loss(logits, teacher, wb):
            if loss_kind == "mse":
                return D.kd_mse_loss(logits, teacher, wb)
            return D.kd_kl_loss(logits, teacher, temp, wb)

        def distill_chunk(params, opt_state, px, teacher, idx, w, valid):
            """Shared proxy batch; per-client weights fold in teacher validity."""
            def loss_fn(pp, ib, wb):
                xb = jnp.take(px, ib, axis=0)
                tb = jnp.take(teacher, ib, axis=0)
                return kd_loss(apply_fn(pp, xb, True), tb, wb)

            return scan_steps(loss_fn)(params, opt_state, idx, w, valid)

        def distill_private_chunk(params, opt_state, x, y, tbc, vbc,
                                  idx, w, valid):
            """Data-free (FKD/PLS): teacher gathered per label from tbc."""
            def loss_fn(pp, ib, wb):
                xb = jnp.take(x, ib, axis=0)
                yb = jnp.take(y, ib, axis=0)
                return kd_loss(apply_fn(pp, xb, True), tbc[yb], wb * vbc[yb])

            return scan_steps(loss_fn)(params, opt_state, idx, w, valid)

        def classwise_chunk(params, x, y, m):
            logits = apply_fn(params, x, False).astype(jnp.float32)
            oh = jax.nn.one_hot(y, k_cls, dtype=jnp.float32) * m[:, None]
            sums = oh.T @ logits
            cnt = jnp.sum(oh, axis=0)
            return sums / jnp.maximum(cnt[:, None], 1.0), cnt

        def kmeans_mask_chunk(cents, thr, cid, pxf, owner):
            d = min_dist_to_centroids(pxf, cents)
            return (owner == cid) | (d <= thr)

        self._train = jax.jit(jax.vmap(train_chunk))
        self._distill = jax.jit(
            jax.vmap(distill_chunk, in_axes=(0, 0, None, None, 0, 0, 0)))
        self._distill_private = jax.jit(
            jax.vmap(distill_private_chunk,
                     in_axes=(0, 0, 0, 0, None, None, 0, 0, 0)))
        self._predict = jax.jit(
            jax.vmap(lambda p, xb: apply_fn(p, xb, False), in_axes=(0, None)))
        self._classwise = jax.jit(jax.vmap(classwise_chunk))
        self._kmeans_masks = jax.jit(
            jax.vmap(kmeans_mask_chunk, in_axes=(0, 0, 0, None, None)))

        def kulsif_mask_chunk(alpha, aux, priv, n, thr, cid, sigma, lam,
                              pxf, owner):
            k_ta = rbf_kernel(pxf, aux, sigma)
            k_tp = rbf_kernel(pxf, priv, sigma)
            r = k_ta @ alpha + jnp.sum(k_tp, axis=1) / (lam * n)
            return (owner == cid) | (r >= thr)

        self._kulsif_masks = jax.jit(
            jax.vmap(kulsif_mask_chunk,
                     in_axes=(0, 0, 0, 0, 0, 0, None, None, None, None)))

    # -------------------------------------------------------------- DRE learn
    def learn_dres(self, key) -> None:
        if self.members[0].dre is None:
            return
        keys = [jax.random.fold_in(key, pos) for pos in self.positions]
        dres = [c.dre for c in self.members]

        if isinstance(dres[0], KMeansDRE):
            ks = {d.num_centroids for d in dres}
            uniform = (len(set(self.n)) == 1 and len(ks) == 1
                       and len({d.threshold for d in dres}) == 1)
            if uniform:
                # the vmapped learn path: every filter fit in one call
                k = ks.pop()
                feats = self.x.reshape(len(self.members), int(self.n[0]), -1)
                res = kmeans_fit_batched(jnp.stack(keys), feats, k,
                                         dres[0].max_iter)
                if dres[0].threshold is None:
                    dmin = jax.vmap(min_dist_to_centroids)(feats, res.centroids)
                    thrs = jnp.quantile(dmin, dres[0].calibration_q, axis=1)
                else:
                    thrs = jnp.full((len(self.members),), dres[0].threshold)
                for i, c in enumerate(self.members):
                    c.dre = dataclasses.replace(
                        c.dre, centroids=res.centroids[i],
                        threshold=float(thrs[i]))
            else:
                for c, kk in zip(self.members, keys):
                    c.learn_dre(kk)
            kmax = max(c.dre.centroids.shape[0] for c in self.members)
            cents = []
            for c in self.members:
                cc = c.dre.centroids
                if cc.shape[0] < kmax:  # pad by repeating the first centroid:
                    pad = jnp.tile(cc[:1], (kmax - cc.shape[0], 1))
                    cc = jnp.concatenate([cc, pad])  # min-distance unchanged
                cents.append(cc)
            self.filter_kind = "kmeans"
            self._filter_state = {
                "centroids": jnp.stack(cents),
                "thresholds": jnp.asarray([c.dre.threshold
                                           for c in self.members],
                                          jnp.float32),
            }
        elif isinstance(dres[0], KuLSIFDRE):
            # sigma/lam are baked into the vmapped ratio evaluation once,
            # so they must agree across members (thresholds are per-client)
            for d in dres[1:]:
                if (d.sigma, d.lam) != (dres[0].sigma, dres[0].lam):
                    raise ValueError(
                        f"cohort KuLSIF DREs disagree on (sigma, lam): "
                        f"{(dres[0].sigma, dres[0].lam)} vs "
                        f"{(d.sigma, d.lam)}; give such clients distinct "
                        "arch_keys")
            for c, kk in zip(self.members, keys):
                c.learn_dre(kk)
            n_max = int(self.n.max())
            d = self.members[0].dre.private.shape[1]
            # pad private sets with a far-away sentinel: its RBF kernel mass
            # underflows to exactly 0, so padded rows contribute nothing
            priv = np.full((len(self.members), n_max, d), 1e6, np.float32)
            for i, c in enumerate(self.members):
                priv[i, : self.n[i]] = np.asarray(c.dre.private)
            self.filter_kind = "kulsif"
            self._filter_state = {
                "alpha": jnp.stack([c.dre.alpha for c in self.members]),
                "aux": jnp.stack([c.dre.aux for c in self.members]),
                "private": jnp.asarray(priv),
                "n": jnp.asarray(self.n, jnp.float32),
                "thresholds": jnp.asarray([c.dre.threshold
                                           for c in self.members],
                                          jnp.float32),
                "sigma": jnp.float32(dres[0].sigma),
                "lam": jnp.float32(dres[0].lam),
            }
        else:  # unknown estimator: fall back to per-client mask calls
            for c, kk in zip(self.members, keys):
                c.learn_dre(kk)
            self.filter_kind = "loop"

    # ----------------------------------------------------------- round phases
    def _plan(self, draw_n: int, epochs: int, batch_size: int,
              weight=None) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Draw per-client epoch permutations (advancing each client's rng
        exactly as the loop engine would) and pack them into fixed arrays."""
        C = len(self.members)
        if draw_n >= 0:
            ns = [draw_n] * C          # shared proxy set
        else:
            ns = [int(v) for v in self.n]
        steps = max(steps_per_epoch(n, batch_size) for n in ns) * epochs
        idx = np.zeros((C, steps, batch_size), np.int32)
        w = np.zeros((C, steps, batch_size), np.float32)
        valid = np.zeros((C, steps), bool)
        for i, c in enumerate(self.members):
            perms = [c.rng.permutation(ns[i]) for _ in range(epochs)]
            idx[i], w[i], valid[i] = padded_epoch_plan(perms, batch_size, steps)
        if weight is not None:
            w = w * np.asarray(weight, np.float32)[idx]
        return idx, w, valid

    def _mean_losses(self, losses, valid) -> List[float]:
        losses = np.asarray(losses, np.float64)
        valid = np.asarray(valid, np.float64)
        cnt = valid.sum(axis=1)
        tot = (losses * valid).sum(axis=1)
        return [float(t / c) if c else 0.0 for t, c in zip(tot, cnt)]

    def local_train(self, epochs: int, batch_size: int) -> List[float]:
        idx, w, valid = self._plan(-1, epochs, batch_size)
        self.params, self.opt_state, losses = self._train(
            self.params, self.opt_state, self.x, self.y,
            jnp.asarray(idx), jnp.asarray(w), jnp.asarray(valid))
        return self._mean_losses(losses, valid)

    def distill(self, px, teacher, weight, epochs: int,
                batch_size: int) -> List[float]:
        idx, w, valid = self._plan(len(px), epochs, batch_size, weight=weight)
        self.params, self.opt_state, losses = self._distill(
            self.params, self.opt_state, jnp.asarray(px), jnp.asarray(teacher),
            jnp.asarray(idx), jnp.asarray(w), jnp.asarray(valid))
        return self._mean_losses(losses, valid)

    def distill_private(self, teacher_by_class, valid_by_class, epochs: int,
                        batch_size: int) -> List[float]:
        idx, w, valid = self._plan(-1, epochs, batch_size)
        self.params, self.opt_state, losses = self._distill_private(
            self.params, self.opt_state, self.x, self.y,
            jnp.asarray(teacher_by_class),
            jnp.asarray(np.asarray(valid_by_class, np.float32)),
            jnp.asarray(idx), jnp.asarray(w), jnp.asarray(valid))
        return self._mean_losses(losses, valid)

    def classwise_means(self):
        means, counts = self._classwise(self.params, self.x, self.y,
                                        self.sample_mask)
        return [(means[i], counts[i]) for i in range(len(self.members))]

    def proxy_logits(self, px) -> np.ndarray:
        return np.asarray(self._predict(self.params, jnp.asarray(px)))

    def filter_masks(self, px, powner) -> np.ndarray:
        t = len(px)
        if self.filter_kind == "none":
            return np.ones((len(self.members), t), bool)
        if self.filter_kind == "loop":
            return np.stack([np.asarray(c.filter_mask(px, powner).mask)
                             for c in self.members])
        pxf = jnp.asarray(np.asarray(px).reshape(t, -1))
        owner = jnp.asarray(powner)
        cids = jnp.asarray([c.cid for c in self.members])
        st = self._filter_state
        if self.filter_kind == "kmeans":
            masks = self._kmeans_masks(st["centroids"], st["thresholds"],
                                       cids, pxf, owner)
        else:
            masks = self._kulsif_masks(st["alpha"], st["aux"], st["private"],
                                       st["n"], st["thresholds"], cids,
                                       st["sigma"], st["lam"], pxf, owner)
        return np.asarray(masks)

    def evaluate(self, x_test, y_test, batch_size: int = 512) -> List[float]:
        n = len(y_test)
        correct = np.zeros(len(self.members), np.int64)
        for s in range(0, n, batch_size):
            logits = self._predict(self.params,
                                   jnp.asarray(x_test[s:s + batch_size]))
            pred = np.asarray(jnp.argmax(logits, -1))          # (C, b)
            correct += (pred == np.asarray(y_test[s:s + batch_size])[None]
                        ).sum(axis=1)
        return [int(c) / n for c in correct]

    def sync_to_clients(self) -> None:
        """Write stacked params/opt-state back onto the Client objects."""
        for i, c in enumerate(self.members):
            c.params = _unstack_tree(self.params, i)
            c.opt_state = _unstack_tree(self.opt_state, i)


class CohortEngine:
    """Engine over architecture-grouped cohorts; same interface as LoopEngine.

    The ``Client`` objects remain the source of private data, DRE config and
    rng streams, but their params/opt-state live *stacked on device* for the
    engine's lifetime; call ``sync_to_clients()`` before reading them back
    (e.g. for checkpointing).
    """

    def __init__(self, clients: Sequence[Client]):
        self.clients = list(clients)
        groups: Dict[object, Tuple[List[Client], List[int]]] = {}
        for pos, c in enumerate(self.clients):
            key = c.arch_key if c.arch_key is not None else ("solo", pos)
            members, positions = groups.setdefault(key, ([], []))
            members.append(c)
            positions.append(pos)
        self.cohorts = [_Cohort(m, p) for m, p in groups.values()]

    @property
    def num_clients(self) -> int:
        return len(self.clients)

    def _scatter(self, per_cohort_lists) -> List:
        out = [None] * len(self.clients)
        for cohort, values in zip(self.cohorts, per_cohort_lists):
            for pos, v in zip(cohort.positions, values):
                out[pos] = v
        return out

    def learn_dres(self, key) -> None:
        for cohort in self.cohorts:
            cohort.learn_dres(key)

    def local_train_all(self, epochs: int, batch_size: int) -> List[float]:
        return self._scatter([c.local_train(epochs, batch_size)
                              for c in self.cohorts])

    def classwise_means_all(self):
        return self._scatter([c.classwise_means() for c in self.cohorts])

    def proxy_logits_and_masks(self, px, powner):
        t = len(px)
        k = self.clients[0].num_classes
        logits = np.zeros((len(self.clients), t, k), np.float32)
        masks = np.zeros((len(self.clients), t), bool)
        for cohort in self.cohorts:
            logits[cohort.positions] = cohort.proxy_logits(px)
            masks[cohort.positions] = cohort.filter_masks(px, powner)
        return logits, masks

    def distill_all(self, px, teacher, weight, epochs: int,
                    batch_size: int) -> List[float]:
        return self._scatter([c.distill(px, teacher, weight, epochs, batch_size)
                              for c in self.cohorts])

    def distill_private_all(self, teacher_by_class, valid_by_class,
                            epochs: int, batch_size: int) -> List[float]:
        return self._scatter(
            [c.distill_private(teacher_by_class, valid_by_class, epochs,
                               batch_size) for c in self.cohorts])

    def evaluate_all(self, x_test, y_test) -> List[float]:
        return self._scatter([c.evaluate(x_test, y_test)
                              for c in self.cohorts])

    def sync_to_clients(self) -> None:
        for cohort in self.cohorts:
            cohort.sync_to_clients()
