"""Batched cohort engine: vmapped clients, scanned minibatches, one jit.

The per-client ``LoopEngine`` (``repro.core.protocol``) pays a Python
dispatch and a host↔device transfer per client per step, capping
simulations at a handful of clients. This engine stacks clients into
leading-axis ``(C, ...)`` pytrees and runs every round phase — local
training, proxy logits, filter masks, distillation, evaluation — as a
single compiled call: ``jax.vmap`` over clients, ``jax.lax.scan`` over
minibatch steps. The KMeans-DRE learn/estimate path is vmapped too
(``core.kmeans.kmeans_fit_batched``), so all clients' filters run in one
call per round.

Homogeneous-cohort grouping rule
--------------------------------
``vmap`` requires every stacked client to share one ``apply_fn`` and one
parameter-tree structure, so clients are grouped by ``Client.arch_key``:
clients with equal keys form one cohort; a client with ``arch_key=None``
becomes a singleton cohort (still batched internally, trivially). The
paper's headline setting (Tables I/II) gives *every* client a distinct
CNN — there this engine degenerates to ten singleton cohorts and wins
little; its target is the paper's CIFAR10* feature mode and the FedDF /
FedD3-style scaling regimes (tens to hundreds of clients sharing an
architecture), where one compiled call replaces C Python loops. Mixed
populations work fine: each architecture group is its own cohort and the
round log is assembled in global client order.

Clients with unequal private-set sizes are padded to the cohort maximum;
padded samples carry zero loss weight and padded steps are no-ops
(params/opt-state gated by a validity flag), so results match the loop
engine exactly (``tests/test_cohort_parity.py``).

Device-mesh sharding
--------------------
Pass a 1-D ``Mesh`` (``repro.fed.mesh.build_client_mesh``) and every
stacked pytree is placed with its client axis split across the mesh
(``NamedSharding``), so each compiled round phase runs device-parallel
with zero cross-device collectives (per-client work is independent; the
server's cross-client aggregation happens on host). Cohorts whose client
count is not a multiple of the mesh size are padded with *dummy clients*
whose step-validity flags are all False — the same ``_where_tree`` gating
that freezes short clients makes every dummy step a no-op — and dummy
rows are sliced off before any result leaves the engine. Outputs of the
jitted phases are pinned back to the client axis via the logical-rules
machinery in ``repro.models.sharding`` (logical axis ``"clients"``), so
params/opt-state never decay to a single device between rounds.

Wave streaming
--------------
``wave_size > 0`` bounds *peak device memory by the wave, not by C*: the
cohort host-stages every stacked ``(C, ...)`` array (data, params,
opt-state, filter state) as numpy and runs each compiled phase
``wave_size`` clients at a time — rows ``[lo, hi)`` are staged onto the
device (padded to the wave's mesh-divisible ``c_pad`` with the same
validity-gated dummy lanes used everywhere else), the phase runs, results
stream back to the host arrays, and the device buffers are dropped before
the next wave. Every jitted phase is built once with the *wave* as its
leading axis, so shapes never change across waves, rounds, or
participation subsets — zero retraces (guarded in
``tests/test_scale.py``). Per-client math is lane-independent, so waved
results match the single-wave path; ``wave_size = 0`` (default) or
``wave_size >= C`` keeps the historical device-resident path bit-for-bit.

Partial participation
---------------------
Every round phase accepts a per-round participation mask
(``repro.fed.participation``). Sampled-out clients ride along as *no-op
lanes*: their step-validity flags stay all-False — the same
``_where_tree`` gating that freezes dummy padding clients — their rng
streams are not advanced (keeping loop↔cohort parity), and their
logits/mask rows are zeroed before leaving the engine. The mask changes
only data, never array shapes, so sampling a different subset each round
reuses every compiled phase, and it composes with mesh padding (a dummy
row is simply a lane no mask ever validates).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distill as D
from repro.core.dre import KMeansDRE, KuLSIFDRE
from repro.core.kmeans import kmeans_fit_batched, min_dist_to_centroids
from repro.fed.batching import padded_epoch_plan, steps_per_epoch
from repro.fed.client import Client
from repro.fed.mesh import (DEFAULT_CLIENT_AXIS, MODEL_LOGICAL_RULES,
                            model_axis_name, padded_size, replicate,
                            shard_clients, shard_stacked_state,
                            stacked_state_shardings)
from repro.kernels import dispatch
from repro.models.sharding import constrain, logical_rules
from repro.optim.optimizers import apply_updates


def _stack_trees(trees):
    return jax.tree.map(lambda *leaves: jnp.stack(leaves), *trees)


def _unstack_tree(tree, i: int):
    return jax.tree.map(lambda leaf: leaf[i], tree)


def _where_tree(flag, new, old):
    return jax.tree.map(lambda a, b: jnp.where(flag, a, b), new, old)


class _Cohort:
    """One homogeneous architecture group: stacked state + jitted round ops."""

    def __init__(self, members: Sequence[Client], positions: Sequence[int],
                 mesh=None, mesh_axis: str = DEFAULT_CLIENT_AXIS,
                 wave_size: int = 0):
        self.members = list(members)
        self.positions = list(positions)     # index into the global client list
        self.mesh = mesh
        self.mesh_axis = mesh_axis
        # 2-D (clients, model) mesh: weight matrices shard over this axis
        # too (repro.fed.mesh.stacked_state_shardings); None on a 1-D mesh
        self.model_axis = model_axis_name(mesh)
        if wave_size < 0:
            raise ValueError(f"wave_size must be >= 0, got {wave_size!r}")
        # wave streaming kicks in only when it would actually split the
        # cohort; a wave covering everyone IS the legacy single-wave path
        self._waved = 0 < wave_size < len(self.members)
        self.wave_size = wave_size if self._waved else len(self.members)
        # client axis of the *device-resident* stack after padding to a
        # multiple of the mesh size: the whole cohort in legacy mode, one
        # wave in streaming mode. Rows past the live members are
        # validity-gated dummy clients either way.
        self.c_pad = padded_size(self.wave_size, mesh)
        c0 = members[0]
        # arch_key only contracts identical (init, apply) structure; the
        # training hyperparameters below are baked into the cohort's jitted
        # fns once, so they must agree across members
        for c in members[1:]:
            if c.opt is not c0.opt:
                # Optimizer is a NamedTuple of closures — equivalence is
                # undecidable, so cohort members must share one instance
                raise ValueError(
                    f"cohort members {c0.cid} and {c.cid} share arch_key "
                    f"{c0.arch_key!r} but hold distinct Optimizer instances; "
                    "construct one optimizer and pass it to every member "
                    "(or give them distinct arch_keys)")
            if c.apply_fn != c0.apply_fn:
                # bound-method equality compares (__self__, __func__), so
                # clients sharing one model spec / MLP instance still pass
                raise ValueError(
                    f"cohort members {c0.cid} and {c.cid} share arch_key "
                    f"{c0.arch_key!r} but hold different apply_fns; the "
                    "cohort would silently run member 0's network for "
                    "everyone — share one model object per arch_key "
                    "(or give them distinct arch_keys)")
            for attr in ("temperature", "distill_loss", "num_classes"):
                if getattr(c, attr) != getattr(c0, attr):
                    raise ValueError(
                        f"cohort members {c0.cid} and {c.cid} share arch_key "
                        f"{c0.arch_key!r} but differ in {attr}: "
                        f"{getattr(c0, attr)!r} vs {getattr(c, attr)!r}")
            # compare *resolved* backends: None and "auto" (and "pallas" vs
            # "auto" on TPU) select the same kernels and must not split a
            # cohort
            if (dispatch.resolve(c.kernel_backend)
                    != dispatch.resolve(c0.kernel_backend)):
                raise ValueError(
                    f"cohort members {c0.cid} and {c.cid} share arch_key "
                    f"{c0.arch_key!r} but resolve to different kernel "
                    f"backends: {c0.kernel_backend!r} vs "
                    f"{c.kernel_backend!r}")
        self.apply_fn = c0.apply_fn
        self.opt = c0.opt
        self.temperature = c0.temperature
        self.loss_kind = c0.distill_loss
        self.num_classes = c0.num_classes
        # resolved once at construction and baked into the jitted phases —
        # flipping the ambient backend later never retraces a phase
        self.kernel_backend = dispatch.resolve(c0.kernel_backend)

        self.n = np.array([len(c.y) for c in members], np.int64)
        n_max = int(self.n.max())
        lead = len(members) if self._waved else self.c_pad
        x_pad = np.zeros((lead, n_max, *c0.x.shape[1:]),
                         np.asarray(c0.x).dtype)
        y_pad = np.zeros((lead, n_max), np.asarray(c0.y).dtype)
        m_pad = np.zeros((lead, n_max), np.float32)
        for i, c in enumerate(members):
            x_pad[i, : self.n[i]] = c.x
            y_pad[i, : self.n[i]] = c.y
            m_pad[i, : self.n[i]] = 1.0
        if self._waved:
            # streaming mode: the master copies live on host; each phase
            # stages wave_size rows at a time (see ``_stage``/``_waves``)
            self._hx, self._hy, self._hm = x_pad, y_pad, m_pad
            # stack in numpy — the full (C, ...) params/opt stack must
            # never touch the device, that's the whole point
            def _np_stack(*leaves):
                return np.stack([np.asarray(l) for l in leaves])
            self._hparams = jax.tree.map(_np_stack,
                                         *[c.params for c in members])
            self._hopt = jax.tree.map(_np_stack,
                                      *[c.opt_state for c in members])
            self.x = self.y = self.sample_mask = None
            self.params = self.opt_state = None
        else:
            self.x = self._put_c(x_pad)
            self.y = self._put_c(y_pad)
            self.sample_mask = self._put_c(m_pad)

            # dummy rows clone member 0's state; their steps never validate,
            # so the clone is inert ballast that keeps the client axis
            # mesh-divisible
            stand_ins = [members[0]] * (self.c_pad - len(members))
            self.params = self._put_state(
                _stack_trees([c.params for c in [*members, *stand_ins]]))
            self.opt_state = self._put_state(
                _stack_trees([c.opt_state for c in [*members, *stand_ins]]))

        # filter state (filled by learn_dres, or packed right away when the
        # clients arrive with already-learned DREs — e.g. the transient
        # engine run_round builds per call from a raw client list)
        self.filter_kind = "none"
        self._filter_state: Dict[str, jax.Array] = {}

        self._build_fns()
        self._pack_learned_filter_state()

    # ----------------------------------------------------- mesh placement
    def _put_c(self, tree):
        """Place leaves with the leading client axis split over the mesh."""
        return shard_clients(jax.tree.map(jnp.asarray, tree),
                             self.mesh, self.mesh_axis)

    def _put_rep(self, tree):
        """Place leaves replicated on every mesh device (shared inputs)."""
        return replicate(jax.tree.map(jnp.asarray, tree), self.mesh)

    def _put_state(self, tree):
        """Place a stacked params/opt-state pytree: client split on a 1-D
        mesh (bit-for-bit the historical ``_put_c``), per-leaf client ×
        model ``NamedSharding``s on a 2-D mesh."""
        return shard_stacked_state(jax.tree.map(jnp.asarray, tree),
                                   self.mesh, self.mesh_axis)

    def _pad_rows(self, arr, fill=None):
        """Pad per-member stacked rows (leading axis C) out to ``c_pad``.

        ``fill=None`` repeats the first row (values are discarded — dummy
        rows only exist to keep the axis mesh-divisible); a scalar ``fill``
        writes that value (e.g. 1.0 where a dummy row would divide by n)."""
        arr = jnp.asarray(arr)
        extra = self.c_pad - arr.shape[0]
        if extra == 0:
            return arr
        if fill is None:
            pad = jnp.tile(arr[:1], (extra,) + (1,) * (arr.ndim - 1))
        else:
            pad = jnp.full((extra, *arr.shape[1:]), fill, arr.dtype)
        return jnp.concatenate([arr, pad])

    # ----------------------------------------------------- wave streaming
    def _waves(self):
        """Yield the ``[lo, hi)`` member ranges of each wave (one full-range
        wave in legacy mode — callers never branch on ``_waved``)."""
        c = len(self.members)
        for lo in range(0, c, self.wave_size):
            yield lo, min(lo + self.wave_size, c)

    def _stage(self, arr, lo: int, hi: int, fill=0):
        """Stage host rows ``[lo, hi)`` as a ``(c_pad, ...)`` device-ready
        array. Rows past ``hi - lo`` are dummy lanes: ``fill`` is a pad
        value (0 for data/plans, sentinels like -1/1.0/1e6 where a dummy
        row feeds a divide or an RBF kernel), or ``None`` to repeat row
        ``lo`` (params/opt-state ballast, values never read back)."""
        arr = np.asarray(arr)
        n = hi - lo
        if n == self.c_pad:
            return arr[lo:hi]
        if fill is None:
            pad = np.repeat(arr[lo:lo + 1], self.c_pad - n, axis=0)
            return np.concatenate([arr[lo:hi], pad])
        out = np.full((self.c_pad, *arr.shape[1:]), fill, arr.dtype)
        out[:n] = arr[lo:hi]
        return out

    def _stage_state(self, lo: int, hi: int):
        """One wave's params/opt-state, staged host -> device."""
        pd = self._put_state(jax.tree.map(
            lambda leaf: self._stage(leaf, lo, hi, fill=None), self._hparams))
        od = self._put_state(jax.tree.map(
            lambda leaf: self._stage(leaf, lo, hi, fill=None), self._hopt))
        return pd, od

    def _write_state(self, params_dev, opt_dev, lo: int, hi: int) -> None:
        """Stream one wave's updated params/opt-state back to the host
        masters (dummy rows dropped); the device buffers die with their
        last reference when the next wave stages."""
        n = hi - lo
        jax.tree.map(
            lambda h, d: h.__setitem__(slice(lo, hi), np.asarray(d)[:n]),
            self._hparams, params_dev)
        jax.tree.map(
            lambda h, d: h.__setitem__(slice(lo, hi), np.asarray(d)[:n]),
            self._hopt, opt_dev)

    def _ctx(self):
        """Logical-rules scope for every jitted call: inside it the logical
        ``"clients"`` axis resolves to this cohort's mesh axis, so traces
        pin outputs to the client mesh and never pick up an outer
        launcher's model-parallel rules. On a 2-D (clients, model) mesh the
        model-side logical axes (heads/ff/vocab/experts) resolve to the
        model axis too, so ``constrain`` calls inside transformer apply_fns
        keep activations in the Megatron layout (replicated residual
        stream, model-sharded heads); on a 1-D mesh those rules resolve to
        nothing and the trace is bit-for-bit the historical one."""
        if self.mesh is None:
            return logical_rules(None, None)
        rules = {**MODEL_LOGICAL_RULES, "clients": self.mesh_axis}
        return logical_rules(rules, self.mesh)

    # ------------------------------------------------------------- jitted ops
    def _build_fns(self):
        apply_fn, opt = self.apply_fn, self.opt
        temp, loss_kind, k_cls = self.temperature, self.loss_kind, self.num_classes
        backend = self.kernel_backend

        # per-leaf output shardings for the training-state outputs: on a
        # 2-D mesh constraining params to P("clients") alone would undo
        # the model split every step (and re-replicate each client's
        # weights across the model axis — exactly the memory the 2-D mesh
        # exists to save), so state outputs pin to the same per-leaf specs
        # their inputs were placed with. Shapes come from whichever stack
        # exists (device stack, or the host masters in waved mode) — only
        # the non-leading dims matter for the specs and they are equal.
        if self.model_axis is not None:
            p_like = self.params if not self._waved else self._hparams
            o_like = self._hopt if self._waved else self.opt_state
            p_sh = stacked_state_shardings(p_like, self.mesh, self.mesh_axis)
            o_sh = stacked_state_shardings(o_like, self.mesh, self.mesh_axis)
        else:
            p_sh = o_sh = None

        def pin_clients(tree):
            return jax.tree.map(lambda leaf: constrain(leaf, "clients"),
                                tree)

        def pin_state(tree, shardings):
            if shardings is None:
                return pin_clients(tree)
            return jax.tree.map(
                lambda leaf, sh: jax.lax.with_sharding_constraint(leaf, sh),
                tree, shardings)

        def pinned(fn, state_out: bool = False):
            """jit(fn) with every output pinned to the client axis (no-op
            when traced without a mesh in scope — see ``_ctx``).
            ``state_out`` marks fns returning (params, opt_state, losses):
            their state outputs take the per-leaf client × model specs."""
            def wrapped(*args):
                out = fn(*args)
                if state_out:
                    params, opt_state, losses = out
                    return (pin_state(params, p_sh),
                            pin_state(opt_state, o_sh),
                            pin_clients(losses))
                return pin_clients(out)
            return jax.jit(wrapped)

        def scan_steps(batch_loss):
            """Shared scan skeleton: grad step + validity gating; the three
            training modes differ only in how (idx-batch, weights) become a
            loss. ``batch_loss(params, ib, wb) -> scalar``."""
            def chunk(params, opt_state, idx, w, valid):
                def step(carry, inp):
                    p, o = carry
                    ib, wb, v = inp
                    loss, grads = jax.value_and_grad(batch_loss)(p, ib, wb)
                    upd, o2 = opt.update(grads, o, p)
                    p2 = apply_updates(p, upd)
                    return (_where_tree(v, p2, p), _where_tree(v, o2, o)), loss

                (params, opt_state), losses = jax.lax.scan(
                    step, (params, opt_state), (idx, w, valid))
                return params, opt_state, losses
            return chunk

        def train_chunk(params, opt_state, x, y, idx, w, valid):
            """One client's scan over (steps, batch) index/weight plans."""
            def loss_fn(pp, ib, wb):
                logits = apply_fn(pp, jnp.take(x, ib, axis=0), True)
                logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
                yb = jnp.take(y, ib, axis=0)
                ll = jnp.take_along_axis(logp, yb[:, None], axis=1)[:, 0]
                return -jnp.sum(ll * wb) / jnp.maximum(jnp.sum(wb), 1.0)

            return scan_steps(loss_fn)(params, opt_state, idx, w, valid)

        def kd_loss(logits, teacher, wb):
            if loss_kind == "mse":
                return D.kd_mse_loss(logits, teacher, wb)
            return D.kd_kl_loss(logits, teacher, temp, wb, backend=backend)

        def distill_chunk(params, opt_state, px, teacher, idx, w, valid):
            """Shared proxy batch; per-client weights fold in teacher validity."""
            def loss_fn(pp, ib, wb):
                xb = jnp.take(px, ib, axis=0)
                tb = jnp.take(teacher, ib, axis=0)
                return kd_loss(apply_fn(pp, xb, True), tb, wb)

            return scan_steps(loss_fn)(params, opt_state, idx, w, valid)

        def distill_private_chunk(params, opt_state, x, y, tbc, vbc,
                                  idx, w, valid):
            """Data-free (FKD/PLS): teacher gathered per label from tbc."""
            def loss_fn(pp, ib, wb):
                xb = jnp.take(x, ib, axis=0)
                yb = jnp.take(y, ib, axis=0)
                return kd_loss(apply_fn(pp, xb, True), tbc[yb], wb * vbc[yb])

            return scan_steps(loss_fn)(params, opt_state, idx, w, valid)

        def classwise_chunk(params, x, y, m):
            logits = apply_fn(params, x, False).astype(jnp.float32)
            oh = jax.nn.one_hot(y, k_cls, dtype=jnp.float32) * m[:, None]
            sums = oh.T @ logits
            cnt = jnp.sum(oh, axis=0)
            return sums / jnp.maximum(cnt[:, None], 1.0), cnt

        def kmeans_mask_chunk(cents, thr, cid, pxf, owner):
            d = min_dist_to_centroids(pxf, cents)
            return (owner == cid) | (d <= thr)

        def eval_chunk(params, xb, yb, mb):
            """Fixed-shape eval: (nb, B, ...) batches, padded tail masked by
            ``mb`` — one compile regardless of ``len(y_test) % B``."""
            def body(correct, inp):
                x1, y1, m1 = inp
                pred = jnp.argmax(apply_fn(params, x1, False), -1)
                return correct + jnp.sum((pred == y1) * m1), None
            correct, _ = jax.lax.scan(body, jnp.zeros((), jnp.int32),
                                      (xb, yb, mb))
            return correct

        self._train = pinned(jax.vmap(train_chunk), state_out=True)
        self._distill = pinned(
            jax.vmap(distill_chunk, in_axes=(0, 0, None, None, 0, 0, 0)),
            state_out=True)
        self._distill_private = pinned(
            jax.vmap(distill_private_chunk,
                     in_axes=(0, 0, 0, 0, None, None, 0, 0, 0)),
            state_out=True)
        self._predict = pinned(
            jax.vmap(lambda p, xb: apply_fn(p, xb, False), in_axes=(0, None)))
        self._eval = pinned(
            jax.vmap(eval_chunk, in_axes=(0, None, None, None)))
        self._classwise = pinned(jax.vmap(classwise_chunk))
        self._kmeans_masks = pinned(
            jax.vmap(kmeans_mask_chunk, in_axes=(0, 0, 0, None, None)))

        def kulsif_mask_chunk(alpha, aux, priv, n, thr, cid, sigma, lam,
                              pxf, owner):
            # dispatched like KuLSIFDRE.estimate — under vmap the Pallas
            # path batches through the kernel's grid (one trace per cohort)
            k_ta = dispatch.rbf_matrix(pxf, aux, sigma, backend=backend)
            k_tp = dispatch.rbf_matrix(pxf, priv, sigma, backend=backend)
            r = k_ta @ alpha + jnp.sum(k_tp, axis=1) / (lam * n)
            return (owner == cid) | (r >= thr)

        self._kulsif_masks = pinned(
            jax.vmap(kulsif_mask_chunk,
                     in_axes=(0, 0, 0, 0, 0, 0, None, None, None, None)))

    # -------------------------------------------------------------- DRE learn
    @staticmethod
    def _check_kulsif_uniform(dres) -> None:
        # sigma/lam/kernel_backend are baked into the vmapped ratio
        # evaluation once, so they must agree across members (thresholds
        # are per-client); backends compare *resolved* — None and "auto"
        # select the same kernels
        for d in dres[1:]:
            if ((d.sigma, d.lam, dispatch.resolve(d.kernel_backend))
                    != (dres[0].sigma, dres[0].lam,
                        dispatch.resolve(dres[0].kernel_backend))):
                raise ValueError(
                    f"cohort KuLSIF DREs disagree on (sigma, lam, "
                    f"kernel_backend): "
                    f"{(dres[0].sigma, dres[0].lam, dres[0].kernel_backend)}"
                    f" vs {(d.sigma, d.lam, d.kernel_backend)}; give such "
                    "clients distinct arch_keys")

    def learn_dres(self, key) -> None:
        if all(c.dre is None for c in self.members):
            return
        keys = [jax.random.fold_in(key, pos) for pos in self.positions]
        dres = [c.dre for c in self.members]

        if all(isinstance(d, KMeansDRE) for d in dres):
            ks = {d.num_centroids for d in dres}
            # the vmapped fit bakes ONE (threshold, calibration_q, max_iter,
            # kernel_backend) into the whole batch, so every fit
            # hyperparameter must agree — anything less silently
            # mis-calibrates the odd member out
            # thresholds may be device scalars after a previous learn()
            # (unhashable) — compare by value; backends compare *resolved*
            # (None and "auto" mean the same thing and must not drop the
            # cohort to the slow per-client fit loop)
            thrs_cfg = {None if d.threshold is None else float(d.threshold)
                        for d in dres}
            fit_backends = {dispatch.resolve(d.kernel_backend) for d in dres}
            uniform = (len(set(self.n)) == 1 and len(ks) == 1
                       and len(thrs_cfg) == 1
                       and len({d.calibration_q for d in dres}) == 1
                       and len({d.max_iter for d in dres}) == 1
                       and len(fit_backends) == 1)
            if uniform:
                # the vmapped learn path: every filter fit in one call per
                # wave, device-parallel over the (padded) client axis;
                # dummy rows fit on all-zero features and are never read
                # back. The fit is per-client math, so waving it changes
                # nothing but peak memory.
                k = ks.pop()
                backend = fit_backends.pop()
                keys_h = np.stack([np.asarray(kk) for kk in keys])
                n0 = int(self.n[0])
                C = len(self.members)
                cents_host = None
                thrs_host = np.zeros((C,), np.float32)
                for lo, hi in self._waves():
                    if self._waved:
                        feats = self._put_c(self._stage(
                            self._hx.reshape(C, n0, -1), lo, hi))
                        keys_w = self._put_c(self._stage(keys_h, lo, hi,
                                                         fill=None))
                    else:
                        feats = self.x.reshape(self.c_pad, n0, -1)
                        keys_w = self._put_c(self._pad_rows(jnp.stack(keys)))
                    with self._ctx():
                        res = kmeans_fit_batched(keys_w, feats, k,
                                                 dres[0].max_iter,
                                                 backend=backend)
                        if dres[0].threshold is None:
                            dmin = jax.vmap(min_dist_to_centroids)(
                                feats, res.centroids)
                            thrs = jnp.quantile(dmin, dres[0].calibration_q,
                                                axis=1)
                        else:
                            thrs = jnp.full((self.c_pad,), dres[0].threshold)
                    # pull centroids/thresholds to host in one gather each:
                    # rows of a mesh-sharded fit live on different devices,
                    # and jnp.stack in the packing step rejects mixed
                    # committed devices (one np.asarray, not C per-scalar
                    # float() syncs)
                    cw = np.asarray(res.centroids)[: hi - lo]
                    if cents_host is None:
                        cents_host = np.zeros((C, *cw.shape[1:]), cw.dtype)
                    cents_host[lo:hi] = cw
                    thrs_host[lo:hi] = np.asarray(thrs)[: hi - lo]
                for i, c in enumerate(self.members):
                    c.dre = dataclasses.replace(
                        c.dre, centroids=jnp.asarray(cents_host[i]),
                        threshold=jnp.float32(thrs_host[i]))
            else:
                for c, kk in zip(self.members, keys):
                    c.learn_dre(kk)
        else:
            # per-client learn (learn_dre no-ops on dre=None); KuLSIF
            # uniformity must fail before any state is mutated
            if all(isinstance(d, KuLSIFDRE) for d in dres):
                self._check_kulsif_uniform(dres)
            for c, kk in zip(self.members, keys):
                c.learn_dre(kk)
        self._pack_filter_state()

    def _pack_filter_state(self) -> None:
        """Stack the members' *learned* DREs into vmappable filter state.

        Legacy mode parks the stacked state on device (padded to
        ``c_pad``); waved mode keeps it host-side numpy with the full
        member axis and ``filter_masks`` stages one wave at a time."""
        dres = [c.dre for c in self.members]
        if all(isinstance(d, KMeansDRE) for d in dres):
            kmax = max(c.dre.centroids.shape[0] for c in self.members)
            cents = []
            for c in self.members:
                cc = np.asarray(c.dre.centroids)
                if cc.shape[0] < kmax:  # pad by repeating the first centroid:
                    pad = np.tile(cc[:1], (kmax - cc.shape[0], 1))
                    cc = np.concatenate([cc, pad])  # min-distance unchanged
                cents.append(cc)
            thrs = np.asarray([c.dre.threshold for c in self.members],
                              np.float32)
            self.filter_kind = "kmeans"
            if self._waved:
                self._filter_state = {"centroids": np.stack(cents),
                                      "thresholds": thrs}
                return
            self._filter_state = {
                "centroids": self._put_c(self._pad_rows(
                    jnp.stack([jnp.asarray(cc) for cc in cents]))),
                "thresholds": self._put_c(self._pad_rows(
                    jnp.asarray(thrs))),
            }
        elif all(isinstance(d, KuLSIFDRE) for d in dres):
            self._check_kulsif_uniform(dres)
            n_max = int(self.n.max())
            d = self.members[0].dre.private.shape[1]
            # pad private sets with a far-away sentinel: its RBF kernel mass
            # underflows to exactly 0, so padded rows contribute nothing —
            # dummy-client rows are entirely sentinel for the same reason.
            # The underflow needs (1e6)^2/(2 sigma^2) >> 88 (float32), so
            # refuse sigmas anywhere near that scale when padding exists
            # (waved cohorts always pad: the last wave is rarely full)
            padded = (self._waved or self.c_pad > len(self.members)
                      or int(self.n.min()) < n_max)
            if padded and dres[0].sigma > 1e4:
                raise ValueError(
                    f"KuLSIF sentinel padding requires sigma <= 1e4 so the "
                    f"pad rows' RBF mass underflows to exactly 0; got "
                    f"sigma={dres[0].sigma!r} with a padded cohort — use "
                    "equal private-set sizes and a mesh-divisible client "
                    "count, or give such clients distinct arch_keys")
            lead = len(self.members) if self._waved else self.c_pad
            priv = np.full((lead, n_max, d), 1e6, np.float32)
            for i, c in enumerate(self.members):
                priv[i, : self.n[i]] = np.asarray(c.dre.private)
            self.filter_kind = "kulsif"
            if self._waved:
                self._filter_state = {
                    "alpha": np.stack([np.asarray(c.dre.alpha)
                                       for c in self.members]),
                    "aux": np.stack([np.asarray(c.dre.aux)
                                     for c in self.members]),
                    "private": priv,
                    "n": np.asarray(self.n, np.float32),
                    "thresholds": np.asarray(
                        [c.dre.threshold for c in self.members], np.float32),
                    "sigma": float(dres[0].sigma),
                    "lam": float(dres[0].lam),
                }
                return
            self._filter_state = {
                "alpha": self._put_c(self._pad_rows(
                    jnp.stack([jnp.asarray(c.dre.alpha)
                               for c in self.members]))),
                "aux": self._put_c(self._pad_rows(
                    jnp.stack([jnp.asarray(c.dre.aux)
                               for c in self.members]))),
                "private": self._put_c(priv),
                # dummy rows divide by n — pad with 1.0, never 0
                "n": self._put_c(self._pad_rows(
                    jnp.asarray(self.n, jnp.float32), fill=1.0)),
                "thresholds": self._put_c(self._pad_rows(
                    jnp.asarray([c.dre.threshold for c in self.members],
                                jnp.float32))),
                "sigma": jnp.float32(dres[0].sigma),
                "lam": jnp.float32(dres[0].lam),
            }
        else:  # unknown or mixed estimators: per-client mask calls
            self.filter_kind = "loop"

    def _pack_learned_filter_state(self) -> None:
        """Adopt DREs the clients *already* learned (a transient engine —
        run_round builds one per call from a raw client list — must filter
        exactly like the long-lived engine whose learn_dres ran)."""
        d0 = self.members[0].dre
        if isinstance(d0, KMeansDRE):
            learned = all(isinstance(c.dre, KMeansDRE)
                          and c.dre.centroids is not None
                          for c in self.members)
        elif isinstance(d0, KuLSIFDRE):
            learned = all(isinstance(c.dre, KuLSIFDRE)
                          and c.dre.alpha is not None
                          for c in self.members)
        elif d0 is not None:
            # unknown estimator: "learned" is undecidable here, so take the
            # per-client mask fallback unconditionally — exactly what the
            # loop engine does with the same clients (unlearned ones fail
            # identically there)
            self.filter_kind = "loop"
            return
        else:
            learned = False  # no DRE: nothing to adopt
        if learned:
            self._pack_filter_state()

    # ----------------------------------------------------------- round phases
    def _plan(self, draw_n: int, epochs: int, batch_size: int,
              weight=None, part=None
              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Draw per-client epoch permutations (advancing each client's rng
        exactly as the loop engine would) and pack them into fixed arrays.

        ``part`` (len(members),) bool marks this round's participants:
        sampled-out members draw no permutation (their rng stream stays in
        lockstep with the loop engine, which skips them entirely) and keep
        all-False step validity — the same ``_where_tree`` no-op gating
        that freezes dummy padding clients. The plan arrays keep their
        shapes either way, so a changing subset never retraces a phase.
        """
        C = len(self.members)
        if draw_n >= 0:
            ns = [draw_n] * C          # shared proxy set
        else:
            ns = [int(v) for v in self.n]
        steps = max(steps_per_epoch(n, batch_size) for n in ns) * epochs
        # dummy-client rows [C:lead] stay all-zero / valid=False: every one
        # of their steps is a no-op under the _where_tree gating (waved
        # mode plans the full member axis and stages per wave, so rng
        # draws happen exactly once per member regardless of wave count)
        lead = C if self._waved else self.c_pad
        idx = np.zeros((lead, steps, batch_size), np.int32)
        w = np.zeros((lead, steps, batch_size), np.float32)
        valid = np.zeros((lead, steps), bool)
        for i, c in enumerate(self.members):
            if part is not None and not part[i]:
                continue               # no-op lane this round
            perms = [c.rng.permutation(ns[i]) for _ in range(epochs)]
            idx[i], w[i], valid[i] = padded_epoch_plan(perms, batch_size, steps)
        if weight is not None:
            w = w * np.asarray(weight, np.float32)[idx]
        return idx, w, valid

    def _mean_losses(self, losses, valid) -> List[float]:
        losses = np.asarray(losses, np.float64)
        valid = np.asarray(valid, np.float64)
        cnt = valid.sum(axis=1)
        tot = (losses * valid).sum(axis=1)
        return [float(t / c) if c else 0.0 for t, c in zip(tot, cnt)]

    def local_train(self, epochs: int, batch_size: int,
                    part=None) -> List[float]:
        idx, w, valid = self._plan(-1, epochs, batch_size, part=part)
        C = len(self.members)
        if not self._waved:
            with self._ctx():
                self.params, self.opt_state, losses = self._train(
                    self.params, self.opt_state, self.x, self.y,
                    self._put_c(idx), self._put_c(w), self._put_c(valid))
            return self._mean_losses(np.asarray(losses)[:C], valid[:C])
        losses_h = np.zeros((C, valid.shape[1]), np.float32)
        for lo, hi in self._waves():
            pd, od = self._stage_state(lo, hi)
            with self._ctx():
                pd, od, losses = self._train(
                    pd, od,
                    self._put_c(self._stage(self._hx, lo, hi)),
                    self._put_c(self._stage(self._hy, lo, hi)),
                    self._put_c(self._stage(idx, lo, hi)),
                    self._put_c(self._stage(w, lo, hi)),
                    self._put_c(self._stage(valid, lo, hi)))
            self._write_state(pd, od, lo, hi)
            losses_h[lo:hi] = np.asarray(losses)[: hi - lo]
        return self._mean_losses(losses_h, valid[:C])

    def distill(self, px, teacher, weight, epochs: int,
                batch_size: int, part=None) -> List[float]:
        idx, w, valid = self._plan(len(px), epochs, batch_size, weight=weight,
                                   part=part)
        C = len(self.members)
        if not self._waved:
            with self._ctx():
                self.params, self.opt_state, losses = self._distill(
                    self.params, self.opt_state,
                    self._put_rep(px), self._put_rep(teacher),
                    self._put_c(idx), self._put_c(w), self._put_c(valid))
            return self._mean_losses(np.asarray(losses)[:C], valid[:C])
        pxd, td = self._put_rep(px), self._put_rep(teacher)  # shared by waves
        losses_h = np.zeros((C, valid.shape[1]), np.float32)
        for lo, hi in self._waves():
            pd, od = self._stage_state(lo, hi)
            with self._ctx():
                pd, od, losses = self._distill(
                    pd, od, pxd, td,
                    self._put_c(self._stage(idx, lo, hi)),
                    self._put_c(self._stage(w, lo, hi)),
                    self._put_c(self._stage(valid, lo, hi)))
            self._write_state(pd, od, lo, hi)
            losses_h[lo:hi] = np.asarray(losses)[: hi - lo]
        return self._mean_losses(losses_h, valid[:C])

    def distill_private(self, teacher_by_class, valid_by_class, epochs: int,
                        batch_size: int, part=None) -> List[float]:
        idx, w, valid = self._plan(-1, epochs, batch_size, part=part)
        C = len(self.members)
        if not self._waved:
            with self._ctx():
                self.params, self.opt_state, losses = self._distill_private(
                    self.params, self.opt_state, self.x, self.y,
                    self._put_rep(teacher_by_class),
                    self._put_rep(np.asarray(valid_by_class, np.float32)),
                    self._put_c(idx), self._put_c(w), self._put_c(valid))
            return self._mean_losses(np.asarray(losses)[:C], valid[:C])
        td = self._put_rep(teacher_by_class)
        vd = self._put_rep(np.asarray(valid_by_class, np.float32))
        losses_h = np.zeros((C, valid.shape[1]), np.float32)
        for lo, hi in self._waves():
            pd, od = self._stage_state(lo, hi)
            with self._ctx():
                pd, od, losses = self._distill_private(
                    pd, od,
                    self._put_c(self._stage(self._hx, lo, hi)),
                    self._put_c(self._stage(self._hy, lo, hi)),
                    td, vd,
                    self._put_c(self._stage(idx, lo, hi)),
                    self._put_c(self._stage(w, lo, hi)),
                    self._put_c(self._stage(valid, lo, hi)))
            self._write_state(pd, od, lo, hi)
            losses_h[lo:hi] = np.asarray(losses)[: hi - lo]
        return self._mean_losses(losses_h, valid[:C])

    def classwise_means(self, part=None):
        if not self._waved:
            with self._ctx():
                means, counts = self._classwise(self.params, self.x, self.y,
                                                self.sample_mask)
            means, counts = np.asarray(means), np.asarray(counts)
        else:
            C = len(self.members)
            means = np.zeros((C, self.num_classes, self.num_classes),
                             np.float32)
            counts = np.zeros((C, self.num_classes), np.float32)
            for lo, hi in self._waves():
                pd, _ = self._stage_state(lo, hi)
                with self._ctx():
                    m_w, c_w = self._classwise(
                        pd,
                        self._put_c(self._stage(self._hx, lo, hi)),
                        self._put_c(self._stage(self._hy, lo, hi)),
                        self._put_c(self._stage(self._hm, lo, hi)))
                means[lo:hi] = np.asarray(m_w)[: hi - lo]
                counts[lo:hi] = np.asarray(c_w)[: hi - lo]
        if part is not None:
            # sampled-out members report nothing (zero counts drop them
            # from the classwise fuse exactly like the loop engine's skip)
            means, counts = means.copy(), counts.copy()
            means[~np.asarray(part, bool)] = 0.0
            counts[~np.asarray(part, bool)] = 0.0
        return [(means[i], counts[i]) for i in range(len(self.members))]

    def proxy_logits(self, px, part=None) -> np.ndarray:
        if not self._waved:
            with self._ctx():
                out = self._predict(self.params, self._put_rep(px))
            out = np.asarray(out)[: len(self.members)]
        else:
            C = len(self.members)
            pxd = self._put_rep(px)
            out = np.zeros((C, len(px), self.num_classes), np.float32)
            for lo, hi in self._waves():
                pd, _ = self._stage_state(lo, hi)
                with self._ctx():
                    o_w = self._predict(pd, pxd)
                out[lo:hi] = np.asarray(o_w)[: hi - lo]
        if part is not None:
            out = out.copy()
            out[~np.asarray(part, bool)] = 0.0
        return out

    def filter_masks(self, px, powner, part=None) -> np.ndarray:
        t = len(px)
        part = None if part is None else np.asarray(part, bool)

        def gated(masks):
            if part is not None:
                masks = masks.copy()
                masks[~part] = False     # sampled-out clients report nothing
            return masks

        if self.filter_kind == "none" \
                and all(c.dre is None for c in self.members):
            return gated(np.ones((len(self.members), t), bool))
        if self.filter_kind in ("none", "loop"):
            # "none" with any DRE present means no state was learned or
            # packed (e.g. a transient engine over unlearned clients, or a
            # mixed some-have-DREs cohort): defer to the per-client path so
            # it behaves exactly like the loop engine — including failing
            # loudly on unlearned estimators instead of silently returning
            # all-True masks (sampled-out members are skipped, again like
            # the loop engine)
            return np.stack([
                np.asarray(c.filter_mask(px, powner).mask)
                if part is None or part[i] else np.zeros((t,), bool)
                for i, c in enumerate(self.members)])
        pxf = self._put_rep(np.asarray(px).reshape(t, -1))
        owner = self._put_rep(powner)
        st = self._filter_state
        if not self._waved:
            # dummy rows get cid -1 (never an owner), masks are sliced off
            cids = self._put_c(self._pad_rows(
                jnp.asarray([c.cid for c in self.members]), fill=-1))
            with self._ctx():
                if self.filter_kind == "kmeans":
                    masks = self._kmeans_masks(st["centroids"],
                                               st["thresholds"],
                                               cids, pxf, owner)
                else:
                    masks = self._kulsif_masks(st["alpha"], st["aux"],
                                               st["private"], st["n"],
                                               st["thresholds"], cids,
                                               st["sigma"], st["lam"],
                                               pxf, owner)
            return gated(np.asarray(masks)[: len(self.members)])
        # waved: filter state lives host-side, staged one wave at a time.
        # Pad fills keep dummy lanes inert where they feed real math: cid
        # -1 never owns, kulsif n=1.0 never divides by zero, private rows
        # ride the existing 1e6 far-away sentinel.
        C = len(self.members)
        cids_h = np.asarray([c.cid for c in self.members])
        out = np.zeros((C, t), bool)
        for lo, hi in self._waves():
            cids = self._put_c(self._stage(cids_h, lo, hi, fill=-1))
            with self._ctx():
                if self.filter_kind == "kmeans":
                    masks = self._kmeans_masks(
                        self._put_c(self._stage(st["centroids"], lo, hi)),
                        self._put_c(self._stage(st["thresholds"], lo, hi)),
                        cids, pxf, owner)
                else:
                    masks = self._kulsif_masks(
                        self._put_c(self._stage(st["alpha"], lo, hi)),
                        self._put_c(self._stage(st["aux"], lo, hi)),
                        self._put_c(self._stage(st["private"], lo, hi,
                                                fill=np.float32(1e6))),
                        self._put_c(self._stage(st["n"], lo, hi,
                                                fill=np.float32(1.0))),
                        self._put_c(self._stage(st["thresholds"], lo, hi)),
                        cids, jnp.float32(st["sigma"]),
                        jnp.float32(st["lam"]), pxf, owner)
            out[lo:hi] = np.asarray(masks)[: hi - lo]
        return gated(out)

    def evaluate(self, x_test, y_test, batch_size: int = 512) -> List[float]:
        """Masked fixed-shape eval: the tail batch is padded to ``batch_size``
        instead of sliced ragged (which recompiled ``_predict`` for every
        distinct ``n % batch_size`` tail), and the whole pass — scan over
        batches, vmap over clients — is one compiled, device-parallel call."""
        x = np.asarray(x_test)
        y = np.asarray(y_test)
        n = len(y)
        nb = max(1, -(-n // batch_size))
        pad = nb * batch_size - n
        if pad:
            x = np.concatenate([x, np.zeros((pad, *x.shape[1:]), x.dtype)])
            y = np.concatenate([y, np.zeros((pad,), y.dtype)])
        m = np.zeros((nb * batch_size,), np.int32)
        m[:n] = 1
        xb = self._put_rep(x.reshape(nb, batch_size, *x.shape[1:]))
        yb = self._put_rep(y.reshape(nb, batch_size))
        mb = self._put_rep(m.reshape(nb, batch_size))
        if not self._waved:
            with self._ctx():
                correct = self._eval(self.params, xb, yb, mb)
            return [int(c) / n
                    for c in np.asarray(correct)[: len(self.members)]]
        C = len(self.members)
        correct = np.zeros((C,), np.int64)
        for lo, hi in self._waves():
            pd, _ = self._stage_state(lo, hi)
            with self._ctx():
                c_w = self._eval(pd, xb, yb, mb)
            correct[lo:hi] = np.asarray(c_w)[: hi - lo]
        return [int(c) / n for c in correct]

    def sync_to_clients(self) -> None:
        """Write stacked params/opt-state back onto the Client objects."""
        if self._waved:
            # the masters already live on host — hand back per-client views
            for i, c in enumerate(self.members):
                c.params = jax.tree.map(lambda l: jnp.asarray(l[i]),
                                        self._hparams)
                c.opt_state = jax.tree.map(lambda l: jnp.asarray(l[i]),
                                           self._hopt)
            return
        params, opt_state = self.params, self.opt_state
        if self.mesh is not None:
            # gather through host first: rows of a mesh-sharded stack live on
            # different devices, but clients expect default-device arrays
            params = jax.tree.map(lambda leaf: jnp.asarray(np.asarray(leaf)),
                                  params)
            opt_state = jax.tree.map(
                lambda leaf: jnp.asarray(np.asarray(leaf)), opt_state)
        for i, c in enumerate(self.members):
            c.params = _unstack_tree(params, i)
            c.opt_state = _unstack_tree(opt_state, i)

    def adopt_member_state(self) -> None:
        """Re-stage the stacked params/opt-state from the member ``Client``
        objects — the inverse of ``sync_to_clients``, used on checkpoint
        restore (the engine checkpoint format is per-client, so a restore
        writes the clients first and re-stacks here). Replays the exact
        construction-time staging: numpy host masters in waved mode,
        mesh-placed padded device stacks otherwise."""
        members = self.members
        if self._waved:
            def _np_stack(*leaves):
                return np.stack([np.asarray(l) for l in leaves])
            self._hparams = jax.tree.map(_np_stack,
                                         *[c.params for c in members])
            self._hopt = jax.tree.map(_np_stack,
                                      *[c.opt_state for c in members])
            return
        stand_ins = [members[0]] * (self.c_pad - len(members))
        self.params = self._put_state(
            _stack_trees([c.params for c in [*members, *stand_ins]]))
        self.opt_state = self._put_state(
            _stack_trees([c.opt_state for c in [*members, *stand_ins]]))


class CohortEngine:
    """Engine over architecture-grouped cohorts; same interface as LoopEngine.

    The ``Client`` objects remain the source of private data, DRE config and
    rng streams, but their params/opt-state live *stacked on device* for the
    engine's lifetime; call ``sync_to_clients()`` before reading them back
    (e.g. for checkpointing).

    ``mesh`` (``repro.fed.mesh.build_client_mesh``) shards every cohort's
    client axis across a 1-D device mesh; ``None`` keeps the single-device
    semantics. Each cohort pads its own client axis to a mesh-size multiple
    with validity-gated dummy clients, so any population shape works.

    ``wave_size`` streams each cohort's client axis through the device in
    fixed-size waves (see the module docstring); 0 keeps the whole axis
    device-resident. Composes with ``mesh`` — each wave is padded to a
    mesh multiple and sharded.
    """

    def __init__(self, clients: Sequence[Client], mesh=None,
                 mesh_axis: str = DEFAULT_CLIENT_AXIS, wave_size: int = 0):
        self.clients = list(clients)
        self.mesh = mesh
        self.mesh_axis = mesh_axis
        self.wave_size = wave_size
        groups: Dict[object, Tuple[List[Client], List[int]]] = {}
        for pos, c in enumerate(self.clients):
            key = c.arch_key if c.arch_key is not None else ("solo", pos)
            members, positions = groups.setdefault(key, ([], []))
            members.append(c)
            positions.append(pos)
        self.cohorts = [_Cohort(m, p, mesh=mesh, mesh_axis=mesh_axis,
                                wave_size=wave_size)
                        for m, p in groups.values()]

    @property
    def num_clients(self) -> int:
        return len(self.clients)

    def _scatter(self, per_cohort_lists) -> List:
        out = [None] * len(self.clients)
        for cohort, values in zip(self.cohorts, per_cohort_lists):
            for pos, v in zip(cohort.positions, values):
                out[pos] = v
        return out

    def _part_for(self, cohort, participants):
        """Slice a global participation mask down to one cohort's members
        (the cohort composes it with its own dummy-padding validity)."""
        if participants is None:
            return None
        part = np.asarray(participants, bool)
        if part.shape != (len(self.clients),):
            raise ValueError(
                f"participation mask shape {part.shape} != "
                f"({len(self.clients)},)")
        return part[cohort.positions]

    def learn_dres(self, key) -> None:
        for cohort in self.cohorts:
            cohort.learn_dres(key)

    # ------------------------------------------------ per-phase entry points
    # (driven by repro.fed.scheduler; the *_all mega-call names below are
    # thin aliases kept for historical callers)
    def phase_local_train(self, epochs: int, batch_size: int,
                          participants=None) -> List[float]:
        return self._scatter(
            [c.local_train(epochs, batch_size,
                           part=self._part_for(c, participants))
             for c in self.cohorts])

    def phase_classwise_report(self, participants=None):
        return self._scatter(
            [c.classwise_means(part=self._part_for(c, participants))
             for c in self.cohorts])

    def phase_report(self, px, powner, participants=None):
        t = len(px)
        k = self.clients[0].num_classes
        logits = np.zeros((len(self.clients), t, k), np.float32)
        masks = np.zeros((len(self.clients), t), bool)
        for cohort in self.cohorts:
            part = self._part_for(cohort, participants)
            logits[cohort.positions] = cohort.proxy_logits(px, part=part)
            masks[cohort.positions] = cohort.filter_masks(px, powner,
                                                          part=part)
        return logits, masks

    def phase_distill(self, px, teacher, weight, epochs: int,
                      batch_size: int, participants=None) -> List[float]:
        return self._scatter(
            [c.distill(px, teacher, weight, epochs, batch_size,
                       part=self._part_for(c, participants))
             for c in self.cohorts])

    def phase_distill_private(self, teacher_by_class, valid_by_class,
                              epochs: int, batch_size: int,
                              participants=None) -> List[float]:
        return self._scatter(
            [c.distill_private(teacher_by_class, valid_by_class, epochs,
                               batch_size,
                               part=self._part_for(c, participants))
             for c in self.cohorts])

    def phase_eval(self, x_test, y_test) -> List[float]:
        return self._scatter([c.evaluate(x_test, y_test)
                              for c in self.cohorts])

    # ------------------------------------------------ per-cohort entry points
    # Concurrent-cohort scheduling (repro.fed.scheduler with
    # cfg.concurrent_cohorts=True) drives each _Cohort independently so
    # different cohorts' phases interleave on the round graph. Each call
    # returns values aligned to that cohort's client positions
    # (``cohort_positions()[ci]``); the scheduler scatters them back into
    # fleet-length structures. LoopEngine implements the same interface
    # with the same grouping rule, so loop == cohort parity holds
    # node-for-node.

    def cohort_positions(self) -> List[np.ndarray]:
        return [np.asarray(c.positions, int) for c in self.cohorts]

    def cohort_local_train(self, ci: int, epochs: int, batch_size: int,
                           participants=None) -> List[float]:
        c = self.cohorts[ci]
        return c.local_train(epochs, batch_size,
                             part=self._part_for(c, participants))

    def cohort_classwise_report(self, ci: int, participants=None):
        c = self.cohorts[ci]
        return c.classwise_means(part=self._part_for(c, participants))

    def cohort_report(self, ci: int, px, powner, participants=None):
        """Returns (logits (m, t, K), masks (m, t)) for cohort ``ci``."""
        c = self.cohorts[ci]
        part = self._part_for(c, participants)
        logits = np.asarray(c.proxy_logits(px, part=part), np.float32)
        masks = np.asarray(c.filter_masks(px, powner, part=part), bool)
        return logits, masks

    def cohort_distill(self, ci: int, px, teacher, weight, epochs: int,
                       batch_size: int, participants=None) -> List[float]:
        c = self.cohorts[ci]
        return c.distill(px, teacher, weight, epochs, batch_size,
                         part=self._part_for(c, participants))

    def cohort_distill_private(self, ci: int, teacher_by_class,
                               valid_by_class, epochs: int, batch_size: int,
                               participants=None) -> List[float]:
        c = self.cohorts[ci]
        return c.distill_private(teacher_by_class, valid_by_class, epochs,
                                 batch_size,
                                 part=self._part_for(c, participants))

    # -------------------------- historical mega-call names (thin aliases)
    def local_train_all(self, epochs: int, batch_size: int,
                        participants=None) -> List[float]:
        return self.phase_local_train(epochs, batch_size, participants)

    def classwise_means_all(self, participants=None):
        return self.phase_classwise_report(participants)

    def proxy_logits_and_masks(self, px, powner, participants=None):
        return self.phase_report(px, powner, participants)

    def distill_all(self, px, teacher, weight, epochs: int,
                    batch_size: int, participants=None) -> List[float]:
        return self.phase_distill(px, teacher, weight, epochs, batch_size,
                                  participants)

    def distill_private_all(self, teacher_by_class, valid_by_class,
                            epochs: int, batch_size: int,
                            participants=None) -> List[float]:
        return self.phase_distill_private(teacher_by_class, valid_by_class,
                                          epochs, batch_size, participants)

    def evaluate_all(self, x_test, y_test) -> List[float]:
        return self.phase_eval(x_test, y_test)

    def sync_to_clients(self) -> None:
        for cohort in self.cohorts:
            cohort.sync_to_clients()

    # ------------------------------------------------- resumable service
    def state_dict(self) -> Dict:
        """Per-client mutable state in the shared engine checkpoint format
        (``repro.fed.state``): the stacked/host-master training state is
        synced back onto the ``Client`` objects first, so the emitted
        checkpoint is identical in layout to the loop engine's and
        restores under any engine/mesh/wave configuration."""
        from repro.fed.state import clients_state_dict
        self.sync_to_clients()
        return clients_state_dict(self.clients)

    def load_state_dict(self, sd: Dict) -> None:
        from repro.fed.state import load_clients_state_dict
        load_clients_state_dict(self.clients, sd)
        for cohort in self.cohorts:
            cohort.adopt_member_state()
