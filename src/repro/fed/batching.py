"""Deterministic-shape minibatching shared by every training loop.

Both ``Client`` (per-client loop engine) and ``CohortEngine`` (vmapped
engine) batch an epoch the same way:

  * ``n >= batch_size``  — full batches only, drop the ragged tail
    (``n // batch_size`` steps of exactly ``batch_size``);
  * ``0 < n < batch_size`` — a single short batch of all ``n`` samples
    (its shape is still deterministic: ``n`` is fixed for a given client /
    proxy set, so jit compiles it once).

Historically ``Client.distill`` used ``range(0, n, batch_size)`` — a ragged
final batch whose size depended on ``n % batch_size``, silently recompiling
the distill step for every distinct tail size and diverging from
``local_train``'s drop-last behaviour. One helper, one rule.
"""
from __future__ import annotations

from typing import List

import numpy as np


def epoch_batches(perm: np.ndarray, batch_size: int) -> List[np.ndarray]:
    """Split a permutation of sample indices into deterministic-shape batches."""
    n = len(perm)
    if n == 0:
        return []
    if n < batch_size:
        return [perm]
    nb = n // batch_size
    return list(perm[: nb * batch_size].reshape(nb, batch_size))


def steps_per_epoch(n: int, batch_size: int) -> int:
    """Number of steps ``epoch_batches`` yields for ``n`` samples."""
    if n == 0:
        return 0
    return 1 if n < batch_size else n // batch_size


def padded_epoch_plan(perms, batch_size: int, num_steps: int):
    """Stack one epoch's batches into fixed arrays for the cohort engine.

    ``perms``: list (one per epoch) of index permutations for a single
    client. Returns ``(idx, w, valid)`` where ``idx`` has shape
    ``(num_steps, batch_size)`` int32, ``w`` is a per-sample weight
    (0 for pad slots), and ``valid`` marks real steps. ``num_steps`` must be
    ≥ the client's total step count across the given epochs; the surplus
    steps are no-ops (valid=False).
    """
    idx = np.zeros((num_steps, batch_size), np.int32)
    w = np.zeros((num_steps, batch_size), np.float32)
    valid = np.zeros((num_steps,), bool)
    s = 0
    for perm in perms:
        for b in epoch_batches(np.asarray(perm), batch_size):
            idx[s, : len(b)] = b
            w[s, : len(b)] = 1.0
            valid[s] = True
            s += 1
    return idx, w, valid
