"""Client/model device mesh for the cohort engine.

The cohort engine stacks clients into leading-axis ``(C, ...)`` pytrees
(``repro.fed.cohort``) — a shape that is already mesh-ready: every round
phase is independent per client, so sharding the leading axis over the
``"clients"`` mesh axis partitions the whole round with zero cross-device
collectives (the only cross-client ops — server aggregation — happen on
host).

``build_client_mesh`` builds that mesh over ``jax.devices()``. On CPU-only
hosts XLA exposes one device by default; set

    XLA_FLAGS=--xla_force_host_platform_device_count=N

*before* the first jax import to emulate an N-device host (this is how CI
exercises the sharded path — see ``tests/test_cohort_parity.py`` and the
multi-device job in ``.github/workflows/ci.yml``).

2-D mesh: clients × model shards
--------------------------------
``model_shards > 0`` folds the same ``num_devices`` devices into a 2-D
``(clients, model)`` mesh of shape ``(num_devices // model_shards,
model_shards)``: the stacked client axis still splits over ``"clients"``,
and each client's *weight matrices* additionally split over ``"model"``
(per-leaf ``NamedSharding``s from :func:`stacked_state_shardings`, driven
by the FSDP/tensor templates in ``repro.launch.mesh.param_spec``). This is
what lets a cohort member bigger than one device be federated at all — the
ROADMAP's "2-D mesh" item. ``model_shards = 0`` (the default) keeps
today's 1-D client mesh bit-for-bit.

The ``REPRO_MODEL_SHARDS`` environment variable fills in when a config
leaves ``model_shards`` at 0 (the CI matrix vehicle, like
``REPRO_KERNEL_BACKEND``). The env request is best-effort: it is clamped
to ``gcd(num_devices, env)`` so every device count in the test matrix
still builds a valid mesh; an explicit config value is strict and raises
on impossible shapes instead.

Cohorts whose client count is not a multiple of the *client-axis* size are
padded with *dummy clients* (``padded_size``): their per-step validity
flags are all False, so the engine's existing ``_where_tree`` gating turns
every training step into a no-op and their outputs are sliced off before
any result leaves the engine.
"""
from __future__ import annotations

import math
import os
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DEFAULT_CLIENT_AXIS = "clients"
# launch.mesh.param_spec's name-aware templates key on the literal axis
# name "model", so the 2-D client mesh reuses it verbatim
DEFAULT_MODEL_AXIS = "model"
MODEL_SHARDS_ENV = "REPRO_MODEL_SHARDS"

# logical-axis rules installed by the cohort engine's trace scope when a
# model axis exists: activations stay replicated across model shards
# (batch/seq/embed -> None, the Megatron residual-stream layout) while
# heads/ff/vocab/experts ride the "model" axis, matching the param specs.
# On a 1-D mesh every "model" entry resolves to nothing (the axis is not
# in the mesh), so installing these is exactly the historical behavior.
MODEL_LOGICAL_RULES = {
    "batch": None,
    "seq": None,
    "embed": None,
    "heads": DEFAULT_MODEL_AXIS,
    "kv_heads": DEFAULT_MODEL_AXIS,
    "head_dim": None,
    "ff": DEFAULT_MODEL_AXIS,
    "vocab": DEFAULT_MODEL_AXIS,
    "experts": DEFAULT_MODEL_AXIS,
    "kv_seq": None,
    "vision_seq": None,
}


def resolve_model_shards(model_shards: int = 0) -> int:
    """Resolve a ``model_shards`` request: explicit value > env > 0 (1-D).

    The returned value is still a *request* — :func:`build_client_mesh`
    clamps an env-sourced request to a divisor of ``num_devices``."""
    if model_shards < 0:
        raise ValueError(
            f"model_shards must be >= 0, got {model_shards!r} "
            "(0 = the 1-D client mesh)")
    if model_shards == 0:
        env = os.environ.get(MODEL_SHARDS_ENV, "").strip()
        if env:
            try:
                model_shards = int(env)
            except ValueError:
                raise ValueError(
                    f"${MODEL_SHARDS_ENV}={env!r} is not an integer")
            if model_shards < 0:
                raise ValueError(
                    f"${MODEL_SHARDS_ENV}={env!r} must be >= 0")
    return model_shards


def build_mesh(shape: Sequence[int], axes: Sequence[str]) -> Mesh:
    """N-D mesh over the first ``prod(shape)`` visible devices.

    The single device-layout code path: ``build_client_mesh`` and the
    launcher factories (``repro.launch.mesh.make_debug_mesh`` /
    ``make_production_mesh``) all route through here. Device order is the
    deterministic ``jax.devices()`` order folded row-major — topology-naive
    but reproducible, which is what the parity/golden tests lean on.
    Raises a legible ``ValueError`` when the host has too few devices.
    """
    shape = tuple(int(s) for s in shape)
    if len(shape) != len(axes):
        raise ValueError(f"mesh shape {shape} has {len(shape)} dims but "
                         f"{len(axes)} axis names: {tuple(axes)!r}")
    if any(s <= 0 for s in shape):
        raise ValueError(f"mesh shape must be positive, got {shape}")
    devices = jax.devices()
    total = int(np.prod(shape))
    if total > len(devices):
        detail = " × ".join(f"{s} {a!r}" for s, a in zip(shape, axes))
        raise ValueError(
            f"requested a {total}-device mesh ({detail}) but only "
            f"{len(devices)} jax device(s) are visible; on CPU hosts set "
            "XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{total} before the first jax import")
    return Mesh(np.asarray(devices[:total]).reshape(shape), tuple(axes))


def build_client_mesh(num_devices: int = 0,
                      axis: str = DEFAULT_CLIENT_AXIS,
                      model_shards: int = 0,
                      model_axis: str = DEFAULT_MODEL_AXIS) -> Optional[Mesh]:
    """Build the client mesh, or ``None`` for the unsharded path.

    ``num_devices``: 0 = no mesh (single-device semantics, the default);
    ``-1`` = all visible devices; ``N > 0`` = exactly N devices (a clear
    error if fewer are visible). ``num_devices`` always counts TOTAL
    devices — with ``model_shards = m > 0`` they fold into a
    ``(num_devices // m, m)`` 2-D ``(clients, model)`` mesh, so the same
    ``num_devices`` never over-subscribes the host when a model dimension
    is added. ``model_shards = 0`` resolves through ``$REPRO_MODEL_SHARDS``
    (clamped to a divisor of ``num_devices``); with neither set the
    historical 1-D mesh is returned bit-for-bit.
    """
    from_env = model_shards == 0
    model_shards = resolve_model_shards(model_shards)
    if num_devices == 0:
        if model_shards > 0 and not from_env:
            raise ValueError(
                f"model_shards={model_shards} requires a device mesh; set "
                "num_devices (e.g. -1 for all visible devices)")
        return None
    devices = jax.devices()
    if num_devices < 0:
        num_devices = len(devices)
    if num_devices > len(devices):
        extra = ""
        if model_shards > 0:
            extra = (f" (num_devices counts TOTAL devices — the clients × "
                     f"model_shards={model_shards} product must fit)")
        raise ValueError(
            f"requested a {num_devices}-device client mesh but only "
            f"{len(devices)} jax device(s) are visible; on CPU hosts set "
            "XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{num_devices} before the first jax import" + extra)
    if model_shards == 0:
        return build_mesh((num_devices,), (axis,))
    if from_env:
        # env requests are a CI sweep vehicle: clamp instead of exploding
        # matrix entries whose device count the env does not divide
        model_shards = math.gcd(num_devices, model_shards)
    elif num_devices % model_shards:
        raise ValueError(
            f"model_shards={model_shards} cannot tile a "
            f"{num_devices}-device mesh: num_devices must be a positive "
            f"multiple of model_shards (the mesh folds to "
            f"(num_devices // model_shards, model_shards) = clients × "
            "model)")
    if model_shards == 1:
        # one shard per model IS no model sharding: the historical 1-D
        # client mesh, bit-for-bit — also where an env-clamped request
        # lands on hosts whose device count the env does not divide, so
        # a $REPRO_MODEL_SHARDS CI sweep never perturbs 1-device entries
        return build_mesh((num_devices,), (axis,))
    return build_mesh((num_devices // model_shards, model_shards),
                      (axis, model_axis))


def client_axis_size(mesh: Optional[Mesh]) -> int:
    """Devices along the client (leading) axis — NOT ``devices.size``,
    which would count model shards on a 2-D mesh."""
    if mesh is None:
        return 1
    return int(mesh.devices.shape[0])


def model_axis_name(mesh: Optional[Mesh]) -> Optional[str]:
    """The model axis name of a 2-D client mesh, else ``None``."""
    if mesh is None or len(mesh.axis_names) < 2:
        return None
    return mesh.axis_names[1]


def padded_size(count: int, mesh: Optional[Mesh]) -> int:
    """Client-axis length after padding to a multiple of the client-axis
    device count (model shards never pad the client axis)."""
    if mesh is None:
        return count
    d = client_axis_size(mesh)
    return ((count + d - 1) // d) * d


def client_sharding(mesh: Mesh, axis: str = DEFAULT_CLIENT_AXIS) -> NamedSharding:
    """Sharding that splits the leading (client) axis across the mesh.

    On a 2-D mesh the remaining dims replicate across model shards — the
    right placement for per-client *data*; params/opt-state go through
    :func:`stacked_state_shardings` instead."""
    return NamedSharding(mesh, P(axis))


def replicated_sharding(mesh: Mesh) -> NamedSharding:
    """Sharding that replicates a value on every mesh device."""
    return NamedSharding(mesh, P())


def shard_clients(tree, mesh: Optional[Mesh],
                  axis: str = DEFAULT_CLIENT_AXIS):
    """Place every leaf of ``tree`` with its leading axis split over the mesh.

    No-op without a mesh, so engine code calls it unconditionally. Leaves
    must already be padded to a client-axis multiple of the mesh size.
    """
    if mesh is None:
        return tree
    s = client_sharding(mesh, axis)
    return jax.tree.map(lambda leaf: jax.device_put(leaf, s), tree)


def replicate(tree, mesh: Optional[Mesh]):
    """Place every leaf of ``tree`` replicated on the mesh (no-op without)."""
    if mesh is None:
        return tree
    s = replicated_sharding(mesh)
    return jax.tree.map(lambda leaf: jax.device_put(leaf, s), tree)


def stacked_state_shardings(tree, mesh: Mesh,
                            axis: str = DEFAULT_CLIENT_AXIS):
    """Per-leaf ``NamedSharding``s for a stacked ``(C, ...)`` state pytree.

    Dim 0 (the client stack) splits over ``axis``; the remaining dims of
    each leaf take the name-aware FSDP/tensor template from
    ``repro.launch.mesh.param_spec`` (wq/wk/wv heads -> model, ff -> model,
    embed vocab -> model, largest-divisible fallback for plain dense
    leaves), with the client axis counted as one extra stack axis on top
    of any layer-stack axes. Works for params and optimizer state alike —
    optimizer moments mirror the param paths, and extra scalar leaves
    (step counters) degrade to a pure client split.

    On a 1-D mesh this reduces to ``P(axis)`` for every leaf, i.e. exactly
    :func:`client_sharding`.
    """
    from repro.launch.mesh import _stack_depth, param_spec

    def leaf(path, x):
        shape = tuple(x.shape)
        if not shape:
            return replicated_sharding(mesh)
        name = next((str(p.key) for p in reversed(path)
                     if hasattr(p, "key")), None)
        spec = param_spec(shape, mesh, n_stack_axes=1 + _stack_depth(path),
                          fsdp=True, name=name)
        parts = list(spec) + [None] * (len(shape) - len(spec))
        parts[0] = axis
        while parts and parts[-1] is None:      # normalize: P(a) == P(a,)
            parts.pop()
        return NamedSharding(mesh, P(*parts))

    return jax.tree_util.tree_map_with_path(leaf, tree)


def shard_stacked_state(tree, mesh: Optional[Mesh],
                        axis: str = DEFAULT_CLIENT_AXIS):
    """Place a stacked ``(C, ...)`` params/opt-state pytree on the mesh:
    client split only on a 1-D mesh (the historical placement, bit-for-bit),
    client × model per-leaf shardings on a 2-D mesh. No-op without a mesh.
    """
    if mesh is None:
        return tree
    if model_axis_name(mesh) is None:
        return shard_clients(tree, mesh, axis)
    shardings = stacked_state_shardings(tree, mesh, axis)
    return jax.tree.map(jax.device_put, tree, shardings)
