"""1-D client mesh for the cohort engine: devices along the client axis.

The cohort engine stacks clients into leading-axis ``(C, ...)`` pytrees
(``repro.fed.cohort``) — a shape that is already mesh-ready: every round
phase is independent per client, so sharding the leading axis over a 1-D
device mesh partitions the whole round with zero cross-device collectives
(the only cross-client ops — server aggregation — happen on host).

``build_client_mesh`` builds that mesh over ``jax.devices()``. On CPU-only
hosts XLA exposes one device by default; set

    XLA_FLAGS=--xla_force_host_platform_device_count=N

*before* the first jax import to emulate an N-device host (this is how CI
exercises the sharded path — see ``tests/test_cohort_parity.py`` and the
multi-device job in ``.github/workflows/ci.yml``).

Cohorts whose client count is not a multiple of the mesh size are padded
with *dummy clients* (``padded_size``): their per-step validity flags are
all False, so the engine's existing ``_where_tree`` gating turns every
training step into a no-op and their outputs are sliced off before any
result leaves the engine.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DEFAULT_CLIENT_AXIS = "clients"


def build_client_mesh(num_devices: int = 0,
                      axis: str = DEFAULT_CLIENT_AXIS) -> Optional[Mesh]:
    """Build the 1-D client mesh, or ``None`` for the unsharded path.

    ``num_devices``: 0 = no mesh (single-device semantics, the default);
    ``-1`` = all visible devices; ``N > 0`` = exactly N devices (a clear
    error if fewer are visible).
    """
    if num_devices == 0:
        return None
    devices = jax.devices()
    if num_devices < 0:
        num_devices = len(devices)
    if num_devices > len(devices):
        raise ValueError(
            f"requested a {num_devices}-device client mesh but only "
            f"{len(devices)} jax device(s) are visible; on CPU hosts set "
            "XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{num_devices} before the first jax import")
    return Mesh(devices[:num_devices], (axis,))


def padded_size(count: int, mesh: Optional[Mesh]) -> int:
    """Client-axis length after padding to a multiple of the mesh size."""
    if mesh is None:
        return count
    d = mesh.devices.size
    return ((count + d - 1) // d) * d


def client_sharding(mesh: Mesh, axis: str = DEFAULT_CLIENT_AXIS) -> NamedSharding:
    """Sharding that splits the leading (client) axis across the mesh."""
    return NamedSharding(mesh, P(axis))


def replicated_sharding(mesh: Mesh) -> NamedSharding:
    """Sharding that replicates a value on every mesh device."""
    return NamedSharding(mesh, P())


def shard_clients(tree, mesh: Optional[Mesh],
                  axis: str = DEFAULT_CLIENT_AXIS):
    """Place every leaf of ``tree`` with its leading axis split over the mesh.

    No-op without a mesh, so engine code calls it unconditionally. Leaves
    must already be padded to a client-axis multiple of the mesh size.
    """
    if mesh is None:
        return tree
    s = client_sharding(mesh, axis)
    return jax.tree.map(lambda leaf: jax.device_put(leaf, s), tree)


def replicate(tree, mesh: Optional[Mesh]):
    """Place every leaf of ``tree`` replicated on the mesh (no-op without)."""
    if mesh is None:
        return tree
    s = replicated_sharding(mesh)
    return jax.tree.map(lambda leaf: jax.device_put(leaf, s), tree)
