"""Round scheduler: partial participation + staleness-aware reuse.

The paper evaluates EdgeFD with every client reporting soft logits every
round, but its target deployment — resource-constrained edge devices — is
exactly the regime where clients drop in and out and report stale
knowledge. This module supplies the two missing pieces:

``sample_participants``
    Draws the subset of clients that trains and reports in round ``r``.
    Three policies, all deterministic in ``(seed, round)`` so every
    execution engine (loop / cohort / mesh-sharded cohort) sees the same
    subset and their round logs stay comparable:

      * ``uniform``    — without replacement, every client equally likely;
      * ``weighted``   — without replacement, P(client) ∝ private-set size
                         (larger shards report more often, FedAvg-style);
      * ``roundrobin`` — deterministic rotating block: round ``r`` takes
                         clients ``[r·k, r·k + k) mod C``, so every client
                         participates exactly once per ``ceil(C / k)``
                         rounds.

``StalenessBuffer``
    Server-side memory of each client's *last-reported* proxy logits and
    ID masks. Non-participants do not recompute logits; the buffer fills
    their rows with the cached report (on the proxy indices the server
    selected this round) and hands ``Server.aggregate`` a per-client
    weight ``staleness_decay ** age`` where ``age`` is the number of
    rounds since the client last reported:

      * ``staleness_decay = 0`` — stale reports get weight ``0**age = 0``
        (fresh reports keep ``0**0 = 1``): non-participants are silently
        dropped from the teacher;
      * ``staleness_decay = 1`` — stale reports keep full weight:
        FedBuff-style unlimited reuse of the last report;
      * in between — geometric down-weighting of old knowledge.

The engines keep sampled-out clients as *no-op lanes*: the cohort engine
reuses the ``_where_tree`` validity gating that already freezes dummy
padding clients, so a changing subset changes only data (never shapes)
and retriggers no compilation, and the mask composes with mesh padding.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import numpy as np

PARTICIPATION_POLICIES = ("uniform", "weighted", "roundrobin")


def validate_config(cfg) -> None:
    """Fail fast on an inconsistent participation config (FedConfig-like)."""
    f = cfg.participation_fraction
    if not 0.0 < f <= 1.0:
        raise ValueError(
            f"participation_fraction must be in (0, 1], got {f!r}")
    if cfg.participation_policy not in PARTICIPATION_POLICIES:
        raise ValueError(
            f"unknown participation_policy {cfg.participation_policy!r}; "
            f"known: {', '.join(PARTICIPATION_POLICIES)}")
    if not 0.0 <= cfg.staleness_decay <= 1.0:
        raise ValueError(
            f"staleness_decay must be in [0, 1], got {cfg.staleness_decay!r}")


def cohort_size(num_clients: int, fraction: float) -> int:
    """Participants per round: ``round(fraction · C)``, clamped to ``[1, C]``.

    ``round`` is Python's banker's rounding, so exact half-integers go to
    the nearest *even* count: ``fraction=0.5, C=5`` gives **2** (not 3),
    ``C=7`` gives 4. This has been the behavior since partial
    participation landed and every golden/round log encodes it, so it is
    deliberately pinned (see ``tests/test_scale.py``) rather than
    switched to half-up; pick fractions that don't straddle ``x.5`` if
    the parity matters to you.
    """
    return int(min(max(round(fraction * num_clients), 1), num_clients))


def round_rng(seed: int, round_idx: int) -> np.random.Generator:
    """The round key: an rng derived from (seed, round) and nothing else,
    so sampling never perturbs the client/server rng streams (legacy logs
    stay bit-for-bit identical at participation_fraction=1)."""
    return np.random.default_rng(
        np.random.SeedSequence([seed % 2**32, round_idx, 0x5EED]))


def sample_participants(round_idx: int, num_clients: int, fraction: float,
                        policy: str = "uniform", *, seed: int = 0,
                        data_sizes: Optional[np.ndarray] = None
                        ) -> np.ndarray:
    """Boolean participation mask of shape ``(num_clients,)`` for one round.

    ``data_sizes`` (per-client private-set sizes) is required by the
    ``weighted`` policy and ignored by the others.
    """
    if policy not in PARTICIPATION_POLICIES:
        raise ValueError(f"unknown participation policy {policy!r}; "
                         f"known: {', '.join(PARTICIPATION_POLICIES)}")
    if not 0.0 < fraction <= 1.0:
        raise ValueError(f"fraction must be in (0, 1], got {fraction!r}")
    k = cohort_size(num_clients, fraction)
    mask = np.zeros((num_clients,), bool)
    if k == num_clients:
        mask[:] = True
        return mask
    if policy == "roundrobin":
        ids = (round_idx * k + np.arange(k)) % num_clients
    elif policy == "uniform":
        ids = round_rng(seed, round_idx).choice(num_clients, size=k,
                                                replace=False)
    else:  # weighted
        if data_sizes is None:
            raise ValueError(
                "policy='weighted' needs per-client data_sizes")
        sizes = np.asarray(data_sizes, np.float64)
        if sizes.shape != (num_clients,) or np.any(sizes < 0):
            raise ValueError(
                f"data_sizes must be {num_clients} non-negative sizes, got "
                f"shape {sizes.shape}")
        if np.count_nonzero(sizes) < k:
            raise ValueError(
                f"policy='weighted' cannot draw {k} of "
                f"{np.count_nonzero(sizes)} clients with data; shrink "
                "participation_fraction or give every client samples")
        ids = round_rng(seed, round_idx).choice(
            num_clients, size=k, replace=False, p=sizes / sizes.sum())
    mask[ids] = True
    return mask


class StaleMerge(NamedTuple):
    """Result of ``StalenessBuffer.merge`` — inputs with stale rows filled.

    ``ages_sum``/``num_contributing`` are the unnormalized pieces of
    ``mean_staleness`` (``mean = ages_sum / num_contributing``); the
    two-tier server fuses them across edge shards so the root reports the
    exact fleet-wide mean, not a mean of shard means.
    """
    logits: np.ndarray          # (C, t, K) fresh or last-reported logits
    masks: np.ndarray           # (C, t) fresh or last-reported ID masks
    client_weights: np.ndarray  # (C,) staleness_decay ** age
    mean_staleness: float       # mean age over clients that ever reported
    ages_sum: float = 0.0       # Σ age over contributing clients
    num_contributing: int = 0   # clients whose report reaches the teacher


class StalenessBuffer:
    """Per-client cache of the last-reported proxy logits and ID masks.

    The cache is indexed by *proxy-dataset position*: when a client
    participates, its fresh logits/masks land at this round's selected
    indices; when it sits out, the merge reads whatever it last reported
    at the indices selected now. Entries a client never reported stay
    masked out, so a client contributes exactly the knowledge it actually
    uploaded — nothing is fabricated.
    """

    def __init__(self, num_clients: int, proxy_size: int, num_classes: int):
        self.logits = np.zeros((num_clients, proxy_size, num_classes),
                               np.float32)
        self.masks = np.zeros((num_clients, proxy_size), bool)
        self.reported = np.zeros((num_clients,), bool)   # ever reported
        self.last_round = np.zeros((num_clients,), np.int64)
        self._last_merge_round: Optional[int] = None

    # ------------------------------------------------- resumable service
    def state_dict(self) -> dict:
        """Full buffer contents for ``repro.fed.state.ExperimentState``."""
        return {"logits": self.logits, "masks": self.masks,
                "reported": self.reported, "last_round": self.last_round,
                "last_merge_round": self._last_merge_round}

    def load_state_dict(self, sd: dict) -> None:
        logits = np.asarray(sd["logits"], np.float32)
        if logits.shape != self.logits.shape:
            raise ValueError(
                f"staleness buffer shape mismatch: checkpoint "
                f"{logits.shape} vs buffer {self.logits.shape}")
        self.logits = logits
        self.masks = np.asarray(sd["masks"], bool)
        self.reported = np.asarray(sd["reported"], bool)
        self.last_round = np.asarray(sd["last_round"], np.int64)
        lmr = sd.get("last_merge_round")
        self._last_merge_round = None if lmr is None else int(lmr)

    @classmethod
    def from_state_dict(cls, sd: dict) -> "StalenessBuffer":
        """Rebuild a (lazily-materialized) buffer from its state dict."""
        c, t, k = np.asarray(sd["logits"]).shape
        buf = cls(c, t, k)
        buf.load_state_dict(sd)
        return buf

    def merge(self, round_idx: int, participants, idx, logits, masks,
              decay: float) -> StaleMerge:
        """Record fresh reports, fill non-participant rows from the cache.

        ``participants``: (C,) bool; ``idx``: this round's proxy indices;
        ``logits``/``masks``: engine outputs whose non-participant rows are
        zeros/False (they are replaced here). Returns the merged arrays
        plus the per-client weights ``decay ** age`` for aggregation.

        Merges must arrive in non-decreasing round order: the age math
        (``round_idx - last_round``) silently goes negative otherwise. The
        overlap scheduler guarantees in-order ingestion via its order
        edges; this guard keeps a direct caller honest.
        """
        if (self._last_merge_round is not None
                and round_idx < self._last_merge_round):
            raise ValueError(
                f"staleness buffer reports must arrive in round order: got "
                f"round {round_idx} after round {self._last_merge_round} — "
                "reusing one Server across experiments needs a fresh buffer")
        self._last_merge_round = round_idx
        part = np.asarray(participants, bool)
        logits = np.asarray(logits, np.float32)
        masks = np.asarray(masks, bool)
        idx = np.asarray(idx)
        pids = np.flatnonzero(part)
        if pids.size:
            # one fancy-index write per array instead of an O(C) Python
            # loop (bit-identical; the loop was the 16k-client hot spot)
            self.logits[pids[:, None], idx[None, :]] = logits[pids]
            self.masks[pids[:, None], idx[None, :]] = masks[pids]
        self.reported[part] = True
        self.last_round[part] = round_idx
        if part.all():
            # identity fast path: everything is fresh — hand back the exact
            # input arrays so fraction=1 reproduces the legacy logs
            # bit-for-bit
            return StaleMerge(logits, masks,
                              np.ones((len(part),), np.float32), 0.0,
                              0.0, int(len(part)))
        merged_logits = np.where(part[:, None, None], logits,
                                 self.logits[:, idx])
        merged_masks = np.where(part[:, None], masks, self.masks[:, idx])
        ages = np.where(part, 0, round_idx - self.last_round)
        # never-reported clients have all-False cached masks, so their
        # weight is irrelevant; zero it anyway to keep the record honest
        weights = np.where(self.reported,
                           np.power(float(decay), ages), 0.0)
        # mean age of the reports that actually reach aggregation: a
        # weight-zero report (decay=0 and stale, or never reported) is
        # dropped from the teacher, so its age must not inflate the metric
        contributing = self.reported & (weights > 0.0)
        n_contrib = int(np.count_nonzero(contributing))
        ages_sum = float(ages[contributing].sum()) if n_contrib else 0.0
        mean_age = ages_sum / n_contrib if n_contrib else 0.0
        return StaleMerge(merged_logits, merged_masks,
                          weights.astype(np.float32), mean_age,
                          ages_sum, n_contrib)
