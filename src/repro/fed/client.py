"""Federated client: private data + private model + DRE + jitted steps.

Each client owns a *different* architecture (system heterogeneity — Tables
I/II), so steps are jitted per client. The filter's feature space is the
flattened sample (paper's MNIST mode) or pre-extracted features (CIFAR10*
mode) — both arrive here simply as ``x``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distill as D
from repro.core.filtering import FilterStats, two_stage_filter
from repro.fed.batching import epoch_batches
from repro.optim.optimizers import Optimizer, apply_updates


class Client:
    def __init__(self, cid: int, apply_fn: Callable, params, opt: Optimizer,
                 x: np.ndarray, y: np.ndarray, dre=None, *,
                 num_classes: int = 10, temperature: float = 3.0,
                 distill_loss: str = "kl", seed: int = 0, arch_key=None,
                 kernel_backend: Optional[str] = None):
        self.cid = cid
        self.apply_fn = apply_fn
        self.params = params
        self.opt = opt
        self.opt_state = opt.init(params)
        self.x = np.asarray(x)
        self.y = np.asarray(y)
        self.dre = dre
        self.num_classes = num_classes
        self.temperature = temperature
        self.distill_loss = distill_loss
        # kernel dispatch for the distill loss (repro.kernels.dispatch);
        # None/"auto" = ambient policy, resolved when the step first traces
        self.kernel_backend = kernel_backend
        # clients sharing an arch_key have identical (init, apply) structure
        # and may be stacked into one cohort (fed/cohort.py); None = unique
        self.arch_key = arch_key
        self.rng = np.random.default_rng(seed + 1000 * cid)
        self.bytes_up = 0
        self.bytes_down = 0

        loss_kind = distill_loss
        backend = kernel_backend

        @jax.jit
        def _train_step(params, opt_state, xb, yb):
            def loss_fn(p):
                logits = self.apply_fn(p, xb, True)
                return D.ce_loss(logits, yb)
            loss, grads = jax.value_and_grad(loss_fn)(params)
            upd, opt_state = self.opt.update(grads, opt_state, params)
            return apply_updates(params, upd), opt_state, loss

        @jax.jit
        def _distill_step(params, opt_state, xb, teacher, w):
            def loss_fn(p):
                logits = self.apply_fn(p, xb, True)
                if loss_kind == "mse":
                    return D.kd_mse_loss(logits, teacher, w)
                return D.kd_kl_loss(logits, teacher, self.temperature, w,
                                    backend=backend)
            loss, grads = jax.value_and_grad(loss_fn)(params)
            upd, opt_state = self.opt.update(grads, opt_state, params)
            return apply_updates(params, upd), opt_state, loss

        @jax.jit
        def _predict(params, xb):
            return self.apply_fn(params, xb, False)

        self._train_step = _train_step
        self._distill_step = _distill_step
        self._predict = _predict

    # ----------------------------------------------------------------- init
    def learn_dre(self, key):
        if self.dre is not None:
            feats = self.x.reshape(len(self.x), -1)
            self.dre = self.dre.learn(key, jnp.asarray(feats))

    # ------------------------------------------------------------- training
    def local_train(self, epochs: int, batch_size: int) -> float:
        n = len(self.y)
        losses = []
        for _ in range(epochs):
            for idx in epoch_batches(self.rng.permutation(n), batch_size):
                self.params, self.opt_state, loss = self._train_step(
                    self.params, self.opt_state,
                    jnp.asarray(self.x[idx]), jnp.asarray(self.y[idx]))
                losses.append(float(loss))
        return float(np.mean(losses)) if losses else 0.0

    def distill(self, proxy_x, teacher, weight, epochs: int,
                batch_size: int) -> float:
        n = len(proxy_x)
        losses = []
        for _ in range(epochs):
            for idx in epoch_batches(self.rng.permutation(n), batch_size):
                self.params, self.opt_state, loss = self._distill_step(
                    self.params, self.opt_state, jnp.asarray(proxy_x[idx]),
                    jnp.asarray(teacher[idx]), jnp.asarray(weight[idx]))
                losses.append(float(loss))
        return float(np.mean(losses)) if losses else 0.0

    # ------------------------------------------------------------ FD round
    def proxy_logits(self, proxy_x) -> jax.Array:
        return self._predict(self.params, jnp.asarray(proxy_x))

    def filter_mask(self, proxy_x, proxy_owner) -> FilterStats:
        if self.dre is None:   # unfiltered methods: everything is "ID"
            t = len(proxy_x)
            ones = jnp.ones((t,), bool)
            return FilterStats(ones, ones, ones, jnp.zeros((t,), jnp.float32))
        feats = jnp.asarray(np.asarray(proxy_x).reshape(len(proxy_x), -1))
        return two_stage_filter(self.dre, feats, jnp.asarray(proxy_owner),
                                self.cid)

    def classwise_means(self):
        """FKD/PLS: per-class mean logits over private data."""
        from repro.core.aggregation import classwise_mean_logits
        logits = self._predict(self.params, jnp.asarray(self.x))
        return classwise_mean_logits(logits, jnp.asarray(self.y),
                                     self.num_classes)

    # ---------------------------------------------------------------- eval
    def evaluate(self, x_test, y_test, batch_size: int = 512) -> float:
        correct = 0
        n = len(y_test)
        for s in range(0, n, batch_size):
            logits = self._predict(self.params, jnp.asarray(x_test[s:s + batch_size]))
            pred = np.asarray(jnp.argmax(logits, -1))
            correct += int((pred == np.asarray(y_test[s:s + batch_size])).sum())
        return correct / n
