"""Deterministic payload-fault traces for Byzantine / corrupted clients.

Wired like :mod:`repro.fed.clock`'s churn and dropout lanes: every draw is
a pure function of ``(seed, round, client)``, so the loop, cohort, and
mesh-sharded engines inject *identical* corruption and the cross-engine
parity tests extend to every fault mode unchanged. Faults are applied to
the report payloads **after** local training (in the scheduler's report
ingest path), never to the training itself — a faulty client trains
honestly and lies on the wire, matching the logit-poisoning threat model
of the FD robustness literature.

Two orthogonal schedules compose into the per-round fault mask:

- ``byzantine_frac`` — a *fixed* adversarial subset (the same clients every
  round), chosen as the ``round(frac * C)`` clients with the smallest
  ``(seed, client)`` lane uniforms.
- ``fault_prob`` — *transient* corruption, an independent per-round coin
  per client (``(seed, round, client)``), modelling flaky hardware rather
  than an adversary.

``fault_start`` / ``fault_duration`` window the attack in round time
(``duration=0`` = unbounded), which is how the watchdog benchmark stages a
mid-run ``nan`` burst.

Modes (``FAULT_MODES``):

- ``none`` — no injection (the legacy protocol; injector is not built).
- ``nan`` — claimed-ID rows are replaced with NaN. With the server's
  sanitize pass disabled this poisons the fused teacher fleet-wide.
- ``random_logits`` — reports replaced with Gaussian noise, deterministic
  in ``(seed, round, client)``.
- ``scaled`` — reports multiplied by ``SCALE_FACTOR`` (magnitude attack:
  a single attacker dominates a plain mean).
- ``colluding_flip`` — reports multiplied by ``-SCALE_FACTOR``: every
  attacker pushes the fused teacher in the same *wrong* direction, the
  strongest coordinated attack against an unweighted mean.
- ``stale_replay`` — each faulty client replays its own report from the
  previous faulty round (first fault round passes through unmodified
  while the cache warms). The replay cache is part of the checkpoint
  state, so kill-and-resume stays bit-for-bit.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.fed.clock import _lane_uniform

FAULT_MODES = ("none", "nan", "random_logits", "scaled", "colluding_flip",
               "stale_replay")

# magnitude used by the scaled / colluding_flip attacks
SCALE_FACTOR = 50.0
# std-dev of the random_logits attack (large vs typical logit scale)
RANDOM_STD = 10.0

_TAG_BYZ = 0xBAD0    # fixed adversarial subset lane
_TAG_FLAKY = 0xFA17  # transient per-round corruption lane


def validate_fault_config(mode: str, fault_prob: float, byzantine_frac: float,
                          fault_start: int, fault_duration: int) -> None:
    if mode not in FAULT_MODES:
        raise ValueError(
            f"fault_mode must be one of {FAULT_MODES}, got {mode!r}")
    if not 0.0 <= fault_prob < 1.0:
        raise ValueError(f"fault_prob must be in [0, 1), got {fault_prob!r}")
    if not 0.0 <= byzantine_frac <= 1.0:
        raise ValueError(
            f"byzantine_frac must be in [0, 1], got {byzantine_frac!r}")
    if fault_start < 0:
        raise ValueError(f"fault_start must be >= 0, got {fault_start!r}")
    if fault_duration < 0:
        raise ValueError(
            f"fault_duration must be >= 0 (0 = unbounded), "
            f"got {fault_duration!r}")


def byzantine_ids(num_clients: int, *, seed: int = 0,
                  byzantine_frac: float = 0.0) -> np.ndarray:
    """``(C,)`` bool — the fixed adversarial subset.

    Exactly ``round(frac * C)`` clients, the ones with the smallest
    ``(seed, client)`` lane uniforms — stable across rounds and fleet
    restarts, and independent of round count.
    """
    k = int(round(byzantine_frac * num_clients))
    mask = np.zeros((num_clients,), bool)
    if k <= 0 or num_clients == 0:
        return mask
    u = _lane_uniform(seed, num_clients, _TAG_BYZ)
    mask[np.argsort(u, kind="stable")[:k]] = True
    return mask


def fault_mask(num_clients: int, round_idx: int, *, seed: int = 0,
               mode: str = "none", fault_prob: float = 0.0,
               byzantine_frac: float = 0.0, fault_start: int = 0,
               fault_duration: int = 0) -> Optional[np.ndarray]:
    """``(C,)`` bool — which clients corrupt their report this round.

    ``None`` means nobody (mode off, schedule empty, or the round falls
    outside the ``[fault_start, fault_start + fault_duration)`` window).
    The mask is the union of the fixed Byzantine subset and the transient
    per-round coins, each deterministic in ``(seed[, round], client)``.
    """
    validate_fault_config(mode, fault_prob, byzantine_frac, fault_start,
                          fault_duration)
    if mode == "none" or (fault_prob == 0.0 and byzantine_frac == 0.0):
        return None
    if round_idx < fault_start:
        return None
    if fault_duration > 0 and round_idx >= fault_start + fault_duration:
        return None
    mask = byzantine_ids(num_clients, seed=seed,
                         byzantine_frac=byzantine_frac)
    if fault_prob > 0.0:
        mask = mask | (_lane_uniform(num_clients=num_clients, seed=seed,
                                     tag=_TAG_FLAKY,
                                     round_idx=round_idx) < fault_prob)
    return mask if mask.any() else None


def _client_rng(seed: int, round_idx: int, cid: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence(
        [seed % 2**32, round_idx % 2**32, int(cid), _TAG_FLAKY]))


class FaultInjector:
    """Applies a fault trace to report payloads, engine-independently.

    Built by the scheduler only when ``fault_mode != "none"`` — the legacy
    path never constructs one, keeping defaults bit-for-bit. The only
    mutable state is the ``stale_replay`` cache (last honest report per
    faulty client), which rides ``state_dict`` through checkpoints.
    """

    def __init__(self, num_clients: int, *, mode: str, seed: int = 0,
                 fault_prob: float = 0.0, byzantine_frac: float = 0.0,
                 fault_start: int = 0, fault_duration: int = 0):
        validate_fault_config(mode, fault_prob, byzantine_frac, fault_start,
                              fault_duration)
        self.num_clients = num_clients
        self.mode = mode
        self.seed = seed
        self.fault_prob = fault_prob
        self.byzantine_frac = byzantine_frac
        self.fault_start = fault_start
        self.fault_duration = fault_duration
        # stale_replay cache: cid -> (logits (t, K), mask (t,)) or, for the
        # classwise path, cid -> (means (Kc, K), counts (Kc,))
        self._replay: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}

    def mask(self, round_idx: int) -> Optional[np.ndarray]:
        return fault_mask(self.num_clients, round_idx, seed=self.seed,
                          mode=self.mode, fault_prob=self.fault_prob,
                          byzantine_frac=self.byzantine_frac,
                          fault_start=self.fault_start,
                          fault_duration=self.fault_duration)

    def _faulty_ids(self, round_idx: int,
                    part: Optional[np.ndarray]) -> List[int]:
        m = self.mask(round_idx)
        if m is None:
            return []
        if part is not None:
            m = m & np.asarray(part, bool)
        return [int(c) for c in np.nonzero(m)[0]]

    def corrupt_reports(self, round_idx: int, logits, masks,
                        part: Optional[np.ndarray]):
        """Corrupt the stacked ``(C, t, K)`` logits / ``(C, t)`` masks.

        Returns ``(logits, masks)`` — the inputs unchanged (same objects)
        when no participant is faulty this round, copies otherwise.
        """
        ids = self._faulty_ids(round_idx, part)
        if not ids:
            return logits, masks
        lo = np.array(logits, np.float32, copy=True)
        mk = np.array(masks, bool, copy=True)
        for c in ids:
            if self.mode == "nan":
                lo[c][mk[c]] = np.nan
            elif self.mode == "random_logits":
                lo[c] = RANDOM_STD * _client_rng(
                    self.seed, round_idx, c).standard_normal(
                        lo[c].shape).astype(np.float32)
            elif self.mode == "scaled":
                lo[c] = SCALE_FACTOR * lo[c]
            elif self.mode == "colluding_flip":
                lo[c] = -SCALE_FACTOR * lo[c]
            elif self.mode == "stale_replay":
                cached = self._replay.get(c)
                fresh = (np.array(lo[c], copy=True),
                         np.array(mk[c], copy=True))
                if cached is not None:
                    lo[c], mk[c] = cached
                self._replay[c] = fresh
        return lo, mk

    def corrupt_classwise(self, round_idx: int,
                          means_counts: Sequence[Tuple[np.ndarray,
                                                       np.ndarray]],
                          part: Optional[np.ndarray]):
        """Same trace applied to data-free ``(means, counts)`` payloads."""
        ids = self._faulty_ids(round_idx, part)
        if not ids:
            return means_counts
        out = [(np.array(m, np.float32, copy=True), np.array(c, copy=True))
               for m, c in means_counts]
        for c in ids:
            means, counts = out[c]
            if self.mode == "nan":
                means[counts > 0] = np.nan
            elif self.mode == "random_logits":
                means[...] = RANDOM_STD * _client_rng(
                    self.seed, round_idx, c).standard_normal(
                        means.shape).astype(np.float32)
            elif self.mode == "scaled":
                means *= SCALE_FACTOR
            elif self.mode == "colluding_flip":
                means *= -SCALE_FACTOR
            elif self.mode == "stale_replay":
                cached = self._replay.get(c)
                fresh = (np.array(means, copy=True),
                         np.array(counts, copy=True))
                if cached is not None:
                    out[c] = cached
                self._replay[c] = fresh
        return out

    # -- checkpoint state ---------------------------------------------------
    def state_dict(self) -> dict:
        return {"replay": [[int(c), np.asarray(a), np.asarray(b)]
                           for c, (a, b) in sorted(self._replay.items())]}

    def load_state_dict(self, sd: dict) -> None:
        self._replay = {int(c): (np.array(a, np.float32, copy=True),
                                 np.array(b, copy=True))
                        for c, a, b in sd.get("replay", [])}
