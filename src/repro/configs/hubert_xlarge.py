"""hubert-xlarge [audio] — encoder-only transformer backbone.

The conv feature extractor / mel frontend is STUBBED per the assignment
carve-out: inputs are precomputed frame embeddings (batch, frames, d_model).
Encoder-only => no decode step; decode_32k / long_500k are skipped (see
DESIGN.md §4). [arXiv:2106.07447]
"""
from repro.common.types import ArchConfig, AttentionKind

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,               # k-means target codebook units
    attention=AttentionKind.ENCODER,
    frontend_stub_dim=1280,
    source="arXiv:2106.07447",
)
