"""Architecture registry: ``--arch <id>`` resolution + reduced smoke variants."""
from __future__ import annotations

import dataclasses

from repro.common.types import ArchConfig, MoEConfig
from repro.configs import (
    granite_8b,
    granite_moe_1b,
    hubert_xlarge,
    internlm2_20b,
    llama32_vision_90b,
    llama3_405b,
    phi3_5_moe,
    qwen2_5_3b,
    recurrentgemma_2b,
    xlstm_350m,
)
from repro.configs.shapes import (DECODE_32K, LONG_500K, PREFILL_32K, SHAPES,
                                  TRAIN_4K)

ARCHS: dict[str, ArchConfig] = {
    c.name: c
    for c in (
        qwen2_5_3b.CONFIG,
        phi3_5_moe.CONFIG,
        internlm2_20b.CONFIG,
        llama32_vision_90b.CONFIG,
        llama3_405b.CONFIG,
        hubert_xlarge.CONFIG,
        xlstm_350m.CONFIG,
        recurrentgemma_2b.CONFIG,
        granite_moe_1b.CONFIG,
        granite_8b.CONFIG,
    )
}

# short aliases for --arch
ALIASES = {
    "qwen2.5-3b": "qwen2.5-3b",
    "phi3.5-moe-42b-a6.6b": "phi3.5-moe-42b-a6.6b",
    "phi3.5-moe": "phi3.5-moe-42b-a6.6b",
    "internlm2-20b": "internlm2-20b",
    "llama-3.2-vision-90b": "llama-3.2-vision-90b",
    "llama32-vision": "llama-3.2-vision-90b",
    "llama3-405b": "llama3-405b",
    "hubert-xlarge": "hubert-xlarge",
    "xlstm-350m": "xlstm-350m",
    "recurrentgemma-2b": "recurrentgemma-2b",
    "granite-moe-1b-a400m": "granite-moe-1b-a400m",
    "granite-moe": "granite-moe-1b-a400m",
    "granite-8b": "granite-8b",
}


def get_arch(name: str) -> ArchConfig:
    key = ALIASES.get(name, name)
    if key not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[key]


def reduced(cfg: ArchConfig, *, layers: int = 2, d_model: int = 256,
            vocab: int = 512) -> ArchConfig:
    """Reduced same-family variant for CPU smoke tests.

    Keeps the family-defining structure (GQA ratio, MoE top-k, hybrid
    pattern, cross-attn cadence) while shrinking every dimension.
    """
    n_heads = max(2, min(4, cfg.num_heads))
    n_kv = max(1, min(n_heads, max(1, n_heads * cfg.num_kv_heads // cfg.num_heads)))
    moe = None
    if cfg.moe is not None:
        moe = MoEConfig(num_experts=min(4, cfg.moe.num_experts),
                        top_k=min(2, cfg.moe.top_k),
                        capacity_factor=cfg.moe.capacity_factor)
    changes = dict(
        num_layers=layers,
        d_model=d_model,
        num_heads=n_heads,
        num_kv_heads=n_kv,
        d_ff=0 if cfg.d_ff == 0 else d_model * 3,
        vocab_size=vocab,
        head_dim=d_model // n_heads,
        moe=moe,
        local_window=min(cfg.local_window, 64),
        num_vision_tokens=min(cfg.num_vision_tokens, 16) if cfg.num_vision_tokens else 0,
        cross_attn_every=2 if cfg.cross_attn_every else 0,
        hybrid_period=cfg.hybrid_period,
        frontend_stub_dim=d_model if cfg.frontend_stub_dim else 0,
        name=cfg.name + "-reduced",
    )
    if cfg.hybrid_period:
        changes["num_layers"] = max(layers, cfg.hybrid_period)
    return dataclasses.replace(cfg, **changes)


__all__ = [
    "ARCHS", "get_arch", "reduced", "SHAPES",
    "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
]
