"""llama-3.2-vision-90b [vlm] — cross-attention image layers every 5th layer.

The vision frontend (ViT encoder + projector) is STUBBED per the assignment
carve-out: ``input_specs`` provides precomputed patch embeddings of shape
(batch, num_vision_tokens, d_model). [hf:meta-llama/Llama-3.2-11B-Vision]
"""
from repro.common.types import ArchConfig, AttentionKind

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    attention=AttentionKind.FULL,
    cross_attn_every=5,           # 20 cross-attn layers out of 100
    num_vision_tokens=1601,       # 1 tile x (40x40 patches + cls), 11B-Vision card
    rope_theta=500_000.0,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)
