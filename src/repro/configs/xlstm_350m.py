"""xlstm-350m [ssm] — alternating sLSTM + mLSTM blocks, attention-free.

d_ff=0: xLSTM blocks integrate their up/down projections (pre-up-projection
mLSTM, post-up-projection sLSTM per arXiv:2405.04517); no separate MLP.
Decode carries a recurrent state (matrix memory C, normalizer n) instead of
a KV cache => long_500k runs natively (state is O(1) in sequence length).
[arXiv:2405.04517]
"""
from repro.common.types import ArchConfig, AttentionKind

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    attention=AttentionKind.RECURRENT,
    slstm_every=2,                # every 2nd block is sLSTM (1:1 mix)
    source="arXiv:2405.04517",
)
