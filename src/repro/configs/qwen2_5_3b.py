"""qwen2.5-3b [dense] — GQA with QKV bias. [hf:Qwen/Qwen2.5-0.5B family]"""
from repro.common.types import ArchConfig, AttentionKind

CONFIG = ArchConfig(
    name="qwen2.5-3b",
    family="dense",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    d_ff=11008,
    vocab_size=151936,
    qkv_bias=True,
    attention=AttentionKind.FULL,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen2.5-0.5B",
)
