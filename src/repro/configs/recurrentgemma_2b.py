"""recurrentgemma-2b [hybrid] — RG-LRU blocks + local attention, 1:2 pattern.

Pattern period 3: (rglru, rglru, local-attn). Decode state = RG-LRU hidden
state + a local-window KV cache (window 2048) => sub-quadratic, long_500k
runs natively. [arXiv:2402.19427]
"""
from repro.common.types import ArchConfig, AttentionKind

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,                # 26 blocks; pattern rounds to 1 attn per 3
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    attention=AttentionKind.LOCAL_HYBRID,
    hybrid_period=3,
    local_window=2048,
    source="arXiv:2402.19427",
)
