"""Pallas TPU kernel: blocked online-softmax (flash) causal attention.

The prefill_32k roofline is dominated by the O(S²) attention; materialising
the (S, S) score matrix is what makes naive attention memory-bound on TPU.
This kernel streams KV tiles through VMEM with the online-softmax recurrence
(running max m, denominator l, accumulator acc as VMEM scratch), so HBM
traffic is O(S·h) per head instead of O(S²).

Grid: (B, N, Sq/bq, Sk/bk) with the KV axis innermost — the accumulator
carries across the innermost grid dimension (standard TPU flash pattern).
Causal masking uses global positions; KV tiles entirely above the diagonal
contribute nothing (masked to −inf) — the `block_skip` hillclimb variant
skips them at the grid level.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_Q = 256
BLOCK_K = 256
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, block_q: int, block_k: int, causal: bool,
            num_kv_blocks: int, kv_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, h)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, h)
    v = v_ref[0, 0].astype(jnp.float32)                  # (bk, h)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = kpos < kv_len                       # mask KV padding
    if causal:
        qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        valid = valid & (kpos <= qpos)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]                                   # (bq,)
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    # rows with every key masked: exp(NEG_INF - NEG_INF) would be 1; zero them
    p = jnp.where((m_new == NEG_INF)[:, None], 0.0, p)
    alpha = jnp.where(m_prev == NEG_INF, 0.0, jnp.exp(m_prev - m_new))
    l_new = alpha * l_prev + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        lsum = l_scr[...]
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(lsum, 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True, kv_len: int = 0,
                           block_q: int = BLOCK_Q, block_k: int = BLOCK_K,
                           interpret: bool = True):
    """q: (B, N, Sq, h); k, v: (B, N, Sk, h) GQA-expanded.
    Sq % block_q == 0 and Sk % block_k == 0 (ops.py pads); kv_len = true
    (unpadded) key count, 0 = Sk."""
    b, n, sq, h = q.shape
    sk = k.shape[2]
    grid = (b, n, sq // block_q, sk // block_k)
    scale = 1.0 / math.sqrt(h)
    kern = functools.partial(
        _kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, num_kv_blocks=sk // block_k, kv_len=kv_len or sk)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, h), lambda b, n, qi, ki: (b, n, qi, 0)),
            pl.BlockSpec((1, 1, block_k, h), lambda b, n, qi, ki: (b, n, ki, 0)),
            pl.BlockSpec((1, 1, block_k, h), lambda b, n, qi, ki: (b, n, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, h), lambda b, n, qi, ki: (b, n, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n, sq, h), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # running max m
            pltpu.VMEM((block_q,), jnp.float32),      # running denom l
            pltpu.VMEM((block_q, h), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
