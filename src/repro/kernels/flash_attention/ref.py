"""Pure-jnp oracle for blocked causal GQA attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B, N, Sq, h); k, v: (B, N, Sk, h) (kv already GQA-expanded).
    Returns (B, N, Sq, h) f32."""
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    logits = jnp.einsum("bnqh,bnkh->bnqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    sq, sk = q.shape[2], k.shape[2]
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask = kpos <= qpos
        if window > 0:
            mask = mask & (kpos > qpos - window)
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bnqk,bnkh->bnqh", probs, v.astype(jnp.float32))
