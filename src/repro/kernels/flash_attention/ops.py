"""Jit'd public wrapper: GQA expansion, padding, layout for flash attention."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret, pad_to
from repro.kernels.flash_attention.kernel import (BLOCK_K, BLOCK_Q,
                                                  flash_attention_pallas)


@functools.partial(jax.jit,
                   static_argnames=("causal", "block_q", "block_k", "interpret"))
def _run(q, k, v, causal, block_q, block_k, interpret):
    b, n, sq, h = q.shape
    nkv = k.shape[1]
    if nkv != n:  # GQA expand
        rep = n // nkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    qp, sq0 = pad_to(q, 2, block_q)
    kp, sk0 = pad_to(k, 2, block_k)
    vp, _ = pad_to(v, 2, block_k)
    out = flash_attention_pallas(qp, kp, vp, causal=causal, kv_len=sk0,
                                 block_q=block_q, block_k=block_k,
                                 interpret=interpret)
    return out[:, :, :sq0]


def attention(q, k, v, *, causal: bool = True, block_q: int = BLOCK_Q,
              block_k: int = BLOCK_K, interpret: bool | None = None):
    """q: (B, N, Sq, h); k, v: (B, NKV, Sk, h) — GQA expanded internally."""
    if interpret is None:
        interpret = default_interpret()
    return _run(q, k, v, causal, block_q, block_k, interpret)
