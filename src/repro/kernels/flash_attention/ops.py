"""Jit'd public wrapper: GQA expansion, padding, layout for flash attention.

The pallas kernel is forward-only (no transpose rule), so ``attention`` is
a ``custom_vjp``: forward runs the fused kernel, backward recomputes via
the jnp oracle (``ref.attention`` is the same mathematical function, so
its VJP is exact up to float reassociation) — flash-attention's standard
no-materialised-probs recompute strategy, reusing the oracle instead of a
second hand-written kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret, pad_to
from repro.kernels.flash_attention import ref as _ref
from repro.kernels.flash_attention.kernel import (BLOCK_K, BLOCK_Q,
                                                  flash_attention_pallas)


def _expand_gqa(q, k, v):
    n, nkv = q.shape[1], k.shape[1]
    if nkv != n:
        rep = n // nkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    return k, v


def _pallas_fwd(q, k, v, causal, block_q, block_k, interpret):
    k, v = _expand_gqa(q, k, v)
    qp, sq0 = pad_to(q, 2, block_q)
    kp, sk0 = pad_to(k, 2, block_k)
    vp, _ = pad_to(v, 2, block_k)
    out = flash_attention_pallas(qp, kp, vp, causal=causal, kv_len=sk0,
                                 block_q=block_q, block_k=block_k,
                                 interpret=interpret)
    return out[:, :, :sq0]


def _ref_gqa(q, k, v, causal):
    """Oracle with the wrapper's GQA expansion and output dtype —
    ``jnp.repeat``'s own VJP sums the grouped kv cotangents correctly."""
    k, v = _expand_gqa(q, k, v)
    return _ref.attention(q, k, v, causal=causal).astype(q.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _attn(q, k, v, causal, block_q, block_k, interpret):
    return _pallas_fwd(q, k, v, causal, block_q, block_k, interpret)


def _attn_fwd(q, k, v, causal, block_q, block_k, interpret):
    return _pallas_fwd(q, k, v, causal, block_q, block_k, interpret), (q, k, v)


def _attn_bwd(causal, block_q, block_k, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: _ref_gqa(q_, k_, v_, causal), q, k, v)
    return vjp(g)


_attn.defvjp(_attn_fwd, _attn_bwd)


@functools.partial(jax.jit,
                   static_argnames=("causal", "block_q", "block_k", "interpret"))
def _run(q, k, v, causal, block_q, block_k, interpret):
    return _attn(q, k, v, causal, block_q, block_k, interpret)


def attention(q, k, v, *, causal: bool = True, block_q: int = BLOCK_Q,
              block_k: int = BLOCK_K, interpret: bool | None = None):
    """q: (B, N, Sq, h); k, v: (B, NKV, Sk, h) — GQA expanded internally."""
    if interpret is None:
        interpret = default_interpret()
    return _run(q, k, v, causal, block_q, block_k, interpret)
