"""Kernel backend dispatch: route hot-path ops to Pallas or pure jnp.

Every compute hot-spot of the federated round — the Lloyd assignment step
of the KMeans-DRE fit, the temperature-KL distillation loss, and the
KuLSIF RBF gram matrices — exists twice in this repo: a purpose-built
Pallas TPU kernel (``repro.kernels.*``) and the pure-jnp reference the
framework historically ran. This module is the single switch between
them.

Backends
--------
``kernel_backend ∈ {"auto", "pallas", "jnp"}``:

* ``"auto"`` (the default everywhere) — Pallas on TPU, jnp elsewhere.
  Interpret-mode Pallas is deliberately **never** an ``auto`` choice: it
  emits the kernel body as ordinary jnp ops (a test/CI vehicle, not a
  fast path), so on CPU/GPU hosts ``auto`` means the tuned XLA reference
  code.
* ``"pallas"`` — force the Pallas kernels. On a TPU they lower through
  Mosaic; on any other backend they run in interpret mode, which is how
  CI exercises the kernel code paths end-to-end
  (``REPRO_KERNEL_BACKEND=pallas`` on a CPU matrix entry).
* ``"jnp"`` — force the reference path. On CPU this is bit-for-bit the
  pre-dispatch behavior (``tests/test_kernel_dispatch.py`` pins it
  against golden round logs).

Resolution order for an ``"auto"``/unset request: the innermost
:func:`kernel_backend` context manager, then the ``REPRO_KERNEL_BACKEND``
environment variable, then the platform rule above. An explicit
``"pallas"``/``"jnp"`` (e.g. ``FedConfig.kernel_backend``) always wins.

Resolution happens at *trace* time: jitted round phases bake the resolved
backend in when they first compile, so flipping the ambient backend never
retraces an already-compiled phase (and selecting a backend per config is
one compile per backend, cached thereafter).

The jnp fallbacks in this module are the **canonical** reference
implementations — ``repro.core.kmeans.pairwise_sq_dists`` and
``repro.core.dre.rbf_kernel`` delegate here. Their op sequences must not
change: the default-backend bit-for-bit guarantee rides on them.
"""
from __future__ import annotations

import contextlib
import os
from typing import List, Optional

import jax
import jax.numpy as jnp

BACKENDS = ("auto", "pallas", "jnp")
ENV_VAR = "REPRO_KERNEL_BACKEND"

_context_stack: List[str] = []


def _validate(name: str, source: str) -> str:
    if name not in BACKENDS:
        raise ValueError(
            f"unknown kernel backend {name!r} (from {source}); "
            f"known: {', '.join(BACKENDS)}")
    return name


def requested_backend(backend: Optional[str] = None) -> str:
    """The raw request before platform resolution (may be ``"auto"``)."""
    if backend is not None and _validate(backend, "argument") != "auto":
        return backend
    if _context_stack and _context_stack[-1] != "auto":
        return _context_stack[-1]
    env = os.environ.get(ENV_VAR, "")
    if env and _validate(env, f"${ENV_VAR}") != "auto":
        return env
    return "auto"


def resolve(backend: Optional[str] = None) -> str:
    """Resolve a request down to the concrete backend: "pallas" or "jnp".

    ``None`` and ``"auto"`` defer to the ambient request (context manager,
    then ``REPRO_KERNEL_BACKEND``), and finally to the platform rule:
    Pallas iff running on TPU.
    """
    b = requested_backend(backend)
    if b != "auto":
        return b
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


@contextlib.contextmanager
def kernel_backend(name: str):
    """Scoped ambient-backend override (tests/benchmarks).

    Overrides ``"auto"``/unset requests inside the ``with`` block; an
    explicit per-call/per-config ``"pallas"``/``"jnp"`` still wins. Note
    that jitted functions resolve at trace time — state built *before*
    entering the context keeps the backend it compiled with.
    """
    _validate(name, "kernel_backend()")
    _context_stack.append(name)
    try:
        yield
    finally:
        _context_stack.pop()


# ---------------------------------------------------------------------------
# Canonical jnp reference implementations (bit-for-bit sensitive)
# ---------------------------------------------------------------------------

def pairwise_sq_dists(x, c):
    """‖x−c‖² via the matmul form (MXU-friendly): x:(n,d), c:(k,d) -> (n,k)."""
    x2 = jnp.sum(jnp.square(x), axis=-1, keepdims=True)        # (n,1)
    c2 = jnp.sum(jnp.square(c), axis=-1)                       # (k,)
    cross = x @ c.T                                            # (n,k)
    return jnp.maximum(x2 - 2.0 * cross + c2[None, :], 0.0)


def _rbf_matrix_jnp(a, b, sigma):
    """K(a,b) = exp(−‖a−b‖²/(2σ²)) — the historical ``dre.rbf_kernel``."""
    d2 = pairwise_sq_dists(a, b)
    return jnp.exp(-d2 / (2.0 * sigma * sigma))


def _lloyd_step_jnp(x, centroids):
    """One fused-Lloyd equivalent in plain jnp (matmul distances, one-hot
    scatter): x (n,d), centroids (k,d) -> (assign (n,) i32, min_d2 (n,),
    sums (k,d), counts (k,)). This is the op sequence ``kmeans_fit``'s
    reference scan body has always used — including its f32 accumulation,
    which the Pallas kernel matches for any input dtype."""
    x = x.astype(jnp.float32)
    centroids = centroids.astype(jnp.float32)
    k = centroids.shape[0]
    d2 = pairwise_sq_dists(x, centroids)
    assign = jnp.argmin(d2, axis=-1)
    one_hot = jax.nn.one_hot(assign, k, dtype=jnp.float32)
    counts = jnp.sum(one_hot, axis=0)                          # (k,)
    sums = one_hot.T @ x                                       # (k, d)
    return (assign.astype(jnp.int32), jnp.min(d2, axis=-1), sums, counts)


# ---------------------------------------------------------------------------
# Dispatched ops
# ---------------------------------------------------------------------------

def lloyd_step(x, centroids, *, backend: Optional[str] = None):
    """Fused Lloyd assignment + accumulation step of the KMeans-DRE fit.

    ``x``: (n, d) or batched (C, n, d); ``centroids``: (k, d) / (C, k, d).
    Returns ``(assign int32, min_d2 f32, sums f32, counts f32)`` with
    matching leading axes. Pallas fuses the matmul-form distances, the
    argmin and the per-centroid sum/count accumulation in VMEM — the
    (n, k) one-hot never reaches HBM and there is no second full matmul
    pass over the data.
    """
    if resolve(backend) == "pallas":
        from repro.kernels.kmeans_dist import ops as kd_ops
        return kd_ops.lloyd_step(x, centroids)
    if x.ndim == 3:
        return jax.vmap(_lloyd_step_jnp)(x, centroids)
    return _lloyd_step_jnp(x, centroids)


def kd_kl_per_sample(student_logits, teacher_logits, temperature: float,
                     *, backend: Optional[str] = None):
    """Per-sample temperature-KL (Hinton) distillation loss, (n, K) -> (n,).

    Differentiable on both backends: the Pallas path carries a
    ``jax.custom_vjp`` whose backward pass is a second fused kernel
    (softmax recompute + both logit gradients in one VMEM tile).
    ``temperature`` is compile-time static on the Pallas path — gradients
    w.r.t. it are not defined there (they never are in the FD protocol).
    """
    if resolve(backend) == "pallas":
        from repro.kernels.distill_kl import ops as kl_ops
        return kl_ops.kd_kl_per_sample_vjp(student_logits, teacher_logits,
                                           float(temperature))
    from repro.kernels.distill_kl import ref as kl_ref
    return kl_ref.kd_kl_per_sample(student_logits, teacher_logits,
                                   temperature)


def rbf_matrix(a, b, sigma, *, backend: Optional[str] = None):
    """RBF gram matrix K(a, b), (n, d) × (m, d) -> (n, m) f32.

    The KuLSIF-DRE learn/estimate hot-spot; the Pallas path tiles the
    gram matrix through VMEM (peak memory one tile, not n×m).
    """
    if resolve(backend) == "pallas":
        from repro.kernels.kulsif_rbf import ops as rbf_ops
        return rbf_ops.rbf_matrix(a, b, sigma)
    return _rbf_matrix_jnp(a, b, sigma)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    backend: Optional[str] = None):
    """Full-sequence attention in the model layout: q/k/v (B, S, N, h),
    kv already GQA-expanded. Returns (B, S, N, h) in ``v.dtype``.

    The transformer local-train/distill hot path. The jnp route is
    op-for-op ``models.layers``' historical mask + scores sequence (the
    default-backend bit-for-bit guarantee rides on it); the Pallas route
    is the fused flash kernel (O(S) memory, online softmax), which covers
    causal/full attention only — a sliding ``window`` always takes the
    reference path regardless of backend. Differentiable on both routes
    (the kernel carries a ``custom_vjp``; see ``flash_attention.ops``).
    """
    if window == 0 and resolve(backend) == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops
        o = fa_ops.attention(q.swapaxes(1, 2), k.swapaxes(1, 2),
                             v.swapaxes(1, 2), causal=causal)
        return o.swapaxes(1, 2).astype(v.dtype)
    from repro.models import layers as L
    mask = L.make_mask(q.shape[1], k.shape[1], causal=causal, window=window)
    return L.attention_scores(q, k, v, mask)
