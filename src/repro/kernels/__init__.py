"""Pallas TPU kernels for the compute hot-spots (DESIGN.md §2).

Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
public wrapper), ref.py (pure-jnp oracle). Validated with interpret=True
on CPU; lowered by Mosaic on TPU.

``repro.kernels.dispatch`` is the backend switch that routes the
framework's hot paths (KMeans-DRE Lloyd fit, KD-KL loss, KuLSIF gram
matrices) to these kernels or to the jnp reference code
(``kernel_backend ∈ {auto, pallas, jnp}``).
"""
from repro.kernels import (dispatch, distill_kl, flash_attention, kmeans_dist,
                           kulsif_rbf)
from repro.kernels.dispatch import kernel_backend as kernel_backend
