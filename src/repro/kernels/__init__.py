"""Pallas TPU kernels for the compute hot-spots (DESIGN.md §2).

Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
public wrapper), ref.py (pure-jnp oracle). Validated with interpret=True
on CPU; lowered by Mosaic on TPU.
"""
from repro.kernels import distill_kl, flash_attention, kmeans_dist, kulsif_rbf
