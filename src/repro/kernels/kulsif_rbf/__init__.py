from repro.kernels.kulsif_rbf import ops, ref
