"""Pure-jnp oracle for the tiled RBF (Gaussian) kernel matrix."""
from __future__ import annotations

import jax.numpy as jnp


def rbf_matrix(a, b, sigma):
    """K[i,j] = exp(−‖a_i−b_j‖² / (2σ²)); a:(n,d), b:(m,d) -> (n,m)."""
    diff = a[:, None, :].astype(jnp.float32) - b[None, :, :].astype(jnp.float32)
    d2 = jnp.sum(jnp.square(diff), axis=-1)
    return jnp.exp(-d2 / (2.0 * sigma * sigma))
