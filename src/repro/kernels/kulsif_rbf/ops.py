"""Jit'd public wrapper for the kulsif_rbf kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret, pad_to
from repro.kernels.kulsif_rbf.kernel import BLOCK_M, BLOCK_N, rbf_matrix_pallas


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def _run(a, b, sigma, block_m, block_n, interpret):
    ap, n = pad_to(a, 0, block_m)
    bp, m = pad_to(b, 0, block_n)
    out = rbf_matrix_pallas(ap, bp, sigma, block_m=block_m, block_n=block_n,
                            interpret=interpret)
    return out[:n, :m]


def rbf_matrix(a, b, sigma, *, block_m: int = BLOCK_M, block_n: int = BLOCK_N,
               interpret: bool | None = None):
    if interpret is None:
        interpret = default_interpret()
    return _run(jnp.asarray(a), jnp.asarray(b), jnp.float32(sigma),
                block_m, block_n, interpret)
