"""Pallas TPU kernel: tiled RBF Gram-matrix computation for KuLSIF-DRE.

The baseline estimator's K11/K12 construction is its learn-phase hot-spot
(paper Table IV: O(m²·d) time, O(m²) space). The kernel tiles the Gram
matrix into (BM × BN) VMEM blocks — matmul-form distances on the MXU, exp on
the VPU — so peak memory per step is one tile, not the full m×m matrix.

Grid: 2-D over (rows, cols) tiles.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_M = 256
BLOCK_N = 256


def _kernel(a_ref, b_ref, sig_ref, out_ref):
    a = a_ref[...].astype(jnp.float32)            # (bm, d)
    b = b_ref[...].astype(jnp.float32)            # (bn, d)
    a2 = jnp.sum(a * a, axis=-1, keepdims=True)   # (bm, 1)
    b2 = jnp.sum(b * b, axis=-1)                  # (bn,)
    cross = jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    d2 = jnp.maximum(a2 - 2.0 * cross + b2[None, :], 0.0)
    sig = sig_ref[0]
    out_ref[...] = jnp.exp(-d2 / (2.0 * sig * sig))


def rbf_matrix_pallas(a, b, sigma, *, block_m: int = BLOCK_M,
                      block_n: int = BLOCK_N, interpret: bool = True):
    """a: (n, d), b: (m, d) — n, m multiples of the block sizes (ops pads).
    Returns (n, m) f32 Gram matrix."""
    n, d = a.shape
    m = b.shape[0]
    sig = jnp.asarray([sigma], jnp.float32)
    grid = (n // block_m, m // block_n)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=interpret,
    )(a, b, sig)
