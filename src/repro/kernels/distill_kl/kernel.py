"""Pallas TPU kernels: fused temperature-softmax KL loss, forward + backward.

Per distillation batch the loss touches two (n, K) logit tensors; unfused,
XLA materialises four intermediates (two log-softmaxes, probs, pointwise
product) in HBM. The forward kernel computes both stabilised log-softmaxes
and the weighted KL reduction inside one VMEM tile — one read of each
operand, one (n,) write.

The backward kernel closes the loop for training through the kernel
(``ops.kd_kl_per_sample_vjp``): it recomputes both softmaxes from the saved
logits (cheaper than storing probabilities) and emits the analytic
gradients in the same tile —

    ∂(T²·KL_i)/∂s = g_i · T · (softmax(s/T) − softmax(t/T))
    ∂(T²·KL_i)/∂t = g_i · T · softmax(t/T) · ((log t̂ − log ŝ) − KL_i/T²)

so a fused distill step never materialises probabilities in HBM in either
direction.

Grid: 1-D over tiles of n; the class axis K stays whole inside a tile
(K ≤ a few thousand for FD logits).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 512


def _kernel(s_ref, t_ref, temp_ref, out_ref):
    s = s_ref[...].astype(jnp.float32)
    t = t_ref[...].astype(jnp.float32)
    temp = temp_ref[0]
    s = s / temp
    t = t / temp
    s_max = jnp.max(s, axis=-1, keepdims=True)
    t_max = jnp.max(t, axis=-1, keepdims=True)
    s_lse = jnp.log(jnp.sum(jnp.exp(s - s_max), axis=-1, keepdims=True)) + s_max
    t_lse = jnp.log(jnp.sum(jnp.exp(t - t_max), axis=-1, keepdims=True)) + t_max
    s_logp = s - s_lse
    t_logp = t - t_lse
    kl = jnp.sum(jnp.exp(t_logp) * (t_logp - s_logp), axis=-1)
    out_ref[...] = kl * temp * temp


def kd_kl_pallas(student, teacher, temperature, *, block_n: int = BLOCK_N,
                 interpret: bool = True):
    """student/teacher: (n, K), n a multiple of block_n (ops pads).
    Returns per-sample KL (n,) f32."""
    n, k = student.shape
    temp = jnp.asarray([temperature], jnp.float32)
    grid = (n // block_n,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, k), lambda i: (i, 0)),
            pl.BlockSpec((block_n, k), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(student, teacher, temp)


def _log_softmaxes(s_ref, t_ref, temp: float):
    """Shared bwd recompute: stabilised log-softmaxes of both logit tiles."""
    s = s_ref[...].astype(jnp.float32) / temp
    t = t_ref[...].astype(jnp.float32) / temp
    s_max = jnp.max(s, axis=-1, keepdims=True)
    t_max = jnp.max(t, axis=-1, keepdims=True)
    s_lse = jnp.log(jnp.sum(jnp.exp(s - s_max), axis=-1, keepdims=True)) + s_max
    t_lse = jnp.log(jnp.sum(jnp.exp(t - t_max), axis=-1, keepdims=True)) + t_max
    return s - s_lse, t - t_lse


def _bwd_ds_kernel(s_ref, t_ref, g_ref, ds_ref, *, temp: float):
    s_logp, t_logp = _log_softmaxes(s_ref, t_ref, temp)
    gt = g_ref[...].astype(jnp.float32)[:, None] * temp
    ds_ref[...] = (gt * (jnp.exp(s_logp) - jnp.exp(t_logp))
                   ).astype(ds_ref.dtype)


def _bwd_dt_kernel(s_ref, t_ref, g_ref, dt_ref, *, temp: float):
    s_logp, t_logp = _log_softmaxes(s_ref, t_ref, temp)
    tp = jnp.exp(t_logp)
    # f = KL_i / T² — recomputed, not saved (one extra reduction in VMEM)
    f = jnp.sum(tp * (t_logp - s_logp), axis=-1, keepdims=True)
    gt = g_ref[...].astype(jnp.float32)[:, None] * temp
    dt_ref[...] = (gt * tp * ((t_logp - s_logp) - f)).astype(dt_ref.dtype)


def _bwd_call(kern, out_dtype, student, teacher, g, block_n, interpret):
    n, k = student.shape
    return pl.pallas_call(
        kern,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, k), lambda i: (i, 0)),
            pl.BlockSpec((block_n, k), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_n, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), out_dtype),
        interpret=interpret,
    )(student, teacher, g)


def kd_kl_bwd_pallas(student, teacher, g, temperature: float, *,
                     block_n: int = BLOCK_N, interpret: bool = True):
    """Backward pass: student/teacher (n, K), per-sample cotangent g (n,).
    Returns (d_student, d_teacher), each (n, K) in the primal dtype.
    ``temperature`` is compile-time static (baked into the kernels).

    The two gradients are *separate* kernel launches on purpose: in the FD
    protocol the teacher is the server's aggregated logits — a constant —
    so its cotangent is dead downstream and XLA eliminates the d_teacher
    launch entirely instead of fusing its cost into every distill step.
    The price is recomputing the two log-softmaxes when both gradients
    really are needed (rare), which is VMEM-cheap.
    """
    temp = float(temperature)
    ds = _bwd_call(functools.partial(_bwd_ds_kernel, temp=temp),
                   student.dtype, student, teacher, g, block_n, interpret)
    dt = _bwd_call(functools.partial(_bwd_dt_kernel, temp=temp),
                   teacher.dtype, student, teacher, g, block_n, interpret)
    return ds, dt
