"""Pallas TPU kernel: fused temperature-softmax KL distillation loss.

Per distillation batch the loss touches two (n, K) logit tensors; unfused,
XLA materialises four intermediates (two log-softmaxes, probs, pointwise
product) in HBM. The kernel computes both stabilised log-softmaxes and the
weighted KL reduction inside one VMEM tile — one read of each operand, one
(n,) write.

Grid: 1-D over tiles of n; the class axis K stays whole inside a tile
(K ≤ a few thousand for FD logits).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 512


def _kernel(s_ref, t_ref, temp_ref, out_ref):
    s = s_ref[...].astype(jnp.float32)
    t = t_ref[...].astype(jnp.float32)
    temp = temp_ref[0]
    s = s / temp
    t = t / temp
    s_max = jnp.max(s, axis=-1, keepdims=True)
    t_max = jnp.max(t, axis=-1, keepdims=True)
    s_lse = jnp.log(jnp.sum(jnp.exp(s - s_max), axis=-1, keepdims=True)) + s_max
    t_lse = jnp.log(jnp.sum(jnp.exp(t - t_max), axis=-1, keepdims=True)) + t_max
    s_logp = s - s_lse
    t_logp = t - t_lse
    kl = jnp.sum(jnp.exp(t_logp) * (t_logp - s_logp), axis=-1)
    out_ref[...] = kl * temp * temp


def kd_kl_pallas(student, teacher, temperature, *, block_n: int = BLOCK_N,
                 interpret: bool = True):
    """student/teacher: (n, K), n a multiple of block_n (ops pads).
    Returns per-sample KL (n,) f32."""
    n, k = student.shape
    temp = jnp.asarray([temperature], jnp.float32)
    grid = (n // block_n,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, k), lambda i: (i, 0)),
            pl.BlockSpec((block_n, k), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(student, teacher, temp)
