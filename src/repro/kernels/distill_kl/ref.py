"""Pure-jnp oracle for the fused temperature-KL distillation loss."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def kd_kl_per_sample(student_logits, teacher_logits, temperature):
    """Per-sample KL(teacher_T ∥ student_T) · T². (n, K) -> (n,)."""
    t = temperature
    sp = jax.nn.log_softmax(student_logits.astype(jnp.float32) / t, axis=-1)
    tlogp = jax.nn.log_softmax(teacher_logits.astype(jnp.float32) / t, axis=-1)
    tp = jnp.exp(tlogp)
    return jnp.sum(tp * (tlogp - sp), axis=-1) * (t * t)
