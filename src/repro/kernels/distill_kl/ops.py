"""Jit'd public wrapper for the distill_kl kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret, pad_to
from repro.kernels.distill_kl.kernel import BLOCK_N, kd_kl_pallas


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def _run(student, teacher, temperature, block_n, interpret):
    sp, n = pad_to(student, 0, block_n)
    tp, _ = pad_to(teacher, 0, block_n)
    kl = kd_kl_pallas(sp, tp, temperature, block_n=block_n,
                      interpret=interpret)
    return kl[:n]


def kd_kl_per_sample(student, teacher, temperature, *,
                     block_n: int = BLOCK_N, interpret: bool | None = None):
    if interpret is None:
        interpret = default_interpret()
    return _run(jnp.asarray(student), jnp.asarray(teacher),
                jnp.float32(temperature), block_n, interpret)
