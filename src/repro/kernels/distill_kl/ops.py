"""Jit'd public wrappers for the distill_kl kernels (fwd + custom-VJP)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret, pad_to
from repro.kernels.distill_kl.kernel import (BLOCK_N, kd_kl_bwd_pallas,
                                             kd_kl_pallas)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def _run(student, teacher, temperature, block_n, interpret):
    sp, n = pad_to(student, 0, block_n)
    tp, _ = pad_to(teacher, 0, block_n)
    kl = kd_kl_pallas(sp, tp, temperature, block_n=block_n,
                      interpret=interpret)
    return kl[:n]


def kd_kl_per_sample(student, teacher, temperature, *,
                     block_n: int = BLOCK_N, interpret: bool | None = None):
    if interpret is None:
        interpret = default_interpret()
    return _run(jnp.asarray(student), jnp.asarray(teacher),
                jnp.float32(temperature), block_n, interpret)


@functools.partial(jax.jit,
                   static_argnames=("temperature", "block_n", "interpret"))
def _run_bwd(student, teacher, g, temperature, block_n, interpret):
    sp, n = pad_to(student, 0, block_n)
    tp, _ = pad_to(teacher, 0, block_n)
    gp, _ = pad_to(g, 0, block_n)      # zero cotangent => zero pad grads
    ds, dt = kd_kl_bwd_pallas(sp, tp, gp, temperature, block_n=block_n,
                              interpret=interpret)
    return ds[:n], dt[:n]


@functools.lru_cache(maxsize=None)
def _vjp_fn(temperature: float, block_n: int, interpret: bool):
    """Build (and cache) the custom-VJP op for one static (T, block) combo.

    The residuals are the raw logits — both softmaxes are recomputed by the
    backward kernel, so nothing beyond the inputs is saved for backward.
    """

    @jax.custom_vjp
    def f(student, teacher):
        return _run(student, teacher, jnp.float32(temperature), block_n,
                    interpret)

    def fwd(student, teacher):
        return f(student, teacher), (student, teacher)

    def bwd(res, g):
        student, teacher = res
        return _run_bwd(student, teacher, g, temperature, block_n, interpret)

    f.defvjp(fwd, bwd)
    return f


def kd_kl_per_sample_vjp(student, teacher, temperature: float, *,
                         block_n: int = BLOCK_N,
                         interpret: bool | None = None):
    """Differentiable per-sample KL: Pallas forward, fused Pallas backward.

    ``temperature`` must be a static python float (it is baked into the
    backward kernel; the FD protocol never differentiates through it).
    """
    if interpret is None:
        interpret = default_interpret()
    return _vjp_fn(float(temperature), block_n, interpret)(
        jnp.asarray(student), jnp.asarray(teacher))
