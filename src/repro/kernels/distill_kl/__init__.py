from repro.kernels.distill_kl import ops, ref
