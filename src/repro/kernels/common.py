"""Shared kernel utilities: interpret-mode default and padding helpers.

On this CPU container every kernel runs with ``interpret=True`` (Pallas
executes the kernel body with jnp semantics); on a real TPU the same code
lowers to Mosaic. Block shapes are chosen for v5e VMEM (~16 MiB usable) and
MXU alignment (multiples of 128 on matmul dims).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def pad_to(x, axis: int, multiple: int, value=0.0):
    """Pad axis up to a multiple; returns (padded, original_size)."""
    n = x.shape[axis]
    rem = (-n) % multiple
    if rem == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths, constant_values=value), n


def cdiv(a: int, b: int) -> int:
    return -(-a // b)
