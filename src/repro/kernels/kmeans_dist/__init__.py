from repro.kernels.kmeans_dist import ops, ref
