"""Pallas TPU kernel: fused min-distance-to-centroid + ID threshold test.

The estimation hot-spot of KMeans-DRE (paper Table IV: O(t·c·d)). TPU-native
formulation (DESIGN.md §3): ‖x−k‖² = ‖x‖² − 2·x·Kᵀ + ‖k‖² turns the distance
into one MXU matmul per tile; min-reduction and the threshold compare fuse in
VMEM so the boolean mask never round-trips to HBM.

Grid: 1-D over tiles of t. The centroid tile (c ≤ 1024, d) stays resident in
VMEM across grid steps (constant index_map).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_T = 256


def _kernel(x_ref, c_ref, thr_ref, dist_ref, mask_ref):
    x = x_ref[...].astype(jnp.float32)           # (bt, d)
    c = c_ref[...].astype(jnp.float32)           # (C, d)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)  # (bt, 1)
    c2 = jnp.sum(c * c, axis=-1)                 # (C,)
    cross = jax.lax.dot_general(                 # (bt, C) — the MXU matmul
        x, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    d2 = jnp.maximum(x2 - 2.0 * cross + c2[None, :], 0.0)
    md = jnp.sqrt(jnp.min(d2, axis=-1))
    dist_ref[...] = md
    mask_ref[...] = (md <= thr_ref[0]).astype(jnp.int8)


def kmeans_dist_pallas(x, centroids, threshold, *, block_t: int = BLOCK_T,
                       interpret: bool = True):
    """x: (t, d) — t must be a multiple of block_t (ops.py pads).
    centroids: (c, d); threshold: scalar.
    Returns (min_dist (t,) f32, is_id (t,) int8)."""
    t, d = x.shape
    c = centroids.shape[0]
    thr = jnp.asarray([threshold], jnp.float32)
    grid = (t // block_t,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, d), lambda i: (i, 0)),
            pl.BlockSpec((c, d), lambda i: (0, 0)),       # resident
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_t,), lambda i: (i,)),
            pl.BlockSpec((block_t,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t,), jnp.float32),
            jax.ShapeDtypeStruct((t,), jnp.int8),
        ],
        interpret=interpret,
    )(x, centroids, thr)
