"""Pallas TPU kernels for KMeans-DRE: min-distance estimation + fused Lloyd.

``kmeans_dist_pallas`` is the *estimation* hot-spot of KMeans-DRE (paper
Table IV: O(t·c·d)). TPU-native formulation (DESIGN.md §3): ‖x−k‖² =
‖x‖² − 2·x·Kᵀ + ‖k‖² turns the distance into one MXU matmul per tile;
min-reduction and the threshold compare fuse in VMEM so the boolean mask
never round-trips to HBM.

``lloyd_step_pallas`` is the *fit* hot-spot (Algorithm 1 line 3,
O(k·n·c·d)): one Lloyd iteration — the same matmul-form distances, the
argmin assignment, and the per-centroid sum/count accumulation — fused in
a single kernel. The reference ``kmeans_fit`` scan body materialises an
(n, k) one-hot in HBM and pays a second full (k, n)·(n, d) matmul pass
over the data; here the one-hot lives only as a (block_t, k) VMEM tile
and the partial sums accumulate into a resident (k, d) output block
across grid steps.

Grid: 1-D over tiles of t (``kmeans_dist``), or (C, tiles-of-t) with a
leading client axis (``lloyd_step`` — the cohort engine fits every
client's filter in one call, so the batch axis is part of the grid, not a
per-client retrace). Centroid tiles (c ≤ 1024, d) stay resident in VMEM
across the tile axis (constant index_map).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_T = 256


def _kernel(x_ref, c_ref, thr_ref, dist_ref, mask_ref):
    x = x_ref[...].astype(jnp.float32)           # (bt, d)
    c = c_ref[...].astype(jnp.float32)           # (C, d)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)  # (bt, 1)
    c2 = jnp.sum(c * c, axis=-1)                 # (C,)
    cross = jax.lax.dot_general(                 # (bt, C) — the MXU matmul
        x, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    d2 = jnp.maximum(x2 - 2.0 * cross + c2[None, :], 0.0)
    md = jnp.sqrt(jnp.min(d2, axis=-1))
    dist_ref[...] = md
    mask_ref[...] = (md <= thr_ref[0]).astype(jnp.int8)


def kmeans_dist_pallas(x, centroids, threshold, *, block_t: int = BLOCK_T,
                       interpret: bool = True):
    """x: (t, d) — t must be a multiple of block_t (ops.py pads).
    centroids: (c, d); threshold: scalar.
    Returns (min_dist (t,) f32, is_id (t,) int8)."""
    t, d = x.shape
    c = centroids.shape[0]
    thr = jnp.asarray([threshold], jnp.float32)
    grid = (t // block_t,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, d), lambda i: (i, 0)),
            pl.BlockSpec((c, d), lambda i: (0, 0)),       # resident
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_t,), lambda i: (i,)),
            pl.BlockSpec((block_t,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t,), jnp.float32),
            jax.ShapeDtypeStruct((t,), jnp.int8),
        ],
        interpret=interpret,
    )(x, centroids, thr)


def _lloyd_kernel(x_ref, c_ref, assign_ref, mind2_ref, sums_ref, counts_ref,
                  *, block_t: int, n_true: int):
    j = pl.program_id(1)
    x = x_ref[0].astype(jnp.float32)             # (bt, d)
    c = c_ref[0].astype(jnp.float32)             # (k, d)
    k = c.shape[0]
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)  # (bt, 1)
    c2 = jnp.sum(c * c, axis=-1)                 # (k,)
    cross = jax.lax.dot_general(                 # (bt, k) — the MXU matmul
        x, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    d2 = jnp.maximum(x2 - 2.0 * cross + c2[None, :], 0.0)
    assign = jnp.argmin(d2, axis=-1)             # (bt,)
    assign_ref[0] = assign.astype(jnp.int32)
    mind2_ref[0] = jnp.min(d2, axis=-1)
    # (bt, k) one-hot lives only in this VMEM tile; rows past the true
    # sample count (ops.py pads t up to a block multiple) carry no mass
    row = j * block_t + jax.lax.broadcasted_iota(jnp.int32, (block_t, 1), 0)
    valid = (row < n_true).astype(jnp.float32)   # (bt, 1)
    oh = (assign[:, None]
          == jax.lax.broadcasted_iota(jnp.int32, (block_t, k), 1)
          ).astype(jnp.float32) * valid
    part_sums = jax.lax.dot_general(             # (k, d) — second MXU matmul
        oh, x, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    part_counts = jnp.sum(oh, axis=0)            # (k,)

    @pl.when(j == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)

    # the (k, d)/(k,) output blocks have a constant index_map along the
    # tile axis, so they stay resident and accumulate across grid steps
    sums_ref[0] += part_sums
    counts_ref[0] += part_counts


def lloyd_step_pallas(x, centroids, *, block_t: int = BLOCK_T,
                      n_true: int | None = None, interpret: bool = True):
    """x: (C, t, d) — t a multiple of block_t (ops.py pads); centroids:
    (C, k, d); n_true = true (unpadded) row count, None = t.
    Returns (assign (C, t) i32, min_d2 (C, t) f32, sums (C, k, d) f32,
    counts (C, k) f32) — padded rows excluded from sums/counts."""
    bc, t, d = x.shape
    k = centroids.shape[1]
    grid = (bc, t // block_t)
    kern = functools.partial(_lloyd_kernel, block_t=block_t,
                             n_true=n_true if n_true is not None else t)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, d), lambda c, j: (c, j, 0)),
            pl.BlockSpec((1, k, d), lambda c, j: (c, 0, 0)),   # resident
        ],
        out_specs=[
            pl.BlockSpec((1, block_t), lambda c, j: (c, j)),
            pl.BlockSpec((1, block_t), lambda c, j: (c, j)),
            pl.BlockSpec((1, k, d), lambda c, j: (c, 0, 0)),   # accumulated
            pl.BlockSpec((1, k), lambda c, j: (c, 0)),         # accumulated
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bc, t), jnp.int32),
            jax.ShapeDtypeStruct((bc, t), jnp.float32),
            jax.ShapeDtypeStruct((bc, k, d), jnp.float32),
            jax.ShapeDtypeStruct((bc, k), jnp.float32),
        ],
        interpret=interpret,
    )(x, centroids)
