"""Pure-jnp oracle for the KMeans-DRE distance/threshold kernel."""
from __future__ import annotations

import jax.numpy as jnp


def min_dist_and_mask(x, centroids, threshold):
    """x: (t, d), centroids: (c, d) -> (min_dist (t,), is_id (t,) bool).

    Naive direct form — the correctness oracle (no matmul trick, so it also
    cross-checks the kernel's ‖x‖²−2x·c+‖c‖² algebra).
    """
    diff = x[:, None, :].astype(jnp.float32) - centroids[None, :, :].astype(jnp.float32)
    d2 = jnp.sum(jnp.square(diff), axis=-1)          # (t, c)
    md = jnp.sqrt(jnp.min(d2, axis=-1))
    return md, md <= threshold
