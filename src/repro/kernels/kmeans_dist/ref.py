"""Pure-jnp oracles for the KMeans-DRE distance and fused-Lloyd kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def min_dist_and_mask(x, centroids, threshold):
    """x: (t, d), centroids: (c, d) -> (min_dist (t,), is_id (t,) bool).

    Naive direct form — the correctness oracle (no matmul trick, so it also
    cross-checks the kernel's ‖x‖²−2x·c+‖c‖² algebra).
    """
    diff = x[:, None, :].astype(jnp.float32) - centroids[None, :, :].astype(jnp.float32)
    d2 = jnp.sum(jnp.square(diff), axis=-1)          # (t, c)
    md = jnp.sqrt(jnp.min(d2, axis=-1))
    return md, md <= threshold


def lloyd_step(x, centroids):
    """Oracle for one fused Lloyd iteration: x (n, d), centroids (k, d) ->
    (assign (n,) i32, min_d2 (n,), sums (k, d), counts (k,)).

    Naive direct-form distances (cross-checks the kernel algebra) with the
    explicit one-hot scatter the fused kernel eliminates.
    """
    diff = x[:, None, :].astype(jnp.float32) - centroids[None, :, :].astype(jnp.float32)
    d2 = jnp.sum(jnp.square(diff), axis=-1)          # (n, k)
    assign = jnp.argmin(d2, axis=-1)
    one_hot = jax.nn.one_hot(assign, centroids.shape[0], dtype=jnp.float32)
    sums = one_hot.T @ x.astype(jnp.float32)
    counts = jnp.sum(one_hot, axis=0)
    return (assign.astype(jnp.int32), jnp.min(d2, axis=-1), sums, counts)
