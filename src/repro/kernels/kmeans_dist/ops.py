"""Jit'd public wrapper for the kmeans_dist kernel (padding + dtype)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret, pad_to
from repro.kernels.kmeans_dist.kernel import (BLOCK_T, kmeans_dist_pallas,
                                              lloyd_step_pallas)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def _run(x, centroids, threshold, block_t, interpret):
    xp, t = pad_to(x, 0, block_t)
    dist, mask = kmeans_dist_pallas(xp, centroids, threshold,
                                    block_t=block_t, interpret=interpret)
    return dist[:t], mask[:t].astype(bool)


def min_dist_and_mask(x, centroids, threshold, *, block_t: int = BLOCK_T,
                      interpret: bool | None = None):
    """Public op: (min_dist (t,), is_id (t,) bool)."""
    if interpret is None:
        interpret = default_interpret()
    return _run(jnp.asarray(x), jnp.asarray(centroids),
                jnp.float32(threshold), block_t, interpret)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def _run_lloyd(x, centroids, block_t, interpret):
    xp, n = pad_to(x, 1, block_t)
    assign, min_d2, sums, counts = lloyd_step_pallas(
        xp, centroids, block_t=block_t, n_true=n, interpret=interpret)
    return assign[:, :n], min_d2[:, :n], sums, counts


def lloyd_step(x, centroids, *, block_t: int = BLOCK_T,
               interpret: bool | None = None):
    """Public op: one fused Lloyd iteration of the KMeans-DRE fit.

    ``x``: (n, d) or (C, n, d); ``centroids``: (k, d) / (C, k, d).
    Returns (assign i32, min_d2 f32, sums (…, k, d) f32, counts (…, k)
    f32) with matching leading axes; padded rows never reach sums/counts.
    """
    if interpret is None:
        interpret = default_interpret()
    x = jnp.asarray(x)
    centroids = jnp.asarray(centroids)
    if x.ndim == 2:
        out = _run_lloyd(x[None], centroids[None], block_t, interpret)
        return tuple(o[0] for o in out)
    return _run_lloyd(x, centroids, block_t, interpret)
