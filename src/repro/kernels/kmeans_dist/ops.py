"""Jit'd public wrapper for the kmeans_dist kernel (padding + dtype)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret, pad_to
from repro.kernels.kmeans_dist.kernel import BLOCK_T, kmeans_dist_pallas


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def _run(x, centroids, threshold, block_t, interpret):
    xp, t = pad_to(x, 0, block_t)
    dist, mask = kmeans_dist_pallas(xp, centroids, threshold,
                                    block_t=block_t, interpret=interpret)
    return dist[:t], mask[:t].astype(bool)


def min_dist_and_mask(x, centroids, threshold, *, block_t: int = BLOCK_T,
                      interpret: bool | None = None):
    """Public op: (min_dist (t,), is_id (t,) bool)."""
    if interpret is None:
        interpret = default_interpret()
    return _run(jnp.asarray(x), jnp.asarray(centroids),
                jnp.float32(threshold), block_t, interpret)
