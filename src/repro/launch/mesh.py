"""Production meshes (TPU v5e pods) and sharding-spec derivation.

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before first init).

Param sharding uses a deterministic auto-sharder (DESIGN.md §5): per leaf,
skip the leading layer-stack axes, shard the largest mesh-divisible dim on
``model`` and the largest remaining divisible dim on ``data`` (FSDP);
``pod`` replicates params (grads all-reduce over DCN) and shards batch.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Thin wrapper over the single device-layout builder
    (``repro.fed.mesh.build_mesh``): deterministic ``jax.devices()`` order
    folded row-major, with the same legible too-few-devices error as the
    federated client mesh."""
    from repro.fed.mesh import build_mesh
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return build_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Tiny mesh over however many (CPU) devices exist — for tests.
    Routed through ``repro.fed.mesh.build_mesh`` like every other mesh."""
    from repro.fed.mesh import build_mesh
    return build_mesh((data, model), ("data", "model"))


# ---------------------------------------------------------------------------
# parameter auto-sharder
# ---------------------------------------------------------------------------

def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


# Name-aware sharding templates (EXPERIMENTS.md §Perf pair C — iterated
# against measured HLO collectives). Two hard-won rules:
#   1. 'model' NEVER goes on a weight's contraction dim unless the psum it
#      induces is the intended Megatron output-psum (wo / wd) — otherwise
#      every projection partial-sums full activations.
#   2. 'data' (FSDP) goes on a CONTRACTION dim (GSPMD then all-gathers the
#      WEIGHT, ZeRO-style — cheap) or rides the same dim as 'model'
#      (joint (model,data) shard, psum covers both axes, zero gathers) —
#      never on an output dim (that re-shards the residual stream).
# Templates: name -> tuple of (negative dim offset, axis-or-tuple) tried in
# order; first divisible assignment wins per axis.
_NAME_SPECS = {
    # (d, n_heads, h): d=contraction -> data(gather W); heads -> model
    "wq": [(-3, "data"), (-2, "model"), (-1, "model")],
    "wk": [(-3, "data"), (-2, "model"), (-1, "model")],
    "wv": [(-3, "data"), (-2, "model"), (-1, "model")],
    # (n, h, d): heads -> model (Megatron out-psum); NO FSDP — any wo shard
    # beyond heads either partial-sums (h) or re-shards the residual stream
    # (d), both measured worse than replicating the remaining 2 MB/rank
    # (§Perf pair C iterations 3/4/7)
    "wo": [(-3, "model")],
    # dense (d, f) / moe (e, d, f): d=contraction -> data; f -> model;
    # moe experts -> model first
    "wg": [(-3 - 100, None), (-2, "data"), (-1, "model")],  # placeholder; fixed below
    # (f, d) / (e, f, d): contraction f -> (model, data) jointly; when the
    # expert dim already took 'model' (MoE), f falls back to 'data' alone
    "wd": [(-2, ("model", "data")), (-2, "data")],
    "embed": [(-2, "model"), (-1, "data")],
    "lm_head": [(-1, "model"), (-2, "data")],
    "router": [(-2, "model"), (-1, "data")],
}
_NAME_SPECS["wg"] = [(-2, "data"), (-1, "model")]
_NAME_SPECS["wu"] = [(-2, "data"), (-1, "model")]
# moe 3-D variants override the leading (expert) dim
_MOE_EXPERT_FIRST = ("wg", "wu", "wd")


def param_spec(shape: tuple, mesh: Mesh, *, n_stack_axes: int = 0,
               fsdp: bool = True, name: Optional[str] = None) -> P:
    """Resolve the template for `name` (fallback: heuristic largest-divisible
    for 'model' on non-attention leaves, then 'data')."""
    model = _axis_size(mesh, "model")
    data = _axis_size(mesh, "data")
    sizes = {"model": model, "data": data}
    nd = len(shape)
    assign: list[Optional[object]] = [None] * nd
    dims = set(range(n_stack_axes, nd))
    used_axes: set = set()

    def axis_len(ax) -> int:
        if isinstance(ax, tuple):
            return int(np.prod([sizes[a] for a in ax]))
        return sizes[ax]

    def place(off: int, ax) -> None:
        if ax is None:
            return
        if not fsdp and (ax == "data" or (isinstance(ax, tuple) and "data" in ax)):
            if ax == "data":
                return
            ax = tuple(a for a in ax if a != "data") or None
            if ax is None:
                return
            if len(ax) == 1:
                ax = ax[0]
        if isinstance(ax, tuple):
            # Size-1 mesh axes are inert — drop them so a joint template
            # still shards over whatever remains (the federated
            # (clients, model) mesh has no 'data' axis, but wd's Megatron
            # out-psum placement on 'model' is still wanted there).
            ax = tuple(a for a in ax if sizes[a] > 1)
            if not ax:
                return
            if len(ax) == 1:
                ax = ax[0]
        i = nd + off if off < 0 else off
        flat = set(ax) if isinstance(ax, tuple) else {ax}
        if i in dims and shape[i] >= axis_len(ax) \
                and shape[i] % axis_len(ax) == 0 \
                and not (flat & used_axes) \
                and all(sizes[a] > 1 for a in flat):
            assign[i] = ax
            dims.discard(i)
            used_axes.update(flat)

    template = list(_NAME_SPECS.get(name or "", []))
    if name in _MOE_EXPERT_FIRST and nd - n_stack_axes == 3:
        # MoE (e, d, f)/(e, f, d): experts -> model (expert parallel).
        # FSDP rides the OUTPUT dim here, not the contraction dim — measured
        # 2 GB/step cheaper on phi3.5-moe (d-sharded expert weights force
        # ~80 GB/step of per-layer weight gathers; §Perf pair C iter 3/4).
        if name in ("wg", "wu"):
            template = [(n_stack_axes - nd, "model"), (-1, "data")]
        else:  # wd (e, f, d)
            template = [(n_stack_axes - nd, "model"), (-2, "data")]
    for off, ax in template:
        place(off, ax)

    if "model" not in used_axes and model > 1 \
            and name not in ("wq", "wk", "wv", "wo", "wd"):
        cands = [i for i in dims if shape[i] >= model and shape[i] % model == 0]
        mi = max(cands, key=lambda i: shape[i], default=None)
        if mi is not None:
            assign[mi] = "model"
            dims.discard(mi)
            used_axes.add("model")
    if fsdp and "data" not in used_axes and data > 1 and not template:
        cands = [i for i in dims if shape[i] >= data and shape[i] % data == 0]
        di = max(cands, key=lambda i: shape[i], default=None)
        if di is not None:
            assign[di] = "data"
    return P(*assign)


def _stack_depth(path) -> int:
    """Number of leading stacked-layer axes for a leaf at this pytree path.

    Layer stacks live under 'blocks'; vlm/hybrid group members nested one
    level deeper ('self'/'rec') carry two stack axes.
    """
    keys = [getattr(p, "key", None) for p in path]
    if "blocks" not in keys:
        return 0
    return 2 if any(k in ("self", "rec") for k in keys) else 1


def param_shardings(params, mesh: Mesh, *, fsdp: bool = True):
    """Pytree of NamedSharding for a parameter pytree."""
    def leaf(path, x):
        name = next((str(p.key) for p in reversed(path)
                     if hasattr(p, "key")), None)
        spec = param_spec(x.shape, mesh, n_stack_axes=_stack_depth(path),
                          fsdp=fsdp, name=name)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(leaf, params)


def opt_state_shardings(params, mesh: Mesh, *, fsdp: bool = True):
    """ZeRO-style shardings for Adam m/v: start from the param spec, then
    force a 'data' placement on any remaining divisible dim. Optimizer state
    is only touched elementwise, so sharding it never induces activation
    collectives — the only cost is one update all-gather for leaves whose
    param is more replicated than its state (e.g. wo: 4.2 GB/step vs
    7.9 GB HBM saved on llama3-405b; §Perf pair A)."""
    data = _axis_size(mesh, "data")

    def leaf(path, x):
        name = next((str(p.key) for p in reversed(path)
                     if hasattr(p, "key")), None)
        spec = param_spec(x.shape, mesh, n_stack_axes=_stack_depth(path),
                          fsdp=fsdp, name=name)
        parts = list(spec) + [None] * (len(x.shape) - len(spec))
        used = {a for p in parts if p for a in (p if isinstance(p, tuple) else (p,))}
        if fsdp and data > 1 and "data" not in used:
            n_stack = _stack_depth(path)
            cands = [i for i in range(n_stack, len(x.shape))
                     if parts[i] is None and x.shape[i] >= data
                     and x.shape[i] % data == 0]
            if cands:
                di = max(cands, key=lambda i: x.shape[i])
                parts[di] = "data"
        return NamedSharding(mesh, P(*parts))
    return jax.tree_util.tree_map_with_path(leaf, params)


def batch_spec(mesh: Mesh, *, shard_batch: bool = True) -> P:
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    return P(tuple(axes)) if (shard_batch and axes) else P()


def data_shardings(batch_like, mesh: Mesh, *, batch_divisible: bool = True):
    """Shard the leading (batch) axis of every input leaf over pod+data.

    For long_500k (batch=1) the batch axis is unshardable; callers pass
    batch_divisible=False and the KV cache length gets sharded instead
    (see dryrun.cache_shardings).
    """
    spec = batch_spec(mesh, shard_batch=batch_divisible)
    def leaf(x):
        nd = x.ndim if hasattr(x, "ndim") else len(x.shape)
        full = P(*(list(spec) + [None] * (nd - 1))) if nd else P()
        return NamedSharding(mesh, full)
    return jax.tree.map(leaf, batch_like)
