"""Multi-pod dry-run: lower + compile every (arch × input-shape) on the
production meshes, and extract the roofline terms from the compiled HLO.

MUST set the host-device override before any other import touches jax.
"""
import os

os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA", "")
                           + " --xla_force_host_platform_device_count=512").strip()

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
from typing import Optional  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np   # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.common.types import ArchConfig, AttentionKind, InputShape  # noqa: E402
from repro.configs import ARCHS, SHAPES, get_arch  # noqa: E402
from repro.launch import mesh as M  # noqa: E402
from repro.launch.analytic import step_cost  # noqa: E402
from repro.launch.hlo_analysis import collective_bytes_corrected  # noqa: E402
from repro.models import transformer as T  # noqa: E402
from repro.models.layers import set_attention_options  # noqa: E402
from repro.models.ssm import set_slstm_unroll  # noqa: E402
from repro.models.sharding import DEFAULT_RULES, PROFILES, set_logical_rules  # noqa: E402
from repro.optim.optimizers import adamw  # noqa: E402

# ---------------------------------------------------------------------------
# TPU v5e constants (roofline)
# ---------------------------------------------------------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link

# long-context policy (DESIGN.md §4): dense/moe/vlm run long_500k only via
# the sliding-window variant; ssm/hybrid run native; audio has no decode.
LONG_WINDOW = 8192
SKIPS = {
    ("hubert-xlarge", "decode_32k"): "encoder-only: no decode step",
    ("hubert-xlarge", "long_500k"): "encoder-only: no decode step",
}


def long_window_for(cfg: ArchConfig, shape: InputShape) -> Optional[int]:
    if shape.name == "long_500k" and cfg.family in ("dense", "moe", "vlm"):
        return LONG_WINDOW
    return None


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins — no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: InputShape, *, dtype=jnp.bfloat16):
    """Batch pytree of ShapeDtypeStructs for train/prefill steps."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if cfg.family == "audio":
        return {"frames": jax.ShapeDtypeStruct((b, s, cfg.frontend_stub_dim), dtype),
                "labels": jax.ShapeDtypeStruct((b, s), i32)}
    batch = {"tokens": jax.ShapeDtypeStruct((b, s), i32),
             "labels": jax.ShapeDtypeStruct((b, s), i32)}
    if cfg.family == "vlm":
        batch["vision"] = jax.ShapeDtypeStruct((b, cfg.num_vision_tokens, cfg.d_model), dtype)
    return batch


def decode_specs(cfg: ArchConfig, shape: InputShape, *, dtype=jnp.bfloat16):
    """(tokens, pos) specs + cache specs for a serve step."""
    b = shape.global_batch
    window = long_window_for(cfg, shape)
    cache = jax.eval_shape(
        lambda: T.init_cache(cfg, b, shape.seq_len, dtype,
                             window_override=window))
    tokens = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return tokens, pos, cache


# ---------------------------------------------------------------------------
# sharding of inputs / caches
# ---------------------------------------------------------------------------

def _batch_axes(mesh, profile: str = "2d"):
    axes = ("pod", "data", "model") if profile == "dp" else ("pod", "data")
    return tuple(a for a in axes if a in mesh.axis_names)


def cache_shardings(cache, mesh, batch: int):
    """Shard cache batch over pod+data when divisible, else shard the cache
    length axis over data (long_500k, batch=1). Heads/model dims sharded on
    'model' when divisible."""
    baxes = _batch_axes(mesh)
    bsize = int(np.prod([mesh.shape[a] for a in baxes])) if baxes else 1
    model = mesh.shape.get("model", 1)
    data = mesh.shape.get("data", 1)

    def leaf(path, x):
        keys = [getattr(p, "key", None) for p in path]
        shape = x.shape
        spec = [None] * len(shape)
        is_kv = keys[-1] in ("k", "v", "ck", "cv") and len(shape) >= 4
        # find the batch axis: first axis of size `batch` after stack axes
        try:
            bi = next(i for i, d in enumerate(shape) if d == batch and i <= 2)
        except StopIteration:
            bi = None
        if bi is not None and batch % max(bsize, 1) == 0 and bsize > 1:
            spec[bi] = baxes if len(baxes) > 1 else baxes[0]
        elif is_kv:
            # batch unshardable -> shard cache length over data
            li = len(shape) - 3
            if shape[li] % data == 0 and data > 1:
                spec[li] = "data"
        if is_kv:
            # KV cache: 'model' goes on the LENGTH axis (flash-decode style
            # sequence parallelism — scores/PV reduce with one tiny psum).
            # Sharding kv-heads usually fails GQA divisibility, and sharding
            # head_dim makes QK^T gather the whole cache (measured
            # 174 GB/step on llama-3.2-vision decode_32k, Perf pair D).
            li = len(shape) - 3
            if spec[li] is None and shape[li] % model == 0 and model > 1:
                spec[li] = "model"
            elif shape[-2] % model == 0 and shape[-2] >= model and model > 1:
                spec[-2] = "model"   # kv heads, when they do divide
            return NamedSharding(mesh, P(*spec))
        # recurrent/conv state: largest remaining dim on model
        for cand in range(len(shape) - 1, -1, -1):
            if spec[cand] is None and shape[cand] % model == 0 \
                    and shape[cand] >= model and model > 1:
                spec[cand] = "model"
                break
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(leaf, cache)


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------

def make_train_step(cfg: ArchConfig, *, remat: bool = True, lr: float = 3e-4,
                    opt_state_dtype=None):
    opt = adamw(lr, state_dtype=opt_state_dtype)

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, metrics = T.train_loss(p, cfg, batch, remat=remat)
            return loss, metrics
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = jax.tree.map(jnp.add, params, updates)
        return params, opt_state, {"loss": loss, **metrics}

    return train_step, opt


def make_serve_step(cfg: ArchConfig, window: Optional[int]):
    def serve_step(params, tokens, cache, pos):
        logits, cache = T.decode_step(params, cfg, tokens, cache, pos,
                                      window_override=window)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, cache
    return serve_step


def make_prefill_step(cfg: ArchConfig):
    def prefill_step(params, batch):
        if cfg.family == "audio":
            logits, _ = T.forward(params, cfg, frames=batch["frames"])
        elif cfg.family == "vlm":
            logits, _ = T.forward(params, cfg, batch["tokens"],
                                  vision=batch["vision"])
        else:
            logits, _ = T.forward(params, cfg, batch["tokens"])
        return logits
    return prefill_step


# ---------------------------------------------------------------------------
# HLO analysis
# ---------------------------------------------------------------------------
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1}
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|pred)"
                       r"\[([0-9,]*)\]")
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(segment: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(segment):
        dt, dims = m.groups()
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op, per kind."""
    out = {k: 0 for k in _COLL_KINDS}
    out["count"] = 0
    for line in hlo_text.splitlines():
        ls = line.strip()
        if "=" not in ls:
            continue
        lhs, _, rhs = ls.partition(" = ")
        for kind in _COLL_KINDS:
            # match op name at the start of RHS type+opname, e.g.
            # "f32[128]{0} all-reduce(" — require "kind(" in rhs and rhs
            # not being a fusion mentioning the name in a comment
            if f" {kind}(" in " " + rhs.split("(")[0].rsplit(" ", 1)[-1] + "(" \
                    and rhs.split("(")[0].rsplit(" ", 1)[-1].startswith(kind):
                out[kind] += _shape_bytes(rhs.split("(")[0])
                out["count"] += 1
                break
    return out


def roofline(cost: dict, mem: dict, coll: dict, n_chips: int,
             model_flops: float, analytic) -> dict:
    """The three roofline terms (seconds).

    compute / memory: from the analytic per-step model (scan bodies are
    undercounted by cost_analysis — see analytic.py); collective: from the
    compiled HLO with while-trip-count correction (hlo_analysis.py).
    HLO raw numbers are kept as cross-checks.
    """
    hlo_flops = float(cost.get("flops", 0.0))
    hlo_bytes = float(cost.get("bytes accessed", 0.0))
    cbytes = float(sum(coll[k] for k in _COLL_KINDS))
    t_compute = analytic.flops / n_chips / PEAK_FLOPS
    t_memory = analytic.hbm_bytes / n_chips / HBM_BW
    t_coll = cbytes / ICI_BW
    dom = max((("compute", t_compute), ("memory", t_memory),
               ("collective", t_coll)), key=lambda kv: kv[1])[0]
    return {
        "analytic_flops_global": analytic.flops,
        "analytic_hbm_bytes_global": analytic.hbm_bytes,
        "hlo_flops_per_device_scan_body_once": hlo_flops,
        "hlo_bytes_per_device_scan_body_once": hlo_bytes,
        "collective_bytes_per_device": cbytes,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "bottleneck": dom,
        "model_flops_total": model_flops,
        "useful_flops_ratio": (model_flops / analytic.flops
                               if analytic.flops else 0.0),
    }


# ---------------------------------------------------------------------------
# the dry run
# ---------------------------------------------------------------------------

def dryrun_one(arch_name: str, shape_name: str, *, multi_pod: bool = False,
               remat: bool = True, fsdp: bool = True, verbose: bool = True,
               opt_state_dtype=None, profile: str = "2d",
               chunk_q: int = 0, slstm_unroll: int = 1,
               bf16_psum: bool = False) -> dict:
    cfg = get_arch(arch_name)
    shape = SHAPES[shape_name]
    skip = SKIPS.get((cfg.name, shape.name))
    if skip:
        return {"arch": cfg.name, "shape": shape.name, "skipped": skip}

    mesh = M.make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    rules = dict(PROFILES[profile])
    if profile == "dp":
        fsdp = False
    if shape.mode == "decode":
        # serving has no optimizer state: TP-only params (no FSDP) kill the
        # per-token weight gathers (§Perf pair D)
        fsdp = False
    set_logical_rules(rules, mesh)
    set_attention_options(chunk_q=chunk_q, bf16_psum=bf16_psum)
    set_slstm_unroll(slstm_unroll)
    dtype = jnp.bfloat16
    t0 = time.perf_counter()

    params_shape = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0), dtype))
    if profile == "dp":
        pshard = jax.tree.map(
            lambda s: NamedSharding(mesh, P()), params_shape)
    else:
        pshard = M.param_shardings(params_shape, mesh, fsdp=fsdp)

    window = long_window_for(cfg, shape)

    if shape.mode == "train":
        step, opt = make_train_step(cfg, remat=remat,
                                    opt_state_dtype=opt_state_dtype)
        opt_shape = jax.eval_shape(lambda p: opt.init(p), params_shape)
        oshard = jax.tree.map(
            lambda s: NamedSharding(
                mesh, M.param_spec(s.shape, mesh, n_stack_axes=0, fsdp=fsdp))
            if s.ndim > 0 else NamedSharding(mesh, P()),
            opt_shape)
        # optimizer state mirrors param sharding (m, v have param shapes)
        zshard = M.opt_state_shardings(params_shape, mesh, fsdp=fsdp)
        oshard = {
            "m": zshard,
            "v": jax.tree.map(lambda s: s, zshard),
            "step": NamedSharding(mesh, P()),
        }
        batch = input_specs(cfg, shape, dtype=dtype)
        baxes = _batch_axes(mesh, profile)
        bspec = P(baxes if len(baxes) > 1 else baxes[0])
        bshard = jax.tree.map(
            lambda s: NamedSharding(mesh, P(*(list(bspec) + [None] * (len(s.shape) - 1)))),
            batch)
        jitted = jax.jit(step,
                         in_shardings=(pshard, oshard, bshard),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(params_shape, opt_shape, batch)
        # tokens-based model flops: 6 * N_active * tokens
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * cfg.active_param_count() * tokens
    elif shape.mode == "prefill":
        step = make_prefill_step(cfg)
        batch = input_specs(cfg, shape, dtype=dtype)
        batch.pop("labels")
        baxes = _batch_axes(mesh, profile)
        bspec = P(baxes if len(baxes) > 1 else baxes[0])
        bshard = jax.tree.map(
            lambda s: NamedSharding(mesh, P(*(list(bspec) + [None] * (len(s.shape) - 1)))),
            batch)
        jitted = jax.jit(step, in_shardings=(pshard, bshard))
        lowered = jitted.lower(params_shape, batch)
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * cfg.active_param_count() * tokens
    else:  # decode
        step = make_serve_step(cfg, window)
        tokens_s, pos_s, cache = decode_specs(cfg, shape, dtype=dtype)
        cshard = cache_shardings(cache, mesh, shape.global_batch)
        baxes = _batch_axes(mesh, profile)
        bsize = int(np.prod([mesh.shape[a] for a in baxes]))
        tok_spec = (P(baxes if len(baxes) > 1 else baxes[0], None)
                    if shape.global_batch % bsize == 0 and bsize > 1 else P())
        tshard = NamedSharding(mesh, tok_spec)
        jitted = jax.jit(step,
                         in_shardings=(pshard, tshard, cshard,
                                       NamedSharding(mesh, P())),
                         donate_argnums=(2,))
        lowered = jitted.lower(params_shape, tokens_s, cache, pos_s)
        model_flops = 2.0 * cfg.active_param_count() * shape.global_batch

    compiled = lowered.compile()
    compile_s = time.perf_counter() - t0

    cost = compiled.cost_analysis()
    mem = compiled.memory_analysis()
    hlo_text = compiled.as_text()
    coll = collective_bytes_corrected(hlo_text)
    coll_raw = collective_bytes(hlo_text)
    analytic = step_cost(cfg, shape, window=window,
                         opt_bytes_per_param=4.0 if opt_state_dtype else 8.0)
    rl = roofline(cost or {}, mem, coll, n_chips, model_flops, analytic)
    result = {
        "arch": cfg.name,
        "shape": shape.name,
        "mesh": "x".join(f"{k}={v}" for k, v in mesh.shape.items()),
        "n_chips": n_chips,
        "mode": shape.mode,
        "profile": profile,
        "fsdp": fsdp,
        "chunk_q": chunk_q,
        "slstm_unroll": slstm_unroll,
        "bf16_psum": bf16_psum,
        "window_override": window,
        "compile_s": round(compile_s, 1),
        "collectives": coll,
        "collectives_uncorrected": coll_raw,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        **rl,
    }
    set_logical_rules(None, None)
    set_attention_options(chunk_q=0)
    if verbose:
        print(json.dumps(result, indent=None, default=str))
    return result


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--opt-bf16", action="store_true",
                    help="bf16 AdamW state (memory lever for 405B)")
    ap.add_argument("--profile", default="2d", choices=sorted(PROFILES),
                    help="sharding profile (see models/sharding.py)")
    ap.add_argument("--chunk-q", type=int, default=0,
                    help="flash-style query-chunked attention tile (0=naive)")
    ap.add_argument("--slstm-unroll", type=int, default=1,
                    help="sLSTM time-scan unroll (all-reduce reassociation)")
    ap.add_argument("--bf16-psum", action="store_true",
                    help="bf16 output on psum-feeding projections")
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    results = []
    for mp in meshes:
        for a in archs:
            for s in shapes:
                try:
                    r = dryrun_one(a, s, multi_pod=mp,
                                   remat=not args.no_remat,
                                   fsdp=not args.no_fsdp,
                                   profile=args.profile,
                                   chunk_q=args.chunk_q,
                                   slstm_unroll=args.slstm_unroll,
                                   bf16_psum=args.bf16_psum,
                                   opt_state_dtype=jnp.bfloat16 if args.opt_bf16 else None)
                except Exception as e:  # record failures; they are bugs
                    r = {"arch": a, "shape": s, "multi_pod": mp,
                         "error": f"{type(e).__name__}: {e}"}
                    print(json.dumps(r, default=str))
                results.append(r)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=2, default=str)
    errs = [r for r in results if "error" in r]
    print(f"\n{len(results)} runs, {len(errs)} errors")
    if errs:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
