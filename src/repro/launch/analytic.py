"""Analytic (napkin-math) FLOPs and HBM-traffic model per (arch × shape).

``cost_analysis()`` counts ``lax.scan`` bodies once (layer stacks and SSM
time loops are scans), so its FLOPs undercount by the trip count. The
roofline's compute and memory terms therefore come from this explicit model;
the HLO numbers are recorded alongside as cross-checks (hlo_analysis.py
corrects the collective term, which genuinely needs the compiled schedule).

All numbers are GLOBAL per step; the roofline divides by chip count.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.common.types import ArchConfig, AttentionKind, InputShape

BF16 = 2
F32 = 4


@dataclasses.dataclass
class CostModel:
    flops: float            # global FLOPs per step
    hbm_bytes: float        # global HBM bytes touched per step
    detail: dict


def _attn_flops_fwd(cfg: ArchConfig, batch: int, s: int,
                    window: Optional[int]) -> float:
    """QKᵀ + PV einsum flops per full forward (all layers), causal-halved."""
    h = cfg.resolved_head_dim
    if cfg.attention == AttentionKind.RECURRENT:
        # mLSTM chunkwise: intra-chunk (S·Lc) scores + state updates ≈ linear
        lc = 256
        d_in = 2 * cfg.d_model
        per_layer = 2 * 2 * batch * s * lc * d_in          # scores + out
        per_layer += 2 * 2 * batch * s * (d_in // cfg.num_heads) * d_in  # state
        return cfg.num_layers / 2 * per_layer              # mLSTM half of blocks
    kv_len = min(window, s) if window else s
    eff = kv_len if window else s / 2                      # causal half
    n_attn_layers = cfg.num_layers
    if cfg.attention == AttentionKind.LOCAL_HYBRID:
        n_attn_layers = cfg.num_layers // cfg.hybrid_period
        eff = min(cfg.local_window, s)
    if cfg.attention == AttentionKind.ENCODER:
        eff = s                                            # bidirectional
    flops = 2 * 2 * batch * s * eff * cfg.num_heads * h * n_attn_layers
    if cfg.cross_attn_every:
        n_cross = cfg.num_layers // cfg.cross_attn_every
        flops += 2 * 2 * batch * s * cfg.num_vision_tokens * cfg.num_heads * h * n_cross
    return flops


def step_cost(cfg: ArchConfig, shape: InputShape, *,
              window: Optional[int] = None,
              opt_bytes_per_param: float = 8.0) -> CostModel:
    """FLOPs + HBM model. Train = 3× forward matmul flops (fwd+bwd) +
    optimizer traffic; decode = 1 token vs full weight read + cache IO."""
    b, s = shape.global_batch, shape.seq_len
    n_active = cfg.active_param_count()
    n_total = cfg.param_count()
    d = cfg.d_model
    h = cfg.resolved_head_dim

    if shape.mode == "train":
        tokens = b * s
        mm = 6.0 * n_active * tokens                # fwd 2NT + bwd 4NT
        attn = 3.0 * _attn_flops_fwd(cfg, b, s, window)
        flops = mm + attn
        # HBM: params read ×2 (fwd+bwd) + grads written + adam m,v r/w
        p_traffic = n_total * BF16 * 3 + n_total * opt_bytes_per_param * 2
        # activations: ~12 live (B,S,d) tensors per layer in bf16 with remat
        act = 12 * tokens * d * BF16 * cfg.num_layers
        logits = tokens * cfg.vocab_size * BF16 * 2
        hbm = p_traffic + act + logits
        detail = {"matmul_flops": mm, "attn_flops": attn,
                  "param_bytes": p_traffic, "act_bytes": act,
                  "logit_bytes": logits}
    elif shape.mode == "prefill":
        tokens = b * s
        mm = 2.0 * n_active * tokens
        attn = _attn_flops_fwd(cfg, b, s, window)
        flops = mm + attn
        act = 4 * tokens * d * BF16 * cfg.num_layers
        hbm = n_total * BF16 + act + tokens * cfg.vocab_size * BF16
        detail = {"matmul_flops": mm, "attn_flops": attn}
    else:  # decode: one token per sequence
        mm = 2.0 * n_active * b
        cache_len = min(window, s) if window else s
        if cfg.attention == AttentionKind.RECURRENT:
            d_in = 2 * d
            hh = d_in // cfg.num_heads
            attn = cfg.num_layers / 2 * b * (2 * cfg.num_heads * hh * hh * 2)
            cache_bytes = (cfg.num_layers / 2) * b * cfg.num_heads * hh * (hh + 1) * F32 * 2
        elif cfg.attention == AttentionKind.LOCAL_HYBRID:
            n_attn = cfg.num_layers // cfg.hybrid_period
            w = min(cfg.local_window, s)
            attn = 2 * 2 * b * w * cfg.num_heads * h * n_attn
            cache_bytes = n_attn * b * w * cfg.num_kv_heads * h * BF16 * 2 * 2
            cache_bytes += (cfg.num_layers - n_attn) * b * d * F32 * 2
        else:
            n_attn = cfg.num_layers
            attn = 2 * 2 * b * cache_len * cfg.num_heads * h * n_attn
            cache_bytes = n_attn * b * cache_len * cfg.num_kv_heads * h * BF16 * 2 * 2
            if cfg.cross_attn_every:
                n_cross = cfg.num_layers // cfg.cross_attn_every
                attn += 2 * 2 * b * cfg.num_vision_tokens * cfg.num_heads * h * n_cross
                cache_bytes += n_cross * b * cfg.num_vision_tokens \
                    * cfg.num_kv_heads * h * BF16 * 2
        flops = mm + attn
        hbm = n_total * BF16 + cache_bytes + b * cfg.vocab_size * BF16
        detail = {"matmul_flops": mm, "attn_flops": attn,
                  "cache_bytes": cache_bytes, "param_bytes": n_total * BF16}
    return CostModel(flops=flops, hbm_bytes=hbm, detail=detail)
