"""Resumable federated service driver: checkpoint, crash, resume.

Where ``fed_train`` is a batch run (build, run N rounds, exit),
``fed_serve`` treats the experiment as a long-running *service*: the
scheduler advances one phase node per event tick, the full experiment
state (scheduler window + in-flight rounds, server buffers and pending
reports, engine params/opt-state, every rng stream) is checkpointed every
``--ckpt-every`` rounds through ``repro.checkpoint`` (atomic write,
retention, corrupt-file fallback), and ``--resume`` picks up the latest
checkpoint after a crash.

The headline guarantee: kill the process at any phase boundary, resume
from the last checkpoint, and the completed round logs are bit-for-bit
identical to the uninterrupted run — on the loop, cohort and mesh-sharded
engines, in both sync and overlap round modes. (``--fixed-phase-costs``
additionally pins the simulated-timeline fields; without it they price at
measured wall-clock, which no checkpoint can replay.)

``--crash-after-phase NAME:K`` is the fault-injection hook the
kill-and-resume harness uses: the process SIGKILLs itself right after
executing node ``(NAME, K)`` — after any checkpoint due at that boundary
— so tests can place a crash at every phase boundary of a round::

    python -m repro.launch.fed_serve --rounds 2 --ckpt-dir /tmp/svc \
        --ckpt-every 1 --fixed-phase-costs --crash-after-phase aggregate:1
    python -m repro.launch.fed_serve --rounds 2 --ckpt-dir /tmp/svc \
        --ckpt-every 1 --fixed-phase-costs --resume --json svc.json

Each retired round logs ``served_model_age_s`` next to ``sim_finish_s``:
the simulated interval the *previous* model stayed the one a user query
would hit (the service's freshness metric; see ``core/protocol.RoundLog``).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import signal
from typing import List, Optional, Tuple

import jax

from repro.checkpoint import latest_step, restore_state, save_state
from repro.core.methods import get_method
from repro.core.protocol import RoundLog
from repro.fed import participation, scheduler as sched_mod, simulator
from repro.fed.scheduler import RoundScheduler
from repro.kernels import dispatch
from repro.launch.fed_train import (add_config_args, config_from_args,
                                    print_round)

# deterministic per-phase base costs (simulated seconds) for
# --fixed-phase-costs; same constants as benchmarks/async_rounds.py, so
# served freshness numbers line up with the async benchmark's timeline
FIXED_COSTS = {"local_train": 1.0, "report": 0.1, "aggregate": 0.3,
               "distill": 1.0, "eval": 0.0}

# retired-round history sidecar, next to the checkpoints: each retired
# RoundLog is appended here as one JSON line *before* the checkpoint is
# written, and checkpoints are taken with ``snapshot(logs_tail=0)`` — so
# checkpoint size stays flat over a long service instead of growing with
# the log history
LOGS_SIDECAR = "logs.jsonl"


def _trim_sidecar(path: str, completed: int,
                  tail_len: int) -> List[RoundLog]:
    """Reconcile the sidecar with a restored checkpoint.

    The sidecar is appended before each checkpoint, so after a crash it
    may hold entries for rounds the restored state has not retired yet —
    those are replayed and re-appended, so the file is truncated to the
    first ``completed`` lines. Returns the history *head*: the retired
    rounds the checkpoint no longer carries (``completed - tail_len``
    entries; zero for pre-sidecar checkpoints that kept every log)."""
    with open(path) as f:
        lines = [ln for ln in f.read().splitlines() if ln.strip()]
    keep = lines[:completed]
    if len(keep) != len(lines):
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            f.write("".join(ln + "\n" for ln in keep))
        os.replace(tmp, path)
    head = keep[:max(completed - tail_len, 0)]
    return [RoundLog(**json.loads(ln)) for ln in head]


def parse_crash_spec(spec: str) -> Optional[Tuple[str, int]]:
    """``"aggregate:1"`` → ``("aggregate", 1)``; empty → ``None``."""
    if not spec:
        return None
    try:
        name, k = spec.rsplit(":", 1)
        return (name, int(k))
    except ValueError:
        raise SystemExit(
            f"--crash-after-phase wants NAME:ROUND (e.g. aggregate:1), "
            f"got {spec!r}")


def build_scheduler(cfg, dataset: str, n_train: int, n_test: int,
                    fixed_costs: bool) -> RoundScheduler:
    """Build the experiment exactly like ``simulator.run`` would.

    Resume relies on this being deterministic in ``cfg``: datasets,
    partitions, model inits and DRE fits are rebuilt from the config, and
    the checkpoint only overlays mutable state on top."""
    participation.validate_config(cfg)
    sched_mod.validate_config(cfg)
    dispatch.resolve(cfg.kernel_backend)
    clients, server, x_test, y_test = simulator.build_experiment(
        cfg, dataset, n_train=n_train, n_test=n_test)
    engine = simulator.build_engine(clients, cfg)
    method = get_method(cfg.method)
    if method.client_filter != "none":
        engine.learn_dres(jax.random.PRNGKey(cfg.seed))
    return RoundScheduler(engine, server, method, cfg, x_test, y_test,
                          sim_phase_costs=FIXED_COSTS if fixed_costs
                          else None)


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Resumable federated service: event-loop scheduling "
                    "with periodic experiment checkpoints")
    add_config_args(ap)
    ap.add_argument("--ckpt-dir", default="",
                    help="checkpoint directory (empty = no checkpointing)")
    ap.add_argument("--ckpt-every", type=int, default=1,
                    help="checkpoint every N retired rounds (0 disables; "
                         "requires --ckpt-dir)")
    ap.add_argument("--keep-last", type=int, default=3,
                    help="retain only the newest K checkpoints "
                         "(0 = keep everything)")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the latest checkpoint in --ckpt-dir "
                         "(falls back to a fresh start when none exists)")
    ap.add_argument("--crash-after-phase", default="",
                    help="fault injection: SIGKILL the process right after "
                         "executing phase node NAME:ROUND (after any "
                         "checkpoint due at that boundary) — the "
                         "kill-and-resume harness hook")
    ap.add_argument("--fixed-phase-costs", action="store_true",
                    help="price the simulated timeline with fixed per-phase "
                         "costs instead of measured wall-clock, making sim "
                         "fields (sim_finish_s, served_model_age_s) "
                         "deterministic and resume bit-for-bit complete")
    ap.add_argument("--json", default="",
                    help="write the full round-log history here on exit")
    args = ap.parse_args(argv)
    cfg = config_from_args(args)
    crash_at = parse_crash_spec(args.crash_after_phase)
    ckpt_on = bool(args.ckpt_dir) and args.ckpt_every > 0
    keep_last = args.keep_last if args.keep_last > 0 else None

    sched = build_scheduler(cfg, args.dataset, args.n_train, args.n_test,
                            args.fixed_phase_costs)

    sidecar = (os.path.join(args.ckpt_dir, LOGS_SIDECAR) if ckpt_on
               else None)
    if sidecar is not None:
        # the sidecar is appended before the first checkpoint is written,
        # so the directory must exist already
        os.makedirs(args.ckpt_dir, exist_ok=True)
    resumed_from = None
    history: List[RoundLog] = []
    if args.resume and args.ckpt_dir:
        step = latest_step(args.ckpt_dir)
        if step is not None:
            sched.restore(restore_state(args.ckpt_dir, step))
            resumed_from = step
            if sidecar is not None and os.path.exists(sidecar):
                history = _trim_sidecar(sidecar, sched.completed,
                                        len(sched.logs))
            print(f"resumed from checkpoint step {step} "
                  f"({sched.completed} rounds already retired)")
    if resumed_from is None:
        sched.begin(0, cfg.rounds)
        if sidecar is not None and os.path.exists(sidecar):
            os.remove(sidecar)  # stale history from a previous service

    while sched.has_pending():
        phase, r, log = sched.step()
        if log is not None:
            print_round(log, cfg.num_clients)
            if sidecar is not None:
                # appended BEFORE the checkpoint: on crash the sidecar can
                # only run ahead of the restored state, and _trim_sidecar
                # truncates the overhang on resume
                with open(sidecar, "a") as f:
                    f.write(json.dumps(dataclasses.asdict(log)) + "\n")
            if ckpt_on and sched.completed % args.ckpt_every == 0:
                try:
                    path = save_state(args.ckpt_dir, sched.completed,
                                      sched.snapshot(logs_tail=0).to_tree(),
                                      keep_last=keep_last)
                    print(f"  checkpoint -> {path}")
                except OSError as e:
                    # the writer already retried with backoff; a service
                    # should keep serving on a transient storage outage
                    # and try again at the next boundary
                    print(f"  checkpoint FAILED after retries ({e!r}); "
                          f"continuing without", flush=True)
        if crash_at is not None and (phase, r) == crash_at:
            print(f"crash hook: SIGKILL after ({phase}, {r})", flush=True)
            os.kill(os.getpid(), signal.SIGKILL)

    logs = history + sched.logs
    if logs:
        mean_age = sum(l.served_model_age_s for l in logs) / len(logs)
        print(f"\nserved {len(logs)} rounds  final={logs[-1].mean_acc:.4f}"
              f"  best={max(l.mean_acc for l in logs):.4f}"
              f"  mean_model_age={mean_age:.2f}s")
    if args.json:
        with open(args.json, "w") as f:
            json.dump([dataclasses.asdict(l) for l in logs], f, indent=2)
    return logs


if __name__ == "__main__":
    main()
