"""Post-compile HLO analysis with while-loop trip-count correction.

XLA's ``cost_analysis``/text both count a ``while`` body ONCE, but our layer
stacks (and the SSM time loops) are ``lax.scan`` → while loops, so naive
collective sums undercount by the trip count. This walker:

  1. splits the optimized HLO module into computations,
  2. finds every while op and its (condition, body) computations,
  3. reads the trip count from the condition's comparison constant,
  4. sums collective result-bytes recursively, body × trip_count.

The result is the actual per-device, per-step collective traffic — the input
to the roofline's collective term.
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1}
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|pred)"
                       r"\[([0-9,]*)\]")
COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
              "collective-permute")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(")
_WHILE_RE = re.compile(r"\bwhile\(.*?condition=%?([\w.\-]+).*?body=%?([\w.\-]+)")
_WHILE_RE2 = re.compile(r"\bwhile\(.*?body=%?([\w.\-]+).*?condition=%?([\w.\-]+)")
_CONST_RE = re.compile(r"s(?:32|64)\[\]\s+constant\((\d+)\)")
_TRIP_RE = re.compile(r"known_trip_count[\"':{\s]+n[\"':\s]+(\d+)")
_OP_RE = re.compile(r"=\s*((?:\([^)]*\)|[\w\[\],{}\s]*?))\s*([\w\-]+)\(")


def _shape_bytes(segment: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(segment):
        dt, dims = m.groups()
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def split_computations(hlo: str) -> Dict[str, list]:
    comps: Dict[str, list] = {}
    cur = None
    for line in hlo.splitlines():
        if not line.startswith(" ") and line.rstrip().endswith("{"):
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps


def _line_op(line: str):
    """Returns (result_type_segment, op_name) or None."""
    ls = line.strip()
    if "=" not in ls:
        return None
    m = _OP_RE.search(ls)
    if not m:
        return None
    return m.group(1), m.group(2)


def _trip_count(cond_lines) -> int:
    consts = [int(m.group(1)) for line in cond_lines
              for m in _CONST_RE.finditer(line)]
    return max(consts) if consts else 1


def collective_bytes_corrected(hlo: str) -> dict:
    """Per-kind collective bytes with while-body trip multiplication."""
    comps = split_computations(hlo)

    # map body computation -> trip count; find whiles in every computation
    whiles = {}   # parent comp -> list[(cond, body, trip_or_None)]
    for name, lines in comps.items():
        for line in lines:
            m = _WHILE_RE.search(line)
            if m:
                cond, body = m.group(1), m.group(2)
            else:
                m2 = _WHILE_RE2.search(line)
                if not m2:
                    continue
                body, cond = m2.group(1), m2.group(2)
            tm = _TRIP_RE.search(line)
            trip = int(tm.group(1)) if tm else None
            whiles.setdefault(name, []).append((cond, body, trip))

    def comp_bytes(name: str, seen: frozenset) -> dict:
        if name not in comps or name in seen:
            return {k: 0 for k in COLL_KINDS} | {"count": 0}
        out = {k: 0 for k in COLL_KINDS}
        out["count"] = 0
        for line in comps[name]:
            op = _line_op(line)
            if op is None:
                continue
            seg, op_name = op
            for kind in COLL_KINDS:
                if op_name == kind or op_name.startswith(kind + "-start"):
                    out[kind] += _shape_bytes(seg)
                    out["count"] += 1
                    break
        for cond, body, trip in whiles.get(name, []):
            if trip is None:
                trip = _trip_count(comps.get(cond, []))
            inner = comp_bytes(body, seen | {name})
            for k in COLL_KINDS:
                out[k] += trip * inner[k]
            out["count"] += trip * inner["count"]
        return out

    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR.match(line.strip())
            if m:
                entry = m.group(1)
            break
    if entry is None:  # fall back: largest computation
        entry = max(comps, key=lambda k: len(comps[k]), default=None)
    return comp_bytes(entry, frozenset()) if entry else \
        {k: 0 for k in COLL_KINDS} | {"count": 0}


def while_trip_counts(hlo: str) -> list:
    """Diagnostic: [(body_name, trip_count), ...]."""
    comps = split_computations(hlo)
    out = []
    for name, lines in comps.items():
        for line in lines:
            m = _WHILE_RE.search(line) or _WHILE_RE2.search(line)
            if m:
                tm = _TRIP_RE.search(line)
                body = m.group(2) if m.re is _WHILE_RE else m.group(1)
                cond = m.group(1) if m.re is _WHILE_RE else m.group(2)
                trip = (int(tm.group(1)) if tm
                        else _trip_count(comps.get(cond, [])))
                out.append((body, trip))
    return out
