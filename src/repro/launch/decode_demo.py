"""Token-decode demo: batched prefill + decode with a KV/state cache.

``python -m repro.launch.decode_demo --arch xlstm-350m --reduced --tokens 32``

(Formerly ``repro.launch.serve``; that name now shims here, and the
federated service driver lives in ``repro.launch.fed_serve``.)
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced as reduce_cfg
from repro.models import transformer as T


def prefill_then_decode(cfg, params, prompt, cache_len: int, n_new: int,
                        *, window: int | None = None, greedy: bool = True,
                        key=None):
    """prompt: (B, S0) int32. Returns generated tokens (B, n_new)."""
    b, s0 = prompt.shape

    logits, _ = T.forward(params, cfg, prompt)
    cache = T.init_cache(cfg, b, cache_len, jnp.float32,
                         window_override=window, params=params)

    # replay the prompt through decode steps to fill the cache (keeps one
    # code path; a fused prefill-into-cache is the production variant)
    @jax.jit
    def step(tok, cache, pos):
        lg, cache = T.decode_step(params, cfg, tok, cache, pos,
                                  window_override=window)
        return lg, cache

    tok = None
    for t in range(s0):
        lg, cache = step(prompt[:, t:t + 1], cache, jnp.int32(t))
    out = []
    tok = jnp.argmax(lg[:, -1:], axis=-1).astype(jnp.int32)
    for i in range(n_new):
        out.append(tok)
        lg, cache = step(tok, cache, jnp.int32(s0 + i))
        tok = jnp.argmax(lg[:, -1:], axis=-1).astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-350m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=64)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    if cfg.family == "audio":
        raise SystemExit("encoder-only architecture: no decode path")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                      (args.batch, args.prompt_len)), jnp.int32)
    t0 = time.perf_counter()
    toks = prefill_then_decode(cfg, params, prompt, args.cache_len, args.tokens)
    dt = time.perf_counter() - t0
    n = args.batch * args.tokens
    print(f"generated {toks.shape} in {dt:.2f}s "
          f"({n/dt:.1f} tok/s batch-aggregate)")
    print(np.asarray(toks)[:, :12])
    return toks


if __name__ == "__main__":
    main()
