"""Federated-distillation driver — the paper's main experiment entry point.

``python -m repro.launch.fed_train --method edgefd --scenario strong \
      --dataset mnist_feat --rounds 10``

The argparse → ``FedConfig`` mapping lives in ``add_config_args`` /
``config_from_args`` so other drivers (``fed_serve``, the resumable
service) expose the identical experiment surface.
"""
from __future__ import annotations

import argparse
import json

from repro.common.types import FedConfig
from repro.core.methods import METHODS
from repro.fed import simulator

# short labels for the per-phase wall-clock breakdown (RoundLog.phase_s)
PHASE_ABBREV = {"local_train": "lt", "report": "rep", "aggregate": "agg",
                "server_distill": "sdist", "distill": "dist", "eval": "ev"}


def add_config_args(ap: argparse.ArgumentParser) -> None:
    """Install every experiment-defining flag (the ``FedConfig`` surface).

    Shared by ``fed_train`` and ``fed_serve`` so a service resumes the
    exact experiment a batch run would execute."""
    ap.add_argument("--method", default="edgefd", choices=sorted(METHODS))
    ap.add_argument("--scenario", default="strong",
                    choices=["strong", "weak", "iid"])
    ap.add_argument("--dataset", default="mnist_feat",
                    help="synthetic dataset (repro.data.synthetic.SPECS): "
                         "*_feat = flat features (MLP zoo), *_like = images "
                         "(CNN zoo), lm_tokens = int32 token sequences — "
                         "each client is a reduced granite transformer "
                         "(core/fd_trainer.py) distilling last-position "
                         "next-token logits, with flash-attention on the "
                         "hot path via --kernel-backend")
    ap.add_argument("--engine", default="loop", choices=["loop", "cohort"],
                    help="loop = per-client python loop; cohort = vmapped "
                         "homogeneous cohorts (fed/cohort.py)")
    ap.add_argument("--devices", type=int, default=0,
                    help="shard the cohort client axis over a 1-D device "
                         "mesh: 0 = unsharded, -1 = all jax devices, N = "
                         "exactly N (CPU hosts: set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N). "
                         "Requires --engine cohort")
    ap.add_argument("--model-shards", type=int, default=0,
                    help="fold the --devices mesh into a 2-D "
                         "(clients, model) mesh: each stacked client's "
                         "weight matrices additionally shard M-way over "
                         "the model axis (heads/ff/vocab dims — "
                         "repro.fed.mesh), so cohort members bigger than "
                         "one device can be federated. --devices must be "
                         "divisible by M. 0 = the 1-D client mesh "
                         "bit-for-bit (REPRO_MODEL_SHARDS can fill in). "
                         "Requires --engine cohort")
    ap.add_argument("--wave-size", type=int, default=0,
                    help="stream the cohort client axis through the device "
                         "in fixed-size waves (fed/cohort.py): peak device "
                         "memory is bounded by the wave, not the client "
                         "count. 0 = whole axis device-resident (the "
                         "historical path, bit-for-bit). Requires "
                         "--engine cohort; composes with --devices")
    ap.add_argument("--edge-aggregators", type=int, default=1,
                    help="two-tier hierarchical server (fed/server.py): E "
                         "edge aggregators each reduce a contiguous client "
                         "shard (filter + staleness bookkeeping local) and "
                         "the root fuses E partial sums — root work scales "
                         "with E, not the client count. 1 = flat legacy "
                         "server")
    ap.add_argument("--arrival-process", default="static",
                    choices=["static", "poisson", "bursty"],
                    help="trace-driven client arrivals on the simulated "
                         "timeline (repro.fed.clock): static = everyone at "
                         "phase start (legacy); poisson = iid exponential "
                         "delays (mean --arrival-spread s); bursty = "
                         "clients cluster into --arrival-bursts spikes "
                         "over --arrival-spread s. Deterministic in "
                         "(seed, round, client); pure accounting")
    ap.add_argument("--arrival-spread", type=float, default=0.0,
                    help="arrival-trace time scale in simulated seconds "
                         "(0 disables the trace)")
    ap.add_argument("--arrival-bursts", type=int, default=4,
                    help="bursty arrivals only: number of arrival spikes "
                         "per round (a client's burst is stable in "
                         "(seed, client) — think timezone waves)")
    ap.add_argument("--churn", type=float, default=0.0,
                    help="per-round whole-round churn probability: an "
                         "offline client skips the round entirely and "
                         "drains through the staleness machinery")
    ap.add_argument("--dropout", type=float, default=0.0,
                    help="mid-round dropout probability: a client trains "
                         "but vanishes before reporting — its fresh report "
                         "never reaches the server")
    ap.add_argument("--participation", type=float, default=1.0,
                    help="fraction of clients sampled each round "
                         "(participation_fraction; 1.0 = every client "
                         "reports every round, the paper's setting)")
    ap.add_argument("--policy", default="uniform",
                    choices=["uniform", "weighted", "roundrobin"],
                    help="how the per-round participant subset is drawn "
                         "(seeded from (seed, round)): uniform without "
                         "replacement, weighted by private-set size, or a "
                         "deterministic rotating block")
    ap.add_argument("--staleness-decay", type=float, default=0.0,
                    help="non-participants keep their last-reported proxy "
                         "logits, down-weighted by decay**age: 0 = drop "
                         "them silently, 1 = FedBuff-style full reuse")
    ap.add_argument("--round-mode", default="auto",
                    choices=["auto", "sync", "overlap"],
                    help="round scheduler (repro.fed.scheduler): sync = "
                         "lockstep Algorithm-1 phase order (bit-for-bit "
                         "the legacy logs); overlap = pipeline up to "
                         "--max-inflight rounds (round r+1 trains/reports "
                         "while round r aggregates/distills through the "
                         "staleness buffer); auto = sync unless "
                         "REPRO_ROUND_MODE says otherwise")
    ap.add_argument("--max-inflight", type=int, default=2,
                    help="overlap only: rounds concurrently in flight "
                         "(1 = lockstep)")
    ap.add_argument("--max-pending-reports", type=int, default=0,
                    help="admission/backpressure cap on client reports the "
                         "server holds in flight across pending rounds; "
                         "reports are admitted in simulated-arrival order "
                         "and overflow clients drain through the staleness "
                         "buffer like dropouts. 0 = unbounded (legacy)")
    ap.add_argument("--straggler-factor", type=float, default=4.0,
                    help="simulated straggler clock spread "
                         "(repro.fed.clock): per-client slowdowns drawn "
                         "deterministically from (seed, client) in "
                         "[1, factor]; 1.0 = homogeneous fleet. Pure "
                         "accounting for the sim=... column, never "
                         "changes numerics")
    ap.add_argument("--server-distill-epochs", type=int, default=0,
                    help="server-student epochs per ensemble-distillation "
                         "round (method server_distill only): the FedDF "
                         "central student usually takes many more steps "
                         "than client KD. 0 = same as distill epochs")
    ap.add_argument("--zoo", default="auto",
                    choices=["auto", "shared", "mixed"],
                    help="feature-mode model zoo (repro.fed.simulator): "
                         "shared = one MLP architecture for every client "
                         "(the historical population); mixed = three width "
                         "variants cycled over clients, giving three "
                         "architecture cohorts; auto = shared unless "
                         "REPRO_ZOO says otherwise. Image datasets are "
                         "always the ten-slot heterogeneous zoo")
    ap.add_argument("--concurrent-cohorts", action="store_true",
                    help="schedule per-cohort phase nodes "
                         "(repro.fed.scheduler): each architecture cohort "
                         "advances through its round phases independently, "
                         "so a fast cohort's round r+1 training overlaps a "
                         "slow cohort's round r reporting. Identical "
                         "numerics to the serial graph; changes only the "
                         "simulated timeline. Requires --engine cohort "
                         "(or any engine exposing cohort_positions)")
    ap.add_argument("--kernel-backend", default="auto",
                    choices=["auto", "pallas", "jnp"],
                    help="hot-path kernel dispatch (repro.kernels.dispatch): "
                         "auto = Pallas kernels on TPU, jnp reference "
                         "elsewhere (REPRO_KERNEL_BACKEND overrides); "
                         "pallas = force the kernels (interpret mode "
                         "off-TPU — validates the kernel path, not a CPU "
                         "speedup); jnp = force the reference code")
    ap.add_argument("--fault-mode", default="none",
                    choices=["none", "nan", "random_logits", "scaled",
                             "colluding_flip", "stale_replay"],
                    help="Byzantine/corruption fault trace "
                         "(repro.fed.faults): faulty clients train "
                         "honestly but corrupt the report they send — "
                         "deterministic in (seed, round, client), so every "
                         "engine injects identically. none = legacy "
                         "protocol, bit-for-bit")
    ap.add_argument("--fault-prob", type=float, default=0.0,
                    help="transient corruption: independent per-round coin "
                         "per client (flaky hardware, not an adversary)")
    ap.add_argument("--byzantine-frac", type=float, default=0.0,
                    help="fixed adversarial subset: round(frac*C) clients, "
                         "the same ones every round")
    ap.add_argument("--fault-start", type=int, default=0,
                    help="first round the fault trace is active")
    ap.add_argument("--fault-duration", type=int, default=0,
                    help="rounds the trace stays active (0 = unbounded); "
                         "start+duration stages a mid-run burst")
    ap.add_argument("--robust-aggregation", default="mean",
                    choices=["mean", "trimmed_mean", "median", "krum_row"],
                    help="teacher fusion over the client axis "
                         "(core/aggregation.py): mean = the paper's "
                         "staleness-weighted masked mean (legacy, "
                         "bit-for-bit); trimmed_mean/median/krum_row = "
                         "Byzantine-robust reducers (contributing clients "
                         "get one vote each; staleness weights act as a "
                         "contribute/exclude mask)")
    ap.add_argument("--trim-frac", type=float, default=0.2,
                    help="trimmed_mean only: fraction trimmed from each "
                         "tail of the per-position client distribution "
                         "(in [0, 0.5); beats f attackers when "
                         "floor(trim*n) >= f)")
    ap.add_argument("--no-sanitize", action="store_true",
                    help="disable the server's report sanitize pass "
                         "(non-finite rows scrubbed and accounted per "
                         "client before any fusion)")
    ap.add_argument("--quarantine-threshold", type=float, default=0.0,
                    help="EWMA trust score above which a client is "
                         "quarantined (sits out rounds, drains through the "
                         "staleness buffer; honest clients hover near 1). "
                         "0 = trust tracking off (legacy)")
    ap.add_argument("--quarantine-rounds", type=int, default=2,
                    help="base quarantine length; escalates linearly with "
                         "a client's strike count")
    ap.add_argument("--trust-ewma", type=float, default=0.5,
                    help="EWMA weight on the newest round's outlier "
                         "distance (in (0, 1]; 1 = no memory)")
    ap.add_argument("--watchdog", action="store_true",
                    help="divergence watchdog (repro.fed.scheduler): on a "
                         "sick RoundLog (non-finite metrics, accuracy "
                         "collapse, distill-loss spike) roll the experiment "
                         "back to the last healthy retirement and "
                         "quarantine the round's top outlier suspects "
                         "before the deterministic replay")
    ap.add_argument("--watchdog-acc-drop", type=float, default=0.2,
                    help="mean-accuracy drop vs the best healthy round "
                         "that trips the watchdog")
    ap.add_argument("--watchdog-loss-factor", type=float, default=10.0,
                    help="distill-loss multiple of the recent healthy "
                         "median that trips the watchdog")
    ap.add_argument("--watchdog-max-rollbacks", type=int, default=3,
                    help="rollback budget per run (spent budget = sick "
                         "rounds retire as-is)")
    ap.add_argument("--clients", type=int, default=10)
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--proxy-fraction", type=float, default=0.2)
    ap.add_argument("--proxy-batch", type=int, default=512)
    ap.add_argument("--threshold", type=float, default=-1.0,
                    help="<0 = per-client quantile calibration")
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--n-train", type=int, default=5000)
    ap.add_argument("--n-test", type=int, default=1000)
    ap.add_argument("--seed", type=int, default=0)


def config_from_args(args: argparse.Namespace) -> FedConfig:
    """Build the ``FedConfig`` from ``add_config_args`` output."""
    return FedConfig(
        num_clients=args.clients,
        rounds=args.rounds,
        method=args.method,
        scenario=args.scenario,
        proxy_fraction=args.proxy_fraction,
        proxy_batch=args.proxy_batch,
        id_threshold=None if args.threshold < 0 else args.threshold,
        lr=args.lr,
        seed=args.seed,
        engine=args.engine,
        num_devices=args.devices,
        model_shards=args.model_shards,
        wave_size=args.wave_size,
        num_edge_aggregators=args.edge_aggregators,
        arrival_process=args.arrival_process,
        arrival_spread=args.arrival_spread,
        arrival_bursts=args.arrival_bursts,
        churn_prob=args.churn,
        dropout_prob=args.dropout,
        participation_fraction=args.participation,
        participation_policy=args.policy,
        staleness_decay=args.staleness_decay,
        round_mode=args.round_mode,
        max_inflight=args.max_inflight,
        max_pending_reports=args.max_pending_reports,
        straggler_factor=args.straggler_factor,
        kernel_backend=args.kernel_backend,
        server_distill_epochs=args.server_distill_epochs,
        zoo=args.zoo,
        concurrent_cohorts=args.concurrent_cohorts,
        fault_mode=args.fault_mode,
        fault_prob=args.fault_prob,
        byzantine_frac=args.byzantine_frac,
        fault_start=args.fault_start,
        fault_duration=args.fault_duration,
        robust_aggregation=args.robust_aggregation,
        trim_frac=args.trim_frac,
        sanitize_reports=not args.no_sanitize,
        quarantine_threshold=args.quarantine_threshold,
        trust_ewma=args.trust_ewma,
        quarantine_rounds=args.quarantine_rounds,
        watchdog=args.watchdog,
        watchdog_acc_drop=args.watchdog_acc_drop,
        watchdog_loss_factor=args.watchdog_loss_factor,
        watchdog_max_rollbacks=args.watchdog_max_rollbacks,
    )


def print_round(log, num_clients: int) -> None:
    """One progress line per retired round (shared with ``fed_serve``)."""
    extra = ""
    if log.server_student_acc is not None:
        extra += f"  student={log.server_student_acc:.4f}"
    if log.participants is not None:
        extra += (f"  part={len(log.participants)}/{num_clients}"
                  f"  stale={log.mean_staleness:.2f}")
    if log.phase_s:
        breakdown = " ".join(
            f"{PHASE_ABBREV.get(k, k)}={v:.2f}"
            for k, v in log.phase_s.items())
        extra += (f"  sim={log.sim_finish_s:.2f}s"
                  f"  age={log.served_model_age_s:.2f}s  [{breakdown}]")
    print(f"round {log.round:3d}  acc={log.mean_acc:.4f}  "
          f"id={log.id_fraction:.2f}  local={log.local_loss:.3f}  "
          f"distill={log.distill_loss:.3f}  "
          f"up={log.bytes_up/1e6:.1f}MB{extra}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    add_config_args(ap)
    ap.add_argument("--json", default="")
    args = ap.parse_args(argv)
    cfg = config_from_args(args)

    def progress(log):
        print_round(log, args.clients)

    res = simulator.run(cfg, args.dataset, n_train=args.n_train,
                        n_test=args.n_test, progress=progress)
    print(f"\n{args.method} / {args.scenario} / {args.dataset}: "
          f"final={res.final_acc:.4f} best={res.best_acc:.4f}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"method": res.method, "scenario": res.scenario,
                       "final": res.final_acc, "best": res.best_acc,
                       "rounds": [vars(r) for r in res.rounds]}, f, indent=2)
    return res


if __name__ == "__main__":
    main()
