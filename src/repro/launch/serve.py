"""Deprecated import shim: the token-decode demo moved to
``repro.launch.decode_demo`` so the ``serve`` name can mean the federated
*service* driver (``repro.launch.fed_serve``). This module re-exports the
demo's public surface and stays runnable for old command lines."""
from repro.launch.decode_demo import main, prefill_then_decode  # noqa: F401

if __name__ == "__main__":
    main()
