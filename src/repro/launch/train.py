"""Training driver: ``python -m repro.launch.train --arch <id> [--reduced]``.

On real hardware this runs the pjit'd train step on the production mesh; on
this CPU container use ``--reduced`` (the smoke-scale config) — the same
code path end to end (config → model → data → optimizer → checkpoint).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import ARCHS, get_arch, reduced as reduce_cfg
from repro.data.tokens import MarkovTokenStream, synth_frames, synth_vision
from repro.launch import mesh as M
from repro.models import transformer as T
from repro.models.sharding import DEFAULT_RULES, set_logical_rules
from repro.optim.optimizers import adamw
from repro.optim.schedules import linear_warmup_cosine


def make_batch_fn(cfg, batch: int, seq: int, seed: int = 0):
    stream = MarkovTokenStream(cfg.vocab_size, seed=seed)
    rng = np.random.default_rng(seed)

    def next_batch():
        if cfg.family == "audio":
            frames = synth_frames(rng, batch, seq, cfg.frontend_stub_dim)
            labels = rng.integers(0, cfg.vocab_size, (batch, seq)).astype(np.int32)
            return {"frames": jnp.asarray(frames), "labels": jnp.asarray(labels)}
        b = stream.batch(batch, seq)
        out = {"tokens": jnp.asarray(b["tokens"]), "labels": jnp.asarray(b["labels"])}
        if cfg.family == "vlm":
            out["vision"] = jnp.asarray(
                synth_vision(rng, batch, cfg.num_vision_tokens, cfg.d_model))
        return out

    return next_batch


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    print(f"arch={cfg.name} params≈{cfg.param_count()/1e6:.1f}M "
          f"active≈{cfg.active_param_count()/1e6:.1f}M")

    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    opt = adamw(linear_warmup_cosine(args.lr, 10, args.steps))
    opt_state = opt.init(params)
    next_batch = make_batch_fn(cfg, args.batch, args.seq)

    @jax.jit
    def train_step(params, opt_state, batch):
        def loss_fn(p):
            return T.train_loss(p, cfg, batch, remat=args.remat)
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        upd, opt_state2 = opt.update(grads, opt_state, params)
        params2 = jax.tree.map(jnp.add, params, upd)
        return params2, opt_state2, loss

    t0 = time.perf_counter()
    losses = []
    for step in range(args.steps):
        params, opt_state, loss = train_step(params, opt_state, next_batch())
        losses.append(float(loss))
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.perf_counter() - t0
            print(f"step {step:5d} loss {losses[-1]:.4f} ({dt:.1f}s)")
    if args.ckpt_dir:
        p = save_checkpoint(args.ckpt_dir, args.steps, params)
        print("saved", p)
    assert losses[-1] < losses[0], "loss did not decrease"
    print(f"loss {losses[0]:.4f} -> {losses[-1]:.4f} over {args.steps} steps")
    return losses


if __name__ == "__main__":
    main()
