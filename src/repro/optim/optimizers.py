"""Pure-JAX pytree optimizers (optax-style (init, update) pairs).

State pytrees mirror the parameter pytree, so whatever sharding the params
carry propagates to optimizer state (and the ZeRO hillclimb can re-shard the
state independently via the launcher's spec rules).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable   # (grads, state, params) -> (updates, state)


def apply_updates(params, updates):
    return jax.tree.map(jnp.add, params, updates)


def clip_by_global_norm(grads, max_norm: float):
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), norm


def sgd(lr, momentum: float = 0.9, nesterov: bool = False):
    """lr: float or schedule fn(step)->float."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {"mu": jax.tree.map(jnp.zeros_like, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        step = state["step"] + 1
        lrv = lr_fn(step)
        mu = jax.tree.map(lambda m, g: momentum * m + g, state["mu"], grads)
        if nesterov:
            upd = jax.tree.map(lambda m, g: -(lrv) * (momentum * m + g), mu, grads)
        else:
            upd = jax.tree.map(lambda m: -(lrv) * m, mu)
        return upd, {"mu": mu, "step": step}

    return Optimizer(init, update)


def adamw(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0, state_dtype=None):
    """AdamW. ``state_dtype`` (e.g. bf16) halves optimizer memory —
    the beyond-paper memory lever used for llama3-405b (EXPERIMENTS.md §Perf).
    """
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def _cast(x):
        return x.astype(state_dtype) if state_dtype is not None else x

    def init(params):
        def z(p):
            return _cast(jnp.zeros_like(p, dtype=jnp.float32))
        return {"m": jax.tree.map(z, params),
                "v": jax.tree.map(z, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        lrv = lr_fn(step)
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m32 = m.astype(jnp.float32) * b1 + (1 - b1) * gf
            v32 = v.astype(jnp.float32) * b2 + (1 - b2) * jnp.square(gf)
            mh = m32 / bc1
            vh = v32 / bc2
            u = -(lrv) * (mh / (jnp.sqrt(vh) + eps)
                          + weight_decay * p.astype(jnp.float32))
            return u.astype(p.dtype), _cast(m32), _cast(v32)

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        # unzip the 3-tuples
        treedef = jax.tree.structure(grads)
        flat = treedef.flatten_up_to(out)
        us, ms, vs = zip(*flat)
        return (jax.tree.unflatten(treedef, us),
                {"m": jax.tree.unflatten(treedef, ms),
                 "v": jax.tree.unflatten(treedef, vs),
                 "step": step})

    return Optimizer(init, update)
