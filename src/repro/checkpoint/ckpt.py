"""Flat-pytree checkpointing to .npz with sharding-aware restore.

Leaves are addressed by '/'-joined pytree paths. On restore, arrays are
device_put with the provided shardings (pytree of NamedSharding or None),
so a checkpoint written on one mesh can be reloaded onto another — resharding
happens at restore time.
"""
from __future__ import annotations

import os
import re
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save_checkpoint(directory: str, step: int, tree) -> str:
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    tmp = path + ".tmp"
    np.savez(tmp, **_flatten(tree))
    os.replace(tmp + ".npz" if os.path.exists(tmp + ".npz") else tmp, path)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.match(r"ckpt_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, like_tree,
                       shardings=None) -> Any:
    """Restore into the structure of ``like_tree``; dtype/shape-checked."""
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    data = np.load(path)
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    shard_leaves = (jax.tree.leaves(shardings, is_leaf=lambda x: x is None)
                    if shardings is not None else [None] * len(leaves_p))
    out = []
    for (pth, like), sh in zip(leaves_p, shard_leaves):
        key = "/".join(_path_str(p) for p in pth)
        arr = data[key]
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(f"shape mismatch at {key}: ckpt {arr.shape} vs "
                             f"model {like.shape}")
        arr = arr.astype(like.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None else arr)
    return jax.tree_util.tree_unflatten(treedef, out)
