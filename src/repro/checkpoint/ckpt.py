"""Checkpointing: flat-pytree .npz + a nested-manifest experiment-state format.

Two layers share one on-disk container (a ``ckpt_<step:08d>.npz`` per step):

``save_checkpoint`` / ``restore_checkpoint``
    The array-pytree format: leaves are addressed by '/'-joined pytree
    paths, restore happens *into the structure of* a caller-supplied
    ``like_tree`` (dtype/shape-checked). On restore, arrays are
    ``device_put`` with the provided shardings (pytree of NamedSharding or
    None), so a checkpoint written on one mesh can be reloaded onto
    another — resharding happens at restore time.

``save_state`` / ``restore_state``
    The experiment-state format (``repro.fed.state.ExperimentState``):
    arbitrary nesting of dicts (string keys), lists, numpy/jax arrays and
    plain scalars — ints of any width (rng bit-generator words), floats,
    strs, bools, None. Arrays land as npz entries; everything else goes
    into an embedded JSON manifest that records the nesting, so restore
    needs no ``like_tree`` and returns plain dicts/lists.

Both writers go through one atomic path: write to a deterministic
``<final>.tmp.npz`` sibling (a name ``np.savez`` will not mangle — it only
appends ``.npz`` when missing), fsync the file *and* the directory, then
``os.replace`` onto the final name. A crash mid-write leaves only a
``*.tmp.npz`` orphan, which ``latest_step`` sweeps. ``keep_last=K``
retention prunes old steps after each successful save, and both restore
entry points can fall back step-by-step past a truncated/corrupt file
instead of taking the service down.
"""
from __future__ import annotations

import json
import os
import re
import time
import warnings
import zipfile
import zlib
from typing import Any, Dict, List, Optional

import jax
import numpy as np

# manifest marker for an array leaf (the value is the npz entry name);
# a dict key equal to this is reserved
_ARRAY_REF = "__npz__"
_MANIFEST_KEY = "__state_manifest__"

# errors that mean "this checkpoint file is unreadable" (truncated zip,
# torn write, bad CRC) — as opposed to structural errors like a shape
# mismatch, which always raise
_CORRUPT_ERRORS = (zipfile.BadZipFile, EOFError, OSError, zlib.error,
                   ValueError, KeyError)


def _ckpt_path(directory: str, step: int) -> str:
    return os.path.join(directory, f"ckpt_{step:08d}.npz")


def flatten_tree(tree) -> Dict[str, np.ndarray]:
    """Flatten a pytree to ``{'/'-joined path: np.ndarray}``."""
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


# historical (pre-export) name, kept for direct importers
_flatten = flatten_tree


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def unflatten_like(flat: Dict[str, np.ndarray], like_tree, shardings=None,
                   *, source: str = "ckpt") -> Any:
    """Rebuild ``like_tree``'s structure from a flat ``{path: array}`` dict.

    Shapes are checked against ``like_tree`` (a mismatch is a structural
    error and always raises); dtypes are cast to the like-leaf's. With
    ``shardings`` (pytree of NamedSharding or None, same structure) each
    leaf is ``device_put`` onto its sharding — resharding at restore time.
    """
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    shard_leaves = (jax.tree.leaves(shardings, is_leaf=lambda x: x is None)
                    if shardings is not None else [None] * len(leaves_p))
    out = []
    for (pth, like), sh in zip(leaves_p, shard_leaves):
        key = "/".join(_path_str(p) for p in pth)
        if key not in flat:
            raise KeyError(f"{source} is missing leaf {key!r}")
        arr = np.asarray(flat[key])
        if tuple(arr.shape) != tuple(np.shape(like)):
            raise ValueError(f"shape mismatch at {key}: {source} "
                             f"{arr.shape} vs model {np.shape(like)}")
        arr = arr.astype(np.asarray(like).dtype)
        out.append(jax.device_put(arr, sh) if sh is not None else arr)
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Atomic container I/O
# ---------------------------------------------------------------------------

# transient-OSError retry policy for checkpoint writes (ENOSPC racing a
# log rotation, EINTR, a flaky network mount): attempts = retries + 1,
# sleeping backoff * 2**attempt between them
_SAVE_RETRIES = 3
_SAVE_BACKOFF_S = 0.05


def _write_tmp(tmp: str, arrays: Dict[str, np.ndarray]) -> None:
    """One durable tmp-file write attempt (tests inject failures here)."""
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())


def _atomic_savez(path: str, arrays: Dict[str, np.ndarray], *,
                  retries: int = _SAVE_RETRIES,
                  backoff: float = _SAVE_BACKOFF_S) -> None:
    """Write ``arrays`` to ``path`` atomically and durably.

    The tmp name is deterministic and ends in ``.npz`` so ``np.savez``
    writes exactly where we point it (handed a *name* without the suffix
    it silently appends one — the historical bug left ``*.npz.tmp.npz``
    orphans and made the final ``os.replace`` a guess). fsync-before-
    rename plus a directory fsync makes the rename itself crash-durable.

    A transient ``OSError`` during the write/rename (ENOSPC while
    retention races, EINTR, flaky mounts) is retried with bounded
    exponential backoff; the final attempt re-raises so callers (the
    serving loop) can decide to warn-and-continue instead of dying.
    """
    tmp = path + ".tmp.npz"
    for attempt in range(retries + 1):
        try:
            _write_tmp(tmp, arrays)
            os.replace(tmp, path)
            break
        except OSError as e:
            try:
                os.remove(tmp)
            except OSError:
                pass  # never written, or swept elsewhere
            if attempt == retries:
                raise
            delay = backoff * (2 ** attempt)
            warnings.warn(
                f"checkpoint write {path} failed ({e!r}); "
                f"retry {attempt + 1}/{retries} in {delay:.2f}s")
            time.sleep(delay)
    try:
        dfd = os.open(os.path.dirname(path) or ".", os.O_RDONLY)
        try:
            os.fsync(dfd)
        finally:
            os.close(dfd)
    except OSError:
        pass  # e.g. a filesystem without directory fds; best effort


def _load_npz(path: str) -> Dict[str, np.ndarray]:
    """Fully materialize an npz (decompression errors surface here)."""
    with np.load(path, allow_pickle=False) as data:
        return {k: data[k] for k in data.files}


def checkpoint_steps(directory: str) -> List[int]:
    """All step numbers with a (non-temp) checkpoint file, ascending."""
    if not os.path.isdir(directory):
        return []
    return sorted(int(m.group(1)) for f in os.listdir(directory)
                  if (m := re.match(r"ckpt_(\d+)\.npz$", f)))


def _apply_retention(directory: str, keep_last: Optional[int]) -> None:
    if not keep_last or keep_last < 1:
        return
    for s in checkpoint_steps(directory)[:-keep_last]:
        try:
            os.remove(_ckpt_path(directory, s))
        except OSError:
            pass  # a concurrent sweep already got it


def latest_step(directory: str) -> Optional[int]:
    """Newest checkpointed step, sweeping stale ``*.tmp*`` orphans.

    A writer that died mid-save leaves a ``ckpt_*.tmp*`` sibling; those
    are never valid restore targets, so they are deleted here — the one
    place every resume path already calls.
    """
    if not os.path.isdir(directory):
        return None
    for f in os.listdir(directory):
        if f.startswith("ckpt_") and ".tmp" in f:
            try:
                os.remove(os.path.join(directory, f))
            except OSError:
                pass
    steps = checkpoint_steps(directory)
    return steps[-1] if steps else None


# ---------------------------------------------------------------------------
# Array-pytree checkpoints (restore into a like_tree)
# ---------------------------------------------------------------------------

def save_checkpoint(directory: str, step: int, tree, *,
                    keep_last: Optional[int] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    path = _ckpt_path(directory, step)
    _atomic_savez(path, flatten_tree(tree))
    _apply_retention(directory, keep_last)
    return path


def restore_checkpoint(directory: str, step: int, like_tree,
                       shardings=None, *, fallback: bool = False) -> Any:
    """Restore into the structure of ``like_tree``; dtype/shape-checked.

    ``fallback=True`` treats an unreadable file (truncated zip, torn
    write) as skippable: it warns and retries the previous step until one
    loads. Structural errors — a shape mismatch, a missing leaf — always
    raise: they mean the caller's model disagrees with the checkpoint,
    and silently reaching for an older step would mask a real bug.
    """
    flat, path = _read_with_fallback(directory, step, fallback)
    return unflatten_like(flat, like_tree, shardings, source=path)


def _read_with_fallback(directory: str, step: int, fallback: bool):
    candidates = [step]
    if fallback:
        candidates += [s for s in reversed(checkpoint_steps(directory))
                       if s < step]
    last_err: Optional[BaseException] = None
    for s in candidates:
        path = _ckpt_path(directory, s)
        try:
            return _load_npz(path), path
        except _CORRUPT_ERRORS as e:
            last_err = e
            if fallback:
                warnings.warn(
                    f"checkpoint {path} is unreadable ({e!r}); falling "
                    "back to the previous step")
    raise last_err if last_err is not None else FileNotFoundError(
        _ckpt_path(directory, step))


# ---------------------------------------------------------------------------
# Nested-manifest experiment state (no like_tree needed)
# ---------------------------------------------------------------------------

def save_state(directory: str, step: int, state, *,
               keep_last: Optional[int] = None) -> str:
    """Serialize arbitrarily nested experiment state to one npz.

    ``state`` may nest dicts (string keys), lists/tuples (restored as
    lists), numpy/jax arrays, and plain scalars — ints of any width (rng
    bit-generator words exceed 64 bits), floats, strs, bools, None.
    """
    arrays: Dict[str, np.ndarray] = {}

    def enc(obj, path):
        if isinstance(obj, (np.ndarray, jax.Array)):
            arrays[path] = np.asarray(obj)
            return {_ARRAY_REF: path}
        if isinstance(obj, np.generic):
            return obj.item()
        if isinstance(obj, dict):
            for k in obj:
                if not isinstance(k, str):
                    raise TypeError(
                        f"state dict keys must be str at {path!r}, got "
                        f"{k!r} — encode int/tuple keys as list entries")
                if k == _ARRAY_REF:
                    raise TypeError(f"dict key {_ARRAY_REF!r} is reserved "
                                    f"(at {path!r})")
            return {k: enc(v, f"{path}/{k}") for k, v in obj.items()}
        if isinstance(obj, (list, tuple)):
            return [enc(v, f"{path}/{i}") for i, v in enumerate(obj)]
        if obj is None or isinstance(obj, (bool, int, float, str)):
            return obj
        raise TypeError(
            f"unserializable state leaf at {path!r}: {type(obj).__name__}")

    manifest = enc(state, "state")
    arrays[_MANIFEST_KEY] = np.frombuffer(
        json.dumps(manifest).encode("utf-8"), np.uint8)
    os.makedirs(directory, exist_ok=True)
    path = _ckpt_path(directory, step)
    _atomic_savez(path, arrays)
    _apply_retention(directory, keep_last)
    return path


def restore_state(directory: str, step: Optional[int] = None, *,
                  fallback: bool = True) -> Any:
    """Load a ``save_state`` checkpoint back into plain dicts/lists.

    ``step=None`` picks ``latest_step``. With ``fallback`` (the default —
    this is the long-running service's restore path) an unreadable file
    warns and falls back to the previous step instead of crashing.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(
                f"no checkpoints under {directory!r}")
    arrays, path = _read_with_fallback(directory, step, fallback)
    if _MANIFEST_KEY not in arrays:
        raise KeyError(
            f"{path} has no state manifest — it is an array-pytree "
            "checkpoint; restore it with restore_checkpoint(like_tree)")
    manifest = json.loads(arrays[_MANIFEST_KEY].tobytes().decode("utf-8"))

    def dec(obj):
        if isinstance(obj, dict):
            if set(obj) == {_ARRAY_REF}:
                return arrays[obj[_ARRAY_REF]]
            return {k: dec(v) for k, v in obj.items()}
        if isinstance(obj, list):
            return [dec(v) for v in obj]
        return obj

    return dec(manifest)
