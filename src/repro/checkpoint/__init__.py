from repro.checkpoint.ckpt import (checkpoint_steps, flatten_tree,
                                   latest_step, restore_checkpoint,
                                   restore_state, save_checkpoint,
                                   save_state, unflatten_like)

__all__ = ["checkpoint_steps", "flatten_tree", "latest_step",
           "restore_checkpoint", "restore_state", "save_checkpoint",
           "save_state", "unflatten_like"]
