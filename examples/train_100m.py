"""End-to-end driver: train a ~100M-parameter dense LM for a few hundred
steps on synthetic Markov data, with checkpointing.

Full run:    PYTHONPATH=src python examples/train_100m.py
Demo (CPU):  PYTHONPATH=src python examples/train_100m.py --steps 30 --tiny
"""
import argparse
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import save_checkpoint
from repro.common.types import ArchConfig, AttentionKind
from repro.launch.train import make_batch_fn
from repro.models import transformer as T
from repro.optim.optimizers import adamw
from repro.optim.schedules import linear_warmup_cosine

CONFIG_100M = ArchConfig(
    name="repro-100m",
    family="dense",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=4,
    d_ff=2048,
    vocab_size=16384,
    attention=AttentionKind.FULL,
    source="this repo (quickstart-scale dense config)",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--tiny", action="store_true",
                    help="10M-param variant for CPU demos")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    cfg = CONFIG_100M
    if args.tiny:
        cfg = dataclasses.replace(cfg, num_layers=4, d_model=256, d_ff=1024,
                                  num_heads=4, num_kv_heads=2, head_dim=64,
                                  vocab_size=4096, name="repro-10m")
    print(f"{cfg.name}: {cfg.param_count()/1e6:.0f}M params")

    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw(linear_warmup_cosine(args.lr, 20, args.steps))
    opt_state = opt.init(params)
    next_batch = make_batch_fn(cfg, args.batch, args.seq)

    @jax.jit
    def train_step(params, opt_state, batch):
        (loss, _), grads = jax.value_and_grad(
            lambda p: T.train_loss(p, cfg, batch), has_aux=True)(params)
        upd, opt_state = opt.update(grads, opt_state, params)
        return jax.tree.map(jnp.add, params, upd), opt_state, loss

    t0 = time.perf_counter()
    first = None
    for step in range(args.steps):
        params, opt_state, loss = train_step(params, opt_state, next_batch())
        if first is None:
            first = float(loss)
        if step % 20 == 0 or step == args.steps - 1:
            tok_s = (step + 1) * args.batch * args.seq / (time.perf_counter() - t0)
            print(f"step {step:4d} loss {float(loss):.4f} ({tok_s:.0f} tok/s)")
    save_checkpoint(args.ckpt_dir, args.steps, params)
    print(f"loss {first:.3f} -> {float(loss):.3f}; ckpt in {args.ckpt_dir}")
    assert float(loss) < first, "training did not reduce loss"


if __name__ == "__main__":
    main()
