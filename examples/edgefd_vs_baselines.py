"""Scenario: reproduce the paper's headline comparison (Table III, strong
non-IID) — EdgeFD vs the unfiltered ensemble (FedMD) vs no collaboration.

Also prints the round-by-round ID fraction: the client filter admits
mostly own-class proxy samples, which is exactly why the aggregated
teacher stays clean under extreme heterogeneity.
"""
from repro.common.types import FedConfig
from repro.fed import simulator

ROUNDS = 6

for method in ("indlearn", "fedmd", "edgefd"):
    cfg = FedConfig(num_clients=5, rounds=ROUNDS, method=method,
                    scenario="strong", proxy_batch=300, lr=1e-2)
    res = simulator.run(cfg, "mnist_feat", n_train=2000, n_test=500)
    accs = " ".join(f"{r.mean_acc:.3f}" for r in res.rounds)
    idf = res.rounds[-1].id_fraction
    print(f"{method:10s} | accs: {accs} | final id_frac={idf:.2f}")

print("\nExpected ordering (paper Table III): edgefd > fedmd >> indlearn")
