"""Scenario: EdgeFD across TRANSFORMER clients — the paper's technique on
production-style backbones, now engine-backed.

The ``lm_tokens`` dataset makes each client a reduced granite-8b
(``core/fd_trainer.TransformerClientModel``): private shards are vocab-band
token sequences (the LM analogue of strong non-IID), the FD 'sample logit'
is the last-position next-token distribution, and attention runs through
``kernels.dispatch.flash_attention`` (set ``--kernel-backend pallas`` /
``REPRO_KERNEL_BACKEND=pallas`` for the fused kernel; interpret mode
off-TPU).

The same experiment scales past one device per client: with
``engine="cohort"``, ``num_devices=4``, ``model_shards=2`` the cohort runs
on a 2-D (clients, model) mesh — clients vmapped over the first axis, each
client's head/ff/vocab dims tensor-sharded over the second (repro.fed.mesh).
On a CPU host set XLA_FLAGS=--xla_force_host_platform_device_count=4 first.

Equivalent CLI:
  python -m repro.launch.fed_train --dataset lm_tokens --engine cohort \
      --devices 4 --model-shards 2 --clients 4 --rounds 3
"""
import jax

from repro.common.types import FedConfig
from repro.fed import simulator

N_DEVICES = jax.device_count()
cfg = FedConfig(
    num_clients=4, rounds=3, batch_size=16, proxy_batch=64, lr=1e-2, seed=0,
    engine="cohort",
    # 2-D mesh when the host exposes enough devices, else single-device
    num_devices=4 if N_DEVICES >= 4 else 0,
    model_shards=2 if N_DEVICES >= 4 else 0,
)

print(f"devices={N_DEVICES}  mesh="
      f"{'2x2 (clients x model)' if N_DEVICES >= 4 else 'unsharded'}")
res = simulator.run(cfg, "lm_tokens", n_train=400, n_test=200,
                    progress=lambda log: print(
                        f"round {log.round}: acc={log.mean_acc:.3f} "
                        f"id_frac={log.id_fraction:.2f} "
                        f"distill={log.distill_loss:.3f}"))

print(f"\nfinal={res.final_acc:.3f} best={res.best_acc:.3f}")
print("Each transformer client distilled only in-distribution proxy "
      "knowledge — the paper's protocol, vmapped over clients and "
      "tensor-sharded over model dims in one compiled phase.")
