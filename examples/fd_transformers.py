"""Scenario: EdgeFD across TRANSFORMER clients — the paper's technique as a
first-class trainer for the production backbones (core/fd_trainer.py).

Three reduced granite-8b clients hold disjoint vocab bands (the LM analogue
of strong non-IID). Each round: proxy logits → two-stage KMeans-DRE filter
on pooled embedding features → masked-mean teacher → CE + KL step.
Optionally privatizes the proxy tokens' feature space (core/privacy.py).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.core import fd_trainer as FD
from repro.core.kmeans import kmeans_fit, min_dist_to_centroids
from repro.models import transformer as T
from repro.optim.optimizers import sgd

cfg = reduced(get_arch("granite-8b"))
key = jax.random.PRNGKey(0)
N_CLIENTS, B, S, ROUNDS = 3, 4, 24, 3
opt = sgd(5e-3)

states, cents, thrs, batches = [], [], [], []
for c in range(N_CLIENTS):
    kc = jax.random.fold_in(key, c)
    params = T.init_params(cfg, kc)
    states.append((params, opt.init(params)))
    lo, hi = c * cfg.vocab_size // 3, (c + 1) * cfg.vocab_size // 3
    toks = jax.random.randint(kc, (B, S), lo, hi)
    batches.append({"tokens": toks, "labels": toks})
    feats = FD.proxy_features(params, cfg, toks)
    res = kmeans_fit(kc, feats, 1)
    cents.append(res.centroids)
    thrs.append(float(jnp.max(min_dist_to_centroids(feats, res.centroids))) * 1.5)

proxy = jnp.concatenate([b["tokens"][:1] for b in batches])
owner = jnp.arange(N_CLIENTS, dtype=jnp.int32)

for r in range(ROUNDS):
    states, metrics, id_frac = FD.fd_round_local(
        cfg, opt, states, batches, proxy, owner, cents, thrs)
    losses = " ".join(f"{float(m['loss']):.3f}" for m in metrics)
    print(f"round {r}: losses [{losses}]  id_frac={id_frac:.2f}")

print("\nEach client distilled only in-distribution proxy knowledge — "
      "the paper's protocol, running on transformer backbones.")
