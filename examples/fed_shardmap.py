"""Scenario: the MESH-COLLECTIVE federated-distillation round.

On a real pod, each client is a rank on the ``data`` mesh axis and the
server's masked-mean aggregation is ONE all-reduce (DESIGN.md §3) — no hub.
This example demonstrates that mode with 8 host devices standing in for 8
clients: every rank filters its own proxy logits with its private KMeans-DRE
centroids, then ``masked_mean_logits_psum`` fuses them in a single psum.

Must be launched as a script (device count is fixed at jax init):
    PYTHONPATH=src python examples/fed_shardmap.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax                              # noqa: E402
import jax.numpy as jnp                 # noqa: E402
import numpy as np                      # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402
from jax.experimental.shard_map import shard_map  # noqa: E402

from repro.core.aggregation import masked_mean_logits, masked_mean_logits_psum  # noqa: E402
from repro.core.kmeans import kmeans_fit, min_dist_to_centroids  # noqa: E402

C, T, K, DIM = 8, 64, 10, 16
mesh = jax.make_mesh((C,), ("clients",))
key = jax.random.PRNGKey(0)

# per-client private centroids (stacked), proxy logits, and a proxy batch
class_means = jax.random.normal(key, (C, DIM)) * 6.0
centroids = class_means[:, None, :]                       # (C, 1, DIM) 1-centroid DRE
proxy_x = jnp.concatenate([
    class_means[i] + jax.random.normal(jax.random.fold_in(key, i), (T // C, DIM))
    for i in range(C)])                                    # (T, DIM) mixed proxy
logits = jax.random.normal(jax.random.fold_in(key, 99), (C, T, K))
threshold = jnp.full((C,), 4.0)


def client_round(cents, thr, logits_local):
    """Runs ON EACH RANK: filter own logits, aggregate via one psum."""
    d = min_dist_to_centroids(proxy_x, cents[0])           # (T,)
    mask = d <= thr[0]
    teacher, valid = masked_mean_logits_psum(logits_local[0], mask[None][0],
                                             "clients")
    return teacher[None], valid[None], mask[None]


fn = shard_map(client_round, mesh=mesh,
               in_specs=(P("clients"), P("clients"), P("clients")),
               out_specs=(P("clients"), P("clients"), P("clients")))
teacher_sharded, valid, masks = fn(centroids, threshold, logits)

# reference: hub-and-spoke masked mean with the same masks
ref_teacher, ref_valid = masked_mean_logits(logits, masks)

np.testing.assert_allclose(np.asarray(teacher_sharded[0]),
                           np.asarray(ref_teacher), rtol=1e-5, atol=1e-6)
print(f"devices: {jax.device_count()} (one per client)")
print(f"ID fraction per client: {np.asarray(masks).mean(axis=1).round(2)}")
print(f"psum teacher == hub teacher ✓  (valid samples: "
      f"{int(np.asarray(ref_valid).sum())}/{T})")
