"""Quickstart: EdgeFD in ~40 lines using the public API.

Five clients, strong non-IID synthetic data, KMeans-DRE client filtering,
five federated-distillation rounds. Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.common.types import FedConfig
from repro.fed import simulator

cfg = FedConfig(
    num_clients=5,
    rounds=5,
    method="edgefd",          # try: fedmd, selective-fd, fkd, indlearn —
                              # or server_distill, which adds a FedDF-style
                              # server_distill phase training a central
                              # server student on the unlabeled proxy batch
                              # against the masked client ensemble
                              # (log.server_student_acc tracks it;
                              # server_distill_epochs sets its step budget)
    scenario="strong",        # strong | weak | iid
    proxy_fraction=0.2,       # alpha — share 20% of private data as proxy
    proxy_batch=300,          # |I_r| proxy samples per round
    id_threshold=None,        # None => per-client quantile calibration
    lr=1e-2,
    engine="cohort",          # vmapped clients; "loop" = same results, 1-by-1
    # Model zoo (repro.fed.simulator): "shared" gives every client the
    # same MLP (one cohort, the historical default); "mixed" cycles three
    # width variants over clients (cid % 3), so the cohort engine runs
    # three architecture cohorts — the system-heterogeneity regime. With
    # concurrent_cohorts=True the scheduler splits each client phase into
    # per-cohort nodes: a fast cohort's round r+1 training overlaps a slow
    # cohort's round r distill on the simulated clock, with numerics
    # identical to the serial graph (benchmarks/hetero_zoo.py measures
    # 1.33x simulated throughput on anti-correlated per-cohort costs).
    # The CLI spells it
    #   python -m repro.launch.fed_train --zoo mixed --concurrent-cohorts
    # "auto" = shared unless the REPRO_ZOO env var says otherwise.
    zoo="auto",
    concurrent_cohorts=False,
    # num_devices=-1 shards the cohort client axis over a 1-D device mesh
    # (all visible jax devices; 0 = unsharded). Same round logs, one
    # device-parallel call per phase. The CLI spells it
    #   python -m repro.launch.fed_train --engine cohort --devices -1
    # CPU-only hosts emulate an N-device host by setting
    # XLA_FLAGS=--xla_force_host_platform_device_count=N before jax loads.
    num_devices=0,
    # model_shards=M folds those devices into a 2-D (clients, model) mesh
    # of shape (num_devices // M, M): the cohort stays vmapped over the
    # client axis while each client's weight matrices (heads/ff/vocab
    # dims) shard M-way over the model axis — cohort members bigger than
    # one device can then be federated. 0 = the 1-D client mesh
    # bit-for-bit; $REPRO_MODEL_SHARDS fills in for 0. Pairs with the
    # transformer scenario (dataset "lm_tokens" — every client a reduced
    # granite backbone with flash-attention on the distill hot path; see
    # examples/fd_transformers.py). The CLI spells it
    #   python -m repro.launch.fed_train --dataset lm_tokens \
    #       --engine cohort --devices 4 --model-shards 2
    model_shards=0,
    # Fleet scale (see benchmarks/scale.py for a C=16384 round):
    # wave_size=N streams the cohort client axis through the device N
    # clients at a time — params/opt-state/data stay in host numpy and
    # peak device memory is bounded by the wave, not the client count
    # (0 = whole axis device-resident; any wave size reproduces it
    # bit-for-bit). num_edge_aggregators=E makes the server two-tier:
    # E edge aggregators each reduce a contiguous client shard (filter +
    # staleness bookkeeping local) and the root fuses E partial sums
    # (1 = the flat legacy server, same results either way). The CLI
    # spells it
    #   python -m repro.launch.fed_train --engine cohort \
    #       --wave-size 1024 --edge-aggregators 8
    wave_size=0,
    num_edge_aggregators=1,
    # Traffic realism (repro.fed.clock, all deterministic in (seed,
    # round, client)): arrival_process "poisson"/"bursty" delays client
    # arrivals on the simulated timeline (spread = time scale; bursty
    # clusters clients into arrival_bursts timezone-like spikes),
    # churn_prob knocks clients out for whole rounds (their last report
    # drains through the staleness machinery), dropout_prob loses
    # trained reports mid-round. The CLI spells it
    #   python -m repro.launch.fed_train --arrival-process bursty \
    #       --arrival-spread 30 --churn 0.05 --dropout 0.05
    arrival_process="static",
    arrival_spread=0.0,
    churn_prob=0.0,
    dropout_prob=0.0,
    # Edge clients drop in and out: participation_fraction=0.5 samples
    # half the clients each round (participation_policy: "uniform",
    # "weighted" by data size, or "roundrobin"), and staleness_decay
    # lets the server reuse a non-participant's last-reported logits at
    # weight decay**age (0 = drop them, 1 = full FedBuff-style reuse).
    # The CLI spells it
    #   python -m repro.launch.fed_train --participation 0.5 \
    #       --policy roundrobin --staleness-decay 0.5
    # The defaults below reproduce the paper's everyone-every-round runs.
    participation_fraction=1.0,
    participation_policy="uniform",
    staleness_decay=0.0,
    # Round scheduling (repro.fed.scheduler): "sync" runs the lockstep
    # Algorithm-1 phase order (local_train -> report -> aggregate ->
    # distill -> eval, one round at a time — bit-for-bit the paper runs);
    # "overlap" pipelines up to max_inflight rounds, so round r+1 trains
    # and reports while round r still aggregates/distills through the
    # staleness buffer — the straggler-bound async regime. The per-round
    # log carries a per-phase wall-clock breakdown (log.phase_s) and the
    # round's finish time on a simulated straggler clock
    # (log.sim_finish_s; per-client speeds in [1, straggler_factor] drawn
    # deterministically from (seed, client) — repro.fed.clock). The CLI
    # spells it
    #   python -m repro.launch.fed_train --round-mode overlap \
    #       --max-inflight 2 --straggler-factor 4.0
    # "auto" (the default) = sync unless REPRO_ROUND_MODE says otherwise.
    round_mode="auto",
    max_inflight=2,
    straggler_factor=4.0,
    # Report backpressure (repro.fed.server): max_pending_reports caps
    # how many client reports the server holds in flight across pending
    # rounds; reports are admitted in simulated-arrival order and
    # overflow clients drain through the staleness buffer like dropouts
    # (0 = unbounded, the legacy ingestion). The CLI spells it
    #   python -m repro.launch.fed_train --max-pending-reports 64
    max_pending_reports=0,
    # Robustness (repro.fed.faults + the server defense stack): inject
    # Byzantine clients with fault_mode ("nan", "random_logits",
    # "scaled", "colluding_flip", "stale_replay") over a fixed
    # adversarial subset (byzantine_frac) and/or per-round coins
    # (fault_prob) — deterministic in (seed, round, client), applied to
    # reports after honest local training. Defend with
    # robust_aggregation ("trimmed_mean"/"median"/"krum_row" replace
    # the mean over the client axis; trim_frac sets the trim window),
    # the default sanitize pass (sanitize_reports scrubs non-finite
    # rows; log.scrubbed_rows counts them), trust-based quarantine
    # (quarantine_threshold > 0 benches persistent outliers for
    # quarantine_rounds, escalating on repeat offenses), and the
    # divergence watchdog (watchdog=True rolls a poisoned round back to
    # the last healthy snapshot and quarantines the suspects;
    # log.rollbacks / log.quarantined record it). The CLI spells it
    #   python -m repro.launch.fed_train --fault-mode colluding_flip \
    #       --byzantine-frac 0.3 --robust-aggregation trimmed_mean \
    #       --trim-frac 0.45 --quarantine-threshold 2.0 --watchdog
    # The defaults below are the trusting legacy protocol, bit-for-bit.
    fault_mode="none",
    byzantine_frac=0.0,
    robust_aggregation="mean",
    sanitize_reports=True,
    quarantine_threshold=0.0,
    watchdog=False,
    # Hot-path kernels (repro.kernels.dispatch): "auto" runs the Pallas
    # TPU kernels (fused Lloyd fit, fused KD-KL fwd+bwd, tiled KuLSIF
    # gram) on TPU and the jnp reference elsewhere — on CPU this is
    # bit-for-bit the historical behavior. "pallas" forces the kernels
    # (interpret mode off-TPU: validates the kernel path, not a CPU
    # speedup); "jnp" forces the reference. The CLI spells it
    #   python -m repro.launch.fed_train --kernel-backend pallas
    kernel_backend="auto",
)

result = simulator.run(cfg, dataset_name="mnist_feat",
                       n_train=2000, n_test=500,
                       progress=lambda log: print(
                           f"round {log.round}: acc={log.mean_acc:.3f} "
                           f"id_frac={log.id_fraction:.2f}"))

print(f"\nEdgeFD final accuracy: {result.final_acc:.3f}")
print(f"bytes uploaded (ID logits only): {result.rounds[-1].bytes_up/1e6:.2f} MB")

# To run the same experiment as a long-running, crash-safe *service* —
# periodic atomic checkpoints of the full experiment state (scheduler
# in-flight rounds, staleness buffers, rng streams, engine params) with
# kill-and-resume that reproduces the uninterrupted logs bit-for-bit,
# plus a served-model freshness metric (log.served_model_age_s) — use
# the fed_serve driver (see `python -m repro.launch.fed_serve --help`):
#   python -m repro.launch.fed_serve --rounds 10 --ckpt-dir ckpts \
#       --ckpt-every 1 --fixed-phase-costs
#   python -m repro.launch.fed_serve --rounds 10 --ckpt-dir ckpts \
#       --ckpt-every 1 --fixed-phase-costs --resume
