"""Table IV: measured scaling exponents of both DREs vs the stated bounds.

Fits log-log slopes of learn/estimate wall time against each parameter
(n private samples, t test samples, c centroids) and checks them against
the complexity table: KuLSIF learn ∈ O(m³ + m²d + nmd), KMeans learn
O(k·n·c·d) (linear in n), estimate O(t·c·d) (linear in t).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks.common import emit, save_json, timeit
from repro.core.dre import KMeansDRE, KuLSIFDRE

D = 50


def _slope(xs, ys):
    xs, ys = np.log(np.asarray(xs, float)), np.log(np.maximum(ys, 1e-9))
    return float(np.polyfit(xs, ys, 1)[0])


def run(quick=False):
    key = jax.random.PRNGKey(0)
    ns = [256, 512, 1024] if quick else [256, 512, 1024, 2048]
    out = {}

    # KMeans learn vs n (expect slope ≈ 1)
    ts = []
    for n in ns:
        x = jax.random.normal(key, (n, D))
        km = KMeansDRE(num_centroids=4)
        ts.append(timeit(lambda: km.learn(jax.random.fold_in(key, 1), x).centroids,
                         iters=3))
    out["kmeans_learn_vs_n_slope"] = _slope(ns, ts)

    # KMeans estimate vs t (expect ≈ 1)
    x = jax.random.normal(key, (1024, D))
    km = KMeansDRE(num_centroids=4).learn(jax.random.fold_in(key, 1), x)
    tests = ns
    ts = []
    for t in tests:
        q = jax.random.normal(jax.random.fold_in(key, 2), (t, D))
        ts.append(timeit(lambda: km.distances(q), iters=3))
    out["kmeans_est_vs_t_slope"] = _slope(tests, ts)

    # KuLSIF learn vs m (aux samples; expect > 1.5 — m³ solve + m² kernel)
    ts = []
    for m in ns:
        ku = KuLSIFDRE(num_aux=m, sigma=3.0)
        ts.append(timeit(lambda: ku.learn(jax.random.fold_in(key, 3), x).alpha,
                         iters=3))
    out["kulsif_learn_vs_m_slope"] = _slope(ns, ts)

    # KuLSIF estimate vs t (expect ≈ 1, but with (n+m)·d constant ≫ c·d)
    ku = KuLSIFDRE(num_aux=1024, sigma=3.0).learn(jax.random.fold_in(key, 3), x)
    ts_ku, ts_km = [], []
    for t in tests:
        q = jax.random.normal(jax.random.fold_in(key, 4), (t, D))
        ts_ku.append(timeit(lambda: ku.estimate(q), iters=3))
        ts_km.append(timeit(lambda: km.distances(q), iters=3))
    out["kulsif_est_vs_t_slope"] = _slope(tests, ts_ku)
    out["est_time_ratio_kulsif_over_kmeans"] = float(np.mean(
        np.asarray(ts_ku) / np.asarray(ts_km)))

    for k, v in out.items():
        emit(f"table4/{k}", 0.0, f"{v:.2f}")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    out = run(quick=args.quick)
    save_json("table4_complexity.json", out)


if __name__ == "__main__":
    main()
