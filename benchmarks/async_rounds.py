"""Sync vs overlap round throughput under the simulated straggler clock.

The phase-graph scheduler (``repro.fed.scheduler``) prices every round
onto a simulated edge deployment (``repro.fed.clock``): clients run in
parallel at deterministic per-client speeds in ``[1, straggler_factor]``,
the server is one serial resource, and ``round_mode="sync"`` barriers
every phase while ``round_mode="overlap"`` pipelines up to
``max_inflight`` rounds. This benchmark runs the same experiment (loop
engine, partial participation so consecutive rounds draw different client
subsets) in both modes and compares:

  * simulated round throughput (rounds per simulated second — the number
    the straggler-bound deployment cares about), overall and steady-state
    (excluding the compile-heavy first round);
  * final accuracy, which must stay within tolerance of lockstep (overlap
    is a different protocol: round r+1 trains before round r's teacher
    lands).

    PYTHONPATH=src:. python benchmarks/async_rounds.py              # C=128
    PYTHONPATH=src:. python benchmarks/async_rounds.py --quick      # CI

Writes ``BENCH_async.json`` at the repo root per the BENCH convention;
``--parse FILE`` re-validates a result file (both modes present, overlap
throughput strictly above sync, accuracy delta within tolerance) and
exits non-zero on regression — CI's bench-smoke job runs the quick
benchmark and then this gate.
"""
from __future__ import annotations

import argparse
import json
import os
import time

ACC_TOL = 0.05          # |final_acc(overlap) - final_acc(sync)| gate
SAMPLES_PER_CLIENT = 64
MLP_HIDDEN = (64,)      # Table-I-scale edge models (see cohort_scaling.py)


# deterministic per-phase base costs for --fixed-costs pricing (seconds of
# nominal edge work per phase; eval is simulation-side measurement). CI's
# gate uses these so the sync/overlap ratio never depends on two noisy
# host-timing runs agreeing.
FIXED_COSTS = {"local_train": 1.0, "report": 0.1, "aggregate": 0.3,
               "distill": 1.0, "eval": 0.0}


def bench_mode(mode: str, *, clients: int, rounds: int, engine: str = "loop",
               fraction: float = 0.5, max_inflight: int = 2,
               straggler_factor: float = 4.0, seed: int = 0,
               fixed_costs: bool = False) -> dict:
    import jax

    from repro.common.types import FedConfig
    from repro.core.methods import get_method
    from repro.fed import simulator
    from repro.fed.scheduler import RoundScheduler

    cfg = FedConfig(num_clients=clients, rounds=rounds, method="edgefd",
                    scenario="iid", proxy_batch=256, batch_size=32,
                    lr=1e-2, seed=seed, engine=engine,
                    participation_fraction=fraction,
                    participation_policy="uniform", staleness_decay=0.5,
                    round_mode=mode, max_inflight=max_inflight,
                    straggler_factor=straggler_factor)
    built = simulator.build_experiment(
        cfg, "mnist_feat", n_train=SAMPLES_PER_CLIENT * clients, n_test=512,
        mlp_hidden=MLP_HIDDEN)
    clients_list, server, x_test, y_test = built
    eng = simulator.build_engine(clients_list, cfg)
    eng.learn_dres(jax.random.PRNGKey(cfg.seed))
    sched = RoundScheduler(
        eng, server, get_method(cfg.method), cfg, x_test, y_test,
        sim_phase_costs=FIXED_COSTS if fixed_costs else None)
    t0 = time.perf_counter()
    logs = sched.run_rounds(0, cfg.rounds)
    wall_total = time.perf_counter() - t0
    finishes = [log.sim_finish_s for log in logs]
    # makespan: overlap rounds need not retire in log order (a fast-subset
    # round can finish before an in-flight straggler round), so the last
    # log's finish is NOT the timeline's end
    sim_total = max(finishes)
    # steady state drops round 0 (jit warmup dominates its measured phase
    # costs identically in both modes, but the absolute number is noise)
    steady = ((rounds - 1) / (sim_total - finishes[0])
              if rounds > 1 and sim_total > finishes[0] else 0.0)
    return {"mode": mode, "engine": engine, "clients": clients,
            "rounds": rounds, "fraction": fraction,
            "max_inflight": max_inflight,
            "straggler_factor": straggler_factor,
            "fixed_costs": fixed_costs,
            "sim_total_s": sim_total,
            "sim_round_s": sim_total / rounds,
            "sim_throughput_rps": rounds / sim_total,
            "sim_steady_throughput_rps": steady,
            "wall_total_s": wall_total,
            "mean_staleness_last": logs[-1].mean_staleness,
            "final_acc": logs[-1].mean_acc}


def run_and_save(quick: bool = False, out: str | None = None,
                 clients: int | None = None, rounds: int | None = None,
                 max_inflight: int = 2,
                 fixed_costs: bool | None = None) -> list:
    clients = clients or (8 if quick else 128)
    rounds = rounds or (4 if quick else 10)
    if fixed_costs is None:
        # quick/CI runs price phases with the deterministic fixed-cost
        # model (two noisy host-timing runs agreeing is not a CI
        # invariant); the full dev-host run keeps measured pricing
        fixed_costs = quick
    rows = []
    print(f"{'mode':>8} {'C':>5} {'rounds':>7} {'sim_total_s':>12} "
          f"{'rps':>8} {'steady_rps':>11} {'final_acc':>10}")
    for mode in ("sync", "overlap"):
        row = bench_mode(mode, clients=clients, rounds=rounds,
                         max_inflight=max_inflight, fixed_costs=fixed_costs)
        rows.append(row)
        print(f"{mode:>8} {clients:>5} {rounds:>7} "
              f"{row['sim_total_s']:12.2f} "
              f"{row['sim_throughput_rps']:8.3f} "
              f"{row['sim_steady_throughput_rps']:11.3f} "
              f"{row['final_acc']:10.4f}")
    ratio = rows[1]["sim_throughput_rps"] / rows[0]["sim_throughput_rps"]
    print(f"overlap/sync simulated throughput: {ratio:.2f}x "
          f"(acc delta {rows[1]['final_acc'] - rows[0]['final_acc']:+.4f})")
    out = out or os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_async.json")
    with open(out, "w") as f:
        json.dump({"benchmark": "async_round_overlap",
                   "host_cpu_count": os.cpu_count(),
                   "acc_tol": ACC_TOL,
                   "note": "simulated deployment timeline "
                           "(repro.fed.clock): clients parallel at "
                           "deterministic straggler speeds, server "
                           "serial; overlap pipelines max_inflight "
                           "rounds so round r+1 trains while round r "
                           "aggregates/distills through the staleness "
                           "buffer",
                   "rows": rows}, f, indent=2)
    print(f"saved {out}")
    return rows


def parse_check(path: str) -> None:
    """Regression gate: both modes present, overlap strictly beats sync on
    simulated throughput, final accuracy within tolerance of lockstep."""
    with open(path) as f:
        data = json.load(f)
    rows = {r["mode"]: r for r in data["rows"]}
    if set(rows) != {"sync", "overlap"}:
        raise SystemExit(f"{path}: need one sync and one overlap row, got "
                         f"{sorted(rows)}")
    for r in rows.values():
        if not (r["sim_total_s"] > 0 and r["wall_total_s"] > 0):
            raise SystemExit(f"{path}: non-positive timing in {r}")
        if not 0.0 <= r["final_acc"] <= 1.0:
            raise SystemExit(f"{path}: final_acc out of [0, 1] in {r}")
    ratio = (rows["overlap"]["sim_throughput_rps"]
             / rows["sync"]["sim_throughput_rps"])
    if ratio <= 1.0:
        raise SystemExit(
            f"{path}: overlap must beat sync on simulated round "
            f"throughput, got {ratio:.3f}x")
    tol = data.get("acc_tol", ACC_TOL)
    delta = abs(rows["overlap"]["final_acc"] - rows["sync"]["final_acc"])
    if delta > tol:
        raise SystemExit(
            f"{path}: overlap final accuracy drifted {delta:.4f} from "
            f"lockstep (tolerance {tol})")
    print(f"{path}: OK — overlap {ratio:.2f}x sync throughput, "
          f"acc delta {delta:.4f} (tol {tol})")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI scale: C=8, 4 rounds (default C=128, 10)")
    ap.add_argument("--clients", type=int, default=None)
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--max-inflight", type=int, default=2)
    ap.add_argument("--fixed-costs", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="price phases with the deterministic FIXED_COSTS "
                         "model instead of measured host seconds "
                         "(default: on for --quick, off otherwise)")
    ap.add_argument("--out", default=None,
                    help="output path (default <repo>/BENCH_async.json)")
    ap.add_argument("--parse", default=None, metavar="FILE",
                    help="validate a previously written result file and "
                         "exit (CI regression gate)")
    args = ap.parse_args(argv)
    if args.parse:
        parse_check(args.parse)
        return []
    return run_and_save(quick=args.quick, out=args.out,
                        clients=args.clients, rounds=args.rounds,
                        max_inflight=args.max_inflight,
                        fixed_costs=args.fixed_costs)


if __name__ == "__main__":
    main()
