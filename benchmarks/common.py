"""Shared benchmark utilities: timing, CSV emission, result storage."""
from __future__ import annotations

import json
import os
import time
from typing import Callable

import jax

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall seconds per call (blocks on jax arrays)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")


def save_json(name: str, obj):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w") as f:
        json.dump(obj, f, indent=2, default=str)
    return path
