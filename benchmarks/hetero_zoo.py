"""Heterogeneous-zoo scheduling + FedDF ensemble server benchmark.

Two claims, one result file (``BENCH_hetero.json`` at the repo root):

* **Schedule** — on a mixed model zoo (three MLP width cohorts, cohort
  engine) with anti-correlated per-cohort phase costs (the wide cohort is
  slow to train, the narrow cohort slow to distill), per-cohort phase
  nodes (``concurrent_cohorts=True``) beat the serial phase graph on the
  simulated straggler clock: serial pays sum-over-phases of the slowest
  cohort (every phase barriers the fleet), concurrent pays roughly the
  slowest single cohort chain (cohorts only meet at aggregate). Both
  graphs run ``round_mode="sync"`` and produce bit-identical numerics, so
  the comparison is pure makespan.

* **Accuracy** — the FedDF-style ensemble server (``method=
  "server_distill"``) trains a central student on unlabeled proxy data
  against the masked/weighted client ensemble; its test accuracy must
  hold within ``ACC_TOL`` of the masked-mean fedmd baseline's mean client
  accuracy on the same mixed zoo.

    PYTHONPATH=src:. python benchmarks/hetero_zoo.py          # C=30
    PYTHONPATH=src:. python benchmarks/hetero_zoo.py --quick  # CI scale

``--parse FILE`` re-validates a result file (concurrent strictly beats
serial on simulated throughput, student accuracy within tolerance) and
exits non-zero on regression — CI's bench-smoke job runs the quick
benchmark and then this gate.
"""

from __future__ import annotations

import argparse
import json
import os
import time

ACC_TOL = 0.05  # student_acc >= fedmd_mean_client_acc - ACC_TOL gate
SAMPLES_PER_CLIENT = 64
MLP_HIDDEN = (32,)  # mixed zoo widens/narrows around this stack
SERVER_DISTILL_EPOCHS = 16  # FedDF central-student steps per round

# deterministic per-cohort phase costs (simulated seconds of edge work,
# divided across the cohort's parallel client lanes). Keys follow the
# scheduler's "phase@cohort" convention; cohorts are cid % 3 under the
# mixed zoo. Costs are anti-correlated on purpose: cohort 0 (configured
# width) is slow to train, cohort 2 (double width) slow to distill, so a
# serial graph pays max(train) + max(distill) while concurrent cohorts
# pay max(train + distill) per chain.
FIXED_COSTS = {
    "local_train@0": 3.0,
    "local_train@1": 1.0,
    "local_train@2": 0.5,
    "report@0": 0.1,
    "report@1": 0.1,
    "report@2": 0.1,
    "aggregate": 0.3,
    "distill@0": 0.5,
    "distill@1": 1.0,
    "distill@2": 3.0,
    "eval": 0.0,
}


def _config(clients, rounds, method, *, concurrent, seed=0):
    from repro.common.types import FedConfig

    return FedConfig(
        num_clients=clients,
        rounds=rounds,
        method=method,
        scenario="iid",
        proxy_batch=256,
        batch_size=32,
        lr=1e-2,
        seed=seed,
        engine="cohort",
        zoo="mixed",
        round_mode="sync",
        straggler_factor=1.0,
        concurrent_cohorts=concurrent,
        server_distill_epochs=SERVER_DISTILL_EPOCHS,
    )


def bench_schedule(*, clients: int, rounds: int, concurrent: bool, seed: int = 0) -> dict:
    """One mixed-zoo run priced on the simulated timeline; sync mode, so
    serial and concurrent produce identical numerics and the makespan is
    the only thing that moves."""
    import jax

    from repro.core.methods import get_method
    from repro.fed import simulator
    from repro.fed.scheduler import RoundScheduler

    cfg = _config(clients, rounds, "fedmd", concurrent=concurrent, seed=seed)
    clients_list, server, x_test, y_test = simulator.build_experiment(
        cfg,
        "mnist_feat",
        n_train=SAMPLES_PER_CLIENT * clients,
        n_test=512,
        mlp_hidden=MLP_HIDDEN,
    )
    eng = simulator.build_engine(clients_list, cfg)
    eng.learn_dres(jax.random.PRNGKey(cfg.seed))
    sched = RoundScheduler(
        eng,
        server,
        get_method(cfg.method),
        cfg,
        x_test,
        y_test,
        sim_phase_costs=FIXED_COSTS,
    )
    t0 = time.perf_counter()
    logs = sched.run_rounds(0, cfg.rounds)
    wall_total = time.perf_counter() - t0
    sim_total = max(log.sim_finish_s for log in logs)
    return {
        "graph": "concurrent" if concurrent else "serial",
        "clients": clients,
        "rounds": rounds,
        "cohorts": len(eng.cohort_positions()),
        "sim_total_s": sim_total,
        "sim_round_s": sim_total / rounds,
        "sim_throughput_rps": rounds / sim_total,
        "wall_total_s": wall_total,
        "final_acc": logs[-1].mean_acc,
    }


def bench_accuracy(*, clients: int, rounds: int, seed: int = 0) -> dict:
    """fedmd masked-mean baseline vs the FedDF ensemble-server student on
    the same mixed zoo and data."""
    from repro.fed import simulator

    n_train = SAMPLES_PER_CLIENT * clients
    base = simulator.run(
        _config(clients, rounds, "fedmd", concurrent=False, seed=seed),
        "mnist_feat",
        n_train=n_train,
        n_test=512,
    )
    dist = simulator.run(
        _config(clients, rounds, "server_distill", concurrent=False, seed=seed),
        "mnist_feat",
        n_train=n_train,
        n_test=512,
    )
    student = dist.rounds[-1].server_student_acc
    return {
        "clients": clients,
        "rounds": rounds,
        "baseline_acc": base.final_acc,
        "student_acc": student,
        "client_acc_under_server_distill": dist.final_acc,
    }


def run_and_save(quick: bool = False, out: str | None = None) -> dict:
    clients = 6 if quick else 30
    rounds = 3 if quick else 10
    rows = []
    print(f"{'graph':>11} {'C':>4} {'rounds':>7} {'sim_total_s':>12} {'rps':>8}")
    for concurrent in (False, True):
        row = bench_schedule(clients=clients, rounds=rounds, concurrent=concurrent)
        rows.append(row)
        print(
            f"{row['graph']:>11} {clients:>4} {rounds:>7} "
            f"{row['sim_total_s']:12.2f} {row['sim_throughput_rps']:8.3f}"
        )
    ratio = rows[1]["sim_throughput_rps"] / rows[0]["sim_throughput_rps"]
    print(f"concurrent/serial simulated throughput: {ratio:.2f}x")
    if rows[0]["final_acc"] != rows[1]["final_acc"]:
        raise SystemExit(
            "serial and concurrent sync runs must be numerically identical, "
            f"got {rows[0]['final_acc']} vs {rows[1]['final_acc']}"
        )
    acc = bench_accuracy(clients=clients, rounds=rounds)
    print(
        f"fedmd baseline acc={acc['baseline_acc']:.4f}  "
        f"FedDF student acc={acc['student_acc']:.4f}"
    )
    out = out or os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_hetero.json",
    )
    data = {
        "benchmark": "hetero_zoo",
        "host_cpu_count": os.cpu_count(),
        "acc_tol": ACC_TOL,
        "note": (
            "mixed MLP zoo (three width cohorts) on the simulated "
            "straggler clock: per-cohort phase nodes vs the serial phase "
            "graph under anti-correlated per-cohort costs, plus the FedDF "
            "ensemble-server student vs the masked-mean fedmd baseline"
        ),
        "fixed_costs": FIXED_COSTS,
        "schedule": rows,
        "accuracy": acc,
    }
    with open(out, "w") as f:
        json.dump(data, f, indent=2)
    print(f"saved {out}")
    return data


def parse_check(path: str) -> None:
    """Regression gate: concurrent strictly beats serial on simulated
    throughput with identical numerics, and the ensemble-server student
    holds within tolerance of the fedmd baseline."""
    with open(path) as f:
        data = json.load(f)
    rows = {r["graph"]: r for r in data["schedule"]}
    if set(rows) != {"serial", "concurrent"}:
        raise SystemExit(f"{path}: need one serial and one concurrent row, got {sorted(rows)}")
    for r in rows.values():
        if not (r["sim_total_s"] > 0 and r["wall_total_s"] > 0):
            raise SystemExit(f"{path}: non-positive timing in {r}")
        if not 0.0 <= r["final_acc"] <= 1.0:
            raise SystemExit(f"{path}: final_acc out of [0, 1] in {r}")
    if rows["serial"]["final_acc"] != rows["concurrent"]["final_acc"]:
        raise SystemExit(
            f"{path}: sync-mode serial and concurrent accs must match "
            f"bit-for-bit, got {rows['serial']['final_acc']} vs "
            f"{rows['concurrent']['final_acc']}"
        )
    ratio = rows["concurrent"]["sim_throughput_rps"] / rows["serial"]["sim_throughput_rps"]
    if ratio <= 1.0:
        raise SystemExit(
            f"{path}: concurrent cohorts must beat the serial graph on "
            f"simulated throughput, got {ratio:.3f}x"
        )
    acc = data["accuracy"]
    tol = data.get("acc_tol", ACC_TOL)
    if acc["student_acc"] is None:
        raise SystemExit(f"{path}: missing server_student_acc")
    if acc["student_acc"] < acc["baseline_acc"] - tol:
        raise SystemExit(
            f"{path}: FedDF student acc {acc['student_acc']:.4f} fell more "
            f"than {tol} below the fedmd baseline {acc['baseline_acc']:.4f}"
        )
    print(
        f"{path}: OK — concurrent {ratio:.2f}x serial throughput, student "
        f"{acc['student_acc']:.4f} vs baseline {acc['baseline_acc']:.4f} "
        f"(tol {tol})"
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--quick",
        action="store_true",
        help="CI scale: C=6, 3 rounds (default C=30, 10)",
    )
    ap.add_argument(
        "--out",
        default=None,
        help="output path (default <repo>/BENCH_hetero.json)",
    )
    ap.add_argument(
        "--parse",
        default=None,
        metavar="FILE",
        help="validate a previously written result file and exit (CI gate)",
    )
    args = ap.parse_args(argv)
    if args.parse:
        parse_check(args.parse)
        return {}
    return run_and_save(quick=args.quick, out=args.out)


if __name__ == "__main__":
    main()
