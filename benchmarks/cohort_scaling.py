"""Round wall-clock vs client count: loop engine vs cohort engine.

The loop engine pays a Python dispatch + host↔device transfer per client per
step (and a per-client jit compile at warmup); the cohort engine runs each
round phase as one vmapped call. This benchmark measures one federated round
(local train + proxy logits + filter + distill + eval) at C ∈ {8, 32, 128,
512} homogeneous MLP clients and reports the speedup.

    PYTHONPATH=src python benchmarks/cohort_scaling.py
    PYTHONPATH=src python benchmarks/cohort_scaling.py --clients 8 32 --rounds 2

Acceptance gate (ISSUE 1): cohort ≥ 5× lower per-round wall-clock at C=128.

Device-count sweep (ISSUE 2): ``--devices 1 2 4`` re-runs the cohort engine
at fixed C with the client axis mesh-sharded over N emulated host devices
(each count in a fresh subprocess — jax fixes the device count at init — via
``XLA_FLAGS=--xla_force_host_platform_device_count=N``) and records the
sweep to ``BENCH_cohort_mesh.json`` at the repo root:

    PYTHONPATH=src python benchmarks/cohort_scaling.py --devices 1 2 4

Wall-clock decreases while the device count stays within the host's
physical cores; oversubscribed counts plateau.

Participation sweep (ISSUE 3): ``--fractions 0.25 0.5 1.0`` re-runs both
engines at fixed C with ``participation_fraction`` swept, recording the
result to ``BENCH_participation.json`` at the repo root. The loop engine's
per-round wall-clock drops roughly linearly with the fraction (it skips
sampled-out clients outright); the cohort engine's compiled phases stay
cached across fractions and rounds (sampled-out clients are ``_where_tree``
no-op lanes — same shapes, zero retraces — so its already-small round time
stays flat while per-round upload bytes shrink with the fraction):

    PYTHONPATH=src python benchmarks/cohort_scaling.py --fractions 0.25 0.5 1.0

``--parse FILE`` validates a previously written result file (rows present,
both engines, sane times/accuracies) and exits non-zero on regression —
CI's bench-smoke job runs the tiny benchmark and then this gate.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import subprocess
import sys
import time

import numpy as np

from benchmarks.common import save_json
from repro.common.types import FedConfig
from repro.core.methods import get_method
from repro.core.protocol import run_round
from repro.fed import simulator

SAMPLES_PER_CLIENT = 64
# Table-I-scale edge models: the paper's clients are tiny (LeNet lineage);
# a small MLP keeps the benchmark in the dispatch-bound regime the cohort
# engine targets rather than saturating this host's matmul throughput.
MLP_HIDDEN = (64,)


def bench_engine(engine: str, num_clients: int, rounds: int,
                 seed: int = 0, num_devices: int = 0,
                 fraction: float = 1.0) -> dict:
    rounds = max(rounds, 1)  # at least one timed round after the warmup
    cfg = FedConfig(num_clients=num_clients, rounds=rounds, method="edgefd",
                    scenario="iid", proxy_batch=256, batch_size=32,
                    lr=1e-2, seed=seed, engine=engine,
                    num_devices=num_devices,
                    participation_fraction=fraction)
    clients, server, x_test, y_test = simulator.build_experiment(
        cfg, "mnist_feat", n_train=SAMPLES_PER_CLIENT * num_clients,
        n_test=512, mlp_hidden=MLP_HIDDEN)
    eng = simulator.build_engine(clients, cfg)
    method = get_method(cfg.method)

    t0 = time.perf_counter()
    import jax
    eng.learn_dres(jax.random.PRNGKey(cfg.seed))
    # warm up at full participation so *every* client's steps compile now:
    # otherwise a swept fraction < 1 pays first-touch compiles for late
    # sampled clients inside the timed rounds (loop engine jits per client)
    warm_cfg = dataclasses.replace(cfg, participation_fraction=1.0)
    run_round(0, eng, server, method, warm_cfg, x_test, y_test)
    warm_s = time.perf_counter() - t0

    times = []
    logs = []
    up0 = server.bytes_received
    for r in range(1, rounds + 1):
        log = run_round(r, eng, server, method, cfg, x_test, y_test)
        times.append(log.wall_s)
        logs.append(log)
    # per-phase wall-clock breakdown (median across timed rounds) — the
    # scheduler produces it for free; it shows where each engine's round
    # time actually goes (RoundLog.phase_s)
    phase_keys = sorted(set().union(*(log.phase_s for log in logs)))
    phase_s = {k: float(np.median([log.phase_s.get(k, 0.0) for log in logs]))
               for k in phase_keys}
    return {"engine": engine, "clients": num_clients,
            "devices": num_devices, "fraction": fraction,
            "warmup_s": warm_s, "round_s": float(np.median(times)),
            "phase_s": phase_s,
            "bytes_up_per_round": (server.bytes_received - up0) // rounds,
            "final_acc": log.mean_acc}


def device_sweep(devices, clients, rounds: int) -> list:
    """Re-run the mesh-sharded cohort engine once per (C, device count).

    Each device count runs in a fresh subprocess with
    ``--xla_force_host_platform_device_count`` set before jax init (the
    count is frozen at init, so one process cannot sweep it)."""
    bad = [d for d in devices if d < 1]
    if bad:
        raise SystemExit(
            f"--devices entries must be >= 1 (got {bad}); the sweep forces "
            "that many host devices per subprocess — devices=1 IS the "
            "unsharded-comparable baseline (a 1-device mesh)")
    rows = []
    print(f"{'C':>5} {'devices':>8} {'warmup_s':>9} {'round_s':>9} "
          f"{'speedup':>8}")
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for c in clients:
        base_s = None
        for d in devices:
            env = dict(os.environ)
            env["XLA_FLAGS"] = (
                env.get("XLA_FLAGS", "")
                + f" --xla_force_host_platform_device_count={d}")
            env.setdefault("JAX_PLATFORMS", "cpu")
            env["PYTHONPATH"] = os.pathsep.join(
                [root, os.path.join(root, "src"), env.get("PYTHONPATH", "")])
            res = subprocess.run(
                [sys.executable, os.path.abspath(__file__),
                 "--_forced-devices", str(d), "--clients", str(c),
                 "--rounds", str(rounds)],
                env=env, capture_output=True, text=True,
                timeout=900)  # a wedged child names its (C, d) cell loudly
            if res.returncode != 0:
                raise RuntimeError(
                    f"device sweep child (C={c}, devices={d}) failed:\n"
                    f"{res.stdout}\n{res.stderr}")
            row = next(json.loads(line[4:])
                       for line in res.stdout.splitlines()
                       if line.startswith("ROW "))
            rows.append(row)
            base_s = base_s if base_s is not None else row["round_s"]
            speed = f"{base_s / row['round_s']:7.2f}x" if base_s else ""
            print(f"{c:>5} {d:>8} {row['warmup_s']:9.2f} "
                  f"{row['round_s']:9.3f} {speed:>8}")
    return rows


def participation_sweep(fractions, clients, rounds: int) -> list:
    """Both engines at fixed C, participation_fraction swept in-process
    (the fraction changes data, never shapes — the cohort engine's jitted
    phases compile once at the first fraction and stay cached)."""
    rows = []
    print(f"{'C':>5} {'engine':>7} {'fraction':>9} {'warmup_s':>9} "
          f"{'round_s':>9} {'MB_up/rd':>9}")
    for c in clients:
        for engine in ("loop", "cohort"):
            for f in fractions:
                row = bench_engine(engine, c, rounds, fraction=f)
                rows.append(row)
                print(f"{c:>5} {engine:>7} {f:>9.2f} {row['warmup_s']:9.2f} "
                      f"{row['round_s']:9.3f} "
                      f"{row['bytes_up_per_round'] / 1e6:9.2f}")
    return rows


def parse_check(path: str) -> None:
    """Regression gate over a result file written by any mode of this
    benchmark: crash-shaped output (no rows, missing engines, nonsense
    times or accuracies) exits non-zero with a reason."""
    with open(path) as f:
        data = json.load(f)
    rows = data["rows"] if isinstance(data, dict) else data
    if not rows:
        raise SystemExit(f"{path}: no benchmark rows")
    engines = {r.get("engine") for r in rows}
    if "cohort" not in engines:
        raise SystemExit(f"{path}: cohort engine missing (got {engines})")
    for r in rows:
        if not (r.get("round_s", 0) > 0 and r.get("warmup_s", 0) > 0):
            raise SystemExit(f"{path}: non-positive timing in row {r}")
        acc = r.get("final_acc", 0.0)
        if not 0.0 <= acc <= 1.0:
            raise SystemExit(f"{path}: final_acc {acc} out of [0, 1] in {r}")
    print(f"{path}: {len(rows)} rows OK (engines: {sorted(engines)})")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, nargs="+", default=None)
    ap.add_argument("--rounds", type=int, default=1,
                    help="timed rounds per configuration (after 1 warmup)")
    ap.add_argument("--skip-loop-above", type=int, default=10_000,
                    help="skip the loop engine beyond this client count "
                         "(it is the slow thing being measured)")
    ap.add_argument("--devices", type=int, nargs="+", default=None,
                    help="mesh-device sweep mode: cohort engine at fixed C "
                         "(default 128), one emulated-host-device count per "
                         "subprocess; writes BENCH_cohort_mesh.json")
    ap.add_argument("--fractions", type=float, nargs="+", default=None,
                    help="participation sweep mode: both engines at fixed C "
                         "(default 128), participation_fraction swept; "
                         "writes BENCH_participation.json")
    ap.add_argument("--out", default=None,
                    help="output path override (default: results dir, or "
                         "<repo>/BENCH_*.json for the sweep modes)")
    ap.add_argument("--parse", default=None, metavar="FILE",
                    help="validate a previously written result file and "
                         "exit (CI regression gate)")
    ap.add_argument("--_forced-devices", type=int, default=0,
                    dest="forced_devices", help=argparse.SUPPRESS)
    args = ap.parse_args(argv)

    if args.parse:
        parse_check(args.parse)
        return []

    if args.forced_devices:
        # device-sweep child: this process was launched with the forced
        # host-device count already in XLA_FLAGS
        clients = (args.clients or [128])[0]
        row = bench_engine("cohort", clients, max(args.rounds, 3),
                           num_devices=args.forced_devices)
        print("ROW " + json.dumps(row))
        return [row]

    if args.devices is not None:
        clients = args.clients or [128]
        rows = device_sweep(args.devices, clients, max(args.rounds, 3))
        out = args.out or os.path.join(
            os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            "BENCH_cohort_mesh.json")
        with open(out, "w") as f:
            json.dump({"benchmark": "cohort_mesh_device_sweep",
                       "clients": clients,
                       "host_cpu_count": os.cpu_count(),
                       "note": "emulated host devices via XLA_FLAGS="
                               "--xla_force_host_platform_device_count; "
                               "wall-clock decreases while devices <= "
                               "physical cores",
                       "rows": rows}, f, indent=2)
        print(f"saved {out}")
        return rows

    if args.fractions is not None:
        clients = args.clients or [128]
        rows = participation_sweep(args.fractions, clients,
                                   max(args.rounds, 3))
        out = args.out or os.path.join(
            os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            "BENCH_participation.json")
        with open(out, "w") as f:
            json.dump({"benchmark": "participation_fraction_sweep",
                       "clients": clients,
                       "host_cpu_count": os.cpu_count(),
                       "note": "loop round time scales with the sampled "
                               "fraction (skipped clients cost nothing); "
                               "cohort phases stay compiled across "
                               "fractions (no-op lanes), so its round "
                               "time is flat while upload bytes shrink",
                       "rows": rows}, f, indent=2)
        print(f"saved {out}")
        return rows

    args.clients = args.clients or [8, 32, 128, 512]
    rows = []
    print(f"{'C':>5} {'engine':>7} {'warmup_s':>9} {'round_s':>9} {'speedup':>8}")
    for c in args.clients:
        loop_s = None
        for engine in ("loop", "cohort"):
            if engine == "loop" and c > args.skip_loop_above:
                print(f"{c:>5} {engine:>7} {'skipped':>9}")
                continue
            row = bench_engine(engine, c, args.rounds)
            rows.append(row)
            if engine == "loop":
                loop_s = row["round_s"]
                speed = ""
            else:
                speed = (f"{loop_s / row['round_s']:7.1f}x"
                         if loop_s else "")
            print(f"{c:>5} {engine:>7} {row['warmup_s']:9.2f} "
                  f"{row['round_s']:9.3f} {speed:>8}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"benchmark": "cohort_scaling", "rows": rows}, f,
                      indent=2)
        path = args.out
    else:
        path = save_json("cohort_scaling.json", rows)
    print(f"saved {path}")
    return rows


if __name__ == "__main__":
    main()
