"""Round wall-clock vs client count: loop engine vs cohort engine.

The loop engine pays a Python dispatch + host↔device transfer per client per
step (and a per-client jit compile at warmup); the cohort engine runs each
round phase as one vmapped call. This benchmark measures one federated round
(local train + proxy logits + filter + distill + eval) at C ∈ {8, 32, 128,
512} homogeneous MLP clients and reports the speedup.

    PYTHONPATH=src python benchmarks/cohort_scaling.py
    PYTHONPATH=src python benchmarks/cohort_scaling.py --clients 8 32 --rounds 2

Acceptance gate (ISSUE 1): cohort ≥ 5× lower per-round wall-clock at C=128.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import save_json
from repro.common.types import FedConfig
from repro.core.methods import get_method
from repro.core.protocol import run_round
from repro.fed import simulator

SAMPLES_PER_CLIENT = 64
# Table-I-scale edge models: the paper's clients are tiny (LeNet lineage);
# a small MLP keeps the benchmark in the dispatch-bound regime the cohort
# engine targets rather than saturating this host's matmul throughput.
MLP_HIDDEN = (64,)


def bench_engine(engine: str, num_clients: int, rounds: int,
                 seed: int = 0) -> dict:
    cfg = FedConfig(num_clients=num_clients, rounds=rounds, method="edgefd",
                    scenario="iid", proxy_batch=256, batch_size=32,
                    lr=1e-2, seed=seed, engine=engine)
    clients, server, x_test, y_test = simulator.build_experiment(
        cfg, "mnist_feat", n_train=SAMPLES_PER_CLIENT * num_clients,
        n_test=512, mlp_hidden=MLP_HIDDEN)
    eng = simulator.build_engine(clients, cfg)
    method = get_method(cfg.method)

    t0 = time.perf_counter()
    import jax
    eng.learn_dres(jax.random.PRNGKey(cfg.seed))
    run_round(0, eng, server, method, cfg, x_test, y_test)   # warmup+compile
    warm_s = time.perf_counter() - t0

    times = []
    for r in range(1, rounds + 1):
        log = run_round(r, eng, server, method, cfg, x_test, y_test)
        times.append(log.wall_s)
    return {"engine": engine, "clients": num_clients,
            "warmup_s": warm_s, "round_s": float(np.median(times)),
            "final_acc": log.mean_acc}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, nargs="+",
                    default=[8, 32, 128, 512])
    ap.add_argument("--rounds", type=int, default=1,
                    help="timed rounds per configuration (after 1 warmup)")
    ap.add_argument("--skip-loop-above", type=int, default=10_000,
                    help="skip the loop engine beyond this client count "
                         "(it is the slow thing being measured)")
    args = ap.parse_args(argv)

    rows = []
    print(f"{'C':>5} {'engine':>7} {'warmup_s':>9} {'round_s':>9} {'speedup':>8}")
    for c in args.clients:
        loop_s = None
        for engine in ("loop", "cohort"):
            if engine == "loop" and c > args.skip_loop_above:
                print(f"{c:>5} {engine:>7} {'skipped':>9}")
                continue
            row = bench_engine(engine, c, args.rounds)
            rows.append(row)
            if engine == "loop":
                loop_s = row["round_s"]
                speed = ""
            else:
                speed = (f"{loop_s / row['round_s']:7.1f}x"
                         if loop_s else "")
            print(f"{c:>5} {engine:>7} {row['warmup_s']:9.2f} "
                  f"{row['round_s']:9.3f} {speed:>8}")
    path = save_json("cohort_scaling.json", rows)
    print(f"saved {path}")
    return rows


if __name__ == "__main__":
    main()
