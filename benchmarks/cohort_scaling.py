"""Round wall-clock vs client count: loop engine vs cohort engine.

The loop engine pays a Python dispatch + host↔device transfer per client per
step (and a per-client jit compile at warmup); the cohort engine runs each
round phase as one vmapped call. This benchmark measures one federated round
(local train + proxy logits + filter + distill + eval) at C ∈ {8, 32, 128,
512} homogeneous MLP clients and reports the speedup.

    PYTHONPATH=src python benchmarks/cohort_scaling.py
    PYTHONPATH=src python benchmarks/cohort_scaling.py --clients 8 32 --rounds 2

Acceptance gate (ISSUE 1): cohort ≥ 5× lower per-round wall-clock at C=128.

Device-count sweep (ISSUE 2): ``--devices 1 2 4`` re-runs the cohort engine
at fixed C with the client axis mesh-sharded over N emulated host devices
(each count in a fresh subprocess — jax fixes the device count at init — via
``XLA_FLAGS=--xla_force_host_platform_device_count=N``) and records the
sweep to ``BENCH_cohort_mesh.json`` at the repo root:

    PYTHONPATH=src python benchmarks/cohort_scaling.py --devices 1 2 4

Wall-clock decreases while the device count stays within the host's
physical cores; oversubscribed counts plateau.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import numpy as np

from benchmarks.common import save_json
from repro.common.types import FedConfig
from repro.core.methods import get_method
from repro.core.protocol import run_round
from repro.fed import simulator

SAMPLES_PER_CLIENT = 64
# Table-I-scale edge models: the paper's clients are tiny (LeNet lineage);
# a small MLP keeps the benchmark in the dispatch-bound regime the cohort
# engine targets rather than saturating this host's matmul throughput.
MLP_HIDDEN = (64,)


def bench_engine(engine: str, num_clients: int, rounds: int,
                 seed: int = 0, num_devices: int = 0) -> dict:
    rounds = max(rounds, 1)  # at least one timed round after the warmup
    cfg = FedConfig(num_clients=num_clients, rounds=rounds, method="edgefd",
                    scenario="iid", proxy_batch=256, batch_size=32,
                    lr=1e-2, seed=seed, engine=engine,
                    num_devices=num_devices)
    clients, server, x_test, y_test = simulator.build_experiment(
        cfg, "mnist_feat", n_train=SAMPLES_PER_CLIENT * num_clients,
        n_test=512, mlp_hidden=MLP_HIDDEN)
    eng = simulator.build_engine(clients, cfg)
    method = get_method(cfg.method)

    t0 = time.perf_counter()
    import jax
    eng.learn_dres(jax.random.PRNGKey(cfg.seed))
    run_round(0, eng, server, method, cfg, x_test, y_test)   # warmup+compile
    warm_s = time.perf_counter() - t0

    times = []
    for r in range(1, rounds + 1):
        log = run_round(r, eng, server, method, cfg, x_test, y_test)
        times.append(log.wall_s)
    return {"engine": engine, "clients": num_clients,
            "devices": num_devices,
            "warmup_s": warm_s, "round_s": float(np.median(times)),
            "final_acc": log.mean_acc}


def device_sweep(devices, clients, rounds: int) -> list:
    """Re-run the mesh-sharded cohort engine once per (C, device count).

    Each device count runs in a fresh subprocess with
    ``--xla_force_host_platform_device_count`` set before jax init (the
    count is frozen at init, so one process cannot sweep it)."""
    bad = [d for d in devices if d < 1]
    if bad:
        raise SystemExit(
            f"--devices entries must be >= 1 (got {bad}); the sweep forces "
            "that many host devices per subprocess — devices=1 IS the "
            "unsharded-comparable baseline (a 1-device mesh)")
    rows = []
    print(f"{'C':>5} {'devices':>8} {'warmup_s':>9} {'round_s':>9} "
          f"{'speedup':>8}")
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for c in clients:
        base_s = None
        for d in devices:
            env = dict(os.environ)
            env["XLA_FLAGS"] = (
                env.get("XLA_FLAGS", "")
                + f" --xla_force_host_platform_device_count={d}")
            env.setdefault("JAX_PLATFORMS", "cpu")
            env["PYTHONPATH"] = os.pathsep.join(
                [root, os.path.join(root, "src"), env.get("PYTHONPATH", "")])
            res = subprocess.run(
                [sys.executable, os.path.abspath(__file__),
                 "--_forced-devices", str(d), "--clients", str(c),
                 "--rounds", str(rounds)],
                env=env, capture_output=True, text=True,
                timeout=900)  # a wedged child names its (C, d) cell loudly
            if res.returncode != 0:
                raise RuntimeError(
                    f"device sweep child (C={c}, devices={d}) failed:\n"
                    f"{res.stdout}\n{res.stderr}")
            row = next(json.loads(line[4:])
                       for line in res.stdout.splitlines()
                       if line.startswith("ROW "))
            rows.append(row)
            base_s = base_s if base_s is not None else row["round_s"]
            speed = f"{base_s / row['round_s']:7.2f}x" if base_s else ""
            print(f"{c:>5} {d:>8} {row['warmup_s']:9.2f} "
                  f"{row['round_s']:9.3f} {speed:>8}")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, nargs="+", default=None)
    ap.add_argument("--rounds", type=int, default=1,
                    help="timed rounds per configuration (after 1 warmup)")
    ap.add_argument("--skip-loop-above", type=int, default=10_000,
                    help="skip the loop engine beyond this client count "
                         "(it is the slow thing being measured)")
    ap.add_argument("--devices", type=int, nargs="+", default=None,
                    help="mesh-device sweep mode: cohort engine at fixed C "
                         "(default 128), one emulated-host-device count per "
                         "subprocess; writes BENCH_cohort_mesh.json")
    ap.add_argument("--out", default=None,
                    help="device-sweep output path (default: "
                         "<repo>/BENCH_cohort_mesh.json)")
    ap.add_argument("--_forced-devices", type=int, default=0,
                    dest="forced_devices", help=argparse.SUPPRESS)
    args = ap.parse_args(argv)

    if args.forced_devices:
        # device-sweep child: this process was launched with the forced
        # host-device count already in XLA_FLAGS
        clients = (args.clients or [128])[0]
        row = bench_engine("cohort", clients, max(args.rounds, 3),
                           num_devices=args.forced_devices)
        print("ROW " + json.dumps(row))
        return [row]

    if args.devices is not None:
        clients = args.clients or [128]
        rows = device_sweep(args.devices, clients, max(args.rounds, 3))
        out = args.out or os.path.join(
            os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            "BENCH_cohort_mesh.json")
        with open(out, "w") as f:
            json.dump({"benchmark": "cohort_mesh_device_sweep",
                       "clients": clients,
                       "host_cpu_count": os.cpu_count(),
                       "note": "emulated host devices via XLA_FLAGS="
                               "--xla_force_host_platform_device_count; "
                               "wall-clock decreases while devices <= "
                               "physical cores",
                       "rows": rows}, f, indent=2)
        print(f"saved {out}")
        return rows

    args.clients = args.clients or [8, 32, 128, 512]
    rows = []
    print(f"{'C':>5} {'engine':>7} {'warmup_s':>9} {'round_s':>9} {'speedup':>8}")
    for c in args.clients:
        loop_s = None
        for engine in ("loop", "cohort"):
            if engine == "loop" and c > args.skip_loop_above:
                print(f"{c:>5} {engine:>7} {'skipped':>9}")
                continue
            row = bench_engine(engine, c, args.rounds)
            rows.append(row)
            if engine == "loop":
                loop_s = row["round_s"]
                speed = ""
            else:
                speed = (f"{loop_s / row['round_s']:7.1f}x"
                         if loop_s else "")
            print(f"{c:>5} {engine:>7} {row['warmup_s']:9.2f} "
                  f"{row['round_s']:9.3f} {speed:>8}")
    path = save_json("cohort_scaling.json", rows)
    print(f"saved {path}")
    return rows


if __name__ == "__main__":
    main()
