"""Render the dry-run JSON into the EXPERIMENTS.md roofline table."""
from __future__ import annotations

import argparse
import json


def render(path: str, title: str) -> str:
    rs = json.load(open(path))
    lines = [f"### {title}", "",
             "| arch | shape | t_compute (s) | t_memory (s) | t_collective (s) "
             "| bottleneck | useful-FLOPs | peak+temp GB/dev | compile s | note |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rs:
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — | — "
                         f"| SKIP: {r['skipped']} |")
            continue
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — | — "
                         f"| ERROR: {r['error'][:60]} |")
            continue
        mem = ((r["memory"]["peak_bytes"] or 0) + (r["memory"]["temp_bytes"] or 0)) / 1e9
        note = f"window={r['window_override']}" if r.get("window_override") else ""
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.4f} "
            f"| {r['t_memory_s']:.4f} | {r['t_collective_s']:.4f} "
            f"| **{r['bottleneck']}** | {r['useful_flops_ratio']:.2f} "
            f"| {mem:.1f} | {r.get('compile_s','')} | {note} |")
    return "\n".join(lines)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("path")
    ap.add_argument("--title", default="Roofline")
    a = ap.parse_args()
    print(render(a.path, a.title))
