"""Benchmark registry — one entry per paper table/figure.

``python -m benchmarks.run``          quick pass (CI-scale, CPU-friendly)
``python -m benchmarks.run --full``   paper-scale sizes

Prints ``name,us_per_call,derived`` CSV rows as each benchmark emits them.
"""
from __future__ import annotations

import argparse
import os
import re
import time

# every repo-root BENCH_* artifact and the registry job that writes it.
# ``_check_writers_registered`` scans benchmarks/*.py for BENCH_*.json
# mentions and fails if a writer exists that no registry job covers — a
# new benchmark must be wired here in the same PR that adds it.
BENCH_WRITERS = {
    "BENCH_kernels.json": "kernels",
    "BENCH_async.json": "async",
    "BENCH_serve.json": "serve",
    "BENCH_hetero.json": "hetero",
    "BENCH_scale.json": "scale",
    "BENCH_cohort_mesh.json": "mesh",
    "BENCH_participation.json": "participation",
    "BENCH_robust.json": "robust",
    "BENCH_fdx.json": "fdx",
}


def _check_writers_registered(job_names) -> None:
    bench_dir = os.path.dirname(os.path.abspath(__file__))
    mentioned = set()
    for fn in sorted(os.listdir(bench_dir)):
        if not fn.endswith(".py"):
            continue
        with open(os.path.join(bench_dir, fn)) as f:
            mentioned |= set(re.findall(r"BENCH_\w+\.json", f.read()))
    unregistered = sorted(mentioned - set(BENCH_WRITERS))
    if unregistered:
        raise SystemExit(
            f"benchmarks write {unregistered} but no registry job covers "
            "them — add entries to BENCH_WRITERS and jobs in run.py")
    missing = sorted(j for j in BENCH_WRITERS.values()
                     if j not in job_names)
    if missing:
        raise SystemExit(
            f"BENCH_WRITERS names jobs {missing} that run.py does not "
            "define")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="",
                    help="comma list: table3,fig2,table4,fig5,kernels,"
                         "async,serve,hetero,scale,mesh,participation,"
                         "robust,fdx")
    args = ap.parse_args(argv)
    quick = not args.full
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (async_rounds, cohort_scaling, fd_transformer,
                            fig2_dre_cost, fig5_sweeps, hetero_zoo,
                            kernel_bench, robust_agg, scale, serve_resume,
                            table3_accuracy, table4_complexity)

    jobs = [
        # kernels records to the repo-root BENCH_kernels.json (micro +
        # wired-path sections, both kernel backends)
        ("kernels", lambda: kernel_bench.run_and_save(quick=quick)),
        # async records sync vs overlap round throughput under the
        # straggler clock to the repo-root BENCH_async.json
        ("async", lambda: async_rounds.run_and_save(quick=quick)),
        # serve records the resumable service's checkpoint overhead per
        # round + restore latency to the repo-root BENCH_serve.json
        ("serve", lambda: serve_resume.run_and_save(quick=quick)),
        # hetero records concurrent-cohort vs serial scheduling on the
        # mixed zoo + the FedDF ensemble-server student accuracy to the
        # repo-root BENCH_hetero.json
        ("hetero", lambda: hetero_zoo.run_and_save(quick=quick)),
        # scale records wave-streaming / two-tier memory-boundedness rows
        # to the repo-root BENCH_scale.json (per-row subprocesses)
        ("scale", lambda: scale.main(["--quick"] if quick else [])),
        # mesh records the emulated-device sweep of the sharded cohort
        # engine to the repo-root BENCH_cohort_mesh.json
        ("mesh", lambda: cohort_scaling.main(
            ["--devices", "1", "2"] if quick else
            ["--devices", "1", "2", "4", "8"])),
        # participation records the participation-fraction sweep on both
        # engines to the repo-root BENCH_participation.json
        ("participation", lambda: cohort_scaling.main(
            ["--fractions", "0.5", "1.0"] + (["--clients", "8"]
                                             if quick else []))),
        # fdx records the 2-D (clients, model) mesh shard sweep of the
        # transformer cohort — round wall-clock + peak per-device state
        # bytes vs model_shards — to the repo-root BENCH_fdx.json
        ("fdx", lambda: fd_transformer.main(
            ["--quick"] if quick else [])),
        # robust records mean-vs-robust-reducer accuracy under Byzantine
        # clients, compiled reducer overhead, and the watchdog
        # rollback-recovery row to the repo-root BENCH_robust.json
        ("robust", lambda: robust_agg.run_and_save(quick=quick)),
        ("fig2", lambda: fig2_dre_cost.run(
            sizes=(256, 512, 1024) if quick else (256, 512, 1024, 2048, 4096))),
        ("table4", lambda: table4_complexity.run(quick=quick)),
        ("table3", lambda: table3_accuracy.run(
            rounds=3 if quick else 6,
            clients=5 if quick else 10,
            n_train=1500 if quick else 4000,
            n_test=400 if quick else 800,
            methods=(["indlearn", "fedmd", "fkd", "selective-fd", "edgefd"]
                     if quick else table3_accuracy.METHODS),
            scenarios=(["strong", "iid"] if quick else
                       table3_accuracy.SCENARIOS))),
        ("fig5", lambda: (fig5_sweeps.threshold_sweep(
                              rounds=3 if quick else 5,
                              n_train=1500 if quick else 4000,
                              n_test=400 if quick else 800),
                          fig5_sweeps.proxy_sweep(
                              rounds=3 if quick else 5,
                              n_train=1500 if quick else 4000,
                              n_test=400 if quick else 800))),
    ]
    _check_writers_registered([name for name, _ in jobs])
    print("name,us_per_call,derived")
    for name, job in jobs:
        if only and name not in only:
            continue
        t0 = time.perf_counter()
        job()
        print(f"bench/{name}/total,{(time.perf_counter()-t0)*1e6:.0f},done",
              flush=True)


if __name__ == "__main__":
    main()
