"""Benchmark registry — one entry per paper table/figure.

``python -m benchmarks.run``          quick pass (CI-scale, CPU-friendly)
``python -m benchmarks.run --full``   paper-scale sizes

Prints ``name,us_per_call,derived`` CSV rows as each benchmark emits them.
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="",
                    help="comma list: table3,fig2,table4,fig5,kernels,"
                         "async,serve")
    args = ap.parse_args(argv)
    quick = not args.full
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (async_rounds, fig2_dre_cost, fig5_sweeps,
                            kernel_bench, serve_resume, table3_accuracy,
                            table4_complexity)

    jobs = [
        # kernels records to the repo-root BENCH_kernels.json (micro +
        # wired-path sections, both kernel backends)
        ("kernels", lambda: kernel_bench.run_and_save(quick=quick)),
        # async records sync vs overlap round throughput under the
        # straggler clock to the repo-root BENCH_async.json
        ("async", lambda: async_rounds.run_and_save(quick=quick)),
        # serve records the resumable service's checkpoint overhead per
        # round + restore latency to the repo-root BENCH_serve.json
        ("serve", lambda: serve_resume.run_and_save(quick=quick)),
        ("fig2", lambda: fig2_dre_cost.run(
            sizes=(256, 512, 1024) if quick else (256, 512, 1024, 2048, 4096))),
        ("table4", lambda: table4_complexity.run(quick=quick)),
        ("table3", lambda: table3_accuracy.run(
            rounds=3 if quick else 6,
            clients=5 if quick else 10,
            n_train=1500 if quick else 4000,
            n_test=400 if quick else 800,
            methods=(["indlearn", "fedmd", "fkd", "selective-fd", "edgefd"]
                     if quick else table3_accuracy.METHODS),
            scenarios=(["strong", "iid"] if quick else
                       table3_accuracy.SCENARIOS))),
        ("fig5", lambda: (fig5_sweeps.threshold_sweep(
                              rounds=3 if quick else 5,
                              n_train=1500 if quick else 4000,
                              n_test=400 if quick else 800),
                          fig5_sweeps.proxy_sweep(
                              rounds=3 if quick else 5,
                              n_train=1500 if quick else 4000,
                              n_test=400 if quick else 800))),
    ]
    print("name,us_per_call,derived")
    for name, job in jobs:
        if only and name not in only:
            continue
        t0 = time.perf_counter()
        job()
        print(f"bench/{name}/total,{(time.perf_counter()-t0)*1e6:.0f},done",
              flush=True)


if __name__ == "__main__":
    main()
