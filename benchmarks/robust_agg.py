"""Robust knowledge aggregation under Byzantine clients.

Three sections, one BENCH row set:

  * ``accuracy`` — final accuracy of mean vs trimmed_mean vs median under
    a colluding logit-flip attack at ``byzantine_frac`` in {0, 0.1, 0.3}
    (the strongest coordinated attack against an unweighted mean: every
    attacker pushes the fused teacher the same wrong way). The headline
    claim: at 30% adversaries the robust reducers land within 0.05 of the
    fault-free baseline while the plain mean collapses.
  * ``overhead`` — compiled-path cost of each robust reducer relative to
    the masked mean on a synthetic (C, t, K) stack (jit, steady-state).
  * ``watchdog`` — a mid-run ``nan`` burst with the sanitize pass
    disabled (the historical poison path): the divergence watchdog rolls
    the burst round back and quarantines the senders, vs the undefended
    service that never recovers.

    PYTHONPATH=src:. python benchmarks/robust_agg.py            # paper
    PYTHONPATH=src:. python benchmarks/robust_agg.py --quick    # CI

Writes ``BENCH_robust.json`` at the repo root per the BENCH convention;
``--parse FILE`` re-validates a result file and exits non-zero when the
robustness margins regress — CI's bench-smoke job runs the quick
benchmark then this gate.
"""
from __future__ import annotations

import argparse
import json
import os
import time

# within-0.05-of-baseline for the robust reducers; the mean must lose at
# least twice that margin for the attack to count as meaningful
ROBUST_ATOL = 0.05
MEAN_DEGRADE_MIN = 2 * ROBUST_ATOL
# trim_frac must exceed byzantine_frac per *surviving position count*:
# with claimed-ID masks only ~n_t <= C clients vote per proxy position,
# so floor(0.3 * n_t) can undershoot the attacker count — 0.45 keeps the
# trim window wide enough at every position while leaving survivors
TRIM_FRAC = 0.45
ATTACK = "colluding_flip"
FRACS = (0.0, 0.1, 0.3)
AGGS = ("mean", "trimmed_mean", "median")


def _cfg(**kw):
    from repro.common.types import FedConfig
    base = dict(num_clients=10, rounds=6, method="edgefd", scenario="iid",
                proxy_batch=96, batch_size=32, lr=1e-2, seed=0)
    base.update(kw)
    return FedConfig(**base)


def _final_acc(cfg, *, n_train=600, n_test=250):
    from repro.fed import simulator
    res = simulator.run(cfg, "mnist_feat", n_train=n_train, n_test=n_test)
    return res


def accuracy_rows(quick: bool) -> list:
    fracs = (0.0, 0.3) if quick else FRACS
    rows = []
    for frac in fracs:
        for agg in AGGS:
            cfg = _cfg(fault_mode=ATTACK if frac > 0 else "none",
                       byzantine_frac=frac, robust_aggregation=agg,
                       trim_frac=TRIM_FRAC)
            res = _final_acc(cfg)
            row = {"section": "accuracy", "attack": ATTACK,
                   "byzantine_frac": frac, "robust_aggregation": agg,
                   "trim_frac": TRIM_FRAC if agg == "trimmed_mean" else None,
                   "final_acc": res.final_acc,
                   "scrubbed_rows": sum(r.scrubbed_rows for r in res.rounds)}
            rows.append(row)
            print(f"accuracy byz={frac:.1f} agg={agg:<12s} "
                  f"final={res.final_acc:.4f}", flush=True)
    return rows


def overhead_rows(quick: bool) -> list:
    """Steady-state compiled cost of each reducer on a synthetic stack."""
    import jax
    import numpy as np

    from repro.core import aggregation

    c, t, k = (32, 256, 10) if quick else (64, 512, 10)
    reps = 20 if quick else 50
    rng = np.random.default_rng(0)
    logits = rng.normal(size=(c, t, k)).astype(np.float32)
    mask = rng.random((c, t)) < 0.8
    rows, mean_us = [], None
    for mode in ("mean", "trimmed_mean", "median", "krum_row"):
        fn = jax.jit(lambda lo, m, mode=mode: aggregation.robust_reduce(
            lo, m, mode, trim_frac=TRIM_FRAC))
        out = fn(logits, mask)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn(logits, mask)
        jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / reps * 1e6
        if mode == "mean":
            mean_us = us
        rows.append({"section": "overhead", "mode": mode,
                     "shape": [c, t, k], "us_per_call": us,
                     "rel_to_mean": us / mean_us})
        print(f"overhead {mode:<12s} {us:9.1f}us/call "
              f"({us / mean_us:.2f}x mean)", flush=True)
    return rows


def watchdog_row(quick: bool) -> dict:
    """Mid-run nan burst, sanitize off: watchdog vs no defense at all."""
    rounds = 4 if quick else 6
    base = dict(num_clients=6, rounds=rounds, scenario="strong",
                sanitize_reports=False)
    burst = dict(fault_mode="nan", byzantine_frac=0.34, fault_start=2,
                 fault_duration=1)
    clean = _final_acc(_cfg(**base))
    broken = _final_acc(_cfg(**base, **burst))
    guarded = _final_acc(_cfg(**base, **burst, watchdog=True))
    row = {"section": "watchdog", "attack": "nan_burst",
           "burst_round": 2, "byzantine_frac": 0.34,
           "fault_free_acc": clean.final_acc,
           "no_watchdog_acc": broken.final_acc,
           "watchdog_acc": guarded.final_acc,
           "rollbacks": guarded.rounds[-1].rollbacks,
           "quarantined": sorted({c for r in guarded.rounds
                                  for c in (r.quarantined or [])})}
    print(f"watchdog fault-free={clean.final_acc:.4f} "
          f"undefended={broken.final_acc:.4f} "
          f"watchdog={guarded.final_acc:.4f} "
          f"rollbacks={row['rollbacks']}", flush=True)
    return row


def run_and_save(quick: bool = False, out: str | None = None) -> list:
    rows = accuracy_rows(quick) + overhead_rows(quick) + [watchdog_row(quick)]
    out = out or os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_robust.json")
    with open(out, "w") as f:
        json.dump({"benchmark": "robust_aggregation",
                   "host_cpu_count": os.cpu_count(),
                   "robust_atol": ROBUST_ATOL,
                   "mean_degrade_min": MEAN_DEGRADE_MIN,
                   "note": "final accuracy under a colluding logit-flip "
                           "attack (mean vs robust reducers), compiled "
                           "reducer overhead, and the divergence "
                           "watchdog's rollback-and-recover vs an "
                           "undefended service under a mid-run nan burst",
                   "rows": rows}, f, indent=2)
    print(f"saved {out}")
    return rows


def parse_check(path: str) -> None:
    """Regression gate on the robustness margins."""
    with open(path) as f:
        data = json.load(f)
    rows = data.get("rows", [])
    atol = data.get("robust_atol", ROBUST_ATOL)
    degrade = data.get("mean_degrade_min", MEAN_DEGRADE_MIN)

    def acc(frac, agg):
        for r in rows:
            if (r.get("section") == "accuracy"
                    and r["byzantine_frac"] == frac
                    and r["robust_aggregation"] == agg):
                return r["final_acc"]
        raise SystemExit(f"{path}: missing accuracy row "
                         f"byz={frac} agg={agg}")

    baseline = acc(0.0, "mean")
    mean_03 = acc(0.3, "mean")
    if mean_03 > baseline - degrade:
        raise SystemExit(
            f"{path}: plain mean only fell {baseline - mean_03:.3f} under "
            f"30% colluding attackers (need >= {degrade}) — the attack is "
            "too weak to certify the robust reducers against")
    for agg in ("trimmed_mean", "median"):
        a = acc(0.3, agg)
        if a < baseline - atol:
            raise SystemExit(
                f"{path}: {agg} recovered only {a:.3f} vs fault-free "
                f"{baseline:.3f} at byzantine_frac=0.3 (gate: within "
                f"{atol})")
        if acc(0.0, agg) < baseline - atol:
            raise SystemExit(
                f"{path}: {agg} costs more than {atol} accuracy even "
                "with zero attackers")

    over = {r["mode"]: r for r in rows if r.get("section") == "overhead"}
    for mode in ("mean", "trimmed_mean", "median", "krum_row"):
        if mode not in over or over[mode]["us_per_call"] <= 0:
            raise SystemExit(f"{path}: missing/degenerate overhead row "
                             f"for {mode}")

    wd = next((r for r in rows if r.get("section") == "watchdog"), None)
    if wd is None:
        raise SystemExit(f"{path}: missing watchdog row")
    if wd["rollbacks"] < 1 or not wd["quarantined"]:
        raise SystemExit(f"{path}: watchdog never rolled back / "
                         f"quarantined nobody: {wd}")
    if wd["watchdog_acc"] < wd["no_watchdog_acc"] + atol:
        raise SystemExit(
            f"{path}: watchdog ({wd['watchdog_acc']:.3f}) does not beat "
            f"the undefended run ({wd['no_watchdog_acc']:.3f}) by {atol}")

    print(f"{path}: OK — baseline={baseline:.3f}, mean@0.3={mean_03:.3f}, "
          f"trimmed@0.3={acc(0.3, 'trimmed_mean'):.3f}, "
          f"median@0.3={acc(0.3, 'median'):.3f}, "
          f"watchdog {wd['no_watchdog_acc']:.3f}->{wd['watchdog_acc']:.3f} "
          f"({wd['rollbacks']} rollbacks)")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI scale: drop the byz=0.1 column, smaller "
                         "overhead stack, 4-round watchdog run")
    ap.add_argument("--out", default=None,
                    help="output path (default <repo>/BENCH_robust.json)")
    ap.add_argument("--parse", default=None, metavar="FILE",
                    help="validate a previously written result file and "
                         "exit (CI regression gate)")
    args = ap.parse_args(argv)
    if args.parse:
        parse_check(args.parse)
        return []
    return run_and_save(quick=args.quick, out=args.out)


if __name__ == "__main__":
    main()
