"""2-D federated mesh benchmark: round wall-clock and peak per-device
state bytes vs ``model_shards`` for a transformer cohort.

The tentpole question ISSUE 10 asks this benchmark to answer: does
folding the cohort's device mesh from 1-D ``(clients,)`` into 2-D
``(clients, model)`` actually shrink the per-device resident state —
stacked params + Adam state of a reduced-granite ``lm_tokens`` cohort —
~linearly with the model-shard count?

Sweep: ``model_shards ∈ {0, 2, 4}``, every row in a fresh subprocess
with ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (jax fixes
the device count at first init, so one process cannot sweep it). The
client axis is held at ONE device row (``num_devices = max(1,
model_shards)``) so the only thing changing between rows is how many
ways each client's weight matrices shard over the model axis:

    model_shards=0  ->  1-device 1-D mesh   (the unsharded baseline)
    model_shards=2  ->  (1, 2) mesh         (heads/ff/vocab split 2-way)
    model_shards=4  ->  (1, 4) mesh

Peak bytes are measured from the arrays themselves — max over device ids
of the summed ``addressable_shards`` sizes across every params/opt-state
leaf of every cohort — so replication (norm scales, biases) is counted
honestly: the shrink is ~linear on the shardable majority, not on the
small replicated residue.

    PYTHONPATH=src:. python benchmarks/fd_transformer.py --quick
    PYTHONPATH=src:. python benchmarks/fd_transformer.py --parse BENCH_fdx.json

``--parse FILE`` is CI's regression gate: rows for all three shard
counts, sane times, and peak bytes strictly decreasing with >= 1.3x
per shard doubling (honest about the replicated residue), else exit
non-zero. Results land at the repo root as ``BENCH_fdx.json``.

On CPU the timing rows validate the wiring (a forced-host-device CPU
mesh adds collective overhead, not speed); the bytes rows are the
deployment-relevant artifact — they are exact on any backend.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import numpy as np

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(REPO_ROOT, "BENCH_fdx.json")
FORCED_DEVICES = 4
SHARD_SWEEP = (0, 2, 4)
CLIENTS = 4
SAMPLES_PER_CLIENT = 96
# gate: each shard doubling must shed at least this factor of peak bytes
# (2.0 would ignore the replicated residue — norms, biases, embeddings'
# unshardable mates — which is real and stays resident on every device)
MIN_SHRINK_PER_DOUBLING = 1.3


def peak_state_bytes_per_device(engine) -> int:
    """Max over devices of resident params + opt-state bytes, summed from
    each leaf's ``addressable_shards`` (replicated leaves count once per
    device, sharded leaves once per shard — the honest HBM number)."""
    import jax
    per_dev: dict = {}
    for cohort in engine.cohorts:
        for tree in (cohort.params, cohort.opt_state):
            for leaf in jax.tree.leaves(tree):
                for sh in getattr(leaf, "addressable_shards", ()):
                    d = sh.device.id
                    per_dev[d] = per_dev.get(d, 0) + sh.data.nbytes
    return int(max(per_dev.values())) if per_dev else 0


def bench_shards(model_shards: int, rounds: int, seed: int = 0) -> dict:
    """One sweep row: a transformer cohort (lm_tokens -> reduced granite
    backbones, flash-attention on the distill hot path) through warmup +
    timed rounds at the given model-shard count."""
    from repro.common.types import FedConfig
    from repro.core.methods import get_method
    from repro.core.protocol import run_round
    from repro.fed import simulator

    rounds = max(rounds, 1)
    # client axis held at ONE device row: shard count is the only variable
    num_devices = max(1, model_shards)
    cfg = FedConfig(num_clients=CLIENTS, rounds=rounds, method="edgefd",
                    proxy_batch=64, batch_size=16, lr=1e-2, seed=seed,
                    engine="cohort", num_devices=num_devices,
                    model_shards=model_shards)
    clients, server, x_test, y_test = simulator.build_experiment(
        cfg, "lm_tokens", n_train=SAMPLES_PER_CLIENT * CLIENTS, n_test=256)
    eng = simulator.build_engine(clients, cfg)
    method = get_method(cfg.method)

    import jax
    t0 = time.perf_counter()
    eng.learn_dres(jax.random.PRNGKey(cfg.seed))
    run_round(0, eng, server, method, cfg, x_test, y_test)
    warm_s = time.perf_counter() - t0
    peak = peak_state_bytes_per_device(eng)

    times = []
    for r in range(1, rounds + 1):
        log = run_round(r, eng, server, method, cfg, x_test, y_test)
        times.append(log.wall_s)
    return {"model_shards": model_shards, "num_devices": num_devices,
            "mesh": "(1,)" if model_shards == 0 else f"(1, {model_shards})",
            "clients": CLIENTS, "warmup_s": warm_s,
            "round_s": float(np.median(times)),
            "peak_state_bytes_per_device": peak,
            "final_acc": log.mean_acc}


def shard_sweep(rounds: int) -> list:
    """One fresh subprocess per shard count, each with the same forced
    host-device topology (the cohort_scaling.device_sweep idiom)."""
    rows = []
    print(f"{'shards':>7} {'mesh':>7} {'warmup_s':>9} {'round_s':>9} "
          f"{'peak_MB/dev':>12} {'shrink':>7}")
    base_peak = None
    for ms in SHARD_SWEEP:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (
            env.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={FORCED_DEVICES}")
        env.setdefault("JAX_PLATFORMS", "cpu")
        env["PYTHONPATH"] = os.pathsep.join(
            [REPO_ROOT, os.path.join(REPO_ROOT, "src"),
             env.get("PYTHONPATH", "")])
        res = subprocess.run(
            [sys.executable, os.path.abspath(__file__),
             "--_forced-shards", str(ms), "--rounds", str(rounds)],
            env=env, capture_output=True, text=True,
            timeout=900)  # a wedged child names its shard count loudly
        if res.returncode != 0:
            raise RuntimeError(
                f"shard sweep child (model_shards={ms}) failed:\n"
                f"{res.stdout}\n{res.stderr}")
        row = next(json.loads(line[4:])
                   for line in res.stdout.splitlines()
                   if line.startswith("ROW "))
        rows.append(row)
        peak = row["peak_state_bytes_per_device"]
        base_peak = base_peak if base_peak is not None else peak
        print(f"{ms:>7} {row['mesh']:>7} {row['warmup_s']:9.2f} "
              f"{row['round_s']:9.3f} {peak/1e6:12.3f} "
              f"{base_peak/peak:6.2f}x")
    return rows


def parse_check(path: str) -> None:
    """Regression gate: all three shard counts present, sane timings, and
    peak per-device bytes shrinking >= MIN_SHRINK_PER_DOUBLING per shard
    doubling. Exits non-zero with a reason on any failure."""
    with open(path) as f:
        data = json.load(f)
    rows = data["rows"] if isinstance(data, dict) else data
    by_ms = {r.get("model_shards"): r for r in rows}
    if set(by_ms) != set(SHARD_SWEEP):
        raise SystemExit(
            f"{path}: expected model_shards rows {sorted(SHARD_SWEEP)}, "
            f"got {sorted(by_ms)}")
    for r in rows:
        if not (r.get("round_s", 0) > 0 and r.get("warmup_s", 0) > 0):
            raise SystemExit(f"{path}: non-positive timing in row {r}")
        if not 0.0 <= r.get("final_acc", -1.0) <= 1.0:
            raise SystemExit(f"{path}: final_acc out of [0, 1] in {r}")
        if r.get("peak_state_bytes_per_device", 0) <= 0:
            raise SystemExit(f"{path}: missing peak bytes in row {r}")
    peaks = [by_ms[ms]["peak_state_bytes_per_device"] for ms in SHARD_SWEEP]
    for (ms_a, a), (ms_b, b) in zip(zip(SHARD_SWEEP, peaks),
                                    zip(SHARD_SWEEP[1:], peaks[1:])):
        if b >= a:
            raise SystemExit(
                f"{path}: peak bytes/device did not shrink "
                f"(shards {ms_a}: {a} -> shards {ms_b}: {b})")
        if a / b < MIN_SHRINK_PER_DOUBLING:
            raise SystemExit(
                f"{path}: shard doubling {ms_a}->{ms_b} shed only "
                f"{a/b:.2f}x peak bytes (< {MIN_SHRINK_PER_DOUBLING}x)")
    print(f"{path}: {len(rows)} rows OK "
          f"(peak MB/dev {peaks[0]/1e6:.3f} -> {peaks[-1]/1e6:.3f}, "
          f"{peaks[0]/peaks[-1]:.2f}x at {SHARD_SWEEP[-1]} shards)")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="1 timed round per row (CI bench-smoke scale)")
    ap.add_argument("--rounds", type=int, default=None,
                    help="timed rounds per row (after 1 warmup round); "
                         "default 1 with --quick else 3")
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help="output JSON path (default: repo-root "
                         "BENCH_fdx.json, like the other BENCH_* files)")
    ap.add_argument("--parse", default=None, metavar="FILE",
                    help="validate a previously written result file and "
                         "exit (CI regression gate)")
    ap.add_argument("--_forced-shards", type=int, default=None,
                    dest="forced_shards", help=argparse.SUPPRESS)
    args = ap.parse_args(argv)

    if args.parse:
        parse_check(args.parse)
        return []

    rounds = args.rounds if args.rounds is not None \
        else (1 if args.quick else 3)

    if args.forced_shards is not None:
        # sweep child: the forced host-device count is already in XLA_FLAGS
        row = bench_shards(args.forced_shards, rounds)
        print("ROW " + json.dumps(row))
        return [row]

    rows = shard_sweep(rounds)
    with open(args.out, "w") as f:
        json.dump({"benchmark": "fd_transformer_shard_sweep",
                   "forced_host_devices": FORCED_DEVICES,
                   "host_cpu_count": os.cpu_count(),
                   "note": "client axis held at 1 device row; peak bytes "
                           "= max over devices of summed addressable "
                           "shards across stacked params + Adam state "
                           "(replicated residue counted); CPU timings "
                           "validate wiring, bytes are exact",
                   "rows": rows}, f, indent=2)
    print(f"saved {args.out}")
    return rows


if __name__ == "__main__":
    main()
