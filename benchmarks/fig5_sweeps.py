"""Fig 5 analog: ID-threshold and proxy-fraction sweeps.

Paper claims: (i) raising T^ID beyond the calibrated point admits OOD
samples and degrades accuracy; (ii) proxy fraction 20% ≈ 80% (diminishing
returns thanks to the filter).
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import emit, save_json
from repro.common.types import FedConfig
from repro.fed import simulator


def threshold_sweep(dataset="mnist_feat", thresholds=(2.0, 4.0, 6.0, 9.0, 14.0),
                    rounds=5, **kw):
    rows = []
    for thr in thresholds:
        cfg = FedConfig(num_clients=5, rounds=rounds, method="edgefd",
                        scenario="strong", id_threshold=thr, proxy_batch=300,
                        lr=1e-2)
        res = simulator.run(cfg, dataset, **kw)
        rows.append({"threshold": thr, "best_acc": res.best_acc,
                     "id_fraction": res.rounds[-1].id_fraction})
        emit(f"fig5/threshold={thr}", 0.0,
             f"best_acc={res.best_acc:.4f} id_frac={res.rounds[-1].id_fraction:.2f}")
    return rows


def proxy_sweep(dataset="mnist_feat", fractions=(0.1, 0.2, 0.4, 0.8),
                rounds=5, **kw):
    rows = []
    for a in fractions:
        cfg = FedConfig(num_clients=5, rounds=rounds, method="edgefd",
                        scenario="strong", proxy_fraction=a, proxy_batch=300,
                        lr=1e-2)
        res = simulator.run(cfg, dataset, **kw)
        rows.append({"alpha": a, "best_acc": res.best_acc})
        emit(f"fig5/proxy_alpha={a}", 0.0, f"best_acc={res.best_acc:.4f}")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    kw = dict(n_train=1500, n_test=400) if args.quick else \
        dict(n_train=4000, n_test=800)
    rounds = 3 if args.quick else 5
    thr = threshold_sweep(rounds=rounds, **kw)
    prox = proxy_sweep(rounds=rounds, **kw)
    save_json("fig5_sweeps.json", {"threshold": thr, "proxy": prox})
    accs = [r["best_acc"] for r in thr]
    print(f"\nthreshold sweep accs: {[round(a,3) for a in accs]} "
          f"(paper: decreasing beyond the calibrated point)")
    paccs = [r["best_acc"] for r in prox]
    print(f"proxy sweep accs: {[round(a,3) for a in paccs]} "
          f"(paper: flat beyond 20%)")


if __name__ == "__main__":
    main()
