"""Checkpoint overhead of the resumable federated service.

The service driver (``repro.launch.fed_serve``) snapshots the full
experiment — scheduler window + in-flight rounds, server buffers/pending
reports, engine params/opt-state, rng streams — every N rounds through
``repro.checkpoint.save_state`` (atomic write + fsync). This benchmark
measures what that durability costs:

  * per-round wall overhead of ``snapshot() + save_state`` (seconds and
    as a fraction of the round's compute);
  * checkpoint size on disk;
  * one restore (``restore_state + RoundScheduler.restore``) latency;
  * and it verifies the resumed run's remaining rounds are bit-for-bit
    identical to the uninterrupted ones (the service's headline
    guarantee — a benchmark that measured a broken checkpoint would be
    noise).

    PYTHONPATH=src:. python benchmarks/serve_resume.py            # C=32
    PYTHONPATH=src:. python benchmarks/serve_resume.py --quick    # CI

Writes ``BENCH_serve.json`` at the repo root per the BENCH convention;
``--parse FILE`` re-validates a result file and exits non-zero on
regression — CI's bench-smoke job runs the quick benchmark then this
gate.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import tempfile
import time

SAMPLES_PER_CLIENT = 64
MLP_HIDDEN = (64,)
# host-measured fields can never match across runs; everything else must
OVERHEAD_FRAC_MAX = 0.5  # ckpt time vs round compute, quick-scale gate
MEASURED_FIELDS = ("wall_s", "phase_s")

FIXED_COSTS = {"local_train": 1.0, "report": 0.1, "aggregate": 0.3,
               "distill": 1.0, "eval": 0.0}


def _build(cfg):
    import jax

    from repro.core.methods import get_method
    from repro.fed import simulator
    from repro.fed.scheduler import RoundScheduler
    clients, server, x_test, y_test = simulator.build_experiment(
        cfg, "mnist_feat", n_train=SAMPLES_PER_CLIENT * cfg.num_clients,
        n_test=512, mlp_hidden=MLP_HIDDEN)
    eng = simulator.build_engine(clients, cfg)
    eng.learn_dres(jax.random.PRNGKey(cfg.seed))
    return RoundScheduler(eng, server, get_method(cfg.method), cfg,
                          x_test, y_test, sim_phase_costs=FIXED_COSTS)


def _strip(logs):
    return [{k: v for k, v in dataclasses.asdict(lg).items()
             if k not in MEASURED_FIELDS} for lg in logs]


def bench(*, clients: int, rounds: int, engine: str = "loop",
          seed: int = 0) -> dict:
    from repro.checkpoint import restore_state, save_state
    from repro.common.types import FedConfig
    cfg = FedConfig(num_clients=clients, rounds=rounds, method="edgefd",
                    scenario="iid", proxy_batch=256, batch_size=32,
                    lr=1e-2, seed=seed, engine=engine,
                    participation_fraction=0.5, staleness_decay=0.5,
                    round_mode="overlap", max_inflight=2)

    # uninterrupted run, no checkpointing: the compute baseline
    sched = _build(cfg)
    t0 = time.perf_counter()
    ref_logs = sched.run_rounds(0, rounds)
    compute_s = time.perf_counter() - t0

    # checkpointed service loop: snapshot + atomic save every round.
    # Alongside the full snapshot, also save the fed_serve production form
    # (``logs_tail=0`` — retired logs stream to the sidecar instead of the
    # checkpoint) to show its bytes stay flat as the service ages.
    with tempfile.TemporaryDirectory() as ckdir, \
            tempfile.TemporaryDirectory() as flatdir:
        sched2 = _build(cfg)
        sched2.begin(0, rounds)
        ckpt_s, ckpt_bytes, n_ckpts = 0.0, 0, 0
        flat_bytes = []
        mid_step = None
        while sched2.has_pending():
            _, _, log = sched2.step()
            if log is not None:
                t0 = time.perf_counter()
                path = save_state(ckdir, len(sched2.logs),
                                  sched2.snapshot().to_tree(), keep_last=3)
                ckpt_s += time.perf_counter() - t0
                ckpt_bytes = os.path.getsize(path)
                n_ckpts += 1
                flat_bytes.append(os.path.getsize(save_state(
                    flatdir, len(sched2.logs),
                    sched2.snapshot(logs_tail=0).to_tree(), keep_last=3)))
                if len(sched2.logs) == max(1, rounds // 2):
                    mid_step = len(sched2.logs)

        # one restore from mid-run, then drain: correctness + latency
        t0 = time.perf_counter()
        tree = restore_state(ckdir, mid_step)
        sched3 = _build(cfg)
        build_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        sched3.restore(tree)
        restore_s = time.perf_counter() - t0
        sched3.drain()
        resume_bitexact = _strip(sched3.logs) == _strip(ref_logs)

    per_round = ckpt_s / max(n_ckpts, 1)
    return {"engine": engine, "clients": clients, "rounds": rounds,
            "compute_s": compute_s,
            "ckpt_total_s": ckpt_s,
            "ckpt_per_round_s": per_round,
            "ckpt_overhead_frac": ckpt_s / compute_s if compute_s else 0.0,
            "ckpt_bytes": ckpt_bytes,
            "ckpt_bytes_flat_first": flat_bytes[0],
            "ckpt_bytes_flat_last": flat_bytes[-1],
            "n_checkpoints": n_ckpts,
            "rebuild_s": build_s,
            "restore_s": restore_s,
            "resume_bitexact": resume_bitexact,
            "final_acc": ref_logs[-1].mean_acc}


def run_and_save(quick: bool = False, out: str | None = None,
                 clients: int | None = None,
                 rounds: int | None = None) -> list:
    clients = clients or (8 if quick else 32)
    rounds = rounds or (4 if quick else 8)
    row = bench(clients=clients, rounds=rounds)
    print(f"C={clients} rounds={rounds}: compute={row['compute_s']:.2f}s "
          f"ckpt={row['ckpt_per_round_s']*1e3:.1f}ms/round "
          f"({100*row['ckpt_overhead_frac']:.1f}% of compute, "
          f"{row['ckpt_bytes']/1e6:.2f}MB) "
          f"restore={row['restore_s']*1e3:.1f}ms "
          f"bitexact={row['resume_bitexact']}")
    out = out or os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_serve.json")
    with open(out, "w") as f:
        json.dump({"benchmark": "serve_resume_overhead",
                   "host_cpu_count": os.cpu_count(),
                   "overhead_frac_max": OVERHEAD_FRAC_MAX,
                   "note": "per-round cost of snapshot()+save_state "
                           "(atomic npz + fsync) in the fed_serve event "
                           "loop, plus one mid-run restore; "
                           "resume_bitexact asserts the restored run's "
                           "logs match the uninterrupted ones",
                   "rows": [row]}, f, indent=2)
    print(f"saved {out}")
    return [row]


def parse_check(path: str) -> None:
    """Regression gate: checkpoint round-trip intact and overhead sane."""
    with open(path) as f:
        data = json.load(f)
    rows = data.get("rows", [])
    if len(rows) != 1:
        raise SystemExit(f"{path}: expected exactly one row, got "
                         f"{len(rows)}")
    r = rows[0]
    if not r.get("resume_bitexact"):
        raise SystemExit(
            f"{path}: resumed run diverged from the uninterrupted one — "
            "the checkpoint round-trip is broken")
    if not (r["n_checkpoints"] == r["rounds"] and r["ckpt_bytes"] > 0):
        raise SystemExit(f"{path}: checkpointing did not run every round "
                         f"({r['n_checkpoints']}/{r['rounds']}, "
                         f"{r['ckpt_bytes']}B)")
    if not (r["compute_s"] > 0 and r["ckpt_per_round_s"] > 0
            and r["restore_s"] > 0):
        raise SystemExit(f"{path}: non-positive timing in {r}")
    first = r.get("ckpt_bytes_flat_first")
    last = r.get("ckpt_bytes_flat_last")
    # one-sided: in-flight overlap state makes individual snapshots vary
    # (and often shrink as rounds drain), but retired history must never
    # accumulate in the checkpoint
    if first is not None and last - first > 1024:
        raise SystemExit(
            f"{path}: logs_tail=0 checkpoint grew {first}B -> {last}B over "
            f"{r['rounds']} rounds — retired-log streaming is not keeping "
            "checkpoint size flat")
    frac_max = data.get("overhead_frac_max", OVERHEAD_FRAC_MAX)
    if r["ckpt_overhead_frac"] > frac_max:
        raise SystemExit(
            f"{path}: checkpointing costs {100*r['ckpt_overhead_frac']:.1f}%"
            f" of round compute (gate {100*frac_max:.0f}%)")
    print(f"{path}: OK — {r['ckpt_per_round_s']*1e3:.1f}ms/round "
          f"({100*r['ckpt_overhead_frac']:.1f}% of compute), "
          f"restore {r['restore_s']*1e3:.1f}ms, bit-exact resume")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI scale: C=8, 4 rounds (default C=32, 8)")
    ap.add_argument("--clients", type=int, default=None)
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--out", default=None,
                    help="output path (default <repo>/BENCH_serve.json)")
    ap.add_argument("--parse", default=None, metavar="FILE",
                    help="validate a previously written result file and "
                         "exit (CI regression gate)")
    args = ap.parse_args(argv)
    if args.parse:
        parse_check(args.parse)
        return []
    return run_and_save(quick=args.quick, out=args.out,
                        clients=args.clients, rounds=args.rounds)


if __name__ == "__main__":
    main()
