"""Table III analog: methods × scenarios accuracy comparison.

Synthetic stand-ins for the paper's datasets (DESIGN.md §7.1); the claim
validated is the ORDERING: EdgeFD ≥ Selective-FD ≫ unfiltered proxy methods
≫ data-free methods under strong non-IID, with the gap closing as data
becomes IID. Also runs the server-filter ablation (EdgeFD needs none).
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import emit, save_json
from repro.common.types import FedConfig
from repro.fed import simulator

METHODS = ["indlearn", "fedmd", "feded", "dsfl", "fkd", "pls",
           "selective-fd", "edgefd"]
SCENARIOS = ["strong", "weak", "iid"]


def run(dataset="mnist_feat", rounds=6, clients=10, n_train=4000, n_test=800,
        methods=METHODS, scenarios=SCENARIOS, seed=0, lr=1e-2):
    table = {}
    for scenario in scenarios:
        for method in methods:
            cfg = FedConfig(num_clients=clients, rounds=rounds, method=method,
                            scenario=scenario, proxy_batch=400, lr=lr,
                            seed=seed)
            res = simulator.run(cfg, dataset, n_train=n_train, n_test=n_test)
            table[(scenario, method)] = res.best_acc
            emit(f"table3/{dataset}/{scenario}/{method}",
                 0.0, f"best_acc={res.best_acc:.4f}")
    return table


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="mnist_feat")
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    kw = {}
    if args.quick:
        kw = dict(rounds=3, clients=5, n_train=1500, n_test=400,
                  methods=["indlearn", "fedmd", "edgefd"],
                  scenarios=["strong", "iid"])
    table = run(dataset=args.dataset, **kw)
    out = {f"{s}/{m}": round(v, 4) for (s, m), v in table.items()}
    save_json(f"table3_{args.dataset}.json", out)
    print("\nscenario".ljust(10), *[m[:9].ljust(10) for m in
                                    sorted({m for _, m in table})])
    for s in sorted({s for s, _ in table}):
        row = [f"{table.get((s, m), float('nan')):.3f}".ljust(10)
               for m in sorted({m for _, m in table})]
        print(s.ljust(10), *row)


if __name__ == "__main__":
    main()
