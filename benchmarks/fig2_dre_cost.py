"""Fig 2 analog: DRE learn/estimate time + memory vs sample count.

KuLSIF-DRE vs KMeans-DRE (1 and 10 centroids) on 50-dimensional data —
exactly the paper's comparison axes. Memory is the analytic working-set
of each phase (Table IV formulas evaluated at the run's sizes), time is
measured wall clock on this host.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save_json, timeit
from repro.core.dre import KMeansDRE, KuLSIFDRE

D = 50


def mem_kulsif_learn(n, m, d=D):
    return (m * m + n * m) * 4          # K11 + K12 f32


def mem_kulsif_est(t, n, m, d=D):
    return t * (n + m) * 4


def mem_kmeans_learn(n, c, d=D):
    return (c * d + n) * 4


def mem_kmeans_est(t, c, d=D):
    return (c * d + t) * 4


def run(sizes=(256, 512, 1024, 2048, 4096), t_test=1024, aux=None):
    key = jax.random.PRNGKey(0)
    rows = []
    test = jax.random.normal(jax.random.fold_in(key, 99), (t_test, D))
    for n in sizes:
        x = jax.random.normal(key, (n, D))
        m = aux or min(n, 1024)

        ku = KuLSIFDRE(num_aux=m, sigma=3.0)
        # .alpha is an array -> block_until_ready actually blocks (dataclass
        # results are not pytrees; timing the bare learn() measured dispatch)
        t_learn_ku = timeit(lambda: ku.learn(jax.random.fold_in(key, 1), x).alpha,
                            iters=3)
        fitted_ku = ku.learn(jax.random.fold_in(key, 1), x)
        t_est_ku = timeit(lambda: fitted_ku.estimate(test), iters=3)

        row = {"n": n, "kulsif_learn_s": t_learn_ku, "kulsif_est_s": t_est_ku,
               "kulsif_learn_mem": mem_kulsif_learn(n, m),
               "kulsif_est_mem": mem_kulsif_est(t_test, n, m)}
        for c in (1, 10):
            km = KMeansDRE(num_centroids=c)
            t_learn = timeit(lambda: km.learn(jax.random.fold_in(key, 2), x).centroids,
                             iters=3)
            fitted = km.learn(jax.random.fold_in(key, 2), x)
            t_est = timeit(lambda: fitted.distances(test), iters=3)
            row[f"kmeans{c}_learn_s"] = t_learn
            row[f"kmeans{c}_est_s"] = t_est
            row[f"kmeans{c}_learn_mem"] = mem_kmeans_learn(n, c)
            row[f"kmeans{c}_est_mem"] = mem_kmeans_est(t_test, c)
        rows.append(row)
        emit(f"fig2/dre_cost/n={n}", row["kulsif_learn_s"] * 1e6,
             f"kulsif_learn={row['kulsif_learn_s']:.4f}s "
             f"kmeans1_learn={row['kmeans1_learn_s']:.4f}s "
             f"speedup={row['kulsif_learn_s']/row['kmeans1_learn_s']:.1f}x")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    sizes = (256, 512, 1024) if args.quick else (256, 512, 1024, 2048, 4096)
    rows = run(sizes=sizes)
    save_json("fig2_dre_cost.json", rows)
    # scaling check: kulsif learn should grow superlinearly, kmeans ~linear
    if len(rows) >= 3:
        r0, r1 = rows[0], rows[-1]
        growth = r1["n"] / r0["n"]
        ku_g = r1["kulsif_learn_s"] / max(r0["kulsif_learn_s"], 1e-9)
        km_g = r1["kmeans1_learn_s"] / max(r0["kmeans1_learn_s"], 1e-9)
        print(f"\nn grew {growth:.0f}x: kulsif learn {ku_g:.1f}x, "
              f"kmeans learn {km_g:.1f}x  (paper: exponential vs linear)")


if __name__ == "__main__":
    main()
