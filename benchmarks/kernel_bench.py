"""Pallas-kernel benchmarks: microbenchmarks + wired hot-path measurements.

Two sections, both per backend where it matters:

* ``micro`` — each kernel wrapper against its pure-jnp oracle at
  FD-realistic sizes (the historical microbenchmarks).
* ``wired`` — the *real* call sites the dispatch layer routes
  (``repro.kernels.dispatch``): a full ``kmeans_fit`` (fused Lloyd step
  vs the reference two-matmul body), one distillation step — forward AND
  backward through ``kd_kl_loss`` (the Pallas path differentiates through
  the custom-VJP backward kernel) — and a ``KuLSIFDRE.learn`` gram-matrix
  solve, each timed on both ``kernel_backend`` values.

On CPU the Pallas backend runs in interpret mode: correctness-scale
numbers only (expect jnp to win — interpret emits the kernel body as
unfused jnp ops). The BlockSpec tiling is the TPU deployment artifact;
on a TPU host the same script times the Mosaic-lowered kernels.

Results land at the repo root as ``BENCH_kernels.json`` (the BENCH_*
convention every other sweep uses); ``--out`` overrides.
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core.distill import kd_kl_loss
from repro.core.dre import KuLSIFDRE
from repro.core.kmeans import kmeans_fit
from repro.kernels import dispatch
from repro.kernels.distill_kl import ops as kl_ops, ref as kl_ref
from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.kernels.kmeans_dist import ops as kd_ops, ref as kd_ref
from repro.kernels.kulsif_rbf import ops as rbf_ops, ref as rbf_ref

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(REPO_ROOT, "BENCH_kernels.json")
BACKENDS = ("jnp", "pallas")


def run_micro(quick=False):
    key = jax.random.PRNGKey(0)
    out = {}

    t, d, c = (1024, 50, 10) if quick else (8192, 50, 10)
    x = jax.random.normal(key, (t, d))
    cent = jax.random.normal(jax.random.fold_in(key, 1), (c, d))
    jit_ref = jax.jit(lambda a, b: kd_ref.min_dist_and_mask(a, b, 7.0))
    t_k = timeit(lambda: kd_ops.min_dist_and_mask(x, cent, 7.0))
    t_r = timeit(lambda: jit_ref(x, cent))
    out["kmeans_dist"] = {"pallas_s": t_k, "ref_s": t_r, "t": t, "d": d, "c": c}
    emit("kernel/kmeans_dist", t_k * 1e6, f"ref={t_r*1e6:.1f}us")

    jit_lref = jax.jit(kd_ref.lloyd_step)
    t_k = timeit(lambda: kd_ops.lloyd_step(x, cent))
    t_r = timeit(lambda: jit_lref(x, cent))
    out["lloyd_step"] = {"pallas_s": t_k, "ref_s": t_r, "t": t, "d": d, "c": c}
    emit("kernel/lloyd_step", t_k * 1e6, f"ref={t_r*1e6:.1f}us")

    n, m = (512, 512) if quick else (2048, 1024)
    a = jax.random.normal(key, (n, d))
    b = jax.random.normal(jax.random.fold_in(key, 2), (m, d))
    jit_ref = jax.jit(lambda p, q: rbf_ref.rbf_matrix(p, q, 3.0))
    t_k = timeit(lambda: rbf_ops.rbf_matrix(a, b, 3.0))
    t_r = timeit(lambda: jit_ref(a, b))
    out["kulsif_rbf"] = {"pallas_s": t_k, "ref_s": t_r, "n": n, "m": m}
    emit("kernel/kulsif_rbf", t_k * 1e6, f"ref={t_r*1e6:.1f}us")

    nn, k = (2048, 10) if quick else (16384, 10)
    s = jax.random.normal(key, (nn, k)) * 3
    tt = jax.random.normal(jax.random.fold_in(key, 3), (nn, k)) * 3
    jit_ref = jax.jit(lambda p, q: kl_ref.kd_kl_per_sample(p, q, 3.0))
    t_k = timeit(lambda: kl_ops.kd_kl_per_sample(s, tt, 3.0))
    t_r = timeit(lambda: jit_ref(s, tt))
    out["distill_kl"] = {"pallas_s": t_k, "ref_s": t_r, "n": nn, "k": k}
    emit("kernel/distill_kl", t_k * 1e6, f"ref={t_r*1e6:.1f}us")

    B, N, S, H = (1, 2, 256, 64) if quick else (1, 4, 1024, 64)
    q = jax.random.normal(key, (B, N, S, H))
    kk = jax.random.normal(jax.random.fold_in(key, 4), (B, N, S, H))
    v = jax.random.normal(jax.random.fold_in(key, 5), (B, N, S, H))
    jit_ref = jax.jit(lambda a1, a2, a3: fa_ref.attention(a1, a2, a3))
    t_k = timeit(lambda: fa_ops.attention(q, kk, v, block_q=128, block_k=128),
                 iters=3)
    t_r = timeit(lambda: jit_ref(q, kk, v), iters=3)
    out["flash_attention"] = {"pallas_s": t_k, "ref_s": t_r,
                              "B": B, "N": N, "S": S, "H": H}
    emit("kernel/flash_attention", t_k * 1e6, f"ref={t_r*1e6:.1f}us")
    return out


def run_wired(quick=False, backends=BACKENDS):
    """Time the dispatch layer's real call sites, per kernel_backend."""
    key = jax.random.PRNGKey(0)
    out = {}

    # full kmeans_fit: the fused Lloyd step (pallas) vs the reference body
    # that materialises the (n, k) one-hot and pays a second matmul (jnp)
    n, d, k, iters = (1024, 50, 10, 25) if quick else (8192, 50, 10, 50)
    x = jax.random.normal(key, (n, d)) * 2
    row = {"n": n, "d": d, "k": k, "max_iter": iters}
    for b in backends:
        row[f"{b}_s"] = timeit(
            lambda b=b: kmeans_fit(key, x, k, iters, backend=b), iters=3)
    out["kmeans_fit"] = row
    emit("wired/kmeans_fit", row["pallas_s"] * 1e6,
         f"jnp={row['jnp_s']*1e6:.1f}us")

    # one distill step: forward + backward through kd_kl_loss (the pallas
    # path exercises the custom-VJP backward kernel)
    nn, kc = (2048, 10) if quick else (16384, 10)
    s = jax.random.normal(key, (nn, kc)) * 3
    tt = jax.random.normal(jax.random.fold_in(key, 3), (nn, kc)) * 3
    w = jnp.ones((nn,), jnp.float32)
    row = {"n": nn, "k": kc}
    for b in backends:
        step = jax.jit(jax.value_and_grad(
            lambda ss, b=b: kd_kl_loss(ss, tt, 3.0, w, backend=b)))
        row[f"{b}_s"] = timeit(lambda step=step: step(s))
    out["distill_step_fwd_bwd"] = row
    emit("wired/distill_step", row["pallas_s"] * 1e6,
         f"jnp={row['jnp_s']*1e6:.1f}us")

    # KuLSIF learn: gram construction + m×m solve (Table IV baseline cost)
    np_, aux = (512, 128) if quick else (2048, 256)
    priv = jax.random.normal(key, (np_, d))
    row = {"n_private": np_, "num_aux": aux}
    for b in backends:
        dre = KuLSIFDRE(sigma=3.0, num_aux=aux, kernel_backend=b)
        row[f"{b}_s"] = timeit(
            lambda dre=dre: dre.learn(jax.random.PRNGKey(1), priv).alpha,
            iters=3)
    out["kulsif_learn"] = row
    emit("wired/kulsif_learn", row["pallas_s"] * 1e6,
         f"jnp={row['jnp_s']*1e6:.1f}us")

    # flash attention at its wired call site — the layers layout
    # dispatch.flash_attention that attention_forward's non-chunked branch
    # routes — forward + backward (pallas = fused kernel forward +
    # oracle-recompute custom-VJP backward)
    Bq, Sq, Nh, Hd = (2, 128, 4, 32) if quick else (4, 512, 8, 64)
    qa = jax.random.normal(key, (Bq, Sq, Nh, Hd))
    ka = jax.random.normal(jax.random.fold_in(key, 6), (Bq, Sq, Nh, Hd))
    va = jax.random.normal(jax.random.fold_in(key, 7), (Bq, Sq, Nh, Hd))
    row = {"B": Bq, "S": Sq, "N": Nh, "h": Hd}
    for b in backends:
        step = jax.jit(jax.grad(lambda qq, b=b: jnp.sum(
            dispatch.flash_attention(qq, ka, va, causal=True,
                                     backend=b) ** 2)))
        row[f"{b}_s"] = timeit(lambda step=step: step(qa), iters=3)
    out["flash_attention_fwd_bwd"] = row
    emit("wired/flash_attention", row["pallas_s"] * 1e6,
         f"jnp={row['jnp_s']*1e6:.1f}us")
    return out


def run(quick=False):
    """Micro + wired sections (the registry entry benchmarks/run.py uses)."""
    return {
        "benchmark": "kernels",
        "platform": jax.default_backend(),
        "interpret_mode": jax.default_backend() != "tpu",
        "note": "off-TPU the pallas backend runs in interpret mode "
                "(kernel body emitted as unfused jnp ops): numbers "
                "validate the wiring, the tiling is the TPU artifact",
        "micro": run_micro(quick=quick),
        "wired": run_wired(quick=quick),
    }


def run_and_save(quick=False, out_path: str = DEFAULT_OUT):
    results = run(quick=quick)
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"saved {out_path}")
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help="output JSON path (default: repo-root "
                         "BENCH_kernels.json, like the other BENCH_* files)")
    args = ap.parse_args(argv)
    run_and_save(quick=args.quick, out_path=args.out)


if __name__ == "__main__":
    main()
