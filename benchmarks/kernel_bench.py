"""Pallas-kernel microbenchmarks (interpret mode on CPU: correctness-scale
numbers; the BlockSpec tiling is the TPU deployment artifact).

Compares each kernel wrapper against its jnp oracle at FD-realistic sizes.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import emit, save_json, timeit
from repro.kernels.distill_kl import ops as kl_ops, ref as kl_ref
from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.kernels.kmeans_dist import ops as kd_ops, ref as kd_ref
from repro.kernels.kulsif_rbf import ops as rbf_ops, ref as rbf_ref


def run(quick=False):
    key = jax.random.PRNGKey(0)
    out = {}

    t, d, c = (1024, 50, 10) if quick else (8192, 50, 10)
    x = jax.random.normal(key, (t, d))
    cent = jax.random.normal(jax.random.fold_in(key, 1), (c, d))
    jit_ref = jax.jit(lambda a, b: kd_ref.min_dist_and_mask(a, b, 7.0))
    t_k = timeit(lambda: kd_ops.min_dist_and_mask(x, cent, 7.0))
    t_r = timeit(lambda: jit_ref(x, cent))
    out["kmeans_dist"] = {"pallas_s": t_k, "ref_s": t_r, "t": t, "d": d, "c": c}
    emit("kernel/kmeans_dist", t_k * 1e6, f"ref={t_r*1e6:.1f}us")

    n, m = (512, 512) if quick else (2048, 1024)
    a = jax.random.normal(key, (n, d))
    b = jax.random.normal(jax.random.fold_in(key, 2), (m, d))
    jit_ref = jax.jit(lambda p, q: rbf_ref.rbf_matrix(p, q, 3.0))
    t_k = timeit(lambda: rbf_ops.rbf_matrix(a, b, 3.0))
    t_r = timeit(lambda: jit_ref(a, b))
    out["kulsif_rbf"] = {"pallas_s": t_k, "ref_s": t_r, "n": n, "m": m}
    emit("kernel/kulsif_rbf", t_k * 1e6, f"ref={t_r*1e6:.1f}us")

    nn, k = (2048, 10) if quick else (16384, 10)
    s = jax.random.normal(key, (nn, k)) * 3
    tt = jax.random.normal(jax.random.fold_in(key, 3), (nn, k)) * 3
    jit_ref = jax.jit(lambda p, q: kl_ref.kd_kl_per_sample(p, q, 3.0))
    t_k = timeit(lambda: kl_ops.kd_kl_per_sample(s, tt, 3.0))
    t_r = timeit(lambda: jit_ref(s, tt))
    out["distill_kl"] = {"pallas_s": t_k, "ref_s": t_r, "n": nn, "k": k}
    emit("kernel/distill_kl", t_k * 1e6, f"ref={t_r*1e6:.1f}us")

    B, N, S, H = (1, 2, 256, 64) if quick else (1, 4, 1024, 64)
    q = jax.random.normal(key, (B, N, S, H))
    kk = jax.random.normal(jax.random.fold_in(key, 4), (B, N, S, H))
    v = jax.random.normal(jax.random.fold_in(key, 5), (B, N, S, H))
    jit_ref = jax.jit(lambda a1, a2, a3: fa_ref.attention(a1, a2, a3))
    t_k = timeit(lambda: fa_ops.attention(q, kk, v, block_q=128, block_k=128),
                 iters=3)
    t_r = timeit(lambda: jit_ref(q, kk, v), iters=3)
    out["flash_attention"] = {"pallas_s": t_k, "ref_s": t_r,
                              "B": B, "N": N, "S": S, "H": H}
    emit("kernel/flash_attention", t_k * 1e6, f"ref={t_r*1e6:.1f}us")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    save_json("kernel_bench.json", run(quick=args.quick))


if __name__ == "__main__":
    main()
